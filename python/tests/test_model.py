"""Layer-2 correctness: quantized operator graphs vs the pure-jnp oracles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(99)


@pytest.mark.parametrize("bits", ref.PRECISIONS)
def test_conv2d_matches_oracle(bits):
    x = ref.random_operand(RNG, (2, 4, 9, 9), bits)
    w = ref.random_operand(RNG, (6, 4, 3, 3), bits)
    got = np.asarray(model.conv2d(x, w, stride=1, padding=1, bits=bits))
    want = np.asarray(ref.conv2d_ref(x, w, stride=1, padding=1))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("stride,pad,k", [(1, 0, 1), (1, 1, 3), (2, 1, 3),
                                          (1, 2, 5), (2, 2, 5)])
def test_conv2d_geometry(stride, pad, k):
    x = ref.random_operand(RNG, (1, 3, 11, 11), 8)
    w = ref.random_operand(RNG, (5, 3, k, k), 8)
    got = np.asarray(model.conv2d(x, w, stride=stride, padding=pad, bits=8))
    want = np.asarray(ref.conv2d_ref(x, w, stride=stride, padding=pad))
    np.testing.assert_array_equal(got, want)


def test_pwconv2d_matches_oracle():
    x = ref.random_operand(RNG, (2, 8, 6, 6), 8)
    w = ref.random_operand(RNG, (12, 8), 8)
    got = np.asarray(model.pwconv2d(x, w, bits=8))
    want = np.asarray(ref.pwconv2d_ref(x, w))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("stride", [1, 2])
def test_dwconv2d_matches_oracle(stride):
    x = ref.random_operand(RNG, (2, 5, 9, 9), 8)
    w = ref.random_operand(RNG, (5, 3, 3), 8)
    got = np.asarray(model.dwconv2d(x, w, stride=stride, padding=1, bits=8))
    want = np.asarray(ref.dwconv2d_ref(x, w, stride=stride, padding=1))
    np.testing.assert_array_equal(got, want)


def test_linear_matches_oracle():
    x = ref.random_operand(RNG, (4, 16), 8)
    w = ref.random_operand(RNG, (10, 16), 8)
    got = np.asarray(model.linear(x, w, bits=8))
    want = np.asarray(ref.mm_ref(x, w.T))
    np.testing.assert_array_equal(got, want)


def test_inverted_residual_shapes_and_range():
    x = ref.random_operand(RNG, (1, 8, 8, 8), 8)
    we = ref.random_operand(RNG, (32, 8), 8)
    wd = ref.random_operand(RNG, (32, 3, 3), 8)
    wp = ref.random_operand(RNG, (8, 32), 8)
    out = np.asarray(model.inverted_residual(x, we, wd, wp, stride=1,
                                             bits=8, shift=7))
    assert out.shape == (1, 8, 8, 8)
    lo, hi = ref.qrange(8)
    assert out.min() >= lo and out.max() <= hi


def test_inverted_residual_stride2_no_residual():
    x = ref.random_operand(RNG, (1, 8, 8, 8), 8)
    we = ref.random_operand(RNG, (16, 8), 8)
    wd = ref.random_operand(RNG, (16, 3, 3), 8)
    wp = ref.random_operand(RNG, (12, 16), 8)
    out = np.asarray(model.inverted_residual(x, we, wd, wp, stride=2,
                                             bits=8, shift=7))
    assert out.shape == (1, 12, 4, 4)


def test_vit_mlp_shapes_and_range():
    x = ref.random_operand(RNG, (16, 32), 8)
    w1 = ref.random_operand(RNG, (32, 128), 8)
    w2 = ref.random_operand(RNG, (128, 32), 8)
    out = np.asarray(model.vit_mlp(x, w1, w2, bits=8, shift=7))
    assert out.shape == (16, 32)
    lo, hi = ref.qrange(8)
    assert out.min() >= lo and out.max() <= hi


def test_attention_scores_matches_manual():
    q = ref.random_operand(RNG, (8, 16), 8)
    k = ref.random_operand(RNG, (8, 16), 8)
    got = np.asarray(model.attention_scores(q, k, bits=8, shift=7))
    want = np.asarray(ref.requantize_ref(ref.mm_ref(q, k.T), 7, 8))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(c=st.integers(1, 5), f=st.integers(1, 6), h=st.integers(3, 9),
       bits=st.sampled_from(ref.PRECISIONS), seed=st.integers(0, 2**31 - 1))
def test_conv_hypothesis_sweep(c, f, h, bits, seed):
    rng = np.random.default_rng(seed)
    x = ref.random_operand(rng, (1, c, h, h), bits)
    w = ref.random_operand(rng, (f, c, 3, 3), bits)
    got = np.asarray(model.conv2d(x, w, stride=1, padding=1, bits=bits))
    want = np.asarray(ref.conv2d_ref(x, w, stride=1, padding=1))
    np.testing.assert_array_equal(got, want)


def test_relu_clamps_negative():
    x = np.array([-5, 0, 3], np.int32)
    np.testing.assert_array_equal(np.asarray(model.relu(x)),
                                  np.array([0, 0, 3], np.int32))
