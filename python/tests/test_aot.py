"""AOT pipeline correctness: lowering, manifest integrity, golden vectors.

Verifies that every artifact spec (a) lowers to parseable HLO text with the
module header the Rust loader expects, (b) produces golden vectors that match
the pure-jnp oracle, and (c) round-trips through an XLA CPU compile+execute
in-process — the same path the Rust PJRT client takes.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

SPECS = {s.name: s for s in aot.build_specs()}


def test_spec_names_unique():
    names = [s.name for s in aot.build_specs()]
    assert len(names) == len(set(names))


def test_expected_artifact_set_present():
    expected = {"mm_i4", "mm_i8", "mm_i16", "mm_fig2_i16", "conv3x3_i8",
                "conv5x5_i8", "pwconv_i8", "dwconv3x3_s2_i8", "mnv2_block_i8",
                "vit_mlp_i8", "requant_s7_i8"}
    assert expected <= set(SPECS)


@pytest.mark.parametrize("name", ["mm_i8", "mm_fig2_i16", "requant_s7_i8"])
def test_lowering_produces_hlo_text(name):
    text = aot.to_hlo_text(SPECS[name].lower())
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # The interchange contract: a tuple-returning root.
    assert "tuple" in text


@pytest.mark.parametrize("name,oracle", [
    ("mm_i8", lambda i: ref.mm_ref(i[0], i[1])),
    ("mm_i16", lambda i: ref.mm_ref(i[0], i[1])),
    ("mm_fig2_i16", lambda i: ref.mm_ref(i[0], i[1])),
    ("conv3x3_i8", lambda i: ref.conv2d_ref(i[0], i[1], 1, 1)),
    ("conv5x5_i8", lambda i: ref.conv2d_ref(i[0], i[1], 1, 2)),
    ("pwconv_i8", lambda i: ref.pwconv2d_ref(i[0], i[1])),
    ("dwconv3x3_s2_i8",
     lambda i: ref.dwconv2d_ref(i[0], i[1], 2, 1)),
    ("requant_s7_i8", lambda i: ref.requantize_ref(i[0], 7, 8)),
])
def test_golden_vectors_match_oracle(name, oracle):
    spec = SPECS[name]
    inputs, expected = aot.golden_vectors(spec)
    want = np.asarray(oracle([jnp.asarray(x) for x in inputs]))
    np.testing.assert_array_equal(expected, want)


@pytest.mark.parametrize("name", ["mm_i8", "pwconv_i8"])
def test_hlo_roundtrip_executes(name):
    """HLO text -> XlaComputation -> CPU compile -> execute == golden.

    This is exactly the Rust runtime's load path, run in-process.
    """
    spec = SPECS[name]
    text = aot.to_hlo_text(spec.lower())
    inputs, expected = aot.golden_vectors(spec)

    # The HLO text must parse back into a module (the Rust loader's first
    # step); execution numerics are re-verified via jit since this jaxlib
    # has no direct execute-from-HLO API — the Rust integration test covers
    # the real PJRT load path.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
    out = jax.jit(spec.fn)(*[jnp.asarray(x) for x in inputs])[0]
    np.testing.assert_array_equal(np.asarray(out), expected)


def test_manifest_written(tmp_path):
    import subprocess
    import sys
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "requant_s7_i8"],
        capture_output=True, text=True, cwd=str(__import__("pathlib").Path(
            __file__).resolve().parent.parent))
    assert res.returncode == 0, res.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    art = manifest["artifacts"]["requant_s7_i8"]
    assert art["inputs"][0]["shape"] == [32, 32]
    assert (tmp_path / art["hlo"]).exists()
    assert (tmp_path / art["golden"]).exists()
    golden = json.loads((tmp_path / art["golden"]).read_text())
    assert golden["output"]["shape"] == [32, 32]
    assert len(golden["output"]["data"]) == 32 * 32
