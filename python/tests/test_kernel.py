"""Layer-1 correctness: Pallas MPTU kernels vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path: everything the Rust
runtime will ever execute is lowered from these kernels, so exact integer
equality against ref.py here certifies the numerics of the whole stack.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.mptu import (
    default_k_block,
    mptu_dwconv,
    mptu_matmul,
    mptu_requantize,
    vmem_footprint_bytes,
    VRF_BYTES_PER_LANE,
)

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# mptu_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", ref.PRECISIONS)
@pytest.mark.parametrize("shape", [(4, 8, 8), (16, 16, 16), (13, 37, 9),
                                   (1, 1, 1), (8, 64, 8), (33, 5, 17)])
def test_matmul_matches_oracle(bits, shape):
    m, k, n = shape
    a = ref.random_operand(RNG, (m, k), bits)
    b = ref.random_operand(RNG, (k, n), bits)
    got = np.asarray(mptu_matmul(a, b, bits=bits, tile_r=4, tile_c=4))
    want = np.asarray(ref.mm_ref(a, b))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("tile_r,tile_c", [(2, 2), (2, 8), (8, 2), (8, 8)])
def test_matmul_tile_geometry_invariance(tile_r, tile_c):
    """Output must not depend on the PE-array geometry — only timing does."""
    a = ref.random_operand(RNG, (12, 24), 8)
    b = ref.random_operand(RNG, (24, 12), 8)
    want = np.asarray(ref.mm_ref(a, b))
    got = np.asarray(mptu_matmul(a, b, bits=8, tile_r=tile_r, tile_c=tile_c))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bits", ref.PRECISIONS)
def test_matmul_k_block_invariance(bits):
    """Any PP-multiple reduction blocking produces identical accumulators."""
    pp = ref.PP_FOR_BITS[bits]
    a = ref.random_operand(RNG, (8, 4 * pp * 3), bits)
    b = ref.random_operand(RNG, (4 * pp * 3, 8), bits)
    want = np.asarray(ref.mm_ref(a, b))
    for stages in (1, 2, 3):
        got = np.asarray(mptu_matmul(a, b, bits=bits, tile_r=4, tile_c=4,
                                     k_block=pp * stages))
        np.testing.assert_array_equal(got, want)


def test_matmul_rejects_bad_precision():
    a = np.zeros((4, 4), np.int32)
    with pytest.raises(ValueError, match="unsupported precision"):
        mptu_matmul(a, a, bits=2)


def test_matmul_rejects_mismatched_k():
    a = np.zeros((4, 4), np.int32)
    b = np.zeros((5, 4), np.int32)
    with pytest.raises(ValueError, match="inner-dim"):
        mptu_matmul(a, b, bits=8)


def test_matmul_rejects_non_pp_k_block():
    a = np.zeros((4, 8), np.int32)
    b = np.zeros((8, 4), np.int32)
    with pytest.raises(ValueError, match="multiple of PP"):
        mptu_matmul(a, b, bits=4, k_block=5)


def test_matmul_extreme_values_no_overflow():
    """Full-range 16-bit operands with K small enough for int32 accumulation."""
    lo, hi = ref.qrange(16)
    a = np.full((4, 2), hi, np.int32)
    b = np.full((2, 4), lo, np.int32)
    got = np.asarray(mptu_matmul(a, b, bits=16, tile_r=2, tile_c=2))
    np.testing.assert_array_equal(got, np.asarray(ref.mm_ref(a, b)))


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 24), k=st.integers(1, 48), n=st.integers(1, 24),
    bits=st.sampled_from(ref.PRECISIONS),
    tile_r=st.sampled_from([2, 4, 8]), tile_c=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_sweep(m, k, n, bits, tile_r, tile_c, seed):
    """Property: kernel == oracle over arbitrary shapes/precisions/tiles."""
    rng = np.random.default_rng(seed)
    a = ref.random_operand(rng, (m, k), bits)
    b = ref.random_operand(rng, (k, n), bits)
    got = np.asarray(mptu_matmul(a, b, bits=bits, tile_r=tile_r,
                                 tile_c=tile_c))
    np.testing.assert_array_equal(got, np.asarray(ref.mm_ref(a, b)))


# ---------------------------------------------------------------------------
# mptu_dwconv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_dwconv_matches_oracle(stride, k):
    x = ref.random_operand(RNG, (4, 11, 11), 8)
    w = ref.random_operand(RNG, (4, k, k), 8)
    got = np.asarray(mptu_dwconv(x, w, stride=stride))
    want = np.asarray(ref.dwconv2d_ref(x[None], w, stride=stride)[0])
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 6), h=st.integers(3, 14),
    k=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]),
    bits=st.sampled_from(ref.PRECISIONS), seed=st.integers(0, 2**31 - 1),
)
def test_dwconv_hypothesis_sweep(c, h, k, stride, bits, seed):
    rng = np.random.default_rng(seed)
    x = ref.random_operand(rng, (c, h, h), bits)
    w = ref.random_operand(rng, (c, k, k), bits)
    got = np.asarray(mptu_dwconv(x, w, stride=stride))
    want = np.asarray(ref.dwconv2d_ref(x[None], w, stride=stride)[0])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# mptu_requantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", ref.PRECISIONS)
@pytest.mark.parametrize("shift", [0, 1, 7, 15])
def test_requantize_matches_oracle(bits, shift):
    acc = RNG.integers(-(2 ** 26), 2 ** 26, size=(17, 5)).astype(np.int32)
    got = np.asarray(mptu_requantize(acc, shift=shift, bits=bits))
    want = np.asarray(ref.requantize_ref(acc, shift, bits))
    np.testing.assert_array_equal(got, want)


def test_requantize_saturates():
    acc = np.array([2 ** 30, -(2 ** 30)], np.int32)
    got = np.asarray(mptu_requantize(acc, shift=0, bits=8))
    np.testing.assert_array_equal(got, np.array([127, -128], np.int32))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 300), shift=st.integers(0, 20),
       bits=st.sampled_from(ref.PRECISIONS), seed=st.integers(0, 2**31 - 1))
def test_requantize_hypothesis_sweep(n, shift, bits, seed):
    rng = np.random.default_rng(seed)
    acc = rng.integers(-(2 ** 28), 2 ** 28, size=(n,)).astype(np.int32)
    got = np.asarray(mptu_requantize(acc, shift=shift, bits=bits))
    want = np.asarray(ref.requantize_ref(acc, shift, bits))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# VMEM budget arithmetic (DESIGN.md §Perf / §Hardware-Adaptation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", ref.PRECISIONS)
@pytest.mark.parametrize("tile", [2, 4, 8])
def test_default_blocks_fit_vrf_budget(bits, tile):
    """Default block shapes must fit the 16 KiB/lane VRF budget."""
    kb = default_k_block(bits, 512)
    assert kb % ref.PP_FOR_BITS[bits] == 0
    assert vmem_footprint_bytes(tile, tile, kb) <= VRF_BYTES_PER_LANE


def test_vmem_footprint_monotone_in_tiles():
    f1 = vmem_footprint_bytes(2, 2, 16)
    f2 = vmem_footprint_bytes(8, 8, 16)
    assert f2 > f1
