"""AOT pipeline: lower fixed-shape L2 graphs to HLO text + a manifest.

This is the single build-time bridge between the Python compile path and the
Rust runtime.  Each artifact is a jitted L2 function lowered to stablehlo and
converted to **HLO text** — NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`), while the HLO text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Every function is lowered with `return_tuple=True`; the Rust side unwraps
with `to_tuple1()`.  All boundary tensors are int32 (values constrained to
the active precision's range — see kernels/ref.py).

Usage:  python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclass
class ArtifactSpec:
    """One AOT-compiled computation the Rust runtime can load by name."""

    name: str
    fn: Callable
    input_shapes: Sequence[tuple[int, ...]]
    meta: dict = field(default_factory=dict)

    def lower(self):
        specs = [jax.ShapeDtypeStruct(s, jnp.int32) for s in self.input_shapes]
        return jax.jit(self.fn).lower(*specs)


def _tuple1(fn):
    """Wrap an L2 function so the lowered computation returns a 1-tuple."""

    def wrapped(*args):
        return (fn(*args),)

    return wrapped


def build_specs() -> list[ArtifactSpec]:
    """The artifact set the Rust coordinator and examples depend on.

    Shapes are chosen to (a) cover every operator class of the paper's
    evaluation, (b) exercise all three precisions' PP blocking in the Pallas
    kernel, and (c) stay small enough that interpret-mode lowering is quick.
    """
    specs: list[ArtifactSpec] = []

    # --- MM operator at each precision (Fig. 12 Transformer path). -------
    for bits in ref.PRECISIONS:
        specs.append(ArtifactSpec(
            name=f"mm_i{bits}",
            fn=_tuple1(lambda a, b, bits=bits: model.matmul(a, b, bits=bits)),
            input_shapes=[(32, 64), (64, 32)],
            meta={"op": "mm", "bits": bits, "m": 32, "k": 64, "n": 32},
        ))

    # --- Fig. 2 trace workload: INT16 4x8 MM. -----------------------------
    specs.append(ArtifactSpec(
        name="mm_fig2_i16",
        fn=_tuple1(lambda a, b: model.matmul(a, b, bits=16, tile_r=2,
                                             tile_c=2)),
        input_shapes=[(4, 8), (8, 8)],
        meta={"op": "mm", "bits": 16, "m": 4, "k": 8, "n": 8},
    ))

    # --- CONV operators (Fig. 10/11 benchmark set). ------------------------
    specs.append(ArtifactSpec(
        name="conv3x3_i8",
        fn=_tuple1(lambda x, w: model.conv2d(x, w, stride=1, padding=1,
                                             bits=8)),
        input_shapes=[(1, 8, 12, 12), (16, 8, 3, 3)],
        meta={"op": "conv", "bits": 8, "k": 3, "stride": 1, "pad": 1,
              "in": [1, 8, 12, 12], "out": [1, 16, 12, 12]},
    ))
    specs.append(ArtifactSpec(
        name="conv5x5_i8",
        fn=_tuple1(lambda x, w: model.conv2d(x, w, stride=1, padding=2,
                                             bits=8)),
        input_shapes=[(1, 8, 12, 12), (16, 8, 5, 5)],
        meta={"op": "conv", "bits": 8, "k": 5, "stride": 1, "pad": 2,
              "in": [1, 8, 12, 12], "out": [1, 16, 12, 12]},
    ))
    specs.append(ArtifactSpec(
        name="pwconv_i8",
        fn=_tuple1(lambda x, w: model.pwconv2d(x, w, bits=8)),
        input_shapes=[(1, 16, 8, 8), (32, 16)],
        meta={"op": "pwcv", "bits": 8, "in": [1, 16, 8, 8],
              "out": [1, 32, 8, 8]},
    ))
    specs.append(ArtifactSpec(
        name="dwconv3x3_s2_i8",
        fn=_tuple1(lambda x, w: model.dwconv2d(x, w, stride=2, padding=1,
                                               bits=8)),
        input_shapes=[(1, 8, 13, 13), (8, 3, 3)],
        meta={"op": "dwcv", "bits": 8, "k": 3, "stride": 2, "pad": 1,
              "in": [1, 8, 13, 13], "out": [1, 8, 7, 7]},
    ))

    # --- Composite blocks for the end-to-end examples. ---------------------
    specs.append(ArtifactSpec(
        name="mnv2_block_i8",
        fn=_tuple1(lambda x, we, wd, wp: model.inverted_residual(
            x, we, wd, wp, stride=1, bits=8, shift=7)),
        input_shapes=[(1, 8, 8, 8), (32, 8), (32, 3, 3), (8, 32)],
        meta={"op": "mnv2_block", "bits": 8, "stride": 1, "shift": 7,
              "in": [1, 8, 8, 8], "out": [1, 8, 8, 8]},
    ))
    specs.append(ArtifactSpec(
        name="vit_mlp_i8",
        fn=_tuple1(lambda x, w1, w2: model.vit_mlp(x, w1, w2, bits=8,
                                                   shift=7)),
        input_shapes=[(16, 32), (32, 128), (128, 32)],
        meta={"op": "vit_mlp", "bits": 8, "shift": 7, "in": [16, 32],
              "out": [16, 32]},
    ))
    specs.append(ArtifactSpec(
        name="requant_s7_i8",
        fn=_tuple1(lambda acc: model.requantize(acc, shift=7, bits=8)),
        input_shapes=[(32, 32)],
        meta={"op": "requant", "bits": 8, "shift": 7, "in": [32, 32],
              "out": [32, 32]},
    ))

    return specs


def golden_vectors(spec: ArtifactSpec, seed: int = 2024):
    """Deterministic inputs + oracle output for the Rust golden check.

    Inputs are drawn in the artifact's precision range; the expected output
    is computed by *executing the jitted L2 function in JAX* (which already
    equals the pure-jnp oracle by the pytest suite).
    """
    rng = np.random.default_rng(seed)
    bits = spec.meta.get("bits", 8)
    inputs = [ref.random_operand(rng, s, min(bits, 8))
              for s in spec.input_shapes]
    out = jax.jit(spec.fn)(*[jnp.asarray(x) for x in inputs])[0]
    return inputs, np.asarray(out)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None,
                        help="comma-separated artifact names to rebuild")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text", "artifacts": {}}
    for spec in build_specs():
        if only and spec.name not in only:
            continue
        text = to_hlo_text(spec.lower())
        path = os.path.join(args.out_dir, f"{spec.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)

        inputs, expected = golden_vectors(spec)
        golden = {
            "inputs": [{"shape": list(x.shape), "data": x.reshape(-1).tolist()}
                       for x in inputs],
            "output": {"shape": list(expected.shape),
                       "data": expected.reshape(-1).tolist()},
        }
        gpath = os.path.join(args.out_dir, f"{spec.name}.golden.json")
        with open(gpath, "w") as f:
            json.dump(golden, f)

        manifest["artifacts"][spec.name] = {
            "hlo": f"{spec.name}.hlo.txt",
            "golden": f"{spec.name}.golden.json",
            "inputs": [{"shape": list(s), "dtype": "i32"}
                       for s in spec.input_shapes],
            "output": {"shape": list(np.asarray(expected).shape),
                       "dtype": "i32"},
            "meta": spec.meta,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
