"""Pure-jnp correctness oracles for the SPEED compute stack.

These functions define the *golden numerics* of the machine: what the MPTU
(multi-precision tensor unit) must compute, expressed with plain jax.numpy and
no Pallas. Every Pallas kernel in this package is pytest/hypothesis-verified
against the oracle here, and the Rust cycle simulator is in turn verified
against the AOT-lowered HLO of the L2 graph built on these semantics.

Precision convention
--------------------
SPEED's datapath carries 4-, 8-, and 16-bit signed integers and accumulates in
32 bits (each PE holds a 32-bit accumulator).  At the HLO interchange boundary
we carry every operand as int32 whose *values* are constrained to the active
precision's range; this sidesteps narrow-dtype support gaps in the PJRT
bridge while keeping the arithmetic bit-exact.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

#: Supported operand precisions (bits) — the paper's 4/8/16-bit datapath.
PRECISIONS = (4, 8, 16)

#: Parallelism-within-PE for each precision (sixteen 4-bit multipliers/PE).
PP_FOR_BITS = {16: 1, 8: 4, 4: 16}


def qrange(bits: int) -> tuple[int, int]:
    """Inclusive signed range for a given operand precision."""
    if bits not in PRECISIONS:
        raise ValueError(f"unsupported precision: {bits} (expected 4/8/16)")
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def quantize(x, bits: int):
    """Clamp values into the signed `bits`-bit range (symmetric clip)."""
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x).astype(jnp.int32), lo, hi)


def random_operand(rng: np.random.Generator, shape, bits: int) -> np.ndarray:
    """Seeded synthetic operand with values in the precision's range."""
    lo, hi = qrange(bits)
    return rng.integers(lo, hi + 1, size=shape, dtype=np.int64).astype(np.int32)


def mm_ref(a, b):
    """int32 matrix multiply oracle: (M,K) @ (K,N) -> (M,N), 32-bit acc.

    This is exactly what a #TILE_R x #TILE_C output-stationary PE array
    produces once every K-stage has been accumulated.
    """
    return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def im2col_ref(x, kh: int, kw: int, stride: int, padding: int):
    """im2col: (N,C,H,W) -> ((C*KH*KW, N*OH*OW), OH, OW)."""
    n, c, h, w = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
        h, w = h + 2 * padding, w + 2 * padding
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, :, i:i + stride * oh:stride, j:j + stride * ow:stride]
            patches.append(patch)  # (N, C, OH, OW)
    cols = jnp.stack(patches, axis=2)  # (N, C, KH*KW, OH, OW)
    cols = cols.transpose(1, 2, 0, 3, 4).reshape(c * kh * kw, n * oh * ow)
    return cols, oh, ow


def conv2d_ref(x, w, stride: int = 1, padding: int = 0):
    """Standard convolution oracle (CONV / PWCV when kh=kw=1).

    x: (N, C, H, W) int32; w: (F, C, KH, KW) int32 -> (N, F, OH, OW) int32.
    Implemented as explicit im2col + matmul so it shares the MM oracle's
    accumulation semantics (the paper converts CONV to MM the same way).
    """
    x = jnp.asarray(x, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    n, c, h, wd = x.shape
    f, cw, kh, kw = w.shape
    assert c == cw, f"channel mismatch {c} vs {cw}"
    cols, oh, ow = im2col_ref(x, kh, kw, stride, padding)
    out = mm_ref(w.reshape(f, c * kh * kw), cols)
    return out.reshape(f, n, oh, ow).transpose(1, 0, 2, 3)


def dwconv2d_ref(x, w, stride: int = 1, padding: int = 0):
    """Depth-wise convolution oracle (DWCV).

    x: (N, C, H, W); w: (C, KH, KW) -> (N, C, OH, OW).  Each channel is
    independent — exactly the decoupling the FF dataflow strategy exploits.
    """
    x = jnp.asarray(x, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    n, c, h, wd = x.shape
    cw, kh, kw = w.shape
    assert c == cw
    if padding:
        x = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
        h, wd = h + 2 * padding, wd + 2 * padding
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    out = jnp.zeros((n, c, oh, ow), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = x[:, :, i:i + stride * oh:stride, j:j + stride * ow:stride]
            out = out + patch * w[None, :, i, j, None, None]
    return out


def pwconv2d_ref(x, w):
    """Point-wise (1x1) convolution oracle: x (N,C,H,W), w (F,C) -> (N,F,H,W)."""
    return conv2d_ref(x, jnp.asarray(w, jnp.int32)[:, :, None, None])


def requantize_ref(acc, shift: int, bits: int):
    """Requantize 32-bit accumulators back to `bits` precision.

    Arithmetic right shift with round-half-up, then clip — the standard
    fixed-point epilogue SPEED performs in the result path before the VRF
    write-back.
    """
    acc = jnp.asarray(acc, jnp.int32)
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    lo, hi = qrange(bits)
    return jnp.clip(acc, lo, hi)


def relu_ref(x):
    """ReLU on integer activations (vector-ALU op in SPEED)."""
    return jnp.maximum(jnp.asarray(x, jnp.int32), 0)
