"""Layer-1 Pallas kernels: the MPTU tensor core as a tiled compute kernel.

The paper's MPTU is a #TILE_R x #TILE_C array of output-stationary PEs, each
holding sixteen 4-bit multipliers that fuse into 1x16-bit / 4x8-bit / 16x4-bit
MACs per cycle (PP = parallelism-within-PE).  The Pallas adaptation for a
tiled-memory machine (see DESIGN.md §Hardware-Adaptation):

* the (TILE_R, TILE_C) *output tile* is the Pallas block shape — the grid
  walks output tiles the way the result queue walks the VRF;
* the reduction dimension is blocked by ``k_block``, a multiple of PP, so one
  grid step along k consumes an integer number of the paper's dataflow
  "stages" (one stage = PP input-channel elements per PE);
* the output block stays resident across the k grid dimension and is
  initialised under ``pl.when(k == 0)`` — the output-stationary strategy of
  the PE's 32-bit accumulator, expressed as an accumulator-carried grid;
* block shapes are sized against the 16 KiB/lane VRF budget (VMEM ≈ VRF);
  :func:`vmem_footprint_bytes` reports the arithmetic used in DESIGN.md §Perf.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowering emits plain HLO that the
Rust runtime's PJRT CPU client executes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PP_FOR_BITS, PRECISIONS

#: VRF capacity per lane (bytes) — the paper's 16 KiB configuration.
VRF_BYTES_PER_LANE = 16 * 1024


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def default_k_block(bits: int, k: int) -> int:
    """Reduction block: a multiple of PP covering up to 8 dataflow stages."""
    pp = PP_FOR_BITS[bits]
    stages = max(1, min(8, k // pp if k >= pp else 1))
    return pp * stages


def vmem_footprint_bytes(tile_r: int, tile_c: int, k_block: int) -> int:
    """Per-grid-step VMEM bytes: input tile + weight tile + int32 accumulator.

    Mirrors the VRF-budget arithmetic of the hardware: the operand queues and
    accumulator of one MPTU invocation must fit the lane-local storage.
    """
    a_tile = tile_r * k_block * 4
    b_tile = k_block * tile_c * 4
    acc = tile_r * tile_c * 4
    return a_tile + b_tile + acc


def _mm_kernel(a_ref, b_ref, o_ref):
    """Output-stationary tile MAC: o += a @ b with 32-bit accumulation."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "tile_r", "tile_c",
                                             "k_block", "interpret"))
def mptu_matmul(a, b, *, bits: int = 8, tile_r: int = 8, tile_c: int = 8,
                k_block: int | None = None, interpret: bool = True):
    """Multi-precision tile matmul on the MPTU PE-array schedule.

    a: (M, K) int32 values in `bits` range; b: (K, N) likewise.
    Returns (M, N) int32 — identical to :func:`ref.mm_ref`.

    M/N/K need not be multiples of the tile sizes; operands are zero-padded
    (zeros contribute nothing to the MAC, matching the hardware's masked
    lanes at tensor edges) and the result is cropped.
    """
    if bits not in PRECISIONS:
        raise ValueError(f"unsupported precision: {bits}")
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner-dim mismatch: {k} vs {k2}")
    kb = k_block if k_block is not None else default_k_block(bits, k)
    pp = PP_FOR_BITS[bits]
    if kb % pp:
        raise ValueError(f"k_block {kb} must be a multiple of PP={pp}")

    mp, np_, kp = _ceil_to(m, tile_r), _ceil_to(n, tile_c), _ceil_to(k, kb)
    a_pad = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_pad = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    grid = (mp // tile_r, np_ // tile_c, kp // kb)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, kb), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((kb, tile_c), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_r, tile_c), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(a_pad, b_pad)
    return out[:m, :n]


def _dw_kernel(x_ref, w_ref, o_ref, *, kh, kw, stride, oh, ow):
    """Per-channel 2D correlation (DWCV) — the FF strategy's inner stage.

    One grid step owns one (channel) plane: inputs are traversed along the
    feature-map dimension with the same weights multiplied every stage,
    exactly the OP1-only schedule of the FF dataflow.
    """
    x = x_ref[...]  # (1, H, W)
    w = w_ref[...]  # (1, kh, kw)
    acc = jnp.zeros((1, oh, ow), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x, (0, i, j), (1, i + stride * (oh - 1) + 1,
                               j + stride * (ow - 1) + 1), (1, stride, stride))
            acc = acc + patch * w[0, i, j]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "interpret"))
def mptu_dwconv(x, w, *, stride: int = 1, interpret: bool = True):
    """Depth-wise convolution kernel, one channel plane per grid step.

    x: (C, H, W) int32; w: (C, KH, KW) int32 -> (C, OH, OW) int32.
    (Batch and padding are handled by the L2 graph, which pads before the
    call — the hardware VLDU likewise delivers pre-padded tiles.)
    """
    x = jnp.asarray(x, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    c, h, wd = x.shape
    cw, kh, kw = w.shape
    assert c == cw
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    kern = functools.partial(_dw_kernel, kh=kh, kw=kw, stride=stride,
                             oh=oh, ow=ow)
    return pl.pallas_call(
        kern,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, h, wd), lambda ci: (ci, 0, 0)),
            pl.BlockSpec((1, kh, kw), lambda ci: (ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow), lambda ci: (ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.int32),
        interpret=interpret,
    )(x, w)


def _requant_kernel(acc_ref, o_ref, *, shift, lo, hi):
    acc = acc_ref[...]
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    o_ref[...] = jnp.clip(acc, lo, hi)


@functools.partial(jax.jit, static_argnames=("shift", "bits", "interpret"))
def mptu_requantize(acc, *, shift: int, bits: int, interpret: bool = True):
    """Result-path epilogue: shift-round-clip 32-bit accums to `bits` range.

    Runs as a flat elementwise Pallas kernel (the vector-ALU path in SPEED).
    """
    from .ref import qrange

    lo, hi = qrange(bits)
    acc = jnp.asarray(acc, jnp.int32)
    flat = acc.reshape(-1)
    n = flat.shape[0]
    blk = min(1024, n)
    npad = _ceil_to(n, blk)
    flat = jnp.pad(flat, (0, npad - n))
    kern = functools.partial(_requant_kernel, shift=shift, lo=lo, hi=hi)
    out = pl.pallas_call(
        kern,
        grid=(npad // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.int32),
        interpret=interpret,
    )(flat)
    return out[:n].reshape(acc.shape)
