"""Layer-2 JAX compute graph: quantized MP-DNN operators built on the MPTU.

This module is the machine's *functional contract*: every operator SPEED
executes (MM, CONV, PWCV, DWCV, requantize, relu) expressed as a JAX graph
that calls the Layer-1 Pallas kernels.  `aot.py` lowers fixed-shape instances
of these functions to HLO text; the Rust coordinator executes those artifacts
via PJRT and cross-checks the cycle simulator's functional output against
them.

Everything here is build-time Python — never imported on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels.mptu import mptu_dwconv, mptu_matmul, mptu_requantize

#: Default MPTU geometry: the paper's four-lane reference config has a 2x2
#: tensor core per lane; the fused logical array seen by the L2 graph is
#: (lanes * TILE_R) x TILE_C for MM-style operators.
DEFAULT_TILE_R = 8
DEFAULT_TILE_C = 8


def matmul(a, b, *, bits: int = 8, tile_r: int = DEFAULT_TILE_R,
           tile_c: int = DEFAULT_TILE_C):
    """MM operator: (M,K) @ (K,N) int32 with `bits`-range operands."""
    return mptu_matmul(a, b, bits=bits, tile_r=tile_r, tile_c=tile_c)


def conv2d(x, w, *, stride: int = 1, padding: int = 0, bits: int = 8,
           tile_r: int = DEFAULT_TILE_R, tile_c: int = DEFAULT_TILE_C):
    """CONV operator via im2col + MPTU matmul (FFCS-mapped in hardware).

    x: (N, C, H, W), w: (F, C, KH, KW) -> (N, F, OH, OW) int32 accumulators.
    """
    x = jnp.asarray(x, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    n, c, h, wd = x.shape
    f, cw, kh, kw = w.shape
    assert c == cw
    cols, oh, ow = ref.im2col_ref(x, kh, kw, stride, padding)
    out = matmul(w.reshape(f, c * kh * kw), cols, bits=bits,
                 tile_r=tile_r, tile_c=tile_c)
    return out.reshape(f, n, oh, ow).transpose(1, 0, 2, 3)


def pwconv2d(x, w, *, bits: int = 8, tile_r: int = DEFAULT_TILE_R,
             tile_c: int = DEFAULT_TILE_C):
    """PWCV operator (1x1 conv, CF-mapped in hardware): w is (F, C)."""
    x = jnp.asarray(x, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    n, c, h, wd = x.shape
    f, cw = w.shape
    assert c == cw
    out = matmul(w, x.transpose(1, 0, 2, 3).reshape(c, n * h * wd),
                 bits=bits, tile_r=tile_r, tile_c=tile_c)
    return out.reshape(f, n, h, wd).transpose(1, 0, 2, 3)


def dwconv2d(x, w, *, stride: int = 1, padding: int = 0, bits: int = 8):
    """DWCV operator (FF-mapped in hardware): x (N,C,H,W), w (C,KH,KW)."""
    x = jnp.asarray(x, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    if padding:
        x = jnp.pad(x, ((0, 0), (0, 0), (padding, padding),
                        (padding, padding)))
    outs = [mptu_dwconv(x[i], w, stride=stride) for i in range(x.shape[0])]
    return jnp.stack(outs, axis=0)


def requantize(acc, *, shift: int, bits: int):
    """Result-path epilogue (shift-round-clip) on 32-bit accumulators."""
    return mptu_requantize(acc, shift=shift, bits=bits)


def relu(x):
    """Vector-ALU ReLU."""
    return jnp.maximum(jnp.asarray(x, jnp.int32), 0)


def linear(x, w, *, bits: int = 8, tile_r: int = DEFAULT_TILE_R,
           tile_c: int = DEFAULT_TILE_C):
    """Fully-connected layer: x (B, K) @ w.T with w (N, K)."""
    return matmul(jnp.asarray(x, jnp.int32), jnp.asarray(w, jnp.int32).T,
                  bits=bits, tile_r=tile_r, tile_c=tile_c)


def inverted_residual(x, w_expand, w_dw, w_project, *, stride: int = 1,
                      bits: int = 8, shift: int = 7):
    """MobileNetV2 inverted-residual block: PWCV -> DWCV -> PWCV.

    The paper's model-level evaluation is dominated by exactly this
    composition (CF strategy for the two PWCVs, FF for the DWCV).  All
    intermediate activations are requantized back to `bits`.
    x: (N, C, H, W); w_expand: (E, C); w_dw: (E, 3, 3); w_project: (F, E).
    Residual add is applied when stride == 1 and C == F.
    """
    h = requantize(relu(pwconv2d(x, w_expand, bits=bits)),
                   shift=shift, bits=bits)
    h = requantize(relu(dwconv2d(h, w_dw, stride=stride, padding=1,
                                 bits=bits)), shift=shift, bits=bits)
    h = requantize(pwconv2d(h, w_project, bits=bits), shift=shift, bits=bits)
    if stride == 1 and x.shape[1] == h.shape[1]:
        h = requantize(x + h, shift=0, bits=bits)
    return h


def vit_mlp(x, w1, w2, *, bits: int = 8, shift: int = 7):
    """Transformer MLP block: two MMs with ReLU between (MM strategy).

    x: (T, D); w1: (D, 4D); w2: (4D, D).
    """
    h = requantize(relu(matmul(x, w1, bits=bits)), shift=shift, bits=bits)
    return requantize(matmul(h, w2, bits=bits), shift=shift, bits=bits)


def attention_scores(q, k, *, bits: int = 8, shift: int = 7):
    """Q @ K^T score matrix — the Transformer MM the paper's Fig. 1 calls out."""
    return requantize(matmul(q, jnp.asarray(k, jnp.int32).T, bits=bits),
                      shift=shift, bits=bits)
