//! Disassembler: decoded instructions back to the assembler's text syntax.
//!
//! `assemble(disassemble(i)) == i` for every supported instruction — the
//! round-trip property is enforced by tests here and by the proptest suite.

use super::insn::{Insn, LdMode, WidthSel};
use crate::config::Precision;

/// Render one instruction in the assembler's syntax.
pub fn disassemble(insn: &Insn) -> String {
    match *insn {
        Insn::Addi { rd, rs1, imm } => {
            if rs1 == 0 {
                format!("li x{rd}, {imm}")
            } else {
                format!("addi x{rd}, x{rs1}, {imm}")
            }
        }
        Insn::Vsetvli { rd, rs1, vtype } => format!("vsetvli x{rd}, x{rs1}, e{}", vtype.sew),
        Insn::Vle { vd, rs1, eew } => format!("vle{eew}.v v{vd}, (x{rs1})"),
        Insn::Vse { vs3, rs1, eew } => format!("vse{eew}.v v{vs3}, (x{rs1})"),
        Insn::Vmacc { vd, vs1, vs2 } => format!("vmacc.vv v{vd}, v{vs1}, v{vs2}"),
        Insn::Vmul { vd, vs1, vs2 } => format!("vmul.vv v{vd}, v{vs1}, v{vs2}"),
        Insn::Vadd { vd, vs1, vs2 } => format!("vadd.vv v{vd}, v{vs1}, v{vs2}"),
        Insn::Vsub { vd, vs1, vs2 } => format!("vsub.vv v{vd}, v{vs1}, v{vs2}"),
        Insn::Vmax { vd, vs1, vs2 } => format!("vmax.vv v{vd}, v{vs1}, v{vs2}"),
        Insn::Vmin { vd, vs1, vs2 } => format!("vmin.vv v{vd}, v{vs1}, v{vs2}"),
        Insn::Vsra { vd, vs1, vs2 } => format!("vsra.vv v{vd}, v{vs1}, v{vs2}"),
        Insn::Vmv { vd, rs1 } => format!("vmv.v.x v{vd}, x{rs1}"),
        Insn::Vsacfg { rd, zimm, uimm } => match Insn::unpack_cfg(zimm) {
            Some((prec, k, strat)) => {
                if uimm == 0 {
                    format!("vsacfg x{rd}, prec={}, k={k}, strat={strat}", prec.bits())
                } else {
                    format!("vsacfg x{rd}, prec={}, k={k}, strat={strat}, uimm={uimm}", prec.bits())
                }
            }
            None => format!("vsacfg x{rd}, uimm={uimm} # raw zimm={zimm:#x}"),
        },
        Insn::VsacfgDim { rd, rs1, dim } => format!("vsacfg.dim x{rd}, x{rs1}, dim={dim}"),
        Insn::Vsald { vd, rs1, mode, width } => {
            let m = match mode {
                LdMode::Sequential => "seq",
                LdMode::Broadcast => "bcast",
            };
            let w = match width {
                WidthSel::FromCfg => "cfg".to_string(),
                WidthSel::Explicit(Precision::Int4) => "4".to_string(),
                WidthSel::Explicit(Precision::Int8) => "8".to_string(),
                WidthSel::Explicit(Precision::Int16) => "16".to_string(),
            };
            format!("vsald v{vd}, (x{rs1}), {m}, w={w}")
        }
        Insn::Vsam { vd, vs1, vs2, stages } => {
            format!("vsam v{vd}, v{vs1}, v{vs2}, stages={stages}")
        }
        Insn::Vsac { vd, vs1, vs2, stages } => {
            format!("vsac v{vd}, v{vs1}, v{vs2}, stages={stages}")
        }
    }
}

/// Render a whole program, one instruction per line.
pub fn disassemble_program(prog: &[Insn]) -> String {
    prog.iter().map(disassemble).collect::<Vec<_>>().join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assembler::{assemble, assemble_line};
    use crate::isa::insn::{Dim, StrategyKind, Vtype};

    fn roundtrip(i: Insn) {
        let text = disassemble(&i);
        let back = assemble_line(&text).unwrap_or_else(|e| panic!("'{text}': {e}"));
        assert_eq!(back, i, "text was '{text}'");
    }

    #[test]
    fn text_roundtrip_all_forms() {
        roundtrip(Insn::Addi { rd: 1, rs1: 0, imm: 64 });
        roundtrip(Insn::Addi { rd: 1, rs1: 2, imm: -64 });
        roundtrip(Insn::Vsetvli { rd: 0, rs1: 2, vtype: Vtype::new(16) });
        roundtrip(Insn::Vle { vd: 3, rs1: 4, eew: 8 });
        roundtrip(Insn::Vse { vs3: 3, rs1: 4, eew: 64 });
        roundtrip(Insn::Vmacc { vd: 1, vs1: 2, vs2: 3 });
        roundtrip(Insn::Vmv { vd: 1, rs1: 2 });
        roundtrip(Insn::Vsacfg {
            rd: 2,
            zimm: Insn::pack_cfg(crate::config::Precision::Int4, 5, StrategyKind::Cf),
            uimm: 3,
        });
        roundtrip(Insn::VsacfgDim { rd: 0, rs1: 9, dim: Dim::NStages });
        roundtrip(Insn::Vsam { vd: 4, vs1: 5, vs2: 6, stages: 12 });
        roundtrip(Insn::Vsac { vd: 4, vs1: 5, vs2: 6, stages: 1 });
    }

    #[test]
    fn program_roundtrip() {
        let src = "li x1, 16\nvsetvli x0, x1, e8\nvmacc.vv v2, v0, v1";
        let prog = assemble(src).unwrap();
        let text = disassemble_program(&prog);
        assert_eq!(assemble(&text).unwrap(), prog);
    }
}
