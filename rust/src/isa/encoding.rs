//! Exact 32-bit encodings of the SPEED instruction subset.
//!
//! Official instructions follow the RISC-V / RVV v1.0 formats; customized
//! instructions occupy the reserved user-defined major opcodes:
//!
//! * `custom-0` (0b0001011) — `VSACFG` (funct3 000) and `VSACFG.DIM`
//!   (funct3 001);
//! * `custom-1` (0b0101011) — `VSALD` (funct3 000), `VSAM` (funct3 001),
//!   `VSAC` (funct3 010).
//!
//! Bit layouts of the custom space (documented here once, asserted by the
//! round-trip tests):
//!
//! ```text
//! VSACFG      |  zimm[8:0] 31:23 | uimm[4:0] 22:18 | 0 17:15 | 000 | rd | 0001011
//! VSACFG.DIM  |  dim[3:0]  31:28 | 0 27:20 | rs1 19:15       | 001 | rd | 0001011
//! VSALD       |  mode 31:30 | width 29:28 | 0 27:20 | rs1    | 000 | vd | 0101011
//! VSAM        |  stages[6:0] 31:25 | vs2 24:20 | vs1 19:15   | 001 | vd | 0101011
//! VSAC        |  stages[6:0] 31:25 | vs2 24:20 | vs1 19:15   | 010 | vd | 0101011
//! ```

use super::insn::{Dim, Insn, LdMode, Vtype, WidthSel};
use crate::config::Precision;

/// RVV arithmetic/config major opcode (OP-V).
pub const OPC_OP_V: u32 = 0b1010111;
/// Vector-load major opcode (LOAD-FP space, as in RVV).
pub const OPC_LOAD_FP: u32 = 0b0000111;
/// Vector-store major opcode (STORE-FP space).
pub const OPC_STORE_FP: u32 = 0b0100111;
/// Scalar OP-IMM major opcode (ADDI).
pub const OPC_OP_IMM: u32 = 0b0010011;
/// custom-0 major opcode: `VSACFG` / `VSACFG.DIM` / `VSALD`.
pub const OPC_CUSTOM0: u32 = 0b0001011;
/// custom-1 major opcode: `VSAM` / `VSAC`.
pub const OPC_CUSTOM1: u32 = 0b0101011;

const F3_OPIVV: u32 = 0b000;
const F3_OPMVV: u32 = 0b010;
const F3_VSETVLI: u32 = 0b111;
const F6_VADD: u32 = 0b000000;
const F6_VSUB: u32 = 0b000010;
const F6_VMIN: u32 = 0b000101;
const F6_VMAX: u32 = 0b000111;
const F6_VSRA: u32 = 0b101011;
const F6_VMUL: u32 = 0b100101;
const F6_VMACC: u32 = 0b101101;
const F6_VMV: u32 = 0b010111;

/// Errors produced when decoding a 32-bit word.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// The major opcode is not one this ISA subset uses.
    UnknownOpcode(u32),
    /// The opcode is known but the funct3/funct6 pair is not.
    UnknownFunct {
        /// Major opcode of the word.
        opcode: u32,
        /// funct3 field (bits 14:12).
        funct3: u32,
        /// funct6 field (bits 31:26).
        funct6: u32,
    },
    /// A field holds a value with no architectural meaning.
    BadField {
        /// Which field was malformed.
        what: &'static str,
        /// The offending raw value.
        value: u32,
    },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::UnknownOpcode(op) => write!(f, "unknown opcode {op:#09b}"),
            DecodeError::UnknownFunct { opcode, funct3, funct6 } => {
                write!(f, "unknown funct3={funct3:#05b}/funct6={funct6:#08b} for opcode {opcode:#09b}")
            }
            DecodeError::BadField { what, value } => write!(f, "bad {what} field: {value}"),
        }
    }
}

impl std::error::Error for DecodeError {}

fn eew_to_width_bits(eew: u32) -> u32 {
    // RVV mew=0 width encodings: 8 -> 000, 16 -> 101, 32 -> 110, 64 -> 111.
    match eew {
        8 => 0b000,
        16 => 0b101,
        32 => 0b110,
        64 => 0b111,
        _ => 0b101,
    }
}

fn width_bits_to_eew(w: u32) -> Option<u32> {
    match w {
        0b000 => Some(8),
        0b101 => Some(16),
        0b110 => Some(32),
        0b111 => Some(64),
        _ => None,
    }
}

fn widthsel_to_bits(w: WidthSel) -> u32 {
    match w {
        WidthSel::FromCfg => 0,
        WidthSel::Explicit(Precision::Int4) => 1,
        WidthSel::Explicit(Precision::Int8) => 2,
        WidthSel::Explicit(Precision::Int16) => 3,
    }
}

fn bits_to_widthsel(b: u32) -> WidthSel {
    match b {
        1 => WidthSel::Explicit(Precision::Int4),
        2 => WidthSel::Explicit(Precision::Int8),
        3 => WidthSel::Explicit(Precision::Int16),
        _ => WidthSel::FromCfg,
    }
}

/// Encode a decoded instruction into its 32-bit word.
pub fn encode(insn: &Insn) -> u32 {
    match *insn {
        Insn::Addi { rd, rs1, imm } => {
            ((imm as u32 & 0xFFF) << 20)
                | ((rs1 as u32 & 0x1F) << 15)
                | ((rd as u32 & 0x1F) << 7)
                | OPC_OP_IMM
        }
        Insn::Vsetvli { rd, rs1, vtype } => {
            // zimm[10:0] in [30:20]; bit 31 = 0 distinguishes vsetvli.
            ((vtype.to_bits() & 0x7FF) << 20)
                | ((rs1 as u32 & 0x1F) << 15)
                | (F3_VSETVLI << 12)
                | ((rd as u32 & 0x1F) << 7)
                | OPC_OP_V
        }
        Insn::Vle { vd, rs1, eew } => {
            // nf=0 mew=0 mop=00 vm=1 lumop=00000
            (1 << 25)
                | ((rs1 as u32 & 0x1F) << 15)
                | (eew_to_width_bits(eew) << 12)
                | ((vd as u32 & 0x1F) << 7)
                | OPC_LOAD_FP
        }
        Insn::Vse { vs3, rs1, eew } => {
            (1 << 25)
                | ((rs1 as u32 & 0x1F) << 15)
                | (eew_to_width_bits(eew) << 12)
                | ((vs3 as u32 & 0x1F) << 7)
                | OPC_STORE_FP
        }
        Insn::Vmacc { vd, vs1, vs2 } => rvv_arith(F6_VMACC, F3_OPMVV, vd, vs1, vs2),
        Insn::Vmul { vd, vs1, vs2 } => rvv_arith(F6_VMUL, F3_OPMVV, vd, vs1, vs2),
        Insn::Vadd { vd, vs1, vs2 } => rvv_arith(F6_VADD, F3_OPIVV, vd, vs1, vs2),
        Insn::Vsub { vd, vs1, vs2 } => rvv_arith(F6_VSUB, F3_OPIVV, vd, vs1, vs2),
        Insn::Vmax { vd, vs1, vs2 } => rvv_arith(F6_VMAX, F3_OPIVV, vd, vs1, vs2),
        Insn::Vmin { vd, vs1, vs2 } => rvv_arith(F6_VMIN, F3_OPIVV, vd, vs1, vs2),
        Insn::Vsra { vd, vs1, vs2 } => rvv_arith(F6_VSRA, F3_OPIVV, vd, vs1, vs2),
        Insn::Vmv { vd, rs1 } => {
            // vmv.v.x: funct6=010111, vm=1, vs2=0, OPIVX funct3=100
            (F6_VMV << 26) | (1 << 25) | ((rs1 as u32 & 0x1F) << 15) | (0b100 << 12)
                | ((vd as u32 & 0x1F) << 7)
                | OPC_OP_V
        }
        Insn::Vsacfg { rd, zimm, uimm } => {
            ((zimm as u32 & 0x1FF) << 23)
                | ((uimm as u32 & 0x1F) << 18)
                | (0b000 << 12)
                | ((rd as u32 & 0x1F) << 7)
                | OPC_CUSTOM0
        }
        Insn::VsacfgDim { rd, rs1, dim } => {
            ((dim.code() & 0xF) << 28)
                | ((rs1 as u32 & 0x1F) << 15)
                | (0b001 << 12)
                | ((rd as u32 & 0x1F) << 7)
                | OPC_CUSTOM0
        }
        Insn::Vsald { vd, rs1, mode, width } => {
            let m = match mode {
                LdMode::Sequential => 0,
                LdMode::Broadcast => 1,
            };
            (m << 30)
                | (widthsel_to_bits(width) << 28)
                | ((rs1 as u32 & 0x1F) << 15)
                | (0b000 << 12)
                | ((vd as u32 & 0x1F) << 7)
                | OPC_CUSTOM1
        }
        Insn::Vsam { vd, vs1, vs2, stages } => custom1_arith(0b001, vd, vs1, vs2, stages),
        Insn::Vsac { vd, vs1, vs2, stages } => custom1_arith(0b010, vd, vs1, vs2, stages),
    }
}

fn rvv_arith(funct6: u32, funct3: u32, vd: u8, vs1: u8, vs2: u8) -> u32 {
    (funct6 << 26)
        | (1 << 25) // vm = 1 (unmasked)
        | ((vs2 as u32 & 0x1F) << 20)
        | ((vs1 as u32 & 0x1F) << 15)
        | (funct3 << 12)
        | ((vd as u32 & 0x1F) << 7)
        | OPC_OP_V
}

fn custom1_arith(funct3: u32, vd: u8, vs1: u8, vs2: u8, stages: u8) -> u32 {
    ((stages as u32 & 0x7F) << 25)
        | ((vs2 as u32 & 0x1F) << 20)
        | ((vs1 as u32 & 0x1F) << 15)
        | (funct3 << 12)
        | ((vd as u32 & 0x1F) << 7)
        | OPC_CUSTOM1
}

/// Decode a 32-bit word back into an instruction.
pub fn decode(word: u32) -> Result<Insn, DecodeError> {
    let opcode = word & 0x7F;
    let rd = ((word >> 7) & 0x1F) as u8;
    let funct3 = (word >> 12) & 0x7;
    let rs1 = ((word >> 15) & 0x1F) as u8;
    match opcode {
        OPC_OP_IMM => {
            let imm = ((word as i32) >> 20) as i32;
            Ok(Insn::Addi { rd, rs1, imm })
        }
        OPC_OP_V => {
            if funct3 == F3_VSETVLI {
                let vtype = Vtype::from_bits((word >> 20) & 0x7FF);
                return Ok(Insn::Vsetvli { rd, rs1, vtype });
            }
            let funct6 = word >> 26;
            let vs2 = ((word >> 20) & 0x1F) as u8;
            let vs1 = rs1;
            match (funct6, funct3) {
                (F6_VMACC, F3_OPMVV) => Ok(Insn::Vmacc { vd: rd, vs1, vs2 }),
                (F6_VMUL, F3_OPMVV) => Ok(Insn::Vmul { vd: rd, vs1, vs2 }),
                (F6_VADD, F3_OPIVV) => Ok(Insn::Vadd { vd: rd, vs1, vs2 }),
                (F6_VSUB, F3_OPIVV) => Ok(Insn::Vsub { vd: rd, vs1, vs2 }),
                (F6_VMAX, F3_OPIVV) => Ok(Insn::Vmax { vd: rd, vs1, vs2 }),
                (F6_VMIN, F3_OPIVV) => Ok(Insn::Vmin { vd: rd, vs1, vs2 }),
                (F6_VSRA, F3_OPIVV) => Ok(Insn::Vsra { vd: rd, vs1, vs2 }),
                (F6_VMV, 0b100) => Ok(Insn::Vmv { vd: rd, rs1 }),
                _ => Err(DecodeError::UnknownFunct { opcode, funct3, funct6 }),
            }
        }
        OPC_LOAD_FP => {
            let eew = width_bits_to_eew(funct3)
                .ok_or(DecodeError::BadField { what: "eew", value: funct3 })?;
            Ok(Insn::Vle { vd: rd, rs1, eew })
        }
        OPC_STORE_FP => {
            let eew = width_bits_to_eew(funct3)
                .ok_or(DecodeError::BadField { what: "eew", value: funct3 })?;
            Ok(Insn::Vse { vs3: rd, rs1, eew })
        }
        OPC_CUSTOM0 => match funct3 {
            0b000 => {
                let zimm = ((word >> 23) & 0x1FF) as u16;
                let uimm = ((word >> 18) & 0x1F) as u8;
                Ok(Insn::Vsacfg { rd, zimm, uimm })
            }
            0b001 => {
                let dimc = (word >> 28) & 0xF;
                let dim = Dim::from_code(dimc)
                    .ok_or(DecodeError::BadField { what: "dim", value: dimc })?;
                Ok(Insn::VsacfgDim { rd, rs1, dim })
            }
            _ => Err(DecodeError::UnknownFunct { opcode, funct3, funct6: 0 }),
        },
        OPC_CUSTOM1 => {
            let vs2 = ((word >> 20) & 0x1F) as u8;
            let stages = ((word >> 25) & 0x7F) as u8;
            match funct3 {
                0b000 => {
                    let mode = if (word >> 30) & 0x1 == 1 {
                        LdMode::Broadcast
                    } else {
                        LdMode::Sequential
                    };
                    let width = bits_to_widthsel((word >> 28) & 0x3);
                    Ok(Insn::Vsald { vd: rd, rs1, mode, width })
                }
                0b001 => Ok(Insn::Vsam { vd: rd, vs1: rs1, vs2, stages }),
                0b010 => Ok(Insn::Vsac { vd: rd, vs1: rs1, vs2, stages }),
                _ => Err(DecodeError::UnknownFunct { opcode, funct3, funct6: 0 }),
            }
        }
        _ => Err(DecodeError::UnknownOpcode(opcode)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::insn::StrategyKind;

    fn roundtrip(i: Insn) {
        let w = encode(&i);
        let back = decode(w).unwrap_or_else(|e| panic!("decode failed for {i:?}: {e}"));
        assert_eq!(back, i, "word {w:#010x}");
    }

    #[test]
    fn roundtrip_official() {
        roundtrip(Insn::Addi { rd: 5, rs1: 0, imm: 1024 });
        roundtrip(Insn::Addi { rd: 5, rs1: 3, imm: -4 });
        roundtrip(Insn::Vsetvli { rd: 0, rs1: 2, vtype: Vtype::new(16) });
        roundtrip(Insn::Vle { vd: 4, rs1: 1, eew: 16 });
        roundtrip(Insn::Vle { vd: 31, rs1: 31, eew: 8 });
        roundtrip(Insn::Vse { vs3: 8, rs1: 3, eew: 32 });
        roundtrip(Insn::Vmacc { vd: 8, vs1: 0, vs2: 4 });
        roundtrip(Insn::Vmul { vd: 1, vs1: 2, vs2: 3 });
        roundtrip(Insn::Vadd { vd: 1, vs1: 2, vs2: 3 });
        roundtrip(Insn::Vsub { vd: 1, vs1: 2, vs2: 3 });
        roundtrip(Insn::Vmax { vd: 4, vs1: 5, vs2: 6 });
        roundtrip(Insn::Vmin { vd: 4, vs1: 5, vs2: 6 });
        roundtrip(Insn::Vsra { vd: 7, vs1: 8, vs2: 9 });
        roundtrip(Insn::Vmv { vd: 7, rs1: 9 });
    }

    #[test]
    fn roundtrip_custom() {
        let zimm = Insn::pack_cfg(crate::config::Precision::Int8, 3, StrategyKind::Ffcs);
        roundtrip(Insn::Vsacfg { rd: 3, zimm, uimm: 0 });
        for dim in Dim::ALL {
            roundtrip(Insn::VsacfgDim { rd: 0, rs1: 7, dim });
        }
        for mode in [LdMode::Sequential, LdMode::Broadcast] {
            for width in [
                WidthSel::FromCfg,
                WidthSel::Explicit(crate::config::Precision::Int4),
                WidthSel::Explicit(crate::config::Precision::Int8),
                WidthSel::Explicit(crate::config::Precision::Int16),
            ] {
                roundtrip(Insn::Vsald { vd: 2, rs1: 10, mode, width });
            }
        }
        roundtrip(Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 4 });
        roundtrip(Insn::Vsam { vd: 31, vs1: 31, vs2: 31, stages: 127 });
        roundtrip(Insn::Vsac { vd: 1, vs1: 2, vs2: 3, stages: 1 });
    }

    #[test]
    fn custom_opcodes_in_user_space() {
        // The encodings must stay inside custom-0 / custom-1 major opcodes.
        let w = encode(&Insn::Vsacfg { rd: 1, zimm: 0, uimm: 0 });
        assert_eq!(w & 0x7F, OPC_CUSTOM0);
        let w = encode(&Insn::Vsam { vd: 1, vs1: 2, vs2: 3, stages: 1 });
        assert_eq!(w & 0x7F, OPC_CUSTOM1);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(0xFFFF_FFFF).is_err());
        assert!(decode(0x0000_0000).is_err());
        // custom-0 with unused funct3.
        assert!(decode((0b111 << 12) | OPC_CUSTOM0).is_err());
    }

    #[test]
    fn negative_imm_sign_extends() {
        let w = encode(&Insn::Addi { rd: 1, rs1: 0, imm: -1 });
        match decode(w).unwrap() {
            Insn::Addi { imm, .. } => assert_eq!(imm, -1),
            other => panic!("{other:?}"),
        }
    }
}
