//! The SPEED instruction set: the official RVV v1.0 subset the paper's
//! programs use plus the four customized instructions (Sec. II-B).
//!
//! Customized instructions live in the reserved user-defined encoding space
//! (RISC-V custom-0 / custom-1 major opcodes):
//!
//! * `VSACFG`  — configuration-setting: precision (4/8/16-bit), convolution
//!   kernel size (1–15, Kseg-decomposed above that), dataflow strategy.
//!   A second minor form (`VSACFG.DIM`) latches operator dimensions
//!   (M/K/N or C/F/H/W/stride) from a scalar register.
//! * `VSALD`   — vector load with sequential *or multi-broadcast* transfer
//!   from external memory to the scalable modules.
//! * `VSAM`    — matrix–matrix tensor arithmetic across all three
//!   parallelism dimensions (PP, POI, POW), executing multiple dataflow
//!   stages per instruction.
//! * `VSAC`    — matrix–vector variant of `VSAM`.
//!
//! The module provides exact 32-bit encodings ([`encoding`]), a decoded
//! instruction form ([`insn`]), a text assembler ([`assembler`]) and a
//! disassembler ([`disasm`]) so every experiment can express its kernel as
//! the same instruction stream the paper shows (Figs. 2 and 9).

pub mod assembler;
pub mod disasm;
pub mod encoding;
pub mod insn;
pub mod stream;

pub use assembler::{assemble, assemble_line, AsmError};
pub use disasm::disassemble;
pub use encoding::{decode, encode, DecodeError};
pub use insn::{Dim, Insn, LdMode, StrategyKind, Vtype, WidthSel};
pub use stream::{RunKind, Segment, StreamRun};
