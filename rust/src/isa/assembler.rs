//! Text assembler for the SPEED instruction subset.
//!
//! Mirrors the inline-assembly programming model of Sec. II-B: programs are
//! written as vector-instruction sequences (plus scalar `li`/`addi` for
//! address setup) and assembled to 32-bit words. Syntax follows standard
//! RISC-V conventions with the custom mnemonics used throughout the paper:
//!
//! ```text
//! li        x1, 0x1000
//! vsetvli   x0, x2, e16
//! vsacfg    x3, prec=16, k=3, strat=ffcs
//! vsacfg.dim x0, x4, dim=m
//! vsald     v0, (x1), bcast, w=cfg
//! vle16.v   v4, (x2)
//! vsam      v8, v0, v4, stages=4
//! vse16.v   v8, (x3)
//! ```
//!
//! `#`/`//` comments and blank lines are ignored.

use super::insn::{Dim, Insn, LdMode, StrategyKind, Vtype, WidthSel};
use crate::config::Precision;
use crate::error::SpeedError;

/// Shorthand: a parse-class [`SpeedError`].
fn perr(m: impl Into<String>) -> SpeedError {
    SpeedError::Parse(m.into())
}

/// Assembly error with 1-based line information.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AsmError {
    /// 1-based source line the error occurred on.
    pub line: usize,
    /// Human-readable description of the problem.
    pub msg: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for AsmError {}

impl From<AsmError> for SpeedError {
    fn from(e: AsmError) -> Self {
        SpeedError::Parse(e.to_string())
    }
}

/// Assemble a full program (one instruction per line).
pub fn assemble(src: &str) -> Result<Vec<Insn>, AsmError> {
    let mut out = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let line = idx + 1;
        let text = raw.split('#').next().unwrap_or("");
        let text = text.split("//").next().unwrap_or("").trim();
        if text.is_empty() {
            continue;
        }
        out.push(assemble_line(text).map_err(|e| AsmError { line, msg: e.detail() })?);
    }
    Ok(out)
}

/// Assemble a single instruction (no comments / blank input).
pub fn assemble_line(text: &str) -> Result<Insn, SpeedError> {
    let (mnemonic, rest) = match text.split_once(char::is_whitespace) {
        Some((m, r)) => (m, r.trim()),
        None => (text, ""),
    };
    let args: Vec<&str> = if rest.is_empty() {
        vec![]
    } else {
        rest.split(',').map(|a| a.trim()).collect()
    };
    let nargs = args.len();
    let wrong =
        |want: usize| perr(format!("{mnemonic}: expected {want} operands, got {nargs}"));

    match mnemonic {
        "li" => {
            if nargs != 2 {
                return Err(wrong(2));
            }
            Ok(Insn::Addi { rd: xreg(args[0])?, rs1: 0, imm: imm12(args[1])? })
        }
        "addi" => {
            if nargs != 3 {
                return Err(wrong(3));
            }
            Ok(Insn::Addi { rd: xreg(args[0])?, rs1: xreg(args[1])?, imm: imm12(args[2])? })
        }
        "vsetvli" => {
            if nargs != 3 {
                return Err(wrong(3));
            }
            let sew = args[2]
                .strip_prefix('e')
                .and_then(|s| s.parse::<u32>().ok())
                .ok_or_else(|| perr(format!("bad sew spec '{}'", args[2])))?;
            Ok(Insn::Vsetvli { rd: xreg(args[0])?, rs1: xreg(args[1])?, vtype: Vtype::new(sew) })
        }
        m if m.starts_with("vle") && m.ends_with(".v") => {
            if nargs != 2 {
                return Err(wrong(2));
            }
            let eew = eew_of(m, "vle")?;
            Ok(Insn::Vle { vd: vreg(args[0])?, rs1: memop(args[1])?, eew })
        }
        m if m.starts_with("vse") && m.ends_with(".v") && m != "vsetvli" => {
            if nargs != 2 {
                return Err(wrong(2));
            }
            let eew = eew_of(m, "vse")?;
            Ok(Insn::Vse { vs3: vreg(args[0])?, rs1: memop(args[1])?, eew })
        }
        "vmacc.vv" => triple(args, |vd, vs1, vs2| Insn::Vmacc { vd, vs1, vs2 }),
        "vmul.vv" => triple(args, |vd, vs1, vs2| Insn::Vmul { vd, vs1, vs2 }),
        "vadd.vv" => triple(args, |vd, vs1, vs2| Insn::Vadd { vd, vs1, vs2 }),
        "vsub.vv" => triple(args, |vd, vs1, vs2| Insn::Vsub { vd, vs1, vs2 }),
        "vmax.vv" => triple(args, |vd, vs1, vs2| Insn::Vmax { vd, vs1, vs2 }),
        "vmin.vv" => triple(args, |vd, vs1, vs2| Insn::Vmin { vd, vs1, vs2 }),
        "vsra.vv" => triple(args, |vd, vs1, vs2| Insn::Vsra { vd, vs1, vs2 }),
        "vmv.v.x" => {
            if nargs != 2 {
                return Err(wrong(2));
            }
            Ok(Insn::Vmv { vd: vreg(args[0])?, rs1: xreg(args[1])? })
        }
        "vsacfg" => {
            if nargs < 2 {
                return Err(perr("vsacfg: expected rd plus prec=/k=/strat= fields"));
            }
            let rd = xreg(args[0])?;
            let mut prec = Precision::Int8;
            let mut k = 1u32;
            let mut strat = StrategyKind::Mm;
            let mut uimm = 0u8;
            for a in &args[1..] {
                if let Some(v) = a.strip_prefix("prec=") {
                    let bits: u32 = v.parse().map_err(|_| perr(format!("bad prec '{v}'")))?;
                    prec = Precision::from_bits(bits).ok_or_else(|| perr(format!("bad prec '{v}'")))?;
                } else if let Some(v) = a.strip_prefix("k=") {
                    k = v.parse().map_err(|_| perr(format!("bad k '{v}'")))?;
                    if k > 15 {
                        return Err(perr(format!("k={k} exceeds 15; apply Kseg decomposition")));
                    }
                } else if let Some(v) = a.strip_prefix("strat=") {
                    strat = strat_of(v)?;
                } else if let Some(v) = a.strip_prefix("uimm=") {
                    uimm = v.parse().map_err(|_| perr(format!("bad uimm '{v}'")))?;
                } else {
                    return Err(perr(format!("vsacfg: unknown field '{a}'")));
                }
            }
            Ok(Insn::Vsacfg { rd, zimm: Insn::pack_cfg(prec, k, strat), uimm })
        }
        "vsacfg.dim" => {
            if nargs != 3 {
                return Err(wrong(3));
            }
            let dim = args[2]
                .strip_prefix("dim=")
                .ok_or_else(|| perr(format!("expected dim=<name>, got '{}'", args[2])))?;
            Ok(Insn::VsacfgDim { rd: xreg(args[0])?, rs1: xreg(args[1])?, dim: dim_of(dim)? })
        }
        "vsald" => {
            if nargs < 2 {
                return Err(perr("vsald: expected vd, (rs1) [, bcast|seq] [, w=...]"));
            }
            let vd = vreg(args[0])?;
            let rs1 = memop(args[1])?;
            let mut mode = LdMode::Sequential;
            let mut width = WidthSel::FromCfg;
            for a in &args[2..] {
                match *a {
                    "bcast" | "broadcast" => mode = LdMode::Broadcast,
                    "seq" | "sequential" => mode = LdMode::Sequential,
                    _ => {
                        if let Some(v) = a.strip_prefix("w=") {
                            width = match v {
                                "cfg" => WidthSel::FromCfg,
                                "4" => WidthSel::Explicit(Precision::Int4),
                                "8" => WidthSel::Explicit(Precision::Int8),
                                "16" => WidthSel::Explicit(Precision::Int16),
                                _ => return Err(perr(format!("bad width '{v}'"))),
                            };
                        } else {
                            return Err(perr(format!("vsald: unknown field '{a}'")));
                        }
                    }
                }
            }
            Ok(Insn::Vsald { vd, rs1, mode, width })
        }
        "vsam" | "vsac" => {
            if nargs != 4 {
                return Err(wrong(4));
            }
            let stages: u8 = args[3]
                .strip_prefix("stages=")
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| perr(format!("expected stages=<n>, got '{}'", args[3])))?;
            let (vd, vs1, vs2) = (vreg(args[0])?, vreg(args[1])?, vreg(args[2])?);
            if mnemonic == "vsam" {
                Ok(Insn::Vsam { vd, vs1, vs2, stages })
            } else {
                Ok(Insn::Vsac { vd, vs1, vs2, stages })
            }
        }
        _ => Err(perr(format!("unknown mnemonic '{mnemonic}'"))),
    }
}

fn triple(args: Vec<&str>, f: impl Fn(u8, u8, u8) -> Insn) -> Result<Insn, SpeedError> {
    if args.len() != 3 {
        return Err(perr(format!("expected 3 operands, got {}", args.len())));
    }
    Ok(f(vreg(args[0])?, vreg(args[1])?, vreg(args[2])?))
}

fn eew_of(m: &str, prefix: &str) -> Result<u32, SpeedError> {
    m.strip_prefix(prefix)
        .and_then(|s| s.strip_suffix(".v"))
        .and_then(|s| s.parse::<u32>().ok())
        .filter(|e| [8, 16, 32, 64].contains(e))
        .ok_or_else(|| perr(format!("bad element width in '{m}'")))
}

fn strat_of(s: &str) -> Result<StrategyKind, SpeedError> {
    match s {
        "mm" => Ok(StrategyKind::Mm),
        "ffcs" => Ok(StrategyKind::Ffcs),
        "cf" => Ok(StrategyKind::Cf),
        "ff" => Ok(StrategyKind::Ff),
        _ => Err(perr(format!("unknown strategy '{s}'"))),
    }
}

fn dim_of(s: &str) -> Result<Dim, SpeedError> {
    match s {
        "m" => Ok(Dim::M),
        "k" => Ok(Dim::K),
        "n" => Ok(Dim::N),
        "c" => Ok(Dim::C),
        "f" => Ok(Dim::F),
        "h" => Ok(Dim::H),
        "w" => Ok(Dim::W),
        "stride" => Ok(Dim::Stride),
        "nstages" => Ok(Dim::NStages),
        _ => Err(perr(format!("unknown dim '{s}'"))),
    }
}

fn xreg(s: &str) -> Result<u8, SpeedError> {
    reg(s, 'x')
}

fn vreg(s: &str) -> Result<u8, SpeedError> {
    reg(s, 'v')
}

fn reg(s: &str, kind: char) -> Result<u8, SpeedError> {
    let body = s
        .strip_prefix(kind)
        .ok_or_else(|| perr(format!("expected {kind}-register, got '{s}'")))?;
    let n: u8 = body.parse().map_err(|_| perr(format!("bad register '{s}'")))?;
    if n > 31 {
        return Err(perr(format!("register index out of range: '{s}'")));
    }
    Ok(n)
}

fn memop(s: &str) -> Result<u8, SpeedError> {
    let inner = s
        .strip_prefix('(')
        .and_then(|t| t.strip_suffix(')'))
        .ok_or_else(|| perr(format!("expected (xN) memory operand, got '{s}'")))?;
    xreg(inner)
}

fn imm12(s: &str) -> Result<i32, SpeedError> {
    let v = if let Some(hex) = s.strip_prefix("0x") {
        i64::from_str_radix(hex, 16).map_err(|_| perr(format!("bad immediate '{s}'")))?
    } else if let Some(hex) = s.strip_prefix("-0x") {
        -i64::from_str_radix(hex, 16).map_err(|_| perr(format!("bad immediate '{s}'")))?
    } else {
        s.parse::<i64>().map_err(|_| perr(format!("bad immediate '{s}'")))?
    };
    if !(-2048..=2047).contains(&v) {
        return Err(perr(format!("immediate {v} out of 12-bit range")));
    }
    Ok(v as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encoding::{decode, encode};

    #[test]
    fn assemble_fig2_style_program() {
        let src = r#"
            # Fig. 2 — SPEED instruction stream for an INT16 MM
            li         x1, 0x100
            li         x2, 0x200
            li         x3, 0x300
            vsetvli    x0, x2, e16
            vsacfg     x4, prec=16, k=1, strat=mm
            vsald      v0, (x1), bcast, w=cfg
            vsald      v4, (x2), seq, w=16
            vsam       v8, v0, v4, stages=4
            vse16.v    v8, (x3)
        "#;
        let prog = assemble(src).unwrap();
        assert_eq!(prog.len(), 9);
        assert!(matches!(prog[4], Insn::Vsacfg { .. }));
        assert!(matches!(
            prog[5],
            Insn::Vsald { mode: LdMode::Broadcast, width: WidthSel::FromCfg, .. }
        ));
        assert!(matches!(prog[7], Insn::Vsam { stages: 4, .. }));
    }

    #[test]
    fn asm_encode_decode_roundtrip() {
        let src = "vsacfg.dim x0, x5, dim=k\nvmacc.vv v8, v0, v4\nvle8.v v1, (x7)\naddi x3, x3, -16";
        for insn in assemble(src).unwrap() {
            assert_eq!(decode(encode(&insn)).unwrap(), insn);
        }
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = assemble("li x1, 5\nbogus x1").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("bogus"));
    }

    #[test]
    fn rejects_oversize_kernel() {
        let e = assemble_line("vsacfg x1, prec=8, k=16, strat=ffcs").unwrap_err();
        assert!(e.to_string().contains("Kseg"));
    }

    #[test]
    fn rejects_bad_regs() {
        assert!(assemble_line("vmacc.vv v32, v0, v1").is_err());
        assert!(assemble_line("li v1, 5").is_err());
        assert!(assemble_line("vle16.v v1, x3").is_err());
        assert!(assemble_line("li x1, 99999").is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let prog = assemble("\n# full comment\nli x1, 1 // trailing\n\n").unwrap();
        assert_eq!(prog.len(), 1);
    }
}
