//! Compiled instruction-stream containers: a [`Segment`] of instructions
//! plus the [`StreamRun`] metadata the operator compiler attaches to it.
//!
//! The compiler's generated code is dominated by three homogeneous
//! patterns — `(li ; vsald/vle)` transfer pairs, chains of identical
//! `VSAM`/`VSAC` bursts, and `(li ; vse)` row drains. A `StreamRun` marks
//! one such maximal run by index range so the simulator's batch fast path
//! can advance it per block instead of per instruction. The metadata is
//! purely advisory: the simulator re-validates each run against the
//! instructions before using it and falls back to per-instruction stepping
//! on any mismatch, so a `Segment` with empty (or wrong) `runs` is always
//! executable.

use super::Insn;

/// The homogeneous pattern a [`StreamRun`] marks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunKind {
    /// `(li xN, addr ; vsald/vle vX, (xN))` pairs with uniform
    /// vl/width/eew (addresses and destination registers may vary).
    Load,
    /// Identical `VSAM`/`VSAC` bursts (same operands, same stage count).
    Tensor,
    /// `(li xN, addr ; vse.v vS, (xN))` row drains under an installed plan.
    Store,
}

/// One maximal homogeneous run inside a segment: instructions
/// `[start, start + len)` all belong to the pattern `kind`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamRun {
    /// Index of the first instruction of the run within its segment.
    pub start: u32,
    /// Number of instructions covered (pairs count as 2).
    pub len: u32,
    /// Pattern of the run.
    pub kind: RunKind,
}

/// A compiled program segment: the instructions plus the stream-run
/// metadata of the emitter that produced them. Derefs to `[Insn]`, so all
/// instruction-level consumers (`Processor::run`, trace printers, counts)
/// keep working on it unchanged.
#[derive(Debug, Clone, Default)]
pub struct Segment {
    /// The instruction stream.
    pub insns: Vec<Insn>,
    /// Non-overlapping, in ascending `start` order.
    pub runs: Vec<StreamRun>,
}

impl Segment {
    /// A segment with no run metadata (always executes per-instruction).
    pub fn new(insns: Vec<Insn>) -> Self {
        Segment { insns, runs: Vec::new() }
    }

    /// Number of instructions in the segment.
    pub fn len(&self) -> usize {
        self.insns.len()
    }

    /// Whether the segment holds no instructions.
    pub fn is_empty(&self) -> bool {
        self.insns.is_empty()
    }
}

impl From<Vec<Insn>> for Segment {
    fn from(insns: Vec<Insn>) -> Self {
        Segment::new(insns)
    }
}

impl std::ops::Deref for Segment {
    type Target = [Insn];

    fn deref(&self) -> &[Insn] {
        &self.insns
    }
}

impl<'a> IntoIterator for &'a Segment {
    type Item = &'a Insn;
    type IntoIter = std::slice::Iter<'a, Insn>;

    fn into_iter(self) -> Self::IntoIter {
        self.insns.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_derefs_to_insns() {
        let seg = Segment::new(vec![
            Insn::Addi { rd: 1, rs1: 0, imm: 4 },
            Insn::Addi { rd: 2, rs1: 0, imm: 8 },
        ]);
        assert_eq!(seg.len(), 2);
        assert!(!seg.is_empty());
        // Deref: slice ops and iteration work directly.
        assert!(matches!(seg[1], Insn::Addi { rd: 2, .. }));
        assert_eq!(seg.iter().count(), 2);
        assert_eq!((&seg).into_iter().count(), 2);
        let from: Segment = vec![Insn::Addi { rd: 1, rs1: 0, imm: 0 }].into();
        assert!(from.runs.is_empty());
    }
}
