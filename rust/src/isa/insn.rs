//! Decoded instruction forms and the small enums they carry.



use crate::config::Precision;
use crate::error::SpeedError;

/// Dataflow mapping strategy selector carried in `VSACFG.zimm[8:6]`
/// (Sec. III): MM for matrix multiplication, FFCS for CONV, CF for PWCV,
/// FF for DWCV.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// Matrix-multiplication mapping (weights multi-broadcast).
    Mm,
    /// Feature-map-First-Channel-Second (CONV).
    Ffcs,
    /// Channel-First (PWCV; partials accumulate inside the PE).
    Cf,
    /// Feature-map-First (DWCV; weights resident, inputs stream once).
    Ff,
}

impl StrategyKind {
    /// The 3-bit strategy code as encoded in `VSACFG.zimm[8:6]`.
    pub fn code(self) -> u32 {
        match self {
            StrategyKind::Mm => 0,
            StrategyKind::Ffcs => 1,
            StrategyKind::Cf => 2,
            StrategyKind::Ff => 3,
        }
    }

    /// Decode a 3-bit strategy code; `None` for reserved codes.
    pub fn from_code(c: u32) -> Option<Self> {
        match c {
            0 => Some(StrategyKind::Mm),
            1 => Some(StrategyKind::Ffcs),
            2 => Some(StrategyKind::Cf),
            3 => Some(StrategyKind::Ff),
            _ => None,
        }
    }

    /// Every strategy, in encoding order.
    pub const ALL: [StrategyKind; 4] =
        [StrategyKind::Mm, StrategyKind::Ffcs, StrategyKind::Cf, StrategyKind::Ff];
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            StrategyKind::Mm => "mm",
            StrategyKind::Ffcs => "ffcs",
            StrategyKind::Cf => "cf",
            StrategyKind::Ff => "ff",
        };
        write!(f, "{s}")
    }
}

/// Transfer mode of `VSALD` (Sec. II-B): sequential allocation like the
/// official `VLE`, or multi-broadcast of the same data to every lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LdMode {
    /// Sequential allocation striped across lanes (like official `VLE`).
    Sequential,
    /// Multi-broadcast: the same data replicated to every lane.
    Broadcast,
}

/// Element width selector of `VSALD`: an explicit width or "whatever the
/// control register currently says" (the common case after `VSACFG`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WidthSel {
    /// Use the operand precision currently latched by `VSACFG`.
    FromCfg,
    /// Use an explicit operand precision, ignoring the latched state.
    Explicit(Precision),
}

/// Operator-dimension registers latched by `VSACFG.DIM`.
///
/// MM uses M/K/N; convolutions use C (input channels), F (output channels),
/// H/W (input feature map), Stride. `NStages` sets the FFCS revisit depth N.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    /// MM output rows.
    M,
    /// MM reduction depth.
    K,
    /// MM output columns.
    N,
    /// Convolution input channels.
    C,
    /// Convolution output channels.
    F,
    /// Input feature-map height.
    H,
    /// Input feature-map width.
    W,
    /// Convolution stride.
    Stride,
    /// FFCS revisit depth N (number of stationary feature-map stages).
    NStages,
}

impl Dim {
    /// The dimension selector code carried by `VSACFG.DIM`.
    pub fn code(self) -> u32 {
        match self {
            Dim::M => 0,
            Dim::K => 1,
            Dim::N => 2,
            Dim::C => 3,
            Dim::F => 4,
            Dim::H => 5,
            Dim::W => 6,
            Dim::Stride => 7,
            Dim::NStages => 8,
        }
    }

    /// Decode a dimension selector code; `None` for reserved codes.
    pub fn from_code(c: u32) -> Option<Self> {
        Some(match c {
            0 => Dim::M,
            1 => Dim::K,
            2 => Dim::N,
            3 => Dim::C,
            4 => Dim::F,
            5 => Dim::H,
            6 => Dim::W,
            7 => Dim::Stride,
            8 => Dim::NStages,
            _ => return None,
        })
    }

    /// Every dimension register, in encoding order.
    pub const ALL: [Dim; 9] =
        [Dim::M, Dim::K, Dim::N, Dim::C, Dim::F, Dim::H, Dim::W, Dim::Stride, Dim::NStages];
}

impl std::fmt::Display for Dim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.as_str())
    }
}

impl Dim {
    /// Lower-case assembly mnemonic of the dimension register.
    pub fn as_str(&self) -> &'static str {
        match self {
            Dim::M => "m",
            Dim::K => "k",
            Dim::N => "n",
            Dim::C => "c",
            Dim::F => "f",
            Dim::H => "h",
            Dim::W => "w",
            Dim::Stride => "stride",
            Dim::NStages => "nstages",
        }
    }
}

/// A tiny allocation-free set of vector-register indices (≤ 3 per insn).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegSet {
    regs: [u8; 3],
    len: u8,
}

impl RegSet {
    /// Build a set from at most 3 register indices.
    pub fn new(rs: &[u8]) -> Self {
        let mut regs = [0u8; 3];
        regs[..rs.len()].copy_from_slice(rs);
        RegSet { regs, len: rs.len() as u8 }
    }

    /// The registers as a slice (also available via `Deref`).
    pub fn as_slice(&self) -> &[u8] {
        &self.regs[..self.len as usize]
    }

    /// Does the set contain register `r`?
    pub fn contains(&self, r: u8) -> bool {
        self.as_slice().contains(&r)
    }

    /// Is the set empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl<'a> IntoIterator for &'a RegSet {
    type Item = &'a u8;
    type IntoIter = std::slice::Iter<'a, u8>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl IntoIterator for RegSet {
    type Item = u8;
    type IntoIter = std::iter::Take<std::array::IntoIter<u8, 3>>;
    fn into_iter(self) -> Self::IntoIter {
        self.regs.into_iter().take(self.len as usize)
    }
}

impl std::ops::Deref for RegSet {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

/// The `vtype` payload of `VSETVLI` — we model the SEW field (and keep
/// LMUL=1, the paper's configuration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Vtype {
    /// Selected element width in bits (8 / 16 / 32 / 64).
    pub sew: u32,
}

impl Vtype {
    /// A vtype with the given SEW (LMUL fixed at 1).
    pub fn new(sew: u32) -> Self {
        Vtype { sew }
    }

    /// vtype encoding: vsew is bits [5:3] with sew = 8 << vsew.
    pub fn to_bits(self) -> u32 {
        let vsew = match self.sew {
            8 => 0,
            16 => 1,
            32 => 2,
            64 => 3,
            _ => 1,
        };
        vsew << 3
    }

    /// Decode a vtype payload (inverse of [`Vtype::to_bits`]).
    pub fn from_bits(bits: u32) -> Self {
        let vsew = (bits >> 3) & 0x7;
        Vtype { sew: 8 << vsew }
    }
}

/// A decoded SPEED instruction.
///
/// The subset covers every instruction appearing in the paper's program
/// examples (Figs. 2, 5, 9): the official RVV configuration / memory /
/// arithmetic instructions, the scalar `ADDI` (for address setup by the
/// tightly-coupled scalar core), and the four customized instructions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Insn {
    // ----- scalar support (the tightly-coupled scalar core) -------------
    /// `addi rd, rs1, imm` — scalar address/length setup; `li` is the
    /// assembler pseudo for `addi rd, x0, imm`.
    Addi { rd: u8, rs1: u8, imm: i32 },

    // ----- official RVV subset ------------------------------------------
    /// `vsetvli rd, rs1, vtype` — set application vector length.
    Vsetvli { rd: u8, rs1: u8, vtype: Vtype },
    /// `vle<eew>.v vd, (rs1)` — unit-stride vector load.
    Vle { vd: u8, rs1: u8, eew: u32 },
    /// `vse<eew>.v vs3, (rs1)` — unit-stride vector store.
    Vse { vs3: u8, rs1: u8, eew: u32 },
    /// `vmacc.vv vd, vs1, vs2` — vd += vs1 * vs2 (elementwise MAC).
    Vmacc { vd: u8, vs1: u8, vs2: u8 },
    /// `vmul.vv vd, vs1, vs2`.
    Vmul { vd: u8, vs1: u8, vs2: u8 },
    /// `vadd.vv vd, vs1, vs2`.
    Vadd { vd: u8, vs1: u8, vs2: u8 },
    /// `vsub.vv vd, vs1, vs2` (vs1 - vs2 element-wise).
    Vsub { vd: u8, vs1: u8, vs2: u8 },
    /// `vmax.vv vd, vs1, vs2` — signed max (requantization clip).
    Vmax { vd: u8, vs1: u8, vs2: u8 },
    /// `vmin.vv vd, vs1, vs2` — signed min (requantization clip).
    Vmin { vd: u8, vs1: u8, vs2: u8 },
    /// `vsra.vv vd, vs1, vs2` — arithmetic right shift (requant scaling).
    Vsra { vd: u8, vs1: u8, vs2: u8 },
    /// `vmv.v.x vd, rs1` — splat scalar into a vector register.
    Vmv { vd: u8, rs1: u8 },

    // ----- customized instructions (custom-0 / custom-1 space) ----------
    /// `vsacfg rd, zimm, uimm` — precision / kernel-size / strategy.
    Vsacfg { rd: u8, zimm: u16, uimm: u8 },
    /// `vsacfg.dim rd, rs1, dim` — latch an operator dimension.
    VsacfgDim { rd: u8, rs1: u8, dim: Dim },
    /// `vsald vd, (rs1), mode, width` — sequential / broadcast DMA load.
    Vsald { vd: u8, rs1: u8, mode: LdMode, width: WidthSel },
    /// `vsam vd, vs1, vs2, stages` — matrix–matrix tensor op.
    Vsam { vd: u8, vs1: u8, vs2: u8, stages: u8 },
    /// `vsac vd, vs1, vs2, stages` — matrix–vector tensor op.
    Vsac { vd: u8, vs1: u8, vs2: u8, stages: u8 },
}

impl Insn {
    /// Is this one of the four customized SPEED instructions?
    pub fn is_custom(&self) -> bool {
        matches!(
            self,
            Insn::Vsacfg { .. }
                | Insn::VsacfgDim { .. }
                | Insn::Vsald { .. }
                | Insn::Vsam { .. }
                | Insn::Vsac { .. }
        )
    }

    /// Is this a vector instruction (executed by SPEED rather than the
    /// scalar core)?
    pub fn is_vector(&self) -> bool {
        !matches!(self, Insn::Addi { .. })
    }

    /// Vector registers read by this instruction (hazard tracking in VIS).
    /// Allocation-free: returns a fixed-size buffer + count (this sits on
    /// the simulator's per-instruction hot path — see EXPERIMENTS.md §Perf).
    pub fn vregs_read(&self) -> RegSet {
        match *self {
            Insn::Vmacc { vd, vs1, vs2 } => RegSet::new(&[vd, vs1, vs2]),
            Insn::Vmul { vs1, vs2, .. }
            | Insn::Vadd { vs1, vs2, .. }
            | Insn::Vsub { vs1, vs2, .. }
            | Insn::Vmax { vs1, vs2, .. }
            | Insn::Vmin { vs1, vs2, .. }
            | Insn::Vsra { vs1, vs2, .. } => RegSet::new(&[vs1, vs2]),
            Insn::Vsam { vs1, vs2, .. } | Insn::Vsac { vs1, vs2, .. } => {
                RegSet::new(&[vs1, vs2])
            }
            Insn::Vse { vs3, .. } => RegSet::new(&[vs3]),
            _ => RegSet::new(&[]),
        }
    }

    /// Vector registers written by this instruction.
    pub fn vregs_written(&self) -> RegSet {
        match *self {
            Insn::Vle { vd, .. }
            | Insn::Vmacc { vd, .. }
            | Insn::Vmul { vd, .. }
            | Insn::Vadd { vd, .. }
            | Insn::Vsub { vd, .. }
            | Insn::Vmax { vd, .. }
            | Insn::Vmin { vd, .. }
            | Insn::Vsra { vd, .. }
            | Insn::Vmv { vd, .. }
            | Insn::Vsald { vd, .. }
            | Insn::Vsam { vd, .. }
            | Insn::Vsac { vd, .. } => RegSet::new(&[vd]),
            _ => RegSet::new(&[]),
        }
    }

    /// Build the main `VSACFG` zimm payload from its fields.
    /// zimm[1:0] = precision code, zimm[5:2] = kernel size, zimm[8:6] =
    /// strategy code.
    pub fn pack_cfg(prec: Precision, ksize: u32, strat: StrategyKind) -> u16 {
        let pcode = match prec {
            Precision::Int16 => 0u16,
            Precision::Int8 => 1,
            Precision::Int4 => 2,
        };
        debug_assert!(ksize <= 15, "kernel size must be Kseg-decomposed below 16");
        pcode | ((ksize as u16 & 0xF) << 2) | ((strat.code() as u16 & 0x7) << 6)
    }

    /// Fallible [`Insn::pack_cfg`]: the `ksize <= 15` Kseg bound as a typed
    /// [`SpeedError::Compile`] instead of a release-invisible
    /// `debug_assert!`. A kernel past the 4-bit field would silently
    /// truncate (`& 0xF`) and configure the wrong kernel size in release
    /// builds; callers that accept external operator descriptors gate on
    /// this before emitting any configuration instruction.
    pub fn try_pack_cfg(
        prec: Precision,
        ksize: u32,
        strat: StrategyKind,
    ) -> Result<u16, SpeedError> {
        if ksize > 15 {
            return Err(SpeedError::Compile(format!(
                "kernel size {ksize} exceeds the 4-bit VSACFG field; \
                 Kseg-decompose below 16 first"
            )));
        }
        Ok(Self::pack_cfg(prec, ksize, strat))
    }

    /// Inverse of [`Insn::pack_cfg`].
    pub fn unpack_cfg(zimm: u16) -> Option<(Precision, u32, StrategyKind)> {
        let prec = match zimm & 0x3 {
            0 => Precision::Int16,
            1 => Precision::Int8,
            2 => Precision::Int4,
            _ => return None,
        };
        let ksize = ((zimm >> 2) & 0xF) as u32;
        let strat = StrategyKind::from_code(((zimm >> 6) & 0x7) as u32)?;
        Some((prec, ksize, strat))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_roundtrip() {
        for prec in Precision::ALL {
            for k in [1u32, 3, 5, 7, 15] {
                for strat in StrategyKind::ALL {
                    let z = Insn::pack_cfg(prec, k, strat);
                    assert_eq!(Insn::unpack_cfg(z), Some((prec, k, strat)));
                }
            }
        }
    }

    #[test]
    fn try_pack_cfg_rejects_oversized_kernel() {
        assert_eq!(
            Insn::try_pack_cfg(Precision::Int8, 15, StrategyKind::Ffcs).unwrap(),
            Insn::pack_cfg(Precision::Int8, 15, StrategyKind::Ffcs)
        );
        let err = Insn::try_pack_cfg(Precision::Int8, 16, StrategyKind::Ffcs).unwrap_err();
        assert!(matches!(err, SpeedError::Compile(_)), "{err}");
        assert!(err.to_string().contains("Kseg"), "{err}");
    }

    #[test]
    fn vtype_roundtrip() {
        for sew in [8, 16, 32, 64] {
            assert_eq!(Vtype::from_bits(Vtype::new(sew).to_bits()).sew, sew);
        }
    }

    #[test]
    fn dim_roundtrip() {
        for d in Dim::ALL {
            assert_eq!(Dim::from_code(d.code()), Some(d));
        }
    }

    #[test]
    fn hazard_sets() {
        let i = Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 4 };
        assert_eq!(i.vregs_read().as_slice(), &[0, 4]);
        assert_eq!(i.vregs_written().as_slice(), &[8]);
        assert!(i.is_custom());
        assert!(i.is_vector());
        let a = Insn::Addi { rd: 1, rs1: 0, imm: 64 };
        assert!(!a.is_vector());
    }

    #[test]
    fn vmacc_reads_vd() {
        // vmacc vd += vs1*vs2 — vd is both read and written.
        let i = Insn::Vmacc { vd: 2, vs1: 3, vs2: 4 };
        assert!(i.vregs_read().contains(2));
        assert!(i.vregs_written().contains(2));
    }
}
