//! `speed-bench` — the machine-readable performance harness.
//!
//! The paper's headline numbers are throughput claims, so the reproduction
//! tracks its own throughput the same way: this module runs the Fig. 11
//! operator sweep, the Fig. 12 model sweep, and the simulator hot-path
//! micro-bench (`sim_hotpath`) through one warm [`Engine`], and emits a
//! machine-readable `BENCH_sim.json` with host-side throughput (ops/s,
//! simulated-stages/s), per-bench wall time, program-cache hit rates,
//! per-entry cycle-attribution breakdowns, and the unified
//! [`crate::obs::Counters`] registry snapshot (schema 3).
//!
//! The hot-path bench runs twice — [`ExecMode::Exact`] (per-instruction
//! stepping) and [`ExecMode::Batch`] (the stream-run fast path) — so every
//! `BENCH_sim.json` records both numbers and the speedup between them.
//!
//! CI gates on a committed `bench/baseline.json`: every metric listed
//! there is **higher-is-better**, and a measured value below
//! `baseline × (1 − tolerance)` fails the run ([`check_baseline`]).

use std::sync::Arc;
use std::time::Instant;

use crate::config::{Precision, SpeedConfig};
use crate::coordinator::Policy;
use crate::engine::Engine;
use crate::error::{Result, SpeedError};
use crate::isa::StrategyKind;
use crate::models::zoo::{model_by_name, MODELS};
use crate::models::OpDesc;
use crate::obs::{Counters, CycleBreakdown};
use crate::runtime::json::{jf, jstr, parse, Json};
use crate::sim::ExecMode;
use crate::tune::{self, TuneOptions};

/// What to run and how hard.
#[derive(Debug, Clone, Copy)]
pub struct BenchOptions {
    /// Downscaled models, fewer operator sizes, fewer hot-path reps —
    /// the CI `bench-smoke` configuration.
    pub quick: bool,
    /// Skip the batch fast path everywhere (escape hatch): the hot-path
    /// section then reports exact-mode numbers for both entries.
    pub exact_only: bool,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { quick: true, exact_only: false }
    }
}

/// One timed benchmark entry (operator or model × precision).
#[derive(Debug, Clone)]
pub struct BenchEntry {
    /// Bench label (operator shape or model name).
    pub name: String,
    /// Operand precision the entry ran at.
    pub prec: Precision,
    /// Mapping strategy label ("mixed" for whole-model runs).
    pub strategy: String,
    /// Host wall time of the timed (cache-warm) pass, in seconds.
    pub wall_s: f64,
    /// Simulated cycles of the timed pass.
    pub sim_cycles: u64,
    /// Multiply-accumulate operations in the workload.
    pub macs: u64,
    /// Simulated throughput of the modeled hardware (GOPS at the
    /// reference clock) — the paper-facing number.
    pub gops_simulated: f64,
    /// Host-side simulation throughput: simulated MAC-ops per second of
    /// wall time — the reproduction-facing number this harness tracks.
    pub mops_per_s_host: f64,
    /// Program-cache hit rate of the owning engine when the entry finished.
    pub cache_hit_rate: f64,
    /// Cycle attribution of the timed pass (components sum to
    /// [`BenchEntry::sim_cycles`] exactly).
    pub breakdown: CycleBreakdown,
}

/// The `sim_hotpath` section: one stage-heavy CONV3×3 stream measured in
/// both execution modes.
#[derive(Debug, Clone)]
pub struct HotpathResult {
    /// Human-readable description of the measured operator.
    pub op: String,
    /// Total MPTU stages in the compiled stream (per rep).
    pub stages: u64,
    /// Wall seconds per rep under [`ExecMode::Exact`].
    pub exact_wall_s: f64,
    /// Wall seconds per rep under the stream-run fast path.
    pub fast_wall_s: f64,
    /// Simulated stages per host second, exact mode.
    pub exact_stages_per_s: f64,
    /// Simulated stages per host second, fast path.
    pub fast_stages_per_s: f64,
    /// fast / exact simulated-stages-per-second.
    pub speedup: f64,
}

/// One auto-tuned vs static-mixed model comparison (`tuned` section of
/// `BENCH_sim.json`). Cycle numbers are *simulated* — bit-identical in
/// batch and exact mode — so the section gates cleanly in either.
#[derive(Debug, Clone)]
pub struct TunedBenchEntry {
    /// Zoo model the comparison ran on.
    pub model: String,
    /// Operand precision of the comparison.
    pub prec: Precision,
    /// Whole-model simulated cycles under `Policy::Mixed`.
    pub cycles_static: u64,
    /// Whole-model simulated cycles under the tuned plan.
    pub cycles_tuned: u64,
    /// Distinct operators whose tuned mapping deviates from static.
    pub improved_ops: usize,
    /// Distinct operators in the plan.
    pub tuned_ops: usize,
    /// Host wall time spent searching (tuning only, not the replays).
    pub tune_wall_s: f64,
}

impl TunedBenchEntry {
    /// static / tuned simulated cycles (>= 1.0 by the tie-to-static rule).
    pub fn speedup(&self) -> f64 {
        if self.cycles_tuned == 0 {
            return 1.0;
        }
        self.cycles_static as f64 / self.cycles_tuned as f64
    }
}

/// Everything one `speed-bench` invocation measured.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// The run used the downscaled CI (`--quick`) configuration.
    pub quick: bool,
    /// The run skipped the batch fast path (`--exact` / `SPEED_EXACT`):
    /// the hotpath "fast" leg is exact-mode data, so the fast-path metrics
    /// are not emitted (and not gated).
    pub exact_only: bool,
    /// The `sim_hotpath` exact-vs-fast measurement.
    pub hotpath: HotpathResult,
    /// Fig. 11-style operator sweep entries.
    pub operators: Vec<BenchEntry>,
    /// Fig. 12-style whole-model sweep entries.
    pub models: Vec<BenchEntry>,
    /// Auto-tuned vs static-mixed comparisons (`repro tune`'s win,
    /// re-measured end to end through composed model runs).
    pub tuned: Vec<TunedBenchEntry>,
    /// Program-cache hits across the operator sweep's shared engine.
    pub cache_hits: u64,
    /// Program-cache misses across the operator sweep's shared engine.
    pub cache_misses: u64,
    /// Unified counter-registry snapshot ([`crate::obs::Counter`] order):
    /// one [`Counters`] pool is shared by every engine the harness builds,
    /// so these totals span the operator, model, and tuned sweeps.
    pub counters: Vec<(&'static str, u64)>,
    /// Wall time of the whole invocation, in seconds.
    pub total_wall_s: f64,
}

impl BenchReport {
    /// The flat, gateable metric map (all higher-is-better).
    pub fn metrics(&self) -> Vec<(String, f64)> {
        let op_wall: f64 = self.operators.iter().map(|e| e.wall_s).sum();
        let op_macs: u64 = self.operators.iter().map(|e| e.macs).sum();
        let model_wall: f64 = self.models.iter().map(|e| e.wall_s).sum();
        let model_macs: u64 = self.models.iter().map(|e| e.macs).sum();
        let lookups = self.cache_hits + self.cache_misses;
        let mut m =
            vec![("sim_hotpath_exact_stages_per_s".into(), self.hotpath.exact_stages_per_s)];
        if !self.exact_only {
            m.push(("sim_hotpath_fast_stages_per_s".into(), self.hotpath.fast_stages_per_s));
            m.push(("sim_hotpath_speedup".into(), self.hotpath.speedup));
        }
        if op_wall > 0.0 {
            m.push(("operators_host_mops_per_s".into(), 2.0 * op_macs as f64 / op_wall / 1e6));
        }
        if model_wall > 0.0 {
            m.push(("models_host_mops_per_s".into(), 2.0 * model_macs as f64 / model_wall / 1e6));
        }
        if lookups > 0 {
            m.push(("engine_cache_hit_rate".into(), self.cache_hits as f64 / lookups as f64));
        }
        if !self.tuned.is_empty() {
            let best = self
                .tuned
                .iter()
                .map(TunedBenchEntry::speedup)
                .fold(f64::MIN, f64::max);
            m.push(("tuned_vs_mixed_best_speedup".into(), best));
        }
        m
    }

    /// Look up one gateable metric by name.
    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics().into_iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// Serialize as the `BENCH_sim.json` document.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        // Schema 3: per-entry cycle-attribution breakdowns + the unified
        // counter-registry snapshot (aligned with `SERVE_bench.json`;
        // schema 2 was never used by this document).
        s.push_str("  \"schema\": 3,\n  \"bench\": \"speed-bench\",\n");
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"exact_only\": {},\n", self.exact_only));
        s.push_str("  \"sim_hotpath\": {\n");
        s.push_str(&format!("    \"op\": {},\n", jstr(&self.hotpath.op)));
        s.push_str(&format!("    \"stages\": {},\n", self.hotpath.stages));
        s.push_str(&format!(
            "    \"exact\": {{ \"wall_s\": {}, \"stages_per_s\": {} }},\n",
            jf(self.hotpath.exact_wall_s),
            jf(self.hotpath.exact_stages_per_s)
        ));
        s.push_str(&format!(
            "    \"fast\": {{ \"wall_s\": {}, \"stages_per_s\": {} }},\n",
            jf(self.hotpath.fast_wall_s),
            jf(self.hotpath.fast_stages_per_s)
        ));
        s.push_str(&format!("    \"speedup\": {}\n  }},\n", jf(self.hotpath.speedup)));
        for (key, entries) in [("operators", &self.operators), ("models", &self.models)] {
            s.push_str(&format!("  \"{key}\": [\n"));
            for (i, e) in entries.iter().enumerate() {
                let buckets = CycleBreakdown::NAMES
                    .iter()
                    .zip(e.breakdown.components())
                    .map(|(n, v)| format!("\"{n}\": {v}"))
                    .collect::<Vec<_>>()
                    .join(", ");
                s.push_str(&format!(
                    "    {{ \"name\": {}, \"prec\": {}, \"strategy\": {}, \"wall_s\": {}, \
                     \"sim_cycles\": {}, \"macs\": {}, \"gops_simulated\": {}, \
                     \"mops_per_s_host\": {}, \"cache_hit_rate\": {}, \
                     \"breakdown\": {{ {} }} }}{}\n",
                    jstr(&e.name),
                    jstr(&e.prec.to_string()),
                    jstr(&e.strategy),
                    jf(e.wall_s),
                    e.sim_cycles,
                    e.macs,
                    jf(e.gops_simulated),
                    jf(e.mops_per_s_host),
                    jf(e.cache_hit_rate),
                    buckets,
                    if i + 1 < entries.len() { "," } else { "" }
                ));
            }
            s.push_str("  ],\n");
        }
        s.push_str("  \"tuned\": [\n");
        for (i, e) in self.tuned.iter().enumerate() {
            s.push_str(&format!(
                "    {{ \"model\": {}, \"prec\": {}, \"cycles_static\": {}, \
                 \"cycles_tuned\": {}, \"speedup\": {}, \"improved_ops\": {}, \
                 \"tuned_ops\": {}, \"tune_wall_s\": {} }}{}\n",
                jstr(&e.model),
                jstr(&e.prec.to_string()),
                e.cycles_static,
                e.cycles_tuned,
                jf(e.speedup()),
                e.improved_ops,
                e.tuned_ops,
                jf(e.tune_wall_s),
                if i + 1 < self.tuned.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"cache\": {{ \"hits\": {}, \"misses\": {} }},\n",
            self.cache_hits, self.cache_misses
        ));
        s.push_str("  \"counters\": {\n");
        for (i, (n, v)) in self.counters.iter().enumerate() {
            s.push_str(&format!(
                "    \"{n}\": {v}{}\n",
                if i + 1 < self.counters.len() { "," } else { "" }
            ));
        }
        s.push_str("  },\n");
        s.push_str("  \"metrics\": {\n");
        let metrics = self.metrics();
        for (i, (n, v)) in metrics.iter().enumerate() {
            s.push_str(&format!(
                "    {}: {}{}\n",
                jstr(n),
                jf(*v),
                if i + 1 < metrics.len() { "," } else { "" }
            ));
        }
        s.push_str("  },\n");
        s.push_str(&format!("  \"total_wall_s\": {}\n}}\n", jf(self.total_wall_s)));
        s
    }

    /// A `bench/baseline.json` seeded from this run's metrics, derated by
    /// `headroom` (e.g. 0.5 commits floors at half the measured values so
    /// slower CI runners don't flap).
    pub fn baseline_json(&self, tolerance: f64, headroom: f64) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"tolerance\": {},\n", jf(tolerance)));
        s.push_str("  \"metrics\": {\n");
        let metrics = self.metrics();
        for (i, (n, v)) in metrics.iter().enumerate() {
            s.push_str(&format!(
                "    {}: {}{}\n",
                jstr(n),
                jf(v * headroom),
                if i + 1 < metrics.len() { "," } else { "" }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Human-readable one-screen summary.
    pub fn summary_text(&self) -> String {
        let h = &self.hotpath;
        let mut s = String::new();
        s.push_str(&format!(
            "sim_hotpath ({}): {} stages\n  exact: {:>10.0} stages/s ({:.1} ms)\n  \
             fast:  {:>10.0} stages/s ({:.1} ms)  => {:.2}x\n",
            h.op,
            h.stages,
            h.exact_stages_per_s,
            h.exact_wall_s * 1e3,
            h.fast_stages_per_s,
            h.fast_wall_s * 1e3,
            h.speedup
        ));
        for (title, entries) in [("operators", &self.operators), ("models", &self.models)] {
            s.push_str(&format!("{title}: {} benches\n", entries.len()));
            for e in entries {
                s.push_str(&format!(
                    "  {:32} {:5} {:5} {:8.1} ms  {:8.1} Mops/s (sim {:.1} GOPS)\n",
                    e.name,
                    e.prec.to_string(),
                    e.strategy,
                    e.wall_s * 1e3,
                    e.mops_per_s_host,
                    e.gops_simulated
                ));
            }
        }
        if !self.tuned.is_empty() {
            s.push_str(&format!("tuned vs static mixed: {} models\n", self.tuned.len()));
            for e in &self.tuned {
                s.push_str(&format!(
                    "  {:16} {:5} {:>12} -> {:>12} sim cycles ({:.3}x, {}/{} ops retuned)\n",
                    e.model,
                    e.prec.to_string(),
                    e.cycles_static,
                    e.cycles_tuned,
                    e.speedup(),
                    e.improved_ops,
                    e.tuned_ops
                ));
            }
        }
        let mut split = CycleBreakdown::default();
        for e in self.operators.iter().chain(&self.models) {
            split.merge(&e.breakdown);
        }
        if split.total() > 0 {
            s.push_str(&format!("cycle split (timed passes): {}\n", split.summary_line()));
        }
        s.push_str(&format!(
            "program cache: {} hits / {} misses; total wall {:.2} s\n",
            self.cache_hits, self.cache_misses, self.total_wall_s
        ));
        s
    }
}

/// The `sim_hotpath` workload: the stage-heavy CONV3×3 stream the
/// EXPERIMENTS perf log has always tracked.
pub fn hotpath_op(quick: bool) -> OpDesc {
    if quick {
        OpDesc::conv(32, 32, 28, 28, 3, 1, 1, Precision::Int16)
    } else {
        OpDesc::conv(64, 64, 56, 56, 3, 1, 1, Precision::Int16)
    }
}

/// Measure simulated-stages-per-second of `op` under one execution mode on
/// a warm engine (the program compiles once; timed reps replay the cached
/// stream). Returns (wall seconds per rep, total stages per rep).
pub fn measure_hotpath(op: &OpDesc, mode: ExecMode, reps: u32) -> Result<(f64, u64)> {
    let mut engine = Engine::new(SpeedConfig::reference())?;
    engine.set_exec_mode(mode);
    // Warm: compile + first execution.
    let (_, prog) = engine.run_op(op, StrategyKind::Ffcs, false)?;
    let stages = prog.summary().total_stages;
    let reps = reps.max(1);
    let t0 = Instant::now();
    for _ in 0..reps {
        engine.run_op(op, StrategyKind::Ffcs, false)?;
    }
    Ok((t0.elapsed().as_secs_f64() / reps as f64, stages))
}

fn operator_cases(quick: bool) -> Vec<(&'static str, OpDesc)> {
    let sizes: &[u32] = if quick { &[8, 16] } else { &[8, 16, 32, 56] };
    let mut out = Vec::new();
    for &s in sizes {
        out.push(("pwcv_64x64", OpDesc::pwcv(64, 64, s, s, Precision::Int16)));
        out.push(("conv3x3_32x32", OpDesc::conv(32, 32, s, s, 3, 1, 1, Precision::Int16)));
        out.push((
            "dwcv3x3s2_32",
            OpDesc::dwcv(32, s.max(3), s.max(3), 3, 2, 1, Precision::Int16),
        ));
        out.push(("mm_sxsxs", OpDesc::mm(s, s, s, Precision::Int16)));
    }
    out
}

/// Run the full harness. One warm [`Engine`] serves the whole operator
/// sweep (each program compiles on its first pass and replays from cache
/// on the timed pass); each model gets its own engine so per-model cache
/// hit rates stay interpretable.
pub fn run_bench(opts: &BenchOptions) -> Result<BenchReport> {
    let t_all = Instant::now();
    let cfg = SpeedConfig::reference();
    // `SPEED_EXACT=1` is the documented global escape hatch — honor it
    // here too (Processor::new reads it, but the harness sets modes
    // explicitly and would otherwise override it).
    let exact_only = opts.exact_only || std::env::var_os("SPEED_EXACT").is_some();
    let mode = if exact_only { ExecMode::Exact } else { ExecMode::Batch };
    // One counter registry shared by every engine the harness builds: the
    // report's `counters` object then totals cache traffic and verifier
    // work across all three sweeps.
    let counters = Counters::new();

    // ---- sim_hotpath: exact vs fast ----
    let op = hotpath_op(opts.quick);
    let reps = if opts.quick { 2 } else { 3 };
    let (exact_wall, stages) = measure_hotpath(&op, ExecMode::Exact, reps)?;
    let (fast_wall, _) = measure_hotpath(&op, mode, reps)?;
    let hotpath = HotpathResult {
        op: format!(
            "conv3x3 {}x{}x{}x{} INT16 ffcs",
            op.c, op.f, op.h, op.w
        ),
        stages,
        exact_wall_s: exact_wall,
        fast_wall_s: fast_wall,
        exact_stages_per_s: stages as f64 / exact_wall.max(1e-12),
        fast_stages_per_s: stages as f64 / fast_wall.max(1e-12),
        speedup: exact_wall / fast_wall.max(1e-12),
    };

    // ---- Fig. 11-style operator sweep (one warm engine) ----
    let mut engine = Engine::new(cfg)?;
    engine.set_exec_mode(mode);
    engine.set_counters(counters.clone());
    let mut operators = Vec::new();
    let cases = operator_cases(opts.quick);
    for prec in Precision::ALL {
        for (name, base) in &cases {
            let op = OpDesc { prec, ..*base };
            let strat = op.preferred_strategy();
            // Warm pass compiles; the timed pass replays the cached program.
            engine.run_op(&op, strat, false)?;
            let b0 = engine.breakdown();
            let t0 = Instant::now();
            let (st, _) = engine.run_op(&op, strat, false)?;
            let wall = t0.elapsed().as_secs_f64();
            operators.push(BenchEntry {
                name: format!("{name}_{}x{}", op.h.max(op.m), op.w.max(op.k)),
                prec,
                strategy: strat.to_string(),
                wall_s: wall,
                sim_cycles: st.cycles,
                macs: st.macs,
                gops_simulated: st.gops(cfg.freq_ghz),
                mops_per_s_host: 2.0 * st.macs as f64 / wall.max(1e-12) / 1e6,
                cache_hit_rate: engine.cache_stats().hit_rate(),
                breakdown: engine.breakdown().since(&b0),
            });
        }
    }
    let cache = engine.cache_stats();

    // ---- Fig. 12-style model sweep ----
    let names: Vec<&str> = if opts.quick {
        vec!["mobilenetv2", "resnet18", "vit_tiny"]
    } else {
        MODELS.to_vec()
    };
    let precs: &[Precision] =
        if opts.quick { &[Precision::Int8] } else { &Precision::ALL };
    let mut models = Vec::new();
    for name in names {
        let mut model = model_by_name(name)
            .ok_or_else(|| SpeedError::Bench(format!("unknown model '{name}'")))?;
        if opts.quick {
            model = crate::report::fig12::downscale(&model, 4);
        }
        let mut engine = Engine::new(cfg)?;
        engine.set_exec_mode(mode);
        engine.set_counters(counters.clone());
        for &prec in precs {
            let b0 = engine.breakdown();
            let t0 = Instant::now();
            let r = engine.session().run_model(&model, prec)?;
            let wall = t0.elapsed().as_secs_f64();
            models.push(BenchEntry {
                name: name.to_string(),
                prec,
                strategy: "mixed".into(),
                wall_s: wall,
                sim_cycles: r.total.cycles,
                macs: r.total.macs,
                gops_simulated: r.total.gops(cfg.freq_ghz),
                mops_per_s_host: 2.0 * r.total.macs as f64 / wall.max(1e-12) / 1e6,
                cache_hit_rate: engine.cache_stats().hit_rate(),
                breakdown: engine.breakdown().since(&b0),
            });
        }
    }

    // ---- tuned vs static mixed dataflow ----
    // The auto-tuner's acceptance measurement: tune a CONV-heavy zoo
    // model, then replay the *whole model* under both mappings through
    // fresh engines. Simulated cycles are mode-independent (batch ==
    // exact bit-for-bit), so the resulting metric gates identically under
    // --exact. INT4 is where the static table's choice is furthest off:
    // PP = 16 shrinks the MPTU schedule 16x while weight refetches only
    // halve, so big layers go memory-bound and the tuner's alternatives
    // (FF everywhere — resident shapes stream weights exactly once,
    // spilled shapes compile honest per-row refetch runs and win or lose
    // on measured merit — smaller channel chunks, wider MM B-tile column
    // blocks, and the model-level chain pass carrying VRF-resident
    // outputs between adjacent layers) can win. The speedup is >= 1.0 by
    // the tie-to-static rule whatever the search finds, so the gated
    // metric's floor holds unconditionally.
    let tuned_points: &[(&str, Precision)] = if opts.quick {
        &[("vgg16", Precision::Int4)]
    } else {
        &[
            ("vgg16", Precision::Int4),
            ("vgg16", Precision::Int8),
            ("resnet18", Precision::Int4),
        ]
    };
    let mut tuned = Vec::new();
    for &(name, prec) in tuned_points {
        let mut model = model_by_name(name)
            .ok_or_else(|| SpeedError::Bench(format!("unknown model '{name}'")))?;
        if opts.quick {
            model = crate::report::fig12::downscale(&model, 4);
        }
        let topts = TuneOptions { exec_mode: mode, ..Default::default() };
        let t0 = Instant::now();
        let plan = tune::tune_model(&cfg, &model, prec, &topts)?;
        let tune_wall = t0.elapsed().as_secs_f64();
        let mut static_engine = Engine::new(cfg)?;
        static_engine.set_exec_mode(mode);
        static_engine.set_counters(counters.clone());
        let static_run = static_engine
            .session()
            .with_policy(Policy::Mixed)
            .run_model(&model, prec)?;
        let mut tuned_engine = Engine::new(cfg)?;
        tuned_engine.set_exec_mode(mode);
        tuned_engine.set_counters(counters.clone());
        let improved_ops = plan.improved_ops();
        let tuned_ops = plan.ops.len();
        let tuned_run = tuned_engine
            .session()
            .with_tuned_plan(Arc::new(plan))
            .run_model(&model, prec)?;
        tuned.push(TunedBenchEntry {
            model: name.to_string(),
            prec,
            cycles_static: static_run.total.cycles,
            cycles_tuned: tuned_run.total.cycles,
            improved_ops,
            tuned_ops,
            tune_wall_s: tune_wall,
        });
    }

    Ok(BenchReport {
        quick: opts.quick,
        exact_only,
        hotpath,
        operators,
        models,
        tuned,
        cache_hits: cache.hits,
        cache_misses: cache.misses,
        counters: counters.snapshot(),
        total_wall_s: t_all.elapsed().as_secs_f64(),
    })
}

/// Gate a report against a `bench/baseline.json` document. Every metric in
/// the baseline is higher-is-better; a measured value below
/// `baseline × (1 − tolerance)` (or a metric missing from the run) is a
/// regression and returns [`SpeedError::Bench`].
///
/// Tolerance precedence: an explicit `cli_tolerance` (the `--tolerance`
/// flag) wins over the baseline file's embedded `"tolerance"`, which wins
/// over the 20% default. Fast-path metrics absent from an `--exact` run
/// are skipped rather than failed — exact mode exists to diagnose
/// fast-path regressions, so it cannot itself be gated on them.
pub fn check_baseline(
    report: &BenchReport,
    baseline_src: &str,
    cli_tolerance: Option<f64>,
) -> Result<()> {
    let doc = parse(baseline_src)?;
    let tol = cli_tolerance
        .or_else(|| doc.get("tolerance").and_then(Json::as_f64))
        .unwrap_or(0.2);
    let metrics = doc
        .get("metrics")
        .and_then(Json::as_obj)
        .ok_or_else(|| SpeedError::Bench("baseline has no \"metrics\" object".into()))?;
    let mut fails = Vec::new();
    for (name, v) in metrics {
        let Some(base) = v.as_f64() else { continue };
        match report.metric(name) {
            None if report.exact_only => {} // fast-path metric, exact run
            None => fails.push(format!("metric '{name}' missing from this run")),
            Some(got) if got < base * (1.0 - tol) => fails.push(format!(
                "{name}: measured {got:.3} < floor {:.3} (baseline {base:.3}, tolerance {:.0}%)",
                base * (1.0 - tol),
                tol * 100.0
            )),
            _ => {}
        }
    }
    if fails.is_empty() {
        Ok(())
    } else {
        Err(SpeedError::Bench(fails.join("; ")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_report() -> BenchReport {
        BenchReport {
            quick: true,
            exact_only: false,
            hotpath: HotpathResult {
                op: "conv3x3 test".into(),
                stages: 1000,
                exact_wall_s: 0.01,
                fast_wall_s: 0.002,
                exact_stages_per_s: 100_000.0,
                fast_stages_per_s: 500_000.0,
                speedup: 5.0,
            },
            operators: vec![BenchEntry {
                name: "mm_8x8".into(),
                prec: Precision::Int8,
                strategy: "mm".into(),
                wall_s: 0.001,
                sim_cycles: 1234,
                macs: 512,
                gops_simulated: 10.0,
                mops_per_s_host: 1.0,
                cache_hit_rate: 0.5,
                breakdown: CycleBreakdown {
                    chain: 1000,
                    load: 200,
                    overhead: 34,
                    ..Default::default()
                },
            }],
            models: vec![],
            tuned: vec![TunedBenchEntry {
                model: "vgg16".into(),
                prec: Precision::Int8,
                cycles_static: 1200,
                cycles_tuned: 1000,
                improved_ops: 3,
                tuned_ops: 10,
                tune_wall_s: 0.1,
            }],
            cache_hits: 1,
            cache_misses: 1,
            counters: vec![("engine_cache_hits", 1), ("engine_cache_misses", 1)],
            total_wall_s: 0.5,
        }
    }

    #[test]
    fn json_is_parseable_and_carries_metrics() {
        let r = fake_report();
        let doc = parse(&r.to_json()).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_i64), Some(3));
        let m = doc.get("metrics").and_then(Json::as_obj).unwrap();
        assert_eq!(
            m.get("sim_hotpath_fast_stages_per_s").and_then(Json::as_f64),
            Some(500_000.0)
        );
        assert!(doc.get("sim_hotpath").is_some());
        assert_eq!(
            doc.get("operators").and_then(Json::as_arr).map(|a| a.len()),
            Some(1)
        );
        // The tuned section carries the static/tuned cycle pair and the
        // gateable best-speedup metric (1200/1000 = 1.2).
        let t = doc.get("tuned").and_then(Json::as_arr).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].get("cycles_tuned").and_then(Json::as_i64), Some(1000));
        // Schema 3: per-entry cycle breakdowns + the counter registry.
        let ops = doc.get("operators").and_then(Json::as_arr).unwrap();
        let bd = ops[0].get("breakdown").unwrap();
        assert_eq!(bd.get("chain").and_then(Json::as_i64), Some(1000));
        assert_eq!(bd.get("overhead").and_then(Json::as_i64), Some(34));
        let counters = doc.get("counters").unwrap();
        assert_eq!(counters.get("engine_cache_hits").and_then(Json::as_i64), Some(1));
        let best = m.get("tuned_vs_mixed_best_speedup").and_then(Json::as_f64).unwrap();
        assert!((best - 1.2).abs() < 1e-9, "{best}");
    }

    #[test]
    fn baseline_gate_passes_within_tolerance_and_fails_past_it() {
        let r = fake_report();
        // Baseline below measured: passes.
        let ok = r.baseline_json(0.2, 0.5);
        check_baseline(&r, &ok, None).unwrap();
        // Baseline far above measured: regression.
        let bad = r#"{ "tolerance": 0.2,
            "metrics": { "sim_hotpath_fast_stages_per_s": 10000000.0 } }"#;
        let err = check_baseline(&r, bad, None).unwrap_err();
        assert!(matches!(err, SpeedError::Bench(_)), "{err}");
        assert!(err.to_string().contains("sim_hotpath_fast_stages_per_s"));
        // Unknown metric in the baseline: reported as missing.
        let missing = r#"{ "metrics": { "no_such_metric": 1.0 } }"#;
        assert!(check_baseline(&r, missing, None).is_err());
        // Within tolerance (measured 500k vs baseline 600k, file tol 20% =>
        // floor 480k): passes.
        let close = r#"{ "tolerance": 0.2,
            "metrics": { "sim_hotpath_fast_stages_per_s": 600000.0 } }"#;
        check_baseline(&r, close, None).unwrap();
        // An explicit CLI tolerance overrides the file's: 5% => floor 570k
        // > measured 500k => regression.
        assert!(check_baseline(&r, close, Some(0.05)).is_err());
    }

    #[test]
    fn exact_only_runs_skip_fastpath_metrics_in_gate() {
        let mut r = fake_report();
        r.exact_only = true;
        // Fast-path metrics are not emitted...
        assert!(r.metric("sim_hotpath_fast_stages_per_s").is_none());
        assert!(r.metric("sim_hotpath_speedup").is_none());
        assert!(r.metric("sim_hotpath_exact_stages_per_s").is_some());
        // ...and a baseline listing them does not spuriously fail the run.
        let base = r#"{ "tolerance": 0.2, "metrics": {
            "sim_hotpath_fast_stages_per_s": 1000000.0,
            "sim_hotpath_speedup": 2.0,
            "sim_hotpath_exact_stages_per_s": 50000.0 } }"#;
        check_baseline(&r, base, None).unwrap();
    }

    #[test]
    fn json_string_escaping() {
        assert_eq!(jstr("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(jf(f64::NAN), "0");
        assert_eq!(jf(1.5), "1.500000");
    }

    #[test]
    fn hotpath_measurement_runs_on_a_tiny_op() {
        // A tiny stand-in op keeps this test fast while exercising the
        // warm-engine measurement path end to end in both modes.
        let op = OpDesc::conv(4, 4, 8, 8, 3, 1, 1, Precision::Int8);
        let (we, s1) = measure_hotpath(&op, ExecMode::Exact, 1).unwrap();
        let (wf, s2) = measure_hotpath(&op, ExecMode::Batch, 1).unwrap();
        assert_eq!(s1, s2);
        assert!(s1 > 0);
        assert!(we > 0.0 && wf > 0.0);
    }
}
