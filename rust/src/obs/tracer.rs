//! Structured tracing on a virtual-tick clock.
//!
//! A [`Tracer`] is a cheap-clone handle onto a bounded span ring buffer
//! plus a *virtual clock*: a cycle cursor advanced only by the simulator
//! as it produces cycles ([`Tracer::advance`] is called exactly where
//! [`crate::sim::SimStats::cycles`] accumulates). Wall time never enters a
//! span, so traces are bit-reproducible and digest-stable: tracer on/off,
//! worker count, and host speed cannot change a single timestamp.
//!
//! Spans form a hierarchy by containment on one timeline per `tid`
//! (serving workers use their worker index): a request span encloses its
//! op spans, an op span its compiled-segment spans, a segment span its
//! stream-run spans. [`chrome_trace_json`] exports the ring as
//! Chrome-trace/Perfetto "X" (complete) events — load the file at
//! `chrome://tracing` or <https://ui.perfetto.dev>.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, PoisonError};

use crate::runtime::json::jstr;

/// Span granularity a [`Tracer`] records, coarsest to finest.
///
/// Each level includes every coarser one; [`TraceLevel::Insn`] additionally
/// makes the batch-mode simulator expand closed-form runs into the
/// per-instruction path (bit-exact by the fast-path parity property) so
/// scoreboard-level spans exist to record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// One span per executed operator (and per serve request).
    Op,
    /// Plus one span per compiled program segment.
    Segment,
    /// Plus one span per batched stream run (tensor chain / load / store).
    Run,
    /// Plus one span per instruction, from the issue scoreboard.
    Insn,
}

impl TraceLevel {
    /// Parse a CLI-facing level name.
    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s {
            "op" => Some(TraceLevel::Op),
            "segment" => Some(TraceLevel::Segment),
            "run" => Some(TraceLevel::Run),
            "insn" => Some(TraceLevel::Insn),
            _ => None,
        }
    }

    /// CLI-facing name of the level.
    pub fn name(self) -> &'static str {
        match self {
            TraceLevel::Op => "op",
            TraceLevel::Segment => "segment",
            TraceLevel::Run => "run",
            TraceLevel::Insn => "insn",
        }
    }
}

/// Category of a recorded [`Span`] (the Chrome-trace `cat` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanCat {
    /// A serve-pool request (or a whole profiled model run).
    Request,
    /// One executed operator.
    Op,
    /// One compiled program segment.
    Segment,
    /// One batched stream run within a segment.
    Run,
    /// One instruction's occupancy window on the scoreboard.
    Insn,
}

impl SpanCat {
    /// Chrome-trace category string.
    pub fn name(self) -> &'static str {
        match self {
            SpanCat::Request => "request",
            SpanCat::Op => "op",
            SpanCat::Segment => "segment",
            SpanCat::Run => "run",
            SpanCat::Insn => "insn",
        }
    }

    /// Is this category recorded at `level`? Request and op spans are
    /// always kept — they are the coarsest useful view.
    pub fn recorded_at(self, level: TraceLevel) -> bool {
        match self {
            SpanCat::Request | SpanCat::Op => true,
            SpanCat::Segment => level >= TraceLevel::Segment,
            SpanCat::Run => level >= TraceLevel::Run,
            SpanCat::Insn => level >= TraceLevel::Insn,
        }
    }
}

/// One recorded span on the virtual-tick timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// Category (hierarchy level) of the span.
    pub cat: SpanCat,
    /// Human-readable label (operator shape, run kind, instruction).
    pub name: String,
    /// Virtual-tick start (simulated cycles since the tracer attached).
    pub begin: u64,
    /// Duration in virtual ticks (simulated cycles).
    pub dur: u64,
    /// Timeline id: the serving worker index (0 for a single engine).
    pub tid: u32,
}

/// The shared ring: spans plus the virtual-clock cursor.
struct TraceBuf {
    spans: VecDeque<Span>,
    capacity: usize,
    dropped: u64,
    now: u64,
}

/// Cheap-clone handle onto one virtual timeline's span ring.
///
/// Cloning shares the ring and the clock (one timeline per worker); the
/// recording `level`, `tid`, and echo flag ride along by value.
#[derive(Clone)]
pub struct Tracer {
    buf: Arc<Mutex<TraceBuf>>,
    tid: u32,
    level: TraceLevel,
    echo: bool,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("tid", &self.tid)
            .field("level", &self.level)
            .field("spans", &self.span_count())
            .finish()
    }
}

impl Tracer {
    /// A tracer recording at `level` into a fresh ring of `capacity`
    /// spans, stamping every span with timeline id `tid`.
    pub fn new(level: TraceLevel, capacity: usize, tid: u32) -> Tracer {
        Tracer {
            buf: Arc::new(Mutex::new(TraceBuf {
                spans: VecDeque::new(),
                capacity: capacity.max(1),
                dropped: 0,
                now: 0,
            })),
            tid,
            level,
            echo: false,
        }
    }

    /// Build a tracer from an [`super::ObsConfig`], or `None` when tracing
    /// is off.
    pub fn from_config(cfg: &super::ObsConfig, tid: u32) -> Option<Tracer> {
        cfg.trace.map(|level| {
            let mut t = Tracer::new(level, cfg.capacity_or_default(), tid);
            t.echo = cfg.echo_insns;
            t
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TraceBuf> {
        self.buf.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Recording granularity.
    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// Echo per-instruction scoreboard lines to stderr (what the
    /// retired `SPEED_TRACE` env var used to force).
    pub fn echo(&self) -> bool {
        self.echo
    }

    /// Current virtual time (cycles accumulated on this timeline).
    pub fn now(&self) -> u64 {
        self.lock().now
    }

    /// Advance the virtual clock. Called exactly where the simulator
    /// accumulates cycles into its stats, so span timelines and
    /// [`crate::sim::SimStats::cycles`] agree by construction.
    pub fn advance(&self, cycles: u64) {
        self.lock().now += cycles;
    }

    /// Record one span (if `cat` is within the recording level). The ring
    /// is bounded: a full ring evicts its oldest span and counts a drop.
    pub fn record(&self, cat: SpanCat, name: impl Into<String>, begin: u64, dur: u64) {
        if !cat.recorded_at(self.level) {
            return;
        }
        let mut b = self.lock();
        if b.spans.len() >= b.capacity {
            b.spans.pop_front();
            b.dropped += 1;
        }
        let tid = self.tid;
        b.spans.push_back(Span { cat, name: name.into(), begin, dur, tid });
    }

    /// Spans evicted from the full ring so far.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    /// Number of spans currently held.
    pub fn span_count(&self) -> usize {
        self.lock().spans.len()
    }

    /// Drain every held span (oldest first), leaving the ring empty and
    /// the clock untouched.
    pub fn take_spans(&self) -> Vec<Span> {
        self.lock().spans.drain(..).collect()
    }
}

/// Export spans as Chrome-trace-format JSON (`traceEvents` of "X"
/// complete events; `ts`/`dur` are virtual cycles, one tick per cycle).
/// `counters` — typically a [`super::Counters::snapshot`] — rides along
/// under the format's free-form `otherData` key.
pub fn chrome_trace_json(spans: &[Span], counters: &[(&'static str, u64)]) -> String {
    let mut s = String::with_capacity(128 + spans.len() * 96);
    s.push_str("{\n  \"displayTimeUnit\": \"ns\",\n  \"traceEvents\": [\n");
    for (i, sp) in spans.iter().enumerate() {
        s.push_str("    {\"name\": ");
        s.push_str(&jstr(&sp.name));
        s.push_str(&format!(
            ", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \
             \"pid\": 1, \"tid\": {}}}",
            sp.cat.name(),
            sp.begin,
            sp.dur,
            sp.tid
        ));
        s.push_str(if i + 1 == spans.len() { "\n" } else { ",\n" });
    }
    s.push_str("  ],\n  \"otherData\": {\n    \"clock\": \"virtual-cycles\",\n");
    s.push_str("    \"counters\": {\n");
    for (i, (name, v)) in counters.iter().enumerate() {
        s.push_str(&format!("      \"{name}\": {v}"));
        s.push_str(if i + 1 == counters.len() { "\n" } else { ",\n" });
    }
    s.push_str("    }\n  }\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered_and_gate_categories() {
        assert!(TraceLevel::Op < TraceLevel::Insn);
        assert!(SpanCat::Op.recorded_at(TraceLevel::Op));
        assert!(!SpanCat::Run.recorded_at(TraceLevel::Segment));
        assert!(SpanCat::Insn.recorded_at(TraceLevel::Insn));
        assert!(SpanCat::Request.recorded_at(TraceLevel::Op));
        for l in ["op", "segment", "run", "insn"] {
            assert_eq!(TraceLevel::parse(l).unwrap().name(), l);
        }
        assert_eq!(TraceLevel::parse("wall-clock"), None);
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = Tracer::new(TraceLevel::Op, 2, 0);
        for i in 0..5u64 {
            t.record(SpanCat::Op, format!("op{i}"), i, 1);
        }
        assert_eq!(t.span_count(), 2);
        assert_eq!(t.dropped(), 3);
        let spans = t.take_spans();
        assert_eq!(spans[0].name, "op3");
        assert_eq!(spans[1].name, "op4");
        assert_eq!(t.span_count(), 0);
    }

    #[test]
    fn clones_share_the_clock_and_ring() {
        let t = Tracer::new(TraceLevel::Segment, 16, 3);
        let u = t.clone();
        t.advance(10);
        assert_eq!(u.now(), 10);
        u.record(SpanCat::Segment, "seg", u.now(), 4);
        assert_eq!(t.span_count(), 1);
        assert_eq!(t.take_spans()[0].tid, 3);
    }

    #[test]
    fn chrome_export_is_parseable_json() {
        let t = Tracer::new(TraceLevel::Op, 8, 0);
        t.record(SpanCat::Op, "conv \"3x3\"", 0, 100);
        t.record(SpanCat::Op, "mm", 100, 50);
        let json = chrome_trace_json(&t.take_spans(), &[("engine_cache_hits", 7)]);
        let doc = crate::runtime::json::parse(&json).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(events[1].get("ts").and_then(|v| v.as_i64()), Some(100));
        let ctrs = doc.get("otherData").and_then(|v| v.get("counters")).unwrap();
        assert_eq!(ctrs.get("engine_cache_hits").and_then(|v| v.as_i64()), Some(7));
    }
}
