//! Exact cycle attribution: where did [`crate::sim::SimStats::cycles`] go?
//!
//! The simulator's completion frontier only ever advances monotonically
//! (`last_complete = max(last_complete, complete)`), so attributing each
//! frontier advancement to the instruction class that caused it telescopes
//! *exactly* to the run's cycle count; the ≤ 1-cycle pipeline-drain clamp
//! applied per run lands in the [`CycleBreakdown::overhead`] bucket. The
//! invariant `breakdown.total() == stats.cycles` therefore holds to the
//! cycle for both exec modes — enforced by `tests/obs_inertness.rs`.
//!
//! The buckets split the paper's story lines: multi-precision systolic
//! compute (VSAM/VSAC chains), the memory system (load / store runs and
//! [`crate::sim::SimStats::stall_mem_port`]), the vector ALU epilogues,
//! scalar/config glue, and the cost of `VSACFG` precision reconfiguration
//! — the axes related mixed-precision processors are evaluated on.

/// Exclusive cycle buckets for one simulation run (or any merge of runs).
///
/// `Copy`/`Eq` so engines can snapshot-and-diff it like a counter; the
/// component sum equals the matching `SimStats::cycles` exactly.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CycleBreakdown {
    /// MPTU systolic chains: `VSAM` / `VSAC` windows (closed-form batch
    /// runs and scoreboard-issued exact steps alike).
    pub chain: u64,
    /// Vector load unit: `VLE` / `VSALD` runs.
    pub load: u64,
    /// Vector store unit: `VSE` runs.
    pub store: u64,
    /// Vector ALU: `VMACC` / `VMUL` / `VADD` / `VMV` epilogues.
    pub alu: u64,
    /// Scalar core + config path: `ADDI` / `VSETVLI` / non-switching
    /// `VSACFG` dimension updates.
    pub scalar: u64,
    /// `VSACFG` executions that re-precision the datapath.
    pub prec_switch: u64,
    /// Per-run pipeline-drain residue: the simulator charges every stream
    /// run at least one cycle; the cycles not explained by a frontier
    /// advancement land here (≤ 1 per run).
    pub overhead: u64,
}

impl CycleBreakdown {
    /// Bucket names in [`CycleBreakdown::components`] order (stable — the
    /// report schema-3 JSON key order).
    pub const NAMES: [&'static str; 7] =
        ["chain", "load", "store", "alu", "scalar", "prec_switch", "overhead"];

    /// Component values in [`CycleBreakdown::NAMES`] order.
    pub fn components(&self) -> [u64; 7] {
        [
            self.chain,
            self.load,
            self.store,
            self.alu,
            self.scalar,
            self.prec_switch,
            self.overhead,
        ]
    }

    /// Sum of every bucket — equals the matching `SimStats::cycles`.
    pub fn total(&self) -> u64 {
        self.components().iter().sum()
    }

    /// Accumulate another breakdown (sequential composition, like
    /// [`crate::sim::SimStats::merge`]).
    pub fn merge(&mut self, other: &CycleBreakdown) {
        self.chain += other.chain;
        self.load += other.load;
        self.store += other.store;
        self.alu += other.alu;
        self.scalar += other.scalar;
        self.prec_switch += other.prec_switch;
        self.overhead += other.overhead;
    }

    /// Component-wise difference vs an earlier snapshot of the same
    /// monotone accumulator (per-op / per-request attribution).
    pub fn since(&self, earlier: &CycleBreakdown) -> CycleBreakdown {
        CycleBreakdown {
            chain: self.chain - earlier.chain,
            load: self.load - earlier.load,
            store: self.store - earlier.store,
            alu: self.alu - earlier.alu,
            scalar: self.scalar - earlier.scalar,
            prec_switch: self.prec_switch - earlier.prec_switch,
            overhead: self.overhead - earlier.overhead,
        }
    }

    /// JSON object (one line per bucket), indented by `indent` spaces for
    /// the inner lines — the schema-3 report fragment.
    pub fn json_object(&self, indent: usize) -> String {
        let pad = " ".repeat(indent);
        let mut s = String::from("{\n");
        for (i, (name, v)) in Self::NAMES.iter().zip(self.components()).enumerate() {
            s.push_str(&format!(
                "{pad}  \"{name}\": {v}{}\n",
                if i + 1 == Self::NAMES.len() { "" } else { "," }
            ));
        }
        s.push_str(&format!("{pad}}}"));
        s
    }

    /// One-line percentage summary for CLI output.
    pub fn summary_line(&self) -> String {
        let total = self.total().max(1) as f64;
        Self::NAMES
            .iter()
            .zip(self.components())
            .filter(|&(_, v)| v > 0)
            .map(|(name, v)| format!("{name} {:.1}%", 100.0 * v as f64 / total))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CycleBreakdown {
        CycleBreakdown {
            chain: 60,
            load: 20,
            store: 10,
            alu: 5,
            scalar: 3,
            prec_switch: 1,
            overhead: 1,
        }
    }

    #[test]
    fn total_is_component_sum() {
        assert_eq!(sample().total(), 100);
        assert_eq!(CycleBreakdown::default().total(), 0);
    }

    #[test]
    fn merge_and_since_are_inverse() {
        let mut a = sample();
        let before = a;
        a.merge(&sample());
        assert_eq!(a.total(), 200);
        assert_eq!(a.since(&before), sample());
    }

    #[test]
    fn json_object_parses_and_keeps_bucket_order() {
        let json = sample().json_object(2);
        let doc = crate::runtime::json::parse(&json).unwrap();
        assert_eq!(doc.get("chain").and_then(|v| v.as_i64()), Some(60));
        assert_eq!(doc.get("overhead").and_then(|v| v.as_i64()), Some(1));
        let names = CycleBreakdown::NAMES;
        let mut last = 0;
        for n in names {
            let pos = json.find(&format!("\"{n}\"")).unwrap();
            assert!(pos > last, "{n} out of order");
            last = pos;
        }
    }

    #[test]
    fn summary_line_skips_empty_buckets() {
        let line = sample().summary_line();
        assert!(line.contains("chain 60.0%"));
        let sparse = CycleBreakdown { chain: 4, ..Default::default() };
        assert_eq!(sparse.summary_line(), "chain 100.0%");
    }
}
