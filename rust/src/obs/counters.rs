//! Unified counter registry: static-ID atomic counters, shared pool-wide.
//!
//! Before this module every subsystem grew its own tally struct — engine
//! `CacheStats`, scheduler fields, serve-metrics tune counters, verifier
//! rule totals — each with its own snapshot and JSON path. [`Counters`] is
//! the one registry they all feed: a fixed array of relaxed atomics
//! indexed by the [`Counter`] enum, cheap-clone shared the same way
//! [`crate::engine::SharedPrograms`] shares compiled programs across a
//! pool's engines. The per-subsystem structs remain the lock-held fast
//! paths and public accessors; the registry is the unified read side with
//! one [`Counters::snapshot`] / [`Counters::json_object`] surface.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Stable identity of one registry counter. The discriminant order is the
/// snapshot/JSON order and is append-only (IDs never renumber).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Engine program-cache hits (private or shared).
    EngineCacheHits,
    /// Engine program-cache hits served from the pool-shared map.
    EngineCacheSharedHits,
    /// Engine program-cache misses (fresh compilations).
    EngineCacheMisses,
    /// Scheduler: requests routed to a lane already at their precision.
    SchedAffinityHits,
    /// Scheduler: requests that re-precisioned their lane.
    SchedAffinityMisses,
    /// Scheduler: micro-batches work-stolen from a backed-up lane.
    SchedSteals,
    /// KV residency: decode steps landing on their resident lane.
    KvHits,
    /// KV residency: decode steps arriving after a spill (or orphaned).
    KvMisses,
    /// KV residency: sessions evicted past the per-worker budget.
    KvSpills,
    /// Online tuning: first-request tune-and-publish stalls.
    TuneStalls,
    /// Online tuning: requests served from the shared plan registry.
    TunePlanHits,
    /// Auto-tuner: candidate mappings costed on the simulator.
    TuneCandidates,
    /// Static verifier: compiled programs verified at cache-insert time.
    VerifyPrograms,
    /// Static verifier: rule evaluations (instructions × rules).
    VerifyRuleEvals,
    /// Tracing: spans evicted from full ring buffers.
    TraceSpansDropped,
    /// Auto-tuner: candidate mappings ranked out by the static cost model
    /// and never simulated (`TuneOptions::prune`).
    TuneCandidatesPruned,
    /// Auto-tuner: enumerated FF candidates whose weight slice spills the
    /// VRF (costed with honest per-row refetch runs, not rejected).
    TuneCandidatesSpilledFf,
}

impl Counter {
    /// Every counter, in stable snapshot order.
    pub const ALL: [Counter; 17] = [
        Counter::EngineCacheHits,
        Counter::EngineCacheSharedHits,
        Counter::EngineCacheMisses,
        Counter::SchedAffinityHits,
        Counter::SchedAffinityMisses,
        Counter::SchedSteals,
        Counter::KvHits,
        Counter::KvMisses,
        Counter::KvSpills,
        Counter::TuneStalls,
        Counter::TunePlanHits,
        Counter::TuneCandidates,
        Counter::VerifyPrograms,
        Counter::VerifyRuleEvals,
        Counter::TraceSpansDropped,
        Counter::TuneCandidatesPruned,
        Counter::TuneCandidatesSpilledFf,
    ];

    /// Position in the registry's slot array.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable snake_case name (the JSON key).
    pub fn name(self) -> &'static str {
        match self {
            Counter::EngineCacheHits => "engine_cache_hits",
            Counter::EngineCacheSharedHits => "engine_cache_shared_hits",
            Counter::EngineCacheMisses => "engine_cache_misses",
            Counter::SchedAffinityHits => "sched_affinity_hits",
            Counter::SchedAffinityMisses => "sched_affinity_misses",
            Counter::SchedSteals => "sched_steals",
            Counter::KvHits => "kv_hits",
            Counter::KvMisses => "kv_misses",
            Counter::KvSpills => "kv_spills",
            Counter::TuneStalls => "tune_stalls",
            Counter::TunePlanHits => "tune_plan_hits",
            Counter::TuneCandidates => "tune_candidates",
            Counter::VerifyPrograms => "verify_programs",
            Counter::VerifyRuleEvals => "verify_rule_evals",
            Counter::TraceSpansDropped => "trace_spans_dropped",
            Counter::TuneCandidatesPruned => "tune_candidates_pruned",
            Counter::TuneCandidatesSpilledFf => "tune_candidates_spilled_ff",
        }
    }
}

/// The shared registry: one relaxed atomic slot per [`Counter`].
///
/// Clones share the slots (an `Arc`), so a pool hands one registry to
/// every worker engine and reads a single coherent snapshot at the end —
/// the `SharedPrograms` sharing pattern applied to counters.
#[derive(Clone)]
pub struct Counters {
    slots: Arc<[AtomicU64]>,
}

impl Default for Counters {
    fn default() -> Self {
        Counters::new()
    }
}

impl std::fmt::Debug for Counters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut d = f.debug_struct("Counters");
        for c in Counter::ALL {
            let v = self.get(c);
            if v > 0 {
                d.field(c.name(), &v);
            }
        }
        d.finish()
    }
}

impl Counters {
    /// A fresh registry with every counter at zero.
    pub fn new() -> Counters {
        Counters { slots: (0..Counter::ALL.len()).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Add `n` to a counter (relaxed; counters are monotone tallies, not
    /// synchronization).
    pub fn add(&self, c: Counter, n: u64) {
        self.slots[c.index()].fetch_add(n, Ordering::Relaxed);
    }

    /// Increment a counter by one.
    pub fn incr(&self, c: Counter) {
        self.add(c, 1);
    }

    /// Current value of one counter.
    pub fn get(&self, c: Counter) -> u64 {
        self.slots[c.index()].load(Ordering::Relaxed)
    }

    /// Snapshot every counter in stable order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        Counter::ALL.iter().map(|&c| (c.name(), self.get(c))).collect()
    }

    /// JSON object (one line per counter), indented by `indent` spaces
    /// for the inner lines — the schema-3 report fragment.
    pub fn json_object(&self, indent: usize) -> String {
        let pad = " ".repeat(indent);
        let snap = self.snapshot();
        let mut s = String::from("{\n");
        for (i, (name, v)) in snap.iter().enumerate() {
            s.push_str(&format!(
                "{pad}  \"{name}\": {v}{}\n",
                if i + 1 == snap.len() { "" } else { "," }
            ));
        }
        s.push_str(&format!("{pad}}}"));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_match_all_order() {
        for (i, c) in Counter::ALL.iter().enumerate() {
            assert_eq!(c.index(), i, "{c:?}");
        }
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<_> = Counter::ALL.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Counter::ALL.len());
    }

    #[test]
    fn clones_share_slots() {
        let a = Counters::new();
        let b = a.clone();
        a.add(Counter::SchedSteals, 3);
        b.incr(Counter::SchedSteals);
        assert_eq!(a.get(Counter::SchedSteals), 4);
        assert_eq!(b.snapshot()[Counter::SchedSteals.index()], ("sched_steals", 4));
    }

    #[test]
    fn json_object_parses_and_lists_every_counter() {
        let c = Counters::new();
        c.add(Counter::KvHits, 11);
        let doc = crate::runtime::json::parse(&c.json_object(4)).unwrap();
        let obj = doc.as_obj().unwrap();
        assert_eq!(obj.len(), Counter::ALL.len());
        assert_eq!(doc.get("kv_hits").and_then(|v| v.as_i64()), Some(11));
        assert_eq!(doc.get("tune_stalls").and_then(|v| v.as_i64()), Some(0));
    }
}
