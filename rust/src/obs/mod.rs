//! Deterministic observability: structured tracing, cycle attribution,
//! and a unified counter registry.
//!
//! Three pillars, one hard invariant:
//!
//! * **Structured tracing** ([`tracer`]): a [`Tracer`] records hierarchical
//!   spans (request → op → compiled-segment → stream-run → instruction) on
//!   a *virtual-tick* clock — simulated cycles, never wall time — into a
//!   bounded ring buffer, exportable as Chrome-trace JSON
//!   ([`chrome_trace_json`], CLI `repro profile`). Virtual timestamps make
//!   traces bit-reproducible: the same workload produces the same trace on
//!   any machine, any worker count.
//! * **Cycle attribution** ([`breakdown`]): the simulator attributes every
//!   cycle of [`crate::sim::SimStats::cycles`] to a [`CycleBreakdown`]
//!   bucket (VSAM chain, load/store runs, ALU, scalar/config, precision
//!   switches, pipeline overhead). The components sum *exactly* to the
//!   total — enforced by property tests — so "where did the cycles go" is
//!   always answerable without reading source.
//! * **Counter registry** ([`counters`]): a [`Counters`] pool of static-ID
//!   atomic counters shared engine-wide (and pool-wide under
//!   [`crate::serve::ServePool`]), absorbing the previously scattered
//!   per-subsystem tallies — engine cache hits, scheduler steals/affinity,
//!   KV residency, tune stalls/plan hits, verifier rule evaluations — with
//!   one snapshot/JSON path.
//!
//! **Observability is free and inert.** Attaching or detaching a tracer
//! must leave [`crate::sim::SimStats`], serve digests, and tuned-plan
//! choices bit-identical. Instruction-level tracing in
//! [`crate::sim::ExecMode::Batch`] expands closed-form runs lazily into
//! the per-instruction path — bit-exact by the fast-path parity property —
//! instead of the old `SPEED_TRACE`-forces-exact-mode hack. The env var
//! is gone; tracing is configured explicitly, never ambiently.

pub mod breakdown;
pub mod counters;
pub mod tracer;

pub use breakdown::CycleBreakdown;
pub use counters::{Counter, Counters};
pub use tracer::{chrome_trace_json, Span, SpanCat, TraceLevel, Tracer};

/// Observability configuration carried by [`crate::engine::Engine`] and
/// [`crate::serve::ServeOptions`].
///
/// The default is fully off: no tracer is attached and execution paths are
/// untouched. Cycle attribution and counters are always live — they are
/// plain integer adds on paths already touching the same cache lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ObsConfig {
    /// Span granularity to record, or `None` for no tracing.
    pub trace: Option<TraceLevel>,
    /// Ring-buffer capacity in spans (`0` = [`ObsConfig::DEFAULT_CAPACITY`]).
    pub capacity: usize,
    /// Echo per-instruction scoreboard lines to stderr (the behaviour the
    /// retired `SPEED_TRACE` env var used to force).
    pub echo_insns: bool,
}

impl ObsConfig {
    /// Default span ring capacity when [`ObsConfig::capacity`] is `0`.
    pub const DEFAULT_CAPACITY: usize = 1 << 16;

    /// Observability fully off (the default).
    pub fn off() -> Self {
        Self::default()
    }

    /// Tracing at `level` with the default ring capacity.
    pub fn tracing(level: TraceLevel) -> Self {
        ObsConfig { trace: Some(level), ..Self::default() }
    }

    /// Effective ring capacity (resolving the `0` = default convention).
    pub fn capacity_or_default(&self) -> usize {
        if self.capacity == 0 {
            Self::DEFAULT_CAPACITY
        } else {
            self.capacity
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_off() {
        let c = ObsConfig::off();
        assert_eq!(c.trace, None);
        assert!(!c.echo_insns);
        assert_eq!(c.capacity_or_default(), ObsConfig::DEFAULT_CAPACITY);
    }

    #[test]
    fn tracing_constructor_sets_level_only() {
        let c = ObsConfig::tracing(TraceLevel::Segment);
        assert_eq!(c.trace, Some(TraceLevel::Segment));
        assert!(!c.echo_insns);
    }
}
