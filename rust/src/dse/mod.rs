//! Design-space exploration (Fig. 14): lanes ∈ {2,4,8} × TILE_{R,C} ∈
//! {2,4,8}², evaluated on the CONV3×3 16-bit workload, reporting achieved
//! throughput (GOPS) and area efficiency (GOPS/mm²).

use crate::config::{Precision, SpeedConfig};
use crate::coordinator::runner::{default_workers, run_parallel};
use crate::engine::Engine;
use crate::error::SpeedError;
use crate::isa::StrategyKind;
use crate::metrics::speed_area;
use crate::models::ops::OpDesc;

/// One evaluated DSE point.
#[derive(Debug, Clone, Copy)]
pub struct DsePoint {
    pub cfg: SpeedConfig,
    pub gops: f64,
    pub area_mm2: f64,
}

impl DsePoint {
    pub fn area_eff(&self) -> f64 {
        self.gops / self.area_mm2
    }
}

/// The Fig. 14 workload: a representative 16-bit CONV3×3 layer.
pub fn dse_workload() -> OpDesc {
    OpDesc::conv(64, 64, 32, 32, 3, 1, 1, Precision::Int16)
}

/// Quick-mode workload: identical operator shape class at 1/4-scale
/// feature maps — the relative ordering of the design points holds, at a
/// fraction of the simulation time.
pub fn dse_workload_quick() -> OpDesc {
    OpDesc::conv(64, 64, 8, 8, 3, 1, 1, Precision::Int16)
}

/// Evaluate one configuration on the DSE workload.
pub fn eval_point(cfg: &SpeedConfig, op: &OpDesc) -> Result<DsePoint, SpeedError> {
    let mut engine = Engine::new(*cfg)?;
    let (stats, _) = engine.run_op(op, StrategyKind::Ffcs, false)?;
    Ok(DsePoint {
        cfg: *cfg,
        gops: stats.gops(cfg.freq_ghz),
        area_mm2: speed_area(cfg).total(),
    })
}

/// The full 27-point sweep (3 lane counts × 3 × 3 tile geometries) with
/// the default worker count, full-size workload.
pub fn sweep() -> Vec<DsePoint> {
    sweep_with(default_workers(), false)
}

/// The 27-point sweep on `workers` threads; `quick` shrinks the workload.
pub fn sweep_with(workers: usize, quick: bool) -> Vec<DsePoint> {
    let mut cfgs = Vec::new();
    for lanes in [2u32, 4, 8] {
        for tr in [2u32, 4, 8] {
            for tc in [2u32, 4, 8] {
                cfgs.push(SpeedConfig::dse(lanes, tr, tc));
            }
        }
    }
    let op = if quick { dse_workload_quick() } else { dse_workload() };
    run_parallel(cfgs, workers, |cfg| eval_point(cfg, &op).expect("DSE point failed"))
}

/// Peak-area-efficiency point of a sweep.
pub fn peak_area_eff(points: &[DsePoint]) -> DsePoint {
    *points
        .iter()
        .max_by(|a, b| a.area_eff().partial_cmp(&b.area_eff()).unwrap())
        .expect("empty sweep")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_grows_with_lanes() {
        let op = dse_workload();
        let small = eval_point(&SpeedConfig::dse(2, 2, 2), &op).unwrap();
        let big = eval_point(&SpeedConfig::dse(8, 4, 4), &op).unwrap();
        assert!(big.gops > small.gops, "{} !> {}", big.gops, small.gops);
        assert!(big.area_mm2 > small.area_mm2);
    }

    #[test]
    fn quick_sweep_preserves_lane_scaling() {
        let pts = sweep_with(2, true);
        assert_eq!(pts.len(), 27);
        let small = pts.iter().find(|p| (p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c) == (2, 2, 2))
            .unwrap();
        let big = pts.iter().find(|p| (p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c) == (8, 4, 4))
            .unwrap();
        assert!(big.gops > small.gops, "{} !> {}", big.gops, small.gops);
    }

    #[test]
    fn gops_within_theoretical_peak() {
        let op = dse_workload();
        for lanes in [2u32, 4] {
            let cfg = SpeedConfig::dse(lanes, 2, 2);
            let p = eval_point(&cfg, &op).unwrap();
            assert!(p.gops <= cfg.peak_gops(Precision::Int16) + 1e-9,
                    "{} > peak {}", p.gops, cfg.peak_gops(Precision::Int16));
            assert!(p.gops > 0.2 * cfg.peak_gops(Precision::Int16));
        }
    }
}
