//! Design-space exploration (Fig. 14): lanes ∈ {2,4,8} × TILE_{R,C} ∈
//! {2,4,8}², evaluated on the CONV3×3 16-bit workload, reporting achieved
//! throughput (GOPS) and area efficiency (GOPS/mm²).

use crate::compiler::{execute_op, MemLayout};
use crate::config::{Precision, SpeedConfig};
use crate::coordinator::runner::{default_workers, run_parallel};
use crate::isa::StrategyKind;
use crate::metrics::speed_area;
use crate::models::ops::OpDesc;
use crate::sim::Processor;

/// One evaluated DSE point.
#[derive(Debug, Clone, Copy)]
pub struct DsePoint {
    pub cfg: SpeedConfig,
    pub gops: f64,
    pub area_mm2: f64,
}

impl DsePoint {
    pub fn area_eff(&self) -> f64 {
        self.gops / self.area_mm2
    }
}

/// The Fig. 14 workload: a representative 16-bit CONV3×3 layer.
pub fn dse_workload() -> OpDesc {
    OpDesc::conv(64, 64, 32, 32, 3, 1, 1, Precision::Int16)
}

/// Evaluate one configuration on the DSE workload.
pub fn eval_point(cfg: &SpeedConfig, op: &OpDesc) -> Result<DsePoint, String> {
    let mut proc = Processor::new(*cfg, 1 << 24);
    let layout = MemLayout::for_op(op, 1 << 24)?;
    let (stats, _) = execute_op(&mut proc, op, StrategyKind::Ffcs, layout, false)?;
    Ok(DsePoint {
        cfg: *cfg,
        gops: stats.gops(cfg.freq_ghz),
        area_mm2: speed_area(cfg).total(),
    })
}

/// The full 27-point sweep (3 lane counts × 3 × 3 tile geometries).
pub fn sweep() -> Vec<DsePoint> {
    let mut cfgs = Vec::new();
    for lanes in [2u32, 4, 8] {
        for tr in [2u32, 4, 8] {
            for tc in [2u32, 4, 8] {
                cfgs.push(SpeedConfig::dse(lanes, tr, tc));
            }
        }
    }
    let op = dse_workload();
    run_parallel(cfgs, default_workers(), |cfg| {
        eval_point(cfg, &op).expect("DSE point failed")
    })
}

/// Peak-area-efficiency point of a sweep.
pub fn peak_area_eff(points: &[DsePoint]) -> DsePoint {
    *points
        .iter()
        .max_by(|a, b| a.area_eff().partial_cmp(&b.area_eff()).unwrap())
        .expect("empty sweep")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_grows_with_lanes() {
        let op = dse_workload();
        let small = eval_point(&SpeedConfig::dse(2, 2, 2), &op).unwrap();
        let big = eval_point(&SpeedConfig::dse(8, 4, 4), &op).unwrap();
        assert!(big.gops > small.gops, "{} !> {}", big.gops, small.gops);
        assert!(big.area_mm2 > small.area_mm2);
    }

    #[test]
    fn gops_within_theoretical_peak() {
        let op = dse_workload();
        for lanes in [2u32, 4] {
            let cfg = SpeedConfig::dse(lanes, 2, 2);
            let p = eval_point(&cfg, &op).unwrap();
            assert!(p.gops <= cfg.peak_gops(Precision::Int16) + 1e-9,
                    "{} > peak {}", p.gops, cfg.peak_gops(Precision::Int16));
            assert!(p.gops > 0.2 * cfg.peak_gops(Precision::Int16));
        }
    }
}
