//! Design-space exploration (Fig. 14): lanes ∈ {2,4,8} × TILE_{R,C} ∈
//! {2,4,8}², evaluated on the CONV3×3 16-bit workload, reporting achieved
//! throughput (GOPS) and area efficiency (GOPS/mm²).
//!
//! The sweep has two modes. The static mode costs every point with the
//! Sec. III mixed mapping (FFCS on the CONV workload) — the paper's
//! methodology. The *tuned* mode (`repro dse --tuned`) additionally runs
//! a per-point [`tune_op`] search — the co-selection of hardware
//! configuration and dataflow mapping the paper's headline
//! area-efficiency claims rest on — and records both outcomes in each
//! [`DsePoint`], preserving the tuned ≤ static cycle invariant per point
//! (ties resolve to the static mapping inside the tuner).

use crate::config::{Precision, SpeedConfig};
use crate::coordinator::runner::{default_workers, run_parallel};
use crate::dataflow::MappingChoice;
use crate::engine::Engine;
use crate::error::SpeedError;
use crate::isa::StrategyKind;
use crate::metrics::speed_area;
use crate::models::ops::OpDesc;
use crate::obs::CycleBreakdown;
use crate::runtime::json::{jf, jstr};
use crate::tune::{tune_op, TuneOptions};

/// The tuned outcome of one DSE point (`--tuned` sweeps only).
#[derive(Debug, Clone, Copy)]
pub struct TunedDsePoint {
    /// Simulated cycles of the tuner-selected mapping (≤ the static
    /// mapping's [`DsePoint::static_cycles`] by the tie-to-static rule).
    pub cycles: u64,
    /// Achieved GOPS under the tuned mapping.
    pub gops: f64,
    /// The winning mapping (equals the static FFCS choice where nothing
    /// beat it).
    pub choice: MappingChoice,
    /// Mapping candidates costed at this point.
    pub candidates: u32,
}

/// One evaluated DSE point.
#[derive(Debug, Clone, Copy)]
pub struct DsePoint {
    /// The configuration evaluated.
    pub cfg: SpeedConfig,
    /// Achieved GOPS under the static Sec. III mapping.
    pub gops: f64,
    /// Modeled area of the configuration, mm².
    pub area_mm2: f64,
    /// Simulated cycles of the static mapping.
    pub static_cycles: u64,
    /// Cycle attribution of the static-mapping run (components sum to
    /// [`DsePoint::static_cycles`]) — shows where a design point is
    /// bound (chain-limited vs load/store-limited) as lanes/tiles scale.
    pub breakdown: CycleBreakdown,
    /// Per-point tuned outcome (`None` on a static-only sweep).
    pub tuned: Option<TunedDsePoint>,
}

impl DsePoint {
    /// Area efficiency of the static mapping (GOPS/mm²).
    pub fn area_eff(&self) -> f64 {
        self.gops / self.area_mm2
    }

    /// Area efficiency under the tuned mapping, when the sweep ran tuned.
    pub fn tuned_area_eff(&self) -> Option<f64> {
        self.tuned.map(|t| t.gops / self.area_mm2)
    }

    /// Best known area efficiency at this point (tuned when present).
    pub fn best_area_eff(&self) -> f64 {
        self.tuned_area_eff().unwrap_or_else(|| self.area_eff())
    }
}

/// The Fig. 14 workload: a representative 16-bit CONV3×3 layer.
pub fn dse_workload() -> OpDesc {
    OpDesc::conv(64, 64, 32, 32, 3, 1, 1, Precision::Int16)
}

/// Quick-mode workload: identical operator shape class at 1/4-scale
/// feature maps — the relative ordering of the design points holds, at a
/// fraction of the simulation time.
pub fn dse_workload_quick() -> OpDesc {
    OpDesc::conv(64, 64, 8, 8, 3, 1, 1, Precision::Int16)
}

/// Evaluate one configuration on the DSE workload (static mapping only).
pub fn eval_point(cfg: &SpeedConfig, op: &OpDesc) -> Result<DsePoint, SpeedError> {
    eval_point_with(cfg, op, false)
}

/// Evaluate one configuration; with `tuned`, also run the per-point
/// mapping search and record both outcomes. The tuner resolves ties to
/// the static mapping, so `tuned.cycles ≤ static_cycles` is an invariant
/// by construction; the point records whatever was measured (both cycle
/// counts are in the `DsePoint`), and the *callers* gate — `repro dse
/// --tuned` exits 1 on a violating point, and the dse unit tests assert
/// it per point — so a tuner defect surfaces as a typed failure, not a
/// worker-thread panic inside the sweep.
pub fn eval_point_with(
    cfg: &SpeedConfig,
    op: &OpDesc,
    tuned: bool,
) -> Result<DsePoint, SpeedError> {
    let mut engine = Engine::new(*cfg)?;
    let (stats, _) = engine.run_op(op, StrategyKind::Ffcs, false)?;
    let mut point = DsePoint {
        cfg: *cfg,
        gops: stats.gops(cfg.freq_ghz),
        area_mm2: speed_area(cfg).total(),
        static_cycles: stats.cycles,
        // The engine is fresh, so its lifetime breakdown is exactly the
        // static run's attribution (captured before any tuned search).
        breakdown: engine.breakdown(),
        tuned: None,
    };
    if tuned {
        // The quick per-point search: the same warm engine (its program
        // cache already holds the static stream) costs every feasible
        // (strategy × chunk) candidate, quiesced per candidate.
        let t = tune_op(&mut engine, op, &TuneOptions::default())?;
        point.tuned = Some(TunedDsePoint {
            cycles: t.cycles,
            // Same MACs, fewer (or equal) cycles: GOPS scales inversely
            // with the cycle count.
            gops: point.gops * point.static_cycles as f64 / t.cycles.max(1) as f64,
            choice: t.choice,
            candidates: t.candidates,
        });
    }
    Ok(point)
}

/// The full 27-point sweep (3 lane counts × 3 × 3 tile geometries) with
/// the default worker count, full-size workload.
pub fn sweep() -> Vec<DsePoint> {
    sweep_with(default_workers(), false)
}

/// The 27-point sweep on `workers` threads; `quick` shrinks the workload.
pub fn sweep_with(workers: usize, quick: bool) -> Vec<DsePoint> {
    sweep_opts(workers, quick, false)
}

/// The 27-point sweep; `tuned` runs the per-point mapping search and
/// fills [`DsePoint::tuned`] at every point.
pub fn sweep_opts(workers: usize, quick: bool, tuned: bool) -> Vec<DsePoint> {
    let mut cfgs = Vec::new();
    for lanes in [2u32, 4, 8] {
        for tr in [2u32, 4, 8] {
            for tc in [2u32, 4, 8] {
                cfgs.push(SpeedConfig::dse(lanes, tr, tc));
            }
        }
    }
    let op = if quick { dse_workload_quick() } else { dse_workload() };
    run_parallel(cfgs, workers, |cfg| {
        eval_point_with(cfg, &op, tuned).expect("DSE point failed")
    })
}

/// Peak-area-efficiency point of a sweep (static metric — the figure's
/// historical ranking; tuned rankings use [`DsePoint::best_area_eff`]).
pub fn peak_area_eff(points: &[DsePoint]) -> DsePoint {
    *points
        .iter()
        .max_by(|a, b| a.area_eff().partial_cmp(&b.area_eff()).unwrap())
        .expect("empty sweep")
}

/// Serialize a sweep as the `DSE_sweep.json` artifact (the `repro dse
/// --out` document the CI tuned-DSE leg uploads).
pub fn sweep_json(points: &[DsePoint], quick: bool) -> String {
    let tuned = points.iter().any(|p| p.tuned.is_some());
    let mut s = String::with_capacity(4096);
    // Schema 2: per-point static-mapping cycle breakdowns.
    s.push_str("{\n  \"schema\": 2,\n  \"bench\": \"dse\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str(&format!("  \"tuned\": {tuned},\n"));
    s.push_str("  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        let (tc, tg, te, choice, cands) = match p.tuned {
            Some(t) => (
                t.cycles.to_string(),
                jf(t.gops),
                jf(t.gops / p.area_mm2),
                jstr(&t.choice.to_string()),
                t.candidates,
            ),
            None => ("null".into(), "null".into(), "null".into(), "null".into(), 0),
        };
        let buckets = CycleBreakdown::NAMES
            .iter()
            .zip(p.breakdown.components())
            .map(|(n, v)| format!("\"{n}\": {v}"))
            .collect::<Vec<_>>()
            .join(", ");
        s.push_str(&format!(
            "    {{ \"lanes\": {}, \"tile_r\": {}, \"tile_c\": {}, \
             \"gops\": {}, \"area_mm2\": {}, \"area_eff\": {}, \
             \"cycles_static\": {}, \"breakdown\": {{ {} }}, \
             \"cycles_tuned\": {}, \"tuned_gops\": {}, \
             \"tuned_area_eff\": {}, \"tuned_choice\": {}, \"candidates\": {} }}{}\n",
            p.cfg.lanes,
            p.cfg.tile_r,
            p.cfg.tile_c,
            jf(p.gops),
            jf(p.area_mm2),
            jf(p.area_eff()),
            p.static_cycles,
            buckets,
            tc,
            tg,
            te,
            choice,
            cands,
            if i + 1 < points.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_grows_with_lanes() {
        let op = dse_workload();
        let small = eval_point(&SpeedConfig::dse(2, 2, 2), &op).unwrap();
        let big = eval_point(&SpeedConfig::dse(8, 4, 4), &op).unwrap();
        assert!(big.gops > small.gops, "{} !> {}", big.gops, small.gops);
        assert!(big.area_mm2 > small.area_mm2);
    }

    #[test]
    fn quick_sweep_preserves_lane_scaling() {
        let pts = sweep_with(2, true);
        assert_eq!(pts.len(), 27);
        let small = pts.iter().find(|p| (p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c) == (2, 2, 2))
            .unwrap();
        let big = pts.iter().find(|p| (p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c) == (8, 4, 4))
            .unwrap();
        assert!(big.gops > small.gops, "{} !> {}", big.gops, small.gops);
    }

    #[test]
    fn gops_within_theoretical_peak() {
        let op = dse_workload();
        for lanes in [2u32, 4] {
            let cfg = SpeedConfig::dse(lanes, 2, 2);
            let p = eval_point(&cfg, &op).unwrap();
            assert!(p.gops <= cfg.peak_gops(Precision::Int16) + 1e-9,
                    "{} > peak {}", p.gops, cfg.peak_gops(Precision::Int16));
            assert!(p.gops > 0.2 * cfg.peak_gops(Precision::Int16));
        }
    }

    #[test]
    fn tuned_sweep_never_worse_than_static_at_any_point() {
        // The `repro dse --tuned --quick` acceptance bar, in-process: every
        // point records both outcomes with tuned cycles ≤ static cycles,
        // tuned GOPS ≥ static GOPS, and best_area_eff ≥ area_eff.
        let points = sweep_opts(2, true, true);
        assert_eq!(points.len(), 27);
        for p in &points {
            let t = p.tuned.expect("tuned sweep fills every point");
            assert!(
                t.cycles <= p.static_cycles,
                "{:?}: tuned {} > static {}",
                (p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c),
                t.cycles,
                p.static_cycles
            );
            assert!(t.gops + 1e-9 >= p.gops);
            assert!(t.candidates >= 1);
            assert!(p.best_area_eff() + 1e-9 >= p.area_eff());
        }
        // The JSON artifact parses and carries both cycle columns.
        use crate::runtime::json::{parse, Json};
        let doc = parse(&sweep_json(&points, true)).unwrap();
        assert_eq!(doc.get("tuned").and_then(Json::as_bool), Some(true));
        let pts = doc.get("points").and_then(Json::as_arr).unwrap();
        assert_eq!(pts.len(), 27);
        for pj in pts {
            let st = pj.get("cycles_static").and_then(Json::as_i64).unwrap();
            let tu = pj.get("cycles_tuned").and_then(Json::as_i64).unwrap();
            assert!(tu <= st, "{tu} > {st}");
        }
    }

    #[test]
    fn static_sweep_leaves_tuned_empty_and_json_nulls() {
        let op = dse_workload_quick();
        let p = eval_point(&SpeedConfig::dse(2, 2, 2), &op).unwrap();
        assert!(p.tuned.is_none());
        assert!(p.static_cycles > 0);
        // Schema 2: the per-point attribution telescopes to the static
        // cycle count exactly.
        assert_eq!(p.breakdown.total(), p.static_cycles);
        assert!(p.breakdown.chain > 0);
        assert_eq!(p.best_area_eff(), p.area_eff());
        use crate::runtime::json::{parse, Json};
        let doc = parse(&sweep_json(&[p], true)).unwrap();
        assert_eq!(doc.get("tuned").and_then(Json::as_bool), Some(false));
        let pj = &doc.get("points").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(pj.get("cycles_tuned"), Some(&Json::Null));
    }
}
