//! MPTU functional engine: the golden arithmetic of the tensor core.
//!
//! The PE array's arithmetic is exact 32-bit accumulation of sign-extended
//! 4/8/16-bit products (wrapping on overflow, like the RTL's 32-bit adders
//! and like XLA's int32 semantics — this is what makes the simulator output
//! bit-exact against the AOT-lowered JAX/Pallas artifacts).
//!
//! Numerics are computed at operator granularity from the DRAM images (the
//! schedule determines *when* bytes move — counted at the instruction level
//! — while this module determines *what* the machine computes).
//!
//! All operator outputs land in a single flat row-major [`OutputRows`]
//! buffer. Because the accumulation is integer arithmetic mod 2³², the
//! summation order is free, so the kernels below use blocked,
//! allocation-free inner loops over contiguous row slices — the result is
//! bit-identical to the naive triple loop while streaming through the
//! caches instead of chasing per-row heap allocations.

use crate::config::Precision;
use crate::models::ops::{OpDesc, OpKind};

use super::elem;
use super::memory::ExtMem;
use super::plan::OpPlan;

/// MPTU pipeline timing constants (Fig. 9): the request → compute →
/// write-back stages overlap across dataflow stages, so a `VSAM` of S
/// stages costs `PIPE_FILL + S` cycles in EX.
pub const PIPE_FILL: u64 = 3;

/// The operator's full output as one flat row-major `i32` buffer with row
/// views — the result-queue image the store path drains row by row.
///
/// Replaces the former `Vec<Vec<i32>>`: one allocation per operator
/// instead of one per output row, and rows stay contiguous so draining a
/// block of rows is a single memcpy-shaped walk.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OutputRows {
    data: Vec<i32>,
    row_elems: usize,
}

impl OutputRows {
    /// A zeroed buffer of `num_rows` rows of `row_elems` elements.
    pub fn new(num_rows: usize, row_elems: usize) -> Self {
        OutputRows { data: vec![0i32; num_rows * row_elems], row_elems }
    }

    /// Wrap an existing flat row-major buffer.
    ///
    /// Panics when the buffer length is not a whole number of rows — an
    /// always-on check (promoted from a `debug_assert!`): a ragged buffer
    /// would shift every subsequent row's contents in release builds,
    /// corrupting functional output instead of failing here.
    pub fn from_flat(data: Vec<i32>, row_elems: usize) -> Self {
        assert!(
            row_elems == 0 || data.len() % row_elems == 0,
            "flat buffer of {} elements is not a whole number of {row_elems}-element rows",
            data.len()
        );
        OutputRows { data, row_elems }
    }

    /// Elements per row.
    pub fn row_elems(&self) -> usize {
        self.row_elems
    }

    /// Number of complete rows held.
    pub fn num_rows(&self) -> usize {
        if self.row_elems == 0 {
            0
        } else {
            self.data.len() / self.row_elems
        }
    }

    /// Row `i` as a contiguous slice.
    pub fn row(&self, i: usize) -> &[i32] {
        &self.data[i * self.row_elems..(i + 1) * self.row_elems]
    }

    /// Row `i` if it exists.
    pub fn get_row(&self, i: usize) -> Option<&[i32]> {
        if i < self.num_rows() {
            Some(self.row(i))
        } else {
            None
        }
    }

    /// Iterate rows in order.
    pub fn rows(&self) -> impl Iterator<Item = &[i32]> {
        self.data.chunks(self.row_elems.max(1))
    }

    /// The whole output, row-major.
    pub fn as_flat(&self) -> &[i32] {
        &self.data
    }

    /// Consume into the flat row-major vector.
    pub fn into_flat(self) -> Vec<i32> {
        self.data
    }

    /// Whether no rows are held.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Drop all rows (plan reinstall), keeping the allocation.
    pub fn clear(&mut self) {
        self.data.clear();
        self.row_elems = 0;
    }
}

/// Compute the operator's full output (row-major rows of i32 accumulators)
/// from the DRAM images referenced by the plan. Reads are *uncounted*
/// (traffic is attributed to the VSALD/VLE instructions of the schedule).
pub fn compute_output_rows(mem: &ExtMem, plan: &OpPlan) -> OutputRows {
    let d = &plan.desc;
    match d.kind {
        OpKind::Mm => mm_rows(mem, d, plan),
        OpKind::Conv => conv_rows(mem, d, plan, false),
        OpKind::Pwcv => conv_rows(mem, d, plan, false),
        OpKind::Dwcv => conv_rows(mem, d, plan, true),
    }
}

fn load_packed(mem: &ExtMem, addr: u64, n: u64, p: Precision) -> Vec<i32> {
    let bytes = mem.inspect(addr, p.bytes_for(n) as usize);
    let mut out = Vec::new();
    elem::unpack_into(bytes, n as usize, p, &mut out);
    out
}

// Cache blocking for the MM kernel: a KB×JB tile of B (≤ 128 KiB at i32)
// stays hot across the whole M loop.
const MM_JB: usize = 256;
const MM_KB: usize = 128;

fn mm_rows(mem: &ExtMem, d: &OpDesc, plan: &OpPlan) -> OutputRows {
    let (m, k, n) = (d.m as usize, d.k as usize, d.n as usize);
    let a = load_packed(mem, plan.in_addr, (m * k) as u64, d.prec);
    let b = load_packed(mem, plan.w_addr, (k * n) as u64, d.prec);
    let mut data = vec![0i32; m * n];
    let mut jb = 0;
    while jb < n {
        let je = (jb + MM_JB).min(n);
        let mut kb = 0;
        while kb < k {
            let ke = (kb + MM_KB).min(k);
            for i in 0..m {
                let arow = &a[i * k + kb..i * k + ke];
                let orow = &mut data[i * n + jb..i * n + je];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0 {
                        continue;
                    }
                    let boff = (kb + kk) * n;
                    let brow = &b[boff + jb..boff + je];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o = o.wrapping_add(av.wrapping_mul(bv));
                    }
                }
            }
            kb = ke;
        }
        jb = je;
    }
    OutputRows::from_flat(data, n)
}

/// CONV / PWCV / DWCV share one walker; `depthwise` selects per-channel
/// weights. Input layout: C×H×W; weights: F×C×K×K (or C×K×K); output rows:
/// (f, oy) → OW elements. The kernel hoists the weight scalar out of the
/// spatial loop and accumulates along contiguous row slices, clipping the
/// padded window bounds once per (ky, kx) instead of per output pixel.
fn conv_rows(mem: &ExtMem, d: &OpDesc, plan: &OpPlan, depthwise: bool) -> OutputRows {
    let (c, h, w) = (d.c as usize, d.h as usize, d.w as usize);
    let f = d.f as usize;
    let k = d.ksize as usize;
    let (oh, ow) = (d.oh() as usize, d.ow() as usize);
    let stride = d.stride as usize;
    let pad = d.pad as i64;

    let x = load_packed(mem, plan.in_addr, (c * h * w) as u64, d.prec);
    let welems = if depthwise { c * k * k } else { f * c * k * k };
    let wt = load_packed(mem, plan.w_addr, welems as u64, d.prec);

    let mut data = vec![0i32; f * oh * ow];
    for fo in 0..f {
        let (c0, c1) = if depthwise { (fo, fo + 1) } else { (0, c) };
        for oy in 0..oh {
            let rbase = (fo * oh + oy) * ow;
            let row = &mut data[rbase..rbase + ow];
            for ci in c0..c1 {
                for ky in 0..k {
                    let iy = (oy * stride) as i64 + ky as i64 - pad;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    let xbase = (ci * h + iy as usize) * w;
                    let xrow = &x[xbase..xbase + w];
                    let wbase = if depthwise {
                        (fo * k + ky) * k
                    } else {
                        ((fo * c + ci) * k + ky) * k
                    };
                    for kx in 0..k {
                        let wv = wt[wbase + kx];
                        if wv == 0 {
                            continue;
                        }
                        // Valid output range: 0 <= ox*stride + kx - pad < w.
                        let off = kx as i64 - pad;
                        let lo = if off >= 0 {
                            0usize
                        } else {
                            ((-off) as usize).div_ceil(stride)
                        };
                        let hi_num = w as i64 - 1 - off;
                        if hi_num < 0 {
                            continue;
                        }
                        let hi = (hi_num as usize / stride).min(ow - 1);
                        if lo > hi {
                            continue;
                        }
                        if stride == 1 {
                            let x0 = (lo as i64 + off) as usize;
                            let xs = &xrow[x0..x0 + (hi - lo + 1)];
                            for (o, &xv) in row[lo..=hi].iter_mut().zip(xs) {
                                *o = o.wrapping_add(xv.wrapping_mul(wv));
                            }
                        } else {
                            for (o, ox) in row[lo..=hi].iter_mut().zip(lo..) {
                                let ix = (ox * stride) as i64 + off;
                                *o = o.wrapping_add(xrow[ix as usize].wrapping_mul(wv));
                            }
                        }
                    }
                }
            }
        }
    }
    OutputRows::from_flat(data, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    fn plan_for(desc: OpDesc) -> (ExtMem, OpPlan) {
        let mem = ExtMem::new(1 << 20);
        let plan = OpPlan {
            desc,
            strat: desc.preferred_strategy(),
            in_addr: 0,
            w_addr: 0x4000,
            out_addr: 0x8000,
            partial_addr: u64::MAX,
            total_stages: 1,
            functional: true,
        };
        (mem, plan)
    }

    /// Nested-vec view for test assertions.
    fn nested(rows: &OutputRows) -> Vec<Vec<i32>> {
        rows.rows().map(|r| r.to_vec()).collect()
    }

    #[test]
    fn mm_identity() {
        let d = OpDesc::mm(2, 2, 2, Precision::Int8);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4], d.prec);
        mem.preload_packed(plan.w_addr, &[1, 0, 0, 1], d.prec); // identity
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(rows.num_rows(), 2);
        assert_eq!(rows.row_elems(), 2);
        assert_eq!(nested(&rows), vec![vec![1, 2], vec![3, 4]]);
        assert_eq!(rows.as_flat(), &[1, 2, 3, 4]);
    }

    #[test]
    fn mm_known_product() {
        let d = OpDesc::mm(2, 2, 2, Precision::Int16);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4], d.prec);
        mem.preload_packed(plan.w_addr, &[1, 1, 1, 1], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(nested(&rows), vec![vec![3, 3], vec![7, 7]]);
    }

    #[test]
    fn mm_blocked_loop_matches_naive_reference() {
        // Shapes straddling the JB/KB block boundaries must agree with the
        // naive triple loop (mod-2^32 accumulation is order-free).
        for (m, k, n) in [(3, MM_KB as u32 + 5, MM_JB as u32 + 3), (7, 130, 257), (1, 300, 1)] {
            let d = OpDesc::mm(m, k, n, Precision::Int8);
            let mut mem = ExtMem::new(1 << 20);
            let a: Vec<i32> = (0..m * k).map(|i| (i % 251) as i32 - 125).collect();
            let b: Vec<i32> = (0..k * n).map(|i| (i % 127) as i32 - 63).collect();
            let plan = OpPlan {
                desc: d,
                strat: d.preferred_strategy(),
                in_addr: 0,
                w_addr: 0x40000,
                out_addr: 0x80000,
                partial_addr: u64::MAX,
                total_stages: 1,
                functional: true,
            };
            mem.preload_packed(plan.in_addr, &a, d.prec);
            mem.preload_packed(plan.w_addr, &b, d.prec);
            let rows = compute_output_rows(&mem, &plan);
            let (m, k, n) = (m as usize, k as usize, n as usize);
            let mut want = vec![0i32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    for j in 0..n {
                        want[i * n + j] = want[i * n + j]
                            .wrapping_add(a[i * k + kk].wrapping_mul(b[kk * n + j]));
                    }
                }
            }
            assert_eq!(rows.as_flat(), &want[..], "{m}x{k}x{n}");
        }
    }

    #[test]
    fn conv_1x1_matches_pwcv() {
        // 1x1 conv == pwcv: out[f][p] = sum_c x[c][p] * w[f][c]
        let dp = OpDesc::pwcv(2, 2, 2, 2, Precision::Int8);
        let (mut mem, plan) = plan_for(dp);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4, 5, 6, 7, 8], dp.prec);
        mem.preload_packed(plan.w_addr, &[1, 2, 3, 4], dp.prec);
        let rows = compute_output_rows(&mem, &plan);
        // f0: x_c0*1 + x_c1*2, rows (oy) of OW elements
        assert_eq!(rows.row(0), vec![1 + 10, 2 + 12]);
        assert_eq!(rows.row(1), vec![3 + 14, 4 + 16]);
        // f1: x_c0*3 + x_c1*4
        assert_eq!(rows.row(2), vec![3 + 20, 6 + 24]);
        assert_eq!(rows.row(3), vec![9 + 28, 12 + 32]);
    }

    #[test]
    fn conv_3x3_padded_center() {
        // Single channel, single filter of all-ones: output at center of a
        // padded 3x3 input = sum of all inputs.
        let d = OpDesc::conv(1, 1, 3, 3, 3, 1, 1, Precision::Int8);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4, 5, 6, 7, 8, 9], d.prec);
        mem.preload_packed(plan.w_addr, &[1; 9], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(rows.num_rows(), 3);
        assert_eq!(rows.row(1)[1], 45);
        // corner: only 2x2 window valid
        assert_eq!(rows.row(0)[0], 1 + 2 + 4 + 5);
    }

    #[test]
    fn strided_conv_matches_naive_reference() {
        // Stride-2 with padding exercises the hoisted window-bound clipping.
        let d = OpDesc::conv(3, 4, 9, 11, 3, 2, 1, Precision::Int8);
        let (mut mem, plan) = plan_for(d);
        let x: Vec<i32> = (0..d.input_elems()).map(|i| (i % 17) as i32 - 8).collect();
        let w: Vec<i32> = (0..d.weight_elems()).map(|i| (i % 13) as i32 - 6).collect();
        mem.preload_packed(plan.in_addr, &x, d.prec);
        mem.preload_packed(plan.w_addr, &w, d.prec);
        let rows = compute_output_rows(&mem, &plan);
        let (c, h, wd, f, k) = (3usize, 9usize, 11usize, 4usize, 3usize);
        let (oh, ow) = (d.oh() as usize, d.ow() as usize);
        for fo in 0..f {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut sum = 0i32;
                    for ci in 0..c {
                        for ky in 0..k {
                            let iy = oy as isize * 2 + ky as isize - 1;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = ox as isize * 2 + kx as isize - 1;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                let xv = x[ci * h * wd + iy as usize * wd + ix as usize];
                                let wv = w[fo * c * k * k + ci * k * k + ky * k + kx];
                                sum = sum.wrapping_add(xv.wrapping_mul(wv));
                            }
                        }
                    }
                    assert_eq!(rows.row(fo * oh + oy)[ox], sum, "f{fo} oy{oy} ox{ox}");
                }
            }
        }
    }

    #[test]
    fn dwcv_channels_independent() {
        let d = OpDesc::dwcv(2, 3, 3, 3, 1, 0, Precision::Int8);
        let (mut mem, plan) = plan_for(d);
        let mut x = vec![0i32; 18];
        x[..9].copy_from_slice(&[1; 9]);
        x[9..].copy_from_slice(&[2; 9]);
        mem.preload_packed(plan.in_addr, &x, d.prec);
        mem.preload_packed(plan.w_addr, &[1; 18], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(nested(&rows), vec![vec![9], vec![18]]);
    }

    #[test]
    fn wrapping_accumulation_matches_hw() {
        // Products that overflow i32 must wrap (like the RTL adder & XLA).
        let d = OpDesc::mm(1, 2, 1, Precision::Int16);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[32767, 32767], d.prec);
        mem.preload_packed(plan.w_addr, &[32767, 32767], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        let expect = (32767i32.wrapping_mul(32767)).wrapping_mul(2);
        assert_eq!(rows.row(0)[0], expect);
    }

    #[test]
    fn output_rows_views() {
        let mut r = OutputRows::from_flat(vec![1, 2, 3, 4, 5, 6], 3);
        assert_eq!(r.num_rows(), 2);
        assert_eq!(r.row(1), &[4, 5, 6]);
        assert_eq!(r.get_row(2), None);
        assert_eq!(r.rows().count(), 2);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.num_rows(), 0);
    }
}
