//! MPTU functional engine: the golden arithmetic of the tensor core.
//!
//! The PE array's arithmetic is exact 32-bit accumulation of sign-extended
//! 4/8/16-bit products (wrapping on overflow, like the RTL's 32-bit adders
//! and like XLA's int32 semantics — this is what makes the simulator output
//! bit-exact against the AOT-lowered JAX/Pallas artifacts).
//!
//! Numerics are computed at operator granularity from the DRAM images (the
//! schedule determines *when* bytes move — counted at the instruction level
//! — while this module determines *what* the machine computes).

use crate::config::Precision;
use crate::models::ops::{OpDesc, OpKind};

use super::elem;
use super::memory::ExtMem;
use super::plan::OpPlan;

/// MPTU pipeline timing constants (Fig. 9): the request → compute →
/// write-back stages overlap across dataflow stages, so a `VSAM` of S
/// stages costs `PIPE_FILL + S` cycles in EX.
pub const PIPE_FILL: u64 = 3;

/// Compute the operator's full output (row-major rows of i32 accumulators)
/// from the DRAM images referenced by the plan. Reads are *uncounted*
/// (traffic is attributed to the VSALD/VLE instructions of the schedule).
pub fn compute_output_rows(mem: &ExtMem, plan: &OpPlan) -> Vec<Vec<i32>> {
    let d = &plan.desc;
    match d.kind {
        OpKind::Mm => mm_rows(mem, d, plan),
        OpKind::Conv => conv_rows(mem, d, plan, false),
        OpKind::Pwcv => conv_rows(mem, d, plan, false),
        OpKind::Dwcv => conv_rows(mem, d, plan, true),
    }
}

fn load_packed(mem: &ExtMem, addr: u64, n: u64, p: Precision) -> Vec<i32> {
    let bytes = mem.inspect(addr, p.bytes_for(n) as usize);
    elem::unpack(bytes, n as usize, p)
}

fn mm_rows(mem: &ExtMem, d: &OpDesc, plan: &OpPlan) -> Vec<Vec<i32>> {
    let (m, k, n) = (d.m as usize, d.k as usize, d.n as usize);
    let a = load_packed(mem, plan.in_addr, (m * k) as u64, d.prec);
    let b = load_packed(mem, plan.w_addr, (k * n) as u64, d.prec);
    let mut rows = Vec::with_capacity(m);
    for i in 0..m {
        let mut row = vec![0i32; n];
        for kk in 0..k {
            let av = a[i * k + kk];
            let boff = kk * n;
            for (j, r) in row.iter_mut().enumerate() {
                *r = r.wrapping_add(av.wrapping_mul(b[boff + j]));
            }
        }
        rows.push(row);
    }
    rows
}

/// CONV / PWCV / DWCV share one walker; `depthwise` selects per-channel
/// weights. Input layout: C×H×W; weights: F×C×K×K (or C×K×K); output rows:
/// (f, oy) → OW elements.
fn conv_rows(mem: &ExtMem, d: &OpDesc, plan: &OpPlan, depthwise: bool) -> Vec<Vec<i32>> {
    let (c, h, w) = (d.c as usize, d.h as usize, d.w as usize);
    let f = d.f as usize;
    let k = d.ksize as usize;
    let (oh, ow) = (d.oh() as usize, d.ow() as usize);
    let (stride, pad) = (d.stride as isize, d.pad as isize);

    let x = load_packed(mem, plan.in_addr, (c * h * w) as u64, d.prec);
    let welems = if depthwise { c * k * k } else { f * c * k * k };
    let wt = load_packed(mem, plan.w_addr, welems as u64, d.prec);

    let mut rows = Vec::with_capacity(f * oh);
    for fo in 0..f {
        for oy in 0..oh {
            let mut row = vec![0i32; ow];
            for (ox, acc) in row.iter_mut().enumerate() {
                let mut sum = 0i32;
                let cs: Box<dyn Iterator<Item = usize>> =
                    if depthwise { Box::new(std::iter::once(fo)) } else { Box::new(0..c) };
                for ci in cs {
                    for ky in 0..k {
                        let iy = oy as isize * stride + ky as isize - pad;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = ox as isize * stride + kx as isize - pad;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let xv = x[ci * h * w + iy as usize * w + ix as usize];
                            let wv = if depthwise {
                                wt[fo * k * k + ky * k + kx]
                            } else {
                                wt[fo * c * k * k + ci * k * k + ky * k + kx]
                            };
                            sum = sum.wrapping_add(xv.wrapping_mul(wv));
                        }
                    }
                }
                *acc = sum;
            }
            rows.push(row);
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    fn plan_for(desc: OpDesc) -> (ExtMem, OpPlan) {
        let mem = ExtMem::new(1 << 20);
        let plan = OpPlan {
            desc,
            strat: desc.preferred_strategy(),
            in_addr: 0,
            w_addr: 0x4000,
            out_addr: 0x8000,
            partial_addr: u64::MAX,
            total_stages: 1,
            functional: true,
        };
        (mem, plan)
    }

    #[test]
    fn mm_identity() {
        let d = OpDesc::mm(2, 2, 2, Precision::Int8);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4], d.prec);
        mem.preload_packed(plan.w_addr, &[1, 0, 0, 1], d.prec); // identity
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(rows, vec![vec![1, 2], vec![3, 4]]);
    }

    #[test]
    fn mm_known_product() {
        let d = OpDesc::mm(2, 2, 2, Precision::Int16);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4], d.prec);
        mem.preload_packed(plan.w_addr, &[1, 1, 1, 1], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(rows, vec![vec![3, 3], vec![7, 7]]);
    }

    #[test]
    fn conv_1x1_matches_pwcv() {
        // 1x1 conv == pwcv: out[f][p] = sum_c x[c][p] * w[f][c]
        let dp = OpDesc::pwcv(2, 2, 2, 2, Precision::Int8);
        let (mut mem, plan) = plan_for(dp);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4, 5, 6, 7, 8], dp.prec);
        mem.preload_packed(plan.w_addr, &[1, 2, 3, 4], dp.prec);
        let rows = compute_output_rows(&mem, &plan);
        // f0: x_c0*1 + x_c1*2, rows (oy) of OW elements
        assert_eq!(rows[0], vec![1 + 10, 2 + 12]);
        assert_eq!(rows[1], vec![3 + 14, 4 + 16]);
        // f1: x_c0*3 + x_c1*4
        assert_eq!(rows[2], vec![3 + 20, 6 + 24]);
        assert_eq!(rows[3], vec![9 + 28, 12 + 32]);
    }

    #[test]
    fn conv_3x3_padded_center() {
        // Single channel, single filter of all-ones: output at center of a
        // padded 3x3 input = sum of all inputs.
        let d = OpDesc::conv(1, 1, 3, 3, 3, 1, 1, Precision::Int8);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[1, 2, 3, 4, 5, 6, 7, 8, 9], d.prec);
        mem.preload_packed(plan.w_addr, &[1; 9], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1][1], 45);
        // corner: only 2x2 window valid
        assert_eq!(rows[0][0], 1 + 2 + 4 + 5);
    }

    #[test]
    fn dwcv_channels_independent() {
        let d = OpDesc::dwcv(2, 3, 3, 3, 1, 0, Precision::Int8);
        let (mut mem, plan) = plan_for(d);
        let mut x = vec![0i32; 18];
        x[..9].copy_from_slice(&[1; 9]);
        x[9..].copy_from_slice(&[2; 9]);
        mem.preload_packed(plan.in_addr, &x, d.prec);
        mem.preload_packed(plan.w_addr, &[1; 18], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        assert_eq!(rows, vec![vec![9], vec![18]]);
    }

    #[test]
    fn wrapping_accumulation_matches_hw() {
        // Products that overflow i32 must wrap (like the RTL adder & XLA).
        let d = OpDesc::mm(1, 2, 1, Precision::Int16);
        let (mut mem, plan) = plan_for(d);
        mem.preload_packed(plan.in_addr, &[32767, 32767], d.prec);
        mem.preload_packed(plan.w_addr, &[32767, 32767], d.prec);
        let rows = compute_output_rows(&mem, &plan);
        let expect = (32767i32.wrapping_mul(32767)).wrapping_mul(2);
        assert_eq!(rows[0][0], expect);
    }
}
