//! The SPEED processor model: a 4-stage (ID/IS/EX/CO) vector pipeline with
//! an event-driven scoreboard.
//!
//! Timing model
//! ------------
//! * **ID** — the VIDU decodes one instruction per cycle (in order).
//! * **IS** — the VIS issues an instruction to its functional unit when the
//!   unit is free and no vector-register hazard (RAW/WAW/WAR) is
//!   outstanding; the VIS hazard table is exactly `Insn::vregs_read/written`.
//! * **EX** — duration depends on the unit:
//!   - VLDU (`VLE`/`VSALD`): memory latency + bytes / port bandwidth; the
//!     external port is shared with the store unit and serializes.
//!   - MPTU (`VSAM`/`VSAC`): `PIPE_FILL + stages` — one dataflow stage per
//!     cycle in steady state, with request/compute/write-back overlapped
//!     (Fig. 9).
//!   - VALU: `vl` elements at `lanes × 64/SEW` per cycle + a 2-cycle ALU
//!     pipeline.
//!   - scalar/config: 1 cycle (`VSACFG` switches precision in a single
//!     cycle — Sec. II-E).
//! * **CO** — 1 cycle, overlapped; total cycles = last completion + 1.
//!
//! Execution modes
//! ---------------
//! The scoreboard recurrence above is deterministic, so long homogeneous
//! instruction runs (the compiler's `VSALD` streams, `VSAM` burst chains,
//! and row-store sequences) do not need per-instruction dispatch. In
//! [`ExecMode::Batch`] (the default), [`Processor::run_segment`] consumes
//! the [`StreamRun`] metadata the operator compiler attaches to each
//! [`Segment`] and advances whole blocks at once — `VSAM` chains in closed
//! form, load/store runs through a specialized loop that shares the exact
//! path's [`Processor::schedule`] core. Statistics, traffic, and memory
//! contents are bit-identical to [`ExecMode::Exact`] (per-instruction
//! `step`), which remains available as an escape hatch via
//! `repro ... --exact` or `SPEED_EXACT=1`.
//!
//! Functional model
//! ----------------
//! Instructions move real bytes: loads copy DRAM → per-lane VRF regions
//! (capacity-checked), stores pop completed output rows from the MPTU
//! result path and write them to DRAM. Operator numerics are computed by
//! [`super::mptu`] at operator granularity (bit-exact vs the JAX/Pallas
//! artifacts) into one flat [`OutputRows`] buffer; *when* bytes move — and
//! therefore every cycle and traffic statistic — is decided by the
//! instruction stream the operator compiler emits.

use crate::config::SpeedConfig;
use crate::isa::{Insn, LdMode, RunKind, Segment, StreamRun, WidthSel};
use crate::obs::{CycleBreakdown, SpanCat, TraceLevel, Tracer};

use super::ctrl::CtrlState;
use super::memory::{ExtMem, TrafficClass};
use super::mptu::{self, OutputRows};
use super::plan::OpPlan;
use super::stats::{Fu, SimStats};

/// Simulation error (structural violation — the compiler emitted a stream
/// the hardware could not execute).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// A load does not fit the target vector register region.
    VrfOverflow { vd: u8, need: usize, have: usize },
    /// A store targeted an address that is not a valid output/partial row.
    StoreUnderflow,
    /// Memory access out of range.
    MemOutOfRange { addr: u64, len: usize, size: usize },
    /// VSAM/VSAC executed without an installed operator plan.
    NoPlan,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::VrfOverflow { vd, need, have } => {
                write!(f, "VRF overflow: v{vd} needs {need} B, region holds {have} B")
            }
            SimError::StoreUnderflow => {
                write!(f, "VSE address does not map to an output row of the plan")
            }
            SimError::MemOutOfRange { addr, len, size } => {
                write!(f, "memory access [{addr:#x}..+{len}) outside {size} B")
            }
            SimError::NoPlan => write!(f, "VSAM/VSAC executed with no operator plan installed"),
        }
    }
}

impl std::error::Error for SimError {}

/// How [`Processor::run_segment`] consumes a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Per-instruction `step` dispatch — the reference semantics.
    Exact,
    /// Recognize the compiler's homogeneous `VSALD`/`VSAM`/`VLE`/`VSE`
    /// stream runs and advance them per block (bit-exact vs `Exact`).
    #[default]
    Batch,
}

/// The SPEED machine.
pub struct Processor {
    /// The hardware configuration.
    pub cfg: SpeedConfig,
    /// Architectural control state (latched VSACFG/VSETVLI).
    pub ctrl: CtrlState,
    /// External memory with traffic accounting.
    pub mem: ExtMem,
    xregs: [i64; 32],
    /// Per-lane VRF byte arrays.
    vrf: Vec<Vec<u8>>,
    /// Installed operator plan (VSACFG-derived state).
    plan: Option<OpPlan>,
    /// Computed output rows (flat row-major; the result-queue path —
    /// `VSE` maps its address back to the row it drains).
    computed_rows: OutputRows,
    /// Stage cursor into the plan's schedule.
    stage_cursor: u64,
    /// Whether the functional engine has produced the operator's output.
    computed: bool,
    /// Batch vs exact consumption of segment run metadata.
    mode: ExecMode,
    /// Attached observability tracer (None = fully inert). Attaching a
    /// tracer never changes [`SimStats`]: instruction-level tracing in
    /// batch mode expands runs into the per-instruction path, which is
    /// bit-exact by the fast-path parity property.
    tracer: Option<Tracer>,
    /// Virtual-clock value at the current `run_insns` entry (span
    /// timestamp base while a tracer is attached).
    span_base: u64,
    /// Completion frontier at the current `run_insns` entry (maps
    /// scoreboard times onto the virtual clock).
    span_frontier: u64,

    // ---- scoreboard state (all times in cycles) ----
    t_decode: u64,
    fu_free: [u64; 5],
    mem_port_free: u64,
    vreg_write_done: [u64; 32],
    vreg_read_done: [u64; 32],
    /// Completion time of the last MPTU burst (chained VSAMs keep the
    /// request/compute/write-back pipeline primed — Fig. 9).
    last_mptu_complete: u64,
    last_complete: u64,

    stats: SimStats,
    /// Lifetime cycle attribution (accumulates exactly in step with
    /// `stats.cycles`; see [`CycleBreakdown`]).
    breakdown: CycleBreakdown,
    vregs_touched: [bool; 32],
    /// Reusable transfer buffer (keeps the hot loop allocation-free).
    scratch: Vec<u8>,
}

impl Processor {
    /// Create a machine with `mem_bytes` of external memory.
    pub fn new(cfg: SpeedConfig, mem_bytes: usize) -> Self {
        let lanes = cfg.lanes as usize;
        let vrf_bytes = cfg.vrf_bytes() as usize;
        Processor {
            cfg,
            ctrl: CtrlState::default(),
            mem: ExtMem::new(mem_bytes),
            xregs: [0; 32],
            vrf: vec![vec![0u8; vrf_bytes]; lanes],
            plan: None,
            computed_rows: OutputRows::default(),
            stage_cursor: 0,
            computed: false,
            mode: if std::env::var_os("SPEED_EXACT").is_some() {
                ExecMode::Exact
            } else {
                ExecMode::Batch
            },
            tracer: None,
            span_base: 0,
            span_frontier: 0,
            t_decode: 0,
            fu_free: [0; 5],
            mem_port_free: 0,
            vreg_write_done: [0; 32],
            vreg_read_done: [0; 32],
            last_mptu_complete: u64::MAX,
            last_complete: 0,
            stats: SimStats::default(),
            breakdown: CycleBreakdown::default(),
            vregs_touched: [false; 32],
            scratch: Vec::new(),
        }
    }

    /// Bytes one vector register occupies per lane (VRF / 32 registers).
    pub fn vreg_region_bytes(&self) -> usize {
        self.cfg.vrf_bytes() as usize / 32
    }

    /// Install the operator plan the subsequent VSAM/VSAC stream executes.
    /// (Models the state the hardware accumulates from VSACFG/VSACFG.DIM.)
    pub fn set_plan(&mut self, plan: OpPlan) {
        self.plan = Some(plan);
        self.stage_cursor = 0;
        self.computed = false;
        self.computed_rows.clear();
    }

    /// The installed operator plan, if any.
    pub fn plan(&self) -> Option<&OpPlan> {
        self.plan.as_ref()
    }

    /// Select batch vs exact consumption of segment run metadata.
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    /// The active simulation mode.
    pub fn exec_mode(&self) -> ExecMode {
        self.mode
    }

    /// Attach (or detach, with `None`) an observability tracer. The tracer
    /// is timing-inert: statistics are bit-identical either way.
    pub fn attach_tracer(&mut self, tracer: Option<Tracer>) {
        self.tracer = tracer;
    }

    /// The attached tracer, if any (the engine emits op/segment spans on
    /// the same virtual clock the processor advances).
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Lifetime cycle attribution across all runs; its bucket sum equals
    /// [`Processor::lifetime_stats`]`.cycles` exactly.
    pub fn breakdown(&self) -> CycleBreakdown {
        self.breakdown
    }

    /// Per-instruction stepping required? True in exact mode and whenever
    /// an instruction-level tracer (or stderr echo) is attached — the
    /// lazy-expansion replacement for the old `SPEED_TRACE`-forces-exact
    /// construction-time hack.
    fn insn_tracing(&self) -> bool {
        self.tracer.as_ref().is_some_and(|t| t.level() >= TraceLevel::Insn || t.echo())
    }

    /// Grow external memory to at least `bytes`, preserving contents and
    /// warm pipeline/control state (the engine's execute-many path sizes
    /// memory up lazily as larger operators arrive).
    pub fn grow_memory(&mut self, bytes: usize) {
        self.mem.grow(bytes);
    }

    /// Drain the pipeline: return the issue/execute scoreboard (decode
    /// clock, FU and memory-port free times, vector-register hazard
    /// tables, MPTU chain state) to exactly its fresh-construction values.
    ///
    /// Control state (`VSACFG` precision, `vl`/`sew`), external-memory
    /// contents, and lifetime counters all persist — only the *timing*
    /// state is quiesced. A program executed after `reset_pipeline`
    /// therefore reports the same per-run [`SimStats`] as on a
    /// newly-constructed machine (modulo the control-state-dependent
    /// precision-switch counter), no matter what ran before. The serving
    /// layer resets at request boundaries so per-request statistics are
    /// independent of how requests were scheduled across a pool.
    pub fn reset_pipeline(&mut self) {
        self.t_decode = 0;
        self.fu_free = [0; 5];
        self.mem_port_free = 0;
        self.vreg_write_done = [0; 32];
        self.vreg_read_done = [0; 32];
        self.last_mptu_complete = u64::MAX;
        self.last_complete = 0;
        self.vregs_touched = [false; 32];
    }

    fn xreg(&self, r: u8) -> i64 {
        if r == 0 {
            0
        } else {
            self.xregs[r as usize]
        }
    }

    /// Run a program to completion; returns the stats of this run.
    /// The machine state (memory, VRF, control) persists across runs so a
    /// network can be executed as a sequence of operator programs.
    ///
    /// This is the exact per-instruction path; [`Processor::run_segment`]
    /// additionally consumes the compiler's stream-run metadata.
    pub fn run(&mut self, prog: &[Insn]) -> Result<SimStats, SimError> {
        self.run_insns(prog, &[])
    }

    /// Run one compiled segment, honoring the processor's [`ExecMode`].
    /// Instruction-level tracing expands runs lazily into the
    /// per-instruction path (bit-exact), so batch mode stays the default
    /// even under a tracer.
    pub fn run_segment(&mut self, seg: &Segment) -> Result<SimStats, SimError> {
        if self.mode == ExecMode::Exact || self.insn_tracing() {
            self.run_insns(&seg.insns, &[])
        } else {
            self.run_insns(&seg.insns, &seg.runs)
        }
    }

    fn run_insns(&mut self, prog: &[Insn], runs: &[StreamRun]) -> Result<SimStats, SimError> {
        let start_traffic = self.mem.traffic;
        let start_switches = self.ctrl.precision_switches;
        let mut run_stats = SimStats::default();
        // Clock at entry: cycles of this run are the advance of the machine
        // clock (last completion), so back-to-back runs telescope correctly.
        let run_begin = self.last_complete;
        // Attribution at entry: whatever `schedule`/`run_tensor` do not
        // explain of this call's cycles is pipeline-drain overhead.
        let attr_begin = self.breakdown.total();
        if let Some(t) = &self.tracer {
            self.span_base = t.now();
            self.span_frontier = run_begin;
        }

        let mut ri = 0usize;
        let mut i = 0usize;
        'outer: while i < prog.len() {
            while let Some(r) = runs.get(ri) {
                if (r.start as usize) < i {
                    // Overlapped/stale metadata (e.g. after a fallback) —
                    // skip it; the instructions execute via `step`.
                    ri += 1;
                    continue;
                }
                if r.start as usize == i {
                    let run_from = self.last_complete;
                    if self.exec_run(prog, r, &mut run_stats)? {
                        if let Some(t) = &self.tracer {
                            let begin =
                                self.span_base + run_from.saturating_sub(self.span_frontier);
                            let label = match r.kind {
                                RunKind::Tensor => "tensor-chain",
                                RunKind::Load => "load-run",
                                RunKind::Store => "store-run",
                            };
                            t.record(SpanCat::Run, label, begin, self.last_complete - run_from);
                        }
                        i += r.len as usize;
                        ri += 1;
                        continue 'outer;
                    }
                }
                break;
            }
            self.step(&prog[i], &mut run_stats)?;
            i += 1;
        }

        // Total cycles: last completion + 1 (CO stage), relative to run start.
        run_stats.cycles = (self.last_complete + 1).saturating_sub(run_begin + 1).max(1);
        // The frontier-advance attribution telescopes to exactly
        // `last_complete - run_begin`; the per-run `max(1)` clamp above is
        // the only unexplained remainder and lands in `overhead`, keeping
        // `breakdown.total() == stats.cycles` to the cycle.
        let attributed = self.breakdown.total() - attr_begin;
        self.breakdown.overhead += run_stats.cycles - attributed.min(run_stats.cycles);
        if let Some(t) = &self.tracer {
            t.advance(run_stats.cycles);
        }
        run_stats.vregs_used = self.vregs_touched.iter().filter(|&&b| b).count() as u32;
        // Switches performed by *this* run (the ctrl counter is lifetime).
        run_stats.precision_switches = self.ctrl.precision_switches - start_switches;
        // Traffic delta for this run.
        let t = self.mem.traffic;
        run_stats.traffic.input_read = t.input_read - start_traffic.input_read;
        run_stats.traffic.weight_read = t.weight_read - start_traffic.weight_read;
        run_stats.traffic.partial_read = t.partial_read - start_traffic.partial_read;
        run_stats.traffic.partial_write = t.partial_write - start_traffic.partial_write;
        run_stats.traffic.output_write = t.output_write - start_traffic.output_write;

        self.stats.merge(&run_stats);
        Ok(run_stats)
    }

    /// Lifetime stats across all runs.
    pub fn lifetime_stats(&self) -> &SimStats {
        &self.stats
    }

    fn step(&mut self, insn: &Insn, st: &mut SimStats) -> Result<(), SimError> {
        // ---- ID stage: one decode per cycle. ----
        let decode_t = self.t_decode;
        self.t_decode += 1;
        st.insns_total += 1;
        if insn.is_custom() {
            st.insns_custom += 1;
        }
        if insn.is_vector() {
            st.insns_vector += 1;
        } else {
            st.insns_scalar += 1;
        }
        let reads = insn.vregs_read();
        let writes = insn.vregs_written();
        for r in reads.iter().chain(writes.iter()) {
            self.vregs_touched[*r as usize] = true;
        }

        // ---- classify: FU, EX duration, memory-port bytes. ----
        let (fu, ex_cycles, port_bytes) = self.cost_of(insn)?;

        // ---- IS/EX scheduling (shared with the batch path). ----
        self.schedule(insn, decode_t, fu, ex_cycles, port_bytes, &reads, &writes, st);

        // ---- functional execution (program order). ----
        self.execute(insn, st)
    }

    /// IS/EX scoreboard advance of one classified instruction: FU + hazard
    /// gating, MPTU chaining, shared-memory-port serialization, and all
    /// stall/busy accounting. Returns the completion time.
    ///
    /// Both execution paths go through this one function so the batch
    /// executors cannot drift from `step`'s timing semantics.
    #[allow(clippy::too_many_arguments)]
    fn schedule(
        &mut self,
        insn: &Insn,
        decode_t: u64,
        fu: Fu,
        mut ex_cycles: u64,
        port_bytes: u64,
        reads: &[u8],
        writes: &[u8],
        st: &mut SimStats,
    ) -> u64 {
        let ready = decode_t + 1; // IS takes one cycle after ID
        let mut issue = ready.max(self.fu_free[fu.index()]);
        if self.fu_free[fu.index()] > ready {
            st.stall_fu_busy += self.fu_free[fu.index()] - ready;
        }
        let mut hazard_until = 0u64;
        for &r in reads {
            hazard_until = hazard_until.max(self.vreg_write_done[r as usize]); // RAW
        }
        for &r in writes {
            hazard_until = hazard_until.max(self.vreg_write_done[r as usize]); // WAW
            hazard_until = hazard_until.max(self.vreg_read_done[r as usize]); // WAR
        }
        if hazard_until > issue {
            st.stall_hazard += hazard_until - issue;
            issue = hazard_until;
        }
        // Chained MPTU bursts: when a VSAM issues exactly as the previous
        // one drains, the request/compute/write-back pipeline stays primed
        // and the refill cost is not paid again (Fig. 9's overlap).
        if fu == Fu::Mptu {
            if issue <= self.last_mptu_complete {
                ex_cycles = ex_cycles.saturating_sub(mptu::PIPE_FILL).max(1);
            }
            self.last_mptu_complete = issue.max(self.fu_free[fu.index()]) + ex_cycles;
        }
        // Shared external-memory port (VLDU + VSU serialize).
        let mut start = issue;
        if port_bytes > 0 {
            if self.mem_port_free > start {
                st.stall_mem_port += self.mem_port_free - start;
                start = self.mem_port_free;
            }
            self.mem_port_free = start + ex_cycles;
        }

        let complete = start + ex_cycles;
        if let Some(t) = &self.tracer {
            if t.echo() {
                eprintln!(
                    "dec={decode_t} rdy={ready} iss={issue} start={start} \
                     done={complete} ex={ex_cycles} {insn:?}"
                );
            }
            if t.level() >= TraceLevel::Insn {
                let begin = self.span_base + start.saturating_sub(self.span_frontier);
                t.record(SpanCat::Insn, format!("{insn:?}"), begin, ex_cycles.max(1));
            }
        }
        self.fu_free[fu.index()] = complete;
        for &r in writes {
            self.vreg_write_done[r as usize] = complete;
        }
        for &r in reads {
            self.vreg_read_done[r as usize] = self.vreg_read_done[r as usize].max(complete);
        }
        st.fu_busy[fu.index()] += ex_cycles;
        let frontier_was = self.last_complete;
        self.last_complete = self.last_complete.max(complete);
        self.attribute(insn, self.last_complete - frontier_was);
        complete
    }

    /// Charge a completion-frontier advancement to the [`CycleBreakdown`]
    /// bucket of the instruction class that caused it. Deltas telescope to
    /// the run's cycle count, so buckets stay an exact partition.
    fn attribute(&mut self, insn: &Insn, delta: u64) {
        if delta == 0 {
            return;
        }
        match *insn {
            Insn::Vsam { .. } | Insn::Vsac { .. } => self.breakdown.chain += delta,
            Insn::Vle { .. } | Insn::Vsald { .. } => self.breakdown.load += delta,
            Insn::Vse { .. } => self.breakdown.store += delta,
            Insn::Vmacc { .. }
            | Insn::Vmul { .. }
            | Insn::Vadd { .. }
            | Insn::Vsub { .. }
            | Insn::Vmax { .. }
            | Insn::Vmin { .. }
            | Insn::Vsra { .. }
            | Insn::Vmv { .. } => self.breakdown.alu += delta,
            Insn::Vsacfg { zimm, .. } => {
                // Classified before `ctrl.apply` runs: a VSACFG selecting a
                // precision other than the latched one is the single-cycle
                // datapath reconfiguration of Sec. II-E.
                if Insn::unpack_cfg(zimm).is_some_and(|(p, _, _)| p != self.ctrl.prec) {
                    self.breakdown.prec_switch += delta;
                } else {
                    self.breakdown.scalar += delta;
                }
            }
            Insn::Addi { .. } | Insn::Vsetvli { .. } | Insn::VsacfgDim { .. } => {
                self.breakdown.scalar += delta;
            }
        }
    }

    // ================= batch fast path =================

    /// Execute one recognized stream run. Returns `Ok(false)` when the
    /// metadata does not match the instructions (the caller then falls
    /// back to per-instruction stepping — validation happens *before* any
    /// state is mutated, so a fallback is always safe).
    fn exec_run(
        &mut self,
        prog: &[Insn],
        run: &StreamRun,
        st: &mut SimStats,
    ) -> Result<bool, SimError> {
        let s = run.start as usize;
        let l = run.len as usize;
        if l == 0 || s + l > prog.len() {
            return Ok(false);
        }
        let body = &prog[s..s + l];
        match run.kind {
            RunKind::Tensor => {
                let first = body[0];
                // No installed plan: fall back so the per-instruction path
                // raises NoPlan with exactly the exact-mode state (counters
                // and the first burst's scheduling happen before the error).
                if self.plan.is_none()
                    || !matches!(first, Insn::Vsam { .. } | Insn::Vsac { .. })
                    || !body.iter().all(|i| *i == first)
                {
                    return Ok(false);
                }
                self.run_tensor(first, l as u64, st)?;
                Ok(true)
            }
            RunKind::Load => {
                if l % 2 != 0 || !Self::valid_load_pairs(body) {
                    return Ok(false);
                }
                self.run_load_pairs(body, st)?;
                Ok(true)
            }
            RunKind::Store => {
                if l % 2 != 0 || self.plan.is_none() || !Self::valid_store_pairs(body) {
                    return Ok(false);
                }
                self.run_store_pairs(body, st)?;
                Ok(true)
            }
        }
    }

    /// `(li xN, addr ; vsald/vle vX, (xN))` pairs with uniform mode/width.
    fn valid_load_pairs(body: &[Insn]) -> bool {
        let key = body[1];
        body.chunks_exact(2).all(|p| match (p[0], p[1]) {
            (Insn::Addi { rd, rs1: 0, .. }, Insn::Vsald { rs1, mode, width, .. }) => {
                rd != 0
                    && rs1 == rd
                    && matches!(key, Insn::Vsald { mode: km, width: kw, .. }
                        if km == mode && kw == width)
            }
            (Insn::Addi { rd, rs1: 0, .. }, Insn::Vle { rs1, eew, .. }) => {
                rd != 0
                    && rs1 == rd
                    && matches!(key, Insn::Vle { eew: ke, .. } if ke == eew)
            }
            _ => false,
        })
    }

    /// `(li xN, addr ; vse32.v vS, (xN))` pairs.
    fn valid_store_pairs(body: &[Insn]) -> bool {
        body.chunks_exact(2).all(|p| match (p[0], p[1]) {
            (Insn::Addi { rd, rs1: 0, .. }, Insn::Vse { rs1, .. }) => rd != 0 && rs1 == rd,
            _ => false,
        })
    }

    /// A chain of identical `VSAM`/`VSAC` bursts. The first burst (and any
    /// prefix still gated by pre-run hazards or the decoder) goes through
    /// [`Processor::schedule`]; once the FU gate dominates, the scoreboard
    /// recurrence is linear and the rest of the chain advances in closed
    /// form: completion grows by the chained EX time per burst and the
    /// FU-busy stall grows arithmetically.
    fn run_tensor(&mut self, insn: Insn, k: u64, st: &mut SimStats) -> Result<(), SimError> {
        let (vd, vs1, vs2, stages) = match insn {
            Insn::Vsam { vd, vs1, vs2, stages } | Insn::Vsac { vd, vs1, vs2, stages } => {
                (vd, vs1, vs2, stages as u64)
            }
            _ => unreachable!("validated tensor run"),
        };
        let plan = *self.plan.as_ref().ok_or(SimError::NoPlan)?;
        let ex_full = mptu::PIPE_FILL + stages;
        let exc = ex_full.saturating_sub(mptu::PIPE_FILL).max(1); // chained EX
        let reads = [vs1, vs2];
        let writes = [vd];
        for r in [vd, vs1, vs2] {
            self.vregs_touched[r as usize] = true;
        }
        st.insns_total += k;
        st.insns_custom += k;
        st.insns_vector += k;
        let mi = Fu::Mptu.index();

        let mut done = 0u64;
        while done < k {
            if done >= 1 {
                let c = self.fu_free[mi];
                let ready_next = self.t_decode + 1;
                // Latest pre-run event that could still hazard-gate a burst
                // (vs1/vs2 RAW against their loads, vd WAR against earlier
                // drains). All are constants during the run.
                let h = self.vreg_read_done[vd as usize]
                    .max(self.vreg_write_done[vs1 as usize])
                    .max(self.vreg_write_done[vs2 as usize]);
                if c >= ready_next && h <= c && self.last_mptu_complete == c {
                    // Steady state: burst j of the remainder issues at
                    // C + (j-1)·exc, stalls (C - ready) + (j-1)·(exc - 1)
                    // on the busy FU, and chains (EX = exc).
                    let r = k - done;
                    let base = c - ready_next;
                    st.stall_fu_busy += r * base + (exc - 1) * (r * (r - 1) / 2);
                    st.fu_busy[mi] += r * exc;
                    let cf = c + r * exc;
                    self.t_decode += r;
                    self.fu_free[mi] = cf;
                    self.last_mptu_complete = cf;
                    self.vreg_write_done[vd as usize] = cf;
                    self.vreg_read_done[vs1 as usize] =
                        self.vreg_read_done[vs1 as usize].max(cf);
                    self.vreg_read_done[vs2 as usize] =
                        self.vreg_read_done[vs2 as usize].max(cf);
                    let frontier_was = self.last_complete;
                    self.last_complete = self.last_complete.max(cf);
                    self.breakdown.chain += self.last_complete - frontier_was;
                    break;
                }
            }
            let d = self.t_decode;
            self.t_decode += 1;
            self.schedule(&insn, d, Fu::Mptu, ex_full, 0, &reads, &writes, st);
            done += 1;
        }

        // Functional accounting telescopes across the whole chain: the
        // per-burst MAC attribution is a difference of the same cursor
        // formula, so k bursts sum to one endpoint difference.
        let slots = self.cfg.peak_macs_per_cycle(plan.desc.prec);
        st.mac_slots += k * stages * slots;
        let total = plan.total_stages.max(1);
        let before =
            (plan.desc.total_macs() as u128 * self.stage_cursor as u128 / total as u128) as u64;
        self.stage_cursor = (self.stage_cursor + k * stages).min(total);
        let after =
            (plan.desc.total_macs() as u128 * self.stage_cursor as u128 / total as u128) as u64;
        st.macs += after - before;
        if self.stage_cursor >= total {
            self.ensure_computed();
        }
        Ok(())
    }

    /// A run of `(li ; vsald/vle)` pairs: uniform transfer cost computed
    /// once, per-pair scheduling through the shared core, bulk instruction
    /// counters, real byte movement per transfer.
    fn run_load_pairs(&mut self, body: &[Insn], st: &mut SimStats) -> Result<(), SimError> {
        let k = (body.len() / 2) as u64;
        let bw = self.cfg.mem_bw_bytes_per_cycle as u64;
        let lat = self.cfg.mem_latency as u64;
        let (bytes, custom) = match body[1] {
            Insn::Vsald { width, .. } => {
                let prec = match width {
                    WidthSel::FromCfg => self.ctrl.prec,
                    WidthSel::Explicit(p) => p,
                };
                (prec.bytes_for(self.ctrl.vl as u64), true)
            }
            Insn::Vle { eew, .. } => (self.ctrl.vl as u64 * (eew as u64 / 8), false),
            _ => unreachable!("validated load run"),
        };
        let ex = lat + bytes.div_ceil(bw).max(1);
        for pair in body.chunks_exact(2) {
            let Insn::Addi { rd, imm, .. } = pair[0] else { unreachable!() };
            let d0 = self.t_decode;
            self.t_decode += 1;
            self.schedule(&pair[0], d0, Fu::Scalar, 1, 0, &[], &[], st);
            self.xregs[rd as usize] = imm as i64;
            let addr = (imm as i64) as u64;
            let d1 = self.t_decode;
            self.t_decode += 1;
            let (vd, broadcast) = match pair[1] {
                Insn::Vsald { vd, mode, .. } => (vd, mode == LdMode::Broadcast),
                Insn::Vle { vd, .. } => (vd, false),
                _ => unreachable!(),
            };
            self.vregs_touched[vd as usize] = true;
            self.schedule(&pair[1], d1, Fu::Vldu, ex, bytes, &[], &[vd], st);
            self.load_to_vrf(vd, addr, bytes as usize, broadcast)?;
        }
        st.insns_total += 2 * k;
        st.insns_scalar += k;
        st.insns_vector += k;
        if custom {
            st.insns_custom += k;
        }
        Ok(())
    }

    /// A run of `(li ; vse32.v)` row drains under an installed plan.
    fn run_store_pairs(&mut self, body: &[Insn], st: &mut SimStats) -> Result<(), SimError> {
        let k = (body.len() / 2) as u64;
        let bw = self.cfg.mem_bw_bytes_per_cycle as u64;
        let plan = *self.plan.as_ref().expect("validated store run");
        for pair in body.chunks_exact(2) {
            let Insn::Addi { rd, imm, .. } = pair[0] else { unreachable!() };
            let d0 = self.t_decode;
            self.t_decode += 1;
            self.schedule(&pair[0], d0, Fu::Scalar, 1, 0, &[], &[], st);
            self.xregs[rd as usize] = imm as i64;
            let addr = (imm as i64) as u64;
            let Insn::Vse { vs3, .. } = pair[1] else { unreachable!() };
            let bytes = if !plan.is_partial_addr(addr) {
                plan.desc.output_row_elems() * 4
            } else {
                self.ctrl.vl as u64 * (self.ctrl.sew as u64 / 8)
            };
            let ex = bytes.div_ceil(bw).max(1);
            let d1 = self.t_decode;
            self.t_decode += 1;
            self.vregs_touched[vs3 as usize] = true;
            self.schedule(&pair[1], d1, Fu::Vsu, ex, bytes, &[vs3], &[], st);
            self.drain_row(addr)?;
        }
        st.insns_total += 2 * k;
        st.insns_scalar += k;
        st.insns_vector += k;
        Ok(())
    }

    // ================= exact path =================

    /// (FU, EX cycles, external-memory bytes) of an instruction under the
    /// current control state.
    fn cost_of(&self, insn: &Insn) -> Result<(Fu, u64, u64), SimError> {
        let cfg = &self.cfg;
        let bw = cfg.mem_bw_bytes_per_cycle as u64;
        let lat = cfg.mem_latency as u64;
        Ok(match *insn {
            Insn::Addi { .. } | Insn::Vsetvli { .. } | Insn::Vsacfg { .. }
            | Insn::VsacfgDim { .. } => (Fu::Scalar, 1, 0),
            Insn::Vle { eew, .. } => {
                let bytes = self.ctrl.vl as u64 * (eew as u64 / 8);
                (Fu::Vldu, lat + bytes.div_ceil(bw).max(1), bytes)
            }
            Insn::Vsald { width, .. } => {
                let prec = match width {
                    WidthSel::FromCfg => self.ctrl.prec,
                    WidthSel::Explicit(p) => p,
                };
                let bytes = prec.bytes_for(self.ctrl.vl as u64);
                (Fu::Vldu, lat + bytes.div_ceil(bw).max(1), bytes)
            }
            Insn::Vse { rs1, .. } => {
                // Stores drain completed i32 rows (result-queue path) or,
                // without a plan, vl elements at SEW.
                let addr = self.xreg(rs1) as u64;
                let bytes = match &self.plan {
                    Some(p) if !p.is_partial_addr(addr) => p.desc.output_row_elems() * 4,
                    _ => self.ctrl.vl as u64 * (self.ctrl.sew as u64 / 8),
                };
                (Fu::Vsu, bytes.div_ceil(bw).max(1), bytes)
            }
            Insn::Vmacc { .. }
            | Insn::Vmul { .. }
            | Insn::Vadd { .. }
            | Insn::Vsub { .. }
            | Insn::Vmax { .. }
            | Insn::Vmin { .. }
            | Insn::Vsra { .. } => {
                let per_cycle = cfg.lanes as u64 * (64 / self.ctrl.sew as u64).max(1);
                (Fu::Valu, 2 + (self.ctrl.vl as u64).div_ceil(per_cycle), 0)
            }
            Insn::Vmv { .. } => (Fu::Valu, 1, 0),
            Insn::Vsam { stages, .. } | Insn::Vsac { stages, .. } => {
                (Fu::Mptu, mptu::PIPE_FILL + stages as u64, 0)
            }
        })
    }

    fn check_mem(&self, addr: u64, len: usize) -> Result<(), SimError> {
        if addr as usize + len > self.mem.size() {
            return Err(SimError::MemOutOfRange { addr, len, size: self.mem.size() });
        }
        Ok(())
    }

    fn execute(&mut self, insn: &Insn, st: &mut SimStats) -> Result<(), SimError> {
        match *insn {
            Insn::Addi { rd, rs1, imm } => {
                if rd != 0 {
                    self.xregs[rd as usize] = self.xreg(rs1) + imm as i64;
                }
            }
            Insn::Vsetvli { .. } | Insn::Vsacfg { .. } | Insn::VsacfgDim { .. } => {
                let regs = self.xregs;
                self.ctrl.apply(insn, |r| if r == 0 { 0 } else { regs[r as usize] });
            }
            Insn::Vle { vd, rs1, eew } => {
                let addr = self.xreg(rs1) as u64;
                let total = self.ctrl.vl as usize * (eew as usize / 8);
                self.load_to_vrf(vd, addr, total, /*broadcast=*/ false)?;
            }
            Insn::Vsald { vd, rs1, mode, width } => {
                let prec = match width {
                    WidthSel::FromCfg => self.ctrl.prec,
                    WidthSel::Explicit(p) => p,
                };
                let addr = self.xreg(rs1) as u64;
                let total = prec.bytes_for(self.ctrl.vl as u64) as usize;
                self.load_to_vrf(vd, addr, total, mode == LdMode::Broadcast)?;
            }
            Insn::Vse { vs3, rs1, .. } => {
                let addr = self.xreg(rs1) as u64;
                if self.plan.is_some() {
                    self.drain_row(addr)?;
                } else {
                    // Raw store: vl elements at SEW from the named vector
                    // register (the ALU epilogue path writes real data).
                    let bytes = self.ctrl.vl as usize * (self.ctrl.sew as usize / 8);
                    self.check_mem(addr, bytes)?;
                    let data = self.vreg_bytes(vs3, bytes);
                    self.mem.write(addr, &data, TrafficClass::Output);
                }
            }
            Insn::Vmv { vd, rs1 } => {
                // Splat a scalar into the vector register (epilogue
                // constants: rounding bias, shift amount, clip bounds).
                let v = self.xreg(rs1);
                let n = self.ctrl.vl as usize;
                let mut out = vec![0u8; n * (self.ctrl.sew as usize / 8)];
                for i in 0..n {
                    self.write_sew(&mut out, i, v);
                }
                self.vreg_write(vd, &out);
            }
            Insn::Vadd { vd, vs1, vs2 } => self.alu_op(vd, vs1, vs2, |a, b| a.wrapping_add(b)),
            Insn::Vsub { vd, vs1, vs2 } => self.alu_op(vd, vs1, vs2, |a, b| a.wrapping_sub(b)),
            Insn::Vmul { vd, vs1, vs2 } => self.alu_op(vd, vs1, vs2, |a, b| a.wrapping_mul(b)),
            Insn::Vmax { vd, vs1, vs2 } => self.alu_op(vd, vs1, vs2, |a, b| a.max(b)),
            Insn::Vmin { vd, vs1, vs2 } => self.alu_op(vd, vs1, vs2, |a, b| a.min(b)),
            Insn::Vsra { vd, vs1, vs2 } => {
                self.alu_op(vd, vs1, vs2, |a, b| a >> (b & 0x3F).max(0))
            }
            Insn::Vmacc { vd, vs1, vs2 } => {
                // vd += vs1 * vs2 (three-operand read).
                let bytes = self.ctrl.vl as usize * (self.ctrl.sew as usize / 8);
                let acc = self.vreg_bytes(vd, bytes);
                let a = self.vreg_bytes(vs1, bytes);
                let b = self.vreg_bytes(vs2, bytes);
                let mut out = vec![0u8; bytes];
                for i in 0..self.ctrl.vl as usize {
                    let v = self
                        .read_sew(&acc, i)
                        .wrapping_add(self.read_sew(&a, i).wrapping_mul(self.read_sew(&b, i)));
                    self.write_sew(&mut out, i, v);
                }
                self.vreg_write(vd, &out);
            }
            Insn::Vsam { stages, .. } | Insn::Vsac { stages, .. } => {
                let plan = self.plan.as_ref().ok_or(SimError::NoPlan)?;
                let slots = self.cfg.peak_macs_per_cycle(plan.desc.prec);
                st.mac_slots += stages as u64 * slots;
                // Advance the stage cursor; attribute the covered MACs.
                let total = plan.total_stages.max(1);
                let before =
                    (plan.desc.total_macs() as u128 * self.stage_cursor as u128 / total as u128) as u64;
                self.stage_cursor = (self.stage_cursor + stages as u64).min(total);
                let after =
                    (plan.desc.total_macs() as u128 * self.stage_cursor as u128 / total as u128) as u64;
                st.macs += after - before;
                // When the schedule completes, the functional engine
                // produces the output rows for the result queue. (Stores
                // may also demand rows earlier — see `drain_row` — timing
                // correctness is enforced by the vreg scoreboard either
                // way.)
                if self.stage_cursor >= total {
                    self.ensure_computed();
                }
            }
        }
        Ok(())
    }

    /// Read an element at the active SEW from a flat byte image.
    fn read_sew(&self, buf: &[u8], idx: usize) -> i64 {
        match self.ctrl.sew {
            8 => super::elem::read_elem(buf, idx, crate::config::Precision::Int8) as i64,
            16 => super::elem::read_elem(buf, idx, crate::config::Precision::Int16) as i64,
            _ => super::elem::read_i32(buf, idx) as i64,
        }
    }

    /// Write an element at the active SEW into a flat byte image.
    fn write_sew(&self, buf: &mut [u8], idx: usize, v: i64) {
        match self.ctrl.sew {
            8 => super::elem::write_elem(buf, idx, crate::config::Precision::Int8, v as i32),
            16 => super::elem::write_elem(buf, idx, crate::config::Precision::Int16, v as i32),
            _ => super::elem::write_i32(buf, idx, v as i32),
        }
    }

    /// Flat byte image of a vector register (concatenated lane stripes, the
    /// same order sequential loads/stores use).
    fn vreg_bytes(&self, v: u8, total: usize) -> Vec<u8> {
        let region = self.vreg_region_bytes();
        let lanes = self.cfg.lanes as usize;
        let per_lane = total.div_ceil(lanes);
        let mut out = vec![0u8; total];
        for (l, lane) in self.vrf.iter().enumerate() {
            let lo = (l * per_lane).min(total);
            let hi = ((l + 1) * per_lane).min(total);
            if lo < hi {
                let take = (hi - lo).min(region);
                let off = v as usize * region;
                out[lo..lo + take].copy_from_slice(&lane[off..off + take]);
            }
        }
        out
    }

    /// Write a flat byte image back into a vector register (lane-striped).
    fn vreg_write(&mut self, v: u8, data: &[u8]) {
        let region = self.vreg_region_bytes();
        let lanes = self.cfg.lanes as usize;
        let total = data.len();
        let per_lane = total.div_ceil(lanes);
        for (l, lane) in self.vrf.iter_mut().enumerate() {
            let lo = (l * per_lane).min(total);
            let hi = ((l + 1) * per_lane).min(total);
            if lo < hi {
                let take = (hi - lo).min(region);
                let off = v as usize * region;
                lane[off..off + take].copy_from_slice(&data[lo..lo + take]);
            }
        }
    }

    /// Element-wise two-operand vector-ALU operation over `vl` elements at
    /// the active SEW.
    fn alu_op(&mut self, vd: u8, vs1: u8, vs2: u8, f: impl Fn(i64, i64) -> i64) {
        let bytes = self.ctrl.vl as usize * (self.ctrl.sew as usize / 8);
        let a = self.vreg_bytes(vs1, bytes);
        let b = self.vreg_bytes(vs2, bytes);
        let mut out = vec![0u8; bytes];
        for i in 0..self.ctrl.vl as usize {
            let v = f(self.read_sew(&a, i), self.read_sew(&b, i));
            self.write_sew(&mut out, i, v);
        }
        self.vreg_write(vd, &out);
    }

    fn load_to_vrf(
        &mut self,
        vd: u8,
        addr: u64,
        total_bytes: usize,
        broadcast: bool,
    ) -> Result<(), SimError> {
        self.check_mem(addr, total_bytes)?;
        let region = self.vreg_region_bytes();
        let lanes = self.cfg.lanes as usize;
        let class = self.classify_load(addr);
        self.scratch.clear();
        self.scratch.extend_from_slice(self.mem.read(addr, total_bytes, class));
        let data = std::mem::take(&mut self.scratch);
        if broadcast {
            // Same bytes delivered to every lane (multi-broadcast): one
            // DRAM fetch, `lanes` VRF writes.
            if total_bytes > region {
                self.scratch = data;
                return Err(SimError::VrfOverflow { vd, need: total_bytes, have: region });
            }
            for lane in self.vrf.iter_mut() {
                let off = vd as usize * region;
                lane[off..off + total_bytes].copy_from_slice(&data);
            }
        } else {
            // Sequential allocation: the transfer is striped across lanes.
            let per_lane = total_bytes.div_ceil(lanes);
            if per_lane > region {
                self.scratch = data;
                return Err(SimError::VrfOverflow { vd, need: per_lane, have: region });
            }
            for (l, lane) in self.vrf.iter_mut().enumerate() {
                let lo = (l * per_lane).min(total_bytes);
                let hi = ((l + 1) * per_lane).min(total_bytes);
                if lo < hi {
                    let off = vd as usize * region;
                    lane[off..off + (hi - lo)].copy_from_slice(&data[lo..hi]);
                }
            }
        }
        self.scratch = data;
        Ok(())
    }

    fn classify_load(&self, addr: u64) -> TrafficClass {
        match &self.plan {
            Some(p) if p.is_partial_addr(addr) => TrafficClass::Partial,
            Some(p) if addr >= p.w_addr && p.w_addr > p.in_addr => TrafficClass::Weight,
            Some(p) if addr >= p.in_addr && addr < p.w_addr => TrafficClass::Input,
            Some(_) => TrafficClass::Input,
            None => TrafficClass::Input,
        }
    }

    /// Produce the operator's rows if not done yet (demand-driven: the
    /// result path may be drained block-by-block while later blocks are
    /// still scheduled).
    fn ensure_computed(&mut self) {
        if self.computed {
            return;
        }
        self.computed = true;
        if let Some(plan) = &self.plan {
            if plan.functional {
                self.computed_rows = mptu::compute_output_rows(&self.mem, plan);
            }
        }
    }

    fn drain_row(&mut self, addr: u64) -> Result<(), SimError> {
        let plan = *self.plan.as_ref().ok_or(SimError::NoPlan)?;
        if plan.is_partial_addr(addr) {
            // Partial spill: numerics are carried inside the functional
            // engine; the store contributes (byte-accurate) traffic.
            let bytes = (self.ctrl.vl as usize * 4).max(4);
            self.check_mem(addr, bytes)?;
            self.scratch.clear();
            self.scratch.resize(bytes, 0);
            let zeros = std::mem::take(&mut self.scratch);
            self.mem.write(addr, &zeros, TrafficClass::Partial);
            self.scratch = zeros;
            return Ok(());
        }
        let row_bytes = plan.desc.output_row_elems() * 4;
        if !plan.functional {
            // Timing-only run: count the bytes of one output row.
            self.check_mem(addr, row_bytes as usize)?;
            self.scratch.clear();
            self.scratch.resize(row_bytes as usize, 0);
            let zeros = std::mem::take(&mut self.scratch);
            self.mem.write(addr, &zeros, TrafficClass::Output);
            self.scratch = zeros;
            return Ok(());
        }
        self.ensure_computed();
        // Map the address back to the output row it drains.
        if addr < plan.out_addr || (addr - plan.out_addr) % row_bytes != 0 {
            return Err(SimError::StoreUnderflow);
        }
        let idx = ((addr - plan.out_addr) / row_bytes) as usize;
        if idx >= self.computed_rows.num_rows() {
            return Err(SimError::StoreUnderflow);
        }
        self.check_mem(addr, row_bytes as usize)?;
        // Serialize the flat row view through the reusable scratch buffer
        // (no per-row allocation on the drain path).
        let mut buf = std::mem::take(&mut self.scratch);
        buf.clear();
        buf.resize(row_bytes as usize, 0);
        for (chunk, v) in buf.chunks_exact_mut(4).zip(self.computed_rows.row(idx)) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        self.mem.write(addr, &buf, TrafficClass::Output);
        self.scratch = buf;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_op, MemLayout};
    use crate::config::Precision;
    use crate::isa::{assemble, StrategyKind};
    use crate::models::ops::OpDesc;

    fn machine() -> Processor {
        Processor::new(SpeedConfig::reference(), 1 << 20)
    }

    #[test]
    fn scalar_program_counts_cycles() {
        let mut p = machine();
        let prog = assemble("li x1, 4\nli x2, 8\naddi x3, x1, 2").unwrap();
        let st = p.run(&prog).unwrap();
        assert_eq!(st.insns_total, 3);
        assert_eq!(st.insns_scalar, 3);
        // 3 decodes (1/cycle), each 1-cycle EX overlapped: ~5 cycles total.
        assert!(st.cycles >= 3 && st.cycles <= 6, "{}", st.cycles);
        assert_eq!(p.xreg(3), 6);
    }

    #[test]
    fn vle_moves_bytes_and_counts_traffic() {
        let mut p = machine();
        p.mem.preload(0x100, &[7u8; 64]);
        let prog = assemble(
            "li x1, 32\nvsetvli x0, x1, e16\nli x2, 0x100\nvle16.v v1, (x2)",
        )
        .unwrap();
        let st = p.run(&prog).unwrap();
        assert_eq!(st.traffic.input_read, 64);
        // Striped across 4 lanes: 16 bytes each at reg offset of v1.
        let region = p.vreg_region_bytes();
        assert_eq!(&p.vrf[0][region..region + 16], &[7u8; 16]);
        assert_eq!(&p.vrf[3][region..region + 16], &[7u8; 16]);
    }

    #[test]
    fn vsald_broadcast_copies_to_all_lanes() {
        let mut p = machine();
        p.mem.preload(0x200, &[9u8; 16]);
        let prog = assemble(
            "li x1, 16\nvsetvli x0, x1, e8\nli x2, 0x200\nvsald v2, (x2), bcast, w=8",
        )
        .unwrap();
        let st = p.run(&prog).unwrap();
        // One DRAM fetch of 16 bytes regardless of lane count.
        assert_eq!(st.traffic.input_read, 16);
        let region = p.vreg_region_bytes();
        for lane in 0..4 {
            assert_eq!(&p.vrf[lane][2 * region..2 * region + 16], &[9u8; 16]);
        }
    }

    #[test]
    fn mm_program_end_to_end_numerics() {
        // Full instruction-driven 2x2 INT8 MM: A @ I = A.
        let mut p = machine();
        let d = OpDesc::mm(2, 2, 2, Precision::Int8);
        let plan = OpPlan {
            desc: d,
            strat: StrategyKind::Mm,
            in_addr: 0x000,
            w_addr: 0x100,
            out_addr: 0x200,
            partial_addr: u64::MAX,
            total_stages: 2,
            functional: true,
        };
        p.mem.preload_packed(plan.in_addr, &[1, 2, 3, 4], d.prec);
        p.mem.preload_packed(plan.w_addr, &[1, 0, 0, 1], d.prec);
        p.set_plan(plan);
        let prog = assemble(
            "li x1, 4\n\
             vsetvli x0, x1, e8\n\
             vsacfg x3, prec=8, k=1, strat=mm\n\
             li x4, 0\n\
             vsald v0, (x4), seq, w=cfg\n\
             li x5, 0x100\n\
             vsald v4, (x5), bcast, w=cfg\n\
             vsam v8, v0, v4, stages=2\n\
             li x6, 0x200\n\
             vse32.v v8, (x6)\n\
             addi x6, x6, 8\n\
             vse32.v v8, (x6)",
        )
        .unwrap();
        let st = p.run(&prog).unwrap();
        assert_eq!(p.mem.inspect_i32(0x200, 4), vec![1, 2, 3, 4]);
        assert_eq!(st.macs, d.total_macs());
        assert_eq!(st.traffic.output_write, 16);
        assert!(st.cycles > 0);
    }

    #[test]
    fn vsam_without_plan_errors() {
        let mut p = machine();
        let prog = assemble("vsam v8, v0, v4, stages=1").unwrap();
        assert_eq!(p.run(&prog).unwrap_err(), SimError::NoPlan);
    }

    #[test]
    fn store_to_unmapped_row_detected() {
        let mut p = machine();
        let d = OpDesc::mm(1, 1, 1, Precision::Int8);
        p.set_plan(OpPlan {
            desc: d,
            strat: StrategyKind::Mm,
            in_addr: 0,
            w_addr: 0x10,
            out_addr: 0x20,
            partial_addr: u64::MAX,
            total_stages: 1,
            functional: true,
        });
        // Misaligned output address (0x21 is not a row boundary).
        let prog = assemble("li x1, 0x21\nvse32.v v8, (x1)").unwrap();
        assert_eq!(p.run(&prog).unwrap_err(), SimError::StoreUnderflow);
        // Row index past the output tensor (row 5 of a 1x1 output).
        let prog = assemble("li x1, 0x34\nvse32.v v8, (x1)").unwrap();
        assert_eq!(p.run(&prog).unwrap_err(), SimError::StoreUnderflow);
    }

    #[test]
    fn vrf_overflow_detected() {
        let mut p = machine();
        // 16 KiB VRF / 32 regs = 512 B per lane-region; broadcast of 1024 B
        // cannot fit one register.
        p.mem.preload(0, &[0u8; 2048]);
        let prog = assemble(
            "li x1, 1024\nvsetvli x0, x1, e8\nli x2, 0\nvsald v1, (x2), bcast, w=8",
        )
        .unwrap();
        match p.run(&prog).unwrap_err() {
            SimError::VrfOverflow { need, have, .. } => {
                assert_eq!(need, 1024);
                assert_eq!(have, 512);
            }
            e => panic!("unexpected {e:?}"),
        }
    }

    #[test]
    fn mem_out_of_range_detected() {
        let mut p = Processor::new(SpeedConfig::reference(), 256);
        let prog =
            assemble("li x1, 16\nvsetvli x0, x1, e8\nli x2, 250\nvle8.v v1, (x2)").unwrap();
        assert!(matches!(p.run(&prog).unwrap_err(), SimError::MemOutOfRange { .. }));
    }

    #[test]
    fn hazards_serialize_dependent_ops() {
        // vsam writes v8; vse reads v8 — must not complete before vsam.
        let mut p = machine();
        let d = OpDesc::mm(2, 2, 2, Precision::Int8);
        p.mem.preload_packed(0, &[1, 1, 1, 1], d.prec);
        p.mem.preload_packed(0x100, &[1, 1, 1, 1], d.prec);
        p.set_plan(OpPlan {
            desc: d,
            strat: StrategyKind::Mm,
            in_addr: 0,
            w_addr: 0x100,
            out_addr: 0x200,
            partial_addr: u64::MAX,
            total_stages: 64,
            functional: true,
        });
        let prog = assemble(
            "li x1, 4\nvsetvli x0, x1, e8\nli x2, 0\nvsald v0, (x2), seq, w=8\n\
             li x3, 0x100\nvsald v4, (x3), bcast, w=8\n\
             vsam v8, v0, v4, stages=64\nli x6, 0x200\nvse32.v v8, (x6)",
        )
        .unwrap();
        let st = p.run(&prog).unwrap();
        // The 64-stage VSAM dominates: cycles must exceed its EX time.
        assert!(st.cycles > 64, "cycles {}", st.cycles);
        assert!(st.stall_hazard > 0, "expected RAW stall on v8");
    }

    #[test]
    fn independent_load_and_compute_overlap() {
        // Two independent VSALDs to different registers overlap with MPTU
        // work only via the shared decode; FU busy sums may exceed cycles.
        let mut p = machine();
        p.mem.preload(0, &[0u8; 4096]);
        let prog = assemble(
            "li x1, 256\nvsetvli x0, x1, e8\nli x2, 0\n\
             vsald v0, (x2), seq, w=8\nli x3, 1024\nvsald v1, (x3), seq, w=8",
        )
        .unwrap();
        let st = p.run(&prog).unwrap();
        // Both loads contend for VLDU + mem port: serialized EX.
        assert!(st.stall_fu_busy > 0 || st.stall_mem_port > 0 || st.cycles > 0);
        assert_eq!(st.traffic.input_read, 512);
    }

    // ---- batch fast path ----

    /// Run a compiled operator in the given mode on a fresh machine and
    /// return (aggregate stats, full memory image).
    fn run_compiled(
        op: &OpDesc,
        strat: StrategyKind,
        functional: bool,
        mode: ExecMode,
    ) -> (SimStats, Vec<u8>) {
        let cfg = SpeedConfig::reference();
        let mem = 1 << 22;
        let mut p = Processor::new(cfg, mem);
        p.set_exec_mode(mode);
        let layout = MemLayout::for_op(op, mem).unwrap();
        let x: Vec<i32> = (0..op.input_elems())
            .map(|i| ((i % 11) as i32) - 5)
            .collect();
        let w: Vec<i32> = (0..op.weight_elems())
            .map(|i| ((i % 7) as i32) - 3)
            .collect();
        p.mem.preload_packed(layout.in_addr, &x, op.prec);
        p.mem.preload_packed(layout.w_addr, &w, op.prec);
        let c = compile_op(op, &cfg, strat, layout, functional).unwrap();
        p.set_plan(c.plan);
        let mut total = SimStats::default();
        for seg in &c.segments {
            total.merge(&p.run_segment(seg).unwrap());
        }
        let image = p.mem.inspect(0, MemLayout::required_bytes(op) as usize).to_vec();
        (total, image)
    }

    #[test]
    fn batch_mode_bit_exact_vs_exact_mode() {
        for (op, strat) in [
            (OpDesc::mm(12, 40, 10, Precision::Int8), StrategyKind::Mm),
            (OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int16), StrategyKind::Ffcs),
            (OpDesc::pwcv(16, 16, 8, 8, Precision::Int4), StrategyKind::Cf),
            (OpDesc::dwcv(6, 9, 9, 3, 2, 1, Precision::Int8), StrategyKind::Ff),
        ] {
            for functional in [true, false] {
                let (se, me) = run_compiled(&op, strat, functional, ExecMode::Exact);
                let (sb, mb) = run_compiled(&op, strat, functional, ExecMode::Batch);
                assert_eq!(se, sb, "{op:?} {strat} functional={functional}");
                assert_eq!(me, mb, "{op:?} {strat} functional={functional}");
            }
        }
    }

    #[test]
    fn tensor_run_closed_form_matches_exact() {
        // A long homogeneous VSAM chain behind loads (which set up the
        // pre-run hazard state the closed form must respect).
        let d = OpDesc::mm(8, 64, 8, Precision::Int8);
        let build = || {
            let mut p = machine();
            p.mem.preload_packed(0, &vec![1; 8 * 64], d.prec);
            p.mem.preload_packed(0x400, &vec![1; 64 * 8], d.prec);
            p.set_plan(OpPlan {
                desc: d,
                strat: StrategyKind::Mm,
                in_addr: 0,
                w_addr: 0x400,
                out_addr: 0x800,
                partial_addr: u64::MAX,
                total_stages: 40,
                functional: false,
            });
            p
        };
        let prologue = assemble(
            "li x1, 64\nvsetvli x0, x1, e8\nli x2, 0\nvsald v0, (x2), seq, w=8\n\
             li x3, 0x400\nvsald v4, (x3), bcast, w=8",
        )
        .unwrap();
        let mut insns = prologue.clone();
        for _ in 0..40 {
            insns.push(Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 1 });
        }
        let runs = vec![StreamRun {
            start: prologue.len() as u32,
            len: 40,
            kind: RunKind::Tensor,
        }];
        let seg = Segment { insns, runs };

        let mut exact = build();
        exact.set_exec_mode(ExecMode::Exact);
        let se = exact.run_segment(&seg).unwrap();
        let mut batch = build();
        batch.set_exec_mode(ExecMode::Batch);
        let sb = batch.run_segment(&seg).unwrap();
        assert_eq!(se, sb);
        assert_eq!(exact.t_decode, batch.t_decode);
        assert_eq!(exact.fu_free, batch.fu_free);
        assert_eq!(exact.vreg_write_done, batch.vreg_write_done);
        assert_eq!(exact.vreg_read_done, batch.vreg_read_done);
        assert_eq!(exact.last_mptu_complete, batch.last_mptu_complete);
        assert_eq!(exact.last_complete, batch.last_complete);
    }

    #[test]
    fn bogus_run_metadata_falls_back_to_exact() {
        // Metadata claiming a scalar prologue is a tensor run must be
        // rejected by validation and produce identical results anyway.
        let prog = assemble("li x1, 4\nvsetvli x0, x1, e8\nli x2, 8\nli x3, 9").unwrap();
        let seg = Segment {
            insns: prog.clone(),
            runs: vec![StreamRun { start: 0, len: 4, kind: RunKind::Tensor }],
        };
        let mut a = machine();
        let sa = a.run(&prog).unwrap();
        let mut b = machine();
        b.set_exec_mode(ExecMode::Batch);
        let sb = b.run_segment(&seg).unwrap();
        assert_eq!(sa, sb);
        assert_eq!(a.xreg(3), b.xreg(3));
    }

    #[test]
    fn reset_pipeline_restores_fresh_run_stats() {
        // The same compiled operator replayed after `reset_pipeline` must
        // report stats bit-identical to its very first run on a fresh
        // machine — the contract the serving layer's per-request
        // determinism is built on.
        let cfg = SpeedConfig::reference();
        let op = OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8);
        let layout = MemLayout::for_op(&op, 1 << 20).unwrap();
        let c = compile_op(&op, &cfg, StrategyKind::Ffcs, layout, false).unwrap();
        let run_once = |p: &mut Processor| {
            p.set_plan(c.plan);
            let mut st = SimStats::default();
            for seg in &c.segments {
                st.merge(&p.run_segment(seg).unwrap());
            }
            st
        };
        let mut p = machine();
        let first = run_once(&mut p);
        // Without a reset the warm scoreboard may shift the run's timing.
        let _warm = run_once(&mut p);
        p.reset_pipeline();
        let replay = run_once(&mut p);
        assert_eq!(first, replay);
        // A different machine that ran other work first agrees too.
        let mut q = machine();
        let other = OpDesc::mm(6, 16, 6, Precision::Int16);
        let lo = MemLayout::for_op(&other, 1 << 20).unwrap();
        let co = compile_op(&other, &cfg, StrategyKind::Mm, lo, false).unwrap();
        q.set_plan(co.plan);
        for seg in &co.segments {
            q.run_segment(seg).unwrap();
        }
        q.reset_pipeline();
        let mut cross = run_once(&mut q);
        // Control state persists across the reset by design: q's datapath
        // is at INT16 from the MM program, so the conv's VSACFG performs a
        // switch that p (already at INT8) did not. Everything else — the
        // timing, traffic, and instruction statistics — must agree.
        assert_eq!(cross.precision_switches, 1);
        cross.precision_switches = first.precision_switches;
        assert_eq!(first, cross);
    }

    #[test]
    fn exec_mode_accessors() {
        let mut p = machine();
        p.set_exec_mode(ExecMode::Exact);
        assert_eq!(p.exec_mode(), ExecMode::Exact);
        p.set_exec_mode(ExecMode::Batch);
        assert_eq!(p.exec_mode(), ExecMode::Batch);
    }

    /// Run one compiled operator in `mode` and return the machine.
    fn compiled_machine(op: &OpDesc, strat: StrategyKind, mode: ExecMode) -> Processor {
        let cfg = SpeedConfig::reference();
        let mut p = Processor::new(cfg, 1 << 22);
        p.set_exec_mode(mode);
        let layout = MemLayout::for_op(op, 1 << 22).unwrap();
        let c = compile_op(op, &cfg, strat, layout, false).unwrap();
        p.set_plan(c.plan);
        for seg in &c.segments {
            p.run_segment(seg).unwrap();
        }
        p
    }

    #[test]
    fn breakdown_partitions_lifetime_cycles_in_both_modes() {
        for mode in [ExecMode::Exact, ExecMode::Batch] {
            let op = OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8);
            let p = compiled_machine(&op, StrategyKind::Ffcs, mode);
            let b = p.breakdown();
            assert_eq!(b.total(), p.lifetime_stats().cycles, "{mode:?}: {b:?}");
            assert!(b.chain > 0, "{mode:?}: MPTU chains must be attributed");
            assert!(b.load > 0, "{mode:?}: load runs must be attributed");
        }
    }

    #[test]
    fn tracer_is_stats_inert_and_records_spans() {
        use crate::obs::ObsConfig;
        let op = OpDesc::mm(12, 40, 10, Precision::Int8);
        let plain = compiled_machine(&op, StrategyKind::Mm, ExecMode::Batch);
        let cfg = SpeedConfig::reference();
        let mut traced = Processor::new(cfg, 1 << 22);
        let tracer =
            Tracer::from_config(&ObsConfig::tracing(TraceLevel::Run), 0).unwrap();
        traced.attach_tracer(Some(tracer.clone()));
        let layout = MemLayout::for_op(&op, 1 << 22).unwrap();
        let c = compile_op(&op, &cfg, StrategyKind::Mm, layout, false).unwrap();
        traced.set_plan(c.plan);
        for seg in &c.segments {
            traced.run_segment(seg).unwrap();
        }
        assert_eq!(plain.lifetime_stats(), traced.lifetime_stats());
        assert_eq!(plain.breakdown(), traced.breakdown());
        assert!(tracer.span_count() > 0, "run-level spans recorded");
        // The virtual clock advanced exactly by the simulated cycles.
        assert_eq!(tracer.now(), traced.lifetime_stats().cycles);
    }

    #[test]
    fn insn_tracer_expands_runs_bit_exactly() {
        use crate::obs::ObsConfig;
        // An instruction-level tracer on a *batch-mode* machine must take
        // the per-instruction path lazily and still produce the exact
        // stats — the replacement for SPEED_TRACE forcing exact mode.
        let op = OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int16);
        let exact = compiled_machine(&op, StrategyKind::Ffcs, ExecMode::Exact);
        let cfg = SpeedConfig::reference();
        let mut traced = Processor::new(cfg, 1 << 22);
        traced.set_exec_mode(ExecMode::Batch);
        let tracer =
            Tracer::from_config(&ObsConfig::tracing(TraceLevel::Insn), 0).unwrap();
        traced.attach_tracer(Some(tracer.clone()));
        let layout = MemLayout::for_op(&op, 1 << 22).unwrap();
        let c = compile_op(&op, &cfg, StrategyKind::Ffcs, layout, false).unwrap();
        traced.set_plan(c.plan);
        for seg in &c.segments {
            traced.run_segment(seg).unwrap();
        }
        assert_eq!(exact.lifetime_stats(), traced.lifetime_stats());
        let spans = tracer.take_spans();
        assert!(
            spans.iter().any(|s| s.cat == SpanCat::Insn),
            "instruction spans recorded in batch mode"
        );
    }
}
