//! Element packing helpers for the multi-precision datapath.
//!
//! External memory and the VRFs store operands at their native width:
//! 16-bit little-endian, 8-bit, or nibble-packed 4-bit (two operands per
//! byte, low nibble first). Accumulators are 32-bit little-endian.

use crate::config::Precision;
use crate::error::SpeedError;

/// Read element `idx` of a packed buffer at precision `p` (sign-extended).
pub fn read_elem(buf: &[u8], idx: usize, p: Precision) -> i32 {
    match p {
        Precision::Int16 => {
            let b = 2 * idx;
            i16::from_le_bytes([buf[b], buf[b + 1]]) as i32
        }
        Precision::Int8 => buf[idx] as i8 as i32,
        Precision::Int4 => {
            let byte = buf[idx / 2];
            let nib = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            // sign-extend 4-bit
            ((nib as i32) << 28) >> 28
        }
    }
}

/// Write element `idx` of a packed buffer at precision `p`.
/// The value is truncated to the precision's width (callers clamp first).
pub fn write_elem(buf: &mut [u8], idx: usize, p: Precision, v: i32) {
    match p {
        Precision::Int16 => {
            let b = 2 * idx;
            buf[b..b + 2].copy_from_slice(&(v as i16).to_le_bytes());
        }
        Precision::Int8 => buf[idx] = v as i8 as u8,
        Precision::Int4 => {
            let b = idx / 2;
            let nib = (v as u8) & 0x0F;
            if idx % 2 == 0 {
                buf[b] = (buf[b] & 0xF0) | nib;
            } else {
                buf[b] = (buf[b] & 0x0F) | (nib << 4);
            }
        }
    }
}

/// Read a 32-bit accumulator at element index `idx`.
pub fn read_i32(buf: &[u8], idx: usize) -> i32 {
    let b = 4 * idx;
    i32::from_le_bytes([buf[b], buf[b + 1], buf[b + 2], buf[b + 3]])
}

/// Write a 32-bit accumulator at element index `idx`.
pub fn write_i32(buf: &mut [u8], idx: usize, v: i32) {
    let b = 4 * idx;
    buf[b..b + 4].copy_from_slice(&v.to_le_bytes());
}

/// Pack a slice of values into a fresh buffer at precision `p`.
///
/// Panics when a value falls outside the precision's signed range — use
/// [`try_pack`] for the fallible form. (The range check was once a
/// `debug_assert!`, so a release build would nibble-truncate the
/// out-of-range operand and corrupt the fixture silently.)
pub fn pack(values: &[i32], p: Precision) -> Vec<u8> {
    try_pack(values, p).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible [`pack`]: a typed error naming the offending operand instead
/// of truncating it.
pub fn try_pack(values: &[i32], p: Precision) -> Result<Vec<u8>, SpeedError> {
    let (lo, hi) = p.range();
    if let Some((i, &v)) = values.iter().enumerate().find(|&(_, &v)| v < lo || v > hi) {
        return Err(SpeedError::Config(format!(
            "operand {v} at index {i} is outside the {p} range [{lo}, {hi}]"
        )));
    }
    let mut buf = vec![0u8; p.bytes_for(values.len() as u64) as usize];
    for (i, &v) in values.iter().enumerate() {
        write_elem(&mut buf, i, p, v);
    }
    Ok(buf)
}

/// Unpack `n` values from a packed buffer at precision `p`.
pub fn unpack(buf: &[u8], n: usize, p: Precision) -> Vec<i32> {
    let mut out = Vec::new();
    unpack_into(buf, n, p, &mut out);
    out
}

/// Unpack `n` values into a caller-owned buffer (cleared first).
///
/// This is the bulk form the MPTU functional engine uses: one
/// precision dispatch per *operand tensor* instead of one per element,
/// with branch-free inner loops the compiler can vectorize. Equivalent
/// to `n` calls of [`read_elem`].
pub fn unpack_into(buf: &[u8], n: usize, p: Precision, out: &mut Vec<i32>) {
    // Always-on shape check (promoted from a trailing `debug_assert_eq!`):
    // a short buffer used to panic only on the INT8 path and silently
    // truncate the output on the INT16/INT4 paths in release builds.
    let need = p.bytes_for(n as u64) as usize;
    assert!(
        buf.len() >= need,
        "unpacking {n} {p} elements needs {need} B, buffer holds {} B",
        buf.len()
    );
    out.clear();
    out.reserve(n);
    match p {
        Precision::Int16 => {
            out.extend(
                buf.chunks_exact(2).take(n).map(|c| i16::from_le_bytes([c[0], c[1]]) as i32),
            );
        }
        Precision::Int8 => {
            out.extend(buf[..n].iter().map(|&b| b as i8 as i32));
        }
        Precision::Int4 => {
            // Two operands per byte, low nibble first; sign-extend via
            // shift pairs (bits [3:0] and [7:4] moved to the top, then
            // arithmetic-shifted back down).
            for &b in &buf[..n / 2] {
                out.push(((b as i32) << 28) >> 28);
                out.push(((b as i32) << 24) >> 28);
            }
            if n % 2 == 1 {
                let b = buf[n / 2];
                out.push(((b as i32) << 28) >> 28);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_precisions() {
        for p in Precision::ALL {
            let (lo, hi) = p.range();
            let vals: Vec<i32> = vec![lo, hi, 0, 1, -1, lo / 2, hi / 2, 3];
            let buf = pack(&vals, p);
            assert_eq!(unpack(&buf, vals.len(), p), vals, "{p}");
        }
    }

    #[test]
    fn nibble_layout_low_first() {
        let buf = pack(&[1, -2], Precision::Int4);
        assert_eq!(buf.len(), 1);
        assert_eq!(buf[0] & 0x0F, 0x1);
        assert_eq!(buf[0] >> 4, 0xE); // -2 as nibble
    }

    #[test]
    fn i32_roundtrip() {
        let mut buf = vec![0u8; 8];
        write_i32(&mut buf, 0, -123456);
        write_i32(&mut buf, 1, i32::MAX);
        assert_eq!(read_i32(&buf, 0), -123456);
        assert_eq!(read_i32(&buf, 1), i32::MAX);
    }

    #[test]
    fn unpack_into_matches_per_element_reads() {
        // The bulk unpack must agree with read_elem for every precision,
        // count parity, and value pattern (including sign extremes).
        for p in Precision::ALL {
            let (lo, hi) = p.range();
            for n in [1usize, 2, 3, 7, 8, 33] {
                let vals: Vec<i32> =
                    (0..n).map(|i| [lo, hi, 0, -1, 1, lo / 3][i % 6]).collect();
                let buf = pack(&vals, p);
                let mut out = Vec::new();
                unpack_into(&buf, n, p, &mut out);
                let want: Vec<i32> = (0..n).map(|i| read_elem(&buf, i, p)).collect();
                assert_eq!(out, want, "{p} n={n}");
                assert_eq!(out, vals, "{p} n={n}");
            }
        }
    }

    #[test]
    fn try_pack_rejects_out_of_range() {
        let err = try_pack(&[1, 200, 3], Precision::Int8).unwrap_err();
        assert!(matches!(err, SpeedError::Config(_)), "{err}");
        assert!(err.to_string().contains("200"), "{err}");
        assert!(try_pack(&[127, -128], Precision::Int8).is_ok());
    }

    #[test]
    #[should_panic(expected = "outside the INT4 range")]
    fn pack_panics_on_out_of_range() {
        pack(&[9], Precision::Int4);
    }

    #[test]
    #[should_panic(expected = "buffer holds")]
    fn unpack_into_rejects_short_buffer() {
        let mut out = Vec::new();
        unpack_into(&[0u8; 2], 3, Precision::Int16, &mut out);
    }

    #[test]
    fn odd_nibble_count_fits() {
        let buf = pack(&[7, -8, 3], Precision::Int4);
        assert_eq!(buf.len(), 2);
        assert_eq!(unpack(&buf, 3, Precision::Int4), vec![7, -8, 3]);
    }
}
