//! External (off-chip) memory model with byte-accurate traffic accounting.
//!
//! External-memory access size is the key energy/efficiency metric of the
//! paper's Fig. 10; every read and write through this model is counted, and
//! the breakdown (inputs / weights / partial sums / outputs) is preserved so
//! the report harness can regenerate the figure's per-strategy bars.

use crate::config::Precision;

use super::elem;

/// What a transfer moves — used for the Fig. 10 traffic breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Input activations.
    Input,
    /// Weights / filters.
    Weight,
    /// Partial sums spilled and refetched.
    Partial,
    /// Final outputs.
    Output,
}

/// Byte counters per traffic class.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TrafficStats {
    /// Bytes of input activations read.
    pub input_read: u64,
    /// Bytes of weights read.
    pub weight_read: u64,
    /// Bytes of partial sums read back.
    pub partial_read: u64,
    /// Bytes of partial sums written out.
    pub partial_write: u64,
    /// Bytes of final outputs written.
    pub output_write: u64,
}

impl TrafficStats {
    /// Total bytes moved over the external-memory interface.
    pub fn total(&self) -> u64 {
        self.input_read + self.weight_read + self.partial_read + self.partial_write
            + self.output_write
    }

    /// Total bytes read (inputs + weights + partial sums).
    pub fn reads(&self) -> u64 {
        self.input_read + self.weight_read + self.partial_read
    }

    /// Total bytes written (partial sums + outputs).
    pub fn writes(&self) -> u64 {
        self.partial_write + self.output_write
    }

    /// Count `bytes` read under `class`.
    pub fn add_read(&mut self, class: TrafficClass, bytes: u64) {
        match class {
            TrafficClass::Input => self.input_read += bytes,
            TrafficClass::Weight => self.weight_read += bytes,
            TrafficClass::Partial => self.partial_read += bytes,
            TrafficClass::Output => self.partial_read += bytes, // outputs are not re-read
        }
    }

    /// Count `bytes` written under `class`.
    pub fn add_write(&mut self, class: TrafficClass, bytes: u64) {
        match class {
            TrafficClass::Partial => self.partial_write += bytes,
            _ => self.output_write += bytes,
        }
    }
}

/// Flat external memory with traffic accounting.
pub struct ExtMem {
    data: Vec<u8>,
    /// Accumulated byte traffic by class.
    pub traffic: TrafficStats,
}

impl ExtMem {
    /// Allocate `bytes` of zeroed external memory.
    pub fn new(bytes: usize) -> Self {
        ExtMem { data: vec![0; bytes], traffic: TrafficStats::default() }
    }

    /// Current memory size in bytes.
    pub fn size(&self) -> usize {
        self.data.len()
    }

    /// Grow the memory to at least `bytes` (contents and traffic counters
    /// are preserved; shrinking is never performed — live layouts assume
    /// their regions stay mapped).
    pub fn grow(&mut self, bytes: usize) {
        if bytes > self.data.len() {
            self.data.resize(bytes, 0);
        }
    }

    /// Counted read of a byte range.
    pub fn read(&mut self, addr: u64, len: usize, class: TrafficClass) -> &[u8] {
        self.traffic.add_read(class, len as u64);
        &self.data[addr as usize..addr as usize + len]
    }

    /// Counted write of a byte slice.
    pub fn write(&mut self, addr: u64, bytes: &[u8], class: TrafficClass) {
        self.traffic.add_write(class, bytes.len() as u64);
        self.data[addr as usize..addr as usize + bytes.len()].copy_from_slice(bytes);
    }

    /// Uncounted initialization (test-bench preload, not DUT traffic).
    pub fn preload(&mut self, addr: u64, bytes: &[u8]) {
        self.data[addr as usize..addr as usize + bytes.len()].copy_from_slice(bytes);
    }

    /// Uncounted inspection (test-bench readback, not DUT traffic).
    pub fn inspect(&self, addr: u64, len: usize) -> &[u8] {
        &self.data[addr as usize..addr as usize + len]
    }

    /// Preload packed operand values at a precision.
    pub fn preload_packed(&mut self, addr: u64, values: &[i32], p: Precision) {
        let buf = elem::pack(values, p);
        self.preload(addr, &buf);
    }

    /// Inspect `n` i32 accumulators at `addr` (test-bench readback).
    pub fn inspect_i32(&self, addr: u64, n: usize) -> Vec<i32> {
        let buf = self.inspect(addr, 4 * n);
        (0..n).map(|i| elem::read_i32(buf, i)).collect()
    }

    /// Reset traffic counters (e.g. between operators).
    pub fn reset_traffic(&mut self) {
        self.traffic = TrafficStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_is_counted_by_class() {
        let mut m = ExtMem::new(1024);
        m.preload(0, &[1, 2, 3, 4]); // uncounted
        let _ = m.read(0, 4, TrafficClass::Input);
        let _ = m.read(0, 2, TrafficClass::Weight);
        m.write(8, &[9; 8], TrafficClass::Output);
        m.write(16, &[7; 4], TrafficClass::Partial);
        let _ = m.read(16, 4, TrafficClass::Partial);
        assert_eq!(m.traffic.input_read, 4);
        assert_eq!(m.traffic.weight_read, 2);
        assert_eq!(m.traffic.output_write, 8);
        assert_eq!(m.traffic.partial_write, 4);
        assert_eq!(m.traffic.partial_read, 4);
        assert_eq!(m.traffic.total(), 22);
    }

    #[test]
    fn packed_preload_roundtrip() {
        let mut m = ExtMem::new(64);
        m.preload_packed(0, &[1, -2, 3], Precision::Int4);
        let buf = m.inspect(0, 2).to_vec();
        assert_eq!(elem::unpack(&buf, 3, Precision::Int4), vec![1, -2, 3]);
    }

    #[test]
    fn inspect_does_not_count() {
        let mut m = ExtMem::new(16);
        m.preload(0, &[5; 16]);
        let _ = m.inspect(0, 16);
        let _ = m.inspect_i32(0, 2);
        assert_eq!(m.traffic.total(), 0);
    }
}
