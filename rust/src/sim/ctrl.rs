//! Architectural control state driven by configuration instructions.
//!
//! `VSACFG` latches precision / kernel size / strategy into the VIDU's
//! internal `rd` register within a single cycle (Sec. II-E), enabling the
//! paper's runtime precision reconfigurability; `VSACFG.DIM` latches the
//! operator dimensions; `VSETVLI` sets the application vector length.

use crate::config::Precision;
use crate::isa::{Dim, Insn, StrategyKind, Vtype};

/// Operator dimensions latched via `VSACFG.DIM`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Dims {
    /// MM rows of `A`.
    pub m: u32,
    /// MM inner dimension.
    pub k: u32,
    /// MM columns of `B`.
    pub n: u32,
    /// Input channels.
    pub c: u32,
    /// Output channels.
    pub f: u32,
    /// Input height.
    pub h: u32,
    /// Input width.
    pub w: u32,
    /// Convolution stride.
    pub stride: u32,
    /// Pipeline stages of the current burst.
    pub nstages: u32,
}

impl Dims {
    /// Latch dimension `dim` to `v`.
    pub fn set(&mut self, dim: Dim, v: u32) {
        match dim {
            Dim::M => self.m = v,
            Dim::K => self.k = v,
            Dim::N => self.n = v,
            Dim::C => self.c = v,
            Dim::F => self.f = v,
            Dim::H => self.h = v,
            Dim::W => self.w = v,
            Dim::Stride => self.stride = v,
            Dim::NStages => self.nstages = v,
        }
    }

    /// Read back a latched dimension.
    pub fn get(&self, dim: Dim) -> u32 {
        match dim {
            Dim::M => self.m,
            Dim::K => self.k,
            Dim::N => self.n,
            Dim::C => self.c,
            Dim::F => self.f,
            Dim::H => self.h,
            Dim::W => self.w,
            Dim::Stride => self.stride,
            Dim::NStages => self.nstages,
        }
    }
}

/// The full control state visible to the functional units.
#[derive(Debug, Clone, Copy)]
pub struct CtrlState {
    /// Active operand precision (from `VSACFG`).
    pub prec: Precision,
    /// Convolution kernel size (1–15; larger kernels are Kseg-decomposed).
    pub ksize: u32,
    /// Active dataflow strategy.
    pub strat: StrategyKind,
    /// Application vector length (elements), from `VSETVLI`.
    pub vl: u32,
    /// Selected element width from `VSETVLI` (bits).
    pub sew: u32,
    /// Operator dimensions.
    pub dims: Dims,
    /// Count of precision switches (each costs one `VSACFG`, Sec. II-E).
    pub precision_switches: u64,
}

impl Default for CtrlState {
    fn default() -> Self {
        CtrlState {
            prec: Precision::Int8,
            ksize: 1,
            strat: StrategyKind::Mm,
            vl: 0,
            sew: 8,
            dims: Dims::default(),
            precision_switches: 0,
        }
    }
}

impl CtrlState {
    /// Apply a configuration instruction; returns true if it was one.
    pub fn apply(&mut self, insn: &Insn, xreg: impl Fn(u8) -> i64) -> bool {
        match *insn {
            Insn::Vsacfg { zimm, .. } => {
                if let Some((prec, ksize, strat)) = Insn::unpack_cfg(zimm) {
                    if prec != self.prec {
                        self.precision_switches += 1;
                    }
                    self.prec = prec;
                    if ksize > 0 {
                        self.ksize = ksize;
                    }
                    self.strat = strat;
                }
                true
            }
            Insn::VsacfgDim { rs1, dim, .. } => {
                self.dims.set(dim, xreg(rs1) as u32);
                true
            }
            Insn::Vsetvli { rs1, vtype, .. } => {
                let Vtype { sew } = vtype;
                self.sew = sew;
                let req = xreg(rs1) as u32;
                if rs1 != 0 {
                    self.vl = req;
                }
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vsacfg_switches_precision_and_counts() {
        let mut c = CtrlState::default();
        let z16 = Insn::pack_cfg(Precision::Int16, 3, StrategyKind::Ffcs);
        let z8 = Insn::pack_cfg(Precision::Int8, 3, StrategyKind::Ffcs);
        assert!(c.apply(&Insn::Vsacfg { rd: 1, zimm: z16, uimm: 0 }, |_| 0));
        assert_eq!(c.prec, Precision::Int16);
        assert_eq!(c.strat, StrategyKind::Ffcs);
        assert_eq!(c.ksize, 3);
        assert_eq!(c.precision_switches, 1);
        // Same precision again — no switch counted.
        assert!(c.apply(&Insn::Vsacfg { rd: 1, zimm: z16, uimm: 0 }, |_| 0));
        assert_eq!(c.precision_switches, 1);
        assert!(c.apply(&Insn::Vsacfg { rd: 1, zimm: z8, uimm: 0 }, |_| 0));
        assert_eq!(c.precision_switches, 2);
    }

    #[test]
    fn dims_latch_from_scalar_regs() {
        let mut c = CtrlState::default();
        let regs = |r: u8| if r == 5 { 128 } else { 0 };
        c.apply(&Insn::VsacfgDim { rd: 0, rs1: 5, dim: Dim::K }, regs);
        assert_eq!(c.dims.k, 128);
        assert_eq!(c.dims.get(Dim::K), 128);
    }

    #[test]
    fn vsetvli_sets_vl_and_sew() {
        let mut c = CtrlState::default();
        c.apply(
            &Insn::Vsetvli { rd: 0, rs1: 3, vtype: Vtype::new(16) },
            |r| if r == 3 { 64 } else { 0 },
        );
        assert_eq!(c.vl, 64);
        assert_eq!(c.sew, 16);
        // rs1 = x0 keeps vl.
        c.apply(&Insn::Vsetvli { rd: 0, rs1: 0, vtype: Vtype::new(8) }, |_| 0);
        assert_eq!(c.vl, 64);
        assert_eq!(c.sew, 8);
    }

    #[test]
    fn non_cfg_insns_ignored() {
        let mut c = CtrlState::default();
        assert!(!c.apply(&Insn::Vmacc { vd: 0, vs1: 1, vs2: 2 }, |_| 0));
    }
}
