//! Operator plans: the contract between the operator compiler and the MPTU.
//!
//! A plan corresponds to what the hardware derives from the `VSACFG` /
//! `VSACFG.DIM` configuration: the operator geometry, the DRAM placement of
//! its tensors, and the total number of dataflow stages the `VSAM`/`VSAC`
//! instructions will walk. The operand requester's address generation is a
//! deterministic function of this state — the simulator walks it the same
//! way the RTL would.

use crate::isa::StrategyKind;
use crate::models::ops::OpDesc;

/// DRAM placement + schedule extent for one operator execution.
#[derive(Debug, Clone, Copy)]
pub struct OpPlan {
    /// The operator being executed.
    pub desc: OpDesc,
    /// Strategy actually used (may differ from `desc.preferred_strategy()`
    /// in ablation runs, e.g. Fig. 10/11 evaluate all strategies per op).
    pub strat: StrategyKind,
    /// DRAM base of the input tensor (precision-packed).
    pub in_addr: u64,
    /// DRAM base of the weight tensor (precision-packed).
    pub w_addr: u64,
    /// DRAM base of the output tensor (int32 accumulators).
    pub out_addr: u64,
    /// DRAM base of the partial-sum spill region (used only when the
    /// schedule spills partials off-chip; `u64::MAX` = no spill region).
    pub partial_addr: u64,
    /// Total dataflow stages the full operator needs (from the mapper).
    pub total_stages: u64,
    /// Whether the functional engine computes real numerics (golden-checked
    /// runs) or only timing/traffic are simulated (large sweeps).
    pub functional: bool,
}

impl OpPlan {
    /// Is `addr` inside the partial-sum spill region?
    pub fn is_partial_addr(&self, addr: u64) -> bool {
        self.partial_addr != u64::MAX && addr >= self.partial_addr
    }
}
