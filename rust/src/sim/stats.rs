//! Simulation statistics: cycles, utilization, stalls, instruction mix.

use super::memory::TrafficStats;

/// Functional-unit identifiers for occupancy accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fu {
    /// Vector load unit (VLE / VSALD).
    Vldu,
    /// Vector store unit (VSE).
    Vsu,
    /// Multi-precision tensor unit (VSAM / VSAC).
    Mptu,
    /// Vector ALU (VMACC / VMUL / VADD / VMV).
    Valu,
    /// Scalar core + config path (ADDI / VSETVLI / VSACFG).
    Scalar,
}

impl Fu {
    /// All functional units, in [`Fu::index`] order.
    pub const ALL: [Fu; 5] = [Fu::Vldu, Fu::Vsu, Fu::Mptu, Fu::Valu, Fu::Scalar];

    /// Position in per-FU stat arrays.
    pub fn index(self) -> usize {
        match self {
            Fu::Vldu => 0,
            Fu::Vsu => 1,
            Fu::Mptu => 2,
            Fu::Valu => 3,
            Fu::Scalar => 4,
        }
    }

    /// Display name of the unit.
    pub fn name(self) -> &'static str {
        match self {
            Fu::Vldu => "VLDU",
            Fu::Vsu => "VSU",
            Fu::Mptu => "MPTU",
            Fu::Valu => "VALU",
            Fu::Scalar => "SCALAR",
        }
    }
}

/// Aggregate statistics of one simulation run.
///
/// `PartialEq`/`Eq` support the fast-path parity contract: batch-mode
/// execution must produce a bit-identical `SimStats` to exact mode.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct SimStats {
    /// Total cycles from first decode to last retire.
    pub cycles: u64,
    /// Instructions decoded, by class.
    pub insns_total: u64,
    /// Custom (VSACFG/VSALD/VSAM/VSAC) instructions decoded.
    pub insns_custom: u64,
    /// Official RVV instructions decoded.
    pub insns_vector: u64,
    /// Scalar instructions decoded.
    pub insns_scalar: u64,
    /// Per-FU busy cycles.
    pub fu_busy: [u64; 5],
    /// Issue stalls: cycles lost waiting on a busy FU.
    pub stall_fu_busy: u64,
    /// Issue stalls: cycles lost on register hazards (RAW/WAW/WAR).
    pub stall_hazard: u64,
    /// Issue stalls: cycles lost on the shared external-memory port.
    pub stall_mem_port: u64,
    /// MACs actually performed by the MPTU.
    pub macs: u64,
    /// MAC slots available while the MPTU was busy (utilization denom).
    pub mac_slots: u64,
    /// Peak number of distinct vector registers concurrently live.
    pub vregs_used: u32,
    /// External-memory traffic (byte-accurate, by class).
    pub traffic: TrafficStats,
    /// Precision switches performed (VSACFG with a new precision).
    pub precision_switches: u64,
}

impl SimStats {
    /// Effective performance in ops/cycle (1 MAC = 2 ops) — the paper's
    /// primary operator-level metric (Fig. 11).
    pub fn ops_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        (2 * self.macs) as f64 / self.cycles as f64
    }

    /// MPTU utilization: MACs performed / MAC slots offered while busy.
    pub fn mptu_utilization(&self) -> f64 {
        if self.mac_slots == 0 {
            return 0.0;
        }
        self.macs as f64 / self.mac_slots as f64
    }

    /// FU occupancy fraction over the whole run.
    pub fn fu_occupancy(&self, fu: Fu) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.fu_busy[fu.index()] as f64 / self.cycles as f64
    }

    /// Throughput in GOPS at a clock frequency.
    pub fn gops(&self, freq_ghz: f64) -> f64 {
        self.ops_per_cycle() * freq_ghz
    }

    /// Merge another run's stats (sequential composition, e.g. layers of a
    /// network).
    pub fn merge(&mut self, other: &SimStats) {
        self.cycles += other.cycles;
        self.insns_total += other.insns_total;
        self.insns_custom += other.insns_custom;
        self.insns_vector += other.insns_vector;
        self.insns_scalar += other.insns_scalar;
        for i in 0..self.fu_busy.len() {
            self.fu_busy[i] += other.fu_busy[i];
        }
        self.stall_fu_busy += other.stall_fu_busy;
        self.stall_hazard += other.stall_hazard;
        self.stall_mem_port += other.stall_mem_port;
        self.macs += other.macs;
        self.mac_slots += other.mac_slots;
        self.vregs_used = self.vregs_used.max(other.vregs_used);
        self.precision_switches += other.precision_switches;
        let t = &mut self.traffic;
        let o = &other.traffic;
        t.input_read += o.input_read;
        t.weight_read += o.weight_read;
        t.partial_read += o.partial_read;
        t.partial_write += o.partial_write;
        t.output_write += o.output_write;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_per_cycle() {
        let s = SimStats { cycles: 100, macs: 400, ..Default::default() };
        assert!((s.ops_per_cycle() - 8.0).abs() < 1e-12);
        assert!((s.gops(1.05) - 8.4).abs() < 1e-12);
    }

    #[test]
    fn zero_cycles_is_zero_not_nan() {
        let s = SimStats::default();
        assert_eq!(s.ops_per_cycle(), 0.0);
        assert_eq!(s.mptu_utilization(), 0.0);
        assert_eq!(s.fu_occupancy(Fu::Mptu), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = SimStats { cycles: 10, macs: 5, vregs_used: 4, ..Default::default() };
        let b = SimStats { cycles: 7, macs: 3, vregs_used: 9, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.cycles, 17);
        assert_eq!(a.macs, 8);
        assert_eq!(a.vregs_used, 9);
    }
}
