//! Cycle-level simulator of the SPEED microarchitecture (Sec. II).
//!
//! Structure mirrors Fig. 3: the VIDU/VIS front-end and hazard tracking,
//! the VLDU's sequential/broadcast transfers, per-lane VRFs, and the MPTU
//! tensor core live in [`processor`]; the golden arithmetic in [`mptu`];
//! external memory with byte-accurate traffic accounting in [`memory`].

pub mod ctrl;
pub mod elem;
pub mod memory;
pub mod mptu;
pub mod plan;
pub mod processor;
pub mod stats;

pub use ctrl::{CtrlState, Dims};
pub use memory::{ExtMem, TrafficClass, TrafficStats};
pub use mptu::OutputRows;
pub use plan::OpPlan;
pub use processor::{ExecMode, Processor, SimError};
pub use stats::{Fu, SimStats};
