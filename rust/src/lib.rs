//! # speed-rvv — full-system reproduction of SPEED (TVLSI 2024)
//!
//! SPEED is a scalable RISC-V vector (RVV) processor for multi-precision
//! (4/8/16-bit) DNN inference. This crate reproduces the complete system as
//! described in the paper, substituting the paper's RTL + QuestaSim + TSMC
//! 28 nm flow with:
//!
//! * a **cycle-level microarchitectural simulator** ([`sim`]) of the SPEED
//!   pipeline — VIDU, VIS, VLDU, lanes with banked VRFs, and the
//!   multi-precision tensor unit (MPTU);
//! * an **Ara baseline model** ([`ara`]) executing official-RVV instruction
//!   schedules with Ara's published pipeline behaviour;
//! * the four **customized instructions** (VSACFG, VSALD, VSAM, VSAC) plus
//!   the official RVV subset, with a full assembler/disassembler ([`isa`]);
//! * the **mixed dataflow mapping** (MM, FFCS, CF, FF) and the operator
//!   compiler that lowers DNN layers to instruction streams ([`dataflow`],
//!   [`compiler`]);
//! * **analytical area/power models** calibrated to the paper's synthesis
//!   results, with the technology-projection rules of Table III
//!   ([`metrics`]);
//! * a **PJRT runtime** ([`runtime`]) that loads the JAX/Pallas-lowered HLO
//!   artifacts (the golden numerics of the machine) and cross-checks the
//!   simulator's functional output — Python never runs on the request path;
//! * the **inference coordinator** ([`coordinator`]) scheduling whole
//!   networks with runtime precision switching and per-operator strategy
//!   selection;
//! * a **report harness** ([`report`]) regenerating every table and figure
//!   of the paper's evaluation (Fig. 2, Fig. 10–14, Tables I–III).
//!
//! See `DESIGN.md` for the substitution rationale and the experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod ara;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod dse;
pub mod isa;
pub mod metrics;
pub mod models;
pub mod report;
pub mod runtime;
pub mod sim;

pub use config::{Precision, SpeedConfig};
