//! # speed-rvv — full-system reproduction of SPEED (TVLSI 2024)
//!
//! SPEED is a scalable RISC-V vector (RVV) processor for multi-precision
//! (4/8/16-bit) DNN inference. This crate reproduces the complete system as
//! described in the paper, substituting the paper's RTL + QuestaSim + TSMC
//! 28 nm flow with a cycle-level simulator and analytical models.
//!
//! ## Primary API: [`engine`]
//!
//! The crate's execution surface is the compile-once / execute-many
//! [`Engine`]/[`Session`] pair:
//!
//! ```no_run
//! use speed_rvv::{Engine, Precision, SpeedConfig};
//! use speed_rvv::models::zoo::model_by_name;
//!
//! # fn main() -> Result<(), speed_rvv::SpeedError> {
//! let cfg = SpeedConfig::builder().lanes(4).tile(2, 2).build()?;
//! let mut engine = Engine::new(cfg)?;          // warm processor + program cache
//! let model = model_by_name("mobilenetv2").unwrap();
//! let mut session = engine.session();
//! let r8 = session.run_model(&model, Precision::Int8)?;   // compiles each layer once
//! let r4 = session.run_model(&model, Precision::Int4)?;   // single-cycle VSACFG switch
//! let again = session.run_model(&model, Precision::Int8)?; // zero recompilation
//! # let _ = (r8, r4, again);
//! assert_eq!(engine.cache_stats().misses, engine.compiled_programs() as u64);
//! # Ok(())
//! # }
//! ```
//!
//! An [`Engine`] owns a warm [`sim::Processor`] plus a program cache keyed
//! on `(operator, strategy, precision, configuration)`; a [`Session`] runs
//! whole models or single operators against it, returning per-layer and
//! aggregate [`sim::SimStats`]. Every fallible path in the crate returns a
//! typed [`SpeedError`] ([`error`]).
//!
//! ## Subsystems
//!
//! * a **cycle-level microarchitectural simulator** ([`sim`]) of the SPEED
//!   pipeline — VIDU, VIS, VLDU, lanes with banked VRFs, and the
//!   multi-precision tensor unit (MPTU);
//! * an **Ara baseline model** ([`ara`]) executing official-RVV instruction
//!   schedules with Ara's published pipeline behaviour;
//! * the four **customized instructions** (VSACFG, VSALD, VSAM, VSAC) plus
//!   the official RVV subset, with a full assembler/disassembler ([`isa`]);
//! * the **mixed dataflow mapping** (MM, FFCS, CF, FF) and the operator
//!   compiler that lowers DNN layers to instruction streams ([`dataflow`],
//!   [`compiler`]);
//! * **analytical area/power models** calibrated to the paper's synthesis
//!   results, with the technology-projection rules of Table III
//!   ([`metrics`]);
//! * a **PJRT runtime** ([`runtime`]) that loads the JAX/Pallas-lowered HLO
//!   artifacts (the golden numerics of the machine) and cross-checks the
//!   simulator's functional output — Python never runs on the request path;
//! * the **inference coordinator** ([`coordinator`]): one-shot wrappers,
//!   strategy policies, and the thread-based sweep runner;
//! * a **report harness** ([`report`]) regenerating every table and figure
//!   of the paper's evaluation (Fig. 2, Fig. 10–14, Tables I–III);
//! * a **perf harness** ([`bench`], CLI `speed-bench`) measuring the
//!   simulator's own throughput (ops/s, simulated-stages/s, cache hit
//!   rates) into a machine-readable `BENCH_sim.json`, gated in CI against
//!   `bench/baseline.json`;
//! * a **multi-tenant serving subsystem** ([`serve`], CLI `serve-bench`):
//!   a [`serve::ServePool`] of warm engines behind a bounded queue with
//!   backpressure, precision-affinity scheduling with work stealing,
//!   dynamic micro-batching of identical requests, JSON scenario files
//!   (`bench/scenarios/`), and a deterministic per-request statistics
//!   contract (`SERVE_bench.json`);
//! * an **empirical mixed-dataflow auto-tuner** ([`tune`], CLI `tune`):
//!   per-operator `(strategy × chunk)` search with the fast-path
//!   simulator as the cost oracle, semantics-preserving by construction
//!   (bit-identical outputs, enforced by parity tests), persisted as
//!   JSON plans (`bench/tuned/`) and served pool-wide through a
//!   [`tune::TunedPlans`] registry
//!   ([`coordinator::Policy::Tuned`]).
//!
//! * a **static program verifier** ([`analysis`], CLI `verify`): an
//!   abstract interpreter over compiled instruction streams that proves
//!   configuration, dataflow, memory-safety, fast-path, and residency
//!   invariants *before* a program reaches the simulator, with stable
//!   rule IDs (`V-CFG-*`, `V-REG-*`, `V-MEM-*`, `V-RUN-*`, `V-RES-*`)
//!   surfaced as [`SpeedError::Verify`] diagnostics;
//!
//! * a **static cost model and performance linter** ([`analysis::cost`],
//!   [`analysis::lint`], CLI `lint`): [`analysis::cost::cost_op`] replays
//!   the simulator's scoreboard recurrence to predict `SimStats` and the
//!   cycle breakdown of a compiled stream *bit-identically* to execution
//!   (it is what lets `tune --prune` skip simulations while producing a
//!   byte-identical plan), while [`analysis::lint`] flags legal-but-
//!   wasteful streams (`L-DEAD-01` … `L-VRF-01`) as warnings that never
//!   fold into errors — the severity contract with the verifier;
//!
//! * an **observability layer** ([`obs`], CLI `profile`): deterministic
//!   hierarchical tracing on a virtual (simulated-cycle) clock exported
//!   as Chrome-trace JSON, an exact cycle-attribution profiler
//!   ([`obs::CycleBreakdown`] — components sum to `SimStats::cycles` to
//!   the cycle), and a unified [`obs::Counters`] registry spanning
//!   engine, scheduler, tuner, and verifier — all inert by contract:
//!   attaching a tracer never changes simulated results or digests.
//!
//! See `DESIGN.md` for the substitution rationale and the experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod analysis;
pub mod ara;
pub mod bench;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod dse;
pub mod engine;
pub mod error;
pub mod isa;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tune;

pub use config::{Precision, SpeedConfig, SpeedConfigBuilder};
pub use engine::{CacheStats, Engine, Session, SharedPrograms};
pub use error::SpeedError;
pub use obs::{Counters, CycleBreakdown, ObsConfig, TraceLevel, Tracer};
pub use serve::{ServePool, Ticket};
pub use sim::ExecMode;
pub use tune::{TunedPlan, TunedPlans};
