//! Fig. 11 — performance (ops/cycle) of SPEED's strategies vs Ara across
//! input tensor sizes, 16-bit precision.
//!
//! Paper ranges (SPEED best strategy over Ara): PWCV 5.21–88.56×,
//! DWCV3×3 1.06–11.27×, CONV3×3 1.38–15.29×, CONV5×5 1.21–22.94× — with
//! Ara collapsing on small tensors while SPEED stays flat.

use crate::ara::{ara_cost, AraParams};
use crate::compiler::{execute_op, MemLayout};
use crate::config::{Precision, SpeedConfig};
use crate::dataflow::feasible;
use crate::isa::StrategyKind;
use crate::models::{OpDesc, OpKind};
use crate::sim::Processor;

/// One point of the Fig. 11 sweep.
#[derive(Debug, Clone)]
pub struct Fig11Point {
    /// Operator label (e.g. "CONV3x3").
    pub operator: &'static str,
    /// Feature-map size of the point.
    pub fmap: u32,
    /// Strategy SPEED ran the operator under.
    pub strat: StrategyKind,
    /// SPEED MAC-ops per cycle.
    pub speed_ops_per_cycle: f64,
    /// Ara MAC-ops per cycle.
    pub ara_ops_per_cycle: f64,
}

impl Fig11Point {
    /// SPEED over Ara throughput.
    pub fn speedup(&self) -> f64 {
        self.speed_ops_per_cycle / self.ara_ops_per_cycle
    }
}

fn op_at(kind: OpKind, fmap: u32) -> OpDesc {
    let p = Precision::Int16;
    match kind {
        OpKind::Pwcv => OpDesc::pwcv(64, 64, fmap, fmap, p),
        OpKind::Conv => OpDesc::conv(32, 32, fmap, fmap, 3, 1, 1, p),
        OpKind::Dwcv => OpDesc::dwcv(32, fmap.max(3), fmap.max(3), 3, 2, 1, p),
        OpKind::Mm => OpDesc::mm(fmap, fmap, fmap, p),
    }
}

fn conv5_at(fmap: u32) -> OpDesc {
    OpDesc::conv(32, 32, fmap.max(5), fmap.max(5), 5, 1, 2, Precision::Int16)
}

/// Evaluate one (operator, size, strategy).
pub fn eval(op: &OpDesc, cfg: &SpeedConfig, strat: StrategyKind) -> f64 {
    let mut p = Processor::new(*cfg, 1 << 26);
    let layout = MemLayout::for_op(op, 1 << 26).unwrap();
    let (stats, _) = execute_op(&mut p, op, strat, layout, false).unwrap();
    stats.ops_per_cycle()
}

/// The full sweep: operators × feature-map sizes × applicable strategies.
pub fn fig11_data(cfg: &SpeedConfig, sizes: &[u32]) -> Vec<Fig11Point> {
    let params = AraParams::default();
    let mut out = Vec::new();
    let mut cases: Vec<(&'static str, OpDesc)> = Vec::new();
    for &s in sizes {
        cases.push(("PWCV", op_at(OpKind::Pwcv, s)));
        cases.push(("CONV3x3", op_at(OpKind::Conv, s)));
        cases.push(("DWCV3x3(s=2)", op_at(OpKind::Dwcv, s)));
        cases.push(("CONV5x5", conv5_at(s)));
    }
    for (name, op) in cases {
        let ara = ara_cost(&op, &params).ops_per_cycle(&op);
        for strat in [StrategyKind::Ffcs, StrategyKind::Cf, StrategyKind::Ff] {
            if !feasible(strat, &op, cfg) {
                continue;
            }
            out.push(Fig11Point {
                operator: name,
                fmap: op.h,
                strat,
                speed_ops_per_cycle: eval(&op, cfg, strat),
                ara_ops_per_cycle: ara,
            });
        }
    }
    out
}

/// Default sizes for the sweep (paper sweeps "various input tensor sizes").
pub const DEFAULT_SIZES: [u32; 4] = [8, 16, 32, 64];

/// Text report.
pub fn fig11(cfg: &SpeedConfig, sizes: &[u32]) -> String {
    let pts = fig11_data(cfg, sizes);
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.operator.to_string(),
                format!("{}x{}", p.fmap, p.fmap),
                p.strat.to_string().to_uppercase(),
                format!("{:.2}", p.speed_ops_per_cycle),
                format!("{:.2}", p.ara_ops_per_cycle),
                format!("{:.2}x", p.speedup()),
            ]
        })
        .collect();
    let mut out = String::from("Fig. 11 — performance vs Ara across tensor sizes (16-bit)\n");
    out.push_str(&super::render_table(
        &["operator", "fmap", "strategy", "SPEED ops/cyc", "Ara ops/cyc", "speedup"],
        &rows,
    ));
    out.push_str(
        "\npaper speedups (best strategy): PWCV 5.21-88.56x, DWCV3x3 1.06-11.27x,\n\
         CONV3x3 1.38-15.29x, CONV5x5 1.21-22.94x; Ara collapses on small tensors\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_shape_holds() {
        let cfg = SpeedConfig::reference();
        let pts = fig11_data(&cfg, &[8, 32]);
        // Best SPEED strategy beats Ara on every operator/size.
        for opname in ["PWCV", "CONV3x3", "DWCV3x3(s=2)", "CONV5x5"] {
            for &s in &[8u32, 32] {
                let best = pts
                    .iter()
                    .filter(|p| p.operator == opname && p.fmap >= s && p.fmap <= s.max(5))
                    .map(|p| p.speedup())
                    .fold(0.0f64, f64::max);
                assert!(best > 1.0, "{opname}@{s}: best speedup {best}");
            }
        }
        // Ara collapse: the PWCV speedup grows as tensors shrink.
        let su = |s: u32| {
            pts.iter()
                .filter(|p| p.operator == "PWCV" && p.fmap == s)
                .map(|p| p.speedup())
                .fold(0.0f64, f64::max)
        };
        assert!(su(8) > su(32), "small {} !> large {}", su(8), su(32));
    }

    #[test]
    fn cf_wins_pwcv_performance() {
        let cfg = SpeedConfig::reference();
        let pts = fig11_data(&cfg, &[16]);
        let get = |s: StrategyKind| {
            pts.iter()
                .find(|p| p.operator == "PWCV" && p.strat == s)
                .unwrap()
                .speed_ops_per_cycle
        };
        assert!(get(StrategyKind::Cf) > get(StrategyKind::Ffcs));
    }
}
