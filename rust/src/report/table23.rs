//! Table II — synthesis comparison vs Ara; Table III — comparison with
//! state-of-the-art RISC-V DNN processors under the 28 nm projection.

use crate::compiler::{execute_op, MemLayout};
use crate::config::{Precision, SpeedConfig};
use crate::isa::StrategyKind;
use crate::metrics::{lane_area, speed_area, speed_power, ReportedMetrics};
use crate::models::OpDesc;
use crate::sim::Processor;

/// Table II text report. Paper: SPEED lane 1.08 mm² / 71 mW at 28 nm
/// 1.05 GHz; Ara lane 1.20 mm² / 229 mW at 22 nm, projected to 1.94 mm² /
/// 229 mW at 0.825 GHz — a 45 % area and 69 % power reduction.
pub fn table2() -> String {
    let cfg = SpeedConfig::reference();
    let speed_lane = lane_area(&cfg).total();
    let speed_power_w = crate::metrics::lane_power(&cfg);
    let ara22 = ReportedMetrics {
        node_nm: 22.0,
        freq_ghz: 1.05,
        area_mm2: 1.20,
        power_w: 0.229,
        gops: 0.0,
    };
    let ara28 = ara22.project(28.0);
    let rows = vec![
        vec!["technology [nm]".to_string(), "22".into(), "28".into(), "28".into()],
        vec!["lanes".into(), "4".into(), "4".into(), "4".into()],
        vec!["VRF [KiB]".into(), "16".into(), "16".into(), "16".into()],
        vec![
            "TT frequency [GHz]".into(),
            format!("{:.2}", ara22.freq_ghz),
            format!("{:.3}", ara28.freq_ghz),
            format!("{:.2}", cfg.freq_ghz),
        ],
        vec![
            "lane area [mm²]".into(),
            format!("{:.2}", ara22.area_mm2),
            format!("{:.2}", ara28.area_mm2),
            format!("{:.2}", speed_lane),
        ],
        vec![
            "lane power [mW]".into(),
            format!("{:.0}", ara22.power_w * 1e3),
            format!("{:.0}", ara28.power_w * 1e3),
            format!("{:.0}", speed_power_w * 1e3),
        ],
    ];
    let mut out = String::from("Table II — synthesis results, Ara vs SPEED\n");
    out.push_str(&super::render_table(
        &["parameter", "Ara reported", "Ara projected*", "SPEED"],
        &rows,
    ));
    out.push_str(&format!(
        "\n* 22→28 nm: linear frequency, quadratic area, constant power\n\
         area reduction {:.0}% (paper 45%), power reduction {:.0}% (paper 69%)\n",
        100.0 * (1.0 - speed_lane / ara28.area_mm2),
        100.0 * (1.0 - speed_power_w / ara28.power_w),
    ));
    out
}

/// Measure SPEED's achieved throughput (GOPS) at a precision on the
/// Table III instance, using a high-utilization CONV3×3 workload.
pub fn measured_peak_gops(cfg: &SpeedConfig, prec: Precision) -> f64 {
    let op = OpDesc::conv(128, 128, 28, 28, 3, 1, 1, prec);
    let mut p = Processor::new(*cfg, 1 << 26);
    let layout = MemLayout::for_op(&op, 1 << 26).unwrap();
    let (stats, _) = execute_op(&mut p, &op, StrategyKind::Ffcs, layout, false).unwrap();
    stats.gops(cfg.freq_ghz)
}

/// A Table III competitor row as reported by its own paper.
#[derive(Debug, Clone)]
pub struct Competitor {
    /// Design name as cited.
    pub name: &'static str,
    /// Process node, nm.
    pub node_nm: f64,
    /// Die / core area, mm².
    pub area_mm2: f64,
    /// Reported clock, GHz.
    pub freq_ghz: f64,
    /// Reported power, W.
    pub power_w: f64,
    /// GOPS at INT8.
    pub int8_gops: f64,
    /// GOPS at the design's best integer precision.
    pub best_gops: f64,
    /// Label of that best precision (e.g. "2b").
    pub best_label: &'static str,
}

/// Reported rows of Table III (Yun, Vega, XPULPNN, DARKSIDE, Dustin).
pub fn competitors() -> Vec<Competitor> {
    vec![
        Competitor { name: "Yun", node_nm: 65.0, area_mm2: 6.0, freq_ghz: 0.28,
            power_w: 0.228, int8_gops: 22.9, best_gops: 22.9, best_label: "8b" },
        Competitor { name: "Vega", node_nm: 22.0, area_mm2: 12.0, freq_ghz: 0.45,
            power_w: 0.0254, int8_gops: 15.6, best_gops: 15.6, best_label: "8b" },
        Competitor { name: "XPULPNN", node_nm: 22.0, area_mm2: 1.05, freq_ghz: 0.4,
            power_w: 0.0207, int8_gops: 23.0, best_gops: 72.0, best_label: "2b" },
        Competitor { name: "DARKSIDE", node_nm: 65.0, area_mm2: 12.0, freq_ghz: 0.29,
            power_w: 0.213, int8_gops: 17.0, best_gops: 65.0, best_label: "2b" },
        Competitor { name: "Dustin", node_nm: 65.0, area_mm2: 10.0, freq_ghz: 0.205,
            power_w: 0.156, int8_gops: 15.0, best_gops: 58.0, best_label: "2b" },
    ]
}

/// One output row of the Table III comparison.
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Design name.
    pub name: String,
    /// Throughput at INT8, GOPS (projected to 28 nm).
    pub gops_8b: f64,
    /// Area efficiency at INT8, GOPS/mm².
    pub area_eff_8b: f64,
    /// Energy efficiency at INT8, GOPS/W.
    pub energy_eff_8b: f64,
    /// Throughput at the best precision, GOPS.
    pub gops_best: f64,
    /// Area efficiency at the best precision, GOPS/mm².
    pub area_eff_best: f64,
    /// Energy efficiency at the best precision, GOPS/W.
    pub energy_eff_best: f64,
    /// Label of the best precision.
    pub best_label: String,
}

/// The full Table III data at 28 nm.
pub fn table3_data() -> Vec<Table3Row> {
    let mut rows = Vec::new();
    for c in competitors() {
        let m8 = ReportedMetrics {
            node_nm: c.node_nm,
            freq_ghz: c.freq_ghz,
            area_mm2: c.area_mm2,
            power_w: c.power_w,
            gops: c.int8_gops,
        }
        .project(28.0);
        let mb = ReportedMetrics {
            node_nm: c.node_nm,
            freq_ghz: c.freq_ghz,
            area_mm2: c.area_mm2,
            power_w: c.power_w,
            gops: c.best_gops,
        }
        .project(28.0);
        rows.push(Table3Row {
            name: c.name.to_string(),
            gops_8b: m8.gops,
            area_eff_8b: m8.area_eff(),
            energy_eff_8b: m8.energy_eff(),
            gops_best: mb.gops,
            area_eff_best: mb.area_eff(),
            energy_eff_best: mb.energy_eff(),
            best_label: c.best_label.to_string(),
        });
    }
    // SPEED: the Table III instance (4 lanes, 8x4 tiles), measured.
    let cfg = SpeedConfig::table3();
    let area = speed_area(&cfg).total();
    let power = speed_power(&cfg);
    let g8 = measured_peak_gops(&cfg, Precision::Int8);
    let g4 = measured_peak_gops(&cfg, Precision::Int4);
    rows.push(Table3Row {
        name: "SPEED (ours)".to_string(),
        gops_8b: g8,
        area_eff_8b: g8 / area,
        energy_eff_8b: g8 / power,
        gops_best: g4,
        area_eff_best: g4 / area,
        energy_eff_best: g4 / power,
        best_label: "4b".to_string(),
    });
    rows
}

/// Text report.
pub fn table3() -> String {
    let rows = table3_data();
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                format!("{:.1}", r.gops_8b),
                format!("{:.1}", r.area_eff_8b),
                format!("{:.0}", r.energy_eff_8b),
                format!("{:.1} ({})", r.gops_best, r.best_label),
                format!("{:.1}", r.area_eff_best),
                format!("{:.0}", r.energy_eff_best),
            ]
        })
        .collect();
    let mut out = String::from(
        "Table III — comparison with state-of-the-art RISC-V processors \
         (projected to 28 nm: linear freq / quadratic area / constant power)\n",
    );
    out.push_str(&super::render_table(
        &[
            "processor",
            "INT8 GOPS",
            "INT8 GOPS/mm²",
            "INT8 GOPS/W",
            "best GOPS",
            "best GOPS/mm²",
            "best GOPS/W",
        ],
        &table,
    ));
    out.push_str(
        "\npaper SPEED row: 343.1 GOPS / 285.8 GOPS/mm² / 643 GOPS/W @8b;\n\
         737.9 GOPS / 614.6 GOPS/mm² / 1383.4 GOPS/W @4b (4 lanes, 8x4 tiles)\n\
         note: the paper reports a 1.20 mm² area for this instance; our\n\
         analytical model (calibrated to Table II / Fig. 13) yields the full-\n\
         processor area, so GOPS/mm² differs by that convention (see\n\
         EXPERIMENTS.md).\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_report_shape() {
        let r = table2();
        assert!(r.contains("1.94"));
        assert!(r.contains("SPEED"));
    }

    #[test]
    fn table3_speed_dominates_throughput_and_area_eff() {
        let rows = table3_data();
        let speed = rows.last().unwrap().clone();
        assert_eq!(speed.name, "SPEED (ours)");
        for r in &rows[..rows.len() - 1] {
            assert!(speed.gops_8b > r.gops_8b, "{}: {} !> {}", r.name, speed.gops_8b, r.gops_8b);
            assert!(speed.gops_best > r.gops_best);
        }
        // 4-bit beats 8-bit on SPEED.
        assert!(speed.gops_best > speed.gops_8b);
    }

    #[test]
    fn competitor_projections_match_paper() {
        let rows = table3_data();
        let find = |n: &str| rows.iter().find(|r| r.name == n).unwrap().clone();
        // Paper's projected values (reported | projected columns).
        assert!((find("Yun").gops_8b - 53.2).abs() < 1.0);
        assert!((find("XPULPNN").gops_8b - 18.1).abs() < 0.5);
        assert!((find("Dustin").gops_best - 134.6).abs() < 2.0);
        assert!((find("DARKSIDE").gops_best - 150.8).abs() < 2.0);
    }
}
