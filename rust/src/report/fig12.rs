//! Fig. 12 — model-level performance of SPEED (mixed dataflow) vs Ara on
//! the six DNN benchmarks at 16/8/4-bit.
//!
//! Paper: average speedup 4.88× @16-bit and 11.89× @8-bit; CNNs with
//! PWCV/DWCV dominance reach 6.63–42.90× @16-bit and 17.85–144.25×
//! @8-bit; ViTs 1.18–1.46× / 2.00–2.13×; 4-bit averages 90.67 ops/cycle
//! (22.22× Ara's best); 8-bit = 2.95× and 4-bit = 5.51× of 16-bit.

use crate::ara::AraParams;
use crate::config::{Precision, SpeedConfig};
use crate::coordinator::{run_model, run_model_ara, Policy};
use crate::coordinator::runner::{default_workers, run_parallel};
use crate::models::zoo::{model_by_name, Model, MODELS};

/// One (model, precision) result.
#[derive(Debug, Clone)]
pub struct Fig12Point {
    /// Model name.
    pub model: String,
    /// Precision of the comparison.
    pub prec: Precision,
    /// SPEED whole-model cycles.
    pub speed_cycles: u64,
    /// SPEED MAC-ops per cycle.
    pub speed_ops_per_cycle: f64,
    /// Ara whole-model cycles.
    pub ara_cycles: u64,
    /// Ara MAC-ops per cycle.
    pub ara_ops_per_cycle: f64,
}

impl Fig12Point {
    /// Ara cycles over SPEED cycles.
    pub fn speedup(&self) -> f64 {
        self.ara_cycles as f64 / self.speed_cycles as f64
    }
}

/// Downscale a model's spatial dims by `factor` (quick mode for tests and
/// iteration — identical operator mix, smaller feature maps).
pub fn downscale(model: &Model, factor: u32) -> Model {
    let mut m = model.clone();
    for op in &mut m.ops {
        if op.kind != crate::models::OpKind::Mm {
            op.h = (op.h / factor).max(op.ksize.max(op.stride));
            op.w = (op.w / factor).max(op.ksize.max(op.stride));
        } else {
            // MM: shrink the token/batch dimension (the "input size");
            // k/n are model dimensions, not workload size.
            op.m = (op.m / factor).max(1);
        }
    }
    m
}

/// Evaluate every (model, precision) pair in parallel with the default
/// worker count.
pub fn fig12_data(cfg: &SpeedConfig, quick: bool) -> Vec<Fig12Point> {
    fig12_data_with(cfg, quick, default_workers())
}

/// Evaluate every (model, precision) pair on `workers` threads.
pub fn fig12_data_with(cfg: &SpeedConfig, quick: bool, workers: usize) -> Vec<Fig12Point> {
    let params = AraParams::default();
    let mut jobs = Vec::new();
    for name in MODELS {
        let mut model = model_by_name(name).unwrap();
        if quick {
            model = downscale(&model, 4);
        }
        for prec in [Precision::Int16, Precision::Int8, Precision::Int4] {
            jobs.push((model.clone(), prec));
        }
    }
    run_parallel(jobs, workers, |(model, prec)| {
        let s = run_model(model, *prec, cfg, Policy::Mixed).expect("model run");
        let a = run_model_ara(model, *prec, &params);
        let total_ops: u64 = model.ops.iter().map(|o| o.total_ops()).sum();
        Fig12Point {
            model: model.name.to_string(),
            prec: *prec,
            speed_cycles: s.vector_cycles(),
            speed_ops_per_cycle: s.ops_per_cycle(),
            ara_cycles: a.cycles,
            ara_ops_per_cycle: total_ops as f64 / a.cycles as f64,
        }
    })
}

/// Average speedup at one precision.
pub fn avg_speedup(points: &[Fig12Point], prec: Precision) -> f64 {
    let v: Vec<f64> =
        points.iter().filter(|p| p.prec == prec).map(|p| p.speedup()).collect();
    v.iter().sum::<f64>() / v.len() as f64
}

/// Average SPEED ops/cycle at one precision.
pub fn avg_ops_per_cycle(points: &[Fig12Point], prec: Precision) -> f64 {
    let v: Vec<f64> = points
        .iter()
        .filter(|p| p.prec == prec)
        .map(|p| p.speed_ops_per_cycle)
        .collect();
    v.iter().sum::<f64>() / v.len() as f64
}

/// Text report.
pub fn fig12(cfg: &SpeedConfig, quick: bool) -> String {
    fig12_with(cfg, quick, default_workers())
}

/// Text report with an explicit sweep worker count.
pub fn fig12_with(cfg: &SpeedConfig, quick: bool, workers: usize) -> String {
    let pts = fig12_data_with(cfg, quick, workers);
    let rows: Vec<Vec<String>> = pts
        .iter()
        .map(|p| {
            vec![
                p.model.clone(),
                p.prec.to_string(),
                p.speed_cycles.to_string(),
                format!("{:.2}", p.speed_ops_per_cycle),
                p.ara_cycles.to_string(),
                format!("{:.2}x", p.speedup()),
            ]
        })
        .collect();
    let mut out = format!(
        "Fig. 12 — model-level SPEED vs Ara{}\n",
        if quick { " (quick mode: 1/4-scale feature maps)" } else { "" }
    );
    out.push_str(&super::render_table(
        &["model", "precision", "SPEED cycles", "SPEED ops/cyc", "Ara cycles", "speedup"],
        &rows,
    ));
    let a16 = avg_speedup(&pts, Precision::Int16);
    let a8 = avg_speedup(&pts, Precision::Int8);
    let o16 = avg_ops_per_cycle(&pts, Precision::Int16);
    let o8 = avg_ops_per_cycle(&pts, Precision::Int8);
    let o4 = avg_ops_per_cycle(&pts, Precision::Int4);
    out.push_str(&format!(
        "\navg speedup: {a16:.2}x @16b (paper 4.88x), {a8:.2}x @8b (paper 11.89x)\n\
         avg SPEED ops/cycle: {o16:.2} @16b, {o8:.2} @8b ({:.2}x of 16b, paper 2.95x), \
         {o4:.2} @4b ({:.2}x of 16b, paper 5.51x; paper avg 90.67 ops/cycle)\n",
        o8 / o16,
        o4 / o16
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig12_shape_holds() {
        let cfg = SpeedConfig::reference();
        let pts = fig12_data(&cfg, true);
        assert_eq!(pts.len(), 18); // 6 models x 3 precisions
        // SPEED wins everywhere — except that 16-bit MMs on quick-mode
        // (token-shrunk) ViTs are a wash by construction: both machines
        // share the same 16-bit peak and the paper itself reports only
        // 1.18-1.46x there. Allow a small tolerance for that cell.
        for p in &pts {
            let floor = if p.model.starts_with("vit") && p.prec == Precision::Int16 {
                0.85
            } else {
                1.0
            };
            assert!(p.speedup() > floor, "{} {}: {}", p.model, p.prec, p.speedup());
        }
        // 8-bit speedup exceeds 16-bit on average (the PP effect + Ara's
        // SEW floor).
        let a16 = avg_speedup(&pts, Precision::Int16);
        let a8 = avg_speedup(&pts, Precision::Int8);
        assert!(a8 > a16, "8b {a8} !> 16b {a16}");
        // Precision scaling of SPEED itself.
        let o16 = avg_ops_per_cycle(&pts, Precision::Int16);
        let o8 = avg_ops_per_cycle(&pts, Precision::Int8);
        let o4 = avg_ops_per_cycle(&pts, Precision::Int4);
        assert!(o8 > 1.5 * o16, "8b {o8} vs 16b {o16}");
        assert!(o4 > o8, "4b {o4} vs 8b {o8}");
    }

    #[test]
    fn downscale_preserves_structure() {
        let m = model_by_name("mobilenetv2").unwrap();
        let d = downscale(&m, 4);
        assert_eq!(m.ops.len(), d.ops.len());
        assert!(d.total_macs() < m.total_macs() / 4);
        for op in &d.ops {
            op.validate().unwrap();
        }
    }
}
