//! Fig. 2 — SPEED vs Ara instruction traces for an INT16 MM operator.
//!
//! The paper's workload produces a 4×8 output (M=4, K=4, N=8) on the
//! 2-lane, 2×2-tile SPEED instance; Ara needs 16 `VMACC`s where SPEED
//! needs 4 `VSAM`s. Paper numbers: SPEED 6.56 OPs/cycle vs Ara 4.74
//! (1.4×), 46 % fewer instructions, 50 % fewer vector registers.

use crate::ara::{ara_cost, AraParams};
use crate::compiler::{compile_op, MemLayout};
use crate::config::{Precision, SpeedConfig};
use crate::isa::{disasm::disassemble_program, Insn, StrategyKind};
use crate::models::ops::OpDesc;
use crate::sim::Processor;

/// Structured Fig. 2 results.
#[derive(Debug, Clone)]
pub struct Fig2Result {
    /// SPEED simulated cycles.
    pub speed_cycles: u64,
    /// Instructions in the SPEED stream.
    pub speed_insns: u64,
    /// Vector registers the SPEED stream touches.
    pub speed_vregs: u32,
    /// SPEED MAC-ops per cycle.
    pub speed_ops_per_cycle: f64,
    /// `VSAM` instructions in the SPEED stream.
    pub speed_vsam_count: u64,
    /// Ara baseline cycles.
    pub ara_cycles: u64,
    /// Ara instruction count.
    pub ara_insns: u64,
    /// Vector registers the Ara schedule touches.
    pub ara_vregs: u32,
    /// Ara MAC-ops per cycle.
    pub ara_ops_per_cycle: f64,
    /// Disassembly of the SPEED stream (the figure's listing).
    pub speed_listing: String,
}

/// The Fig. 2 workload on the Fig. 2 hardware configuration.
pub fn fig2_data() -> Fig2Result {
    let op = OpDesc::mm(4, 4, 8, Precision::Int16);
    let cfg = SpeedConfig { lanes: 2, ..SpeedConfig::reference() };

    let layout = MemLayout::for_op(&op, 1 << 20).unwrap();
    let compiled = compile_op(&op, &cfg, StrategyKind::Mm, layout, true).unwrap();
    let mut p = Processor::new(cfg, 1 << 20);
    // Seeded operands (values don't affect timing; they make the listing a
    // real runnable program).
    let a: Vec<i32> = (0..16).map(|i| (i % 7) - 3).collect();
    let b: Vec<i32> = (0..32).map(|i| (i % 5) - 2).collect();
    p.mem.preload_packed(layout.in_addr, &a, op.prec);
    p.mem.preload_packed(layout.w_addr, &b, op.prec);
    p.set_plan(compiled.plan);
    let mut st = crate::sim::SimStats::default();
    for seg in &compiled.segments {
        st.merge(&p.run(seg).unwrap());
    }
    // Count vector instructions only (the paper's Fig. 2 listings show the
    // vector stream; scalar address setup lives on the scalar core).
    let vec_insns: u64 = compiled
        .segments
        .iter()
        .flatten()
        .filter(|i| i.is_vector())
        .count() as u64;
    let vsams = compiled
        .segments
        .iter()
        .flatten()
        .filter(|i| matches!(i, Insn::Vsam { .. }))
        .count() as u64;

    let ara = ara_cost(&op, &AraParams::default());
    let all: Vec<Insn> = compiled.segments.iter().flatten().copied().collect();

    Fig2Result {
        speed_cycles: st.cycles,
        speed_insns: vec_insns,
        speed_vregs: compiled.summary.vregs_used,
        speed_ops_per_cycle: st.ops_per_cycle(),
        speed_vsam_count: vsams,
        ara_cycles: ara.cycles,
        ara_insns: ara.insns,
        ara_vregs: ara.vregs,
        ara_ops_per_cycle: ara.ops_per_cycle(&op),
        speed_listing: disassemble_program(&all),
    }
}

/// Text report.
pub fn fig2() -> String {
    let d = fig2_data();
    let fewer_insns = 100.0 * (1.0 - d.speed_insns as f64 / d.ara_insns as f64);
    let fewer_regs = 100.0 * (1.0 - d.speed_vregs as f64 / d.ara_vregs as f64);
    let speedup = d.speed_ops_per_cycle / d.ara_ops_per_cycle;
    let rows = vec![
        vec![
            "SPEED".into(),
            d.speed_insns.to_string(),
            d.speed_vregs.to_string(),
            d.speed_cycles.to_string(),
            format!("{:.2}", d.speed_ops_per_cycle),
        ],
        vec![
            "Ara".into(),
            d.ara_insns.to_string(),
            d.ara_vregs.to_string(),
            d.ara_cycles.to_string(),
            format!("{:.2}", d.ara_ops_per_cycle),
        ],
    ];
    let mut out = String::from("Fig. 2 — INT16 MM (4x8 output) instruction traces\n");
    out.push_str(&super::render_table(
        &["processor", "vector insns", "vregs", "cycles", "OPs/cycle"],
        &rows,
    ));
    out.push_str(&format!(
        "\nSPEED uses {fewer_insns:.0}% fewer instructions (paper: 46%), \
         {fewer_regs:.0}% fewer registers (paper: 50%), {speedup:.2}x throughput \
         (paper: 1.4x = 6.56 vs 4.74 OPs/cycle)\n\nSPEED program:\n{}\n",
        d.speed_listing
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape_holds() {
        let d = fig2_data();
        // SPEED: 4 VSAM replace Ara's 16 VMACC.
        assert_eq!(d.speed_vsam_count, 4, "{}", d.speed_listing);
        // Fewer instructions, fewer registers, higher throughput.
        assert!(d.speed_insns < d.ara_insns, "{} !< {}", d.speed_insns, d.ara_insns);
        assert!(d.speed_vregs < d.ara_vregs);
        assert!(
            d.speed_ops_per_cycle > d.ara_ops_per_cycle,
            "{} !> {}",
            d.speed_ops_per_cycle,
            d.ara_ops_per_cycle
        );
        // Ratio in the published regime (paper: 1.4x).
        let ratio = d.speed_ops_per_cycle / d.ara_ops_per_cycle;
        assert!((1.05..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn report_renders() {
        let r = fig2();
        assert!(r.contains("vsam"));
        assert!(r.contains("OPs/cycle"));
    }
}
