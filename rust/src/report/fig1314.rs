//! Fig. 13 — area breakdown; Fig. 14 — design-space exploration.

use crate::config::SpeedConfig;
use crate::coordinator::runner::default_workers;
use crate::dse::{peak_area_eff, sweep_opts, DsePoint};
use crate::metrics::{lane_area, speed_area};

/// Fig. 13 text report: processor- and lane-level area breakdown of the
/// reference instance. Paper: lanes 59 % of the processor; lane = VRF 33 %,
/// OP queues 21 %, OP requester 16 %, ALU 13 %, MPTU 12 %.
pub fn fig13() -> String {
    let cfg = SpeedConfig::reference();
    let b = speed_area(&cfg);
    let lane = lane_area(&cfg);
    let lt = lane.total();
    let rows = vec![
        vec!["VRF".to_string(), format!("{:.4}", lane.vrf), format!("{:.0}%", 100.0 * lane.vrf / lt), "33%".into()],
        vec!["OP queues".into(), format!("{:.4}", lane.queues), format!("{:.0}%", 100.0 * lane.queues / lt), "21%".into()],
        vec!["OP requester".into(), format!("{:.4}", lane.requester), format!("{:.0}%", 100.0 * lane.requester / lt), "16%".into()],
        vec!["ALU".into(), format!("{:.4}", lane.alu), format!("{:.0}%", 100.0 * lane.alu / lt), "13%".into()],
        vec!["MPTU".into(), format!("{:.4}", lane.mptu), format!("{:.0}%", 100.0 * lane.mptu / lt), "12%".into()],
        vec!["misc".into(), format!("{:.4}", lane.misc), format!("{:.0}%", 100.0 * lane.misc / lt), "5%".into()],
    ];
    let mut out = String::from("Fig. 13 — area breakdown (TSMC 28 nm analytical model)\n");
    out.push_str(&format!(
        "processor: total {:.2} mm², lanes {:.2} mm² ({:.0}%, paper 59%), \
         front-end {:.2} mm² ({:.0}%, paper 41%)\n\nlane breakdown:\n",
        b.total(),
        b.lanes_total,
        100.0 * b.lane_fraction(),
        b.frontend,
        100.0 * (1.0 - b.lane_fraction()),
    ));
    out.push_str(&super::render_table(&["component", "mm²", "share", "paper"], &rows));
    out.push_str(&format!(
        "\none MPTU = {:.1}% of the whole processor (paper 1.7%) while \
         delivering the multi-precision throughput\n",
        100.0 * lane.mptu / b.total()
    ));
    out
}

/// Fig. 14 text report: throughput / area efficiency across the 27-point
/// design space. Paper: 8.5–161.3 GOPS on CONV3×3 @16-bit; peak
/// 80.3 GOPS/mm² at 96.4 GOPS; 4-lane instances peak area efficiency.
pub fn fig14() -> (String, Vec<DsePoint>) {
    fig14_with(default_workers(), false)
}

/// Fig. 14 with an explicit sweep worker count and optional quick mode
/// (1/4-scale workload).
pub fn fig14_with(workers: usize, quick: bool) -> (String, Vec<DsePoint>) {
    fig14_tuned_with(workers, quick, false)
}

/// [`fig14_with`] with an optional per-point mapping search (`repro dse
/// --tuned`): the table gains tuned-cycle / tuned-efficiency columns and
/// the winning mapping per point, and the summary reports the tuned peak
/// alongside the static one.
pub fn fig14_tuned_with(
    workers: usize,
    quick: bool,
    tuned: bool,
) -> (String, Vec<DsePoint>) {
    let points = sweep_opts(workers, quick, tuned);
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            let mut row = vec![
                format!("{}L {}x{}", p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c),
                format!("{:.1}", p.gops),
                format!("{:.2}", p.area_mm2),
                format!("{:.1}", p.area_eff()),
            ];
            if tuned {
                let t = p.tuned.expect("tuned sweep fills every point");
                row.push(format!("{:.1}", t.gops));
                row.push(format!("{:.1}", p.best_area_eff()));
                row.push(format!(
                    "{}{}",
                    t.choice,
                    if t.cycles < p.static_cycles { " *" } else { "" }
                ));
            }
            row
        })
        .collect();
    let peak = peak_area_eff(&points);
    let lo = points.iter().map(|p| p.gops).fold(f64::MAX, f64::min);
    let hi = points.iter().map(|p| p.gops).fold(0.0f64, f64::max);
    let mut out = String::from(
        "Fig. 14 — DSE: CONV3x3 @16-bit across lanes x tile geometry\n",
    );
    if tuned {
        out.push_str(&super::render_table(
            &["config", "GOPS", "area mm²", "GOPS/mm²", "tuned GOPS", "tuned GOPS/mm²",
              "mapping"],
            &rows,
        ));
    } else {
        out.push_str(&super::render_table(
            &["config", "GOPS", "area mm²", "GOPS/mm²"],
            &rows,
        ));
    }
    out.push_str(&format!(
        "\nthroughput range {lo:.1}-{hi:.1} GOPS (paper 8.5-161.3); peak area \
         efficiency {:.1} GOPS/mm² at {:.1} GOPS on {}L {}x{} (paper 80.3 at 96.4, \
         4-lane peak)\n",
        peak.area_eff(),
        peak.gops,
        peak.cfg.lanes,
        peak.cfg.tile_r,
        peak.cfg.tile_c
    ));
    if tuned {
        let improved = points
            .iter()
            .filter(|p| p.tuned.is_some_and(|t| t.cycles < p.static_cycles))
            .count();
        let violations = points
            .iter()
            .filter(|p| p.tuned.is_some_and(|t| t.cycles > p.static_cycles))
            .count();
        let best = points
            .iter()
            .max_by(|a, b| a.best_area_eff().partial_cmp(&b.best_area_eff()).unwrap())
            .expect("non-empty sweep");
        out.push_str(&format!(
            "tuned sweep: mapping search improved {improved}/{} points \
             (* marks them); tuned peak area efficiency {:.1} GOPS/mm² on \
             {}L {}x{}; {}\n",
            points.len(),
            best.best_area_eff(),
            best.cfg.lanes,
            best.cfg.tile_r,
            best.cfg.tile_c,
            if violations == 0 {
                "tuned cycles <= static cycles held at every point".to_string()
            } else {
                // cmd_dse turns this into a typed nonzero exit right after.
                format!("TUNER DEFECT: tuned > static at {violations} point(s)")
            }
        ));
    }
    (out, points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_reports_reference_breakdown() {
        let r = fig13();
        assert!(r.contains("VRF"));
        assert!(r.contains("MPTU"));
        assert!(r.contains("59%") || r.contains("58%") || r.contains("60%"));
    }

    #[test]
    fn fig14_peak_is_mid_size_config() {
        let (_, points) = fig14();
        assert_eq!(points.len(), 27);
        let peak = peak_area_eff(&points);
        // The paper's conclusion: 4-lane instances balance throughput and
        // area; the extreme corners must not win.
        assert_eq!(peak.cfg.lanes, 4, "peak at {:?}", peak.cfg);
        // Wide dynamic range across the space.
        let lo = points.iter().map(|p| p.gops).fold(f64::MAX, f64::min);
        let hi = points.iter().map(|p| p.gops).fold(0.0f64, f64::max);
        assert!(hi / lo > 3.0, "range {lo}..{hi}");
    }
}
