//! Table I — complete-application inference: VGG16 and MobileNetV2 at
//! INT8, convolution-layers-only vs complete application (scalar core
//! handles pooling / normalization / non-vectorizable glue).
//!
//! Paper: VGG16 6.11× (conv-only) / 5.84× (complete); MobileNetV2
//! 144.25× (conv-only) / 100.81× (complete) — the gap narrows on the
//! lightweight network because non-linear scalar work is a larger share.

use crate::ara::AraParams;
use crate::config::{Precision, SpeedConfig};
use crate::coordinator::runner::{default_workers, run_parallel};
use crate::coordinator::{ara_complete_cycles, run_model, run_model_ara, Policy};
use crate::models::zoo::model_by_name;
use crate::report::fig12::downscale;

/// One Table I row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Model name.
    pub model: String,
    /// SPEED cycles, convolutional layers only.
    pub speed_conv_cycles: u64,
    /// SPEED cycles, complete application.
    pub speed_complete_cycles: u64,
    /// Ara cycles, convolutional layers only.
    pub ara_conv_cycles: u64,
    /// Ara cycles, complete application.
    pub ara_complete_cycles: u64,
}

impl Table1Row {
    /// Ara over SPEED, convolutional layers only.
    pub fn conv_speedup(&self) -> f64 {
        self.ara_conv_cycles as f64 / self.speed_conv_cycles as f64
    }

    /// Ara over SPEED, complete application.
    pub fn complete_speedup(&self) -> f64 {
        self.ara_complete_cycles as f64 / self.speed_complete_cycles as f64
    }
}

/// Evaluate both Table I networks at INT8 with the default worker count.
pub fn table1_data(cfg: &SpeedConfig, quick: bool) -> Vec<Table1Row> {
    table1_data_with(cfg, quick, default_workers())
}

/// Evaluate both Table I networks at INT8 on `workers` threads.
pub fn table1_data_with(cfg: &SpeedConfig, quick: bool, workers: usize) -> Vec<Table1Row> {
    let params = AraParams::default();
    let jobs: Vec<&str> = vec!["vgg16", "mobilenetv2"];
    run_parallel(jobs, workers, |name| {
        let mut model = model_by_name(name).unwrap();
        if quick {
            model = downscale(&model, 4);
        }
        let s = run_model(&model, Precision::Int8, cfg, Policy::Mixed).unwrap();
        let a = run_model_ara(&model, Precision::Int8, &params);
        Table1Row {
            model: name.to_string(),
            speed_conv_cycles: s.vector_cycles(),
            speed_complete_cycles: s.complete_cycles(),
            ara_conv_cycles: a.cycles,
            ara_complete_cycles: ara_complete_cycles(&a, &s),
        }
    })
}

/// Text report.
pub fn table1(cfg: &SpeedConfig, quick: bool) -> String {
    table1_with(cfg, quick, default_workers())
}

/// Text report with an explicit sweep worker count.
pub fn table1_with(cfg: &SpeedConfig, quick: bool, workers: usize) -> String {
    let rows = table1_data_with(cfg, quick, workers);
    let table: Vec<Vec<String>> = rows
        .iter()
        .flat_map(|r| {
            vec![
                vec![
                    r.model.clone(),
                    "conv-only".into(),
                    r.speed_conv_cycles.to_string(),
                    r.ara_conv_cycles.to_string(),
                    format!("{:.2}x", r.conv_speedup()),
                ],
                vec![
                    r.model.clone(),
                    "complete".into(),
                    r.speed_complete_cycles.to_string(),
                    r.ara_complete_cycles.to_string(),
                    format!("{:.2}x", r.complete_speedup()),
                ],
            ]
        })
        .collect();
    let mut out = format!(
        "Table I — INT8 inference cycles, SPEED vs Ara{}\n",
        if quick { " (quick mode)" } else { "" }
    );
    out.push_str(&super::render_table(
        &["model", "scope", "SPEED cycles", "Ara cycles", "speedup"],
        &table,
    ));
    out.push_str(
        "\npaper: VGG16 6.11x conv-only / 5.84x complete \
         (622,010,560 vs 3,677,525,600 cycles);\n\
         MobileNetV2 144.25x conv-only / 100.81x complete \
         (13,395,597 vs 1,932,019,408 cycles)\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_holds() {
        let rows = table1_data(&SpeedConfig::reference(), true);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.conv_speedup() > 1.0, "{}: {}", r.model, r.conv_speedup());
            // Scalar share narrows the complete-application speedup.
            assert!(
                r.complete_speedup() < r.conv_speedup(),
                "{}: complete {} !< conv {}",
                r.model,
                r.complete_speedup(),
                r.conv_speedup()
            );
        }
        // MobileNetV2's PWCV/DWCV dominance gives it the (much) larger
        // speedup, and its scalar share the larger conv->complete drop.
        let vgg = &rows[0];
        let mnv2 = &rows[1];
        assert!(mnv2.conv_speedup() > vgg.conv_speedup());
        let vgg_drop = vgg.conv_speedup() / vgg.complete_speedup();
        let mnv2_drop = mnv2.conv_speedup() / mnv2.complete_speedup();
        assert!(mnv2_drop > vgg_drop, "{mnv2_drop} !> {vgg_drop}");
    }
}
