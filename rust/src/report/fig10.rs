//! Fig. 10 — external-memory access size of SPEED's dataflow strategies
//! relative to Ara, per benchmark operator.
//!
//! Paper values (SPEED traffic as % of Ara's): PWCV — FFCS 12.12 %, CF
//! 47.12 %, FF 9.81 %; DWCV3×3(s=2) — FF 15.92 %; FF saves 70.22–90.19 %
//! across operators; FFCS saves 35.11–87.88 % (excluding DWCV).

use crate::ara::{ara_cost, AraParams};
use crate::compiler::{execute_op, MemLayout};
use crate::config::SpeedConfig;
use crate::dataflow::feasible;
use crate::isa::StrategyKind;
use crate::models::OpDesc;
use crate::sim::Processor;

/// Traffic of one (operator, strategy) cell, in bytes.
#[derive(Debug, Clone)]
pub struct Fig10Cell {
    /// Operator label.
    pub operator: &'static str,
    /// Strategy SPEED ran under.
    pub strat: StrategyKind,
    /// SPEED external-memory traffic, bytes.
    pub speed_bytes: u64,
    /// Ara external-memory traffic, bytes.
    pub ara_bytes: u64,
}

impl Fig10Cell {
    /// SPEED's traffic as a percentage of Ara's (the paper's metric).
    pub fn percent_of_ara(&self) -> f64 {
        100.0 * self.speed_bytes as f64 / self.ara_bytes as f64
    }
}

/// Measure SPEED traffic for one (op, strategy) by running the compiled
/// instruction stream (byte-accurate, from the memory model's counters).
pub fn speed_traffic(op: &OpDesc, cfg: &SpeedConfig, strat: StrategyKind) -> u64 {
    let mut p = Processor::new(*cfg, 1 << 24);
    let layout = MemLayout::for_op(op, 1 << 24).unwrap();
    let (stats, _) = execute_op(&mut p, op, strat, layout, false).unwrap();
    stats.traffic.total()
}

/// All Fig. 10 cells.
pub fn fig10_data(cfg: &SpeedConfig) -> Vec<Fig10Cell> {
    let params = AraParams::default();
    let mut cells = Vec::new();
    for (name, op) in super::benchmark_ops() {
        let ara = ara_cost(&op, &params).dram_total();
        for strat in [StrategyKind::Ffcs, StrategyKind::Cf, StrategyKind::Ff] {
            if !feasible(strat, &op, cfg) {
                continue;
            }
            cells.push(Fig10Cell {
                operator: name,
                strat,
                speed_bytes: speed_traffic(&op, cfg, strat),
                ara_bytes: ara,
            });
        }
    }
    cells
}

/// Text report.
pub fn fig10(cfg: &SpeedConfig) -> String {
    let cells = fig10_data(cfg);
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.operator.to_string(),
                c.strat.to_string().to_uppercase(),
                format!("{:.1}", c.speed_bytes as f64 / 1024.0),
                format!("{:.1}", c.ara_bytes as f64 / 1024.0),
                format!("{:.2}%", c.percent_of_ara()),
            ]
        })
        .collect();
    let mut out =
        String::from("Fig. 10 — external memory access size vs Ara (16-bit operators)\n");
    out.push_str(&super::render_table(
        &["operator", "strategy", "SPEED KiB", "Ara KiB", "SPEED % of Ara"],
        &rows,
    ));
    out.push_str(
        "\npaper: PWCV FFCS 12.12% / CF 47.12% / FF 9.81%; DWCV FF 15.92%;\n\
         FF saves 70.22-90.19% across ops; FFCS saves 35.11-87.88% (excl. DWCV)\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_shape_holds() {
        let cells = fig10_data(&SpeedConfig::reference());
        // 3 strategies x PWCV/CONV3/CONV5 + FF on DWCV = 10 cells.
        assert_eq!(cells.len(), 10);
        for c in &cells {
            // Every SPEED strategy beats Ara on traffic...
            assert!(
                c.speed_bytes < c.ara_bytes,
                "{} {}: {} !< {}",
                c.operator,
                c.strat,
                c.speed_bytes,
                c.ara_bytes
            );
        }
        // ...and the PWCV ordering matches the paper: FF < FFCS < CF.
        let pw: Vec<&Fig10Cell> = cells.iter().filter(|c| c.operator == "PWCV").collect();
        let pct = |s: StrategyKind| {
            pw.iter().find(|c| c.strat == s).unwrap().percent_of_ara()
        };
        assert!(pct(StrategyKind::Ff) < pct(StrategyKind::Ffcs));
        assert!(pct(StrategyKind::Ffcs) < pct(StrategyKind::Cf));
        // CF is the traffic-heavy arm on every operator it applies to.
        for opname in ["CONV3x3", "CONV5x5"] {
            let row: Vec<&Fig10Cell> =
                cells.iter().filter(|c| c.operator == opname).collect();
            let cf = row.iter().find(|c| c.strat == StrategyKind::Cf).unwrap();
            let ff = row.iter().find(|c| c.strat == StrategyKind::Ff).unwrap();
            assert!(cf.speed_bytes > ff.speed_bytes);
        }
    }
}
