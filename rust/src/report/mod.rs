//! Report harness: regenerates every table and figure of the paper's
//! evaluation (Sec. IV) from this repository's models and simulators.
//!
//! Each `figNN`/`tableN` function returns the same rows/series the paper
//! reports, as plain text plus structured data for the benches. Paper
//! values are printed side-by-side where the paper states them so
//! EXPERIMENTS.md can record paper-vs-measured directly.

pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig2;
pub mod fig1314;
pub mod table1;
pub mod table23;

pub use fig10::fig10;
pub use fig11::fig11;
pub use fig12::{fig12, fig12_with};
pub use fig1314::{fig13, fig14, fig14_tuned_with, fig14_with};
pub use fig2::fig2;
pub use table1::{table1, table1_with};
pub use table23::{table2, table3};

/// Render a text table with aligned columns.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    line(
        &mut out,
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
    );
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// The standard operator benchmark set of Figs. 10/11 (16-bit precision,
/// shapes representative of the paper's operator-level evaluation).
pub fn benchmark_ops() -> Vec<(&'static str, crate::models::OpDesc)> {
    use crate::config::Precision::Int16;
    use crate::models::OpDesc;
    vec![
        ("PWCV", OpDesc::pwcv(64, 64, 12, 12, Int16)),
        ("CONV3x3", OpDesc::conv(32, 32, 16, 16, 3, 1, 1, Int16)),
        ("DWCV3x3(s=2)", OpDesc::dwcv(32, 17, 17, 3, 2, 1, Int16)),
        ("CONV5x5", OpDesc::conv(32, 32, 16, 16, 5, 1, 2, Int16)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let t = render_table(
            &["a", "long-header"],
            &[vec!["x".into(), "y".into()], vec!["wide-cell".into(), "z".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("long-header"));
        assert!(lines[1].starts_with("---"));
    }

    #[test]
    fn benchmark_set_matches_paper() {
        let ops = benchmark_ops();
        assert_eq!(ops.len(), 4);
        assert!(ops.iter().all(|(_, o)| o.validate().is_ok()));
    }
}
