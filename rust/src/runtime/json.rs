//! Minimal JSON parser and emission/digest helpers.
//!
//! The deployment image vendors no serde; the AOT manifest format is
//! small and fixed (objects, arrays, strings, integers/floats, bools), so
//! a compact recursive-descent parser keeps the runtime self-contained.
//!
//! This module is also the one home of the crate's hand-rolled JSON
//! *emission* helpers ([`jstr`], [`jf`], [`jopt`]) and of the stable
//! [`Fnv64`] hasher — `serve::batch` (batch keys), the serve stats
//! digest, `tune::ops_digest`, and the bench report writers all used to
//! carry private copies; they now share these. Digest compatibility with
//! the pre-consolidation implementations is locked by the unit tests
//! below (published FNV-1a vectors plus a byte-for-byte comparison
//! against the legacy per-word fold).

use std::collections::BTreeMap;
use std::hash::Hasher;

use crate::error::SpeedError;

/// FNV-1a, 64-bit: a tiny deterministic hasher. The std `DefaultHasher`
/// is not guaranteed stable across releases, while batching keys, the
/// serve-bench stats digest, and the tuned-plan cache file names must be
/// reproducible across platforms and releases.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    /// A hasher at the FNV-1a offset basis.
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher for Fnv64 {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }
}

/// JSON-escape a string into a quoted literal.
pub fn jstr(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a finite float for JSON (non-finite values serialize as 0).
pub fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "0".into()
    }
}

/// An optional unsigned integer as a JSON number or `null`.
pub fn jopt(v: Option<u32>) -> String {
    match v {
        None => "null".into(),
        Some(x) => x.to_string(),
    }
}

/// Shorthand: a parse-class [`SpeedError`].
fn perr(m: impl Into<String>) -> SpeedError {
    SpeedError::Parse(m.into())
}

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, keys sorted.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object member lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as an array slice, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The value as a string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as a number, if it is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value truncated to i64, if it is a number.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    /// The value as an object map, if it is one.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of integers (shape lists, data vectors).
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()?.iter().map(|j| j.as_i64()).collect()
    }
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json, SpeedError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(perr(format!("trailing bytes at {}", p.i)));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8, SpeedError> {
        self.ws();
        self.b.get(self.i).copied().ok_or_else(|| perr("unexpected end"))
    }

    fn eat(&mut self, c: u8) -> Result<(), SpeedError> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(perr(format!("expected '{}' at {}", c as char, self.i)))
        }
    }

    fn value(&mut self) -> Result<Json, SpeedError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, SpeedError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(perr(format!("bad literal at {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json, SpeedError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| perr(format!("bad number at {start}")))
    }

    fn string(&mut self) -> Result<String, SpeedError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| perr("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| perr("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| perr("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(hex).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(perr(format!("bad escape '\\{}'", e as char))),
                    }
                }
                _ => out.push(c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json, SpeedError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(perr(format!("expected , or ] got '{}'", c as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, SpeedError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.eat(b':')?;
            m.insert(k, self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(perr(format!("expected , or }} got '{}'", c as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shapes() {
        let doc = r#"{"format": "hlo-text", "artifacts": {"mm_i8": {
            "inputs": [{"shape": [32, 64], "dtype": "i32"}],
            "meta": {"bits": 8, "op": "mm"}, "sha256": "ab12"}}}"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let art = j.get("artifacts").unwrap().get("mm_i8").unwrap();
        let shape = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_i64_vec()
            .unwrap();
        assert_eq!(shape, vec![32, 64]);
        assert_eq!(art.get("meta").unwrap().get("bits").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn parses_negative_numbers_and_nesting() {
        let j = parse(r#"[-1, 2.5, [3, -4], {"a": [null, true, false]}]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(-1));
        assert_eq!(a[1].as_f64(), Some(2.5));
        assert_eq!(a[2].as_i64_vec().unwrap(), vec![3, -4]);
        assert_eq!(a[3].get("a").unwrap().as_arr().unwrap()[1], Json::Bool(true));
    }

    #[test]
    fn parses_escapes() {
        let j = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\nd\u{41}"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'a': 1}").is_err());
    }

    #[test]
    fn large_int_data_roundtrip() {
        let j = parse("[2147483647, -2147483648]").unwrap();
        assert_eq!(j.as_i64_vec().unwrap(), vec![i32::MAX as i64, i32::MIN as i64]);
    }

    fn fnv(bytes: &[u8]) -> u64 {
        let mut h = Fnv64::new();
        h.write(bytes);
        h.finish()
    }

    #[test]
    fn fnv64_matches_published_vectors() {
        // The canonical FNV-1a 64-bit test vectors (Fowler/Noll/Vo): any
        // deviation would silently invalidate every committed batch key,
        // stats digest, and tuned-plan cache file name.
        assert_eq!(fnv(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fnv64_matches_the_legacy_per_word_fold() {
        // `tune::ops_digest` used to fold u32 words through a private
        // byte-at-a-time FNV-1a; the consolidated hasher must reproduce
        // those digests exactly so existing cache file names stay valid.
        fn legacy_fold_u32(mut h: u64, v: u32) -> u64 {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        let words = [0u32, 1, 16, 0xDEAD_BEEF, u32::MAX, 0x0102_0304];
        let mut legacy = 0xcbf2_9ce4_8422_2325u64;
        let mut new = Fnv64::new();
        for w in words {
            legacy = legacy_fold_u32(legacy, w);
            new.write(&w.to_le_bytes());
        }
        assert_eq!(new.finish(), legacy);
    }

    #[test]
    fn emission_helpers() {
        assert_eq!(jstr("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(jstr("\u{1}"), "\"\\u0001\"");
        assert_eq!(jf(1.5), "1.500000");
        assert_eq!(jf(f64::NAN), "0");
        assert_eq!(jf(f64::INFINITY), "0");
        assert_eq!(jopt(None), "null");
        assert_eq!(jopt(Some(12)), "12");
        // Emitted strings parse back through this module's own parser.
        let doc = format!("{{ \"s\": {}, \"f\": {}, \"o\": {} }}",
                          jstr("x\ny"), jf(2.25), jopt(Some(7)));
        let j = parse(&doc).unwrap();
        assert_eq!(j.get("s").and_then(Json::as_str), Some("x\ny"));
        assert_eq!(j.get("f").and_then(Json::as_f64), Some(2.25));
        assert_eq!(j.get("o").and_then(Json::as_i64), Some(7));
    }
}
