//! Artifact manifest: the contract `python/compile/aot.py` writes and the
//! Rust runtime consumes.

use std::collections::BTreeMap;
use std::path::Path;

use super::aerr;
use super::json::{parse, Json};
use crate::error::Result;

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Manifest key (computation name).
    pub name: String,
    /// Path (relative to the artifact dir) of the HLO text file.
    pub hlo_file: String,
    /// Path of the golden-vector JSON (empty when none was exported).
    pub golden_file: String,
    /// Shapes of each input operand, outermost dimension first.
    pub input_shapes: Vec<Vec<i64>>,
    /// Shape of the single output.
    pub output_shape: Vec<i64>,
    /// Operator metadata (op kind, bits, stride, ...) as parsed JSON.
    pub meta: Json,
}

impl Artifact {
    /// Precision in bits from the metadata (defaults to 8).
    pub fn bits(&self) -> u32 {
        self.meta.get("bits").and_then(|j| j.as_i64()).unwrap_or(8) as u32
    }

    /// Operator kind string from the metadata ("?" when absent).
    pub fn op_kind(&self) -> &str {
        self.meta.get("op").and_then(|j| j.as_str()).unwrap_or("?")
    }
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    /// Read and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            aerr(format!("reading {} (run `make artifacts`): {e}", path.display()))
        })?;
        Self::parse(&text)
    }

    /// Parse a manifest document from its JSON source.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = parse(text).map_err(|e| aerr(format!("manifest: {e}")))?;
        if doc.get("format").and_then(|j| j.as_str()) != Some("hlo-text") {
            return Err(aerr("manifest format must be 'hlo-text'"));
        }
        let arts = doc
            .get("artifacts")
            .and_then(|j| j.as_obj())
            .ok_or_else(|| aerr("manifest missing 'artifacts'"))?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in arts {
            let input_shapes = a
                .get("inputs")
                .and_then(|j| j.as_arr())
                .ok_or_else(|| aerr("artifact missing inputs"))?
                .iter()
                .map(|i| {
                    i.get("shape")
                        .and_then(|s| s.as_i64_vec())
                        .ok_or_else(|| aerr("bad shape"))
                })
                .collect::<Result<Vec<_>>>()?;
            let output_shape = a
                .get("output")
                .and_then(|o| o.get("shape"))
                .and_then(|s| s.as_i64_vec())
                .ok_or_else(|| aerr("artifact missing output shape"))?;
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    hlo_file: a
                        .get("hlo")
                        .and_then(|j| j.as_str())
                        .ok_or_else(|| aerr("missing hlo file"))?
                        .to_string(),
                    golden_file: a
                        .get("golden")
                        .and_then(|j| j.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    input_shapes,
                    output_shape,
                    meta: a.get("meta").cloned().unwrap_or(Json::Null),
                },
            );
        }
        Ok(Manifest { artifacts })
    }

    /// Look up one artifact by name.
    pub fn artifact(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.get(name)
    }

    /// All artifact names, in sorted order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }

    /// Number of artifacts in the manifest.
    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    /// Whether the manifest holds no artifacts.
    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }
}

/// Golden vectors for one artifact (inputs + expected output).
#[derive(Debug, Clone)]
pub struct Golden {
    /// Flattened integer input operands, in artifact order.
    pub inputs: Vec<Vec<i32>>,
    /// Flattened expected output.
    pub output: Vec<i32>,
    /// Shape of the expected output.
    pub output_shape: Vec<i64>,
}

impl Golden {
    /// Read and parse the golden-vector file for `art` under `dir`.
    pub fn load(dir: &Path, art: &Artifact) -> Result<Self> {
        let path = dir.join(&art.golden_file);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| aerr(format!("reading {}: {e}", path.display())))?;
        let doc = parse(&text).map_err(|e| aerr(format!("golden: {e}")))?;
        let inputs = doc
            .get("inputs")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| aerr("golden missing inputs"))?
            .iter()
            .map(|i| {
                i.get("data")
                    .and_then(|d| d.as_i64_vec())
                    .map(|v| v.into_iter().map(|x| x as i32).collect())
                    .ok_or_else(|| aerr("bad golden input data"))
            })
            .collect::<Result<Vec<Vec<i32>>>>()?;
        let out = doc.get("output").ok_or_else(|| aerr("golden missing output"))?;
        let output = out
            .get("data")
            .and_then(|d| d.as_i64_vec())
            .ok_or_else(|| aerr("bad golden output"))?
            .into_iter()
            .map(|x| x as i32)
            .collect();
        let output_shape = out
            .get("shape")
            .and_then(|s| s.as_i64_vec())
            .ok_or_else(|| aerr("bad golden output shape"))?;
        Ok(Golden { inputs, output, output_shape })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{"format": "hlo-text", "artifacts": {
        "mm_i8": {"hlo": "mm_i8.hlo.txt", "golden": "mm_i8.golden.json",
                  "inputs": [{"shape": [4, 8], "dtype": "i32"},
                             {"shape": [8, 4], "dtype": "i32"}],
                  "output": {"shape": [4, 4], "dtype": "i32"},
                  "meta": {"op": "mm", "bits": 8}, "sha256": "x"}}}"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.artifact("mm_i8").unwrap();
        assert_eq!(a.input_shapes, vec![vec![4, 8], vec![8, 4]]);
        assert_eq!(a.output_shape, vec![4, 4]);
        assert_eq!(a.bits(), 8);
        assert_eq!(a.op_kind(), "mm");
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = DOC.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // Integration sanity: if `make artifacts` has run, the real
        // manifest must parse and contain the expected artifact set.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for name in ["mm_i8", "mm_i16", "mm_i4", "conv3x3_i8", "dwconv3x3_s2_i8"] {
                assert!(m.artifact(name).is_some(), "{name} missing");
            }
        }
    }
}
