//! Golden-model verification: the three-way agreement at the heart of the
//! reproduction.
//!
//! For every artifact the AOT pipeline exports, three values must agree
//! bit-exactly:
//!
//! 1. the **golden vector** computed by JAX at build time (itself pytest-
//!    verified against the pure-jnp oracle and the Pallas kernels);
//! 2. the **PJRT execution** of the lowered HLO from Rust (the request
//!    path);
//! 3. where the artifact is a single operator, the **cycle simulator's
//!    functional output** for the equivalent instruction stream.

use std::path::Path;

use crate::compiler::{compile_op, MemLayout};
use crate::config::Precision;
use crate::error::Result;
use crate::models::ops::OpDesc;
use crate::sim::Processor;

use super::artifacts::{Artifact, Golden};
use super::{aerr, PjrtEngine};

/// Outcome of one artifact's golden check.
#[derive(Debug, Clone)]
pub struct GoldenReport {
    /// Artifact name.
    pub name: String,
    /// PJRT output == build-time golden vector.
    pub pjrt_ok: bool,
    /// Simulator output == PJRT output (None = artifact is not a single
    /// operator the simulator executes).
    pub sim_ok: Option<bool>,
    /// Output elements compared.
    pub elems: usize,
}

impl GoldenReport {
    /// Every performed comparison matched.
    pub fn ok(&self) -> bool {
        self.pjrt_ok && self.sim_ok.unwrap_or(true)
    }
}

/// Build the simulator operator equivalent of an artifact, if it is one.
pub fn op_for_artifact(art: &Artifact) -> Option<OpDesc> {
    let prec = Precision::from_bits(art.bits())?;
    let meta = &art.meta;
    let dim = |j: &super::json::Json, k: usize| -> u32 {
        j.as_i64_vec().map(|v| v.get(k).copied().unwrap_or(0) as u32).unwrap_or(0)
    };
    match art.op_kind() {
        "mm" => {
            let m = meta.get("m")?.as_i64()? as u32;
            let k = meta.get("k")?.as_i64()? as u32;
            let n = meta.get("n")?.as_i64()? as u32;
            Some(OpDesc::mm(m, k, n, prec))
        }
        "conv" => {
            let i = meta.get("in")?;
            let (c, h, w) = (dim(i, 1), dim(i, 2), dim(i, 3));
            let f = dim(meta.get("out")?, 1);
            let k = meta.get("k")?.as_i64()? as u32;
            let s = meta.get("stride")?.as_i64()? as u32;
            let p = meta.get("pad")?.as_i64()? as u32;
            Some(OpDesc::conv(c, f, h, w, k, s, p, prec))
        }
        "pwcv" => {
            let i = meta.get("in")?;
            let (c, h, w) = (dim(i, 1), dim(i, 2), dim(i, 3));
            let f = dim(meta.get("out")?, 1);
            Some(OpDesc::pwcv(c, f, h, w, prec))
        }
        "dwcv" => {
            let i = meta.get("in")?;
            let (c, h, w) = (dim(i, 1), dim(i, 2), dim(i, 3));
            let k = meta.get("k")?.as_i64()? as u32;
            let s = meta.get("stride")?.as_i64()? as u32;
            let p = meta.get("pad")?.as_i64()? as u32;
            Some(OpDesc::dwcv(c, h, w, k, s, p, prec))
        }
        _ => None,
    }
}

/// Run the simulator's compiled instruction stream for `op` on the golden
/// inputs and return its DRAM output image.
pub fn simulate_op(op: &OpDesc, inputs: &[Vec<i32>]) -> Result<Vec<i32>> {
    let mem = 1 << 24;
    let layout = MemLayout::for_op(op, mem)?;
    let mut p = Processor::new(crate::config::SpeedConfig::reference(), mem);
    p.mem.preload_packed(layout.in_addr, &inputs[0], op.prec);
    p.mem.preload_packed(layout.w_addr, &inputs[1], op.prec);
    let strat = op.preferred_strategy();
    let compiled = compile_op(op, &p.cfg, strat, layout, true)?;
    p.set_plan(compiled.plan);
    for seg in &compiled.segments {
        // Batch-aware execution: the golden three-way check therefore also
        // cross-checks the simulator's fast path against PJRT numerics.
        p.run_segment(seg)?;
    }
    Ok(p.mem.inspect_i32(layout.out_addr, op.output_elems() as usize))
}

/// Check one artifact: PJRT vs golden, and simulator vs PJRT when the
/// artifact maps to a single operator.
pub fn golden_check(engine: &mut PjrtEngine, dir: &Path, name: &str) -> Result<GoldenReport> {
    let art = engine
        .manifest()
        .artifact(name)
        .ok_or_else(|| aerr(format!("unknown artifact '{name}'")))?
        .clone();
    let golden = Golden::load(dir, &art)?;
    let out = engine.execute(name, &golden.inputs)?;
    let pjrt_ok = out == golden.output;

    let sim_ok = match op_for_artifact(&art) {
        Some(op) if golden.inputs.len() == 2 => {
            let sim = simulate_op(&op, &golden.inputs)?;
            Some(sim == out)
        }
        _ => None,
    };
    Ok(GoldenReport { name: name.to_string(), pjrt_ok, sim_ok, elems: out.len() })
}

/// Check every artifact in the manifest.
pub fn golden_check_all(engine: &mut PjrtEngine, dir: &Path) -> Result<Vec<GoldenReport>> {
    let names: Vec<String> = engine.manifest().names().map(|s| s.to_string()).collect();
    names.iter().map(|n| golden_check(engine, dir, n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::json::parse;

    fn fake_artifact(meta: &str, shapes: &str) -> Artifact {
        Artifact {
            name: "t".into(),
            hlo_file: String::new(),
            golden_file: String::new(),
            input_shapes: parse(shapes)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|s| s.as_i64_vec().unwrap())
                .collect(),
            output_shape: vec![],
            meta: parse(meta).unwrap(),
        }
    }

    #[test]
    fn op_mapping_mm() {
        let a = fake_artifact(
            r#"{"op": "mm", "bits": 16, "m": 4, "k": 8, "n": 8}"#,
            "[[4, 8], [8, 8]]",
        );
        let op = op_for_artifact(&a).unwrap();
        assert_eq!((op.m, op.k, op.n), (4, 8, 8));
        assert_eq!(op.prec, Precision::Int16);
    }

    #[test]
    fn op_mapping_conv_and_dwcv() {
        let a = fake_artifact(
            r#"{"op": "conv", "bits": 8, "k": 3, "stride": 1, "pad": 1,
                "in": [1, 8, 12, 12], "out": [1, 16, 12, 12]}"#,
            "[[1, 8, 12, 12], [16, 8, 3, 3]]",
        );
        let op = op_for_artifact(&a).unwrap();
        assert_eq!((op.c, op.f, op.h, op.ksize), (8, 16, 12, 3));
        let d = fake_artifact(
            r#"{"op": "dwcv", "bits": 8, "k": 3, "stride": 2, "pad": 1,
                "in": [1, 8, 13, 13], "out": [1, 8, 7, 7]}"#,
            "[[1, 8, 13, 13], [8, 3, 3]]",
        );
        let op = op_for_artifact(&d).unwrap();
        assert_eq!((op.c, op.stride, op.oh()), (8, 2, 7));
    }

    #[test]
    fn composite_artifacts_have_no_sim_op() {
        let a = fake_artifact(r#"{"op": "mnv2_block", "bits": 8}"#, "[[1,8,8,8]]");
        assert!(op_for_artifact(&a).is_none());
    }

    #[test]
    fn simulate_op_matches_known_product() {
        let op = OpDesc::mm(2, 2, 2, Precision::Int8);
        let out = simulate_op(&op, &[vec![1, 2, 3, 4], vec![1, 0, 0, 1]]).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4]);
    }
}
