//! PJRT runtime: loads the AOT-compiled HLO artifacts (the JAX/Pallas
//! golden numerics of the machine) and executes them from Rust.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only consumer of its outputs and is entirely self-contained at runtime:
//! HLO **text** (never serialized protos — the vendored xla_extension
//! 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids) is parsed, compiled
//! on the PJRT CPU client, and executed with int32 operands.

pub mod artifacts;
pub mod golden;
pub mod json;

pub use artifacts::{Artifact, Manifest};
pub use golden::{golden_check, golden_check_all, GoldenReport};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Result, SpeedError};

/// Shorthand: an artifact-class [`SpeedError`].
pub(crate) fn aerr(m: impl Into<String>) -> SpeedError {
    SpeedError::Artifact(m.into())
}

/// A PJRT engine holding the CPU client and a compiled-executable cache —
/// one compiled executable per model variant, loaded once and reused on
/// the hot path. Named for the runtime it wraps, distinguishing it from
/// the simulator-side [`crate::engine::Engine`].
pub struct PjrtEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| aerr(format!("PJRT: {e:?}")))?;
        Ok(PjrtEngine { client, dir, manifest, cache: HashMap::new() })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let art = self
                .manifest
                .artifact(name)
                .ok_or_else(|| aerr(format!("unknown artifact '{name}'")))?;
            let path = self.dir.join(&art.hlo_file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| aerr("bad path"))?,
            )
            .map_err(|e| aerr(format!("parse {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| aerr(format!("compile {name}: {e:?}")))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on int32 inputs (shapes validated against the
    /// manifest). Returns the flattened int32 output.
    pub fn execute(&mut self, name: &str, inputs: &[Vec<i32>]) -> Result<Vec<i32>> {
        let views: Vec<&[i32]> = inputs.iter().map(Vec::as_slice).collect();
        self.execute_slices(name, &views)
    }

    /// Borrowing variant of [`PjrtEngine::execute`]: a serving hot loop keeps
    /// its weights loaded once and passes them by reference on every
    /// request, instead of cloning megabytes of operands per call.
    pub fn execute_slices(&mut self, name: &str, inputs: &[&[i32]]) -> Result<Vec<i32>> {
        let art = self
            .manifest
            .artifact(name)
            .ok_or_else(|| aerr(format!("unknown artifact '{name}'")))?
            .clone();
        if inputs.len() != art.input_shapes.len() {
            return Err(aerr(format!(
                "{name}: expected {} inputs, got {}",
                art.input_shapes.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&art.input_shapes).enumerate() {
            let n: i64 = shape.iter().product();
            if n as usize != data.len() {
                return Err(aerr(format!(
                    "{name}: input {i} has {} elements, shape {:?} wants {n}",
                    data.len(),
                    shape
                )));
            }
            let lit = xla::Literal::vec1(*data)
                .reshape(shape)
                .map_err(|e| aerr(format!("reshape input {i}: {e:?}")))?;
            literals.push(lit);
        }
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| aerr(format!("execute {name}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| aerr(format!("sync {name}: {e:?}")))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| aerr(format!("untuple {name}: {e:?}")))?;
        out.to_vec::<i32>().map_err(|e| aerr(format!("to_vec {name}: {e:?}")))
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}
