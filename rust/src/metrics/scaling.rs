//! Technology-node projection rules (Table II / Table III footnotes).
//!
//! The paper projects prior-art numbers to 28 nm "assuming linear frequency
//! scaling, quadratic area scaling, and constant power scaling (since Vdd
//! does not scale)" — the same methodology as EIE (Han et al., ISCA'16).

/// Project a frequency from `from_nm` to `to_nm` (linear in 1/node).
pub fn project_frequency(freq: f64, from_nm: f64, to_nm: f64) -> f64 {
    freq * from_nm / to_nm
}

/// Project an area from `from_nm` to `to_nm` (quadratic in node).
pub fn project_area(area: f64, from_nm: f64, to_nm: f64) -> f64 {
    area * (to_nm / from_nm).powi(2)
}

/// Project power across nodes (constant — Vdd does not scale).
pub fn project_power(power: f64, _from_nm: f64, _to_nm: f64) -> f64 {
    power
}

/// A performance point reported at some node, projectable to another.
#[derive(Debug, Clone, Copy)]
pub struct ReportedMetrics {
    /// Process node, nm.
    pub node_nm: f64,
    /// Clock frequency, GHz.
    pub freq_ghz: f64,
    /// Area, mm².
    pub area_mm2: f64,
    /// Power, W.
    pub power_w: f64,
    /// Throughput, GOPS.
    pub gops: f64,
}

impl ReportedMetrics {
    /// Project everything to `to_nm`: throughput scales with frequency
    /// (linear), area quadratic, power constant.
    pub fn project(&self, to_nm: f64) -> ReportedMetrics {
        let f = project_frequency(self.freq_ghz, self.node_nm, to_nm);
        ReportedMetrics {
            node_nm: to_nm,
            freq_ghz: f,
            area_mm2: project_area(self.area_mm2, self.node_nm, to_nm),
            power_w: project_power(self.power_w, self.node_nm, to_nm),
            gops: self.gops * f / self.freq_ghz,
        }
    }

    /// Area efficiency, GOPS/mm².
    pub fn area_eff(&self) -> f64 {
        self.gops / self.area_mm2
    }

    /// Energy efficiency, GOPS/W.
    pub fn energy_eff(&self) -> f64 {
        self.gops / self.power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ara_projection_matches_table2() {
        // Table II: Ara reported at 22 nm (1.05 GHz, 1.20 mm², 229 mW)
        // projects to 28 nm as 0.825 GHz, 1.94 mm², 229 mW.
        let f = project_frequency(1.05, 22.0, 28.0);
        assert!((f - 0.825).abs() < 0.001, "{f}");
        let a = project_area(1.20, 22.0, 28.0);
        assert!((a - 1.94).abs() < 0.01, "{a}");
        assert_eq!(project_power(0.229, 22.0, 28.0), 0.229);
    }

    #[test]
    fn xpulpnn_projection_matches_table3() {
        // Table III: XPULPNN 22nm 23 GOPS @8b -> 18.1 projected to 28nm;
        // area eff 21.9 -> 10.6 GOPS/mm².
        let m = ReportedMetrics {
            node_nm: 22.0,
            freq_ghz: 0.4,
            area_mm2: 1.05,
            power_w: 0.0207,
            gops: 23.0,
        };
        let p = m.project(28.0);
        assert!((p.gops - 18.07).abs() < 0.1, "{}", p.gops);
        assert!((p.area_eff() - 10.6).abs() < 0.3, "{}", p.area_eff());
    }

    #[test]
    fn yun_65nm_projection_matches_table3() {
        // Yun reported at 65 nm: 22.9 GOPS -> 53.2 projected; area eff
        // 3.8 -> 48.3 GOPS/mm² (projection *improves* both at 28 nm).
        let m = ReportedMetrics {
            node_nm: 65.0,
            freq_ghz: 0.28,
            area_mm2: 6.0,
            power_w: 0.228,
            gops: 22.9,
        };
        let p = m.project(28.0);
        assert!((p.gops - 53.17).abs() < 0.2, "{}", p.gops);
        assert!((p.area_eff() - 47.8).abs() < 1.0, "{}", p.area_eff());
    }

    #[test]
    fn projection_roundtrip_identity() {
        let m = ReportedMetrics {
            node_nm: 28.0,
            freq_ghz: 1.0,
            area_mm2: 2.0,
            power_w: 0.5,
            gops: 100.0,
        };
        let p = m.project(65.0).project(28.0);
        assert!((p.gops - m.gops).abs() < 1e-9);
        assert!((p.area_mm2 - m.area_mm2).abs() < 1e-9);
    }
}
