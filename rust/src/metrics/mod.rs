//! Metrics: analytical area/power models calibrated to the paper's
//! synthesis results (Tables II/III, Fig. 13) and the technology-node
//! projection rules used in the state-of-the-art comparison.

pub mod area;
pub mod power;
pub mod scaling;

pub use area::{lane_area, speed_area, AreaBreakdown, LaneArea};
pub use power::{energy_eff, inference_energy_mj, lane_power, speed_power};
pub use scaling::{project_area, project_frequency, project_power, ReportedMetrics};
