//! Analytical area model, calibrated to the paper's synthesis results.
//!
//! Calibration points (TSMC 28 nm, TT 0.9 V, 1.05 GHz):
//! * Table II — one SPEED lane (16 KiB VRF, 2×2 MPTU) is 1.08 mm².
//! * Fig. 13(b) — lane breakdown: VRF 33 %, OP queues 21 %, OP requester
//!   16 %, ALU 13 %, MPTU 12 %, misc 5 %.
//! * Fig. 13(a) — lanes are 59 % of the processor at 4 lanes, so the
//!   non-lane front-end (VIDU, VIS, VLDU, scalar core, interconnect) is
//!   41 % ≈ 3.0 mm² at that size.
//!
//! The unit costs below are *solved from those totals once* and then used
//! to predict every other configuration (Fig. 14's DSE and Table III's
//! instance) out of sample. The lane-count-dependent front-end includes a
//! quadratic interconnect term (the VLDU multi-broadcast network and VIS
//! response fabric grow with the lane crossbar).

use crate::config::SpeedConfig;

/// Reference lane area (mm², Table II) and its Fig. 13 breakdown.
const LANE_REF_MM2: f64 = 1.08;
const FRAC_VRF: f64 = 0.33;
const FRAC_QUEUES: f64 = 0.21;
const FRAC_REQUESTER: f64 = 0.16;
const FRAC_ALU: f64 = 0.13;
const FRAC_MPTU: f64 = 0.12;
const FRAC_MISC: f64 = 0.05;

/// Reference geometry the calibration constants were solved at.
const REF_VRF_KIB: f64 = 16.0;
const REF_PES: f64 = 4.0; // 2x2
const REF_TILE_PERIM: f64 = 4.0; // TILE_R + TILE_C

/// Front-end (non-lane) area: linear sequencer/decoder cost plus a
/// quadratic broadcast-network term, solved so 4 lanes gives 3.0 mm²
/// (41 % of the paper's 4-lane instance) and area efficiency peaks at
/// 4 lanes (Fig. 14).
fn frontend_mm2(lanes: f64) -> f64 {
    1.0 + 0.30 * lanes + 0.05 * lanes * lanes
}

/// Per-component lane area for a configuration (mm² at 28 nm).
#[derive(Debug, Clone, Copy)]
pub struct LaneArea {
    /// Banked vector register file.
    pub vrf: f64,
    /// Operand/result queues.
    pub queues: f64,
    /// Operand requester.
    pub requester: f64,
    /// Vector ALU.
    pub alu: f64,
    /// Multi-precision tensor unit.
    pub mptu: f64,
    /// Everything else (control, wiring).
    pub misc: f64,
}

impl LaneArea {
    /// Total lane area, mm².
    pub fn total(&self) -> f64 {
        self.vrf + self.queues + self.requester + self.alu + self.mptu + self.misc
    }
}

/// Lane area model: VRF scales with capacity, MPTU with PE count, queues
/// and requester with the tile perimeter (operand/result port widths),
/// ALU and misc fixed per lane.
pub fn lane_area(cfg: &SpeedConfig) -> LaneArea {
    let pes = cfg.pes_per_lane() as f64;
    let perim = (cfg.tile_r + cfg.tile_c) as f64;
    LaneArea {
        vrf: LANE_REF_MM2 * FRAC_VRF * (cfg.vrf_kib as f64 / REF_VRF_KIB),
        queues: LANE_REF_MM2 * FRAC_QUEUES * (perim / REF_TILE_PERIM),
        requester: LANE_REF_MM2 * FRAC_REQUESTER * (perim / REF_TILE_PERIM),
        alu: LANE_REF_MM2 * FRAC_ALU,
        mptu: LANE_REF_MM2 * FRAC_MPTU * (pes / REF_PES),
        misc: LANE_REF_MM2 * FRAC_MISC,
    }
}

/// Full-processor area breakdown (mm² at 28 nm).
#[derive(Debug, Clone, Copy)]
pub struct AreaBreakdown {
    /// One lane's component breakdown.
    pub lane: LaneArea,
    /// All lanes together, mm².
    pub lanes_total: f64,
    /// Frontend (VIDU/VIS/VLDU + scalar interface), mm².
    pub frontend: f64,
}

impl AreaBreakdown {
    /// Total processor area, mm².
    pub fn total(&self) -> f64 {
        self.lanes_total + self.frontend
    }

    /// Fraction of the processor occupied by the lanes (Fig. 13a).
    pub fn lane_fraction(&self) -> f64 {
        self.lanes_total / self.total()
    }
}

/// Area of a full SPEED instance.
pub fn speed_area(cfg: &SpeedConfig) -> AreaBreakdown {
    let lane = lane_area(cfg);
    AreaBreakdown {
        lane,
        lanes_total: lane.total() * cfg.lanes as f64,
        frontend: frontend_mm2(cfg.lanes as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_lane_matches_table2() {
        let a = lane_area(&SpeedConfig::reference());
        assert!((a.total() - 1.08).abs() < 1e-9, "{}", a.total());
    }

    #[test]
    fn reference_breakdown_matches_fig13b() {
        let a = lane_area(&SpeedConfig::reference());
        let t = a.total();
        assert!((a.vrf / t - 0.33).abs() < 0.01);
        assert!((a.queues / t - 0.21).abs() < 0.01);
        assert!((a.requester / t - 0.16).abs() < 0.01);
        assert!((a.alu / t - 0.13).abs() < 0.01);
        assert!((a.mptu / t - 0.12).abs() < 0.01);
    }

    #[test]
    fn four_lane_instance_matches_fig13a() {
        let b = speed_area(&SpeedConfig::reference());
        // Lanes ≈ 59 % of the processor.
        assert!((b.lane_fraction() - 0.59).abs() < 0.03, "{}", b.lane_fraction());
    }

    #[test]
    fn mptu_is_tiny_fraction_of_total() {
        // Fig. 13: one MPTU ≈ 1.7 % of the total at the reference instance.
        let b = speed_area(&SpeedConfig::reference());
        let frac = b.lane.mptu / b.total();
        assert!((0.015..0.02).contains(&frac), "{frac}");
    }

    #[test]
    fn area_scales_with_geometry() {
        let small = speed_area(&SpeedConfig::dse(2, 2, 2)).total();
        let big = speed_area(&SpeedConfig::dse(8, 8, 8)).total();
        assert!(big > 2.0 * small);
        // Table III config (8x4 tiles) grows the lane relative to 2x2.
        let t3 = lane_area(&SpeedConfig::table3()).total();
        assert!(t3 > 1.08 && t3 < 4.0, "{t3}");
    }
}
