//! Analytical power model, calibrated to the paper's synthesis results.
//!
//! Calibration points (28 nm, TT 0.9 V, 1.05 GHz):
//! * Table II — one reference lane (16 KiB VRF, 2×2 MPTU) draws 71 mW
//!   (vs 229 mW for an Ara lane — the FPU removal + MPTU efficiency).
//! * Table III — the 4-lane, 8×4-tile instance draws 533 mW total, which
//!   with four 2×2-reference lanes at 71 mW fixes the per-PE increment
//!   (~1.5 mW/PE) and the front-end share (~80 mW).

use crate::config::{Precision, SpeedConfig};

/// Reference lane power (W) at 1.05 GHz and its decomposition.
const LANE_REF_W: f64 = 0.071;
const REF_PES: f64 = 4.0;
/// Incremental power per additional PE (W at 1.05 GHz).
const PE_W: f64 = 0.0015;
/// Front-end power (VIDU/VIS/VLDU/scalar core), per instance.
const FRONTEND_W: f64 = 0.080;
/// Reference frequency the constants were solved at.
const REF_GHZ: f64 = 1.05;

/// Lane power at full MPTU activity (W).
pub fn lane_power(cfg: &SpeedConfig) -> f64 {
    let pes = cfg.pes_per_lane() as f64;
    let base = LANE_REF_W - REF_PES * PE_W;
    (base + pes * PE_W) * (cfg.freq_ghz / REF_GHZ)
        * (cfg.vrf_kib as f64 / 16.0).sqrt().max(1.0)
}

/// Full-instance power at full activity (W).
pub fn speed_power(cfg: &SpeedConfig) -> f64 {
    FRONTEND_W * (cfg.freq_ghz / REF_GHZ) + cfg.lanes as f64 * lane_power(cfg)
}

/// Energy efficiency (GOPS/W) at an achieved throughput.
pub fn energy_eff(cfg: &SpeedConfig, gops: f64) -> f64 {
    gops / speed_power(cfg)
}

/// Energy per external-memory byte (pJ/B) — DRAM access energy used to
/// translate Fig. 10's traffic savings into energy (LPDDR4-class, the
/// standard edge assumption).
pub const DRAM_PJ_PER_BYTE: f64 = 40.0;

/// Energy of one inference: core energy (power × time) + DRAM traffic.
pub fn inference_energy_mj(cfg: &SpeedConfig, cycles: u64, dram_bytes: u64) -> f64 {
    let seconds = cycles as f64 / (cfg.freq_ghz * 1e9);
    let core_j = speed_power(cfg) * seconds;
    let dram_j = dram_bytes as f64 * DRAM_PJ_PER_BYTE * 1e-12;
    (core_j + dram_j) * 1e3
}

/// Peak-efficiency summary for Table III style reporting.
pub fn peak_summary(cfg: &SpeedConfig, prec: Precision, achieved_gops: f64) -> (f64, f64, f64) {
    let area = super::area::speed_area(cfg).total();
    let power = speed_power(cfg);
    let _ = prec;
    (achieved_gops, achieved_gops / area, achieved_gops / power)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_lane_matches_table2() {
        let p = lane_power(&SpeedConfig::reference());
        assert!((p - 0.071).abs() < 1e-6, "{p}");
    }

    #[test]
    fn table3_instance_power_matches_published() {
        // 4 lanes x 8x4 tiles at 1.05 GHz should land near 533 mW.
        let p = speed_power(&SpeedConfig::table3());
        assert!((0.45..0.62).contains(&p), "{p}");
    }

    #[test]
    fn energy_eff_matches_published_arithmetic() {
        // Table III: 343.1 GOPS at ~533 mW -> ~643 GOPS/W.
        let cfg = SpeedConfig::table3();
        let ee = energy_eff(&cfg, 343.1);
        assert!((550.0..750.0).contains(&ee), "{ee}");
    }

    #[test]
    fn power_scales_with_pes_and_freq() {
        let base = speed_power(&SpeedConfig::reference());
        let more_pes = speed_power(&SpeedConfig::dse(4, 8, 8));
        assert!(more_pes > base);
        let slower = speed_power(&SpeedConfig {
            freq_ghz: 0.5,
            ..SpeedConfig::reference()
        });
        assert!(slower < base);
    }

    #[test]
    fn inference_energy_accounts_for_dram() {
        let cfg = SpeedConfig::reference();
        let no_dram = inference_energy_mj(&cfg, 1_000_000, 0);
        let with_dram = inference_energy_mj(&cfg, 1_000_000, 100 << 20);
        assert!(with_dram > no_dram);
    }
}
