//! The serving metrics layer: per-request latency accounting and the
//! aggregate snapshot (`SERVE_bench.json`'s `metrics` object).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::engine::CacheStats;
use crate::obs::CycleBreakdown;
use crate::runtime::json::{jf, jstr};

use super::scenario::XorShift64;
use super::Phase;

/// Counters harvested from the scheduler under its lock.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct SchedCounters {
    pub steals: u64,
    pub affinity_hits: u64,
    pub affinity_misses: u64,
    pub kv_hits: u64,
    pub kv_misses: u64,
    pub kv_spills: u64,
    pub kv_bytes_peak: u64,
    pub max_depth: usize,
    pub avg_depth: f64,
}

/// Exact per-request latencies are kept up to this many samples; past it
/// the vector stops growing and reservoir replacement keeps a uniform
/// sample of the whole stream (a long-lived pool must not accumulate one
/// `u64` per request forever). `mean`/`max` stay exact regardless.
const LATENCY_SAMPLE_CAP: usize = 1 << 16;

#[derive(Default)]
struct Core {
    submitted: u64,
    rejected: u64,
    completed: u64,
    failed: u64,
    batches: u64,
    /// Requests that shared a batch with at least one other request.
    coalesced: u64,
    /// Online tuning searches performed by workers (`Policy::TunedOnline`
    /// executions that found no covering plan in the registry).
    tune_stalls: u64,
    /// `Policy::TunedOnline` executions served from an already-published
    /// covering plan in the shared registry.
    plan_hits: u64,
    /// Bounded latency sample (see [`LATENCY_SAMPLE_CAP`]).
    lat_us: Vec<u64>,
    /// Total finished requests observed (reservoir denominator).
    lat_seen: u64,
    /// Exact running sum and max over *all* latencies.
    lat_sum: u64,
    lat_max: u64,
    /// Per-phase bounded latency samples (same reservoir discipline) —
    /// the prefill/decode split of the transformer-serving report.
    prefill: PhaseLat,
    decode: PhaseLat,
    /// Deterministic generator for reservoir replacement.
    rng: XorShift64,
}

/// One phase's bounded latency reservoir.
#[derive(Default)]
struct PhaseLat {
    us: Vec<u64>,
    seen: u64,
}

/// Live pool counters (one mutex, touched once per request event).
pub(crate) struct ServeMetrics {
    core: Mutex<Core>,
    started: Instant,
}

impl ServeMetrics {
    pub(crate) fn new() -> Self {
        ServeMetrics { core: Mutex::new(Core::default()), started: Instant::now() }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Core> {
        self.core.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub(crate) fn record_submitted(&self) {
        self.lock().submitted += 1;
    }

    pub(crate) fn record_rejected(&self) {
        self.lock().rejected += 1;
    }

    pub(crate) fn record_batch(&self, size: u64) {
        let mut c = self.lock();
        c.batches += 1;
        if size > 1 {
            c.coalesced += size;
        }
    }

    pub(crate) fn record_tune_stall(&self) {
        self.lock().tune_stalls += 1;
    }

    pub(crate) fn record_plan_hit(&self) {
        self.lock().plan_hits += 1;
    }

    pub(crate) fn record_finished(&self, ok: bool, latency: Duration, phase: Phase) {
        let us = latency.as_micros() as u64;
        let mut c = self.lock();
        if ok {
            c.completed += 1;
        } else {
            c.failed += 1;
        }
        c.lat_seen += 1;
        c.lat_sum += us;
        c.lat_max = c.lat_max.max(us);
        if c.lat_us.len() < LATENCY_SAMPLE_CAP {
            c.lat_us.push(us);
        } else {
            // Algorithm R: replace a uniformly drawn slot with probability
            // cap / seen, keeping the sample uniform over the stream.
            let seen = c.lat_seen;
            let idx = c.rng.below(seen) as usize;
            if idx < LATENCY_SAMPLE_CAP {
                c.lat_us[idx] = us;
            }
        }
        // Per-phase reservoir under the same discipline.
        let seen = {
            let p = match phase {
                Phase::Prefill => &mut c.prefill,
                Phase::Decode => &mut c.decode,
            };
            p.seen += 1;
            p.seen
        };
        let idx = if seen as usize > LATENCY_SAMPLE_CAP {
            Some(c.rng.below(seen) as usize)
        } else {
            None
        };
        let p = match phase {
            Phase::Prefill => &mut c.prefill,
            Phase::Decode => &mut c.decode,
        };
        match idx {
            None => p.us.push(us),
            Some(i) if i < LATENCY_SAMPLE_CAP => p.us[i] = us,
            Some(_) => {}
        }
    }

    pub(crate) fn snapshot(
        &self,
        workers: usize,
        sched: SchedCounters,
        cache: CacheStats,
        precision_switches: u64,
        compiled_programs: usize,
        breakdown: CycleBreakdown,
        counters: Vec<(&'static str, u64)>,
    ) -> MetricsSnapshot {
        let wall_s = self.started.elapsed().as_secs_f64();
        // Copy out under the lock; the O(n log n) sort happens outside it
        // so the completion hot path is never stalled behind a snapshot.
        struct Scalars {
            submitted: u64,
            rejected: u64,
            completed: u64,
            failed: u64,
            batches: u64,
            coalesced: u64,
            tune_stalls: u64,
            plan_hits: u64,
            lat_seen: u64,
            lat_sum: u64,
            lat_max: u64,
            prefill_seen: u64,
            decode_seen: u64,
        }
        let (c, mut sorted, mut pre_sorted, mut dec_sorted) = {
            let c = self.lock();
            (
                Scalars {
                    submitted: c.submitted,
                    rejected: c.rejected,
                    completed: c.completed,
                    failed: c.failed,
                    batches: c.batches,
                    coalesced: c.coalesced,
                    tune_stalls: c.tune_stalls,
                    plan_hits: c.plan_hits,
                    lat_seen: c.lat_seen,
                    lat_sum: c.lat_sum,
                    lat_max: c.lat_max,
                    prefill_seen: c.prefill.seen,
                    decode_seen: c.decode.seen,
                },
                c.lat_us.clone(),
                c.prefill.us.clone(),
                c.decode.us.clone(),
            )
        };
        sorted.sort_unstable();
        pre_sorted.sort_unstable();
        dec_sorted.sort_unstable();
        let mean_us = if c.lat_seen == 0 {
            0.0
        } else {
            c.lat_sum as f64 / c.lat_seen as f64
        };
        MetricsSnapshot {
            workers,
            submitted: c.submitted,
            rejected: c.rejected,
            completed: c.completed,
            failed: c.failed,
            in_flight: c.submitted.saturating_sub(c.completed + c.failed),
            batches: c.batches,
            coalesced: c.coalesced,
            tune_stalls: c.tune_stalls,
            plan_hits: c.plan_hits,
            wall_s,
            throughput_rps: if wall_s > 0.0 {
                (c.completed + c.failed) as f64 / wall_s
            } else {
                0.0
            },
            p50_us: percentile_us(&sorted, 0.50),
            p95_us: percentile_us(&sorted, 0.95),
            p99_us: percentile_us(&sorted, 0.99),
            max_us: c.lat_max,
            mean_us,
            prefill_requests: c.prefill_seen,
            decode_requests: c.decode_seen,
            prefill_p50_us: percentile_us(&pre_sorted, 0.50),
            prefill_p95_us: percentile_us(&pre_sorted, 0.95),
            prefill_p99_us: percentile_us(&pre_sorted, 0.99),
            decode_p50_us: percentile_us(&dec_sorted, 0.50),
            decode_p95_us: percentile_us(&dec_sorted, 0.95),
            decode_p99_us: percentile_us(&dec_sorted, 0.99),
            queue_max_depth: sched.max_depth,
            queue_avg_depth: sched.avg_depth,
            steals: sched.steals,
            affinity_hits: sched.affinity_hits,
            affinity_misses: sched.affinity_misses,
            kv_hits: sched.kv_hits,
            kv_misses: sched.kv_misses,
            kv_spills: sched.kv_spills,
            kv_bytes_peak: sched.kv_bytes_peak,
            cache,
            compiled_programs,
            precision_switches,
            breakdown,
            counters,
        }
    }
}

/// Nearest-rank percentile over an already-sorted latency vector.
///
/// Uses the textbook nearest-rank definition: the q-th percentile of n
/// samples is the element at 1-based rank `ceil(q·n)` — e.g. q = 0.5 over
/// `1..=100` is 50 (not the rounded-linear-interpolation 51 this function
/// once returned). `q <= 0` returns the minimum, `q >= 1` the maximum.
pub fn percentile_us(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// A point-in-time aggregate view of a pool.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Worker engines in the pool.
    pub workers: usize,
    /// Requests admitted to the queue.
    pub submitted: u64,
    /// `try_submit` calls refused for lack of queue space.
    pub rejected: u64,
    /// Requests finished successfully.
    pub completed: u64,
    /// Requests that finished with an error.
    pub failed: u64,
    /// Admitted but not yet finished.
    pub in_flight: u64,
    /// Micro-batches executed (a lone request is a batch of one).
    pub batches: u64,
    /// Requests that shared a batch with at least one other request.
    pub coalesced: u64,
    /// Online tuning searches performed by workers — `Policy::TunedOnline`
    /// executions that found no covering plan in the shared registry and
    /// tuned on the spot (the *tune stall* of the online-tuning loop).
    /// Serialized same-key traffic pays exactly one stall per `(model,
    /// precision, config-sig)` key; simultaneous first requests on
    /// different workers may each tune before the first publish lands
    /// (deterministic and merge-resolved — wasted wall time, never wrong
    /// results), in which case each search is counted.
    pub tune_stalls: u64,
    /// `Policy::TunedOnline` executions served from an already-published
    /// covering plan in the shared [`TunedPlans`](crate::tune::TunedPlans)
    /// registry.
    pub plan_hits: u64,
    /// Seconds since the pool started.
    pub wall_s: f64,
    /// Finished requests per second of pool lifetime.
    pub throughput_rps: f64,
    /// Median request latency, µs.
    pub p50_us: u64,
    /// 95th-percentile request latency, µs.
    pub p95_us: u64,
    /// 99th-percentile request latency, µs.
    pub p99_us: u64,
    /// Worst request latency, µs.
    pub max_us: u64,
    /// Mean request latency, µs.
    pub mean_us: f64,
    /// Finished requests accounted under [`Phase::Prefill`] (stateless
    /// requests included — prefill is the default phase).
    pub prefill_requests: u64,
    /// Finished requests accounted under [`Phase::Decode`].
    pub decode_requests: u64,
    /// Median prefill latency, µs (0 when no prefill finished).
    pub prefill_p50_us: u64,
    /// 95th-percentile prefill latency, µs.
    pub prefill_p95_us: u64,
    /// 99th-percentile prefill latency, µs.
    pub prefill_p99_us: u64,
    /// Median decode-step latency, µs (0 when no decode finished).
    pub decode_p50_us: u64,
    /// 95th-percentile decode-step latency, µs.
    pub decode_p95_us: u64,
    /// 99th-percentile decode-step latency, µs.
    pub decode_p99_us: u64,
    /// Deepest total queue observed at routing time.
    pub queue_max_depth: usize,
    /// Mean total queue depth observed at routing time.
    pub queue_avg_depth: f64,
    /// Requests a worker stole from another lane's queue.
    pub steals: u64,
    /// Requests routed to a lane already at their precision.
    pub affinity_hits: u64,
    /// Requests routed to a lane at a different precision.
    pub affinity_misses: u64,
    /// Decode steps routed to the lane holding their session's KV-cache
    /// residency.
    pub kv_hits: u64,
    /// Decode steps whose session had no residency (first decode without
    /// a prefill, or evicted by a spill) — re-installed where routed.
    pub kv_misses: u64,
    /// Sessions evicted from a lane's KV budget (LRU) to admit another.
    pub kv_spills: u64,
    /// Largest KV residency observed on any one worker, bytes.
    pub kv_bytes_peak: u64,
    /// Pool-wide program-cache counters (summed over workers).
    pub cache: CacheStats,
    /// Distinct compiled programs resident across workers (sum of private
    /// caches; shared-cache reuse makes this ≥ the distinct-key count).
    pub compiled_programs: usize,
    /// Aggregate *datapath* precision switches across all workers —
    /// including the request-boundary switches the affinity scheduler
    /// exists to minimize (per-request stats exclude them; see the
    /// `serve` module docs).
    pub precision_switches: u64,
    /// Pool-wide cycle attribution summed over worker engines: where the
    /// served cycles went (components sum to the total simulated cycles
    /// across workers exactly).
    pub breakdown: CycleBreakdown,
    /// Unified counter-registry snapshot in [`Counter::ALL`] order —
    /// engine/tune/verify counters fed live by workers, scheduler
    /// counters mirrored in at snapshot time.
    ///
    /// [`Counter::ALL`]: crate::obs::Counter::ALL
    pub counters: Vec<(&'static str, u64)>,
}

impl MetricsSnapshot {
    /// Look up one unified-registry counter by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| *n == name)
            .map(|&(_, v)| v)
            .unwrap_or(0)
    }

    /// Fraction of routed requests that landed on a precision-matched lane.
    pub fn affinity_rate(&self) -> f64 {
        let n = self.affinity_hits + self.affinity_misses;
        if n == 0 {
            return 0.0;
        }
        self.affinity_hits as f64 / n as f64
    }

    /// Serialize as a JSON object (embedded in `SERVE_bench.json` under
    /// `"metrics"`). `indent` is prepended to every inner line.
    pub fn json_object(&self, indent: &str) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str("{\n");
        let mut field = |k: &str, v: String, last: bool| {
            s.push_str(&format!("{indent}  {}: {}{}\n", jstr(k), v, if last { "" } else { "," }));
        };
        field("workers", self.workers.to_string(), false);
        field("submitted", self.submitted.to_string(), false);
        field("rejected", self.rejected.to_string(), false);
        field("completed", self.completed.to_string(), false);
        field("failed", self.failed.to_string(), false);
        field("in_flight", self.in_flight.to_string(), false);
        field("batches", self.batches.to_string(), false);
        field("coalesced", self.coalesced.to_string(), false);
        field("wall_s", jf(self.wall_s), false);
        field("throughput_rps", jf(self.throughput_rps), false);
        field(
            "latency_us",
            format!(
                "{{ \"p50\": {}, \"p95\": {}, \"p99\": {}, \"max\": {}, \"mean\": {} }}",
                self.p50_us,
                self.p95_us,
                self.p99_us,
                self.max_us,
                jf(self.mean_us)
            ),
            false,
        );
        field("prefill_requests", self.prefill_requests.to_string(), false);
        field("decode_requests", self.decode_requests.to_string(), false);
        field(
            "prefill_latency_us",
            format!(
                "{{ \"p50\": {}, \"p95\": {}, \"p99\": {} }}",
                self.prefill_p50_us, self.prefill_p95_us, self.prefill_p99_us
            ),
            false,
        );
        field(
            "decode_latency_us",
            format!(
                "{{ \"p50\": {}, \"p95\": {}, \"p99\": {} }}",
                self.decode_p50_us, self.decode_p95_us, self.decode_p99_us
            ),
            false,
        );
        field(
            "queue",
            format!(
                "{{ \"max_depth\": {}, \"avg_depth\": {} }}",
                self.queue_max_depth,
                jf(self.queue_avg_depth)
            ),
            false,
        );
        field("steals", self.steals.to_string(), false);
        field("tune_stalls", self.tune_stalls.to_string(), false);
        field("plan_hits", self.plan_hits.to_string(), false);
        field("affinity_hits", self.affinity_hits.to_string(), false);
        field("affinity_misses", self.affinity_misses.to_string(), false);
        field("affinity_rate", jf(self.affinity_rate()), false);
        field("kv_hits", self.kv_hits.to_string(), false);
        field("kv_misses", self.kv_misses.to_string(), false);
        field("kv_spills", self.kv_spills.to_string(), false);
        field("kv_bytes_peak", self.kv_bytes_peak.to_string(), false);
        field(
            "cache",
            format!(
                "{{ \"hits\": {}, \"misses\": {}, \"shared_hits\": {}, \"hit_rate\": {} }}",
                self.cache.hits,
                self.cache.misses,
                self.cache.shared_hits,
                jf(self.cache.hit_rate())
            ),
            false,
        );
        field("compiled_programs", self.compiled_programs.to_string(), false);
        field("precision_switches", self.precision_switches.to_string(), false);
        field(
            "breakdown",
            {
                let parts: Vec<String> = CycleBreakdown::NAMES
                    .iter()
                    .zip(self.breakdown.components())
                    .map(|(n, v)| format!("\"{n}\": {v}"))
                    .collect();
                format!("{{ {} }}", parts.join(", "))
            },
            false,
        );
        field(
            "counters",
            {
                let parts: Vec<String> = self
                    .counters
                    .iter()
                    .map(|(n, v)| format!("\"{n}\": {v}"))
                    .collect();
                format!("{{ {} }}", parts.join(", "))
            },
            true,
        );
        s.push_str(&format!("{indent}}}"));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::json::{parse, Json};

    #[test]
    fn percentiles_nearest_rank() {
        assert_eq!(percentile_us(&[], 0.5), 0);
        assert_eq!(percentile_us(&[7], 0.99), 7);
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_us(&v, 0.0), 1);
        assert_eq!(percentile_us(&v, 0.50), 50); // ceil(0.5*100)=50 -> v[49]
        assert_eq!(percentile_us(&v, 0.95), 95);
        assert_eq!(percentile_us(&v, 0.99), 99);
        assert_eq!(percentile_us(&v, 1.0), 100);
        // Odd-length vector: ceil picks the true median, never past-end.
        let odd: Vec<u64> = (1..=5).map(|i| i * 100).collect();
        assert_eq!(percentile_us(&odd, 0.5), 300);
        assert_eq!(percentile_us(&odd, 0.2), 100);
        assert_eq!(percentile_us(&odd, 0.21), 200);
    }

    #[test]
    fn latency_sample_is_bounded_but_mean_max_exact() {
        let m = ServeMetrics::new();
        let n = LATENCY_SAMPLE_CAP as u64 + 8_192;
        for i in 0..n {
            m.record_finished(true, Duration::from_micros(i + 1), Phase::Prefill);
        }
        let snap = m.snapshot(
            1,
            SchedCounters::default(),
            CacheStats::default(),
            0,
            0,
            CycleBreakdown::default(),
            Vec::new(),
        );
        assert_eq!(snap.completed, n);
        // Exact even past the sample cap.
        assert_eq!(snap.max_us, n);
        assert!((snap.mean_us - (n + 1) as f64 / 2.0).abs() < 1.0);
        // Percentiles come from the bounded uniform sample: ordered and
        // inside the observed range.
        assert!(snap.p50_us >= 1 && snap.p50_us <= n);
        assert!(snap.p50_us <= snap.p95_us);
        assert!(snap.p95_us <= snap.p99_us);
        assert!(snap.p99_us <= snap.max_us);
    }

    #[test]
    fn snapshot_counts_and_json_parse() {
        let m = ServeMetrics::new();
        for _ in 0..5 {
            m.record_submitted();
        }
        m.record_rejected();
        m.record_batch(3);
        m.record_batch(1);
        m.record_tune_stall();
        m.record_plan_hit();
        m.record_plan_hit();
        for i in 0..4 {
            m.record_finished(true, Duration::from_micros(100 * (i + 1)), Phase::Prefill);
        }
        m.record_finished(false, Duration::from_micros(900), Phase::Decode);
        let snap = m.snapshot(
            2,
            SchedCounters {
                steals: 1,
                affinity_hits: 3,
                affinity_misses: 2,
                kv_hits: 5,
                kv_misses: 1,
                kv_spills: 2,
                kv_bytes_peak: 4096,
                max_depth: 4,
                avg_depth: 2.0,
            },
            CacheStats { hits: 8, misses: 2, shared_hits: 4 },
            7,
            2,
            CycleBreakdown { chain: 90, load: 8, overhead: 2, ..Default::default() },
            vec![("engine_cache_hits", 8), ("tune_stalls", 1)],
        );
        assert_eq!(snap.submitted, 5);
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.in_flight, 0);
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.coalesced, 3);
        assert_eq!(snap.tune_stalls, 1);
        assert_eq!(snap.plan_hits, 2);
        assert_eq!(snap.p50_us, 300);
        assert_eq!(snap.max_us, 900);
        assert!((snap.affinity_rate() - 0.6).abs() < 1e-12);
        assert!(snap.throughput_rps > 0.0);

        let doc = parse(&snap.json_object("")).unwrap();
        assert_eq!(doc.get("completed").and_then(Json::as_i64), Some(4));
        assert_eq!(
            doc.get("latency_us").and_then(|l| l.get("p99")).and_then(Json::as_i64),
            Some(900)
        );
        assert_eq!(
            doc.get("cache").and_then(|c| c.get("shared_hits")).and_then(Json::as_i64),
            Some(4)
        );
        assert_eq!(doc.get("precision_switches").and_then(Json::as_i64), Some(7));
        assert_eq!(doc.get("tune_stalls").and_then(Json::as_i64), Some(1));
        assert_eq!(doc.get("plan_hits").and_then(Json::as_i64), Some(2));
        // Phase split + KV residency counters (schema-2 additions).
        assert_eq!(snap.prefill_requests, 4);
        assert_eq!(snap.decode_requests, 1);
        assert_eq!(snap.prefill_p99_us, 400);
        assert_eq!(snap.decode_p50_us, 900);
        assert_eq!(doc.get("prefill_requests").and_then(Json::as_i64), Some(4));
        assert_eq!(doc.get("decode_requests").and_then(Json::as_i64), Some(1));
        assert_eq!(
            doc.get("decode_latency_us").and_then(|l| l.get("p50")).and_then(Json::as_i64),
            Some(900)
        );
        assert_eq!(doc.get("kv_hits").and_then(Json::as_i64), Some(5));
        assert_eq!(doc.get("kv_misses").and_then(Json::as_i64), Some(1));
        assert_eq!(doc.get("kv_spills").and_then(Json::as_i64), Some(2));
        assert_eq!(doc.get("kv_bytes_peak").and_then(Json::as_i64), Some(4096));
        // Schema-3 additions: cycle attribution + unified counters.
        assert_eq!(
            doc.get("breakdown").and_then(|b| b.get("chain")).and_then(Json::as_i64),
            Some(90)
        );
        assert_eq!(
            doc.get("counters")
                .and_then(|c| c.get("engine_cache_hits"))
                .and_then(Json::as_i64),
            Some(8)
        );
    }
}
