//! JSON scenario files: reproducible serving workloads.
//!
//! A scenario describes a request stream — model mix, precision mix, and
//! a deterministic arrival pattern — with **no wall-clock dependence**:
//! the request sequence is a pure function of the scenario seed, and the
//! arrival pattern is expressed in virtual ticks (the submitter yields
//! the CPU between ticks instead of sleeping), so any two runs of the
//! same file replay the identical workload. Committed scenarios live in
//! `bench/scenarios/*.json` and drive `repro serve-bench`.
//!
//! ```json
//! {
//!   "version": 2,
//!   "name": "mixed_edge",
//!   "seed": 42,
//!   "requests": 64,
//!   "capacity": 32,
//!   "max_batch": 8,
//!   "arrival": { "pattern": "burst", "size": 8 },
//!   "mix": [
//!     { "model": "mobilenetv2", "prec": 8, "weight": 3, "downscale": 2 },
//!     { "model": "vit_tiny", "prec": 4, "weight": 2, "downscale": 2 },
//!     { "op": "mm", "m": 64, "k": 64, "n": 64, "prec": 16, "weight": 2 },
//!     { "llm": "llm_tiny", "prompt": 64, "decode": 8, "prec": 8, "weight": 1 }
//!   ]
//! }
//! ```
//!
//! # Schema versioning
//!
//! The top-level `"version"` field names the schema the file was written
//! against. Files without it load as version 1 (the documented default —
//! every pre-versioning scenario keeps working); versions this build does
//! not understand fail fast with a typed [`SpeedError::Parse`]. The
//! current schema is [`SCENARIO_VERSION`] = 2, which adds `"llm"` mix
//! entries; an `"llm"` entry in a version-1 document is a parse error
//! naming the required version.
//!
//! Mix entries are drawn per request with probability proportional to
//! `weight`. Model entries accept `downscale` (spatial/token reduction
//! via the Fig. 12 harness) and `policy`
//! (`mixed|ffcs|cf|ff|tuned|tuned_online`); operator
//! entries accept the dimensions of their kind (`mm`: `m,k,n`; `conv`:
//! `c,f,h,w,ksize[,stride,pad]`; `pwcv`: `c,f,h,w`; `dwcv`:
//! `c,h,w,ksize[,stride,pad]`) and an optional explicit `strat`.
//!
//! `"llm"` entries (version 2) name a zoo LLM spec and describe one
//! autoregressive *session* per draw: a `prompt`-token prefill request
//! followed by `decode` single-token decode-step requests with growing
//! KV length, all sharing a [`SessionId`](super::SessionId) so the pool
//! pins the decode tail to the lane holding the session's KV-cache
//! residency.

use std::path::Path;

use crate::config::Precision;
use crate::coordinator::Policy;
use crate::dataflow;
use crate::error::{Result, SpeedError};
use crate::isa::StrategyKind;
use crate::models::zoo::{llm_spec, model_by_name, LlmSpec, LLM_DEFAULT_TOKENS, MODELS};
use crate::models::OpDesc;
use crate::report::fig12::downscale;
use crate::runtime::json::{parse, Json};

use super::{Phase, Request, RequestKind, SessionId};

/// Quick mode caps the generated request count at this many.
pub const QUICK_REQUEST_CAP: usize = 24;
/// Quick mode multiplies every model entry's downscale factor by this
/// (and divides llm prompt lengths by it).
pub const QUICK_DOWNSCALE: u32 = 4;
/// Newest scenario schema version this parser understands. Version 1 is
/// the pre-versioning schema (the default when `"version"` is absent);
/// version 2 adds `"llm"` mix entries.
pub const SCENARIO_VERSION: u32 = 2;

fn perr(m: impl Into<String>) -> SpeedError {
    SpeedError::Parse(m.into())
}

/// xorshift64* — the tiny deterministic generator behind scenario
/// request streams (seed-stable across platforms and releases).
#[derive(Debug, Clone)]
pub struct XorShift64(u64);

impl Default for XorShift64 {
    fn default() -> Self {
        Self::new(0)
    }
}

impl XorShift64 {
    /// Seed the generator (any value, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        // Splitmix-style scramble keeps low-entropy seeds (0, 1, 2...)
        // from producing correlated streams; `| 1` keeps the state
        // nonzero.
        XorShift64(
            seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(0x2545_F491_4F6C_DD1D)
                | 1,
        )
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform draw in `0..n` (n = 0 yields 0).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.next_u64() % n
    }
}

/// Deterministic arrival pattern, in virtual ticks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// A steady trickle: `per_tick` requests, then one quiet tick.
    Steady { per_tick: u32 },
    /// Bursty traffic: `size` back-to-back requests, then a quiet period
    /// of `size` ticks (the deeper gap is what distinguishes a burst from
    /// a steady trickle at the same average rate).
    Burst { size: u32 },
    /// Seeded random gaps of `0..=max_gap` empty ticks between requests.
    Random { max_gap: u32 },
}

impl Arrival {
    /// How many virtual ticks (submitter yields) follow request `i`.
    pub fn yields_after(&self, i: usize, rng: &mut XorShift64) -> u32 {
        match *self {
            Arrival::Steady { per_tick } => {
                u32::from((i as u64 + 1) % per_tick.max(1) as u64 == 0)
            }
            Arrival::Burst { size } => {
                let size = size.max(1);
                if (i as u64 + 1) % size as u64 == 0 {
                    size
                } else {
                    0
                }
            }
            Arrival::Random { max_gap } => rng.below(max_gap as u64 + 1) as u32,
        }
    }
}

/// What a mix entry instantiates.
#[derive(Debug, Clone)]
pub enum Workload {
    /// A zoo model by name, optionally downscaled (Fig. 12 harness).
    Model { name: String, downscale: u32 },
    /// A single operator (stored at its scenario precision).
    Op(OpDesc),
    /// An autoregressive LLM session (scenario `"version": 2`): one draw
    /// emits a `prompt`-token prefill request plus `decode` single-token
    /// decode-step requests with growing KV length, all carrying the same
    /// freshly numbered [`SessionId`](super::SessionId).
    Llm {
        /// The zoo LLM architecture the session runs.
        spec: LlmSpec,
        /// Prompt tokens the prefill request processes (divided by
        /// [`QUICK_DOWNSCALE`] in quick mode, floor 1).
        prompt: u32,
        /// Decode steps emitted after the prefill.
        decode: u32,
    },
}

/// One weighted line of the workload mix.
#[derive(Debug, Clone)]
pub struct MixEntry {
    /// What the entry instantiates.
    pub workload: Workload,
    /// Precision requests from this entry run at.
    pub prec: Precision,
    /// Relative draw weight within the mix.
    pub weight: u32,
    /// Strategy-selection policy for model entries.
    pub policy: Policy,
    /// Explicit dataflow strategy for operator entries (default: the
    /// operator's preferred strategy).
    pub strat: Option<StrategyKind>,
}

impl MixEntry {
    /// Materialize one request from a model or operator entry (LLM
    /// entries expand to whole sessions via [`MixEntry::emit`]).
    fn instantiate(&self, quick: bool) -> Result<RequestKind> {
        match &self.workload {
            Workload::Model { name, downscale: d } => {
                let model = model_by_name(name).ok_or_else(|| {
                    perr(format!("unknown model '{name}' in scenario ({MODELS:?})"))
                })?;
                let f = (*d).max(1) * if quick { QUICK_DOWNSCALE } else { 1 };
                let model = if f > 1 { downscale(&model, f) } else { model };
                Ok(RequestKind::Model { model, prec: self.prec, policy: self.policy })
            }
            Workload::Op(op) => {
                let op = OpDesc { prec: self.prec, ..*op };
                let strat = self.strat.unwrap_or_else(|| op.preferred_strategy());
                Ok(RequestKind::Op { op, strat })
            }
            Workload::Llm { .. } => Err(perr(
                "llm entries expand to sessions, not single requests (internal)",
            )),
        }
    }

    /// Append every request one draw of this entry emits: one request for
    /// model/op entries, a whole prefill-plus-decode session for llm
    /// entries (numbered from `sessions`, which advances per session).
    fn emit(&self, quick: bool, sessions: &mut u64, out: &mut Vec<Request>) -> Result<()> {
        let Workload::Llm { spec, prompt, decode } = &self.workload else {
            out.push(Request::from(self.instantiate(quick)?));
            return Ok(());
        };
        let prompt = if quick { (prompt / QUICK_DOWNSCALE).max(1) } else { *prompt };
        let sid = SessionId(*sessions);
        *sessions += 1;
        out.push(
            Request::model(spec.prefill(self.prec, prompt))
                .prec(self.prec)
                .policy(self.policy)
                .session(sid)
                .kv(spec.kv_bytes(self.prec, prompt)),
        );
        for i in 0..*decode {
            // Decode step i attends over `prompt + i` cached tokens and
            // appends one more — the residency charge is the post-step
            // cache size.
            let kv_len = prompt + i;
            out.push(
                Request::model(spec.decode_step(self.prec, kv_len))
                    .prec(self.prec)
                    .policy(self.policy)
                    .session(sid)
                    .phase(Phase::Decode)
                    .kv(spec.kv_bytes(self.prec, kv_len + 1)),
            );
        }
        Ok(())
    }
}

/// A parsed scenario file.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Schema version the document declared (1 when absent).
    pub version: u32,
    /// Scenario name (from the document or the file stem).
    pub name: String,
    /// RNG seed driving arrivals and mix draws.
    pub seed: u64,
    /// Requests to generate (capped at [`QUICK_REQUEST_CAP`] in quick
    /// mode). Counts *emitted* requests: an llm draw contributes its
    /// prefill and every decode step, and the last session may be
    /// truncated mid-decode to land exactly on this count.
    pub requests: usize,
    /// Pool queue bound override (None = the pool default).
    pub capacity: Option<usize>,
    /// Micro-batch cap override (None = the pool default).
    pub max_batch: Option<usize>,
    /// Arrival pattern of the generated requests.
    pub arrival: Arrival,
    /// Weighted workload mix.
    pub mix: Vec<MixEntry>,
}

impl Scenario {
    /// Parse a scenario document, failing fast on unknown models, invalid
    /// operators, or inapplicable strategies.
    pub fn from_json(src: &str) -> Result<Scenario> {
        let doc = parse(src)?;
        if doc.as_obj().is_none() {
            return Err(perr("scenario must be a JSON object"));
        }
        let version = match doc.get("version") {
            // Pre-versioning files carry no field: the documented
            // default is version 1 and they keep loading unchanged.
            None => 1,
            Some(v) => v
                .as_i64()
                .filter(|&n| n >= 1 && n <= SCENARIO_VERSION as i64)
                .ok_or_else(|| {
                    perr(format!(
                        "unsupported scenario \"version\" (this build reads 1..={SCENARIO_VERSION})"
                    ))
                })? as u32,
        };
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("unnamed")
            .to_string();
        let seed = doc.get("seed").and_then(Json::as_i64).unwrap_or(1) as u64;
        let requests = doc
            .get("requests")
            .and_then(Json::as_i64)
            .filter(|&n| n >= 1)
            .ok_or_else(|| perr("scenario needs a positive integer \"requests\""))?
            as usize;
        let capacity = opt_pos(&doc, "capacity")?;
        let max_batch = opt_pos(&doc, "max_batch")?;
        let arrival = parse_arrival(doc.get("arrival"))?;
        let mix_json = doc
            .get("mix")
            .and_then(Json::as_arr)
            .filter(|a| !a.is_empty())
            .ok_or_else(|| perr("scenario needs a non-empty \"mix\" array"))?;
        let mut mix = Vec::with_capacity(mix_json.len());
        for entry in mix_json {
            mix.push(parse_mix_entry(entry)?);
        }
        let sc =
            Scenario { version, name, seed, requests, capacity, max_batch, arrival, mix };
        // `"llm"` entries are a version-2 construct: a version-1 document
        // using one is missing the required field, not quietly upgraded.
        if sc.version < 2
            && sc.mix.iter().any(|e| matches!(e.workload, Workload::Llm { .. }))
        {
            return Err(perr("\"llm\" mix entries require \"version\": 2"));
        }
        // Fail at parse time, not mid-bench. A weight of 0 disables one
        // entry; all-zero weights leave the weighted pick with nothing to
        // draw (`rng.below(0)` degenerates and the pick panics at bench
        // time), so the sum is rejected here with the fail-fast Parse
        // error every other malformed field gets.
        if sc.mix.iter().map(|e| e.weight as u64).sum::<u64>() == 0 {
            return Err(perr("mix weights sum to zero (no entry can be drawn)"));
        }
        // Every entry must emit, even zero-weight (disabled) ones.
        for e in &sc.mix {
            let (mut sessions, mut probe) = (0, Vec::new());
            e.emit(false, &mut sessions, &mut probe)?;
        }
        Ok(sc)
    }

    /// Load a scenario file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Scenario> {
        let path = path.as_ref();
        let src = std::fs::read_to_string(path)
            .map_err(|e| perr(format!("reading scenario {}: {e}", path.display())))?;
        Self::from_json(&src)
    }

    /// Generate the deterministic request stream: same seed, same stream,
    /// on every platform and every run. Llm draws emit whole sessions
    /// (prefill plus decode steps), so generation draws until `requests`
    /// requests exist and truncates the final session if it overshoots.
    pub fn generate(&self, quick: bool) -> Result<Vec<Request>> {
        let total_weight: u64 = self.mix.iter().map(|e| e.weight as u64).sum();
        // `from_json` rejects this, but `Scenario` is a plain public
        // struct: a hand-built instance must fail typed, not panic.
        if total_weight == 0 {
            return Err(perr("mix weights sum to zero (no entry can be drawn)"));
        }
        let n = if quick { self.requests.min(QUICK_REQUEST_CAP) } else { self.requests };
        let mut rng = XorShift64::new(self.seed);
        let mut sessions = 0u64;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let mut pick = rng.below(total_weight);
            let entry = self
                .mix
                .iter()
                .find(|e| {
                    if pick < e.weight as u64 {
                        true
                    } else {
                        pick -= e.weight as u64;
                        false
                    }
                })
                .expect("weights are positive and sum over the mix");
            entry.emit(quick, &mut sessions, &mut out)?;
        }
        out.truncate(n);
        Ok(out)
    }
}

fn opt_pos(doc: &Json, key: &str) -> Result<Option<usize>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_i64()
            .filter(|&n| n >= 1)
            .map(|n| Some(n as usize))
            .ok_or_else(|| perr(format!("\"{key}\" must be a positive integer"))),
    }
}

fn parse_arrival(j: Option<&Json>) -> Result<Arrival> {
    let Some(a) = j else {
        return Ok(Arrival::Steady { per_tick: 1 });
    };
    let pattern = a
        .get("pattern")
        .and_then(Json::as_str)
        .ok_or_else(|| perr("\"arrival\" needs a \"pattern\" string"))?;
    let field = |k: &str, default: u32| -> Result<u32> {
        match a.get(k) {
            None => Ok(default),
            Some(v) => v
                .as_i64()
                .filter(|&n| n >= 1 && n <= u32::MAX as i64)
                .map(|n| n as u32)
                .ok_or_else(|| {
                    perr(format!("arrival \"{k}\" must be a positive 32-bit integer"))
                }),
        }
    };
    match pattern {
        "steady" => Ok(Arrival::Steady { per_tick: field("per_tick", 1)? }),
        "burst" => Ok(Arrival::Burst { size: field("size", 8)? }),
        "random" => Ok(Arrival::Random { max_gap: field("max_gap", 3)? }),
        other => Err(perr(format!(
            "unknown arrival pattern '{other}' (steady|burst|random)"
        ))),
    }
}

fn parse_policy(s: &str) -> Result<Policy> {
    match s {
        "mixed" => Ok(Policy::Mixed),
        "ffcs" => Ok(Policy::Fixed(StrategyKind::Ffcs)),
        "cf" => Ok(Policy::Fixed(StrategyKind::Cf)),
        "ff" => Ok(Policy::Fixed(StrategyKind::Ff)),
        // Serve from the pool's tuned-plan registry (falls back to the
        // static mixed mapping for operators without a tuned entry).
        "tuned" => Ok(Policy::Tuned),
        // Online first-request tuning: an uncovered (model, precision,
        // config-sig) key tunes on the owning worker and publishes the
        // plan to the pool's shared registry for every later request.
        "tuned_online" => Ok(Policy::TunedOnline),
        other => Err(perr(format!(
            "unknown policy '{other}' (mixed|ffcs|cf|ff|tuned|tuned_online)"
        ))),
    }
}

fn parse_strat(s: &str) -> Result<StrategyKind> {
    match s {
        "mm" => Ok(StrategyKind::Mm),
        "ffcs" => Ok(StrategyKind::Ffcs),
        "cf" => Ok(StrategyKind::Cf),
        "ff" => Ok(StrategyKind::Ff),
        other => Err(perr(format!("unknown strategy '{other}' (mm|ffcs|cf|ff)"))),
    }
}

fn parse_mix_entry(e: &Json) -> Result<MixEntry> {
    let prec_bits = e
        .get("prec")
        .and_then(Json::as_i64)
        .ok_or_else(|| perr("mix entry needs integer \"prec\" (16|8|4)"))?;
    let prec = Precision::from_bits(prec_bits as u32)
        .ok_or_else(|| perr(format!("bad precision {prec_bits} (16|8|4)")))?;
    let weight = match e.get("weight") {
        None => 1,
        Some(v) => v
            .as_i64()
            .filter(|&n| n >= 0 && n <= u32::MAX as i64)
            .map(|n| n as u32)
            .ok_or_else(|| {
                perr("mix \"weight\" must be a non-negative 32-bit integer")
            })?,
    };
    let policy = match e.get("policy").and_then(Json::as_str) {
        None => Policy::Mixed,
        Some(p) => parse_policy(p)?,
    };

    if let Some(name) = e.get("llm").and_then(Json::as_str) {
        let spec = llm_spec(name)
            .ok_or_else(|| perr(format!("unknown llm spec '{name}' (try \"llm_tiny\")")))?;
        let count = |k: &str, default: u32| -> Result<u32> {
            match e.get(k) {
                None => Ok(default),
                Some(v) => v
                    .as_i64()
                    .filter(|&n| n >= 1 && n <= u32::MAX as i64)
                    .map(|n| n as u32)
                    .ok_or_else(|| {
                        perr(format!("llm \"{k}\" must be a positive 32-bit integer"))
                    }),
            }
        };
        let prompt = count("prompt", LLM_DEFAULT_TOKENS)?;
        let decode = count("decode", 8)?;
        return Ok(MixEntry {
            workload: Workload::Llm { spec, prompt, decode },
            prec,
            weight,
            policy,
            strat: None,
        });
    }

    if let Some(name) = e.get("model").and_then(Json::as_str) {
        if model_by_name(name).is_none() {
            return Err(perr(format!("unknown model '{name}' ({MODELS:?})")));
        }
        let ds = match e.get("downscale") {
            None => 1,
            Some(v) => v
                .as_i64()
                .filter(|&n| n >= 1 && n <= u32::MAX as i64)
                .map(|n| n as u32)
                .ok_or_else(|| perr("\"downscale\" must be a positive 32-bit integer"))?,
        };
        return Ok(MixEntry {
            workload: Workload::Model { name: name.to_string(), downscale: ds },
            prec,
            weight,
            policy,
            strat: None,
        });
    }

    let Some(kind) = e.get("op").and_then(Json::as_str) else {
        return Err(perr("mix entry needs \"model\", \"op\", or \"llm\""));
    };
    let dim = |k: &str| -> Result<u32> {
        e.get(k)
            .and_then(Json::as_i64)
            .filter(|&n| n >= 1 && n <= u32::MAX as i64)
            .map(|n| n as u32)
            .ok_or_else(|| perr(format!("op \"{kind}\" needs positive integer \"{k}\"")))
    };
    let opt_dim = |k: &str, default: u32| -> Result<u32> {
        match e.get(k) {
            None => Ok(default),
            Some(v) => v
                .as_i64()
                .filter(|&n| n >= 0 && n <= u32::MAX as i64)
                .map(|n| n as u32)
                .ok_or_else(|| perr(format!("op \"{k}\" must be a non-negative integer"))),
        }
    };
    let op = match kind {
        "mm" => OpDesc::mm(dim("m")?, dim("k")?, dim("n")?, prec),
        "conv" => OpDesc::conv(
            dim("c")?,
            dim("f")?,
            dim("h")?,
            dim("w")?,
            dim("ksize")?,
            opt_dim("stride", 1)?.max(1),
            opt_dim("pad", 0)?,
            prec,
        ),
        "pwcv" => OpDesc::pwcv(dim("c")?, dim("f")?, dim("h")?, dim("w")?, prec),
        "dwcv" => OpDesc::dwcv(
            dim("c")?,
            dim("h")?,
            dim("w")?,
            dim("ksize")?,
            opt_dim("stride", 1)?.max(1),
            opt_dim("pad", 0)?,
            prec,
        ),
        other => return Err(perr(format!("unknown op kind '{other}' (mm|conv|pwcv|dwcv)"))),
    };
    op.validate()?;
    let strat = match e.get("strat").and_then(Json::as_str) {
        None => None,
        Some(s) => {
            let strat = parse_strat(s)?;
            if !dataflow::applicable(strat, &op) {
                return Err(perr(format!(
                    "strategy '{s}' not applicable to op '{kind}'"
                )));
            }
            Some(strat)
        }
    };
    Ok(MixEntry { workload: Workload::Op(op), prec, weight, policy, strat })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::SpeedError;

    const SC: &str = r#"{
        "name": "unit",
        "seed": 7,
        "requests": 12,
        "capacity": 8,
        "max_batch": 4,
        "arrival": { "pattern": "burst", "size": 4 },
        "mix": [
            { "model": "mobilenetv2", "prec": 8, "weight": 2, "downscale": 4 },
            { "op": "mm", "m": 16, "k": 16, "n": 16, "prec": 4, "weight": 1 },
            { "op": "dwcv", "c": 8, "h": 12, "w": 12, "ksize": 3, "prec": 16,
              "weight": 1, "strat": "ff" }
        ]
    }"#;

    #[test]
    fn parses_and_generates_deterministically() {
        let sc = Scenario::from_json(SC).unwrap();
        assert_eq!(sc.version, 1, "absent \"version\" defaults to 1");
        assert_eq!(sc.name, "unit");
        assert_eq!(sc.requests, 12);
        assert_eq!(sc.capacity, Some(8));
        assert_eq!(sc.max_batch, Some(4));
        assert_eq!(sc.arrival, Arrival::Burst { size: 4 });
        assert_eq!(sc.mix.len(), 3);
        let a = sc.generate(false).unwrap();
        let b = sc.generate(false).unwrap();
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind.label(), y.kind.label());
            assert_eq!(x.kind.precision(), y.kind.precision());
        }
        // All three entries appear across a 12-request draw with these
        // weights and this seed (a fixed-stream regression canary).
        let labels: Vec<String> = a.iter().map(|r| r.kind.label()).collect();
        assert!(labels.iter().any(|l| l == "mobilenetv2@INT8"), "{labels:?}");
        assert!(labels.iter().any(|l| l == "MM@INT4"), "{labels:?}");
    }

    #[test]
    fn different_seeds_differ() {
        let sc = Scenario::from_json(SC).unwrap();
        let mut other = sc.clone();
        other.seed = 8;
        let a: Vec<String> =
            sc.generate(false).unwrap().iter().map(|r| r.kind.label()).collect();
        let b: Vec<String> =
            other.generate(false).unwrap().iter().map(|r| r.kind.label()).collect();
        assert_ne!(a, b, "seed must shape the stream");
    }

    #[test]
    fn quick_caps_requests_and_downscales() {
        let mut sc = Scenario::from_json(SC).unwrap();
        sc.requests = 500;
        let quick = sc.generate(true).unwrap();
        assert_eq!(quick.len(), QUICK_REQUEST_CAP);
        // A quick-mode model request is smaller than the full-mode one.
        let full = sc.generate(false).unwrap();
        let macs_of = |ks: &[Request]| -> Option<u64> {
            ks.iter().find_map(|k| match &k.kind {
                RequestKind::Model { model, .. } => Some(model.total_macs()),
                _ => None,
            })
        };
        let (fq, ff) = (macs_of(&quick).unwrap(), macs_of(&full).unwrap());
        assert!(fq < ff, "quick {fq} !< full {ff}");
    }

    #[test]
    fn rejects_malformed_scenarios() {
        assert!(Scenario::from_json("[]").is_err());
        assert!(Scenario::from_json(r#"{ "requests": 4 }"#).is_err());
        let bad_model = r#"{ "requests": 1,
            "mix": [ { "model": "nope", "prec": 8 } ] }"#;
        assert!(matches!(
            Scenario::from_json(bad_model),
            Err(SpeedError::Parse(_))
        ));
        let bad_prec = r#"{ "requests": 1,
            "mix": [ { "op": "mm", "m": 2, "k": 2, "n": 2, "prec": 7 } ] }"#;
        assert!(Scenario::from_json(bad_prec).is_err());
        let bad_strat = r#"{ "requests": 1,
            "mix": [ { "op": "mm", "m": 2, "k": 2, "n": 2, "prec": 8,
                       "strat": "ff" } ] }"#;
        assert!(Scenario::from_json(bad_strat).is_err());
        let bad_op = r#"{ "requests": 1,
            "mix": [ { "op": "conv", "c": 2, "f": 2, "h": 2, "w": 2,
                       "ksize": 5, "prec": 8 } ] }"#;
        assert!(Scenario::from_json(bad_op).is_err(), "kernel > padded input");
        let bad_arrival = r#"{ "requests": 1,
            "arrival": { "pattern": "warp" },
            "mix": [ { "op": "mm", "m": 2, "k": 2, "n": 2, "prec": 8 } ] }"#;
        assert!(Scenario::from_json(bad_arrival).is_err());
    }

    #[test]
    fn tuned_online_policy_parses() {
        let sc = r#"{ "requests": 2, "mix": [
            { "model": "mobilenetv2", "prec": 8, "downscale": 4,
              "policy": "tuned_online" } ] }"#;
        let sc = Scenario::from_json(sc).unwrap();
        assert_eq!(sc.mix[0].policy, Policy::TunedOnline);
        let reqs = sc.generate(false).unwrap();
        assert!(matches!(
            &reqs[0].kind,
            RequestKind::Model { policy: Policy::TunedOnline, .. }
        ));
        // Unknown policies still fail fast, naming the accepted set.
        let bad = r#"{ "requests": 1, "mix": [
            { "model": "mobilenetv2", "prec": 8, "policy": "tuned_offline" } ] }"#;
        match Scenario::from_json(bad) {
            Err(SpeedError::Parse(m)) => assert!(m.contains("tuned_online"), "{m}"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn all_zero_weights_rejected_at_parse() {
        // Zero total weight used to reach the weighted pick and blow up
        // mid-bench; now it is a fail-fast typed Parse error at load.
        let zero = r#"{ "requests": 4, "mix": [
            { "op": "mm", "m": 2, "k": 2, "n": 2, "prec": 8, "weight": 0 },
            { "op": "mm", "m": 4, "k": 4, "n": 4, "prec": 8, "weight": 0 } ] }"#;
        match Scenario::from_json(zero) {
            Err(SpeedError::Parse(m)) => assert!(m.contains("weights sum to zero"), "{m}"),
            other => panic!("unexpected {other:?}"),
        }
        // A hand-built scenario bypassing from_json fails typed too.
        let mut sc = Scenario::from_json(SC).unwrap();
        for e in &mut sc.mix {
            e.weight = 0;
        }
        assert!(matches!(sc.generate(false), Err(SpeedError::Parse(_))));
    }

    #[test]
    fn zero_weight_entry_is_disabled_not_rejected() {
        let one_off = r#"{ "requests": 16, "seed": 3, "mix": [
            { "op": "mm", "m": 2, "k": 2, "n": 2, "prec": 8, "weight": 1 },
            { "op": "mm", "m": 4, "k": 4, "n": 4, "prec": 4, "weight": 0 } ] }"#;
        let sc = Scenario::from_json(one_off).unwrap();
        let reqs = sc.generate(false).unwrap();
        assert_eq!(reqs.len(), 16);
        // The zero-weight entry is never drawn.
        assert!(reqs.iter().all(|r| r.kind.label() == "MM@INT8"), "{:?}",
                reqs.iter().map(|r| r.kind.label()).collect::<Vec<_>>());
    }

    #[test]
    fn version_gates_llm_entries() {
        // Unknown future versions fail fast and typed.
        let future = r#"{ "version": 3, "requests": 1, "mix": [
            { "op": "mm", "m": 2, "k": 2, "n": 2, "prec": 8 } ] }"#;
        match Scenario::from_json(future) {
            Err(SpeedError::Parse(m)) => assert!(m.contains("version"), "{m}"),
            other => panic!("unexpected {other:?}"),
        }
        // An llm entry in an implicit version-1 document names the fix.
        let v1_llm = r#"{ "requests": 4, "mix": [
            { "llm": "llm_tiny", "prompt": 8, "decode": 2, "prec": 8 } ] }"#;
        match Scenario::from_json(v1_llm) {
            Err(SpeedError::Parse(m)) => assert!(m.contains("\"version\": 2"), "{m}"),
            other => panic!("unexpected {other:?}"),
        }
        // Version 2 accepts llm entries; unknown llm specs still fail.
        let v2 = r#"{ "version": 2, "requests": 4, "mix": [
            { "llm": "llm_tiny", "prompt": 8, "decode": 2, "prec": 8 } ] }"#;
        let sc = Scenario::from_json(v2).unwrap();
        assert_eq!(sc.version, 2);
        let bad = r#"{ "version": 2, "requests": 4, "mix": [
            { "llm": "llm_huge", "prec": 8 } ] }"#;
        assert!(matches!(Scenario::from_json(bad), Err(SpeedError::Parse(_))));
    }

    #[test]
    fn llm_draw_expands_to_a_session() {
        let v2 = r#"{ "version": 2, "requests": 9, "seed": 5, "mix": [
            { "llm": "llm_tiny", "prompt": 8, "decode": 3, "prec": 8 } ] }"#;
        let sc = Scenario::from_json(v2).unwrap();
        let reqs = sc.generate(false).unwrap();
        assert_eq!(reqs.len(), 9);
        // Draw 1 is session 0 (prefill + 3 decodes), draw 2 is session 1,
        // and the ninth request truncates session 2 after its prefill.
        assert_eq!(reqs[0].phase, Phase::Prefill);
        assert_eq!(reqs[0].session, Some(SessionId(0)));
        for (i, r) in reqs[1..4].iter().enumerate() {
            assert_eq!(r.phase, Phase::Decode);
            assert_eq!(r.session, Some(SessionId(0)));
            // Growing KV: every step charges one more cached token.
            assert!(r.kv_bytes > reqs[i].kv_bytes, "step {i}");
        }
        assert_eq!(reqs[4].session, Some(SessionId(1)));
        assert_eq!(reqs[4].phase, Phase::Prefill);
        assert_eq!(reqs[8].session, Some(SessionId(2)));
        assert_eq!(reqs[8].phase, Phase::Prefill);
        // Decode steps are single-token: every MM is one row, or one row
        // per head in the fused attention MMs.
        let RequestKind::Model { model, .. } = &reqs[1].kind else {
            panic!("decode step is a model request");
        };
        assert!(model.ops.iter().all(|o| o.m == 1 || o.m == 4));
        // Quick mode shrinks the prompt, so the prefill gets lighter.
        let quick = sc.generate(true).unwrap();
        let macs = |r: &Request| match &r.kind {
            RequestKind::Model { model, .. } => model.total_macs(),
            _ => unreachable!(),
        };
        assert!(macs(&quick[0]) < macs(&reqs[0]));
    }

    #[test]
    fn arrival_yields() {
        let mut rng = XorShift64::new(3);
        let steady = Arrival::Steady { per_tick: 1 };
        assert_eq!(steady.yields_after(0, &mut rng), 1);
        assert_eq!(steady.yields_after(1, &mut rng), 1);
        let burst = Arrival::Burst { size: 4 };
        assert_eq!(burst.yields_after(2, &mut rng), 0);
        // A burst boundary opens a quiet period as deep as the burst.
        assert_eq!(burst.yields_after(3, &mut rng), 4);
        let random = Arrival::Random { max_gap: 2 };
        for i in 0..32 {
            assert!(random.yields_after(i, &mut rng) <= 2);
        }
    }

    #[test]
    fn xorshift_is_deterministic_and_spread() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(va, vb);
        // Zero seed still produces a live stream.
        let mut z = XorShift64::new(0);
        let vz: Vec<u64> = (0..8).map(|_| z.next_u64()).collect();
        assert!(vz.iter().any(|&v| v != 0));
        let mut counts = [0usize; 4];
        let mut r = XorShift64::new(9);
        for _ in 0..4000 {
            counts[r.below(4) as usize] += 1;
        }
        for c in counts {
            assert!(c > 700, "skewed draw: {counts:?}");
        }
    }
}
