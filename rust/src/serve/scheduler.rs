//! Precision-affinity scheduling state (pure logic, no threads).
//!
//! Every worker owns a lane. A request is routed to the least-loaded lane
//! whose worker was last configured at the request's precision — keeping
//! same-precision streams on the same datapath so the per-request
//! `VSACFG` elides the precision switch (Sec. II-E) and the worker's
//! private program cache stays hot. When no lane has the right affinity,
//! the shortest lane takes the request (and adopts the new affinity).
//! When a lane backs up past `steal_threshold`, an idle worker steals a
//! micro-batch from its tail. The whole structure lives behind one mutex
//! owned by the pool; all methods here are called with that lock held.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::config::Precision;

use super::batch::BatchKey;
use super::{Completion, Request};

/// A routed request waiting in a lane.
pub(crate) struct Job {
    pub req: Request,
    pub key: BatchKey,
    pub prec: Precision,
    pub enqueued: Instant,
    pub done: Arc<Completion>,
}

struct Lane {
    queue: VecDeque<Job>,
    /// Precision of the last request routed to / popped by this lane's
    /// worker — the proxy for "what the datapath is configured at".
    affinity: Option<Precision>,
}

/// Scheduler state: per-worker lanes plus the shared queue bound.
pub(crate) struct SchedState {
    lanes: Vec<Lane>,
    queued: usize,
    capacity: usize,
    max_batch: usize,
    steal_threshold: usize,
    pub shutdown: bool,
    // ---- counters (harvested into MetricsSnapshot) ----
    pub affinity_hits: u64,
    pub affinity_misses: u64,
    pub steals: u64,
    pub max_depth: usize,
    pub depth_sum: u64,
    pub depth_samples: u64,
}

impl SchedState {
    pub fn new(
        workers: usize,
        capacity: usize,
        max_batch: usize,
        steal_threshold: usize,
    ) -> Self {
        SchedState {
            lanes: (0..workers.max(1))
                .map(|_| Lane { queue: VecDeque::new(), affinity: None })
                .collect(),
            queued: 0,
            capacity: capacity.max(1),
            max_batch: max_batch.max(1),
            steal_threshold: steal_threshold.max(1),
            shutdown: false,
            affinity_hits: 0,
            affinity_misses: 0,
            steals: 0,
            max_depth: 0,
            depth_sum: 0,
            depth_samples: 0,
        }
    }

    pub fn queued(&self) -> usize {
        self.queued
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn has_space(&self) -> bool {
        self.queued < self.capacity
    }

    /// Route a job to a lane (affinity first, then least-loaded). Returns
    /// the chosen lane index, or the job back when the queue is full.
    pub fn route(&mut self, job: Job) -> Result<usize, Job> {
        if !self.has_space() {
            return Err(job);
        }
        // Pass 1: among lanes whose worker is at the request's precision,
        // the shortest queue (lowest index on ties).
        let mut chosen: Option<usize> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            if lane.affinity == Some(job.prec)
                && chosen.map_or(true, |c| lane.queue.len() < self.lanes[c].queue.len())
            {
                chosen = Some(i);
            }
        }
        let hit = chosen.is_some();
        // Pass 2: no affinity match — least-loaded lane overall.
        let w = chosen.unwrap_or_else(|| {
            let mut best = 0;
            for (i, lane) in self.lanes.iter().enumerate() {
                if lane.queue.len() < self.lanes[best].queue.len() {
                    best = i;
                }
            }
            best
        });
        if hit {
            self.affinity_hits += 1;
        } else {
            self.affinity_misses += 1;
        }
        let lane = &mut self.lanes[w];
        lane.affinity = Some(job.prec);
        lane.queue.push_back(job);
        self.queued += 1;
        self.max_depth = self.max_depth.max(self.queued);
        self.depth_sum += self.queued as u64;
        self.depth_samples += 1;
        Ok(w)
    }

    /// Next micro-batch for worker `w`: the head of its own lane plus
    /// every same-key job waiting there (up to `max_batch`); if the lane
    /// is empty, a batch stolen from the tail of the most backed-up lane.
    /// `None` = nothing runnable for this worker right now.
    pub fn next_batch(&mut self, w: usize) -> Option<Vec<Job>> {
        if let Some(head) = self.lanes[w].queue.pop_front() {
            let key = head.key.clone();
            let prec = head.prec;
            let mut batch = vec![head];
            let lane = &mut self.lanes[w].queue;
            let mut i = 0;
            while i < lane.len() && batch.len() < self.max_batch {
                if lane[i].key == key {
                    batch.push(lane.remove(i).expect("index checked"));
                } else {
                    i += 1;
                }
            }
            self.lanes[w].affinity = Some(prec);
            self.queued -= batch.len();
            return Some(batch);
        }
        // Work-stealing: only from a lane that has actually backed up —
        // below the threshold the owning worker keeps its affinity run.
        let victim = (0..self.lanes.len())
            .filter(|&i| i != w)
            .max_by_key(|&i| self.lanes[i].queue.len())?;
        if self.lanes[victim].queue.len() < self.steal_threshold {
            return None;
        }
        let tail = self.lanes[victim].queue.pop_back().expect("length checked");
        let key = tail.key.clone();
        let prec = tail.prec;
        let mut batch = vec![tail];
        // Take the contiguous same-key run at the tail (the victim's FIFO
        // front — its worker's next work — stays untouched).
        while batch.len() < self.max_batch {
            let same = matches!(self.lanes[victim].queue.back(), Some(j) if j.key == key);
            if !same {
                break;
            }
            batch.push(self.lanes[victim].queue.pop_back().expect("just peeked"));
        }
        batch.reverse(); // restore submission order within the batch
        self.steals += 1;
        self.lanes[w].affinity = Some(prec);
        self.queued -= batch.len();
        Some(batch)
    }

    /// Average queue depth observed at routing time.
    pub fn avg_depth(&self) -> f64 {
        if self.depth_samples == 0 {
            return 0.0;
        }
        self.depth_sum as f64 / self.depth_samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::isa::StrategyKind;
    use crate::models::OpDesc;
    use crate::serve::RequestKind;

    fn job(id: u64, m: u32, prec: Precision) -> Job {
        let kind = RequestKind::Op {
            op: OpDesc::mm(m, 2, 2, prec),
            strat: StrategyKind::Mm,
        };
        Job {
            key: BatchKey::of(&kind),
            prec,
            req: Request { id, kind },
            enqueued: Instant::now(),
            done: Arc::new(Completion::default()),
        }
    }

    #[test]
    fn affinity_routes_same_precision_to_same_lane() {
        let mut s = SchedState::new(3, 64, 1, 2);
        let a = s.route(job(0, 2, Precision::Int8)).unwrap_or_else(|_| panic!());
        let b = s.route(job(1, 3, Precision::Int8)).unwrap_or_else(|_| panic!());
        assert_eq!(a, b, "same precision sticks to one lane");
        let c = s.route(job(2, 2, Precision::Int4)).unwrap_or_else(|_| panic!());
        assert_ne!(a, c, "new precision takes an empty lane");
        assert_eq!(s.affinity_hits, 1);
        assert_eq!(s.affinity_misses, 2);
        assert_eq!(s.queued(), 3);
    }

    #[test]
    fn overflow_returns_the_job() {
        let mut s = SchedState::new(1, 2, 1, 2);
        assert!(s.route(job(0, 2, Precision::Int8)).is_ok());
        assert!(s.route(job(1, 2, Precision::Int8)).is_ok());
        let back = s.route(job(2, 2, Precision::Int8));
        assert!(back.is_err());
        assert_eq!(back.err().map(|j| j.req.id), Some(2));
        assert!(!s.has_space());
        assert_eq!(s.max_depth, 2);
    }

    #[test]
    fn micro_batch_takes_same_key_jobs_up_to_cap() {
        let mut s = SchedState::new(1, 64, 3, 2);
        // Keys: A A B A A — batch pops [A,A,A] (cap 3), leaves [B,A].
        for (id, m) in [(0, 2), (1, 2), (2, 9), (3, 2), (4, 2)] {
            s.route(job(id, m, Precision::Int8)).unwrap_or_else(|_| panic!());
        }
        let batch = s.next_batch(0).unwrap();
        assert_eq!(batch.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![0, 1, 3]);
        assert_eq!(s.queued(), 2);
        let batch = s.next_batch(0).unwrap();
        assert_eq!(batch[0].req.id, 2, "skipped jobs keep FIFO order");
        assert_eq!(batch.len(), 1);
        let batch = s.next_batch(0).unwrap();
        assert_eq!(batch[0].req.id, 4);
        assert!(s.next_batch(0).is_none());
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn stealing_only_from_backed_up_lanes() {
        let mut s = SchedState::new(2, 64, 8, 2);
        // Everything lands on lane 0 (same precision).
        s.route(job(0, 2, Precision::Int8)).unwrap_or_else(|_| panic!());
        // One queued job is below the threshold: worker 1 must not steal.
        assert!(s.next_batch(1).is_none());
        s.route(job(1, 3, Precision::Int8)).unwrap_or_else(|_| panic!());
        s.route(job(2, 3, Precision::Int8)).unwrap_or_else(|_| panic!());
        // Lane 0 is backed up now; worker 1 steals the same-key tail run
        // in submission order.
        let batch = s.next_batch(1).unwrap();
        assert_eq!(batch.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(s.steals, 1);
        // The victim's head job is untouched.
        let own = s.next_batch(0).unwrap();
        assert_eq!(own[0].req.id, 0);
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn depth_accounting() {
        let mut s = SchedState::new(1, 8, 1, 2);
        for id in 0..4 {
            s.route(job(id, 2, Precision::Int8)).unwrap_or_else(|_| panic!());
        }
        assert_eq!(s.max_depth, 4);
        assert!((s.avg_depth() - 2.5).abs() < 1e-9, "{}", s.avg_depth());
    }
}
