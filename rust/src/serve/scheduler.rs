//! Precision-affinity + session-affinity scheduling state (pure logic,
//! no threads).
//!
//! Every worker owns a lane. A request is routed to the least-loaded lane
//! whose worker was last configured at the request's precision — keeping
//! same-precision streams on the same datapath so the per-request
//! `VSACFG` elides the precision switch (Sec. II-E) and the worker's
//! private program cache stays hot. When no lane has the right affinity,
//! the shortest lane takes the request (and adopts the new affinity).
//! When a lane backs up past `steal_threshold`, an idle worker steals a
//! micro-batch from its tail.
//!
//! Session-carrying requests add a stronger constraint: the lane holding
//! a session's KV-cache residency owns every later request of that
//! session — a decode step *must* land on the worker whose engine keeps
//! the session's K/V tensors warm, so session affinity overrides both
//! queue-length balancing and precision affinity. Residency is tracked
//! in bytes per lane against a KV budget with LRU eviction (a *spill*);
//! a decode step finding its residency is a *hit*, one arriving after a
//! spill (or without a prefill) is a *miss* and re-installs the session
//! where normal routing puts it. Pinned (decode) tail jobs are never
//! work-stolen — stealing one would defeat the residency it was routed
//! for. The whole structure lives behind one mutex owned by the pool;
//! all methods here are called with that lock held.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::config::Precision;

use super::batch::BatchKey;
use super::{Completion, Phase, Request, SessionId};

/// A routed request waiting in a lane.
pub(crate) struct Job {
    /// Pool-assigned request id, ascending in submission order.
    pub id: u64,
    pub req: Request,
    pub key: BatchKey,
    pub prec: Precision,
    pub enqueued: Instant,
    pub done: Arc<Completion>,
}

impl Job {
    /// Cache-affine jobs are pinned to their routed lane: stealing a
    /// decode step would move it off the worker holding its KV residency.
    fn pinned(&self) -> bool {
        self.req.session.is_some() && self.req.phase == Phase::Decode
    }
}

struct Lane {
    queue: VecDeque<Job>,
    /// Precision of the last request routed to / popped by this lane's
    /// worker — the proxy for "what the datapath is configured at".
    affinity: Option<Precision>,
    /// Sessions whose KV cache is resident on this lane's worker, in LRU
    /// order (front = coldest), with the bytes each occupies.
    kv: Vec<(SessionId, u64)>,
    /// Total KV bytes resident on this lane.
    kv_bytes: u64,
}

/// Scheduler state: per-worker lanes plus the shared queue bound.
///
/// The counter fields below are the lock-held fast path; the pool
/// mirrors them into the unified [`crate::obs::Counters`] registry view
/// at snapshot time (`ServePool::metrics`), so they appear in the
/// schema-3 `counters` object without a second atomic write per routing
/// decision.
pub(crate) struct SchedState {
    lanes: Vec<Lane>,
    queued: usize,
    capacity: usize,
    max_batch: usize,
    steal_threshold: usize,
    /// Per-worker KV residency budget in bytes (0 = unlimited).
    kv_capacity: u64,
    pub shutdown: bool,
    // ---- counters (harvested into MetricsSnapshot) ----
    pub affinity_hits: u64,
    pub affinity_misses: u64,
    pub steals: u64,
    pub kv_hits: u64,
    pub kv_misses: u64,
    pub kv_spills: u64,
    pub kv_bytes_peak: u64,
    pub max_depth: usize,
    pub depth_sum: u64,
    pub depth_samples: u64,
}

impl SchedState {
    pub fn new(
        workers: usize,
        capacity: usize,
        max_batch: usize,
        steal_threshold: usize,
        kv_capacity: u64,
    ) -> Self {
        SchedState {
            lanes: (0..workers.max(1))
                .map(|_| Lane {
                    queue: VecDeque::new(),
                    affinity: None,
                    kv: Vec::new(),
                    kv_bytes: 0,
                })
                .collect(),
            queued: 0,
            capacity: capacity.max(1),
            max_batch: max_batch.max(1),
            steal_threshold: steal_threshold.max(1),
            kv_capacity,
            shutdown: false,
            affinity_hits: 0,
            affinity_misses: 0,
            steals: 0,
            kv_hits: 0,
            kv_misses: 0,
            kv_spills: 0,
            kv_bytes_peak: 0,
            max_depth: 0,
            depth_sum: 0,
            depth_samples: 0,
        }
    }

    pub fn queued(&self) -> usize {
        self.queued
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn has_space(&self) -> bool {
        self.queued < self.capacity
    }

    /// The lane holding `sid`'s KV residency, if any.
    fn kv_lane(&self, sid: SessionId) -> Option<usize> {
        self.lanes.iter().position(|l| l.kv.iter().any(|&(s, _)| s == sid))
    }

    /// Install or refresh `sid`'s residency on lane `w` (move to the hot
    /// end of the LRU, update its byte charge), then evict cold sessions
    /// past the per-worker budget — each eviction is a *spill*. The
    /// just-touched session is never evicted, so one oversized session
    /// may exceed the budget (tracked by `kv_bytes_peak`).
    fn touch_kv(&mut self, w: usize, sid: SessionId, bytes: u64) {
        let lane = &mut self.lanes[w];
        if let Some(pos) = lane.kv.iter().position(|&(s, _)| s == sid) {
            let (_, old) = lane.kv.remove(pos);
            lane.kv_bytes -= old;
        }
        lane.kv.push((sid, bytes));
        lane.kv_bytes += bytes;
        while self.kv_capacity > 0 && lane.kv_bytes > self.kv_capacity && lane.kv.len() > 1 {
            let (_, old) = lane.kv.remove(0);
            lane.kv_bytes -= old;
            self.kv_spills += 1;
        }
        self.kv_bytes_peak = self.kv_bytes_peak.max(lane.kv_bytes);
    }

    /// Route a job to a lane (session residency first, then precision
    /// affinity, then least-loaded). Returns the chosen lane index, or
    /// the job back when the queue is full.
    pub fn route(&mut self, job: Job) -> Result<usize, Job> {
        if !self.has_space() {
            return Err(job);
        }
        // Pass 0: a session resident on a lane owns the request — decode
        // must run where its KV cache is warm, and later prefill chunks
        // of a session stay with their predecessors.
        let resident = job.req.session.and_then(|sid| self.kv_lane(sid));
        let w = if let Some(w) = resident {
            if job.pinned() {
                self.kv_hits += 1;
            }
            w
        } else {
            if job.pinned() {
                self.kv_misses += 1;
            }
            // Pass 1: among lanes whose worker is at the request's
            // precision, the shortest queue (lowest index on ties).
            let mut chosen: Option<usize> = None;
            for (i, lane) in self.lanes.iter().enumerate() {
                if lane.affinity == Some(job.prec)
                    && chosen.map_or(true, |c| lane.queue.len() < self.lanes[c].queue.len())
                {
                    chosen = Some(i);
                }
            }
            // Pass 2: no affinity match — least-loaded lane overall.
            chosen.unwrap_or_else(|| {
                let mut best = 0;
                for (i, lane) in self.lanes.iter().enumerate() {
                    if lane.queue.len() < self.lanes[best].queue.len() {
                        best = i;
                    }
                }
                best
            })
        };
        if self.lanes[w].affinity == Some(job.prec) {
            self.affinity_hits += 1;
        } else {
            self.affinity_misses += 1;
        }
        if let Some(sid) = job.req.session {
            self.touch_kv(w, sid, job.req.kv_bytes);
        }
        let lane = &mut self.lanes[w];
        lane.affinity = Some(job.prec);
        lane.queue.push_back(job);
        self.queued += 1;
        self.max_depth = self.max_depth.max(self.queued);
        self.depth_sum += self.queued as u64;
        self.depth_samples += 1;
        Ok(w)
    }

    /// Next micro-batch for worker `w`: the head of its own lane plus
    /// every same-key job waiting there (up to `max_batch`); if the lane
    /// is empty, a batch stolen from the tail of the most backed-up lane
    /// — unless that tail is a pinned decode step, which only its
    /// residency-holding worker may run. `None` = nothing runnable for
    /// this worker right now.
    pub fn next_batch(&mut self, w: usize) -> Option<Vec<Job>> {
        if let Some(head) = self.lanes[w].queue.pop_front() {
            let key = head.key.clone();
            let prec = head.prec;
            let mut batch = vec![head];
            let lane = &mut self.lanes[w].queue;
            let mut i = 0;
            while i < lane.len() && batch.len() < self.max_batch {
                if lane[i].key == key {
                    batch.push(lane.remove(i).expect("index checked"));
                } else {
                    i += 1;
                }
            }
            self.lanes[w].affinity = Some(prec);
            self.queued -= batch.len();
            return Some(batch);
        }
        // Work-stealing: only from a lane that has actually backed up —
        // below the threshold the owning worker keeps its affinity run.
        let victim = (0..self.lanes.len())
            .filter(|&i| i != w)
            .max_by_key(|&i| self.lanes[i].queue.len())?;
        if self.lanes[victim].queue.len() < self.steal_threshold
            || self.lanes[victim].queue.back().is_some_and(|j| j.pinned())
        {
            return None;
        }
        let tail = self.lanes[victim].queue.pop_back().expect("length checked");
        let key = tail.key.clone();
        let prec = tail.prec;
        let mut batch = vec![tail];
        // Take the contiguous same-key run at the tail (the victim's FIFO
        // front — its worker's next work — stays untouched; pinned jobs
        // end the run).
        while batch.len() < self.max_batch {
            let same = matches!(self.lanes[victim].queue.back(),
                Some(j) if j.key == key && !j.pinned());
            if !same {
                break;
            }
            batch.push(self.lanes[victim].queue.pop_back().expect("just peeked"));
        }
        batch.reverse(); // restore submission order within the batch
        self.steals += 1;
        self.lanes[w].affinity = Some(prec);
        self.queued -= batch.len();
        Some(batch)
    }

    /// Average queue depth observed at routing time.
    pub fn avg_depth(&self) -> f64 {
        if self.depth_samples == 0 {
            return 0.0;
        }
        self.depth_sum as f64 / self.depth_samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::isa::StrategyKind;
    use crate::models::OpDesc;
    use crate::serve::RequestKind;

    fn job(id: u64, m: u32, prec: Precision) -> Job {
        let kind = RequestKind::Op {
            op: OpDesc::mm(m, 2, 2, prec),
            strat: StrategyKind::Mm,
        };
        Job {
            id,
            key: BatchKey::of(&kind),
            prec,
            req: Request::from(kind),
            enqueued: Instant::now(),
            done: Arc::new(Completion::default()),
        }
    }

    fn session_job(id: u64, sid: u64, phase: Phase, kv: u64) -> Job {
        let mut j = job(id, 1 + id as u32, Precision::Int8);
        j.req = j.req.session(SessionId(sid)).phase(phase).kv(kv);
        j
    }

    fn sched(workers: usize) -> SchedState {
        SchedState::new(workers, 64, 1, 2, 0)
    }

    #[test]
    fn affinity_routes_same_precision_to_same_lane() {
        let mut s = sched(3);
        let a = s.route(job(0, 2, Precision::Int8)).unwrap_or_else(|_| panic!());
        let b = s.route(job(1, 3, Precision::Int8)).unwrap_or_else(|_| panic!());
        assert_eq!(a, b, "same precision sticks to one lane");
        let c = s.route(job(2, 2, Precision::Int4)).unwrap_or_else(|_| panic!());
        assert_ne!(a, c, "new precision takes an empty lane");
        assert_eq!(s.affinity_hits, 1);
        assert_eq!(s.affinity_misses, 2);
        assert_eq!(s.queued(), 3);
    }

    #[test]
    fn overflow_returns_the_job() {
        let mut s = SchedState::new(1, 2, 1, 2, 0);
        assert!(s.route(job(0, 2, Precision::Int8)).is_ok());
        assert!(s.route(job(1, 2, Precision::Int8)).is_ok());
        let back = s.route(job(2, 2, Precision::Int8));
        assert!(back.is_err());
        assert_eq!(back.err().map(|j| j.id), Some(2));
        assert!(!s.has_space());
        assert_eq!(s.max_depth, 2);
    }

    #[test]
    fn micro_batch_takes_same_key_jobs_up_to_cap() {
        let mut s = SchedState::new(1, 64, 3, 2, 0);
        // Keys: A A B A A — batch pops [A,A,A] (cap 3), leaves [B,A].
        for (id, m) in [(0, 2), (1, 2), (2, 9), (3, 2), (4, 2)] {
            s.route(job(id, m, Precision::Int8)).unwrap_or_else(|_| panic!());
        }
        let batch = s.next_batch(0).unwrap();
        assert_eq!(batch.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1, 3]);
        assert_eq!(s.queued(), 2);
        let batch = s.next_batch(0).unwrap();
        assert_eq!(batch[0].id, 2, "skipped jobs keep FIFO order");
        assert_eq!(batch.len(), 1);
        let batch = s.next_batch(0).unwrap();
        assert_eq!(batch[0].id, 4);
        assert!(s.next_batch(0).is_none());
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn stealing_only_from_backed_up_lanes() {
        let mut s = SchedState::new(2, 64, 8, 2, 0);
        // Everything lands on lane 0 (same precision).
        s.route(job(0, 2, Precision::Int8)).unwrap_or_else(|_| panic!());
        // One queued job is below the threshold: worker 1 must not steal.
        assert!(s.next_batch(1).is_none());
        s.route(job(1, 3, Precision::Int8)).unwrap_or_else(|_| panic!());
        s.route(job(2, 3, Precision::Int8)).unwrap_or_else(|_| panic!());
        // Lane 0 is backed up now; worker 1 steals the same-key tail run
        // in submission order.
        let batch = s.next_batch(1).unwrap();
        assert_eq!(batch.iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(s.steals, 1);
        // The victim's head job is untouched.
        let own = s.next_batch(0).unwrap();
        assert_eq!(own[0].id, 0);
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn depth_accounting() {
        let mut s = SchedState::new(1, 8, 1, 2, 0);
        for id in 0..4 {
            s.route(job(id, 2, Precision::Int8)).unwrap_or_else(|_| panic!());
        }
        assert_eq!(s.max_depth, 4);
        assert!((s.avg_depth() - 2.5).abs() < 1e-9, "{}", s.avg_depth());
    }

    #[test]
    fn decode_lands_on_the_resident_lane() {
        let mut s = sched(4);
        // Prefill installs residency (neither hit nor miss).
        let home = s.route(session_job(0, 7, Phase::Prefill, 1024)).unwrap_or_else(|_| panic!());
        assert_eq!((s.kv_hits, s.kv_misses), (0, 0));
        // Pile unrelated work onto the home lane so load balancing alone
        // would steer elsewhere — residency must still win.
        for id in 1..4 {
            let w = s.route(job(id, 2, Precision::Int8)).unwrap_or_else(|_| panic!());
            assert_eq!(w, home, "INT8 affinity keeps these on the home lane");
        }
        let w = s.route(session_job(4, 7, Phase::Decode, 1040)).unwrap_or_else(|_| panic!());
        assert_eq!(w, home, "decode must land on the KV-resident lane");
        assert_eq!((s.kv_hits, s.kv_misses), (1, 0));
        // A sessionless decode-free stream never touches KV counters.
        assert_eq!(s.kv_spills, 0);
        assert_eq!(s.kv_bytes_peak, 1040, "refresh replaces the byte charge");
    }

    #[test]
    fn orphan_decode_counts_a_miss_and_reinstalls() {
        let mut s = sched(2);
        let w = s.route(session_job(0, 9, Phase::Decode, 512)).unwrap_or_else(|_| panic!());
        assert_eq!((s.kv_hits, s.kv_misses), (0, 1));
        let w2 = s.route(session_job(1, 9, Phase::Decode, 520)).unwrap_or_else(|_| panic!());
        assert_eq!(w2, w, "re-installed residency is honored");
        assert_eq!((s.kv_hits, s.kv_misses), (1, 1));
    }

    #[test]
    fn kv_budget_evicts_lru_and_counts_spills() {
        let mut s = SchedState::new(1, 64, 1, 2, 1000);
        s.route(session_job(0, 1, Phase::Prefill, 600)).unwrap_or_else(|_| panic!());
        s.route(session_job(1, 2, Phase::Prefill, 600)).unwrap_or_else(|_| panic!());
        // Session 1 (coldest) was evicted to fit session 2.
        assert_eq!(s.kv_spills, 1);
        // Its decode step now misses and re-installs, evicting session 2.
        s.route(session_job(2, 1, Phase::Decode, 610)).unwrap_or_else(|_| panic!());
        assert_eq!((s.kv_hits, s.kv_misses, s.kv_spills), (0, 1, 2));
        // An oversized session is never evicted on its own behalf.
        s.route(session_job(3, 3, Phase::Prefill, 5000)).unwrap_or_else(|_| panic!());
        assert_eq!(s.kv_spills, 3, "resident session 1 spilled for it");
        assert_eq!(s.kv_bytes_peak, 5000);
    }

    #[test]
    fn pinned_decode_tail_is_never_stolen() {
        let mut s = SchedState::new(2, 64, 8, 2, 0);
        // Route everything to lane 0: prefill installs residency, then
        // queued decode steps pile up behind an op request.
        s.route(session_job(0, 5, Phase::Prefill, 256)).unwrap_or_else(|_| panic!());
        s.route(session_job(1, 5, Phase::Decode, 260)).unwrap_or_else(|_| panic!());
        s.route(session_job(2, 5, Phase::Decode, 264)).unwrap_or_else(|_| panic!());
        // Lane 0 is past the steal threshold but its tail is pinned.
        assert!(s.next_batch(1).is_none(), "decode steps must not be stolen");
        // The owning worker drains them in order.
        let b = s.next_batch(0).unwrap();
        assert_eq!(b[0].id, 0);
        assert_eq!(s.next_batch(0).unwrap()[0].id, 1);
        assert_eq!(s.next_batch(0).unwrap()[0].id, 2);
        assert_eq!(s.kv_hits, 2);
    }
}
