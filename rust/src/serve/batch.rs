//! Dynamic micro-batching: coalescing identical requests into one replay.
//!
//! The simulator is deterministic and the pool quiesces the pipeline at
//! request boundaries, so two requests with the same [`BatchKey`] are
//! guaranteed to produce bit-identical [`SimStats`]. The scheduler
//! exploits that: when a worker pops a lane it also takes every same-key
//! request waiting there (up to the configured cap), runs the compiled
//! programs **once**, and fulfills the whole batch with the one result —
//! `k` queued inferences for the cost of one simulation, with no change
//! to any request's reported statistics (`tests/serve_parity.rs` holds
//! batched and unbatched runs bit-equal).

use std::hash::{Hash, Hasher};

use crate::config::Precision;
use crate::coordinator::Policy;
use crate::engine::Engine;
use crate::error::Result;
use crate::isa::StrategyKind;
use crate::models::OpDesc;
use crate::sim::SimStats;
use crate::tune::TunedPlans;

use super::RequestKind;

/// Coalescing key: requests compare equal exactly when they replay the
/// same compiled-program sequence — same workload, same precision, same
/// strategy selection.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum BatchKey {
    /// A model request: zoo name, requested precision, policy, and an
    /// FNV-64 digest over the full operator list (downscaled variants of
    /// the same zoo model must not coalesce with full-size ones).
    Model { name: &'static str, prec: Precision, policy: Policy, ops_hash: u64 },
    /// A single-operator request (the descriptor is its own key).
    Op { op: OpDesc, strat: StrategyKind },
}

impl BatchKey {
    pub fn of(kind: &RequestKind) -> BatchKey {
        match kind {
            RequestKind::Model { model, prec, policy } => {
                let mut h = Fnv64::new();
                for op in &model.ops {
                    op.hash(&mut h);
                }
                BatchKey::Model {
                    name: model.name,
                    prec: *prec,
                    policy: *policy,
                    ops_hash: h.finish(),
                }
            }
            RequestKind::Op { op, strat } => BatchKey::Op { op: *op, strat: *strat },
        }
    }
}

/// Execute one request (or the representative of a micro-batch) on a
/// quiesced worker engine. Returns the deterministic per-request stats
/// plus the number of vector operators executed.
///
/// `stats.precision_switches` is rewritten to the request's *internal*
/// switch count (see the `serve` module docs): the boundary switch a
/// worker may pay when its datapath was left at another precision is
/// schedule-dependent and is accounted at pool level instead.
///
/// A [`Policy::Tuned`] model request resolves its plan from the pool's
/// shared [`TunedPlans`] registry; a missing or configuration-mismatched
/// plan degrades to the static mixed mapping (never an error). The
/// registry is fixed for a pool's lifetime, so same-key requests resolve
/// the same plan and micro-batching stays semantics-preserving.
pub(crate) fn execute_request(
    engine: &mut Engine,
    kind: &RequestKind,
    tuned: &TunedPlans,
) -> Result<(SimStats, usize)> {
    engine.quiesce();
    match kind {
        RequestKind::Model { model, prec, policy } => {
            let plan = if *policy == Policy::Tuned {
                tuned.get(model.name, *prec, engine.config())
            } else {
                None
            };
            let mut session = engine.session().with_policy(*policy);
            if let Some(plan) = plan {
                session = session.with_tuned_plan(plan);
            }
            let r = session.run_model(model, *prec)?;
            let mut stats = r.total.clone();
            stats.precision_switches =
                intra_request_switches(r.layers.iter().map(|l| l.op.prec));
            Ok((stats, r.layers.len()))
        }
        RequestKind::Op { op, strat } => {
            let (mut stats, _) = engine.run_op(op, *strat, false)?;
            stats.precision_switches = 0;
            Ok((stats, 1))
        }
    }
}

/// Precision transitions *within* one request's executed operator
/// sequence (independent of what the worker ran before).
fn intra_request_switches(mut precs: impl Iterator<Item = Precision>) -> u64 {
    let Some(mut cur) = precs.next() else {
        return 0;
    };
    let mut switches = 0;
    for p in precs {
        if p != cur {
            switches += 1;
            cur = p;
        }
    }
    switches
}

/// FNV-1a, 64-bit: a tiny deterministic hasher (the std `DefaultHasher`
/// is not guaranteed stable across releases, and batching keys plus the
/// serve-bench digest must be reproducible).
pub(crate) struct Fnv64(u64);

impl Fnv64 {
    pub(crate) fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }
}

impl Hasher for Fnv64 {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedConfig;
    use crate::models::zoo::model_by_name;
    use crate::report::fig12::downscale;

    #[test]
    fn op_requests_key_on_descriptor_and_strategy() {
        let a = RequestKind::Op {
            op: OpDesc::mm(4, 4, 4, Precision::Int8),
            strat: StrategyKind::Mm,
        };
        let b = RequestKind::Op {
            op: OpDesc::mm(4, 4, 4, Precision::Int8),
            strat: StrategyKind::Mm,
        };
        let c = RequestKind::Op {
            op: OpDesc::mm(4, 4, 4, Precision::Int4),
            strat: StrategyKind::Mm,
        };
        assert_eq!(BatchKey::of(&a), BatchKey::of(&b));
        assert_ne!(BatchKey::of(&a), BatchKey::of(&c));
    }

    #[test]
    fn model_requests_distinguish_shape_variants() {
        let full = model_by_name("mobilenetv2").unwrap();
        let small = downscale(&full, 4);
        let k_full = BatchKey::of(&RequestKind::Model {
            model: full.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        });
        let k_small = BatchKey::of(&RequestKind::Model {
            model: small.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        });
        let k_small2 = BatchKey::of(&RequestKind::Model {
            model: small.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        });
        assert_ne!(k_full, k_small, "downscaled variant must not coalesce");
        assert_eq!(k_small, k_small2);
        let k_prec = BatchKey::of(&RequestKind::Model {
            model: small,
            prec: Precision::Int4,
            policy: Policy::Mixed,
        });
        assert_ne!(k_small, k_prec);
    }

    #[test]
    fn intra_switches_count_transitions_only() {
        use Precision::*;
        assert_eq!(intra_request_switches(std::iter::empty::<Precision>()), 0);
        assert_eq!(intra_request_switches([Int8, Int8, Int8].into_iter()), 0);
        assert_eq!(intra_request_switches([Int8, Int4, Int4, Int16].into_iter()), 2);
    }

    #[test]
    fn execute_request_is_repeatable_on_one_engine() {
        let tuned = TunedPlans::new();
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let kind = RequestKind::Op {
            op: OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8),
            strat: StrategyKind::Ffcs,
        };
        let (a, la) = execute_request(&mut engine, &kind, &tuned).unwrap();
        // Interleave unrelated work at another precision, then repeat.
        let other = RequestKind::Op {
            op: OpDesc::mm(6, 12, 6, Precision::Int16),
            strat: StrategyKind::Mm,
        };
        execute_request(&mut engine, &other, &tuned).unwrap();
        let (b, lb) = execute_request(&mut engine, &kind, &tuned).unwrap();
        assert_eq!(a, b, "quiesce + switch normalization make replays bit-identical");
        assert_eq!(la, lb);
    }

    #[test]
    fn tuned_policy_without_plan_matches_mixed() {
        // A Tuned model request with an empty registry must degrade to the
        // static mixed mapping, bit-identically.
        let tuned = TunedPlans::new();
        let model = downscale(&model_by_name("mobilenetv2").unwrap(), 8);
        let mixed = RequestKind::Model {
            model: model.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        };
        let tuned_kind = RequestKind::Model {
            model,
            prec: Precision::Int8,
            policy: Policy::Tuned,
        };
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let (a, la) = execute_request(&mut engine, &mixed, &tuned).unwrap();
        let (b, lb) = execute_request(&mut engine, &tuned_kind, &tuned).unwrap();
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }
}
