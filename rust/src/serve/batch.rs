//! Dynamic micro-batching: coalescing identical requests into one replay.
//!
//! The simulator is deterministic and the pool quiesces the pipeline at
//! request boundaries, so two requests with the same [`BatchKey`] are
//! guaranteed to produce bit-identical [`SimStats`]. The scheduler
//! exploits that: when a worker pops a lane it also takes every same-key
//! request waiting there (up to the configured cap), runs the compiled
//! programs **once**, and fulfills the whole batch with the one result —
//! `k` queued inferences for the cost of one simulation, with no change
//! to any request's reported statistics (`tests/serve_parity.rs` holds
//! batched and unbatched runs bit-equal).

use std::hash::{Hash, Hasher};

use crate::config::Precision;
use crate::coordinator::Policy;
use crate::engine::Engine;
use crate::error::Result;
use crate::isa::StrategyKind;
use crate::models::OpDesc;
use crate::runtime::json::Fnv64;
use crate::sim::SimStats;
use crate::tune::{tune_model_on, TuneOptions, TunedPlans};

use super::RequestKind;

/// Coalescing key: requests compare equal exactly when they replay the
/// same compiled-program sequence — same workload, same precision, same
/// strategy selection.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum BatchKey {
    /// A model request: zoo name, requested precision, policy, and an
    /// FNV-64 digest over the full operator list (downscaled variants of
    /// the same zoo model must not coalesce with full-size ones).
    Model { name: &'static str, prec: Precision, policy: Policy, ops_hash: u64 },
    /// A single-operator request (the descriptor is its own key).
    Op { op: OpDesc, strat: StrategyKind },
}

impl BatchKey {
    /// Derive the coalescing key for a request kind.
    pub fn of(kind: &RequestKind) -> BatchKey {
        match kind {
            RequestKind::Model { model, prec, policy } => {
                let mut h = Fnv64::new();
                for op in &model.ops {
                    op.hash(&mut h);
                }
                BatchKey::Model {
                    name: model.name,
                    prec: *prec,
                    policy: *policy,
                    ops_hash: h.finish(),
                }
            }
            RequestKind::Op { op, strat } => BatchKey::Op { op: *op, strat: *strat },
        }
    }
}

/// What online tuning did for one executed request (pool metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TuneEvent {
    /// Not a [`Policy::TunedOnline`] model request.
    None,
    /// Served from an already-published covering plan in the registry.
    PlanHit,
    /// First request for an uncovered `(model, precision, config-sig)`
    /// key: the worker ran the tuning search and published the plan.
    Stall,
}

/// Execute one request (or the representative of a micro-batch) on a
/// quiesced worker engine. Returns the deterministic per-request stats,
/// the number of vector operators executed, and the online-tuning
/// disposition.
///
/// `stats.precision_switches` is rewritten to the request's *internal*
/// switch count (see the `serve` module docs): the boundary switch a
/// worker may pay when its datapath was left at another precision is
/// schedule-dependent and is accounted at pool level instead.
///
/// A [`Policy::Tuned`] model request resolves its plan from the pool's
/// shared [`TunedPlans`] registry; a missing or configuration-mismatched
/// plan degrades to the static mixed mapping (never an error). The
/// registry is fixed for a pool's lifetime, so same-key requests resolve
/// the same plan and micro-batching stays semantics-preserving.
///
/// A [`Policy::TunedOnline`] model request additionally closes the loop:
/// when the registry has no plan *covering this model's operators* for
/// the engine's configuration, the worker tunes the model right here
/// ([`tune_model_on`] — a *tune stall*, wall time only), publishes the
/// plan, and serves the request from the published (merge-resolved)
/// registry entry. Tuning is deterministic and every execution is
/// quiesced, so a request's stats are bit-identical whether it stalled,
/// hit the registry, or raced another worker's concurrent tune of the
/// same key.
pub(crate) fn execute_request(
    engine: &mut Engine,
    kind: &RequestKind,
    tuned: &TunedPlans,
) -> Result<(SimStats, usize, TuneEvent)> {
    engine.quiesce();
    match kind {
        RequestKind::Model { model, prec, policy } => {
            let mut event = TuneEvent::None;
            let plan = match policy {
                Policy::Tuned => tuned.get(model.name, *prec, engine.config()),
                Policy::TunedOnline => {
                    // Coverage must be checked against the ops *at the
                    // request precision* (exactly what `tune_model_on`
                    // tunes and `run_model` executes): `OpDesc` equality
                    // includes `prec`, so comparing raw `model.ops` would
                    // never match a plan tuned at a different precision
                    // and every such request would re-tune.
                    let typed = model.at_precision(*prec);
                    let covering = tuned
                        .get(model.name, *prec, engine.config())
                        .filter(|p| {
                            typed.ops.iter().all(|op| p.choice_for(op).is_some())
                        });
                    match covering {
                        Some(p) => {
                            event = TuneEvent::PlanHit;
                            Some(p)
                        }
                        None => {
                            // The worker's engine (in the pool's exec
                            // mode) is the search oracle; its program
                            // cache keeps every candidate compilation for
                            // the replays that follow.
                            let plan = tune_model_on(
                                engine,
                                model,
                                *prec,
                                &TuneOptions::default(),
                            )?;
                            event = TuneEvent::Stall;
                            engine.quiesce();
                            Some(tuned.insert(plan))
                        }
                    }
                }
                _ => None,
            };
            let mut session = engine.session().with_policy(*policy);
            if let Some(plan) = plan {
                session = session.with_tuned_plan(plan);
            }
            let r = session.run_model(model, *prec)?;
            let mut stats = r.total.clone();
            stats.precision_switches =
                intra_request_switches(r.layers.iter().map(|l| l.op.prec));
            Ok((stats, r.layers.len(), event))
        }
        RequestKind::Op { op, strat } => {
            let (mut stats, _) = engine.run_op(op, *strat, false)?;
            stats.precision_switches = 0;
            Ok((stats, 1, TuneEvent::None))
        }
    }
}

/// Precision transitions *within* one request's executed operator
/// sequence (independent of what the worker ran before).
fn intra_request_switches(mut precs: impl Iterator<Item = Precision>) -> u64 {
    let Some(mut cur) = precs.next() else {
        return 0;
    };
    let mut switches = 0;
    for p in precs {
        if p != cur {
            switches += 1;
            cur = p;
        }
    }
    switches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpeedConfig;
    use crate::models::zoo::model_by_name;
    use crate::report::fig12::downscale;

    #[test]
    fn op_requests_key_on_descriptor_and_strategy() {
        let a = RequestKind::Op {
            op: OpDesc::mm(4, 4, 4, Precision::Int8),
            strat: StrategyKind::Mm,
        };
        let b = RequestKind::Op {
            op: OpDesc::mm(4, 4, 4, Precision::Int8),
            strat: StrategyKind::Mm,
        };
        let c = RequestKind::Op {
            op: OpDesc::mm(4, 4, 4, Precision::Int4),
            strat: StrategyKind::Mm,
        };
        assert_eq!(BatchKey::of(&a), BatchKey::of(&b));
        assert_ne!(BatchKey::of(&a), BatchKey::of(&c));
    }

    #[test]
    fn model_requests_distinguish_shape_variants() {
        let full = model_by_name("mobilenetv2").unwrap();
        let small = downscale(&full, 4);
        let k_full = BatchKey::of(&RequestKind::Model {
            model: full.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        });
        let k_small = BatchKey::of(&RequestKind::Model {
            model: small.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        });
        let k_small2 = BatchKey::of(&RequestKind::Model {
            model: small.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        });
        assert_ne!(k_full, k_small, "downscaled variant must not coalesce");
        assert_eq!(k_small, k_small2);
        let k_prec = BatchKey::of(&RequestKind::Model {
            model: small,
            prec: Precision::Int4,
            policy: Policy::Mixed,
        });
        assert_ne!(k_small, k_prec);
    }

    #[test]
    fn intra_switches_count_transitions_only() {
        use Precision::*;
        assert_eq!(intra_request_switches(std::iter::empty::<Precision>()), 0);
        assert_eq!(intra_request_switches([Int8, Int8, Int8].into_iter()), 0);
        assert_eq!(intra_request_switches([Int8, Int4, Int4, Int16].into_iter()), 2);
    }

    #[test]
    fn execute_request_is_repeatable_on_one_engine() {
        let tuned = TunedPlans::new();
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let kind = RequestKind::Op {
            op: OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8),
            strat: StrategyKind::Ffcs,
        };
        let (a, la, _) = execute_request(&mut engine, &kind, &tuned).unwrap();
        // Interleave unrelated work at another precision, then repeat.
        let other = RequestKind::Op {
            op: OpDesc::mm(6, 12, 6, Precision::Int16),
            strat: StrategyKind::Mm,
        };
        execute_request(&mut engine, &other, &tuned).unwrap();
        let (b, lb, _) = execute_request(&mut engine, &kind, &tuned).unwrap();
        assert_eq!(a, b, "quiesce + switch normalization make replays bit-identical");
        assert_eq!(la, lb);
    }

    #[test]
    fn tuned_policy_without_plan_matches_mixed() {
        // A Tuned model request with an empty registry must degrade to the
        // static mixed mapping, bit-identically.
        let tuned = TunedPlans::new();
        let model = downscale(&model_by_name("mobilenetv2").unwrap(), 8);
        let mixed = RequestKind::Model {
            model: model.clone(),
            prec: Precision::Int8,
            policy: Policy::Mixed,
        };
        let tuned_kind = RequestKind::Model {
            model,
            prec: Precision::Int8,
            policy: Policy::Tuned,
        };
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let (a, la, ea) = execute_request(&mut engine, &mixed, &tuned).unwrap();
        let (b, lb, eb) = execute_request(&mut engine, &tuned_kind, &tuned).unwrap();
        assert_eq!(a, b);
        assert_eq!(la, lb);
        assert_eq!(ea, TuneEvent::None);
        assert_eq!(eb, TuneEvent::None);
    }

    #[test]
    fn tuned_online_stalls_once_then_hits_and_stays_bit_identical() {
        let registry = TunedPlans::new();
        let model = downscale(&model_by_name("mobilenetv2").unwrap(), 16);
        let kind = RequestKind::Model {
            model: model.clone(),
            prec: Precision::Int8,
            policy: Policy::TunedOnline,
        };
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        // First execution: uncovered key — the worker tunes and publishes.
        let (a, la, ea) = execute_request(&mut engine, &kind, &registry).unwrap();
        assert_eq!(ea, TuneEvent::Stall);
        assert_eq!(registry.len(), 1);
        // Second execution: served from the shared registry, bit-identical.
        let (b, lb, eb) = execute_request(&mut engine, &kind, &registry).unwrap();
        assert_eq!(eb, TuneEvent::PlanHit);
        assert_eq!(a, b, "stall vs registry replay must be bit-identical");
        assert_eq!(la, lb);
        // A second engine (another worker) sees the published plan too.
        let mut other = Engine::new(SpeedConfig::reference()).unwrap();
        let (c, lc, ec) = execute_request(&mut other, &kind, &registry).unwrap();
        assert_eq!(ec, TuneEvent::PlanHit);
        assert_eq!(a, c);
        assert_eq!(la, lc);
        // TunedOnline is never slower than the static mixed mapping.
        let mixed_kind = RequestKind::Model {
            model,
            prec: Precision::Int8,
            policy: Policy::Mixed,
        };
        let (m, _, _) = execute_request(&mut engine, &mixed_kind, &registry).unwrap();
        assert_eq!(a.macs, m.macs);
        assert!(a.cycles <= m.cycles, "online {} > mixed {}", a.cycles, m.cycles);
    }
}
