//! The engine pool: worker threads, the bounded queue, and completion
//! tickets.
//!
//! Topology: `ServePool::new` spawns N workers, each owning a warm
//! [`Engine`] attached to one pool-wide [`SharedPrograms`] cache. The
//! scheduler state (per-worker lanes, bound, counters) lives behind a
//! single mutex with two condvars — `work` (workers wait for jobs) and
//! `space` (blocking submitters wait for queue room). Shutdown is
//! graceful: workers drain every admitted request before exiting, so a
//! [`Ticket`] obtained from a successful submit always resolves.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::SpeedConfig;
use crate::coordinator::runner::default_workers;
use crate::engine::{CacheStats, Engine, SharedPrograms};
use crate::error::{Result, SpeedError};
use crate::obs::{Counter, Counters, CycleBreakdown, ObsConfig, Span, SpanCat, Tracer};
use crate::sim::ExecMode;
use crate::tune::TunedPlans;

use super::batch::{execute_request, BatchKey, TuneEvent};
use super::metrics::{SchedCounters, ServeMetrics};
use super::scheduler::{Job, SchedState};
use super::{Completion, MetricsSnapshot, Request, RequestKind, RequestResult};

/// Pool construction parameters.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Worker threads (= warm engines).
    pub workers: usize,
    /// Bound on admitted-but-unstarted requests across all lanes;
    /// [`ServePool::submit`] blocks and [`ServePool::try_submit`] fails
    /// once it is reached.
    pub capacity: usize,
    /// Micro-batch cap: how many same-key requests one program replay may
    /// serve (1 disables coalescing).
    pub max_batch: usize,
    /// An idle worker steals from another lane only once that lane holds
    /// at least this many requests.
    pub steal_threshold: usize,
    /// Simulator execution mode for every worker (bit-exact either way).
    pub exec_mode: ExecMode,
    /// Initial external-memory bytes per engine (grows lazily; 0 = the
    /// engine floor).
    pub mem_bytes: usize,
    /// Per-worker KV-cache residency budget, bytes: how much session K/V
    /// state one worker's external-memory layout keeps warm before the
    /// scheduler LRU-evicts the coldest session (a *spill*). 0 disables
    /// eviction (unlimited residency). Scheduling-only — affects where
    /// decode steps land and the hit/spill counters, never per-request
    /// stats.
    pub kv_capacity: u64,
    /// Observability configuration applied to every worker: when tracing
    /// is on, each worker records spans on its own timeline (`tid` =
    /// worker index) into a per-worker ring drained by
    /// [`ServePool::take_spans`]. Inert by contract — per-request stats
    /// and digests are bit-identical traced or not.
    pub obs: ObsConfig,
}

/// Default per-worker KV residency budget: 4 MiB — a small, deliberate
/// fraction of the engine's lazily-grown external memory, enough for
/// hundreds of `llm_tiny`-scale sessions while still exercising eviction
/// under sustained multi-tenant load.
pub const DEFAULT_KV_CAPACITY: u64 = 4 << 20;

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: default_workers().min(4),
            capacity: 256,
            max_batch: 8,
            steal_threshold: 2,
            exec_mode: ExecMode::Batch,
            mem_bytes: 0,
            kv_capacity: DEFAULT_KV_CAPACITY,
            obs: ObsConfig::off(),
        }
    }
}

/// Per-worker engine counters, harvested after every batch.
#[derive(Debug, Default, Clone, Copy)]
struct EngineCounters {
    cache: CacheStats,
    switches: u64,
    programs: usize,
    breakdown: CycleBreakdown,
}

struct PoolShared {
    cfg: SpeedConfig,
    opts: ServeOptions,
    sched: Mutex<SchedState>,
    work_cv: Condvar,
    space_cv: Condvar,
    metrics: ServeMetrics,
    programs: SharedPrograms,
    /// Tuned-plan registry consulted for `Policy::Tuned` model requests
    /// (empty unless the pool was built with [`ServePool::new_tuned`]).
    tuned: TunedPlans,
    engines: Mutex<Vec<EngineCounters>>,
    next_id: AtomicU64,
    /// Unified counter registry shared by every worker engine.
    counters: Counters,
    /// One tracer per worker timeline (empty when tracing is off).
    tracers: Vec<Tracer>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A handle for one submitted request; [`Ticket::wait`] blocks until a
/// worker fulfills it (shutdown drains the queue first, so every admitted
/// ticket resolves).
pub struct Ticket {
    id: u64,
    done: Arc<Completion>,
}

impl Ticket {
    /// The pool-assigned request id (ascending in submission order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request finishes; returns its result.
    pub fn wait(self) -> Result<RequestResult> {
        self.done.wait()
    }
}

/// A pool of warm engines serving concurrent request streams.
pub struct ServePool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl ServePool {
    /// Validate the configuration and spawn the workers.
    pub fn new(cfg: SpeedConfig, opts: ServeOptions) -> Result<ServePool> {
        Self::new_tuned(cfg, opts, TunedPlans::new())
    }

    /// [`ServePool::new`] with a shared tuned-plan registry: model
    /// requests submitted under
    /// [`Policy::Tuned`](crate::coordinator::Policy::Tuned) run the
    /// registered per-operator mappings (and fall back to the static
    /// mixed mapping where no plan matches).
    pub fn new_tuned(
        cfg: SpeedConfig,
        opts: ServeOptions,
        tuned: TunedPlans,
    ) -> Result<ServePool> {
        cfg.validate()?;
        if opts.workers == 0 {
            return Err(SpeedError::Config("serve pool needs at least 1 worker".into()));
        }
        if opts.capacity == 0 {
            return Err(SpeedError::Config("serve queue capacity must be >= 1".into()));
        }
        if opts.max_batch == 0 {
            return Err(SpeedError::Config("serve max_batch must be >= 1".into()));
        }
        let shared = Arc::new(PoolShared {
            cfg,
            opts,
            sched: Mutex::new(SchedState::new(
                opts.workers,
                opts.capacity,
                opts.max_batch,
                opts.steal_threshold,
                opts.kv_capacity,
            )),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            metrics: ServeMetrics::new(),
            programs: SharedPrograms::new(),
            tuned,
            engines: Mutex::new(vec![EngineCounters::default(); opts.workers]),
            next_id: AtomicU64::new(0),
            counters: Counters::new(),
            tracers: (0..opts.workers)
                .filter_map(|w| Tracer::from_config(&opts.obs, w as u32))
                .collect(),
        });
        let mut handles = Vec::with_capacity(opts.workers);
        for w in 0..opts.workers {
            let sh = shared.clone();
            match std::thread::Builder::new()
                .name(format!("speed-serve-{w}"))
                .spawn(move || worker_loop(sh, w))
            {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // Partial spawn: shut down and join the workers that
                    // did start, or they would block on `work_cv` forever.
                    let mut partial = ServePool { shared, handles };
                    partial.signal_and_join();
                    return Err(SpeedError::Serve(format!("spawning worker {w}: {e}")));
                }
            }
        }
        Ok(ServePool { shared, handles })
    }

    /// Submit a request, blocking while the queue is at capacity
    /// (backpressure). Fails with [`SpeedError::Serve`] once the pool is
    /// shut down. Accepts a built [`Request`] or (for migration) a bare
    /// [`RequestKind`].
    pub fn submit(&self, req: impl Into<Request>) -> Result<Ticket> {
        self.enqueue(req.into(), true)
    }

    /// Submit without blocking: a full queue is an immediate typed
    /// [`SpeedError::Serve`] overflow (counted in the metrics).
    pub fn try_submit(&self, req: impl Into<Request>) -> Result<Ticket> {
        self.enqueue(req.into(), false)
    }

    fn enqueue(&self, req: Request, block: bool) -> Result<Ticket> {
        let prec = req.kind.precision();
        let key = BatchKey::of(&req.kind);
        let mut s = lock(&self.shared.sched);
        loop {
            if s.shutdown {
                return Err(SpeedError::Serve("submit to a shut-down pool".into()));
            }
            if s.has_space() {
                break;
            }
            if !block {
                self.shared.metrics.record_rejected();
                return Err(SpeedError::Serve(format!(
                    "request queue full ({} queued, capacity {})",
                    s.queued(),
                    s.capacity()
                )));
            }
            s = self.shared.space_cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let done = Arc::new(Completion::default());
        let job = Job {
            id,
            req,
            key,
            prec,
            enqueued: Instant::now(),
            done: done.clone(),
        };
        if s.route(job).is_err() {
            // Unreachable: `has_space` held under the same lock.
            return Err(SpeedError::Serve("queue full".into()));
        }
        drop(s);
        self.shared.metrics.record_submitted();
        self.shared.work_cv.notify_all();
        Ok(Ticket { id, done })
    }

    /// Submit a stream of requests (blocking, in order) and wait for all
    /// results; results come back in submission order.
    pub fn run_all<I>(&self, reqs: I) -> Result<Vec<RequestResult>>
    where
        I: IntoIterator,
        I::Item: Into<Request>,
    {
        let tickets: Result<Vec<Ticket>> =
            reqs.into_iter().map(|r| self.submit(r)).collect();
        tickets?.into_iter().map(Ticket::wait).collect()
    }

    /// Point-in-time aggregate metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        let sched = {
            let s = lock(&self.shared.sched);
            SchedCounters {
                steals: s.steals,
                affinity_hits: s.affinity_hits,
                affinity_misses: s.affinity_misses,
                max_depth: s.max_depth,
                avg_depth: s.avg_depth(),
                kv_hits: s.kv_hits,
                kv_misses: s.kv_misses,
                kv_spills: s.kv_spills,
                kv_bytes_peak: s.kv_bytes_peak,
            }
        };
        let engines = lock(&self.shared.engines);
        let mut cache = CacheStats::default();
        let mut switches = 0u64;
        let mut programs = 0usize;
        let mut breakdown = CycleBreakdown::default();
        for e in engines.iter() {
            cache.hits += e.cache.hits;
            cache.misses += e.cache.misses;
            cache.shared_hits += e.cache.shared_hits;
            switches += e.switches;
            programs += e.programs;
            breakdown.merge(&e.breakdown);
        }
        drop(engines);
        // Unified registry snapshot: engine/tune counters are fed live by
        // the workers; scheduler counters live under the scheduler lock
        // (its fast path) and are mirrored in at snapshot time.
        let mut counters = self.shared.counters.snapshot();
        counters[Counter::SchedSteals.index()].1 = sched.steals;
        counters[Counter::SchedAffinityHits.index()].1 = sched.affinity_hits;
        counters[Counter::SchedAffinityMisses.index()].1 = sched.affinity_misses;
        counters[Counter::KvHits.index()].1 = sched.kv_hits;
        counters[Counter::KvMisses.index()].1 = sched.kv_misses;
        counters[Counter::KvSpills.index()].1 = sched.kv_spills;
        counters[Counter::TraceSpansDropped.index()].1 =
            self.shared.tracers.iter().map(|t| t.dropped()).sum();
        self.shared.metrics.snapshot(
            self.shared.opts.workers,
            sched,
            cache,
            switches,
            programs,
            breakdown,
            counters,
        )
    }

    /// Drain every worker tracer's recorded spans (oldest first per
    /// worker timeline). Empty when the pool was built with tracing off.
    pub fn take_spans(&self) -> Vec<Span> {
        self.shared.tracers.iter().flat_map(|t| t.take_spans()).collect()
    }

    /// Number of distinct compiled programs in the pool-wide shared cache.
    pub fn shared_programs(&self) -> usize {
        self.shared.programs.len()
    }

    fn signal_and_join(&mut self) {
        {
            let mut s = lock(&self.shared.sched);
            s.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: every admitted request is drained and fulfilled
    /// first; returns the final metrics snapshot.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.signal_and_join();
        self.metrics()
    }
}

impl Drop for ServePool {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.signal_and_join();
        }
    }
}

fn build_engine(shared: &PoolShared, w: usize) -> Engine {
    let mut engine =
        Engine::with_shared(shared.cfg, shared.opts.mem_bytes, shared.programs.clone())
            .expect("pool configuration was validated at construction");
    engine.set_exec_mode(shared.opts.exec_mode);
    engine.set_counters(shared.counters.clone());
    if let Some(t) = shared.tracers.get(w) {
        engine.set_tracer(Some(t.clone()));
    }
    engine
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into())
}

fn worker_loop(shared: Arc<PoolShared>, w: usize) {
    let mut engine = build_engine(&shared, w);
    // Counters accumulated by engines discarded after a panic — added back
    // at every harvest so pool metrics never lose prior accounting.
    let mut lost = EngineCounters::default();
    loop {
        let batch = {
            let mut s = lock(&shared.sched);
            loop {
                if let Some(b) = s.next_batch(w) {
                    break Some(b);
                }
                if s.shutdown {
                    break None;
                }
                s = shared.work_cv.wait(s).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(batch) = batch else { return };
        shared.space_cv.notify_all();

        let kind = batch[0].req.kind.clone();
        let req_begin = shared.tracers.get(w).map(|t| t.now());
        let (executed, tune_event) = match catch_unwind(AssertUnwindSafe(|| {
            execute_request(&mut engine, &kind, &shared.tuned)
        })) {
                Ok(Ok((stats, layers, event))) => (Ok((stats, layers)), event),
                Ok(Err(e)) => (Err(e), TuneEvent::None),
                Err(payload) => {
                    // The engine's internal state is unknowable after a
                    // panic: preserve its accounting, rebuild it (the
                    // shared cache keeps every compilation), and fail the
                    // batch with a typed error.
                    let cache = engine.cache_stats();
                    lost.cache.hits += cache.hits;
                    lost.cache.misses += cache.misses;
                    lost.cache.shared_hits += cache.shared_hits;
                    lost.switches += engine.precision_switches();
                    lost.programs += engine.compiled_programs();
                    lost.breakdown.merge(&engine.breakdown());
                    engine = build_engine(&shared, w);
                    (
                        Err(SpeedError::Serve(format!(
                            "worker {w} panicked serving {}: {}",
                            kind.label(),
                            panic_msg(payload.as_ref())
                        ))),
                        TuneEvent::None,
                    )
                }
            };
        // Online-tuning accounting: one event per executed batch (the
        // batch runs the search / registry lookup once, whatever its
        // size). The stall happened on this worker's thread only — other
        // lanes kept serving throughout.
        match tune_event {
            TuneEvent::Stall => {
                shared.metrics.record_tune_stall();
                shared.counters.incr(Counter::TuneStalls);
            }
            TuneEvent::PlanHit => {
                shared.metrics.record_plan_hit();
                shared.counters.incr(Counter::TunePlanHits);
            }
            TuneEvent::None => {}
        }

        let n = batch.len();
        // One request span per executed batch: begin was the worker's
        // virtual time before execution, the duration its simulated
        // cycles (coalesced requests share one execution).
        if let (Some(begin), Some(t), Ok((stats, _))) =
            (req_begin, shared.tracers.get(w), &executed)
        {
            t.record(SpanCat::Request, kind.label(), begin, stats.cycles);
        }
        shared.metrics.record_batch(n as u64);
        for job in batch {
            let latency = job.enqueued.elapsed();
            let result = executed.clone().map(|(stats, layers)| RequestResult {
                id: job.id,
                stats,
                layers,
                worker: w,
                batch_size: n,
                latency,
                session: job.req.session,
                phase: job.req.phase,
            });
            shared.metrics.record_finished(result.is_ok(), latency, job.req.phase);
            job.done.fulfill(result);
        }
        let cache = engine.cache_stats();
        let mut breakdown = lost.breakdown;
        breakdown.merge(&engine.breakdown());
        lock(&shared.engines)[w] = EngineCounters {
            cache: CacheStats {
                hits: lost.cache.hits + cache.hits,
                misses: lost.cache.misses + cache.misses,
                shared_hits: lost.cache.shared_hits + cache.shared_hits,
            },
            switches: lost.switches + engine.precision_switches(),
            programs: lost.programs + engine.compiled_programs(),
            breakdown,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::coordinator::Policy;
    use crate::isa::StrategyKind;
    use crate::models::zoo::Model;
    use crate::models::OpDesc;

    fn tiny_op(prec: Precision) -> RequestKind {
        RequestKind::Op {
            op: OpDesc::mm(4, 8, 4, prec),
            strat: StrategyKind::Mm,
        }
    }

    fn tiny_model_kind(prec: Precision) -> RequestKind {
        RequestKind::Model {
            model: Model {
                name: "tiny",
                ops: vec![
                    OpDesc::conv(4, 8, 10, 10, 3, 1, 1, prec),
                    OpDesc::mm(10, 8, 12, prec),
                ],
                scalar_fraction: 0.1,
            },
            prec,
            policy: Policy::Mixed,
        }
    }

    fn pool(workers: usize, capacity: usize, max_batch: usize) -> ServePool {
        ServePool::new(
            SpeedConfig::reference(),
            ServeOptions { workers, capacity, max_batch, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn serves_and_preserves_order() {
        let p = pool(2, 64, 4);
        let kinds: Vec<RequestKind> = (0..10)
            .map(|i| {
                tiny_op(if i % 2 == 0 { Precision::Int8 } else { Precision::Int4 })
            })
            .collect();
        let results = p.run_all(kinds).unwrap();
        assert_eq!(results.len(), 10);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.stats.cycles > 0);
            assert!(r.stats.macs > 0);
        }
        // Same-key requests report identical deterministic stats.
        assert_eq!(results[0].stats, results[2].stats);
        assert_eq!(results[1].stats, results[3].stats);
        let snap = p.shutdown();
        assert_eq!(snap.completed, 10);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.in_flight, 0);
    }

    #[test]
    fn worker_count_does_not_change_stats() {
        let kinds: Vec<RequestKind> = vec![
            tiny_op(Precision::Int8),
            tiny_model_kind(Precision::Int4),
            tiny_op(Precision::Int16),
            tiny_op(Precision::Int8),
            tiny_model_kind(Precision::Int4),
        ];
        let a = pool(1, 64, 1).run_all(kinds.clone()).unwrap();
        let b = pool(3, 64, 8).run_all(kinds).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.stats, y.stats, "request {}", x.id);
            assert_eq!(x.layers, y.layers);
        }
    }

    #[test]
    fn micro_batching_coalesces_identical_requests() {
        // One worker pinned on a slow model request while a burst of
        // identical light requests queues up behind it — the burst
        // coalesces into (almost certainly one) replay batch.
        let p = pool(1, 64, 16);
        let mut kinds: Vec<RequestKind> = vec![tiny_model_kind(Precision::Int8)];
        kinds.extend((0..11).map(|_| tiny_op(Precision::Int8)));
        let results = p.run_all(kinds).unwrap();
        let snap = p.shutdown();
        // All twelve completed, in strictly fewer batches than requests.
        assert_eq!(snap.completed, 12);
        assert!(snap.batches < 12, "expected coalescing, got {} batches", snap.batches);
        assert!(snap.coalesced >= 2);
        // Batched or not, the identical requests report identical stats.
        for r in &results[1..] {
            assert_eq!(r.stats, results[1].stats);
        }
    }

    #[test]
    fn try_submit_overflows_with_typed_error() {
        // Pool whose single worker is kept busy: fill the queue, then
        // overflow it.
        let p = pool(1, 2, 1);
        let mut tickets = Vec::new();
        // Admit until the bound trips (the worker may drain a few).
        let mut overflowed = false;
        for _ in 0..64 {
            match p.try_submit(tiny_model_kind(Precision::Int8)) {
                Ok(t) => tickets.push(t),
                Err(SpeedError::Serve(m)) => {
                    assert!(m.contains("queue full"), "{m}");
                    overflowed = true;
                    break;
                }
                Err(other) => panic!("unexpected {other}"),
            }
        }
        assert!(overflowed, "capacity-2 queue never overflowed");
        for t in tickets {
            t.wait().unwrap();
        }
        let snap = p.shutdown();
        assert!(snap.rejected >= 1);
    }

    #[test]
    fn shutdown_drains_admitted_requests_then_rejects() {
        let p = pool(2, 64, 4);
        let tickets: Vec<Ticket> =
            (0..6).map(|_| p.submit(tiny_op(Precision::Int8)).unwrap()).collect();
        let snap = p.shutdown();
        assert_eq!(snap.completed + snap.failed, 6);
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let mut p = pool(1, 4, 1);
        p.signal_and_join();
        match p.submit(tiny_op(Precision::Int8)) {
            Err(SpeedError::Serve(m)) => assert!(m.contains("shut-down"), "{m}"),
            Err(other) => panic!("unexpected {other}"),
            Ok(_) => panic!("submit succeeded after shutdown"),
        }
    }

    #[test]
    fn pool_rejects_bad_options() {
        let cfg = SpeedConfig::reference();
        assert!(matches!(
            ServePool::new(cfg, ServeOptions { workers: 0, ..Default::default() }),
            Err(SpeedError::Config(_))
        ));
        assert!(matches!(
            ServePool::new(cfg, ServeOptions { capacity: 0, ..Default::default() }),
            Err(SpeedError::Config(_))
        ));
        assert!(matches!(
            ServePool::new(cfg, ServeOptions { max_batch: 0, ..Default::default() }),
            Err(SpeedError::Config(_))
        ));
        let bad = SpeedConfig { lanes: 3, ..cfg };
        assert!(matches!(
            ServePool::new(bad, ServeOptions::default()),
            Err(SpeedError::Config(_))
        ));
    }

    #[test]
    fn shared_cache_serves_the_whole_pool() {
        let p = pool(3, 64, 1);
        let kinds: Vec<RequestKind> =
            (0..9).map(|_| tiny_op(Precision::Int8)).collect();
        p.run_all(kinds).unwrap();
        // One distinct program pool-wide (or_insert keeps the first copy
        // even if two workers raced to compile it).
        assert_eq!(p.shared_programs(), 1);
        let snap = p.shutdown();
        assert_eq!(snap.cache.hits + snap.cache.misses, 9, "one lookup per request");
        assert!(
            snap.cache.misses <= 3,
            "at most one racing compile per worker: {}",
            snap.cache.misses
        );
        assert!(snap.cache.hits >= 6);
    }

    #[test]
    fn failing_request_reports_typed_error_and_pool_survives() {
        let p = pool(1, 8, 1);
        // An invalid operator: MM with zero K fails validation inside the
        // compiler. Build it directly (constructors allow it; validate()
        // is the compile-time gate).
        let bad = RequestKind::Op {
            op: OpDesc::mm(4, 0, 4, Precision::Int8),
            strat: StrategyKind::Mm,
        };
        let err = p.submit(bad).unwrap().wait().unwrap_err();
        // Typed, not a panic — and the pool still serves afterwards.
        let _ = err.kind();
        let ok = p.submit(tiny_op(Precision::Int8)).unwrap().wait().unwrap();
        assert!(ok.stats.cycles > 0);
        let snap = p.shutdown();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn tuned_registry_serves_tuned_model_requests() {
        use crate::models::zoo::model_by_name;
        use crate::report::fig12::downscale;
        use crate::tune::{tune_model, TuneOptions, TunedPlans};
        let cfg = SpeedConfig::reference();
        let model = downscale(&model_by_name("resnet18").unwrap(), 16);
        let prec = Precision::Int8;
        let plan = tune_model(&cfg, &model, prec, &TuneOptions::default()).unwrap();
        let registry = TunedPlans::new();
        registry.insert(plan);
        let p = ServePool::new_tuned(
            cfg,
            ServeOptions { workers: 2, capacity: 16, max_batch: 2, ..Default::default() },
            registry,
        )
        .unwrap();
        let tuned_kind =
            RequestKind::Model { model: model.clone(), prec, policy: Policy::Tuned };
        let mixed_kind = RequestKind::Model { model, prec, policy: Policy::Mixed };
        let results =
            p.run_all(vec![tuned_kind.clone(), mixed_kind, tuned_kind]).unwrap();
        // Tuned requests are deterministic, compute the same work, and are
        // never slower than the static mixed mapping.
        assert_eq!(results[0].stats, results[2].stats);
        assert_eq!(results[0].stats.macs, results[1].stats.macs);
        assert!(
            results[0].stats.cycles <= results[1].stats.cycles,
            "tuned {} > mixed {}",
            results[0].stats.cycles,
            results[1].stats.cycles
        );
        p.shutdown();
    }

    #[test]
    fn results_exclude_boundary_precision_switches() {
        // One worker alternating precisions: per-request stats must stay
        // schedule-independent (0 internal switches), while the aggregate
        // counter sees the datapath flips.
        let p = pool(1, 64, 1);
        let kinds = vec![
            tiny_op(Precision::Int16),
            tiny_op(Precision::Int4),
            tiny_op(Precision::Int16),
            tiny_op(Precision::Int4),
        ];
        let results = p.run_all(kinds).unwrap();
        for r in &results {
            assert_eq!(r.stats.precision_switches, 0);
        }
        let snap = p.shutdown();
        assert!(
            snap.precision_switches >= 3,
            "datapath flipped at request boundaries: {}",
            snap.precision_switches
        );
    }

    #[test]
    fn tracing_pool_is_stats_inert_and_collects_spans() {
        use crate::obs::TraceLevel;
        let kinds: Vec<RequestKind> = vec![
            tiny_op(Precision::Int8),
            tiny_model_kind(Precision::Int4),
            tiny_op(Precision::Int8),
            tiny_op(Precision::Int16),
        ];
        let plain = pool(2, 64, 2).run_all(kinds.clone()).unwrap();
        let traced_pool = ServePool::new(
            SpeedConfig::reference(),
            ServeOptions {
                workers: 2,
                capacity: 64,
                max_batch: 2,
                obs: ObsConfig::tracing(TraceLevel::Run),
                ..Default::default()
            },
        )
        .unwrap();
        let traced = traced_pool.run_all(kinds).unwrap();
        // Inertness: identical per-request stats, tracer on or off.
        for (a, b) in plain.iter().zip(&traced) {
            assert_eq!(a.stats, b.stats, "request {}", a.id);
            assert_eq!(a.layers, b.layers);
        }
        let spans = traced_pool.take_spans();
        assert!(spans.iter().any(|s| s.cat == SpanCat::Request));
        assert!(spans.iter().any(|s| s.cat == SpanCat::Op));
        let snap = traced_pool.shutdown();
        assert!(snap.breakdown.total() > 0);
        let get = |name: &str| {
            snap.counters.iter().find(|(n, _)| *n == name).map(|&(_, v)| v).unwrap()
        };
        assert_eq!(
            get("engine_cache_hits") + get("engine_cache_misses"),
            snap.cache.lookups(),
            "registry mirrors the harvested cache counters"
        );
        assert_eq!(get("sched_steals"), snap.steals);
        assert_eq!(get("trace_spans_dropped"), 0);
    }

    #[test]
    fn decode_follows_session_residency_and_phases_are_counted() {
        use crate::serve::{Phase, Request, SessionId};
        let p = pool(2, 64, 1);
        let sid = SessionId(1);
        let prefill =
            Request::op(OpDesc::mm(4, 8, 4, Precision::Int8)).session(sid).kv(512);
        let mut reqs = vec![prefill];
        reqs.extend((0..4).map(|_| {
            Request::op(OpDesc::mm(1, 8, 4, Precision::Int8))
                .session(sid)
                .phase(Phase::Decode)
                .kv(512)
        }));
        let results = p.run_all(reqs).unwrap();
        assert_eq!(results[0].phase, Phase::Prefill);
        assert_eq!(results[0].session, Some(sid));
        // Every decode step lands on the lane holding the session's KV
        // residency (installed when the prefill was routed).
        let resident = results[1].worker;
        for r in &results[1..] {
            assert_eq!(r.phase, Phase::Decode);
            assert_eq!(r.session, Some(sid));
            assert_eq!(r.worker, resident, "decode migrated off the resident lane");
        }
        let snap = p.shutdown();
        assert_eq!(snap.prefill_requests, 1);
        assert_eq!(snap.decode_requests, 4);
        assert_eq!(snap.kv_hits, 4);
        assert_eq!(snap.kv_misses, 0);
        assert_eq!(snap.kv_spills, 0);
        assert!(snap.kv_bytes_peak >= 512, "peak {}", snap.kv_bytes_peak);
    }
}
