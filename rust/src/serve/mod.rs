//! `speed_rvv::serve` — the multi-tenant serving subsystem.
//!
//! The [`Engine`](crate::engine::Engine) API is compile-once /
//! execute-many for *one* caller; a deployment multiplexes many
//! concurrent request streams — different models, different precisions —
//! over a pool of warm engines. This module is that layer:
//!
//! * [`ServePool`] — N worker threads, each owning a warm engine, behind
//!   a **bounded** MPMC request queue. Submission past the bound either
//!   blocks ([`ServePool::submit`], backpressure) or fails with a typed
//!   [`SpeedError::Serve`](crate::error::SpeedError::Serve)
//!   ([`ServePool::try_submit`]). Workers share one
//!   [`SharedPrograms`](crate::engine::SharedPrograms) cache, so each
//!   distinct `(op, strategy, precision, config)` program is compiled
//!   once pool-wide.
//! * **Precision-affinity scheduling** (`scheduler`) — a request is
//!   steered to the lane of the worker last configured at its precision,
//!   so the per-layer `VSACFG` names the already-active precision and the
//!   datapath switch is elided (Sec. II-E); an idle worker steals from
//!   the most backed-up lane once it exceeds a threshold.
//! * **Dynamic micro-batching** (`batch`) — same-[`BatchKey`] requests
//!   waiting in a lane are coalesced and served by a single replay of the
//!   cached compiled programs; every member of the batch receives the
//!   same (deterministic) statistics at a fraction of the simulation
//!   cost.
//! * **Metrics** ([`MetricsSnapshot`]) — throughput, queue depth,
//!   p50/p95/p99 latency, pool-wide program-cache hit rate, steal and
//!   affinity counters, and aggregate datapath precision switches.
//! * **Scenario files** ([`Scenario`]) — JSON workload descriptions
//!   (model mix, precision mix, deterministic arrival pattern + seed)
//!   under `bench/scenarios/`, driven by `repro serve-bench`.
//! * **Online first-request tuning** — a model request under
//!   [`Policy::TunedOnline`](crate::coordinator::Policy::TunedOnline)
//!   (scenario `"policy": "tuned_online"`) consults the pool's shared
//!   [`TunedPlans`](crate::tune::TunedPlans) registry; the first request
//!   for an uncovered `(model, precision, config-sig)` key tunes on the
//!   owning worker (a *tune stall*, counted in
//!   [`MetricsSnapshot::tune_stalls`]) and publishes the plan, and every
//!   later request replays it ([`MetricsSnapshot::plan_hits`]). Only the
//!   stalling worker's lane pays the search; other lanes keep serving.
//! * **Stateful transformer serving** — a request may carry a
//!   [`SessionId`] and a [`Phase`]. [`Phase::Prefill`] requests are
//!   throughput-bound and batchable; [`Phase::Decode`] requests are
//!   latency-bound and *cache-affine*: the scheduler pins a session's
//!   decode steps to the lane holding its KV-cache residency, tracked in
//!   bytes against a per-worker budget
//!   ([`ServeOptions::kv_capacity`](pool::ServeOptions::kv_capacity))
//!   with LRU eviction and hit/miss/spill accounting in
//!   [`MetricsSnapshot`]. Scenario `"llm"` mix entries
//!   ([`Workload::Llm`]) expand one logical generation into a prefill
//!   request plus many growing-K decode-step requests sharing a session.
//!
//! # Determinism contract
//!
//! Scheduling is semantics-preserving: the pool quiesces the worker's
//! pipeline at every request boundary
//! ([`Engine::quiesce`](crate::engine::Engine::quiesce)), so a request's
//! [`SimStats`] are a pure function of the request itself and the
//! hardware configuration — bit-identical no matter how many workers the
//! pool has, whether the request was micro-batched or served alone,
//! whether its programs were cache hits, and whether the simulator ran in
//! batch or `--exact` mode (`tests/serve_parity.rs` enforces all four).
//! One field needs care: a *datapath* precision switch at a request
//! boundary depends on what the worker ran before, which is exactly the
//! scheduling the contract must hide. Per-request
//! [`SimStats::precision_switches`] therefore counts only switches
//! *within* the request (zero for single-precision requests), while
//! boundary switches are accounted in the aggregate
//! [`MetricsSnapshot::precision_switches`] — the number the
//! precision-affinity scheduler exists to minimize.
//!
//! Session affinity follows the same rule: KV residency decides *where*
//! a decode step runs, never *what* it computes — the decode workload
//! already names its cache length in its operator shapes, so its
//! `SimStats` are identical whether the step hit its resident lane or
//! was re-routed after a spill. KV hits, misses, and spills are
//! aggregate [`MetricsSnapshot`] counters only, and
//! `tests/serve_parity.rs` pins the per-request digest across worker
//! counts for session-carrying streams too.

pub mod batch;
pub mod metrics;
pub mod pool;
pub mod scenario;
mod scheduler;

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::{Precision, SpeedConfig};
use crate::coordinator::runner::default_workers;
use crate::coordinator::Policy;
use crate::error::Result;
use crate::isa::StrategyKind;
use crate::models::zoo::Model;
use crate::models::OpDesc;
use crate::obs::{ObsConfig, Span};
use crate::sim::{ExecMode, SimStats};

pub use batch::BatchKey;
pub use metrics::MetricsSnapshot;
pub use pool::{ServeOptions, ServePool, Ticket};
pub use scenario::{Arrival, MixEntry, Scenario, Workload, XorShift64};

use crate::runtime::json::{jf, jstr, Fnv64};

/// What one request asks the pool to run (timing/traffic simulation; the
/// functional path is certified separately by the golden checks).
#[derive(Debug, Clone)]
pub enum RequestKind {
    /// A whole-model inference at a precision under a strategy policy.
    Model { model: Model, prec: Precision, policy: Policy },
    /// A single operator under an explicit dataflow strategy.
    Op { op: OpDesc, strat: StrategyKind },
}

impl RequestKind {
    /// The operand precision the request runs at — the affinity key the
    /// scheduler routes on.
    pub fn precision(&self) -> Precision {
        match self {
            RequestKind::Model { prec, .. } => *prec,
            RequestKind::Op { op, .. } => op.prec,
        }
    }

    /// Short human-readable tag (`mobilenetv2@INT8`, `MM@INT4`).
    pub fn label(&self) -> String {
        match self {
            RequestKind::Model { model, prec, .. } => format!("{}@{prec}", model.name),
            RequestKind::Op { op, .. } => format!("{}@{}", op.kind, op.prec),
        }
    }

}

/// Identity of one logical serving session — an autoregressive
/// generation whose decode steps share KV-cache residency. Ids are
/// caller-chosen (scenario generation numbers them in draw order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub u64);

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Serving phase — the scheduling class of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Phase {
    /// Throughput-bound and batchable: whole-prompt prefill, and every
    /// stateless request (the phase-less API of earlier releases).
    #[default]
    Prefill,
    /// Latency-bound and cache-affine: one autoregressive decode step
    /// that must land on the worker holding its session's KV residency.
    Decode,
}

impl std::fmt::Display for Phase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        })
    }
}

/// A typed serve request: what to run ([`RequestKind`]) plus the serving
/// metadata the scheduler routes on. Construct through the builders —
/// the struct is `#[non_exhaustive]`, so future metadata (priorities,
/// deadlines, ...) will not be breaking changes:
///
/// ```
/// use speed_rvv::config::Precision;
/// use speed_rvv::models::model_by_name;
/// use speed_rvv::serve::{Phase, Request, SessionId};
///
/// let m = model_by_name("llm_tiny").unwrap();
/// let req = Request::model(m)
///     .prec(Precision::Int4)
///     .session(SessionId(7))
///     .phase(Phase::Decode);
/// assert_eq!(req.phase, Phase::Decode);
/// ```
#[non_exhaustive]
#[derive(Debug, Clone)]
pub struct Request {
    /// What the request executes.
    pub kind: RequestKind,
    /// Logical session this request belongs to (`None` = stateless).
    pub session: Option<SessionId>,
    /// Scheduling class (defaults to [`Phase::Prefill`]).
    pub phase: Phase,
    /// KV-cache bytes the session occupies *after* this request — the
    /// residency charged against the owning worker's KV budget (0 for
    /// stateless requests).
    pub kv_bytes: u64,
}

impl Request {
    /// A whole-model request at the default INT8 precision under the
    /// paper's mixed strategy policy; refine with
    /// [`prec`](Request::prec) / [`policy`](Request::policy).
    pub fn model(model: Model) -> Request {
        RequestKind::Model { model, prec: Precision::Int8, policy: Policy::Mixed }.into()
    }

    /// A single-operator request under the operator's preferred
    /// strategy; refine with [`strategy`](Request::strategy).
    pub fn op(op: OpDesc) -> Request {
        RequestKind::Op { op, strat: op.preferred_strategy() }.into()
    }

    /// Set the operand precision (re-types a single-operator payload).
    pub fn prec(mut self, prec: Precision) -> Request {
        match &mut self.kind {
            RequestKind::Model { prec: p, .. } => *p = prec,
            RequestKind::Op { op, .. } => op.prec = prec,
        }
        self
    }

    /// Set the strategy policy (whole-model requests; no-op for ops).
    pub fn policy(mut self, policy: Policy) -> Request {
        if let RequestKind::Model { policy: p, .. } = &mut self.kind {
            *p = policy;
        }
        self
    }

    /// Set the dataflow strategy (single-operator requests; no-op for
    /// whole-model requests, whose policy picks per-layer strategies).
    pub fn strategy(mut self, strat: StrategyKind) -> Request {
        if let RequestKind::Op { strat: s, .. } = &mut self.kind {
            *s = strat;
        }
        self
    }

    /// Attach the request to a logical session.
    pub fn session(mut self, id: SessionId) -> Request {
        self.session = Some(id);
        self
    }

    /// Set the serving phase.
    pub fn phase(mut self, phase: Phase) -> Request {
        self.phase = phase;
        self
    }

    /// Declare the session's KV-cache residency (bytes) after this
    /// request.
    pub fn kv(mut self, bytes: u64) -> Request {
        self.kv_bytes = bytes;
        self
    }
}

impl From<RequestKind> for Request {
    fn from(kind: RequestKind) -> Request {
        Request { kind, session: None, phase: Phase::Prefill, kv_bytes: 0 }
    }
}

/// The outcome of one served request.
#[derive(Debug, Clone)]
pub struct RequestResult {
    /// Id of the request this result answers.
    pub id: u64,
    /// Deterministic per-request simulation statistics (see the module
    /// docs for the determinism contract).
    pub stats: SimStats,
    /// Vector operators executed.
    pub layers: usize,
    /// Worker that executed the request (informational; which worker a
    /// request lands on is schedule-dependent, its stats are not).
    pub worker: usize,
    /// Number of requests coalesced into the micro-batch this rode in
    /// (1 = served alone).
    pub batch_size: usize,
    /// Submit-to-completion wall time (measured, host-side).
    pub latency: Duration,
    /// Session the request belonged to (copied from the request).
    pub session: Option<SessionId>,
    /// Serving phase the request was accounted under.
    pub phase: Phase,
}

/// One-shot completion slot a worker fulfills and a [`Ticket`] waits on.
#[derive(Default)]
pub(crate) struct Completion {
    slot: Mutex<Option<Result<RequestResult>>>,
    ready: Condvar,
}

impl Completion {
    pub(crate) fn fulfill(&self, result: Result<RequestResult>) {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(result);
        }
        drop(slot);
        self.ready.notify_all();
    }

    pub(crate) fn wait(&self) -> Result<RequestResult> {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.ready.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// How `serve-bench` runs a [`Scenario`].
#[derive(Debug, Clone, Copy)]
pub struct ServeBenchOptions {
    /// Pool worker count.
    pub workers: usize,
    /// Downscaled models and a capped request count (the CI `serve-smoke`
    /// configuration).
    pub quick: bool,
    /// Per-instruction simulation (the escape hatch / parity oracle).
    pub exact: bool,
    /// Override the scenario's micro-batch cap (1 disables coalescing).
    pub max_batch: Option<usize>,
    /// Auto-tune every distinct model workload of the mix before the run
    /// and serve model requests under
    /// [`Policy::Tuned`](crate::coordinator::Policy::Tuned) from the
    /// pool's [`TunedPlans`](crate::tune::TunedPlans) registry. Tuning
    /// wall time is excluded from the measured serving window.
    pub tuned: bool,
    /// Observability configuration for the pool's workers (tracing is
    /// inert: the stats digest is bit-identical traced or not). Spans
    /// are returned by [`run_serve_bench_traced`].
    pub obs: ObsConfig,
}

impl Default for ServeBenchOptions {
    fn default() -> Self {
        ServeBenchOptions {
            workers: default_workers().min(4),
            quick: true,
            exact: false,
            max_batch: None,
            tuned: false,
            obs: ObsConfig::off(),
        }
    }
}

/// Everything one `serve-bench` invocation measured — serialized as
/// `SERVE_bench.json`.
#[derive(Debug, Clone)]
pub struct ServeBenchReport {
    /// Scenario name.
    pub scenario: String,
    /// Scenario RNG seed the run used.
    pub seed: u64,
    /// The run used the downscaled quick configuration.
    pub quick: bool,
    /// The run simulated per-instruction (exact mode).
    pub exact: bool,
    /// Model requests were served from auto-tuned mapping plans.
    pub tuned: bool,
    /// Worker engines that served the run.
    pub workers: usize,
    /// Requests generated and served.
    pub requests: usize,
    /// Simulated cycles summed over every request.
    pub total_cycles: u64,
    /// Simulated MACs summed over every request.
    pub total_macs: u64,
    /// External-memory traffic summed over every request (bytes).
    pub total_traffic_bytes: u64,
    /// FNV-64 digest over the ordered per-request [`SimStats`]: identical
    /// for a fixed scenario seed regardless of worker count, micro-batch
    /// cap, and batch-vs-exact simulation mode — the determinism witness
    /// `serve-bench` prints so any two runs can be compared at a glance.
    pub stats_digest: u64,
    /// Wall time of the submit-to-last-completion window.
    pub wall_s: f64,
    /// Final pool metrics snapshot.
    pub snapshot: MetricsSnapshot,
}

impl ServeBenchReport {
    /// Serialize as the `SERVE_bench.json` document.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(2048);
        s.push_str("{\n");
        // Schema 3: cycle-attribution breakdown + unified counter
        // registry in the metrics object (schema 2 added the phase-split
        // metrics + KV-cache residency counters).
        s.push_str("  \"schema\": 3,\n  \"bench\": \"serve-bench\",\n");
        s.push_str(&format!("  \"scenario\": {},\n", jstr(&self.scenario)));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"exact\": {},\n", self.exact));
        s.push_str(&format!("  \"tuned\": {},\n", self.tuned));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"requests\": {},\n", self.requests));
        s.push_str(&format!("  \"wall_s\": {},\n", jf(self.wall_s)));
        s.push_str(&format!(
            "  \"sim\": {{ \"cycles\": {}, \"macs\": {}, \"traffic_bytes\": {} }},\n",
            self.total_cycles, self.total_macs, self.total_traffic_bytes
        ));
        s.push_str(&format!(
            "  \"stats_digest\": {},\n",
            jstr(&format!("{:016x}", self.stats_digest))
        ));
        s.push_str("  \"metrics\": ");
        s.push_str(&self.snapshot.json_object("  "));
        s.push_str("\n}\n");
        s
    }

    /// Human-readable one-screen summary.
    pub fn summary_text(&self) -> String {
        let m = &self.snapshot;
        let mut s = String::new();
        s.push_str(&format!(
            "serve-bench '{}' (seed {}): {} requests on {} workers{}{}\n",
            self.scenario,
            self.seed,
            self.requests,
            self.workers,
            if self.quick { ", quick" } else { "" },
            if self.exact { ", exact" } else { "" },
        ));
        if self.tuned {
            s.push_str("  (model requests served from auto-tuned mapping plans)\n");
        }
        s.push_str(&format!(
            "  throughput: {:.1} req/s ({:.2} s wall)\n",
            m.throughput_rps, self.wall_s
        ));
        s.push_str(&format!(
            "  latency:    p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  max {:.2} ms\n",
            m.p50_us as f64 / 1e3,
            m.p95_us as f64 / 1e3,
            m.p99_us as f64 / 1e3,
            m.max_us as f64 / 1e3
        ));
        if m.decode_requests > 0 {
            s.push_str(&format!(
                "  phases:     {} prefill / {} decode requests\n",
                m.prefill_requests, m.decode_requests
            ));
            s.push_str(&format!(
                "    prefill:  p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms\n",
                m.prefill_p50_us as f64 / 1e3,
                m.prefill_p95_us as f64 / 1e3,
                m.prefill_p99_us as f64 / 1e3
            ));
            s.push_str(&format!(
                "    decode:   p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms\n",
                m.decode_p50_us as f64 / 1e3,
                m.decode_p95_us as f64 / 1e3,
                m.decode_p99_us as f64 / 1e3
            ));
            s.push_str(&format!(
                "  kv cache:   {} hits / {} misses / {} spills (peak {:.1} KiB/worker)\n",
                m.kv_hits,
                m.kv_misses,
                m.kv_spills,
                m.kv_bytes_peak as f64 / 1024.0
            ));
        }
        s.push_str(&format!(
            "  queue:      max depth {}, avg {:.1}; {} steals\n",
            m.queue_max_depth, m.queue_avg_depth, m.steals
        ));
        s.push_str(&format!(
            "  batching:   {} batches, {} requests coalesced\n",
            m.batches, m.coalesced
        ));
        s.push_str(&format!(
            "  affinity:   {:.0}% ({} hits / {} misses), {} datapath precision switch(es)\n",
            100.0 * m.affinity_rate(),
            m.affinity_hits,
            m.affinity_misses,
            m.precision_switches
        ));
        if m.tune_stalls + m.plan_hits > 0 {
            s.push_str(&format!(
                "  online tune: {} stall(s), {} plan-registry hit(s)\n",
                m.tune_stalls, m.plan_hits
            ));
        }
        s.push_str(&format!(
            "  programs:   {} compiled, cache {:.0}% hit ({} shared)\n",
            m.compiled_programs,
            100.0 * m.cache.hit_rate(),
            m.cache.shared_hits
        ));
        if m.breakdown.total() > 0 {
            s.push_str(&format!("  cycle split: {}\n", m.breakdown.summary_line()));
        }
        s.push_str(&format!(
            "  sim totals: {} cycles, {} MACs, {:.1} MiB traffic\n",
            self.total_cycles,
            self.total_macs,
            self.total_traffic_bytes as f64 / (1 << 20) as f64
        ));
        s.push_str(&format!("  stats digest: {:016x}\n", self.stats_digest));
        s
    }
}

/// Run a [`Scenario`] through a fresh [`ServePool`] on the reference
/// configuration and collect the report. The generated request stream and
/// every per-request statistic are deterministic in the scenario seed;
/// the throughput/latency numbers are measured host wall time.
///
/// With [`ServeBenchOptions::tuned`], every model entry of the mix is
/// first auto-tuned ([`crate::tune::tune_model`], one plan per distinct
/// `(model, precision)` workload) and model requests are served under
/// `Policy::Tuned` from the pool's registry. Tuning happens before the
/// measured window opens.
pub fn run_serve_bench(sc: &Scenario, opts: &ServeBenchOptions) -> Result<ServeBenchReport> {
    run_serve_bench_traced(sc, opts).map(|(report, _)| report)
}

/// [`run_serve_bench`] returning the worker span trace alongside the
/// report. The spans are empty unless [`ServeBenchOptions::obs`] enables
/// tracing; export them with [`crate::obs::chrome_trace_json`] (the
/// `repro profile --scenario` path).
pub fn run_serve_bench_traced(
    sc: &Scenario,
    opts: &ServeBenchOptions,
) -> Result<(ServeBenchReport, Vec<Span>)> {
    let cfg = SpeedConfig::reference();
    // Under --tuned, model mix entries are served at Policy::Tuned.
    let sc_tuned: Option<Scenario> = if opts.tuned {
        let mut s = sc.clone();
        for e in &mut s.mix {
            if matches!(e.workload, Workload::Model { .. } | Workload::Llm { .. }) {
                e.policy = crate::coordinator::Policy::Tuned;
            }
        }
        Some(s)
    } else {
        None
    };
    let sc = sc_tuned.as_ref().unwrap_or(sc);
    let reqs = sc.generate(opts.quick)?;
    let registry = crate::tune::TunedPlans::new();
    if opts.tuned {
        // One plan per distinct (model, precision, shape-variant) workload
        // in the generated stream: two downscale variants of one zoo model
        // are distinct workloads (their `OpDesc`s differ), so each must be
        // tuned — the registry merges them under the shared model name and
        // `choice_for` resolves per operator.
        let topts = crate::tune::TuneOptions {
            exec_mode: if opts.exact { ExecMode::Exact } else { ExecMode::Batch },
            ..Default::default()
        };
        let mut done: Vec<(String, u32, u64)> = Vec::new();
        for req in &reqs {
            if let RequestKind::Model { model, prec, .. } = &req.kind {
                let key = (
                    model.name.to_string(),
                    prec.bits(),
                    crate::tune::ops_digest(model.ops.iter()),
                );
                if done.contains(&key) {
                    continue;
                }
                registry.insert(crate::tune::tune_model(&cfg, model, *prec, &topts)?);
                done.push(key);
            }
        }
    }
    let defaults = ServeOptions::default();
    let pool = ServePool::new_tuned(
        cfg,
        ServeOptions {
            workers: opts.workers.max(1),
            capacity: sc.capacity.unwrap_or(defaults.capacity),
            max_batch: opts.max_batch.or(sc.max_batch).unwrap_or(defaults.max_batch),
            exec_mode: if opts.exact { ExecMode::Exact } else { ExecMode::Batch },
            obs: opts.obs,
            ..defaults
        },
        registry,
    )?;

    // Virtual-tick pacing: the arrival pattern decides where the
    // submitter yields the CPU, not any wall-clock sleep — runs are
    // reproducible and as fast as the machine allows.
    let mut rng = XorShift64::new(sc.seed ^ 0xA5A5_5A5A_C0FF_EE00);
    let requests = reqs.len();
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(requests);
    for (i, req) in reqs.into_iter().enumerate() {
        tickets.push(pool.submit(req)?);
        for _ in 0..sc.arrival.yields_after(i, &mut rng) {
            std::thread::yield_now();
        }
    }
    let mut results = Vec::with_capacity(requests);
    for t in tickets {
        results.push(t.wait()?);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let spans = pool.take_spans();
    let snapshot = pool.shutdown();

    let mut total_cycles = 0u64;
    let mut total_macs = 0u64;
    let mut total_traffic = 0u64;
    for r in &results {
        total_cycles += r.stats.cycles;
        total_macs += r.stats.macs;
        total_traffic += r.stats.traffic.total();
    }
    let report = ServeBenchReport {
        scenario: sc.name.clone(),
        seed: sc.seed,
        quick: opts.quick,
        exact: opts.exact,
        tuned: opts.tuned,
        workers: opts.workers.max(1),
        requests,
        total_cycles,
        total_macs,
        total_traffic_bytes: total_traffic,
        stats_digest: stats_digest(&results),
        wall_s,
        snapshot,
    };
    Ok((report, spans))
}

/// Order-sensitive FNV-64 digest over per-request statistics (results are
/// in request-id order). Two serve runs of the same scenario seed agree on
/// this digest exactly when their per-request `SimStats` agree.
pub fn stats_digest(results: &[RequestResult]) -> u64 {
    use std::hash::Hasher;
    let mut h = Fnv64::new();
    for r in results {
        let t = &r.stats.traffic;
        for v in [
            r.id,
            r.stats.cycles,
            r.stats.insns_total,
            r.stats.insns_custom,
            r.stats.insns_vector,
            r.stats.insns_scalar,
            r.stats.stall_fu_busy,
            r.stats.stall_hazard,
            r.stats.stall_mem_port,
            r.stats.macs,
            r.stats.mac_slots,
            r.stats.vregs_used as u64,
            r.stats.precision_switches,
            t.input_read,
            t.weight_read,
            t.partial_read,
            t.partial_write,
            t.output_write,
            r.layers as u64,
        ] {
            h.write(&v.to_le_bytes());
        }
        for b in r.stats.fu_busy {
            h.write(&b.to_le_bytes());
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::SpeedError;

    #[test]
    fn request_kind_precision_and_label() {
        let op = OpDesc::mm(4, 4, 4, Precision::Int4);
        let kind = RequestKind::Op { op, strat: StrategyKind::Mm };
        assert_eq!(kind.precision(), Precision::Int4);
        assert_eq!(kind.label(), "MM@INT4");
        let model = crate::models::zoo::model_by_name("mobilenetv2").unwrap();
        let kind = RequestKind::Model { model, prec: Precision::Int8, policy: Policy::Mixed };
        assert_eq!(kind.precision(), Precision::Int8);
        assert_eq!(kind.label(), "mobilenetv2@INT8");
    }

    #[test]
    fn request_builder_defaults_and_refinement() {
        let model = crate::models::zoo::model_by_name("llm_tiny").unwrap();
        let req = Request::model(model);
        assert_eq!(req.kind.precision(), Precision::Int8);
        assert_eq!(req.phase, Phase::Prefill);
        assert!(req.session.is_none());
        assert_eq!(req.kv_bytes, 0);
        let req = req
            .prec(Precision::Int4)
            .policy(Policy::Fixed(StrategyKind::Mm))
            .session(SessionId(3))
            .phase(Phase::Decode)
            .kv(4096);
        assert_eq!(req.kind.precision(), Precision::Int4);
        match &req.kind {
            RequestKind::Model { policy, .. } => {
                assert_eq!(*policy, Policy::Fixed(StrategyKind::Mm))
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(req.session, Some(SessionId(3)));
        assert_eq!((req.phase, req.kv_bytes), (Phase::Decode, 4096));
        assert_eq!(format!("{} {}", SessionId(3), req.phase), "s3 decode");

        // Op builder: precision re-types the operator; strategy applies.
        let op = OpDesc::mm(1, 64, 32, Precision::Int8);
        let req = Request::op(op).prec(Precision::Int16).strategy(StrategyKind::Mm);
        match &req.kind {
            RequestKind::Op { op, strat } => {
                assert_eq!(op.prec, Precision::Int16);
                assert_eq!(*strat, StrategyKind::Mm);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Cross-kind refinements are explicit no-ops.
        let req = req.policy(Policy::Mixed);
        assert!(matches!(req.kind, RequestKind::Op { .. }));
    }

    #[test]
    fn completion_roundtrip() {
        let c = Completion::default();
        c.fulfill(Err(SpeedError::Serve("gone".into())));
        // A second fulfill must not clobber the first outcome.
        c.fulfill(Err(SpeedError::Serve("later".into())));
        match c.wait() {
            Err(SpeedError::Serve(m)) => assert_eq!(m, "gone"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn digest_is_sensitive_to_stats() {
        let base = RequestResult {
            id: 0,
            stats: SimStats { cycles: 100, macs: 7, ..Default::default() },
            layers: 1,
            worker: 0,
            batch_size: 1,
            latency: Duration::from_micros(5),
            session: None,
            phase: Phase::Prefill,
        };
        let mut other = base.clone();
        other.stats.cycles = 101;
        let a = stats_digest(std::slice::from_ref(&base));
        let b = stats_digest(std::slice::from_ref(&other));
        assert_ne!(a, b);
        // Worker / batch placement and latency are schedule-dependent and
        // deliberately excluded.
        let mut placed = base.clone();
        placed.worker = 3;
        placed.batch_size = 8;
        placed.latency = Duration::from_micros(99);
        placed.session = Some(SessionId(1));
        placed.phase = Phase::Decode;
        assert_eq!(a, stats_digest(std::slice::from_ref(&placed)));
    }
}
