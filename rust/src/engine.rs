//! The execution engine: the compile-once / execute-many API of the crate.
//!
//! [`coordinator::run_model`](crate::coordinator::run_model) is a one-shot
//! convenience: it builds a fresh [`Processor`], re-lowers every operator
//! through the dataflow compiler, and re-derives every [`MemLayout`] on
//! each call. A serving deployment amortizes all of that across a network
//! and across requests — the whole premise of SPEED's single-cycle `VSACFG`
//! reconfiguration (Sec. II-E) is that the expensive state (compiled
//! operator programs, tensor placements, datapath precision) persists while
//! only the operands change. This module provides that surface:
//!
//! * [`Engine`] — owns a warm [`Processor`] plus a **program cache** keyed
//!   on `(operator, strategy, precision, configuration)`. A cache hit
//!   reuses the lowered instruction stream, the DRAM placement, and the
//!   sized operator plan; a miss pays compilation exactly once. Hit/miss
//!   counters are exposed via [`Engine::cache_stats`].
//! * [`Session`] — a run handle over an engine: executes whole models or
//!   single operators, returns per-layer and aggregate [`SimStats`], and
//!   tracks precision switches. Because the processor's control state is
//!   warm, the `VSACFG` in each program prologue performs (and the
//!   hardware counts) a precision *switch* only when the operand precision
//!   actually changes — consecutive same-precision layers, or a repeat run
//!   of a whole model, pay zero switches.
//!
//! Programs whose instruction streams are too large to keep resident
//! (above [`MATERIALIZE_LIMIT`]) cache their plan, layout, and sizing
//! summary, and re-stream generation on each execution — a hit still skips
//! the sizing pre-pass and all layout/validation work.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::analysis;
use crate::compiler::{self, CodegenSummary, MemLayout, MEM_MIN_BYTES};
use crate::config::{Precision, SpeedConfig};
use crate::coordinator::{LayerResult, ModelResult, Policy};
use crate::dataflow::MappingChoice;
use crate::error::{Result, SpeedError};
use crate::isa::{Segment, StrategyKind};
use crate::models::attn::AttnDesc;
use crate::models::zoo::Model;
use crate::models::{OpDesc, OpKind};
use crate::obs::{Counter, Counters, CycleBreakdown, ObsConfig, SpanCat, Tracer};
use crate::sim::{ExecMode, OpPlan, Processor, SimStats};
use crate::tune::TunedPlan;

/// Largest instruction count a cached program keeps resident. Streams above
/// this are regenerated on each execution (their plan/layout/summary are
/// still cached, so repeat executions skip the sizing pre-pass).
pub const MATERIALIZE_LIMIT: u64 = 1 << 20;

/// The configuration fields that shape generated code (tile geometry and
/// VRF capacity drive chunking; frequency and memory timing do not).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct CfgSig {
    lanes: u32,
    tile_r: u32,
    tile_c: u32,
    vrf_kib: u32,
}

impl CfgSig {
    fn of(cfg: &SpeedConfig) -> Self {
        CfgSig { lanes: cfg.lanes, tile_r: cfg.tile_r, tile_c: cfg.tile_c, vrf_kib: cfg.vrf_kib }
    }
}

/// Program-cache key: operator (which carries its precision), dataflow
/// strategy, chunk override (None = the analytic default), and the
/// code-shaping configuration signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ProgramKey {
    /// The operator (shape + precision).
    pub op: OpDesc,
    /// Dataflow strategy the program was compiled for.
    pub strat: StrategyKind,
    /// Auto-tuner chunk override ([`MappingChoice::chunk`]); distinct
    /// chunks compile distinct streams and must cache separately.
    pub chunk: Option<u32>,
    /// Auto-tuner MM B-tile column-block override
    /// ([`MappingChoice::jchunk`]) — same cache-separation rule.
    pub jchunk: Option<u32>,
    /// Carry-in mapping ([`MappingChoice::carry_in`]): a carried program
    /// elides its input loads, so it is a distinct stream from the
    /// reload-from-DRAM program and must cache separately.
    pub carry: bool,
    cfg: CfgSig,
}

/// A compiled operator program resident in an engine's cache.
#[derive(Debug)]
pub struct Program {
    plan: OpPlan,
    choice: MappingChoice,
    layout: MemLayout,
    required_bytes: u64,
    summary: CodegenSummary,
    /// `None` when the stream exceeds [`MATERIALIZE_LIMIT`].
    segments: Option<Vec<Segment>>,
}

impl Program {
    /// Codegen summary (instruction/stage counts) of the compiled stream.
    pub fn summary(&self) -> &CodegenSummary {
        &self.summary
    }

    /// The mapping choice (strategy + chunk override) this program was
    /// compiled under.
    pub fn choice(&self) -> MappingChoice {
        self.choice
    }

    /// External-memory placement the program was compiled against.
    pub fn layout(&self) -> &MemLayout {
        &self.layout
    }

    /// External-memory bytes the program's placement spans.
    pub fn required_bytes(&self) -> u64 {
        self.required_bytes
    }

    /// Whether the instruction stream is kept resident.
    pub fn is_materialized(&self) -> bool {
        self.segments.is_some()
    }
}

/// Program-cache hit/miss counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from cache (private or shared).
    pub hits: u64,
    /// Lookups that compiled a new program.
    pub misses: u64,
    /// Subset of `hits` that were satisfied by a [`SharedPrograms`] cache
    /// (another engine in the pool compiled the program first).
    pub shared_hits: u64,
}

impl CacheStats {
    /// Total cache lookups.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hits over lookups (0.0 when no lookups happened).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups() as f64
    }
}

/// A compiled-program cache shared by every engine of a pool: cloning is
/// cheap (one `Arc`), and a program any member compiles becomes a cache
/// hit for all of them. Engines consult their private map first (no lock
/// on the steady-state hot path) and fall back to the shared map before
/// compiling.
#[derive(Clone, Default)]
pub struct SharedPrograms {
    map: Arc<Mutex<HashMap<ProgramKey, Arc<Program>>>>,
}

impl SharedPrograms {
    /// An empty shared cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of distinct compiled programs in the shared cache.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Whether the shared cache holds no programs.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn get(&self, key: &ProgramKey) -> Option<Arc<Program>> {
        self.map.lock().unwrap_or_else(|e| e.into_inner()).get(key).cloned()
    }

    fn insert(&self, key: ProgramKey, prog: Arc<Program>) {
        self.map
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(key)
            .or_insert(prog);
    }
}

/// A warm SPEED instance plus its compiled-program cache.
pub struct Engine {
    cfg: SpeedConfig,
    proc: Processor,
    programs: HashMap<ProgramKey, Arc<Program>>,
    /// Pool-wide second-level cache (see [`SharedPrograms`]).
    shared: Option<SharedPrograms>,
    cache: CacheStats,
    /// Release-build opt-in for compile-time stream verification (debug
    /// builds always verify — see [`Engine::set_verify_on_compile`]).
    verify_on_compile: bool,
    /// Opt-in lint pass on cache miss (see
    /// [`Engine::set_lint_on_compile`]). Findings are warnings: they
    /// accumulate on the engine and never reject a program.
    lint_on_compile: bool,
    /// Lint findings accumulated since the last
    /// [`Engine::take_lint_findings`].
    lint_findings: Vec<analysis::lint::Finding>,
    /// Observability configuration last applied via [`Engine::set_obs`].
    obs: ObsConfig,
    /// Unified counter registry this engine feeds (own by default;
    /// pool-shared after [`Engine::set_counters`]).
    counters: Counters,
}

/// Short human-readable operator label for trace spans.
fn op_label(op: &OpDesc) -> String {
    match op.kind {
        OpKind::Mm => format!("MM {}x{}x{} {}", op.m, op.k, op.n, op.prec),
        _ => format!(
            "{} c{} f{} {}x{} k{} {}",
            op.kind, op.c, op.f, op.h, op.w, op.ksize, op.prec
        ),
    }
}

impl Engine {
    /// Build an engine from a validated configuration with the default
    /// external-memory floor (memory grows lazily as operators demand).
    pub fn new(cfg: SpeedConfig) -> Result<Self> {
        Self::with_memory(cfg, MEM_MIN_BYTES as usize)
    }

    /// Build an engine with at least `mem_bytes` of external memory.
    pub fn with_memory(cfg: SpeedConfig, mem_bytes: usize) -> Result<Self> {
        cfg.validate()?;
        let mem = mem_bytes.max(MEM_MIN_BYTES as usize);
        Ok(Engine {
            cfg,
            proc: Processor::new(cfg, mem),
            programs: HashMap::new(),
            shared: None,
            cache: CacheStats::default(),
            verify_on_compile: false,
            lint_on_compile: false,
            lint_findings: Vec::new(),
            obs: ObsConfig::off(),
            counters: Counters::new(),
        })
    }

    /// Build a pool-member engine: compilation results are exchanged with
    /// every other engine attached to the same [`SharedPrograms`], so the
    /// pool compiles each distinct `(op, strategy, precision, config)`
    /// program once rather than once per worker.
    pub fn with_shared(
        cfg: SpeedConfig,
        mem_bytes: usize,
        shared: SharedPrograms,
    ) -> Result<Self> {
        let mut engine = Self::with_memory(cfg, mem_bytes)?;
        engine.shared = Some(shared);
        Ok(engine)
    }

    /// The processor configuration this engine was built with.
    pub fn config(&self) -> &SpeedConfig {
        &self.cfg
    }

    /// The warm processor (its clock, control state, and memory persist
    /// across every program this engine runs).
    pub fn processor(&self) -> &Processor {
        &self.proc
    }

    /// Program-cache hit/miss counters so far.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache
    }

    /// Apply an observability configuration: attaches a fresh tracer on
    /// timeline 0 (or detaches it when tracing is off). Attaching or
    /// detaching a tracer never changes [`SimStats`] — the inertness
    /// invariant enforced by `tests/obs_inertness.rs`. Pool workers attach
    /// a pre-built per-worker tracer via [`Engine::set_tracer`] instead.
    pub fn set_obs(&mut self, obs: ObsConfig) {
        self.obs = obs;
        self.proc.attach_tracer(Tracer::from_config(&obs, 0));
    }

    /// The observability configuration last applied.
    pub fn obs(&self) -> ObsConfig {
        self.obs
    }

    /// Attach a pre-built tracer (pools share one ring per worker
    /// timeline), or detach tracing with `None`.
    pub fn set_tracer(&mut self, tracer: Option<Tracer>) {
        self.proc.attach_tracer(tracer);
    }

    /// The attached tracer, when tracing is on.
    pub fn tracer(&self) -> Option<&Tracer> {
        self.proc.tracer()
    }

    /// Replace the counter registry (pools inject one shared registry
    /// into every worker engine; see [`Counters`]).
    pub fn set_counters(&mut self, counters: Counters) {
        self.counters = counters;
    }

    /// The unified counter registry this engine feeds.
    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Lifetime cycle attribution of the warm processor. The component
    /// sum equals the processor's lifetime cycle count exactly; diff
    /// snapshots with [`CycleBreakdown::since`] for per-op attribution.
    pub fn breakdown(&self) -> CycleBreakdown {
        self.proc.breakdown()
    }

    /// Number of distinct compiled programs resident in the cache.
    pub fn compiled_programs(&self) -> usize {
        self.programs.len()
    }

    /// Lifetime count of actual datapath precision switches (a `VSACFG`
    /// naming the already-active precision does not count — Sec. II-E).
    pub fn precision_switches(&self) -> u64 {
        self.proc.ctrl.precision_switches
    }

    /// Select batch (default) vs exact per-instruction simulation. Batch
    /// mode consumes the compiler's stream-run metadata and is bit-exact
    /// against [`ExecMode::Exact`] — the exact mode exists as the
    /// `--exact` escape hatch and as the parity oracle in tests.
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        self.proc.set_exec_mode(mode);
    }

    /// The active simulation mode.
    pub fn exec_mode(&self) -> ExecMode {
        self.proc.exec_mode()
    }

    /// Opt a release build into static stream verification on every
    /// program-cache miss (see [`crate::analysis`]). Debug builds always
    /// verify regardless of this flag; a failing program is rejected with
    /// [`SpeedError::Verify`] and never enters the cache.
    pub fn set_verify_on_compile(&mut self, on: bool) {
        self.verify_on_compile = on;
    }

    /// Whether this engine verifies compiled streams on cache miss
    /// (always true in debug builds).
    pub fn verify_on_compile(&self) -> bool {
        cfg!(debug_assertions) || self.verify_on_compile
    }

    /// Opt into the performance lint pass ([`crate::analysis::lint`]) on
    /// every program-cache miss. Unlike verification, lint findings are
    /// *warnings*: they accumulate on the engine — drain them with
    /// [`Engine::take_lint_findings`] — and never reject a program.
    pub fn set_lint_on_compile(&mut self, on: bool) {
        self.lint_on_compile = on;
    }

    /// Whether this engine lints compiled streams on cache miss.
    pub fn lint_on_compile(&self) -> bool {
        self.lint_on_compile
    }

    /// Drain the lint findings accumulated by compile-time linting.
    pub fn take_lint_findings(&mut self) -> Vec<analysis::lint::Finding> {
        std::mem::take(&mut self.lint_findings)
    }

    /// Drain the warm processor's pipeline back to its fresh-construction
    /// timing state (see [`Processor::reset_pipeline`]). The program
    /// cache, external memory, and datapath control state all persist —
    /// after a quiesce, a cached program replays with exactly the
    /// [`SimStats`] it would report on a brand-new engine. The serving
    /// layer quiesces at request boundaries so per-request statistics do
    /// not depend on what the worker ran before.
    pub fn quiesce(&mut self) {
        self.proc.reset_pipeline();
    }

    /// Open a run handle. Sessions borrow the engine mutably; state
    /// (cache, clock, precision) persists across sessions.
    pub fn session(&mut self) -> Session<'_> {
        let switch_base = self.precision_switches();
        Session {
            engine: self,
            policy: Policy::Mixed,
            tuned: None,
            functional: false,
            total: SimStats::default(),
            switch_base,
        }
    }

    /// Preload packed operand values into external memory at `addr`
    /// (uncounted test-bench/golden-path initialization; memory grows to
    /// fit). Use a program's [`Program::layout`] for the addresses.
    pub fn preload_packed(&mut self, addr: u64, values: &[i32], prec: Precision) {
        let end = addr + prec.bytes_for(values.len() as u64);
        self.proc.grow_memory(end as usize);
        self.proc.mem.preload_packed(addr, values, prec);
    }

    /// Inspect `n` i32 accumulators at `addr` (uncounted readback of a
    /// functional run's output region).
    pub fn inspect_i32(&self, addr: u64, n: usize) -> Vec<i32> {
        self.proc.mem.inspect_i32(addr, n)
    }

    /// Fetch the compiled program for `(op, strat)` at the default chunk,
    /// compiling on miss.
    pub fn program(&mut self, op: &OpDesc, strat: StrategyKind) -> Result<Arc<Program>> {
        self.program_with(op, MappingChoice::of(strat))
    }

    /// Fetch the compiled program for an explicit mapping choice
    /// (strategy + optional chunk override), compiling on miss. Distinct
    /// chunks are distinct cache entries — a tuned plan and the static
    /// mapping never collide.
    pub fn program_with(&mut self, op: &OpDesc, choice: MappingChoice) -> Result<Arc<Program>> {
        let key = ProgramKey {
            op: *op,
            strat: choice.strat,
            chunk: choice.chunk,
            jchunk: choice.jchunk,
            carry: choice.carry_in,
            cfg: CfgSig::of(&self.cfg),
        };
        if let Some(p) = self.programs.get(&key) {
            self.cache.hits += 1;
            self.counters.incr(Counter::EngineCacheHits);
            return Ok(p.clone());
        }
        if let Some(shared) = &self.shared {
            if let Some(p) = shared.get(&key) {
                self.cache.hits += 1;
                self.cache.shared_hits += 1;
                self.counters.incr(Counter::EngineCacheHits);
                self.counters.incr(Counter::EngineCacheSharedHits);
                self.programs.insert(key, p.clone());
                return Ok(p);
            }
        }
        self.cache.misses += 1;
        self.counters.incr(Counter::EngineCacheMisses);
        let (layout, required_bytes) = MemLayout::place(op);
        // Sizing pass first: `Sink::Collect` would materialize the *whole*
        // stream, so the only memory-safe way to decide materialization is
        // to count before collecting. Small programs therefore generate
        // twice on their one-and-only miss; every hit replays for free.
        let summary = compiler::summarize_op_with(op, &self.cfg, choice, &layout)?;
        let segments = if summary.total_insns <= MATERIALIZE_LIMIT {
            Some(compiler::compile_op_with(op, &self.cfg, choice, layout, false)?.segments)
        } else {
            None
        };
        // Static verification before the program can enter the cache: a
        // stream that would misconfigure the datapath or touch memory
        // outside its layout is a typed error here, not a simulator fault
        // three layers later. Streamed (non-materialized) programs skip
        // this — `repro verify` covers them via the streaming verifier.
        if self.verify_on_compile() {
            if let Some(segs) = &segments {
                let report = analysis::verify_segments(op, &self.cfg, choice, layout, segs);
                self.counters.incr(Counter::VerifyPrograms);
                self.counters.add(
                    Counter::VerifyRuleEvals,
                    report.insns * analysis::Rule::ALL.len() as u64,
                );
                report.into_result()?;
            }
        }
        // The opt-in lint pass piggybacks on the same materialized
        // segments. Findings are performance advice, never errors: they
        // accumulate for `take_lint_findings` and the program caches
        // regardless.
        if self.lint_on_compile {
            if let Some(segs) = &segments {
                let report = analysis::lint::lint_segments(&self.cfg, segs);
                self.lint_findings.extend(report.findings);
            }
        }
        let plan = OpPlan {
            desc: *op,
            strat: choice.strat,
            in_addr: layout.in_addr,
            w_addr: layout.w_addr,
            out_addr: layout.out_addr,
            partial_addr: layout.partial_addr,
            total_stages: summary.total_stages.max(1),
            functional: false,
        };
        let prog = Arc::new(Program {
            plan,
            choice,
            layout,
            required_bytes,
            summary,
            segments,
        });
        self.programs.insert(key, prog.clone());
        if let Some(shared) = &self.shared {
            shared.insert(key, prog.clone());
        }
        Ok(prog)
    }

    /// Execute one operator program on the warm processor. Returns the
    /// run's stats plus the (cached) program that produced them.
    pub fn run_op(
        &mut self,
        op: &OpDesc,
        strat: StrategyKind,
        functional: bool,
    ) -> Result<(SimStats, Arc<Program>)> {
        self.run_op_with(op, MappingChoice::of(strat), functional)
    }

    /// [`Engine::run_op`] with an explicit mapping choice — the execution
    /// entry point for tuned plans.
    pub fn run_op_with(
        &mut self,
        op: &OpDesc,
        choice: MappingChoice,
        functional: bool,
    ) -> Result<(SimStats, Arc<Program>)> {
        let prog = self.program_with(op, choice)?;
        self.proc.grow_memory(prog.required_bytes as usize);
        let mut plan = prog.plan;
        plan.functional = functional;
        self.proc.set_plan(plan);
        // Span begin times come from the tracer's virtual clock *before*
        // each unit runs; durations are that unit's simulated cycles. The
        // clock itself advances only inside the simulator, so op-span
        // durations sum to exactly the run's `SimStats::cycles`.
        let op_begin = self.proc.tracer().map(|t| t.now());
        let mut stats = SimStats::default();
        match &prog.segments {
            Some(segs) => {
                for (i, seg) in segs.iter().enumerate() {
                    let seg_begin = self.proc.tracer().map(|t| t.now());
                    let seg_stats = self.proc.run_segment(seg)?;
                    if let (Some(begin), Some(t)) = (seg_begin, self.proc.tracer()) {
                        t.record(SpanCat::Segment, format!("segment {i}"), begin, seg_stats.cycles);
                    }
                    stats.merge(&seg_stats);
                }
            }
            None => {
                let cfg = self.cfg;
                let proc = &mut self.proc;
                let mut seg_idx = 0usize;
                let mut feed = |seg: Segment| -> Result<(), SpeedError> {
                    let seg_begin = proc.tracer().map(|t| t.now());
                    let seg_stats = proc.run_segment(&seg)?;
                    if let (Some(begin), Some(t)) = (seg_begin, proc.tracer()) {
                        let name = format!("segment {seg_idx} (streamed)");
                        t.record(SpanCat::Segment, name, begin, seg_stats.cycles);
                    }
                    seg_idx += 1;
                    stats.merge(&seg_stats);
                    Ok(())
                };
                compiler::stream_op_with(op, &cfg, choice, &prog.layout, &mut feed)?;
            }
        }
        if let (Some(begin), Some(t)) = (op_begin, self.proc.tracer()) {
            t.record(SpanCat::Op, op_label(op), begin, stats.cycles);
        }
        Ok((stats, prog))
    }
}

/// A run handle over an [`Engine`]: executes models/operators and
/// aggregates their statistics.
pub struct Session<'e> {
    engine: &'e mut Engine,
    policy: Policy,
    /// Tuned per-operator mapping consulted under [`Policy::Tuned`].
    tuned: Option<Arc<TunedPlan>>,
    functional: bool,
    total: SimStats,
    switch_base: u64,
}

impl<'e> Session<'e> {
    /// Strategy-selection policy for [`Session::run_model`] (default:
    /// the paper's mixed dataflow).
    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Attach a tuned per-operator mapping and select [`Policy::Tuned`].
    /// Operators without a tuned entry fall back to the static mixed
    /// mapping, so a partial plan (e.g. tuned on a downscaled variant) is
    /// safe.
    pub fn with_tuned_plan(mut self, plan: Arc<TunedPlan>) -> Self {
        self.tuned = Some(plan);
        self.policy = Policy::Tuned;
        self
    }

    /// Enable functional simulation (real numerics, golden-checkable) in
    /// addition to timing/traffic.
    pub fn with_functional(mut self, on: bool) -> Self {
        self.functional = on;
        self
    }

    /// The strategy-selection policy this session runs under.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Execute a single operator under an explicit strategy.
    pub fn run_op(&mut self, op: &OpDesc, strat: StrategyKind) -> Result<LayerResult> {
        let (stats, _) = self.engine.run_op(op, strat, self.functional)?;
        self.total.merge(&stats);
        Ok(LayerResult { op: *op, strat, stats })
    }

    /// The mapping choice this session's policy assigns to `op` (None =
    /// not applicable under a fixed-strategy ablation policy).
    fn choice_for(&self, op: &OpDesc) -> Option<MappingChoice> {
        if matches!(self.policy, Policy::Tuned | Policy::TunedOnline) {
            if let Some(plan) = &self.tuned {
                if let Some(choice) = plan.choice_for(op) {
                    return Some(choice);
                }
            }
            // No plan attached / no tuned entry: static mixed fallback.
            return Some(MappingChoice::preferred(op));
        }
        // Fixed-strategy ablations skip operators outside the strategy's
        // applicability matrix (an `--policy ff` sweep skips MMs, not
        // more). FF on a huge-F CONV is *feasible*: the compiler emits
        // its per-row weight refetch runs and the sweep costs the spill
        // honestly instead of skipping or rejecting the shape.
        self.policy
            .strategy_for(op)
            .filter(|s| crate::dataflow::feasible(*s, op, &self.engine.cfg))
            .map(MappingChoice::of)
    }

    /// Execute a whole model at a precision; the engine's program cache
    /// makes repeat runs compile nothing, and the warm datapath makes the
    /// per-layer `VSACFG` switch precision only when it actually changes.
    pub fn run_model(&mut self, model: &Model, prec: Precision) -> Result<ModelResult> {
        let m = model.at_precision(prec);
        let mut layers = Vec::with_capacity(m.ops.len());
        let mut total = SimStats::default();
        for (i, op) in m.ops.iter().enumerate() {
            let Some(mut choice) = self.choice_for(op) else {
                continue;
            };
            // Model-level chain: a tuned plan may mark layer i as carrying
            // its input from layer i-1's VRF-resident output. The chain is
            // positional, so it only applies when it covers this exact
            // layer sequence, and the residency precondition is rechecked
            // against the actual adjacent operators — a plan tuned on a
            // different shape variant can never smuggle in an unsound
            // carry (it just reloads, which is always safe).
            if i > 0 && matches!(self.policy, Policy::Tuned | Policy::TunedOnline) {
                if let Some(plan) = &self.tuned {
                    if plan.chain.len() == m.ops.len()
                        && plan.chain[i]
                        && crate::dataflow::carries_residency(&m.ops[i - 1], op, &self.engine.cfg)
                    {
                        choice.carry_in = true;
                    }
                }
            }
            let (stats, _) = self.engine.run_op_with(op, choice, self.functional)?;
            self.total.merge(&stats);
            total.merge(&stats);
            layers.push(LayerResult { op: *op, strat: choice.strat, stats });
        }
        let scalar_cycles = (total.cycles as f64 * m.scalar_fraction) as u64;
        Ok(ModelResult { name: m.name.to_string(), prec, layers, total, scalar_cycles })
    }

    /// Execute one attention layer as its MM composition
    /// ([`AttnDesc::lower`]): per FlashAttention-style KV tile, a `QK^T`
    /// score MM and an `AV` weighted-value MM, mapped under the session's
    /// policy like any other workload (the softmax-scale epilogue between
    /// them is scalar-core work outside the vector datapath). The engine's
    /// program cache makes repeated decode steps at the same cache length
    /// compile nothing.
    pub fn run_attn(&mut self, desc: &AttnDesc) -> Result<ModelResult> {
        desc.validate()?;
        let cfg = *self.engine.config();
        self.run_model(&desc.to_model(&cfg), desc.prec)
    }

    /// Aggregate stats over everything this session has run.
    pub fn stats(&self) -> &SimStats {
        &self.total
    }

    /// Datapath precision switches performed since this session opened.
    pub fn precision_switches(&self) -> u64 {
        self.engine.precision_switches() - self.switch_base
    }

    /// The underlying engine this session borrows.
    pub fn engine(&self) -> &Engine {
        self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator;
    use crate::models::zoo::Model;

    fn tiny_model() -> Model {
        Model {
            name: "tiny",
            ops: vec![
                OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8),
                OpDesc::pwcv(8, 8, 10, 10, Precision::Int8),
                OpDesc::dwcv(8, 10, 10, 3, 1, 1, Precision::Int8),
                OpDesc::mm(10, 8, 12, Precision::Int8),
            ],
            scalar_fraction: 0.1,
        }
    }

    #[test]
    fn second_pass_compiles_zero_new_programs() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let model = tiny_model();
        let mut session = engine.session();
        let first = session.run_model(&model, Precision::Int8).unwrap();
        drop(session);
        let after_first = engine.cache_stats();
        assert_eq!(after_first.misses, 4, "each layer compiles once");
        assert_eq!(engine.compiled_programs(), 4);

        let mut session = engine.session();
        let second = session.run_model(&model, Precision::Int8).unwrap();
        drop(session);
        let after_second = engine.cache_stats();
        // The acceptance bar: zero recompilations on the second pass.
        assert_eq!(after_second.misses, after_first.misses);
        assert_eq!(after_second.hits, after_first.hits + 4);
        assert_eq!(engine.compiled_programs(), 4);
        // Cached programs replay the identical stream: identical work.
        assert_eq!(first.total.macs, second.total.macs);
        assert_eq!(first.total.insns_total, second.total.insns_total);
        assert_eq!(first.total.traffic, second.total.traffic);
    }

    #[test]
    fn precision_switch_only_when_it_changes() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let model = tiny_model();
        // The datapath resets to INT8; an all-INT16 model switches once
        // (first layer), then every later VSACFG names the active precision.
        let mut session = engine.session();
        session.run_model(&model, Precision::Int16).unwrap();
        assert_eq!(session.precision_switches(), 1);
        // Second pass at the same precision: the datapath is already there.
        session.run_model(&model, Precision::Int16).unwrap();
        assert_eq!(session.precision_switches(), 1);
        drop(session);
        // Changing precision costs exactly one switch per transition.
        let mut session = engine.session();
        session.run_model(&model, Precision::Int4).unwrap();
        session.run_model(&model, Precision::Int16).unwrap();
        session.run_model(&model, Precision::Int16).unwrap();
        assert_eq!(session.precision_switches(), 2);
    }

    #[test]
    fn distinct_precisions_cache_distinct_programs() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let model = tiny_model();
        let mut session = engine.session();
        session.run_model(&model, Precision::Int16).unwrap();
        session.run_model(&model, Precision::Int8).unwrap();
        session.run_model(&model, Precision::Int4).unwrap();
        drop(session);
        assert_eq!(engine.compiled_programs(), 12, "4 ops x 3 precisions");
        assert_eq!(engine.cache_stats().misses, 12);
    }

    #[test]
    fn session_matches_one_shot_run_model() {
        // The Engine path must reproduce the legacy one-shot numbers: same
        // streams, same warm-processor composition, same cycles.
        let model = tiny_model();
        let cfg = SpeedConfig::reference();
        let legacy =
            coordinator::run_model(&model, Precision::Int8, &cfg, Policy::Mixed).unwrap();
        let mut engine = Engine::new(cfg).unwrap();
        let result = engine.session().run_model(&model, Precision::Int8).unwrap();
        assert_eq!(result.total.cycles, legacy.total.cycles);
        assert_eq!(result.total.macs, legacy.total.macs);
        assert_eq!(result.total.traffic, legacy.total.traffic);
        assert_eq!(result.layers.len(), legacy.layers.len());
        assert_eq!(result.scalar_cycles, legacy.scalar_cycles);
    }

    #[test]
    fn run_op_grows_memory_on_demand() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        // Needs far more than the 1 MiB floor.
        let op = OpDesc::conv(64, 64, 64, 64, 3, 1, 1, Precision::Int8);
        assert!(MemLayout::required_bytes(&op) > MEM_MIN_BYTES);
        let layer = engine.session().run_op(&op, StrategyKind::Ffcs).unwrap();
        assert_eq!(layer.stats.macs, op.total_macs());
        assert!(engine.processor().mem.size() as u64 >= MemLayout::required_bytes(&op));
    }

    #[test]
    fn session_aggregates_across_runs() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let model = tiny_model();
        let mut session = engine.session();
        let a = session.run_model(&model, Precision::Int8).unwrap();
        let b = session.run_model(&model, Precision::Int4).unwrap();
        assert_eq!(session.stats().macs, a.total.macs + b.total.macs);
        assert_eq!(session.stats().cycles, a.total.cycles + b.total.cycles);
    }

    #[test]
    fn invalid_config_is_rejected_at_engine_construction() {
        let bad = SpeedConfig { lanes: 3, ..SpeedConfig::reference() };
        let err = Engine::new(bad).map(|_| ()).unwrap_err();
        assert!(matches!(err, SpeedError::Config(_)), "{err}");
    }

    #[test]
    fn quiesce_reproduces_fresh_engine_stats() {
        // After arbitrary prior work plus a quiesce, a model run reports
        // per-run stats bit-identical to a brand-new engine's first run —
        // the serving layer's per-request determinism contract.
        let model = tiny_model();
        let mut fresh = Engine::new(SpeedConfig::reference()).unwrap();
        let baseline = fresh.session().run_model(&model, Precision::Int8).unwrap();

        let mut warm = Engine::new(SpeedConfig::reference()).unwrap();
        let mut session = warm.session();
        session.run_model(&model, Precision::Int16).unwrap();
        session.run_model(&model, Precision::Int8).unwrap();
        drop(session);
        warm.quiesce();
        let replay = warm.session().run_model(&model, Precision::Int8).unwrap();
        assert_eq!(baseline.total, replay.total);
        for (a, b) in baseline.layers.iter().zip(&replay.layers) {
            assert_eq!(a.stats, b.stats);
        }
    }

    #[test]
    fn shared_programs_compile_once_across_engines() {
        let shared = SharedPrograms::new();
        let cfg = SpeedConfig::reference();
        let model = tiny_model();
        let mut a = Engine::with_shared(cfg, 0, shared.clone()).unwrap();
        a.session().run_model(&model, Precision::Int8).unwrap();
        assert_eq!(a.cache_stats().misses, 4);
        assert_eq!(shared.len(), 4);

        // A second pool member finds every program already compiled.
        let mut b = Engine::with_shared(cfg, 0, shared.clone()).unwrap();
        b.session().run_model(&model, Precision::Int8).unwrap();
        let stats = b.cache_stats();
        assert_eq!(stats.misses, 0);
        assert_eq!(stats.hits, 4);
        assert_eq!(stats.shared_hits, 4);
        // ...and its private map now holds them: a repeat pass hits
        // without touching the shared lock's counters again.
        b.session().run_model(&model, Precision::Int8).unwrap();
        assert_eq!(b.cache_stats().shared_hits, 4);
        assert_eq!(b.cache_stats().hits, 8);
        assert!(!shared.is_empty());
    }

    #[test]
    fn verify_on_compile_accepts_codegen_output() {
        // With verification forced on (it is already on in debug builds),
        // every compiler-emitted program must pass the static verifier and
        // cache exactly as before — soundness of the verifier against its
        // own codegen is the no-false-positive contract.
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        engine.set_verify_on_compile(true);
        assert!(engine.verify_on_compile());
        let model = tiny_model();
        engine.session().run_model(&model, Precision::Int8).unwrap();
        assert_eq!(engine.cache_stats().misses, 4);
        assert_eq!(engine.compiled_programs(), 4);
    }

    #[test]
    fn lint_on_compile_is_clean_on_codegen_and_drains() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        assert!(!engine.lint_on_compile());
        engine.set_lint_on_compile(true);
        assert!(engine.lint_on_compile());
        engine.session().run_model(&tiny_model(), Precision::Int8).unwrap();
        // The compiler's own output must lint clean (the no-false-positive
        // contract lint shares with the verifier), and draining resets.
        assert!(engine.take_lint_findings().is_empty());
        assert!(engine.take_lint_findings().is_empty());
    }

    #[test]
    fn fixed_policy_session_skips_inapplicable_layers() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let model = tiny_model();
        let r = engine
            .session()
            .with_policy(Policy::Fixed(StrategyKind::Cf))
            .run_model(&model, Precision::Int8)
            .unwrap();
        // CF applies to CONV and PWCV only.
        assert_eq!(r.layers.len(), 2);
    }

    #[test]
    fn op_spans_sum_to_session_cycles_and_counters_track_cache() {
        use crate::obs::TraceLevel;
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        engine.set_obs(ObsConfig::tracing(TraceLevel::Segment));
        let model = tiny_model();
        let r = engine.session().run_model(&model, Precision::Int8).unwrap();
        let spans = engine.tracer().unwrap().take_spans();
        let op_sum: u64 =
            spans.iter().filter(|s| s.cat == SpanCat::Op).map(|s| s.dur).sum();
        assert_eq!(op_sum, r.total.cycles, "op spans partition the run");
        let seg_sum: u64 =
            spans.iter().filter(|s| s.cat == SpanCat::Segment).map(|s| s.dur).sum();
        assert_eq!(seg_sum, r.total.cycles, "segments partition it too");
        let c = engine.counters();
        assert_eq!(c.get(Counter::EngineCacheMisses), 4);
        assert_eq!(c.get(Counter::EngineCacheHits), engine.cache_stats().hits);
        if engine.verify_on_compile() {
            assert_eq!(c.get(Counter::VerifyPrograms), 4);
            assert!(c.get(Counter::VerifyRuleEvals) > 0);
        }
        // Detaching restores the zero-overhead path.
        engine.set_obs(ObsConfig::off());
        assert!(engine.tracer().is_none());
    }

    #[test]
    fn carry_programs_cache_separately() {
        // A carried program elides its input loads — a different stream —
        // so the program cache must never hand the reload program back
        // for a carry request (or the chain measurement would be a no-op).
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let op = OpDesc::mm(1, 128, 256, Precision::Int8);
        let base = MappingChoice::of(StrategyKind::Mm);
        let carry = MappingChoice { carry_in: true, ..base };
        let p1 = engine.program_with(&op, base).unwrap();
        let p2 = engine.program_with(&op, carry).unwrap();
        assert_eq!(engine.cache_stats().misses, 2, "carry compiles its own program");
        assert!(
            p2.summary().total_insns < p1.summary().total_insns,
            "carried stream elides the input loads"
        );
        // Both now hit.
        engine.program_with(&op, base).unwrap();
        engine.program_with(&op, carry).unwrap();
        assert_eq!(engine.cache_stats().misses, 2);
        assert_eq!(engine.cache_stats().hits, 2);
    }

    #[test]
    fn tuned_session_applies_the_chain_and_never_regresses() {
        use crate::tune::{self, TuneOptions};
        // Two skinny MMs whose output feeds the next layer's K axis: the
        // model-level chain pass must carry the second layer, and a
        // session running the chained plan must beat (never trail) the
        // same plan with its chain stripped, at identical MAC counts.
        let cfg = SpeedConfig::reference();
        let model = Model {
            name: "chain2",
            ops: vec![
                OpDesc::mm(1, 128, 256, Precision::Int8),
                OpDesc::mm(1, 256, 128, Precision::Int8),
            ],
            scalar_fraction: 0.0,
        };
        let prec = Precision::Int8;
        let plan = tune::tune_model(&cfg, &model, prec, &TuneOptions::default()).unwrap();
        assert!(plan.chain.iter().any(|&b| b), "decode-shaped MMs must chain");
        let mut unchained = plan.clone();
        unchained.chain.clear();

        let mut chained_engine = Engine::new(cfg).unwrap();
        let chained_run = chained_engine
            .session()
            .with_tuned_plan(Arc::new(plan))
            .run_model(&model, prec)
            .unwrap();
        let mut reload_engine = Engine::new(cfg).unwrap();
        let reload_run = reload_engine
            .session()
            .with_tuned_plan(Arc::new(unchained))
            .run_model(&model, prec)
            .unwrap();
        assert_eq!(chained_run.total.macs, reload_run.total.macs);
        assert!(
            chained_run.total.cycles <= reload_run.total.cycles,
            "chained {} > per-op {}",
            chained_run.total.cycles,
            reload_run.total.cycles
        );
        assert!(
            chained_run.total.traffic.total() < reload_run.total.traffic.total(),
            "the carried layer must elide its input reload traffic"
        );
    }

    #[test]
    fn attention_runs_as_tiled_mm_composition() {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let desc = AttnDesc::decode(4, 32, 96, Precision::Int8);
        let mut session = engine.session();
        let res = session.run_attn(&desc).unwrap();
        drop(session);
        // QK^T and AV per KV tile: an even number of MM layers covering
        // the layer's full MAC count (tile padding can only add work).
        assert!(res.layers.len() >= 2 && res.layers.len() % 2 == 0);
        assert!(res.total.macs >= desc.total_macs());
        // The same decode shape replays entirely from the program cache.
        let misses = engine.cache_stats().misses;
        engine.session().run_attn(&desc).unwrap();
        assert_eq!(engine.cache_stats().misses, misses);
        // Malformed descriptors fail typed before touching the datapath.
        let bad = AttnDesc { head_dim: 0, ..desc };
        assert!(engine.session().run_attn(&bad).is_err());
    }
}
