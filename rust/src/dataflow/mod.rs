//! The mixed dataflow mapping method of Sec. III.
//!
//! Four strategies, each matched to an operator's compute/storage profile:
//!
//! * **MM** — matrix multiplication: weights multi-broadcast across lanes,
//!   inputs reused across processing stages, PP packs the reduction dim.
//! * **FFCS** (Feature-map-First-Channel-Second) — CONV: weights stay
//!   stationary for N feature-map stages (OP1), then the walk steps along
//!   the input-channel dimension (OP2); partial sums live in the VRF.
//! * **CF** (Channel-First) — PWCV: the input-channel dimension is
//!   traversed first so partial sums accumulate *inside the PE*, removing
//!   the MPTU↔VRF partial traffic — at the cost of re-fetching weights per
//!   feature-map tile when they exceed the VRF.
//! * **FF** (Feature-map-First) — DWCV: channels are decoupled, inputs are
//!   streamed exactly once, weights are tiny and resident.
//!
//! This module provides the *geometry* of each mapping — chunk sizes that
//! respect the VRF budget, stage counts, and the applicability rules — and
//! [`crate::compiler`] turns a mapping into the concrete instruction stream
//! whose simulation yields the cycles and DRAM traffic of Figs. 10–12.

use crate::config::{Precision, SpeedConfig};
use crate::isa::StrategyKind;
use crate::models::ops::{OpDesc, OpKind};

/// One point of the per-operator mapping space the auto-tuner searches:
/// a dataflow strategy plus optional chunk-size overrides.
///
/// `chunk: None` means the analytically-derived maximum that fits the VRF
/// ([`default_chunk`]) — the value the static mapping has always used. An
/// explicit chunk is clamped into the valid range by [`resolve_chunk`]
/// before code generation, so every choice compiles to a stream with the
/// same stage count and bit-identical outputs; only the load/store
/// structure (and therefore cycles and traffic) changes.
///
/// `jchunk` widens the search along MM's *other* tiled dimension: the
/// B-tile column block. `None` keeps the static structure (one broadcast
/// B load per `TILE_C`-wide column tile, or the whole K-chunk of B when it
/// fits a vreg region); `Some(jc)` loads `jc` columns' worth of B per
/// broadcast ([`resolve_jchunk`] clamps to a `TILE_C` multiple the vreg
/// region fits). Conv strategies ignore it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MappingChoice {
    /// The dataflow strategy.
    pub strat: StrategyKind,
    /// Chunk-size override (None = the analytic default).
    pub chunk: Option<u32>,
    /// MM-only B-tile column-block (J-dim) override.
    pub jchunk: Option<u32>,
    /// Model-level tuning: this operator's input is already VRF-resident
    /// (the previous layer's output), so code generation elides the input
    /// load runs. Only legal where [`carries_residency`] holds for the
    /// producing/consuming layer pair; [`crate::compiler`] rejects a carry
    /// on an operator whose input could not fit the input partition.
    pub carry_in: bool,
}

impl MappingChoice {
    /// The strategy with its default (maximal) chunk.
    pub fn of(strat: StrategyKind) -> Self {
        MappingChoice { strat, chunk: None, jchunk: None, carry_in: false }
    }

    /// The static mixed-dataflow choice for `op` (Sec. III table).
    pub fn preferred(op: &OpDesc) -> Self {
        Self::of(op.preferred_strategy())
    }
}

impl std::fmt::Display for MappingChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.strat)?;
        if let Some(c) = self.chunk {
            write!(f, "/c{c}")?;
        }
        if let Some(j) = self.jchunk {
            write!(f, "/j{j}")?;
        }
        if self.carry_in {
            write!(f, "+carry")?;
        }
        Ok(())
    }
}

/// Geometry of one strategy applied to one operator on one configuration.
#[derive(Debug, Clone, Copy)]
pub struct Mapping {
    /// The strategy this geometry realizes.
    pub strat: StrategyKind,
    /// Input-channel (or reduction-dim) elements consumed per chunk.
    pub chunk: u32,
    /// Output-channel group processed per pass (lanes × TILE_C for
    /// CONV/PWCV; lanes × PP channels for DWCV under FF).
    pub group: u32,
    /// MPTU stages (≈ cycles in EX steady state) for the whole operator,
    /// including non-overlapped accumulation stages the strategy incurs.
    pub total_stages: u64,
    /// Whether partial sums fit the VRF partial partition (no DRAM spill).
    pub partials_in_vrf: bool,
    /// FF on CONV/PWCV: extra weight-element loads beyond one full pass,
    /// paid when the all-F weight slice overflows the weight partition and
    /// the non-resident remainder must be re-streamed per output row
    /// ([`ff_weight_refetches`]). Zero when the slice is resident, and
    /// zero for every other strategy — their per-tile weight walks are
    /// part of the stream structure itself, not a spill.
    pub weight_refetches: u64,
}

/// VRF partition budget per lane: the paper's VRF serves three concurrently
/// accessible partitions (inputs / weights / results, Sec. III-C); each
/// gets a third of the lane's capacity.
pub fn partition_budget(cfg: &SpeedConfig) -> u32 {
    cfg.vrf_bytes() / 3
}

/// Bytes one vector register region holds per lane (32 architectural regs).
pub fn vreg_region(cfg: &SpeedConfig) -> u32 {
    cfg.vrf_bytes() / 32
}

fn floor_to(v: u32, m: u32) -> u32 {
    (v / m).max(1) * m
}

/// Is `strat` applicable to `op`? (Fig. 10: FFCS and CF are developed for
/// computations along the input-channel dimension and are not applicable
/// to DWCV; MM applies only to MM operators and vice versa.)
pub fn applicable(strat: StrategyKind, op: &OpDesc) -> bool {
    match (strat, op.kind) {
        (StrategyKind::Mm, OpKind::Mm) => true,
        (_, OpKind::Mm) | (StrategyKind::Mm, _) => false,
        (StrategyKind::Ffcs | StrategyKind::Cf, OpKind::Dwcv) => false,
        _ => true,
    }
}

/// Compute the mapping geometry of `strat` over `op`.
///
/// Panics if the strategy is not applicable (callers check [`applicable`]).
pub fn map_op(op: &OpDesc, cfg: &SpeedConfig, strat: StrategyKind) -> Mapping {
    assert!(applicable(strat, op), "{strat} not applicable to {}", op.kind);
    match strat {
        StrategyKind::Mm => map_mm(op, cfg),
        StrategyKind::Ffcs => map_ffcs(op, cfg),
        StrategyKind::Cf => map_cf(op, cfg),
        StrategyKind::Ff => map_ff(op, cfg),
    }
}

/// Reduction-dim chunk for MM: the A-tile (TILE_R × kc) and broadcast
/// B-tile (kc × TILE_C) must each fit one vreg region.
pub fn mm_k_chunk(op: &OpDesc, cfg: &SpeedConfig) -> u32 {
    let pb = bytes_per_elem_x16(op.prec); // fixed-point x16 to handle nibbles
    let region = vreg_region(cfg) * 16;
    let by_a = region / (cfg.tile_r * pb);
    let by_b = region / (cfg.tile_c * pb);
    let pp = op.prec.pp();
    floor_to(by_a.min(by_b).min(op.k).max(pp), pp).min(floor_to(op.k.max(pp), pp))
}

/// Channel chunk for convolutions: the per-lane weight slice
/// (TILE_C × cc × K²) must fit one vreg region.
pub fn conv_c_chunk(op: &OpDesc, cfg: &SpeedConfig) -> u32 {
    let pb = bytes_per_elem_x16(op.prec);
    let region = vreg_region(cfg) * 16;
    let kk = op.ksize * op.ksize;
    let fit = region / (cfg.tile_c * kk * pb);
    let pp = op.prec.pp();
    floor_to(fit.max(pp), pp).min(floor_to(op.c.max(pp), pp))
}

/// Bytes per element ×16 (so INT4's half-byte is exact integer arithmetic).
fn bytes_per_elem_x16(p: Precision) -> u32 {
    2 * p.bits() // 16 * bits/8
}

/// Channel chunk for FF on CONV/PWCV: *all* output channels' weights for
/// the chunk (`(F/lanes) × cc × K²` per lane) must fit the VRF weight
/// partition, so inputs and weights both stream exactly once.
///
/// The chunk is capped at the largest PP multiple the partition fits. At
/// very large F even the minimal PP-sized chunk overflows the partition
/// and this helper returns the PP floor: the mapping then keeps a
/// [`ff_resident_f`]-channel weight prefix resident and re-streams the
/// remainder per output row — real loads code generation emits and
/// [`ff_weight_refetches`] counts, not a fiction the cost model hides.
///
/// Interior math is u64: `per_lane_f * kk * pb` overflows u32 for
/// extreme F × K² (the same class of bug as the PR-4 `oh()/ow()`
/// underflow), while [`ff_weights_resident`] was already widened.
pub fn ff_c_chunk(op: &OpDesc, cfg: &SpeedConfig) -> u32 {
    let pb = bytes_per_elem_x16(op.prec) as u64;
    let kk = (op.ksize * op.ksize) as u64;
    let budget = partition_budget(cfg) as u64 * 16;
    let per_lane_f = op.f.div_ceil(cfg.lanes).max(1) as u64;
    let fit = (budget / (per_lane_f * kk * pb).max(1)).min(u32::MAX as u64) as u32;
    let pp = op.prec.pp();
    floor_to(fit.max(pp), pp).min(floor_to(op.c.max(pp), pp))
}

/// FF-on-CONV/PWCV weight residency: does the per-lane all-F weight slice
/// of the *minimal* (PP-sized) channel chunk fit the VRF weight
/// partition? When it does not, no chunk cap can restore residency (the
/// overflow is driven by F, not by the chunk) and FF's "weights fetched
/// exactly once" no longer holds: code generation keeps the largest
/// resident prefix of output channels and re-streams the remainder's
/// weights per output row — honest extra traffic counted by
/// [`ff_weight_refetches`] and costed like any other load. DWCV's
/// per-lane weight slice is PP × K² and always fits.
pub fn ff_weights_resident(op: &OpDesc, cfg: &SpeedConfig) -> bool {
    if op.kind == OpKind::Dwcv {
        return true;
    }
    let pb = bytes_per_elem_x16(op.prec) as u64;
    let kk = (op.ksize * op.ksize) as u64;
    let per_lane_f = op.f.div_ceil(cfg.lanes).max(1) as u64;
    let pp = op.prec.pp() as u64;
    per_lane_f * kk * pp * pb <= partition_budget(cfg) as u64 * 16
}

/// The largest output-channel count whose weights for a `cc`-channel
/// chunk fit the VRF weight partition (a multiple of `lanes` since the
/// slice is lane-striped, capped at `op.f`). Equals `op.f` exactly when
/// the chunk is resident; the `F - ff_resident_f` remainder is what a
/// spilled FF stream re-fetches per output row.
pub fn ff_resident_f(op: &OpDesc, cfg: &SpeedConfig, cc: u32) -> u32 {
    let pb = bytes_per_elem_x16(op.prec) as u64;
    let kk = (op.ksize * op.ksize) as u64;
    let budget = partition_budget(cfg) as u64 * 16;
    let per_lane = budget / ((cc as u64) * kk * pb).max(1);
    (per_lane.saturating_mul(cfg.lanes as u64)).min(op.f as u64) as u32
}

/// Extra weight-element loads an FF stream over CONV/PWCV performs beyond
/// one full pass of `op.weight_elems()`, under the chunk override `chunk`
/// (resolved like code generation resolves it). Zero for resident shapes
/// and for DWCV.
///
/// Mirrors [`crate::compiler`]'s emission exactly: per channel chunk, the
/// [`ff_resident_f`]-channel weight prefix loads once, and the remainder
/// (`F - rf` channels × chunk × K² elements) re-streams on every one of
/// the `OH` output rows — `OH - 1` of those passes are refetches.
pub fn ff_weight_refetches(op: &OpDesc, cfg: &SpeedConfig, chunk: Option<u32>) -> u64 {
    if op.kind == OpKind::Dwcv || !applicable(StrategyKind::Ff, op) {
        return 0;
    }
    let cc = resolve_chunk(op, cfg, StrategyKind::Ff, chunk);
    let kk = (op.ksize * op.ksize) as u64;
    let oh = op.oh() as u64;
    let mut total = 0u64;
    let mut c0 = 0u32;
    while c0 < op.c {
        let ccur = cc.min(op.c - c0);
        let rf = ff_resident_f(op, cfg, ccur);
        total += oh.saturating_sub(1) * (op.f - rf) as u64 * ccur as u64 * kk;
        c0 += ccur;
    }
    total
}

/// Configuration-aware applicability. Since the honest FF spill model
/// landed this coincides with [`applicable`]: FF on a non-resident
/// CONV/PWCV shape compiles a real refetch stream instead of being
/// rejected, so the auto-tuner costs resident and spilled mappings alike.
/// The function stays configuration-parameterized because feasibility is
/// the contract point where a future config-dependent constraint belongs.
pub fn feasible(strat: StrategyKind, op: &OpDesc, cfg: &SpeedConfig) -> bool {
    let _ = cfg;
    applicable(strat, op)
}

/// Does `op`'s input tensor fit the VRF input partition — the local
/// precondition for running `op` with [`MappingChoice::carry_in`]?
/// Conv-family inputs are broadcast (each lane holds the full tensor); MM
/// A-tiles are lane-striped, so the per-lane share is what must fit.
pub fn carry_input_fits(op: &OpDesc, cfg: &SpeedConfig) -> bool {
    let budget = partition_budget(cfg) as u64;
    match op.kind {
        OpKind::Mm => op.input_bytes().div_ceil(cfg.lanes as u64) <= budget,
        _ => op.input_bytes() <= budget,
    }
}

/// Model-level residency chain: can `next` consume `prev`'s output
/// directly from the VRF, skipping the drain/reload round trip? True when
/// the tensors chain exactly (same precision, `prev`'s output geometry is
/// `next`'s input geometry), `prev`'s i32 output fits the per-lane output
/// partition, and `next`'s input satisfies [`carry_input_fits`]. The
/// tuner only sets [`MappingChoice::carry_in`] at positions where this
/// holds — and only keeps it when the measured cost is no worse.
pub fn carries_residency(prev: &OpDesc, next: &OpDesc, cfg: &SpeedConfig) -> bool {
    if prev.prec != next.prec || prev.output_elems() != next.input_elems() {
        return false;
    }
    let chained = match (prev.kind, next.kind) {
        (OpKind::Mm, OpKind::Mm) => prev.m == next.m && prev.n == next.k,
        (OpKind::Mm, _) | (_, OpKind::Mm) => false,
        (pk, _) => {
            let prev_ch = if pk == OpKind::Dwcv { prev.c } else { prev.f };
            prev_ch == next.c && prev.oh() == next.h && prev.ow() == next.w
        }
    };
    chained
        && prev.output_bytes().div_ceil(cfg.lanes as u64) <= partition_budget(cfg) as u64
        && carry_input_fits(next, cfg)
}

/// The chunk size the static mapping uses for `strat` over `op`: the
/// maximal slice that fits the VRF budget (DWCV under FF has no channel
/// chunking — its "chunk" is the PP packing factor). An inapplicable
/// `(strat, op)` pair degenerates to the PP floor rather than feeding the
/// conv chunk math an operator with no kernel (callers that compile go
/// through [`applicable`] anyway; this keeps the helper total).
pub fn default_chunk(op: &OpDesc, cfg: &SpeedConfig, strat: StrategyKind) -> u32 {
    if !applicable(strat, op) {
        return op.prec.pp();
    }
    match strat {
        StrategyKind::Mm => mm_k_chunk(op, cfg),
        StrategyKind::Ffcs | StrategyKind::Cf => conv_c_chunk(op, cfg),
        StrategyKind::Ff => {
            if op.kind == OpKind::Dwcv {
                op.prec.pp()
            } else {
                ff_c_chunk(op, cfg)
            }
        }
    }
}

/// Clamp a requested chunk override into the range code generation can
/// honor: a multiple of the PP packing factor (so per-chunk stage counts
/// telescope to the same total), at least PP, and at most the default
/// (the default is the largest slice the VRF regions fit — anything
/// bigger would overflow a vector register at load time). `None` is the
/// default chunk itself.
pub fn resolve_chunk(
    op: &OpDesc,
    cfg: &SpeedConfig,
    strat: StrategyKind,
    want: Option<u32>,
) -> u32 {
    let d = default_chunk(op, cfg, strat);
    match want {
        None => d,
        Some(w) => {
            let pp = op.prec.pp();
            floor_to(w.clamp(pp, d.max(pp)), pp).min(d.max(pp))
        }
    }
}

/// Candidate chunk overrides the auto-tuner tries for `strat` over `op`:
/// power-of-two fractions of the default, deduplicated and excluding the
/// default itself (which every search already costs as `chunk: None`).
pub fn chunk_candidates(op: &OpDesc, cfg: &SpeedConfig, strat: StrategyKind) -> Vec<u32> {
    let d = default_chunk(op, cfg, strat);
    let mut out = Vec::new();
    // Skinny MMs — autoregressive decode steps: at most one row block,
    // with the reduction dimension growing alongside the KV cache — are
    // dominated by the K walk, so the search gets a finer d/8 arm there.
    let skinny = op.kind == OpKind::Mm && op.m <= cfg.lanes * cfg.tile_r;
    let divs: &[u32] = if skinny { &[2, 4, 8] } else { &[2, 4] };
    for &div in divs {
        let c = resolve_chunk(op, cfg, strat, Some(d / div));
        if c < d && !out.contains(&c) {
            out.push(c);
        }
    }
    out
}

/// Largest useful MM B-tile column block for reduction chunk `kc`: a
/// multiple of `TILE_C` whose `kc × jc` B slice still fits one vreg
/// region (a wider block would split back into multiple VSALD images,
/// recreating the per-tile structure it was meant to coalesce), capped at
/// the operator's padded column count.
pub fn mm_j_chunk_max(op: &OpDesc, cfg: &SpeedConfig, kc: u32) -> u32 {
    let pb = bytes_per_elem_x16(op.prec) as u64;
    let region = vreg_region(cfg) as u64 * 16;
    let fit = (region / (kc as u64 * pb).max(1)) as u32;
    let cols = op.n.div_ceil(cfg.tile_c) * cfg.tile_c;
    floor_to(fit.max(cfg.tile_c), cfg.tile_c).min(cols.max(cfg.tile_c))
}

/// Clamp an MM B-tile column-block override into the range code
/// generation honors: a `TILE_C` multiple in `[TILE_C, mm_j_chunk_max]`.
/// `None` (or a non-MM strategy) keeps the static per-tile structure.
pub fn resolve_jchunk(
    op: &OpDesc,
    cfg: &SpeedConfig,
    strat: StrategyKind,
    want: Option<u32>,
    kc: u32,
) -> Option<u32> {
    if strat != StrategyKind::Mm || op.kind != OpKind::Mm {
        return None;
    }
    let w = want?;
    let maxj = mm_j_chunk_max(op, cfg, kc);
    Some(floor_to(w.clamp(cfg.tile_c, maxj), cfg.tile_c))
}

/// Candidate B-tile column blocks the auto-tuner tries for MM (the J-dim
/// arm of the chunk search, alongside [`chunk_candidates`]'s
/// reduction-dim arm): 2× and 4× `TILE_C` plus the region-limited
/// maximum, deduplicated, each strictly wider than the static per-tile
/// load. Empty for conv strategies and for MMs too narrow to widen.
pub fn jchunk_candidates(op: &OpDesc, cfg: &SpeedConfig, strat: StrategyKind) -> Vec<u32> {
    if strat != StrategyKind::Mm || op.kind != OpKind::Mm {
        return Vec::new();
    }
    let kc = mm_k_chunk(op, cfg);
    let maxj = mm_j_chunk_max(op, cfg, kc);
    let mut out = Vec::new();
    for want in [2 * cfg.tile_c, 4 * cfg.tile_c, maxj] {
        let j = floor_to(want.clamp(cfg.tile_c, maxj), cfg.tile_c);
        if j > cfg.tile_c && !out.contains(&j) {
            out.push(j);
        }
    }
    out
}

fn map_mm(op: &OpDesc, cfg: &SpeedConfig) -> Mapping {
    let pp = op.prec.pp();
    let kc = mm_k_chunk(op, cfg);
    let row_blocks = op.m.div_ceil(cfg.lanes * cfg.tile_r) as u64;
    let col_tiles = op.n.div_ceil(cfg.tile_c) as u64;
    let kchunks = op.k.div_ceil(kc) as u64;
    let stages_per_chunk = kc.div_ceil(pp) as u64;
    // Last chunk may be smaller; compute exactly.
    let last_kc = op.k - (kchunks as u32 - 1) * kc;
    let stages_k = (kchunks - 1) * stages_per_chunk + last_kc.div_ceil(pp) as u64;
    Mapping {
        strat: StrategyKind::Mm,
        chunk: kc,
        group: cfg.lanes * cfg.tile_r,
        total_stages: row_blocks * col_tiles * stages_k,
        partials_in_vrf: true, // output-stationary in PE across K chunks
        weight_refetches: 0,
    }
}

/// Does a per-lane partial image of `rows × OW × TILE_C` i32 fit the
/// partial partition?
fn conv_partials_fit(op: &OpDesc, cfg: &SpeedConfig) -> bool {
    let per_lane = op.oh() as u64 * op.ow() as u64 * cfg.tile_c as u64 * 4;
    per_lane <= partition_budget(cfg) as u64
}

fn map_ffcs(op: &OpDesc, cfg: &SpeedConfig) -> Mapping {
    let pp = op.prec.pp();
    let cc = conv_c_chunk(op, cfg);
    let fgroups = op.f.div_ceil(cfg.lanes * cfg.tile_c) as u64;
    let kk = (op.ksize * op.ksize) as u64;
    let pixel_tiles = (op.oh() as u64) * (op.ow() as u64).div_ceil(cfg.tile_r as u64);
    let cpasses = op.c.div_ceil(pp) as u64;
    let mut stages = fgroups * pixel_tiles * cpasses * kk;
    // Non-overlapped accumulation penalty: with a 1-cycle window walk
    // (K == 1) every input-channel step's partial-sum round trip through
    // the VRF cannot hide behind compute (Fig. 9's overlap needs ≥ 2
    // cycles per stage burst) — Sec. III-B's "frequent VRF accesses ...
    // dominate the overall computation time" for PWCV under FFCS.
    if op.ksize == 1 {
        stages += fgroups * pixel_tiles * cpasses;
    }
    Mapping {
        strat: StrategyKind::Ffcs,
        chunk: cc,
        group: cfg.lanes * cfg.tile_c,
        total_stages: stages,
        partials_in_vrf: conv_partials_fit(op, cfg),
        weight_refetches: 0,
    }
}

fn map_cf(op: &OpDesc, cfg: &SpeedConfig) -> Mapping {
    let pp = op.prec.pp();
    let cc = conv_c_chunk(op, cfg);
    let fgroups = op.f.div_ceil(cfg.lanes * cfg.tile_c) as u64;
    let kk = (op.ksize * op.ksize) as u64;
    let pixel_tiles = (op.oh() as u64) * (op.ow() as u64).div_ceil(cfg.tile_r as u64);
    let cpasses = op.c.div_ceil(pp) as u64;
    // Channel-first: partials live in the PE across the whole C traversal —
    // no accumulation stages, ever.
    Mapping {
        strat: StrategyKind::Cf,
        chunk: cc,
        group: cfg.lanes * cfg.tile_c,
        total_stages: fgroups * pixel_tiles * cpasses * kk,
        partials_in_vrf: true,
        weight_refetches: 0,
    }
}

fn map_ff(op: &OpDesc, cfg: &SpeedConfig) -> Mapping {
    let pp = op.prec.pp();
    let kk = (op.ksize * op.ksize) as u64;
    if op.kind == OpKind::Dwcv {
        // Channels decoupled: lanes × PP channels per group; POI × POW both
        // cover feature-map pixels.
        let cgroups = op.c.div_ceil(cfg.lanes * pp) as u64;
        let pixel_tiles =
            (op.oh() as u64) * (op.ow() as u64).div_ceil((cfg.tile_r * cfg.tile_c) as u64);
        Mapping {
            strat: StrategyKind::Ff,
            chunk: pp,
            group: cfg.lanes * pp,
            total_stages: cgroups * pixel_tiles * kk,
            partials_in_vrf: true, // no cross-channel accumulation at all
            weight_refetches: 0,
        }
    } else {
        // FF applied to CONV/PWCV (ablation arm of Figs. 10/11): inputs
        // stream exactly once and the resident weight prefix too; when the
        // all-F slice overflows the weight partition the remainder
        // re-streams per output row (`weight_refetches` > 0). Like FFCS,
        // the K == 1 case cannot hide the per-channel-pass partial round
        // trip.
        let cc = ff_c_chunk(op, cfg);
        let fgroups = op.f.div_ceil(cfg.lanes * cfg.tile_c) as u64;
        let pixel_tiles = (op.oh() as u64) * (op.ow() as u64).div_ceil(cfg.tile_r as u64);
        let cpasses = op.c.div_ceil(pp) as u64;
        let mut stages = fgroups * pixel_tiles * cpasses * kk;
        if op.ksize == 1 {
            stages += fgroups * pixel_tiles * cpasses;
        }
        Mapping {
            strat: StrategyKind::Ff,
            chunk: cc,
            group: cfg.lanes * cfg.tile_c,
            total_stages: stages,
            partials_in_vrf: conv_partials_fit(op, cfg),
            weight_refetches: ff_weight_refetches(op, cfg, None),
        }
    }
}

/// Kseg decomposition (Sec. II-B): kernels larger than 15 are split into
/// sub-kernels no larger than 15, each a separate CONV whose partial sums
/// compose. Returns the sub-kernel sizes along one axis.
///
/// The split is balanced: the minimum number of pieces, with sizes
/// differing by at most one. The greedy `[15, 15, ..., rest]` split this
/// function once produced degenerates at boundaries — `kseg_decompose(16)`
/// was `[15, 1]`, a 1-wide sub-kernel whose CONV pass does almost no work
/// per input fetch — whereas the balanced split gives `[8, 8]`.
pub fn kseg_decompose(ksize: u32) -> Vec<u32> {
    if ksize <= 15 {
        return vec![ksize];
    }
    let pieces = ksize.div_ceil(15);
    let base = ksize / pieces;
    let rem = ksize % pieces;
    (0..pieces).map(|i| base + u32::from(i < rem)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    fn cfg() -> SpeedConfig {
        SpeedConfig::reference()
    }

    #[test]
    fn applicability_matrix_matches_paper() {
        let conv = OpDesc::conv(8, 16, 12, 12, 3, 1, 1, Precision::Int16);
        let pwcv = OpDesc::pwcv(16, 32, 8, 8, Precision::Int16);
        let dwcv = OpDesc::dwcv(8, 13, 13, 3, 2, 1, Precision::Int16);
        let mm = OpDesc::mm(4, 8, 8, Precision::Int16);
        // FFCS/CF not applicable to DWCV (Fig. 10 caption).
        assert!(!applicable(StrategyKind::Ffcs, &dwcv));
        assert!(!applicable(StrategyKind::Cf, &dwcv));
        assert!(applicable(StrategyKind::Ff, &dwcv));
        // All three conv strategies apply to CONV / PWCV.
        for s in [StrategyKind::Ffcs, StrategyKind::Cf, StrategyKind::Ff] {
            assert!(applicable(s, &conv));
            assert!(applicable(s, &pwcv));
        }
        // MM only for MM.
        assert!(applicable(StrategyKind::Mm, &mm));
        assert!(!applicable(StrategyKind::Ffcs, &mm));
        assert!(!applicable(StrategyKind::Mm, &conv));
    }

    #[test]
    fn mm_stage_count_exact_small() {
        // Fig. 2 workload: 4x8 MM @16b on 2 lanes of 2x2 tiles:
        // row_blocks = ceil(4/4)=1, col_tiles = ceil(8/2)=4, K=8 PP=1.
        let c = SpeedConfig { lanes: 2, ..cfg() };
        let op = OpDesc::mm(4, 8, 8, Precision::Int16);
        let m = map_op(&op, &c, StrategyKind::Mm);
        assert_eq!(m.total_stages, 1 * 4 * 8);
        assert!(m.partials_in_vrf);
    }

    #[test]
    fn mm_stages_scale_with_pp() {
        let op16 = OpDesc::mm(16, 64, 16, Precision::Int16);
        let op4 = OpDesc::mm(16, 64, 16, Precision::Int4);
        let s16 = map_op(&op16, &cfg(), StrategyKind::Mm).total_stages;
        let s4 = map_op(&op4, &cfg(), StrategyKind::Mm).total_stages;
        // 4-bit packs 16 MACs/PE/cycle vs 1 at 16-bit: 16x fewer stages.
        assert_eq!(s16, 16 * s4);
    }

    #[test]
    fn ffcs_pwcv_pays_accumulation_penalty_cf_does_not() {
        let op = OpDesc::pwcv(64, 64, 12, 12, Precision::Int16);
        let ffcs = map_op(&op, &cfg(), StrategyKind::Ffcs);
        let cf = map_op(&op, &cfg(), StrategyKind::Cf);
        assert!(ffcs.total_stages > cf.total_stages,
                "FFCS {} vs CF {}", ffcs.total_stages, cf.total_stages);
    }

    #[test]
    fn cf_and_ffcs_equal_on_k3(){
        let op = OpDesc::conv(16, 16, 12, 12, 3, 1, 1, Precision::Int16);
        let ffcs = map_op(&op, &cfg(), StrategyKind::Ffcs);
        let cf = map_op(&op, &cfg(), StrategyKind::Cf);
        assert_eq!(ffcs.total_stages, cf.total_stages);
    }

    #[test]
    fn dwcv_ff_uses_both_tile_dims_for_pixels() {
        let op = OpDesc::dwcv(8, 13, 13, 3, 2, 1, Precision::Int16);
        let m = map_op(&op, &cfg(), StrategyKind::Ff);
        // cgroups = ceil(8/(4*1)) = 2; pixel tiles = 7 * ceil(7/4) = 14; k²=9
        assert_eq!(m.total_stages, 2 * 14 * 9);
    }

    #[test]
    fn chunks_respect_vrf_and_pp() {
        for prec in Precision::ALL {
            let op = OpDesc::conv(256, 256, 56, 56, 3, 1, 1, prec);
            let cc = conv_c_chunk(&op, &cfg());
            assert_eq!(cc % prec.pp(), 0);
            let per_lane_weight_bits =
                cfg().tile_c * cc * 9 * prec.bits();
            assert!(per_lane_weight_bits / 8 <= vreg_region(&cfg()),
                    "{prec}: weight slice {} B > region", per_lane_weight_bits / 8);
            let mm = OpDesc::mm(64, 4096, 64, prec);
            let kc = mm_k_chunk(&mm, &cfg());
            assert_eq!(kc % prec.pp(), 0);
        }
    }

    #[test]
    fn kseg_splits_large_kernels() {
        assert_eq!(kseg_decompose(3), vec![3]);
        assert_eq!(kseg_decompose(15), vec![15]);
        assert_eq!(kseg_decompose(16), vec![8, 8]);
        assert_eq!(kseg_decompose(31), vec![11, 10, 10]);
        assert_eq!(kseg_decompose(45), vec![15, 15, 15]);
        for ksize in 16..=128u32 {
            let pieces = kseg_decompose(ksize);
            assert_eq!(pieces.iter().sum::<u32>(), ksize, "k={ksize}");
            let max = *pieces.iter().max().unwrap();
            let min = *pieces.iter().min().unwrap();
            assert!(max <= 15, "k={ksize}: piece {max} > 15");
            // Balanced: no degenerate sliver. Pieces differ by at most
            // one, which also guarantees min >= ksize/2 for the two-piece
            // range (16..=30) — the [15, 1] regression cannot recur.
            assert!(max - min <= 1, "k={ksize}: {pieces:?}");
            assert!(min >= max / 2, "k={ksize}: {pieces:?}");
            if pieces.len() == 2 {
                assert!(min >= ksize / 2, "k={ksize}: {pieces:?}");
            }
        }
    }

    #[test]
    fn chunk_resolution_clamps_and_quantizes() {
        let cfg = cfg();
        for prec in Precision::ALL {
            let op = OpDesc::conv(256, 256, 56, 56, 3, 1, 1, prec);
            let pp = prec.pp();
            for strat in [StrategyKind::Ffcs, StrategyKind::Cf, StrategyKind::Ff] {
                let d = default_chunk(&op, &cfg, strat);
                assert_eq!(resolve_chunk(&op, &cfg, strat, None), d);
                // Oversized requests clamp to the default (VRF safety).
                assert_eq!(resolve_chunk(&op, &cfg, strat, Some(d * 8)), d);
                // Undersized requests clamp up to PP.
                assert_eq!(resolve_chunk(&op, &cfg, strat, Some(1)), pp.min(d.max(pp)));
                // Every resolved value is a PP multiple within [PP, d].
                for want in [d / 2, d / 3, d / 4, 7, 1000] {
                    let c = resolve_chunk(&op, &cfg, strat, Some(want));
                    assert_eq!(c % pp, 0, "{prec} {strat} want={want}");
                    assert!(c >= pp && c <= d.max(pp), "{prec} {strat}: {c} vs d={d}");
                }
            }
            // Candidates are strictly smaller than the default, deduped.
            let cands = chunk_candidates(&op, &cfg, StrategyKind::Ffcs);
            let d = default_chunk(&op, &cfg, StrategyKind::Ffcs);
            for c in &cands {
                assert!(*c < d && *c >= pp && *c % pp == 0);
            }
            // DWCV under FF has no channel chunking to vary.
            let dw = OpDesc::dwcv(32, 14, 14, 3, 1, 1, prec);
            assert_eq!(default_chunk(&dw, &cfg, StrategyKind::Ff), pp);
            assert!(chunk_candidates(&dw, &cfg, StrategyKind::Ff).is_empty());
        }
    }

    #[test]
    fn big_fmap_spills_partials_small_does_not() {
        let small = OpDesc::conv(8, 16, 12, 12, 3, 1, 1, Precision::Int16);
        let big = OpDesc::conv(64, 64, 112, 112, 3, 1, 1, Precision::Int16);
        assert!(map_op(&small, &cfg(), StrategyKind::Ffcs).partials_in_vrf);
        assert!(!map_op(&big, &cfg(), StrategyKind::Ffcs).partials_in_vrf);
    }

    #[test]
    fn ff_residency_boundary_at_large_f() {
        // Reference config: budget×16 = (16384/3)×16 = 87376. INT8 3×3:
        // per-lane slice at the minimal PP chunk is (F/4)·9·4·16 ≤ 87376
        // ⟺ F/4 ≤ 151 — F = 604 is the last resident shape, 608 the
        // first spilled one. Both are feasible: the spilled side now
        // compiles a real refetch stream instead of being rejected.
        let cfg = cfg();
        let resident = OpDesc::conv(64, 604, 14, 14, 3, 1, 1, Precision::Int8);
        let spilled = OpDesc::conv(64, 608, 14, 14, 3, 1, 1, Precision::Int8);
        assert!(ff_weights_resident(&resident, &cfg));
        assert!(!ff_weights_resident(&spilled, &cfg));
        assert!(feasible(StrategyKind::Ff, &resident, &cfg));
        assert!(feasible(StrategyKind::Ff, &spilled, &cfg));
        assert_eq!(ff_weight_refetches(&resident, &cfg, None), 0);
        assert!(ff_weight_refetches(&spilled, &cfg, None) > 0);
        assert_eq!(map_op(&resident, &cfg, StrategyKind::Ff).weight_refetches, 0);
        assert!(map_op(&spilled, &cfg, StrategyKind::Ff).weight_refetches > 0);
        // The other conv strategies never stage all-F weights: no spill.
        assert!(feasible(StrategyKind::Ffcs, &spilled, &cfg));
        assert!(feasible(StrategyKind::Cf, &spilled, &cfg));
        // The vgg16-class INT4 shape the ROADMAP named: PP = 16 pushes the
        // minimal chunk past the partition even though `ff_c_chunk` floors
        // at PP — the remainder re-streams per output row, honestly
        // counted.
        let vgg_like = OpDesc::conv(512, 512, 14, 14, 3, 1, 1, Precision::Int4);
        assert_eq!(ff_c_chunk(&vgg_like, &cfg), Precision::Int4.pp());
        assert!(!ff_weights_resident(&vgg_like, &cfg));
        assert!(ff_weight_refetches(&vgg_like, &cfg, None) > 0);
        // DWCV weights are PP×K² per lane: always resident.
        let dw = OpDesc::dwcv(4096, 14, 14, 3, 1, 1, Precision::Int4);
        assert!(ff_weights_resident(&dw, &cfg));
        assert!(feasible(StrategyKind::Ff, &dw, &cfg));
        assert_eq!(ff_weight_refetches(&dw, &cfg, None), 0);
    }

    #[test]
    fn ff_refetch_count_matches_closed_form() {
        let cfg = cfg();
        // F=608 INT8 3×3: per-lane fit at cc=4 is 87376/(4·9·16) = 151
        // rows → rf = 604 resident channels, 4 refetched. oh=14 with
        // pad 1 stride 1 ⇒ 14 output rows, 13 of them refetch passes.
        let op = OpDesc::conv(64, 608, 14, 14, 3, 1, 1, Precision::Int8);
        let cc = ff_c_chunk(&op, &cfg);
        assert_eq!(cc, Precision::Int8.pp());
        let rf = ff_resident_f(&op, &cfg, cc);
        assert!(rf < op.f && rf % cfg.lanes == 0);
        let chunks = op.c / cc;
        let want = (op.oh() as u64 - 1)
            * (op.f - rf) as u64
            * cc as u64
            * 9
            * chunks as u64;
        assert_eq!(ff_weight_refetches(&op, &cfg, None), want);
        // A smaller chunk override keeps more channels resident per chunk
        // (never fewer), so refetches never increase with a smaller chunk.
        for c in chunk_candidates(&op, &cfg, StrategyKind::Ff) {
            assert!(
                ff_weight_refetches(&op, &cfg, Some(c))
                    <= ff_weight_refetches(&op, &cfg, None),
                "chunk {c}"
            );
        }
    }

    #[test]
    fn ff_c_chunk_survives_extreme_f_times_k2() {
        // u32 interior math overflowed here: per_lane_f·kk·pb for
        // F = 2^22, K = 15 at INT16 is 2^20·225·32 ≈ 2^32.8. The widened
        // u64 math must floor the chunk at PP, count refetches, and agree
        // with the residency predicate instead of wrapping (or panicking
        // in debug builds).
        let cfg = cfg();
        let op = OpDesc::conv(64, 1 << 22, 64, 64, 15, 1, 7, Precision::Int16);
        let pp = Precision::Int16.pp();
        assert_eq!(ff_c_chunk(&op, &cfg), pp);
        assert!(!ff_weights_resident(&op, &cfg));
        assert_eq!(ff_resident_f(&op, &cfg, pp) % cfg.lanes, 0);
        assert!(ff_weight_refetches(&op, &cfg, None) > 0);
    }

    #[test]
    fn residency_carry_chain_geometry_and_fit() {
        let cfg = cfg();
        // llm_tiny decode MLP pair: 1×128×256 feeding 1×256×128. Output
        // of the first is 256 i32 = 1 KiB (256 B/lane ≤ 5461) and the
        // second's lane-striped A share is 64 B — the chain carries.
        let up = OpDesc::mm(1, 128, 256, Precision::Int8);
        let down = OpDesc::mm(1, 256, 128, Precision::Int8);
        assert!(carries_residency(&up, &down, &cfg));
        assert!(carry_input_fits(&down, &cfg));
        // Geometry mismatch (K of the consumer != N of the producer).
        let wrong = OpDesc::mm(1, 128, 128, Precision::Int8);
        assert!(!carries_residency(&up, &wrong, &cfg));
        // Precision mismatch breaks the chain.
        let down4 = OpDesc::mm(1, 256, 128, Precision::Int4);
        assert!(!carries_residency(&up, &down4, &cfg));
        // A large prefill MM's output overflows the output partition.
        let big_up = OpDesc::mm(64, 128, 256, Precision::Int8);
        let big_down = OpDesc::mm(64, 256, 128, Precision::Int8);
        assert!(!carries_residency(&big_up, &big_down, &cfg));
        // Conv chains: f/oh/ow must line up with c/h/w at the consumer.
        let a = OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8);
        let b = OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8);
        assert!(carries_residency(&a, &b, &cfg));
        let misfit = OpDesc::conv(8, 8, 12, 12, 3, 1, 1, Precision::Int8);
        assert!(!carries_residency(&a, &misfit, &cfg));
        // MM never chains into a conv.
        assert!(!carries_residency(&up, &a, &cfg));
    }

    #[test]
    fn jchunk_resolution_and_candidates() {
        let cfg = cfg();
        // Wide MM: many column tiles, so the J-dim search has room.
        let op = OpDesc::mm(16, 64, 192, Precision::Int8);
        let kc = mm_k_chunk(&op, &cfg);
        let maxj = mm_j_chunk_max(&op, &cfg, kc);
        assert_eq!(maxj % cfg.tile_c, 0);
        assert!(maxj >= cfg.tile_c);
        // The widened B slice still fits one vreg region.
        assert!(
            op.prec.bytes_for(kc as u64 * maxj as u64) <= vreg_region(&cfg) as u64,
            "kc={kc} maxj={maxj}"
        );
        // Resolution clamps into [TILE_C, maxj] as a TILE_C multiple.
        assert_eq!(resolve_jchunk(&op, &cfg, StrategyKind::Mm, None, kc), None);
        assert_eq!(
            resolve_jchunk(&op, &cfg, StrategyKind::Mm, Some(1), kc),
            Some(cfg.tile_c)
        );
        assert_eq!(
            resolve_jchunk(&op, &cfg, StrategyKind::Mm, Some(u32::MAX), kc),
            Some(maxj)
        );
        for want in [3u32, 7, 10, 1000] {
            let j = resolve_jchunk(&op, &cfg, StrategyKind::Mm, Some(want), kc).unwrap();
            assert_eq!(j % cfg.tile_c, 0, "want={want}");
            assert!(j >= cfg.tile_c && j <= maxj, "want={want}: {j}");
        }
        // Candidates: strictly wider than the static per-tile load, deduped.
        let cands = jchunk_candidates(&op, &cfg, StrategyKind::Mm);
        assert!(!cands.is_empty(), "wide MM must offer J-dim candidates");
        for (i, j) in cands.iter().enumerate() {
            assert!(*j > cfg.tile_c && *j <= maxj && *j % cfg.tile_c == 0);
            assert!(!cands[i + 1..].contains(j), "{j} duplicated");
        }
        // Conv strategies and narrow MMs have no J-dim to widen.
        let conv = OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8);
        assert!(jchunk_candidates(&conv, &cfg, StrategyKind::Ffcs).is_empty());
        assert_eq!(resolve_jchunk(&conv, &cfg, StrategyKind::Ffcs, Some(8), 4), None);
        let narrow = OpDesc::mm(8, 32, cfg.tile_c, Precision::Int8);
        assert!(jchunk_candidates(&narrow, &cfg, StrategyKind::Mm).is_empty());
    }

    #[test]
    fn decode_shapes_stay_feasible_and_get_skinny_candidates() {
        let cfg = cfg();
        // Decode-step MMs: one output row (or one per fused head), K
        // growing with the KV cache. Every growing-K variant must stay
        // feasible and resolve legal chunks — the serve path tunes each
        // cache length as its own workload.
        for prec in Precision::ALL {
            let pp = prec.pp();
            for kv in [64u32, 96, 160, 256, 1024] {
                let op = OpDesc::mm(1, kv, 128, prec);
                assert!(feasible(StrategyKind::Mm, &op, &cfg), "{prec} kv={kv}");
                let d = default_chunk(&op, &cfg, StrategyKind::Mm);
                for c in chunk_candidates(&op, &cfg, StrategyKind::Mm) {
                    assert!(c < d && c >= pp && c % pp == 0, "{prec} kv={kv}: {c}");
                }
            }
        }
        // The skinny arm: a single-row-block MM offers a finer minimum
        // candidate than the same-(K, N) many-row MM (same default chunk,
        // since the VRF tile math is M-independent).
        let skinny = OpDesc::mm(1, 256, 128, Precision::Int16);
        let wide = OpDesc::mm(1024, 256, 128, Precision::Int16);
        let d = default_chunk(&skinny, &cfg, StrategyKind::Mm);
        assert_eq!(d, default_chunk(&wide, &cfg, StrategyKind::Mm));
        let min_of = |op: &OpDesc| {
            chunk_candidates(op, &cfg, StrategyKind::Mm).into_iter().min().unwrap()
        };
        assert!(
            min_of(&skinny) < min_of(&wide),
            "skinny {} !< wide {}",
            min_of(&skinny),
            min_of(&wide)
        );
    }
}
