//! Thread-based sweep runner: evaluates many (model, precision, config)
//! points concurrently.
//!
//! The coordinator's sweeps (Fig. 12's 6 models × 3 precisions, Fig. 14's
//! 27-point DSE) are embarrassingly parallel; each point owns its own
//! `Processor`. (The deployment image is fully offline — no async runtime
//! is vendored — so the runner uses `std::thread` scoped threads; see
//! DESIGN.md "Substitutions".)

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::thread;

/// Run `jobs` across up to `workers` threads, preserving input order.
///
/// A panicking job does not poison the pool: the panic payload is captured
/// on the worker, the remaining jobs still run, and the first payload is
/// re-raised on the calling thread (so the caller sees the *original*
/// panic message, not a channel/join artifact).
pub fn run_parallel<T, R, F>(jobs: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();
    let jobs: Vec<(usize, T)> = jobs.into_iter().enumerate().collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            let jobs = &jobs;
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (idx, job) = &jobs[i];
                let r = catch_unwind(AssertUnwindSafe(|| f(job)));
                if tx.send((*idx, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic_payload = None;
        for (idx, r) in rx {
            match r {
                Ok(v) => out[idx] = Some(v),
                Err(payload) => {
                    // Keep the first panic; later ones are typically
                    // knock-on failures of the same root cause.
                    panic_payload.get_or_insert(payload);
                }
            }
        }
        if let Some(payload) = panic_payload {
            resume_unwind(payload);
        }
        out.into_iter().map(|o| o.expect("worker dropped a job")).collect()
    })
}

/// Default worker count: physical parallelism minus one, at least 1.
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get().saturating_sub(1)).unwrap_or(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_results() {
        let jobs: Vec<u64> = (0..100).collect();
        let out = run_parallel(jobs, 8, |&x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        let out: Vec<u32> = run_parallel(Vec::<u32>::new(), 4, |&x| x);
        assert!(out.is_empty());
        let out = run_parallel(vec![7], 4, |&x| x + 1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn more_workers_than_jobs_is_fine() {
        let out = run_parallel(vec![1, 2, 3], 64, |&x| x * 10);
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn worker_panic_resurfaces_with_original_payload() {
        let jobs: Vec<u32> = (0..16).collect();
        let caught = std::panic::catch_unwind(|| {
            run_parallel(jobs, 4, |&x| {
                if x == 7 {
                    panic!("job 7 exploded");
                }
                x
            })
        })
        .expect_err("panic must propagate to the caller");
        let msg = caught
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| caught.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("job 7 exploded"), "payload was '{msg}'");
    }

    #[test]
    fn surviving_jobs_complete_despite_a_panic() {
        // With one worker the panicking job must not starve the rest.
        let jobs: Vec<u32> = (0..8).collect();
        let caught = std::panic::catch_unwind(|| {
            run_parallel(jobs, 1, |&x| {
                if x == 0 {
                    panic!("first job dies");
                }
                x * 2
            })
        });
        assert!(caught.is_err(), "panic must still propagate");
    }

    #[test]
    fn runs_simulations_in_parallel() {
        use crate::config::{Precision, SpeedConfig};
        use crate::coordinator::{run_model, Policy};
        use crate::models::ops::OpDesc;
        use crate::models::zoo::Model;

        let model = Model {
            name: "par",
            ops: vec![OpDesc::conv(4, 8, 8, 8, 3, 1, 1, Precision::Int8)],
            scalar_fraction: 0.0,
        };
        let jobs: Vec<Precision> = vec![Precision::Int16, Precision::Int8, Precision::Int4];
        let out = run_parallel(jobs, 3, |&p| {
            run_model(&model, p, &SpeedConfig::reference(), Policy::Mixed)
                .unwrap()
                .vector_cycles()
        });
        assert_eq!(out.len(), 3);
        assert!(out[2] < out[0], "4-bit must beat 16-bit: {out:?}");
    }
}
