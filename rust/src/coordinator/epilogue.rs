//! Result-path epilogues on the vector ALU.
//!
//! SPEED's MPTU produces 32-bit accumulators; quantized deployment
//! requantizes them back to the operating precision (shift-round-clip)
//! before the next layer. The paper routes this through the lane's vector
//! ALU — this module emits that instruction stream, and the integration
//! tests verify it bit-exactly against the AOT-compiled `requant_s7_i8`
//! JAX/Pallas artifact via PJRT (the fourth leg of the golden agreement).

use crate::config::{Precision, SpeedConfig};
use crate::isa::{Insn, Vtype};

// Scalar scratch registers (disjoint from the codegen set).
const X_ADDR: u8 = 20;
const X_VAL: u8 = 21;
const X_VL: u8 = 22;

// Vector registers: data + four splatted constants.
const V_DATA: u8 = 24;
const V_ROUND: u8 = 25;
const V_SHIFT: u8 = 26;
const V_HI: u8 = 27;
const V_LO: u8 = 28;

/// Emit a requantization program over `n` 32-bit accumulators at
/// `in_addr`, writing requantized 32-bit values (clipped to the `bits`
/// range, like the artifact) to `out_addr`.
///
/// Per chunk: `acc = clip((acc + (1 << (shift-1))) >> shift, lo, hi)` via
/// `VADD`/`VSRA`/`VMIN`/`VMAX` — the exact arithmetic of
/// `kernels/ref.py::requantize_ref`.
pub fn requant_program(
    cfg: &SpeedConfig,
    n: u64,
    shift: u32,
    bits: u32,
    in_addr: u64,
    out_addr: u64,
) -> Vec<Insn> {
    let prec = Precision::from_bits(bits).expect("4/8/16-bit only");
    let (lo, hi) = prec.range();
    // Chunk so each lane stripe of i32 data fits one vreg region.
    let chunk = (cfg.lanes as u64 * (cfg.vrf_bytes() as u64 / 32) / 4).min(n).max(1);

    let mut prog = Vec::new();
    let li = |prog: &mut Vec<Insn>, rd: u8, v: i64| {
        prog.push(Insn::Addi { rd, rs1: 0, imm: v as i32 });
    };
    let setvl = |prog: &mut Vec<Insn>, vl: u64| {
        li(prog, X_VL, vl as i64);
        prog.push(Insn::Vsetvli { rd: 0, rs1: X_VL, vtype: Vtype::new(32) });
    };

    // Splat the constants once (full-chunk vl).
    setvl(&mut prog, chunk);
    if shift > 0 {
        li(&mut prog, X_VAL, 1i64 << (shift - 1));
        prog.push(Insn::Vmv { vd: V_ROUND, rs1: X_VAL });
        li(&mut prog, X_VAL, shift as i64);
        prog.push(Insn::Vmv { vd: V_SHIFT, rs1: X_VAL });
    }
    li(&mut prog, X_VAL, hi as i64);
    prog.push(Insn::Vmv { vd: V_HI, rs1: X_VAL });
    li(&mut prog, X_VAL, lo as i64);
    prog.push(Insn::Vmv { vd: V_LO, rs1: X_VAL });

    let mut done = 0u64;
    while done < n {
        let cur = chunk.min(n - done);
        if cur != chunk {
            // Tail chunk: re-splat constants at the shorter vl so the
            // element-wise ops line up.
            setvl(&mut prog, cur);
            if shift > 0 {
                li(&mut prog, X_VAL, 1i64 << (shift - 1));
                prog.push(Insn::Vmv { vd: V_ROUND, rs1: X_VAL });
                li(&mut prog, X_VAL, shift as i64);
                prog.push(Insn::Vmv { vd: V_SHIFT, rs1: X_VAL });
            }
            li(&mut prog, X_VAL, hi as i64);
            prog.push(Insn::Vmv { vd: V_HI, rs1: X_VAL });
            li(&mut prog, X_VAL, lo as i64);
            prog.push(Insn::Vmv { vd: V_LO, rs1: X_VAL });
        }
        li(&mut prog, X_ADDR, (in_addr + done * 4) as i64);
        prog.push(Insn::Vle { vd: V_DATA, rs1: X_ADDR, eew: 32 });
        if shift > 0 {
            prog.push(Insn::Vadd { vd: V_DATA, vs1: V_DATA, vs2: V_ROUND });
            prog.push(Insn::Vsra { vd: V_DATA, vs1: V_DATA, vs2: V_SHIFT });
        }
        prog.push(Insn::Vmin { vd: V_DATA, vs1: V_DATA, vs2: V_HI });
        prog.push(Insn::Vmax { vd: V_DATA, vs1: V_DATA, vs2: V_LO });
        li(&mut prog, X_ADDR, (out_addr + done * 4) as i64);
        prog.push(Insn::Vse { vs3: V_DATA, rs1: X_ADDR, eew: 32 });
        done += cur;
    }
    prog
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Processor;

    fn run_requant(acc: &[i32], shift: u32, bits: u32) -> Vec<i32> {
        let cfg = SpeedConfig::reference();
        let mut p = Processor::new(cfg, 1 << 20);
        let in_addr = 0x100u64;
        let out_addr = 0x8000u64;
        for (i, &v) in acc.iter().enumerate() {
            p.mem.preload(in_addr + 4 * i as u64, &v.to_le_bytes());
        }
        let prog = requant_program(&cfg, acc.len() as u64, shift, bits, in_addr, out_addr);
        p.run(&prog).unwrap();
        p.mem.inspect_i32(out_addr, acc.len())
    }

    fn requant_ref(acc: &[i32], shift: u32, bits: u32) -> Vec<i32> {
        let prec = Precision::from_bits(bits).unwrap();
        acc.iter()
            .map(|&a| {
                let v = if shift > 0 { (a + (1 << (shift - 1))) >> shift } else { a };
                prec.clamp(v)
            })
            .collect()
    }

    #[test]
    fn requant_matches_reference_math() {
        let acc: Vec<i32> = (-50..50).map(|i| i * 1_000_003).collect();
        for (shift, bits) in [(0u32, 8u32), (7, 8), (7, 4), (12, 16), (1, 8)] {
            assert_eq!(
                run_requant(&acc, shift, bits),
                requant_ref(&acc, shift, bits),
                "shift={shift} bits={bits}"
            );
        }
    }

    #[test]
    fn requant_saturates_extremes() {
        let acc = vec![i32::MAX / 2, i32::MIN / 2, 0, 127, -128, 128, -129];
        let got = run_requant(&acc, 0, 8);
        assert_eq!(got, vec![127, -128, 0, 127, -128, 127, -128]);
    }

    #[test]
    fn requant_handles_tail_chunks() {
        // A length that is not a multiple of the chunk size.
        let acc: Vec<i32> = (0..5000).map(|i| (i - 2500) * 77).collect();
        assert_eq!(run_requant(&acc, 7, 8), requant_ref(&acc, 7, 8));
    }

    #[test]
    fn requant_uses_the_vector_alu() {
        let cfg = SpeedConfig::reference();
        let mut p = Processor::new(cfg, 1 << 20);
        let prog = requant_program(&cfg, 64, 7, 8, 0x100, 0x8000);
        let st = p.run(&prog).unwrap();
        assert!(st.fu_busy[crate::sim::Fu::Valu.index()] > 0, "VALU never used");
    }
}
