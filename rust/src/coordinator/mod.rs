//! Inference coordinator: schedules whole networks on SPEED.
//!
//! The coordinator is the deployment layer of Sec. IV-C: it walks a
//! model's operator sequence, selects the dataflow strategy per operator
//! (the paper's *mixed dataflow*: MM / FFCS / CF / FF by operator kind, or
//! a fixed strategy for ablation), emits the `VSACFG` precision switches,
//! executes every operator's instruction stream on the cycle simulator,
//! and accounts the scalar-core share of the complete application
//! (Table I). A thread-based sweep runner evaluates many (model,
//! precision, config) points in parallel.
//!
//! Execution itself is delegated to [`crate::engine`]: `run_model` here is
//! the one-shot wrapper; hold an [`Engine`] directly to amortize
//! compilation across repeated runs.

pub mod epilogue;
pub mod runner;

use crate::ara::{ara_cost, AraParams};
use crate::compiler::{MemLayout, MEM_MIN_BYTES};
use crate::config::{Precision, SpeedConfig};
use crate::engine::Engine;
use crate::error::SpeedError;
use crate::isa::StrategyKind;
use crate::models::zoo::Model;
use crate::models::OpDesc;
use crate::sim::SimStats;

/// Strategy selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// The paper's mixed dataflow: each operator uses its matched strategy.
    Mixed,
    /// Force one strategy for every applicable operator (ablation).
    Fixed(StrategyKind),
    /// Use an empirically tuned per-operator mapping
    /// ([`crate::tune::TunedPlan`]). The plan itself is attached to the
    /// executing [`Session`](crate::engine::Session) (or resolved from a
    /// pool's [`crate::tune::TunedPlans`] registry); operators without a
    /// tuned entry — and sessions with no plan attached — fall back to the
    /// static mixed mapping, so `Tuned` is always safe to request.
    Tuned,
    /// Like [`Policy::Tuned`], but the plan is produced *online* by the
    /// serve pool: the first request for an uncovered `(model, precision,
    /// config-signature)` triggers a tuning search on the owning worker
    /// (a *tune stall*), the plan is published to the pool's shared
    /// [`crate::tune::TunedPlans`] registry, and every later same-key
    /// request replays it (a *plan-registry hit*). Per-request statistics
    /// are identical whether a request stalled or hit — the stall is wall
    /// time, not simulated work. Outside a pool this behaves exactly like
    /// `Tuned`.
    TunedOnline,
}

impl Policy {
    /// Strategy for an operator under this policy (None = not applicable,
    /// the operator is skipped in ablation sweeps). For [`Policy::Tuned`]
    /// this is the static fallback; the session substitutes the tuned
    /// choice (strategy + chunk) when a plan is attached.
    pub fn strategy_for(&self, op: &OpDesc) -> Option<StrategyKind> {
        match self {
            Policy::Mixed | Policy::Tuned | Policy::TunedOnline => {
                Some(op.preferred_strategy())
            }
            Policy::Fixed(s) => crate::dataflow::applicable(*s, op).then_some(*s),
        }
    }
}

/// Per-layer outcome.
#[derive(Debug, Clone)]
pub struct LayerResult {
    /// The operator that ran.
    pub op: OpDesc,
    /// Strategy it ran under.
    pub strat: StrategyKind,
    /// Simulation statistics of the run.
    pub stats: SimStats,
}

/// Whole-model outcome on SPEED.
#[derive(Debug, Clone)]
pub struct ModelResult {
    /// Model name.
    pub name: String,
    /// Precision the model ran at.
    pub prec: Precision,
    /// Per-layer outcomes, in execution order.
    pub layers: Vec<LayerResult>,
    /// Merged vector-processor stats (cycles = Σ layer cycles).
    pub total: SimStats,
    /// Scalar-core cycles of the complete application (pooling, norms...).
    pub scalar_cycles: u64,
}

impl ModelResult {
    /// Vector-only cycles (the paper's "inference convolutional layers
    /// only" rows in Table I).
    pub fn vector_cycles(&self) -> u64 {
        self.total.cycles
    }

    /// Complete-application cycles (vector + scalar core).
    pub fn complete_cycles(&self) -> u64 {
        self.total.cycles + self.scalar_cycles
    }

    /// Whole-model MAC-ops per simulated cycle.
    pub fn ops_per_cycle(&self) -> f64 {
        self.total.ops_per_cycle()
    }

    /// Whole-model throughput at `freq_ghz`, in GOPS.
    pub fn gops(&self, freq_ghz: f64) -> f64 {
        self.total.gops(freq_ghz)
    }
}

/// External-memory bytes a model execution needs (largest operator under
/// the compiler's canonical placement — shared with [`MemLayout::place`],
/// so sizing and placement cannot drift).
pub fn mem_requirement(model: &Model) -> usize {
    model
        .ops
        .iter()
        .map(MemLayout::required_bytes)
        .fold(MEM_MIN_BYTES, u64::max) as usize
}

/// Run a model at a precision on a SPEED configuration.
///
/// One-shot convenience kept for the report harness and tests: builds a
/// throwaway [`Engine`] and runs a single session against it. Serving-style
/// repeated execution should hold an [`Engine`] instead — its program cache
/// makes the second and later passes compile nothing.
///
/// Timing/traffic simulation only (`functional = false`): numerics of every
/// operator class are certified separately against the AOT-compiled JAX
/// artifacts (see `runtime::golden` and the integration tests).
pub fn run_model(
    model: &Model,
    prec: Precision,
    cfg: &SpeedConfig,
    policy: Policy,
) -> Result<ModelResult, SpeedError> {
    let m = model.at_precision(prec);
    let mut engine = Engine::with_memory(*cfg, mem_requirement(&m))?;
    engine.session().with_policy(policy).run_model(model, prec)
}

/// Ara cost of the same model (official RVV baseline). 4-bit runs at
/// Ara's minimum SEW of 8 (no sub-byte support).
#[derive(Debug, Clone, Copy, Default)]
pub struct AraModelResult {
    /// Total Ara cycles over all layers.
    pub cycles: u64,
    /// Total DRAM traffic, bytes.
    pub dram_bytes: u64,
    /// Total RVV instructions issued.
    pub insns: u64,
}

/// Sum the Ara baseline cost model over every layer of `model` at `prec`.
pub fn run_model_ara(model: &Model, prec: Precision, params: &AraParams) -> AraModelResult {
    let m = model.at_precision(prec);
    let mut out = AraModelResult::default();
    for op in &m.ops {
        let c = ara_cost(op, params);
        out.cycles += c.cycles;
        out.dram_bytes += c.dram_total();
        out.insns += c.insns;
    }
    out
}

/// Ara complete-application cycles: the scalar-core share is the same
/// absolute work as on SPEED (both couple to an equivalent scalar core —
/// Table I adds ~equal scalar cycles to both columns).
pub fn ara_complete_cycles(ara: &AraModelResult, speed: &ModelResult) -> u64 {
    ara.cycles + speed.scalar_cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn tiny_model() -> Model {
        Model {
            name: "tiny",
            ops: vec![
                OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8),
                OpDesc::pwcv(8, 8, 10, 10, Precision::Int8),
                OpDesc::dwcv(8, 10, 10, 3, 1, 1, Precision::Int8),
                OpDesc::mm(10, 8, 12, Precision::Int8),
            ],
            scalar_fraction: 0.1,
        }
    }

    #[test]
    fn mixed_policy_assigns_matched_strategies() {
        let m = tiny_model();
        let r = run_model(&m, Precision::Int8, &SpeedConfig::reference(), Policy::Mixed)
            .unwrap();
        assert_eq!(r.layers.len(), 4);
        assert_eq!(r.layers[0].strat, StrategyKind::Ffcs);
        assert_eq!(r.layers[1].strat, StrategyKind::Cf);
        assert_eq!(r.layers[2].strat, StrategyKind::Ff);
        assert_eq!(r.layers[3].strat, StrategyKind::Mm);
        assert!(r.total.cycles > 0);
        assert_eq!(r.total.macs,
            m.ops.iter().map(|o| o.total_macs()).sum::<u64>());
        assert!(r.complete_cycles() > r.vector_cycles());
    }

    #[test]
    fn fixed_policy_skips_inapplicable() {
        let m = tiny_model();
        let r = run_model(&m, Precision::Int8, &SpeedConfig::reference(),
                          Policy::Fixed(StrategyKind::Cf)).unwrap();
        // CF applies to CONV and PWCV only (not DWCV, not MM).
        assert_eq!(r.layers.len(), 2);
    }

    #[test]
    fn lower_precision_is_faster() {
        let m = tiny_model();
        let cfg = SpeedConfig::reference();
        let c16 = run_model(&m, Precision::Int16, &cfg, Policy::Mixed).unwrap();
        let c8 = run_model(&m, Precision::Int8, &cfg, Policy::Mixed).unwrap();
        let c4 = run_model(&m, Precision::Int4, &cfg, Policy::Mixed).unwrap();
        assert!(c8.vector_cycles() < c16.vector_cycles(),
                "8b {} !< 16b {}", c8.vector_cycles(), c16.vector_cycles());
        assert!(c4.vector_cycles() < c8.vector_cycles());
    }

    #[test]
    fn speed_beats_ara_on_every_benchmark_model_precision() {
        // The headline claim of Fig. 12, on a reduced-size proxy: use the
        // tiny model to keep the test fast.
        let m = tiny_model();
        let cfg = SpeedConfig::reference();
        let params = AraParams::default();
        for prec in [Precision::Int16, Precision::Int8] {
            let s = run_model(&m, prec, &cfg, Policy::Mixed).unwrap();
            let a = run_model_ara(&m, prec, &params);
            assert!(a.cycles > s.vector_cycles(),
                    "{prec}: Ara {} !> SPEED {}", a.cycles, s.vector_cycles());
        }
    }

    #[test]
    fn mem_requirement_covers_all_models() {
        for name in zoo::MODELS {
            let m = zoo::model_by_name(name).unwrap();
            let need = mem_requirement(&m);
            for op in &m.ops {
                assert!(MemLayout::for_op(op, need).is_ok(), "{name} {op:?}");
            }
        }
    }
}
