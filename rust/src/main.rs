//! `repro` — the SPEED reproduction CLI (leader entrypoint).
//!
//! Subcommands map one-to-one onto the paper's evaluation:
//!
//! ```text
//! repro report <fig2|fig10|fig11|fig12|table1|table2|fig13|fig14|table3|all> [--quick]
//! repro golden [--artifacts DIR]        three-way golden checks via PJRT
//! repro run-model <name> [--prec N] [--policy mixed|ffcs|cf|ff] [--quick]
//! repro dse                              Fig. 14 sweep
//! repro asm <file.s>                     assemble / encode / disassemble
//! repro info                             configuration + artifact summary
//! ```
//!
//! (The deployment image vendors no argument-parsing crate; the parser is
//! a small hand-rolled positional/flag scanner — see DESIGN.md.)

use std::process::ExitCode;

use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::coordinator::{run_model, run_model_ara, Policy};
use speed_rvv::isa::{self, StrategyKind};
use speed_rvv::models::zoo::{model_by_name, MODELS};
use speed_rvv::report;
use speed_rvv::runtime::{golden_check_all, Engine};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn dispatch(args: &[String]) -> Result<(), String> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "report" => cmd_report(rest),
        "golden" => cmd_golden(rest),
        "run-model" => cmd_run_model(rest),
        "dse" => {
            let (text, _) = report::fig14();
            println!("{text}");
            Ok(())
        }
        "asm" => cmd_asm(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try `repro help`)")),
    }
}

const HELP: &str = "repro — SPEED (TVLSI'24) full-system reproduction
commands:
  report <id|all> [--quick]   regenerate a paper table/figure
                              ids: fig2 fig10 fig11 fig12 table1 table2
                                   fig13 fig14 table3
  golden [--artifacts DIR]    three-way golden checks (JAX == PJRT == sim)
  run-model <name> [--prec N] [--policy mixed|ffcs|cf|ff] [--quick]
                              names: vgg16 resnet18 googlenet mobilenetv2
                                     vit_tiny vit_b16
  dse                         Fig. 14 design-space sweep
  asm <file.s>                assemble, encode, and disassemble a program
  info                        configuration + artifact summary";

fn cmd_report(args: &[String]) -> Result<(), String> {
    let id = args.first().map(|s| s.as_str()).unwrap_or("all");
    let quick = flag(args, "--quick");
    let cfg = SpeedConfig::reference();
    let emit = |name: &str| -> Result<(), String> {
        let text = match name {
            "fig2" => report::fig2(),
            "fig10" => report::fig10(&cfg),
            "fig11" => report::fig11(&cfg, &report::fig11::DEFAULT_SIZES),
            "fig12" => report::fig12(&cfg, quick),
            "table1" => report::table1(&cfg, quick),
            "table2" => report::table2(),
            "fig13" => report::fig13(),
            "fig14" => report::fig14().0,
            "table3" => report::table3(),
            other => return Err(format!("unknown report id '{other}'")),
        };
        println!("{text}");
        Ok(())
    };
    if id == "all" {
        for name in
            ["fig2", "fig10", "fig11", "fig12", "table1", "table2", "fig13", "fig14", "table3"]
        {
            emit(name)?;
        }
        Ok(())
    } else {
        emit(id)
    }
}

fn cmd_golden(args: &[String]) -> Result<(), String> {
    let dir = std::path::PathBuf::from(opt(args, "--artifacts").unwrap_or("artifacts"));
    let mut engine = Engine::open(&dir).map_err(|e| e.to_string())?;
    let reports = golden_check_all(&mut engine, &dir).map_err(|e| e.to_string())?;
    let mut failed = 0;
    for r in &reports {
        let sim = match r.sim_ok {
            Some(true) => "sim ok",
            Some(false) => "sim FAIL",
            None => "sim n/a",
        };
        println!(
            "{:18} pjrt {} | {} ({} elems)",
            r.name,
            if r.pjrt_ok { "ok" } else { "FAIL" },
            sim,
            r.elems
        );
        if !r.ok() {
            failed += 1;
        }
    }
    if failed > 0 {
        return Err(format!("{failed} golden check(s) failed"));
    }
    println!("all {} golden checks passed", reports.len());
    Ok(())
}

fn cmd_run_model(args: &[String]) -> Result<(), String> {
    let name = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| format!("run-model needs a model name (one of {MODELS:?})"))?;
    let prec = match opt(args, "--prec").unwrap_or("8") {
        "16" => Precision::Int16,
        "8" => Precision::Int8,
        "4" => Precision::Int4,
        other => return Err(format!("bad precision '{other}'")),
    };
    let policy = match opt(args, "--policy").unwrap_or("mixed") {
        "mixed" => Policy::Mixed,
        "ffcs" => Policy::Fixed(StrategyKind::Ffcs),
        "cf" => Policy::Fixed(StrategyKind::Cf),
        "ff" => Policy::Fixed(StrategyKind::Ff),
        other => return Err(format!("bad policy '{other}'")),
    };
    let mut model =
        model_by_name(name).ok_or_else(|| format!("unknown model '{name}' ({MODELS:?})"))?;
    if flag(args, "--quick") {
        model = report::fig12::downscale(&model, 4);
    }
    let cfg = SpeedConfig::reference();
    let r = run_model(&model, prec, &cfg, policy)?;
    let ara = run_model_ara(&model, prec, &Default::default());
    println!("model {name} @ {prec} ({} vector ops)", r.layers.len());
    println!(
        "  SPEED: {} cycles ({:.2} ops/cycle, {:.1} GOPS @ {:.2} GHz)",
        r.vector_cycles(),
        r.ops_per_cycle(),
        r.gops(cfg.freq_ghz),
        cfg.freq_ghz
    );
    println!("  complete application: {} cycles", r.complete_cycles());
    println!(
        "  Ara: {} cycles  ->  speedup {:.2}x",
        ara.cycles,
        ara.cycles as f64 / r.vector_cycles() as f64
    );
    println!(
        "  DRAM traffic: SPEED {:.1} MiB vs Ara {:.1} MiB",
        r.total.traffic.total() as f64 / (1 << 20) as f64,
        ara.dram_bytes as f64 / (1 << 20) as f64
    );
    Ok(())
}

fn cmd_asm(args: &[String]) -> Result<(), String> {
    let path = args.first().ok_or("asm needs a file path")?;
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let prog = isa::assemble(&src).map_err(|e| e.to_string())?;
    for insn in &prog {
        let word = isa::encode(insn);
        println!("{word:08x}  {}", isa::disasm::disassemble(insn));
    }
    println!("# {} instructions", prog.len());
    Ok(())
}

fn cmd_info(_args: &[String]) -> Result<(), String> {
    let cfg = SpeedConfig::reference();
    let t3 = SpeedConfig::table3();
    println!("SPEED reference instance (Sec. IV-A):");
    println!(
        "  {} lanes x {}x{} MPTU, {} KiB VRF/lane, {:.2} GHz",
        cfg.lanes, cfg.tile_r, cfg.tile_c, cfg.vrf_kib, cfg.freq_ghz
    );
    for p in Precision::ALL {
        println!("  {p}: PP={} -> peak {:.1} GOPS", p.pp(), cfg.peak_gops(p));
    }
    println!(
        "Table III instance: {}x{} tiles -> peak {:.1} GOPS @4b",
        t3.tile_r,
        t3.tile_c,
        t3.peak_gops(Precision::Int4)
    );
    let area = speed_rvv::metrics::speed_area(&cfg);
    println!(
        "  area {:.2} mm² (lanes {:.0}%), power {:.0} mW",
        area.total(),
        100.0 * area.lane_fraction(),
        speed_rvv::metrics::speed_power(&cfg) * 1e3
    );
    if let Ok(engine) = Engine::open("artifacts") {
        println!("artifacts: {} compiled computations available", engine.manifest().len());
    } else {
        println!("artifacts: not built (run `make artifacts`)");
    }
    Ok(())
}
