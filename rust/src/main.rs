//! `repro` — the SPEED reproduction CLI (leader entrypoint).
//!
//! Subcommands map one-to-one onto the paper's evaluation:
//!
//! ```text
//! repro report <fig2|fig10|fig11|fig12|table1|table2|fig13|fig14|table3|all>
//!              [--quick] [--workers N]
//! repro golden [--artifacts DIR]        three-way golden checks via PJRT
//! repro run-model <name> [--prec 16|8|4|all] [--policy mixed|ffcs|cf|ff]
//!                 [--quick] [--workers N]
//! repro dse [--quick] [--workers N] [--tuned] [--out FILE]
//!                                       Fig. 14 sweep (± per-point tuning)
//! repro speed-bench [--quick] [--exact] [--out FILE] [--baseline FILE]
//!                   [--write-baseline FILE] [--tolerance F]
//!                                       perf harness -> BENCH_sim.json
//! repro serve-bench --scenario FILE [--workers N] [--quick] [--exact]
//!                   [--max-batch K] [--trace] [--out FILE]
//!                                       serving harness -> SERVE_bench.json
//! repro profile [--model M --prec P | --scenario F] [--quick]
//!               [--level op|segment|run|insn] [--out trace.json]
//!                                       deterministic profiler -> Chrome trace
//! repro verify [--model M --prec P | --all] [--strategy S] [--quick] [--json]
//!                                       static stream verification sweep
//! repro lint [--model M --prec P | --all] [--strategy S] [--quick] [--json]
//!                                       performance lint sweep (warnings)
//! repro asm <file.s>                    assemble / encode / disassemble
//! repro info                            configuration + artifact summary
//! ```
//!
//! `run-model` executes through the [`speed_rvv::engine`] API: one warm
//! `Engine` whose program cache persists across precisions, so `--prec all`
//! compiles each layer once per precision and switches the datapath with a
//! single-cycle `VSACFG`. `--workers N` feeds the sweep runner behind
//! `report`/`dse`, and with `run-model --prec all` it evaluates the
//! precisions concurrently (one engine per worker) instead of sharing the
//! warm cache (default: all cores but one).
//!
//! (The deployment image vendors no argument-parsing crate; the parser is
//! a small hand-rolled positional/flag scanner — see DESIGN.md.)

use std::process::ExitCode;
use std::sync::Arc;

use speed_rvv::analysis::lint::LintRule;
use speed_rvv::analysis::{self, Rule};
use speed_rvv::bench;
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::coordinator::runner::{default_workers, run_parallel};
use speed_rvv::coordinator::{run_model, run_model_ara, ModelResult, Policy};
use speed_rvv::engine::Engine;
use speed_rvv::error::SpeedError;
use speed_rvv::isa::{self, StrategyKind};
use speed_rvv::models::zoo::{model_by_name, MODELS};
use speed_rvv::models::OpDesc;
use speed_rvv::obs::{chrome_trace_json, Counter, ObsConfig, SpanCat, TraceLevel};
use speed_rvv::report;
use speed_rvv::runtime::json::jstr;
use speed_rvv::runtime::{golden_check_all, PjrtEngine};
use speed_rvv::serve;
use speed_rvv::sim::ExecMode;
use speed_rvv::tune::{self, TuneOptions, TunedPlan};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// `--workers N` (default: physical parallelism minus one).
fn workers_opt(args: &[String]) -> Result<usize, SpeedError> {
    match opt(args, "--workers") {
        None => Ok(default_workers()),
        Some(v) => v
            .parse::<usize>()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| SpeedError::Config(format!("bad --workers '{v}' (want N >= 1)"))),
    }
}

fn dispatch(args: &[String]) -> Result<(), SpeedError> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "report" => cmd_report(rest),
        "golden" => cmd_golden(rest),
        "run-model" => cmd_run_model(rest),
        "dse" => cmd_dse(rest),
        "speed-bench" => cmd_speed_bench(rest),
        "serve-bench" => cmd_serve_bench(rest),
        "profile" => cmd_profile(rest),
        "tune" => cmd_tune(rest),
        "verify" => cmd_verify(rest),
        "lint" => cmd_lint(rest),
        "asm" => cmd_asm(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(SpeedError::Config(format!(
            "unknown command '{other}' (try `repro help`)"
        ))),
    }
}

const HELP: &str = "repro — SPEED (TVLSI'24) full-system reproduction
commands:
  report <id|all> [--quick] [--workers N]
                              regenerate a paper table/figure
                              ids: fig2 fig10 fig11 fig12 table1 table2
                                   fig13 fig14 table3
  golden [--artifacts DIR]    three-way golden checks (JAX == PJRT == sim)
  run-model <name> [--prec 16|8|4|all] [--policy mixed|ffcs|cf|ff]
            [--quick] [--workers N]
                              run through the Engine/Session API
                              names: vgg16 resnet18 googlenet mobilenetv2
                                     vit_tiny vit_b16
  dse [--quick] [--workers N] [--tuned] [--out FILE]
                              Fig. 14 design-space sweep; --tuned runs a
                              per-point (strategy x chunk) mapping search
                              alongside the static Sec. III mapping,
                              reports both, verifies tuned <= static
                              cycles at every point (exit 1 on violation),
                              and --out writes the DSE_sweep.json artifact
  speed-bench [--quick] [--exact] [--out FILE] [--baseline FILE]
              [--write-baseline FILE] [--tolerance F]
                              run the perf harness; writes BENCH_sim.json
                              (ops/s, simulated-stages/s, wall time, cache
                              hit rates) and optionally gates against a
                              committed baseline (exit 1 on regression)
  serve-bench --scenario FILE [--workers N] [--quick] [--exact]
              [--max-batch K] [--tuned] [--trace] [--out FILE]
                              run a serving scenario (bench/scenarios/*.json)
                              through a ServePool; writes SERVE_bench.json
                              (throughput, p50/p95/p99 latency, queue depth,
                              cache hit rate, precision switches) and prints a
                              per-request stats digest that is identical for
                              any worker count / batching / --exact choice
                              (--tuned pre-tunes every model in the mix and
                              serves them from the tuned-plan registry; a
                              scenario mix entry with "policy":
                              "tuned_online" instead tunes online — the
                              first request for an uncovered model tunes on
                              its worker and publishes the plan, later
                              requests hit the shared registry; --trace
                              attaches per-worker tracers — observability
                              is inert, so the printed stats digest is
                              unchanged)
  profile [--model M --prec 16|8|4 | --scenario FILE] [--quick] [--exact]
          [--level op|segment|run|insn] [--out trace.json]
                              deterministic cycle profiler: run one model
                              (default mobilenetv2 @ INT8) or a serving
                              scenario with tracing attached, print the
                              cycle-attribution split, and write a
                              Chrome-trace/Perfetto JSON whose timestamps
                              are simulated cycles (virtual clock — the
                              trace is bit-reproducible run to run).
                              Exits nonzero if the op spans do not sum to
                              the simulated total (the self-check)
  tune [--model M] [--prec 16|8|4] [--quick] [--no-chunks] [--exact]
       [--prune] [--cache DIR] [--out FILE] [--no-verify]
                              empirical mixed-dataflow auto-tuner: search
                              (strategy x chunk) per operator with the
                              simulator as cost oracle; writes the plan JSON,
                              proves the JSON round-trip, bit-verifies parity
                              vs the static mapping, and exits nonzero if the
                              tuned plan is slower than static (it never is,
                              by construction). --cache DIR reuses
                              bench/tuned/-style plan files across runs;
                              --prune ranks candidates with the bit-exact
                              static cost model and simulates only potential
                              winners (same plan, fewer simulations)
  verify [--model M] [--prec 16|8|4|all] [--all] [--strategy mm|ffcs|cf|ff]
         [--quick] [--json]
                              static stream verifier: abstract-interpret
                              every compiled program (zoo x precisions x
                              feasible mapping candidates, no simulation),
                              print a per-rule violation table, and exit
                              nonzero on any diagnostic. Default sweeps
                              the whole zoo at all precisions; --quick
                              downscales the models for a fast smoke pass;
                              --json emits a machine-readable summary
  lint [--model M] [--prec 16|8|4|all] [--all] [--strategy mm|ffcs|cf|ff]
       [--quick] [--json]
                              performance linter: the same sweep as verify
                              but for L-* efficiency smells (dead defs,
                              redundant reloads/re-latches, split runs,
                              register pressure). Findings are warnings —
                              the exit code stays 0; --json emits the same
                              summary shape as verify --json for CI greps
  asm <file.s>                assemble, encode, and disassemble a program
  info                        configuration + artifact summary
run-model also accepts --exact (per-instruction simulation; the default
batch fast path is bit-exact, this is the escape hatch / parity oracle)
and --policy tuned (auto-tune the model per precision before running)";

fn cmd_report(args: &[String]) -> Result<(), SpeedError> {
    let id = args.first().map(|s| s.as_str()).unwrap_or("all");
    let quick = flag(args, "--quick");
    let workers = workers_opt(args)?;
    let cfg = SpeedConfig::reference();
    let emit = |name: &str| -> Result<(), SpeedError> {
        let text = match name {
            "fig2" => report::fig2(),
            "fig10" => report::fig10(&cfg),
            "fig11" => report::fig11(&cfg, &report::fig11::DEFAULT_SIZES),
            "fig12" => report::fig12_with(&cfg, quick, workers),
            "table1" => report::table1_with(&cfg, quick, workers),
            "table2" => report::table2(),
            "fig13" => report::fig13(),
            "fig14" => report::fig14_with(workers, quick).0,
            "table3" => report::table3(),
            other => {
                return Err(SpeedError::Config(format!("unknown report id '{other}'")))
            }
        };
        println!("{text}");
        Ok(())
    };
    if id == "all" {
        for name in
            ["fig2", "fig10", "fig11", "fig12", "table1", "table2", "fig13", "fig14", "table3"]
        {
            emit(name)?;
        }
        Ok(())
    } else {
        emit(id)
    }
}

fn cmd_golden(args: &[String]) -> Result<(), SpeedError> {
    let dir = std::path::PathBuf::from(opt(args, "--artifacts").unwrap_or("artifacts"));
    let mut engine = PjrtEngine::open(&dir)?;
    let reports = golden_check_all(&mut engine, &dir)?;
    let mut failed = 0;
    for r in &reports {
        let sim = match r.sim_ok {
            Some(true) => "sim ok",
            Some(false) => "sim FAIL",
            None => "sim n/a",
        };
        println!(
            "{:18} pjrt {} | {} ({} elems)",
            r.name,
            if r.pjrt_ok { "ok" } else { "FAIL" },
            sim,
            r.elems
        );
        if !r.ok() {
            failed += 1;
        }
    }
    if failed > 0 {
        return Err(SpeedError::Artifact(format!("{failed} golden check(s) failed")));
    }
    println!("all {} golden checks passed", reports.len());
    Ok(())
}

fn cmd_run_model(args: &[String]) -> Result<(), SpeedError> {
    let name = args.first().filter(|a| !a.starts_with("--")).ok_or_else(|| {
        SpeedError::Config(format!("run-model needs a model name (one of {MODELS:?})"))
    })?;
    let precs: Vec<Precision> = match opt(args, "--prec").unwrap_or("8") {
        "16" => vec![Precision::Int16],
        "8" => vec![Precision::Int8],
        "4" => vec![Precision::Int4],
        "all" => vec![Precision::Int16, Precision::Int8, Precision::Int4],
        other => return Err(SpeedError::Config(format!("bad precision '{other}'"))),
    };
    let policy = match opt(args, "--policy").unwrap_or("mixed") {
        "mixed" => Policy::Mixed,
        "ffcs" => Policy::Fixed(StrategyKind::Ffcs),
        "cf" => Policy::Fixed(StrategyKind::Cf),
        "ff" => Policy::Fixed(StrategyKind::Ff),
        "tuned" => Policy::Tuned,
        other => return Err(SpeedError::Config(format!("bad policy '{other}'"))),
    };
    let mut model = model_by_name(name).ok_or_else(|| {
        SpeedError::Config(format!("unknown model '{name}' ({MODELS:?})"))
    })?;
    if flag(args, "--quick") {
        model = report::fig12::downscale(&model, 4);
    }
    let workers = workers_opt(args)?;
    let cfg = SpeedConfig::reference();
    let print_result = |prec: Precision, r: &ModelResult| {
        let ara = run_model_ara(&model, prec, &Default::default());
        println!("model {name} @ {prec} ({} vector ops)", r.layers.len());
        println!(
            "  SPEED: {} cycles ({:.2} ops/cycle, {:.1} GOPS @ {:.2} GHz)",
            r.vector_cycles(),
            r.ops_per_cycle(),
            r.gops(cfg.freq_ghz),
            cfg.freq_ghz
        );
        println!("  complete application: {} cycles", r.complete_cycles());
        println!(
            "  Ara: {} cycles  ->  speedup {:.2}x",
            ara.cycles,
            ara.cycles as f64 / r.vector_cycles() as f64
        );
        println!(
            "  DRAM traffic: SPEED {:.1} MiB vs Ara {:.1} MiB",
            r.total.traffic.total() as f64 / (1 << 20) as f64,
            ara.dram_bytes as f64 / (1 << 20) as f64
        );
    };
    if precs.len() > 1 && workers > 1 && !flag(args, "--exact") && policy != Policy::Tuned {
        // Parallel sweep: one throwaway engine per precision on the sweep
        // runner (trades the shared warm cache for wall-clock time).
        // (--exact forces the single warm engine below, which owns the
        // execution-mode switch.)
        let results = run_parallel(precs.clone(), workers, |&prec| {
            run_model(&model, prec, &cfg, policy).map(|r| (prec, r))
        });
        for res in results {
            let (prec, r) = res?;
            print_result(prec, &r);
        }
        println!("(parallel sweep: {workers} workers, one engine per precision)");
        return Ok(());
    }
    // One warm engine for every precision: layers compile once, the
    // datapath re-precisions with a single-cycle VSACFG per transition.
    let mut engine = Engine::new(cfg)?;
    if flag(args, "--exact") {
        engine.set_exec_mode(ExecMode::Exact);
    }
    let switches_base = engine.precision_switches();
    let mut results = Vec::new();
    if policy == Policy::Tuned {
        // Tuned plans are per-precision: tune each point first, then run
        // the model under its plan on the same warm engine.
        let topts = TuneOptions {
            exec_mode: engine.exec_mode(),
            ..Default::default()
        };
        for &prec in &precs {
            let plan = tune::tune_model(&cfg, &model, prec, &topts)?;
            println!(
                "tuned {name} @ {prec}: {}/{} ops retuned, plan speedup {:.3}x",
                plan.improved_ops(),
                plan.ops.len(),
                plan.speedup()
            );
            let r = engine
                .session()
                .with_tuned_plan(Arc::new(plan))
                .run_model(&model, prec)?;
            results.push((prec, r));
        }
    } else {
        let mut session = engine.session().with_policy(policy);
        for &prec in &precs {
            results.push((prec, session.run_model(&model, prec)?));
        }
    }
    let switches = engine.precision_switches() - switches_base;
    for (prec, r) in &results {
        print_result(*prec, r);
    }
    let cache = engine.cache_stats();
    println!(
        "engine: {} compiled programs, {} cache hits / {} misses, \
         {switches} precision switch(es)",
        engine.compiled_programs(),
        cache.hits,
        cache.misses
    );
    Ok(())
}

fn cmd_dse(args: &[String]) -> Result<(), SpeedError> {
    let workers = workers_opt(args)?;
    let quick = flag(args, "--quick");
    let tuned = flag(args, "--tuned");
    let (text, points) = report::fig14_tuned_with(workers, quick, tuned);
    println!("{text}");
    if tuned {
        // The acceptance gate: ties resolve to static inside the tuner,
        // so a point where tuned costs more cycles is a defect and must
        // fail the run (and the tune-smoke CI leg).
        for p in &points {
            let t = p.tuned.expect("tuned sweep fills every point");
            if t.cycles > p.static_cycles {
                return Err(SpeedError::Bench(format!(
                    "DSE point {}L {}x{}: tuned {} cycles > static {}",
                    p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c, t.cycles, p.static_cycles
                )));
            }
        }
        println!(
            "tuned <= static cycles verified at all {} DSE points",
            points.len()
        );
    }
    if let Some(out) = opt(args, "--out") {
        std::fs::write(out, speed_rvv::dse::sweep_json(&points, quick))
            .map_err(|e| SpeedError::Bench(format!("writing {out}: {e}")))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_speed_bench(args: &[String]) -> Result<(), SpeedError> {
    let opts = bench::BenchOptions {
        quick: flag(args, "--quick"),
        exact_only: flag(args, "--exact"),
    };
    // None = flag absent; an explicit flag overrides the baseline file's
    // embedded tolerance in `check_baseline`.
    let tolerance: Option<f64> = match opt(args, "--tolerance") {
        None => None,
        Some(v) => Some(
            v.parse::<f64>()
                .ok()
                .filter(|t| (0.0..1.0).contains(t))
                .ok_or_else(|| {
                    SpeedError::Config(format!("bad --tolerance '{v}' (want 0.0 <= F < 1.0)"))
                })?,
        ),
    };
    let report = bench::run_bench(&opts)?;
    print!("{}", report.summary_text());

    let out = opt(args, "--out").unwrap_or("BENCH_sim.json");
    std::fs::write(out, report.to_json())
        .map_err(|e| SpeedError::Bench(format!("writing {out}: {e}")))?;
    println!("wrote {out}");

    if let Some(path) = opt(args, "--write-baseline") {
        // Commit floors at half the measured throughput so slower CI
        // runners don't flap the gate.
        std::fs::write(path, report.baseline_json(tolerance.unwrap_or(0.2), 0.5))
            .map_err(|e| SpeedError::Bench(format!("writing {path}: {e}")))?;
        println!("wrote baseline {path}");
    }

    if let Some(path) = opt(args, "--baseline") {
        let src = std::fs::read_to_string(path)
            .map_err(|e| SpeedError::Bench(format!("reading {path}: {e}")))?;
        bench::check_baseline(&report, &src, tolerance)?;
        println!("baseline check passed ({path})");
    }
    Ok(())
}

fn cmd_serve_bench(args: &[String]) -> Result<(), SpeedError> {
    let scenario_path = opt(args, "--scenario").ok_or_else(|| {
        SpeedError::Config(
            "serve-bench needs --scenario FILE (see bench/scenarios/)".into(),
        )
    })?;
    let scenario = serve::Scenario::load(scenario_path)?;
    // Defaults (worker count included) live in ServeBenchOptions::default;
    // the CLI only overrides what was passed.
    let mut opts = serve::ServeBenchOptions {
        quick: flag(args, "--quick"),
        exact: flag(args, "--exact"),
        tuned: flag(args, "--tuned"),
        ..Default::default()
    };
    if flag(args, "--trace") {
        // Attach per-worker tracers. Observability is inert by contract:
        // the per-request stats digest printed below is bit-identical
        // with or without this flag (the CI obs-smoke leg checks that).
        opts.obs = ObsConfig::tracing(TraceLevel::Op);
    }
    if let Some(v) = opt(args, "--workers") {
        opts.workers = v
            .parse::<usize>()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| SpeedError::Config(format!("bad --workers '{v}' (want N >= 1)")))?;
    }
    if let Some(v) = opt(args, "--max-batch") {
        opts.max_batch = Some(
            v.parse::<usize>().ok().filter(|&n| n >= 1).ok_or_else(|| {
                SpeedError::Config(format!("bad --max-batch '{v}' (want K >= 1)"))
            })?,
        );
    }
    let report = serve::run_serve_bench(&scenario, &opts)?;
    print!("{}", report.summary_text());
    let out = opt(args, "--out").unwrap_or("SERVE_bench.json");
    // Bench-harness failure class, matching cmd_speed_bench: an unwritable
    // report path is not a serving overload.
    std::fs::write(out, report.to_json())
        .map_err(|e| SpeedError::Bench(format!("writing {out}: {e}")))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_profile(args: &[String]) -> Result<(), SpeedError> {
    let level = match opt(args, "--level") {
        None => TraceLevel::Run,
        Some(v) => TraceLevel::parse(v).ok_or_else(|| {
            SpeedError::Config(format!("bad --level '{v}' (want op|segment|run|insn)"))
        })?,
    };
    let out = opt(args, "--out").unwrap_or("trace.json");

    let (spans, counters, breakdown) = if let Some(path) = opt(args, "--scenario") {
        // Serving-scenario profile: per-worker tracers through the pool.
        let scenario = serve::Scenario::load(path)?;
        let opts = serve::ServeBenchOptions {
            quick: flag(args, "--quick"),
            exact: flag(args, "--exact"),
            obs: ObsConfig::tracing(level),
            ..Default::default()
        };
        let (report, spans) = serve::run_serve_bench_traced(&scenario, &opts)?;
        print!("{}", report.summary_text());
        if spans.is_empty() {
            return Err(SpeedError::Obs("scenario profile produced no spans".into()));
        }
        // Request spans cover executed batches (coalesced requests share
        // one execution, and online tune searches run between spans), so
        // the exactness bound here is one-sided: span time can never
        // exceed the cycles the worker engines actually simulated.
        let req_sum: u64 = spans
            .iter()
            .filter(|s| s.cat == SpanCat::Request)
            .map(|s| s.dur)
            .sum();
        let simulated = report.snapshot.breakdown.total();
        if report.snapshot.counter("trace_spans_dropped") == 0 && req_sum > simulated {
            return Err(SpeedError::Obs(format!(
                "request spans sum to {req_sum} cycles, workers simulated only {simulated}"
            )));
        }
        (spans, report.snapshot.counters.clone(), report.snapshot.breakdown)
    } else {
        // Single-model profile (default): one warm traced engine.
        let name = opt(args, "--model").unwrap_or("mobilenetv2");
        let prec = match opt(args, "--prec").unwrap_or("8") {
            "16" => Precision::Int16,
            "8" => Precision::Int8,
            "4" => Precision::Int4,
            other => return Err(SpeedError::Config(format!("bad precision '{other}'"))),
        };
        let mut model = model_by_name(name).ok_or_else(|| {
            SpeedError::Config(format!("unknown model '{name}' ({MODELS:?})"))
        })?;
        if flag(args, "--quick") {
            model = report::fig12::downscale(&model, 4);
        }
        let mut engine = Engine::new(SpeedConfig::reference())?;
        if flag(args, "--exact") {
            engine.set_exec_mode(ExecMode::Exact);
        }
        engine.set_obs(ObsConfig { trace: Some(level), capacity: 0, echo_insns: false });
        let r = engine.session().run_model(&model, prec)?;
        let breakdown = engine.breakdown();
        let tracer = engine.tracer().expect("profile always attaches a tracer");
        let dropped = tracer.dropped();
        let spans = tracer.take_spans();
        println!(
            "profile {name} @ {prec}: {} vector ops, {} simulated cycles, {} spans",
            r.layers.len(),
            r.total.cycles,
            spans.len()
        );
        // The self-check behind the trace's exactness claim: op spans
        // partition the simulated timeline, so their durations must sum
        // to the simulator's own cycle count (unless the ring dropped
        // early spans under `--level insn` on a large model).
        let op_sum: u64 =
            spans.iter().filter(|s| s.cat == SpanCat::Op).map(|s| s.dur).sum();
        if dropped == 0 && op_sum != r.total.cycles {
            return Err(SpeedError::Obs(format!(
                "op spans sum to {op_sum} cycles, simulator reports {} — trace is not exact",
                r.total.cycles
            )));
        }
        if breakdown.total() != r.total.cycles {
            return Err(SpeedError::Obs(format!(
                "cycle breakdown sums to {} of {} simulated cycles",
                breakdown.total(),
                r.total.cycles
            )));
        }
        engine.counters().add(Counter::TraceSpansDropped, dropped);
        (spans, engine.counters().snapshot(), breakdown)
    };

    println!("cycle split: {}", breakdown.summary_line());
    std::fs::write(out, chrome_trace_json(&spans, &counters))
        .map_err(|e| SpeedError::Obs(format!("writing {out}: {e}")))?;
    println!("wrote {out} ({} spans, virtual-cycle clock)", spans.len());
    Ok(())
}

/// Functional parity checks are O(MACs); above this per-operator bound
/// the CLI reports the check as skipped instead of grinding (downscaled
/// `--quick` models stay far below it).
const TUNE_VERIFY_MAC_LIMIT: u64 = 1 << 25;

fn cmd_tune(args: &[String]) -> Result<(), SpeedError> {
    let name = opt(args, "--model").unwrap_or("mobilenetv2");
    let prec = match opt(args, "--prec").unwrap_or("8") {
        "16" => Precision::Int16,
        "8" => Precision::Int8,
        "4" => Precision::Int4,
        other => return Err(SpeedError::Config(format!("bad precision '{other}'"))),
    };
    let mut model = model_by_name(name).ok_or_else(|| {
        SpeedError::Config(format!("unknown model '{name}' ({MODELS:?})"))
    })?;
    if flag(args, "--quick") {
        model = report::fig12::downscale(&model, 4);
    }
    let cfg = SpeedConfig::reference();
    let topts = TuneOptions {
        chunks: !flag(args, "--no-chunks"),
        exec_mode: if flag(args, "--exact") { ExecMode::Exact } else { ExecMode::Batch },
        prune: flag(args, "--prune"),
    };

    let t0 = std::time::Instant::now();
    // The cache-less path tunes on a local engine so the search's counter
    // registry (candidates simulated vs pruned) is reportable below.
    let mut tune_engine = Engine::new(cfg)?;
    tune_engine.set_exec_mode(topts.exec_mode);
    let (plan, cached) = match opt(args, "--cache") {
        Some(dir) => tune::tune_model_cached(&cfg, &model, prec, &topts, dir)?,
        None => (tune::tune_model_on(&mut tune_engine, &model, prec, &topts)?, false),
    };
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "tune {name} @ {prec} ({} distinct ops, {} candidates/op max{}, {:.2} s{})",
        plan.ops.len(),
        plan.ops.iter().map(|t| t.candidates).max().unwrap_or(0),
        if topts.chunks { "" } else { ", strategies only" },
        wall,
        if cached { ", from cache" } else { "" }
    );
    for t in &plan.ops {
        let marker = if t.improved() { "*" } else { " " };
        println!(
            " {marker} {:5} {:28} {:>10} cycles {}  (static {} {} cycles)",
            t.op.kind.to_string(),
            format!(
                "c{} f{} {}x{} k{} / m{} k{} n{}",
                t.op.c, t.op.f, t.op.h, t.op.w, t.op.ksize, t.op.m, t.op.k, t.op.n
            ),
            t.cycles,
            t.choice,
            t.static_choice,
            t.static_cycles,
        );
    }
    println!(
        "plan: {} of {} ops retuned; sim cycles {} -> {} ({:.3}x)",
        plan.improved_ops(),
        plan.ops.len(),
        plan.static_cycles(),
        plan.tuned_cycles(),
        plan.speedup()
    );
    if opt(args, "--cache").is_none() {
        // Machine-greppable search-effort line (the tune-smoke CI leg
        // checks tune_candidates_pruned > 0 under --prune and
        // tune_candidates_spilled_ff > 0 on shapes that spill under FF).
        let c = tune_engine.counters();
        println!(
            "search: tune_candidates={} tune_candidates_spilled_ff={} tune_candidates_pruned={}",
            c.get(Counter::TuneCandidates),
            c.get(Counter::TuneCandidatesSpilledFf),
            c.get(Counter::TuneCandidatesPruned)
        );
    }

    // Invariant gate: ties resolve to static, so tuned can never be
    // slower. A violation is a tuner defect and must fail the run (and
    // the tune-smoke CI job).
    if plan.tuned_cycles() > plan.static_cycles() {
        return Err(SpeedError::Bench(format!(
            "tuned plan slower than static: {} > {} cycles",
            plan.tuned_cycles(),
            plan.static_cycles()
        )));
    }

    // The JSON representation must round-trip exactly — the plan cache is
    // only trustworthy if load(save(plan)) == plan.
    let back = TunedPlan::from_json(&plan.to_json())?;
    if back != plan {
        return Err(SpeedError::Bench(
            "tuned plan JSON round-trip mismatch".into(),
        ));
    }
    println!("plan JSON round-trip ok ({} ops)", back.ops.len());

    if !flag(args, "--no-verify") {
        let (verified, skipped) =
            tune::verify_plan(&cfg, &plan, TUNE_VERIFY_MAC_LIMIT)?;
        println!(
            "parity: {verified} retuned op(s) bit-identical to static\
             {}",
            if skipped > 0 {
                format!(" ({skipped} skipped above the functional-check MAC bound)")
            } else {
                String::new()
            }
        );
    }

    let out = opt(args, "--out").unwrap_or("TUNED_plan.json");
    std::fs::write(out, plan.to_json())
        .map_err(|e| SpeedError::Bench(format!("writing {out}: {e}")))?;
    println!("wrote {out}");
    Ok(())
}

/// The shared machine-readable summary of an analysis sweep — `repro
/// verify --json` and `repro lint --json` emit one shape, so CI greps
/// both passes identically (`"clean": true`, `"findings": 0`).
fn analysis_json(
    pass: &str,
    programs: u64,
    insns: u64,
    segments: u64,
    rules: &[(&'static str, u64)],
) -> String {
    let total: u64 = rules.iter().map(|(_, n)| *n).sum();
    let mut s = String::from("{\n  \"schema\": 1,\n");
    s.push_str(&format!("  \"pass\": {},\n", jstr(pass)));
    s.push_str(&format!("  \"programs\": {programs},\n"));
    s.push_str(&format!("  \"insns\": {insns},\n"));
    s.push_str(&format!("  \"segments\": {segments},\n"));
    s.push_str(&format!("  \"findings\": {total},\n"));
    s.push_str(&format!("  \"clean\": {},\n", total == 0));
    s.push_str("  \"rules\": {\n");
    for (i, (id, n)) in rules.iter().enumerate() {
        s.push_str(&format!(
            "    {}: {n}{}\n",
            jstr(id),
            if i + 1 < rules.len() { "," } else { "" }
        ));
    }
    s.push_str("  }\n}\n");
    s
}

/// `--prec` selector shared by the analysis sweeps (default: all three).
fn precs_opt(args: &[String]) -> Result<Vec<Precision>, SpeedError> {
    match opt(args, "--prec").unwrap_or("all") {
        "16" => Ok(vec![Precision::Int16]),
        "8" => Ok(vec![Precision::Int8]),
        "4" => Ok(vec![Precision::Int4]),
        "all" => Ok(vec![Precision::Int16, Precision::Int8, Precision::Int4]),
        other => Err(SpeedError::Config(format!("bad precision '{other}'"))),
    }
}

/// `--strategy` filter shared by the analysis sweeps.
fn strat_filter_opt(args: &[String]) -> Result<Option<StrategyKind>, SpeedError> {
    match opt(args, "--strategy") {
        None => Ok(None),
        Some("mm") => Ok(Some(StrategyKind::Mm)),
        Some("ffcs") => Ok(Some(StrategyKind::Ffcs)),
        Some("cf") => Ok(Some(StrategyKind::Cf)),
        Some("ff") => Ok(Some(StrategyKind::Ff)),
        Some(other) => Err(SpeedError::Config(format!("bad strategy '{other}'"))),
    }
}

fn cmd_verify(args: &[String]) -> Result<(), SpeedError> {
    let names: Vec<&str> = match opt(args, "--model") {
        Some(n) => vec![n],
        // `--all` (and the bare default) sweep the whole zoo.
        None => MODELS.to_vec(),
    };
    let precs = precs_opt(args)?;
    let strat_filter = strat_filter_opt(args)?;
    let quick = flag(args, "--quick");
    let json = flag(args, "--json");
    let cfg = SpeedConfig::reference();
    let topts = TuneOptions::default(); // full (strategy x chunk) candidate space

    let mut rule_totals = [0u64; Rule::ALL.len()];
    let (mut programs, mut insns, mut segments) = (0u64, 0u64, 0u64);
    let mut failures: Vec<String> = Vec::new();
    let t0 = std::time::Instant::now();
    for name in &names {
        let mut model = model_by_name(name).ok_or_else(|| {
            SpeedError::Config(format!("unknown model '{name}' ({MODELS:?})"))
        })?;
        if quick {
            model = report::fig12::downscale(&model, 4);
        }
        for &prec in &precs {
            let m = model.at_precision(prec);
            let mut seen: Vec<OpDesc> = Vec::new();
            for op in &m.ops {
                if seen.contains(op) {
                    continue;
                }
                seen.push(*op);
                for choice in tune::candidates_for(op, &cfg, &topts) {
                    if strat_filter.is_some_and(|s| choice.strat != s) {
                        continue;
                    }
                    // Streams the program through the abstract interpreter;
                    // nothing is simulated and nothing is cached.
                    let rep = analysis::verify_op(op, &cfg, choice)?;
                    programs += 1;
                    insns += rep.insns;
                    segments += rep.segments as u64;
                    for (t, c) in rule_totals.iter_mut().zip(rep.rule_counts) {
                        *t += c;
                    }
                    if !rep.is_clean() && failures.len() < 32 {
                        for d in rep.diagnostics.iter().take(3) {
                            failures.push(format!("{name} @ {prec} {choice}: {d}"));
                        }
                    }
                }
            }
        }
    }
    if json {
        let rules: Vec<(&'static str, u64)> =
            Rule::ALL.iter().zip(&rule_totals).map(|(r, &n)| (r.id(), n)).collect();
        print!("{}", analysis_json("verify", programs, insns, segments, &rules));
    } else {
        println!(
            "verified {programs} compiled program(s): {insns} instructions in \
             {segments} segments, {} model(s) x {} precision(s), {:.2} s",
            names.len(),
            precs.len(),
            t0.elapsed().as_secs_f64()
        );
        println!("  {:<10} {:>9}  invariant", "rule", "hits");
        for (rule, &n) in Rule::ALL.iter().zip(&rule_totals) {
            println!("  {:<10} {n:>9}  {}", rule.id(), rule.summary());
        }
    }
    let total: u64 = rule_totals.iter().sum();
    if total > 0 {
        for f in &failures {
            eprintln!("  {f}");
        }
        return Err(SpeedError::Verify(format!(
            "{total} violation(s) across {programs} program(s)"
        )));
    }
    if !json {
        println!("all {programs} programs verifier-clean");
    }
    Ok(())
}

fn cmd_lint(args: &[String]) -> Result<(), SpeedError> {
    let names: Vec<&str> = match opt(args, "--model") {
        Some(n) => vec![n],
        // `--all` (and the bare default) sweep the whole zoo.
        None => MODELS.to_vec(),
    };
    let precs = precs_opt(args)?;
    let strat_filter = strat_filter_opt(args)?;
    let quick = flag(args, "--quick");
    let json = flag(args, "--json");
    let cfg = SpeedConfig::reference();
    let topts = TuneOptions::default(); // full (strategy x chunk) candidate space

    let mut rule_totals = [0u64; LintRule::ALL.len()];
    let (mut programs, mut insns, mut segments) = (0u64, 0u64, 0u64);
    let mut samples: Vec<String> = Vec::new();
    let t0 = std::time::Instant::now();
    for name in &names {
        let mut model = model_by_name(name).ok_or_else(|| {
            SpeedError::Config(format!("unknown model '{name}' ({MODELS:?})"))
        })?;
        if quick {
            model = report::fig12::downscale(&model, 4);
        }
        for &prec in &precs {
            let m = model.at_precision(prec);
            let mut seen: Vec<OpDesc> = Vec::new();
            for op in &m.ops {
                if seen.contains(op) {
                    continue;
                }
                seen.push(*op);
                for choice in tune::candidates_for(op, &cfg, &topts) {
                    if strat_filter.is_some_and(|s| choice.strat != s) {
                        continue;
                    }
                    // Streams the program through the linter; nothing is
                    // simulated and nothing is cached.
                    let rep = analysis::lint::lint_op(op, &cfg, choice)?;
                    programs += 1;
                    insns += rep.insns;
                    segments += rep.segments as u64;
                    for (t, c) in rule_totals.iter_mut().zip(rep.rule_counts) {
                        *t += c;
                    }
                    if !rep.is_clean() && samples.len() < 32 {
                        for f in rep.findings.iter().take(3) {
                            samples.push(format!("{name} @ {prec} {choice}: {f}"));
                        }
                    }
                }
            }
        }
    }
    let total: u64 = rule_totals.iter().sum();
    if json {
        let rules: Vec<(&'static str, u64)> =
            LintRule::ALL.iter().zip(&rule_totals).map(|(r, &n)| (r.id(), n)).collect();
        print!("{}", analysis_json("lint", programs, insns, segments, &rules));
    } else {
        println!(
            "linted {programs} compiled program(s): {insns} instructions in \
             {segments} segments, {} model(s) x {} precision(s), {:.2} s",
            names.len(),
            precs.len(),
            t0.elapsed().as_secs_f64()
        );
        println!("  {:<10} {:>9}  smell", "rule", "hits");
        for (rule, &n) in LintRule::ALL.iter().zip(&rule_totals) {
            println!("  {:<10} {n:>9}  {}", rule.id(), rule.summary());
        }
        if total == 0 {
            println!("all {programs} programs lint-clean");
        } else {
            println!(
                "{total} warning(s) across {programs} program(s) — advisory only"
            );
        }
    }
    // Warnings never fail the run; samples go to stderr for humans.
    for s in &samples {
        eprintln!("  {s}");
    }
    Ok(())
}

fn cmd_asm(args: &[String]) -> Result<(), SpeedError> {
    let path = args
        .first()
        .ok_or_else(|| SpeedError::Config("asm needs a file path".into()))?;
    let src = std::fs::read_to_string(path)
        .map_err(|e| SpeedError::Parse(format!("{path}: {e}")))?;
    let prog = isa::assemble(&src)?;
    for insn in &prog {
        let word = isa::encode(insn);
        println!("{word:08x}  {}", isa::disasm::disassemble(insn));
    }
    println!("# {} instructions", prog.len());
    Ok(())
}

fn cmd_info(_args: &[String]) -> Result<(), SpeedError> {
    let cfg = SpeedConfig::reference();
    let t3 = SpeedConfig::table3();
    println!("SPEED reference instance (Sec. IV-A):");
    println!(
        "  {} lanes x {}x{} MPTU, {} KiB VRF/lane, {:.2} GHz",
        cfg.lanes, cfg.tile_r, cfg.tile_c, cfg.vrf_kib, cfg.freq_ghz
    );
    for p in Precision::ALL {
        println!("  {p}: PP={} -> peak {:.1} GOPS", p.pp(), cfg.peak_gops(p));
    }
    println!(
        "Table III instance: {}x{} tiles -> peak {:.1} GOPS @4b",
        t3.tile_r,
        t3.tile_c,
        t3.peak_gops(Precision::Int4)
    );
    let area = speed_rvv::metrics::speed_area(&cfg);
    println!(
        "  area {:.2} mm² (lanes {:.0}%), power {:.0} mW",
        area.total(),
        100.0 * area.lane_fraction(),
        speed_rvv::metrics::speed_power(&cfg) * 1e3
    );
    if let Ok(engine) = PjrtEngine::open("artifacts") {
        println!("artifacts: {} compiled computations available", engine.manifest().len());
    } else {
        println!("artifacts: not built (run `make artifacts`)");
    }
    Ok(())
}
