//! `speed_rvv::tune` — the empirical mixed-dataflow auto-tuner.
//!
//! The paper's Sec. III assigns each operator class a fixed strategy (MM /
//! FFCS / CF / FF — [`OpDesc::preferred_strategy`]). That static table is
//! right *on average*, but the best mapping shifts with layer shape and
//! precision: a CONV whose feature map dwarfs the VRF pays FFCS's
//! per-feature-map-block weight refetch on every block, while FF keeps all
//! weights resident and streams them exactly once. Instead of extending
//! the analytic table, this module measures: it enumerates every
//! *feasible* `(strategy × chunk)` mapping candidate
//! ([`dataflow::feasible`] — the applicability matrix; FF mappings whose
//! weight slice spills the VRF stay in the set and are costed with their
//! honest per-row refetch runs rather than rejected — with
//! [`dataflow::chunk_candidates`] on the
//! reduction/channel axis and [`dataflow::jchunk_candidates`] on the MM
//! B-tile column axis), costs each one on the fast-path cycle simulator
//! ([`ExecMode::Batch`] — bit-exact vs per-instruction mode, so the
//! oracle is the machine itself), and records the winner per operator in
//! a [`TunedPlan`].
//!
//! Beyond the per-operator argmax, [`tune_model_on`] runs a model-level
//! chain pass: where layer N's output can stay VRF-resident and feed
//! layer N+1 directly ([`dataflow::carries_residency`]), the carried
//! mapping ([`MappingChoice::carry_in`]) is gated on the bit-exact static
//! cost model, verified, then confirmed with a quiesced measurement, and
//! recorded positionally in [`TunedPlan::chain`] — the drain/reload
//! round-trip through DRAM drops out. The pass only ever accepts strict
//! improvements over the per-op winner, so the model-level plan is never
//! worse than the per-op plan, and it is independent of
//! [`TuneOptions::prune`], so pruned and full searches emit identical
//! chains.
//!
//! Tuning is **semantics-preserving by construction**: strategies and
//! chunk sizes only reorder/partition the same arithmetic, so every
//! candidate produces bit-identical output memory ([`verify_choice`]
//! checks this end to end; `tests/tune_parity.rs` holds it across random
//! shapes and every precision). The tuner only ever *re-labels* work — it
//! never changes what is computed.
//!
//! A plan persists as JSON (`bench/tuned/<model>@intN-<digest>.json`,
//! where the digest identifies the shape variant so quick/downscaled and
//! full-size plans coexist; [`TunedPlan::save`]/[`TunedPlan::load`]) and
//! pools share plans through
//! the [`TunedPlans`] registry the same way engines share compiled
//! programs through `SharedPrograms`. Selection falls back to the static
//! mixed mapping for any operator without a tuned entry, so a stale or
//! partial plan can never make a request fail — at worst it runs at the
//! static mapping's speed.
//!
//! Ties go to the static mapping: a [`TunedPlan`] deviates from Sec. III
//! only where the simulator shows strictly fewer cycles (then strictly
//! less DRAM traffic as the tiebreak), which makes "tuned is never slower
//! than static" an invariant rather than an aspiration.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::config::{Precision, SpeedConfig};
use crate::dataflow::{self, MappingChoice};
use crate::engine::Engine;
use crate::error::{Result, SpeedError};
use crate::isa::StrategyKind;
use crate::models::ops::{OpDesc, OpKind};
use crate::models::zoo::Model;
use crate::obs::Counter;
use crate::runtime::json::{jopt, jstr, parse, Fnv64, Json};
use crate::sim::ExecMode;

fn perr(m: impl Into<String>) -> SpeedError {
    SpeedError::Parse(m.into())
}

/// The configuration fields that shape generated code — the part of a
/// [`SpeedConfig`] a tuned plan is valid for (frequency and memory timing
/// scale costs uniformly and do not change the argmax).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TunedConfigSig {
    /// Number of vector lanes.
    pub lanes: u32,
    /// MPTU tile rows per lane.
    pub tile_r: u32,
    /// MPTU tile columns per lane.
    pub tile_c: u32,
    /// VRF capacity per lane, KiB.
    pub vrf_kib: u32,
}

impl TunedConfigSig {
    /// The code-shaping signature of `cfg`.
    pub fn of(cfg: &SpeedConfig) -> Self {
        TunedConfigSig {
            lanes: cfg.lanes,
            tile_r: cfg.tile_r,
            tile_c: cfg.tile_c,
            vrf_kib: cfg.vrf_kib,
        }
    }

    /// A full configuration carrying this signature's code-shaping fields
    /// (timing fields from the reference instance). Mapping feasibility —
    /// [`dataflow::feasible`] — depends only on the signature fields, so
    /// this is sufficient to validate a plan document's entries.
    fn as_config(&self) -> SpeedConfig {
        SpeedConfig {
            lanes: self.lanes,
            tile_r: self.tile_r,
            tile_c: self.tile_c,
            vrf_kib: self.vrf_kib,
            ..SpeedConfig::reference()
        }
    }
}

/// One operator's tuning outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpTuning {
    /// The operator, at the plan's precision.
    pub op: OpDesc,
    /// Occurrences of this exact operator in the tuned model.
    pub count: u32,
    /// The winning mapping (== `static_choice` when nothing beat it).
    pub choice: MappingChoice,
    /// Simulated cycles of the winning mapping (one quiesced execution).
    pub cycles: u64,
    /// The static Sec. III mapping and its simulated cycles.
    pub static_choice: MappingChoice,
    /// Simulated cycles of the static mapping.
    pub static_cycles: u64,
    /// Mapping candidates costed (including the static one).
    pub candidates: u32,
}

impl OpTuning {
    /// Did tuning deviate from the static mapping?
    pub fn improved(&self) -> bool {
        self.choice != self.static_choice
    }
}

/// An empirically tuned per-operator mapping for one
/// `(model, precision, configuration)` point.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedPlan {
    /// Zoo model name (or any caller-chosen label for ad-hoc op sets).
    pub model: String,
    /// Precision the plan was tuned at.
    pub prec: Precision,
    /// Code-shaping configuration the plan is valid for.
    pub cfg: TunedConfigSig,
    /// Whether the search that produced this plan included chunk-size
    /// candidates ([`TuneOptions::chunks`]). The persistent cache refuses
    /// to satisfy a broader search request with a narrower plan.
    pub search_chunks: bool,
    /// One entry per *distinct* operator, in first-occurrence order.
    pub ops: Vec<OpTuning>,
    /// Model-level residency chain, positional over the model's full
    /// layer sequence (not the distinct-op table): `chain[i]` is true
    /// when layer `i` consumes layer `i-1`'s output directly from the
    /// VRF (its tuned choice runs with [`MappingChoice::carry_in`])
    /// instead of the drain/reload round-trip through DRAM. Empty when
    /// the plan predates model-level tuning or was hand-built — every
    /// layer then reloads, which is always safe.
    pub chain: Vec<bool>,
}

impl TunedPlan {
    /// The tuned mapping for `op`, if this plan has one.
    pub fn choice_for(&self, op: &OpDesc) -> Option<MappingChoice> {
        self.ops.iter().find(|t| t.op == *op).map(|t| t.choice)
    }

    /// Whether this plan was tuned for (the code-shaping part of) `cfg`.
    pub fn matches(&self, cfg: &SpeedConfig) -> bool {
        self.cfg == TunedConfigSig::of(cfg)
    }

    /// Occurrence-weighted simulated cycles under the tuned mapping.
    pub fn tuned_cycles(&self) -> u64 {
        self.ops.iter().map(|t| t.count as u64 * t.cycles).sum()
    }

    /// Occurrence-weighted simulated cycles under the static mapping.
    pub fn static_cycles(&self) -> u64 {
        self.ops.iter().map(|t| t.count as u64 * t.static_cycles).sum()
    }

    /// static / tuned cycle ratio (>= 1.0 by the tie-to-static rule).
    pub fn speedup(&self) -> f64 {
        if self.tuned_cycles() == 0 {
            return 1.0;
        }
        self.static_cycles() as f64 / self.tuned_cycles() as f64
    }

    /// Distinct operators whose tuned mapping differs from the static one.
    pub fn improved_ops(&self) -> usize {
        self.ops.iter().filter(|t| t.improved()).count()
    }

    /// Shape-variant digest of this plan: [`ops_digest`] over its distinct
    /// operators. A downscaled zoo model and its full-size original share
    /// a name but never a digest, so their cache files coexist.
    pub fn variant_digest(&self) -> u64 {
        ops_digest(self.ops.iter().map(|t| &t.op))
    }

    /// Canonical cache file name: `<model>@int<bits>-<digest>.json`, where
    /// `digest` is the low 32 bits of the shape-variant digest (quick
    /// downscaled plans must not clobber expensive full-size ones).
    pub fn cache_file_name(model: &str, prec: Precision, digest: u64) -> String {
        format!("{model}@int{}-{:08x}.json", prec.bits(), digest & 0xFFFF_FFFF)
    }

    /// Serialize as the `bench/tuned/` JSON document.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str("{\n  \"schema\": 1,\n");
        s.push_str(&format!("  \"model\": {},\n", jstr(&self.model)));
        s.push_str(&format!("  \"prec\": {},\n", self.prec.bits()));
        s.push_str(&format!(
            "  \"config\": {{ \"lanes\": {}, \"tile_r\": {}, \"tile_c\": {}, \"vrf_kib\": {} }},\n",
            self.cfg.lanes, self.cfg.tile_r, self.cfg.tile_c, self.cfg.vrf_kib
        ));
        s.push_str(&format!("  \"search_chunks\": {},\n", self.search_chunks));
        s.push_str(&format!(
            "  \"chain\": [{}],\n",
            self.chain.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(", ")
        ));
        s.push_str(&format!("  \"cycles_static\": {},\n", self.static_cycles()));
        s.push_str(&format!("  \"cycles_tuned\": {},\n", self.tuned_cycles()));
        s.push_str("  \"ops\": [\n");
        for (i, t) in self.ops.iter().enumerate() {
            let o = &t.op;
            s.push_str(&format!(
                "    {{ \"kind\": {}, \"m\": {}, \"k\": {}, \"n\": {}, \"c\": {}, \
                 \"f\": {}, \"h\": {}, \"w\": {}, \"ksize\": {}, \"stride\": {}, \
                 \"pad\": {}, \"count\": {}, \"strat\": {}, \"chunk\": {}, \
                 \"jchunk\": {}, \"cycles\": {}, \"static_strat\": {}, \
                 \"static_chunk\": {}, \"static_jchunk\": {}, \
                 \"static_cycles\": {}, \"candidates\": {} }}{}\n",
                jstr(kind_name(o.kind)),
                o.m,
                o.k,
                o.n,
                o.c,
                o.f,
                o.h,
                o.w,
                o.ksize,
                o.stride,
                o.pad,
                t.count,
                // StrategyKind's Display is the canonical lowercase name
                // strat_from parses back.
                jstr(&t.choice.strat.to_string()),
                jopt(t.choice.chunk),
                jopt(t.choice.jchunk),
                t.cycles,
                jstr(&t.static_choice.strat.to_string()),
                jopt(t.static_choice.chunk),
                jopt(t.static_choice.jchunk),
                t.static_cycles,
                t.candidates,
                if i + 1 < self.ops.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse a plan document, failing fast (typed `Parse`) on unknown
    /// strategies, bad precisions, or missing fields.
    pub fn from_json(src: &str) -> Result<TunedPlan> {
        let doc = parse(src)?;
        let model = doc
            .get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| perr("tuned plan needs a \"model\" string"))?
            .to_string();
        let bits = doc
            .get("prec")
            .and_then(Json::as_i64)
            .ok_or_else(|| perr("tuned plan needs integer \"prec\""))?;
        let prec = Precision::from_bits(bits as u32)
            .ok_or_else(|| perr(format!("bad tuned-plan precision {bits}")))?;
        let cj = doc
            .get("config")
            .ok_or_else(|| perr("tuned plan needs a \"config\" object"))?;
        let cfg_field = |k: &str| -> Result<u32> {
            cj.get(k)
                .and_then(Json::as_i64)
                .filter(|&v| v >= 1 && v <= u32::MAX as i64)
                .map(|v| v as u32)
                .ok_or_else(|| perr(format!("tuned-plan config needs \"{k}\"")))
        };
        let cfg = TunedConfigSig {
            lanes: cfg_field("lanes")?,
            tile_r: cfg_field("tile_r")?,
            tile_c: cfg_field("tile_c")?,
            vrf_kib: cfg_field("vrf_kib")?,
        };
        let search_chunks = doc
            .get("search_chunks")
            .and_then(Json::as_bool)
            .ok_or_else(|| perr("tuned plan needs boolean \"search_chunks\""))?;
        let ops_json = doc
            .get("ops")
            .and_then(Json::as_arr)
            .ok_or_else(|| perr("tuned plan needs an \"ops\" array"))?;
        let mut ops = Vec::with_capacity(ops_json.len());
        for e in ops_json {
            ops.push(parse_op_tuning(e, prec, &cfg)?);
        }
        // Absent in pre-model-level plan documents: parses as empty
        // (no layer carries — always safe).
        let chain = match doc.get("chain") {
            None | Some(Json::Null) => Vec::new(),
            Some(j) => j
                .as_arr()
                .ok_or_else(|| perr("tuned plan \"chain\" must be an array"))?
                .iter()
                .map(|v| {
                    v.as_bool()
                        .ok_or_else(|| perr("tuned plan \"chain\" entries must be booleans"))
                })
                .collect::<Result<Vec<bool>>>()?,
        };
        Ok(TunedPlan { model, prec, cfg, search_chunks, ops, chain })
    }

    /// Write this plan to `dir` under its canonical cache file name;
    /// returns the path written. Creates the directory if needed.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<std::path::PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .map_err(|e| SpeedError::Bench(format!("creating {}: {e}", dir.display())))?;
        let path =
            dir.join(Self::cache_file_name(&self.model, self.prec, self.variant_digest()));
        std::fs::write(&path, self.to_json())
            .map_err(|e| SpeedError::Bench(format!("writing {}: {e}", path.display())))?;
        Ok(path)
    }

    /// Load a plan file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<TunedPlan> {
        let path = path.as_ref();
        let src = std::fs::read_to_string(path)
            .map_err(|e| perr(format!("reading tuned plan {}: {e}", path.display())))?;
        Self::from_json(&src)
    }
}

fn kind_name(kind: OpKind) -> &'static str {
    match kind {
        OpKind::Mm => "mm",
        OpKind::Conv => "conv",
        OpKind::Pwcv => "pwcv",
        OpKind::Dwcv => "dwcv",
    }
}

fn kind_from(s: &str) -> Result<OpKind> {
    match s {
        "mm" => Ok(OpKind::Mm),
        "conv" => Ok(OpKind::Conv),
        "pwcv" => Ok(OpKind::Pwcv),
        "dwcv" => Ok(OpKind::Dwcv),
        other => Err(perr(format!("unknown op kind '{other}' (mm|conv|pwcv|dwcv)"))),
    }
}

fn strat_from(s: &str) -> Result<StrategyKind> {
    match s {
        "mm" => Ok(StrategyKind::Mm),
        "ffcs" => Ok(StrategyKind::Ffcs),
        "cf" => Ok(StrategyKind::Cf),
        "ff" => Ok(StrategyKind::Ff),
        other => Err(perr(format!("unknown strategy '{other}' (mm|ffcs|cf|ff)"))),
    }
}

fn parse_op_tuning(e: &Json, prec: Precision, sig: &TunedConfigSig) -> Result<OpTuning> {
    let kind = kind_from(
        e.get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| perr("tuned op needs a \"kind\" string"))?,
    )?;
    let dim = |k: &str| -> Result<u32> {
        e.get(k)
            .and_then(Json::as_i64)
            .filter(|&v| v >= 0 && v <= u32::MAX as i64)
            .map(|v| v as u32)
            .ok_or_else(|| perr(format!("tuned op needs non-negative \"{k}\"")))
    };
    let num = |k: &str| -> Result<u64> {
        e.get(k)
            .and_then(Json::as_i64)
            .filter(|&v| v >= 0)
            .map(|v| v as u64)
            .ok_or_else(|| perr(format!("tuned op needs non-negative \"{k}\"")))
    };
    let chunk = |k: &str| -> Result<Option<u32>> {
        match e.get(k) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v
                .as_i64()
                .filter(|&n| n >= 1 && n <= u32::MAX as i64)
                .map(|n| Some(n as u32))
                .ok_or_else(|| perr(format!("tuned op \"{k}\" must be a positive integer"))),
        }
    };
    let strat = |k: &str| -> Result<StrategyKind> {
        strat_from(
            e.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| perr(format!("tuned op needs a \"{k}\" string")))?,
        )
    };
    let op = OpDesc {
        kind,
        prec,
        m: dim("m")?,
        k: dim("k")?,
        n: dim("n")?,
        c: dim("c")?,
        f: dim("f")?,
        h: dim("h")?,
        w: dim("w")?,
        ksize: dim("ksize")?,
        stride: dim("stride")?,
        pad: dim("pad")?,
    };
    op.validate()?;
    let choice = MappingChoice {
        strat: strat("strat")?,
        chunk: chunk("chunk")?,
        // Absent in pre-J-dim plan documents: parses as None.
        jchunk: chunk("jchunk")?,
        // Per-op entries never carry; carrying is positional model-level
        // state ([`TunedPlan::chain`]), applied at run time.
        carry_in: false,
    };
    let static_choice = MappingChoice {
        strat: strat("static_strat")?,
        chunk: chunk("static_chunk")?,
        jchunk: chunk("static_jchunk")?,
        carry_in: false,
    };
    // Feasibility (the applicability matrix) is validated against the
    // plan's own configuration signature, so a stale document naming a
    // mapping code generation would reject fails at load time — never
    // mid-request. Spilled FF mappings are feasible: their refetch runs
    // compile and are costed honestly.
    if !dataflow::feasible(choice.strat, &op, &sig.as_config()) {
        return Err(perr(format!(
            "tuned strategy {} not feasible for {} on the plan's configuration",
            choice.strat, op.kind
        )));
    }
    Ok(OpTuning {
        op,
        count: dim("count")?.max(1),
        choice,
        cycles: num("cycles")?,
        static_choice,
        static_cycles: num("static_cycles")?,
        candidates: dim("candidates")?,
    })
}

/// Stable digest over an operator sequence — the identity of a *shape
/// variant* (a downscaled zoo model digests differently from its
/// full-size original even though both keep the model name). Runs on the
/// crate-wide [`Fnv64`] hasher; byte-for-byte compatible with the private
/// per-word fold this module carried before the consolidation (locked by
/// `digest_matches_pre_consolidation_fold` below), so existing cache file
/// names stay valid.
pub fn ops_digest<'a>(ops: impl IntoIterator<Item = &'a OpDesc>) -> u64 {
    use std::hash::Hasher;
    let mut h = Fnv64::new();
    for op in ops {
        for v in [
            op.kind as u32,
            op.prec.bits(),
            op.m,
            op.k,
            op.n,
            op.c,
            op.f,
            op.h,
            op.w,
            op.ksize,
            op.stride,
            op.pad,
        ] {
            h.write(&v.to_le_bytes());
        }
    }
    h.finish()
}

/// The distinct operators of a model with occurrence counts, in
/// first-occurrence order — the exact entry order of a [`TunedPlan`]'s
/// `ops`, so a plan's [`TunedPlan::variant_digest`] agrees with a digest
/// computed from the model before tuning.
fn distinct_ops(ops: &[OpDesc]) -> Vec<(OpDesc, u32)> {
    let mut distinct: Vec<(OpDesc, u32)> = Vec::new();
    for op in ops {
        match distinct.iter_mut().find(|(o, _)| o == op) {
            Some((_, n)) => *n += 1,
            None => distinct.push((*op, 1)),
        }
    }
    distinct
}

/// How hard to search.
#[derive(Debug, Clone, Copy)]
pub struct TuneOptions {
    /// Also try smaller-than-default chunk sizes per strategy (the full
    /// `(strategy × chunk)` space of the module docs). Strategy-only
    /// search is ~3x cheaper and captures most of the win.
    pub chunks: bool,
    /// Simulator mode of the cost oracle. Batch (the default) and Exact
    /// report bit-identical cycles, so this only trades oracle wall time.
    pub exec_mode: ExecMode,
    /// Rank candidates with the bit-exact static cost model
    /// ([`crate::analysis::cost`]) and simulate only the static mapping
    /// plus the candidates tying the best predicted cost. Because the
    /// model reproduces simulated `(cycles, traffic)` exactly, the pruned
    /// search selects the same winner — the resulting [`TunedPlan`] is
    /// byte-identical to the full search's. Skipped candidates tally
    /// [`Counter::TuneCandidatesPruned`].
    pub prune: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions { chunks: true, exec_mode: ExecMode::Batch, prune: false }
    }
}

/// Enumerate the mapping candidates for `op` (static choice first).
/// Candidates are restricted to [`dataflow::feasible`] strategies (the
/// applicability matrix — FF on CONV/PWCV stays in even where its weight
/// slice spills the VRF; the spilled stream's refetch runs are costed
/// honestly and lose or win on measured merit),
/// and with [`TuneOptions::chunks`] the search covers both chunk axes:
/// smaller reduction/channel chunks ([`dataflow::chunk_candidates`]) and,
/// for MM, wider B-tile column blocks ([`dataflow::jchunk_candidates`]).
pub fn candidates_for(op: &OpDesc, cfg: &SpeedConfig, opts: &TuneOptions) -> Vec<MappingChoice> {
    let static_choice = MappingChoice::preferred(op);
    let mut out = vec![static_choice];
    for strat in StrategyKind::ALL {
        if !dataflow::feasible(strat, op, cfg) {
            continue;
        }
        let base = MappingChoice::of(strat);
        if base != static_choice {
            out.push(base);
        }
        if opts.chunks {
            for c in dataflow::chunk_candidates(op, cfg, strat) {
                out.push(MappingChoice { chunk: Some(c), ..base });
            }
            for j in dataflow::jchunk_candidates(op, cfg, strat) {
                out.push(MappingChoice { jchunk: Some(j), ..base });
            }
        }
    }
    out
}

/// Tune one operator on a warm engine: cost every candidate with a
/// quiesced execution (per-candidate stats are then a pure function of
/// the candidate — the serving layer's determinism contract) and keep the
/// strict winner. Ties — including "everything ties" — resolve to the
/// static mapping. With [`TuneOptions::prune`] the bit-exact static cost
/// model pre-ranks the candidates and only potential winners are
/// simulated; the outcome is provably the same.
pub fn tune_op(engine: &mut Engine, op: &OpDesc, opts: &TuneOptions) -> Result<OpTuning> {
    op.validate()?;
    let cfg = *engine.config();
    let cands = candidates_for(op, &cfg, opts);
    for choice in &cands {
        // Honest-spill observability: FF candidates whose weight slice
        // spills are tallied so tune runs surface how often the search is
        // costing refetch streams instead of rejecting them.
        if choice.strat == StrategyKind::Ff
            && dataflow::ff_weight_refetches(op, &cfg, choice.chunk) > 0
        {
            engine.counters().incr(Counter::TuneCandidatesSpilledFf);
        }
    }
    let mut verified: Vec<MappingChoice> = Vec::with_capacity(cands.len());
    for choice in &cands {
        // Statically verify the candidate's stream before paying for its
        // simulation. A broken *static* mapping is a compiler bug and
        // aborts the tune; a broken alternative candidate is merely
        // dropped from the search (the static fallback always remains).
        if let Err(e) = crate::analysis::ensure_verified(op, &cfg, *choice) {
            if *choice == cands[0] {
                return Err(e);
            }
            continue;
        }
        verified.push(*choice);
    }
    // With pruning, the static cost model ranks the verified candidates
    // and only potential winners reach the simulator. The model is
    // bit-exact, so "ties the best predicted cost" is exactly the set of
    // candidates that could win the simulated search; iteration order is
    // preserved below, so the pruned argmax is the full search's argmax.
    // The static mapping is always simulated: `static_cycles` is a
    // measured number, never a prediction.
    let keep: Vec<bool> = if opts.prune {
        let mut costs = Vec::with_capacity(verified.len());
        for choice in &verified {
            costs.push(crate::analysis::cost::cost_op(op, &cfg, *choice)?.cost());
        }
        let best = costs.iter().min().copied().expect("candidate list is never empty");
        verified
            .iter()
            .zip(&costs)
            .map(|(choice, cost)| *choice == cands[0] || *cost == best)
            .collect()
    } else {
        vec![true; verified.len()]
    };
    let mut best: Option<(MappingChoice, u64, u64)> = None;
    let mut static_cycles = 0u64;
    for (choice, keep) in verified.iter().zip(&keep) {
        if !*keep {
            engine.counters().incr(Counter::TuneCandidatesPruned);
            continue;
        }
        engine.quiesce();
        let (stats, _) = engine.run_op_with(op, *choice, false)?;
        engine.counters().incr(Counter::TuneCandidates);
        let cost = (stats.cycles, stats.traffic.total());
        if *choice == cands[0] {
            static_cycles = stats.cycles;
        }
        let better = match &best {
            None => true,
            Some((_, bc, bt)) => cost.0 < *bc || (cost.0 == *bc && cost.1 < *bt),
        };
        if better {
            best = Some((*choice, cost.0, cost.1));
        }
    }
    let (choice, cycles, _) = best.expect("candidate list is never empty");
    Ok(OpTuning {
        op: *op,
        count: 1,
        choice,
        cycles,
        static_choice: cands[0],
        static_cycles,
        candidates: cands.len() as u32,
    })
}

/// Tune every distinct operator of `model` at `prec` on `cfg`, returning
/// the plan (occurrence counts preserved, first-occurrence order).
pub fn tune_model(
    cfg: &SpeedConfig,
    model: &Model,
    prec: Precision,
    opts: &TuneOptions,
) -> Result<TunedPlan> {
    let mut engine = Engine::new(*cfg)?;
    engine.set_exec_mode(opts.exec_mode);
    tune_model_on(&mut engine, model, prec, opts)
}

/// [`tune_model`] on an existing warm engine — the serve pool's online
/// first-request tuning path: the owning worker's engine (and its
/// program cache, which keeps every candidate compilation for the replays
/// that follow) performs the search. The engine's current execution mode
/// is used as-is ([`TuneOptions::exec_mode`] only selects the mode when
/// [`tune_model`] builds a throwaway engine); batch and exact report
/// bit-identical cycles, so the plan is mode-independent either way. The
/// engine is left quiesced, ready for the request that triggered the
/// tune.
pub fn tune_model_on(
    engine: &mut Engine,
    model: &Model,
    prec: Precision,
    opts: &TuneOptions,
) -> Result<TunedPlan> {
    let m = model.at_precision(prec);
    let cfg = *engine.config();
    let distinct = distinct_ops(&m.ops);
    let mut ops = Vec::with_capacity(distinct.len());
    for (op, count) in distinct {
        let mut t = tune_op(engine, &op, opts)?;
        t.count = count;
        ops.push(t);
    }
    // Model-level chain pass: at every position where layer i-1's output
    // can stay VRF-resident for layer i, try the tuned choice with
    // carry-in. Gated on the bit-exact static cost model (so the pass is
    // identical under both prune modes), verified, then confirmed with a
    // quiesced measurement — chain[i] is set only when the carried
    // mapping is strictly better, so the model-level plan is never worse
    // than the per-op plan.
    let mut chain = vec![false; m.ops.len()];
    for i in 1..m.ops.len() {
        let (prev, cur) = (&m.ops[i - 1], &m.ops[i]);
        if !dataflow::carries_residency(prev, cur, &cfg) {
            continue;
        }
        let base = ops
            .iter()
            .find(|t| t.op == *cur)
            .expect("distinct table covers the model")
            .choice;
        let carry = MappingChoice { carry_in: true, ..base };
        let base_cost = crate::analysis::cost::cost_op(cur, &cfg, base)?.cost();
        let carry_cost = crate::analysis::cost::cost_op(cur, &cfg, carry)?.cost();
        if carry_cost >= base_cost {
            continue;
        }
        if crate::analysis::ensure_verified(cur, &cfg, carry).is_err() {
            continue;
        }
        engine.quiesce();
        let (bs, _) = engine.run_op_with(cur, base, false)?;
        engine.quiesce();
        let (cs, _) = engine.run_op_with(cur, carry, false)?;
        chain[i] = cs.cycles < bs.cycles
            || (cs.cycles == bs.cycles && cs.traffic.total() < bs.traffic.total());
    }
    engine.quiesce();
    Ok(TunedPlan {
        model: m.name.to_string(),
        prec,
        cfg: TunedConfigSig::of(engine.config()),
        search_chunks: opts.chunks,
        ops,
        chain,
    })
}

/// Tune with a persistent JSON cache: load `dir/<model>@intN.json` when it
/// exists and matches `cfg`, otherwise tune and save. Returns the plan and
/// whether it came from the cache.
pub fn tune_model_cached(
    cfg: &SpeedConfig,
    model: &Model,
    prec: Precision,
    opts: &TuneOptions,
    dir: impl AsRef<Path>,
) -> Result<(TunedPlan, bool)> {
    let dir = dir.as_ref();
    let m = model.at_precision(prec);
    let digest = ops_digest(distinct_ops(&m.ops).iter().map(|(op, _)| op));
    let path = dir.join(TunedPlan::cache_file_name(m.name, prec, digest));
    if path.is_file() {
        if let Ok(plan) = TunedPlan::load(&path) {
            let covers = m.ops.iter().all(|op| plan.choice_for(op).is_some());
            // A chunk-searched plan satisfies any request; a
            // strategies-only plan must not silently stand in for the
            // broader (strategy x chunk) search the caller asked for.
            let broad_enough = plan.search_chunks || !opts.chunks;
            if plan.matches(cfg) && plan.model == m.name && covers && broad_enough {
                return Ok((plan, true));
            }
        }
        // Mismatched / stale / narrower / unparseable cache entries are
        // re-tuned and overwritten rather than trusted.
    }
    let plan = tune_model(cfg, model, prec, opts)?;
    plan.save(dir)?;
    Ok((plan, false))
}

/// Deterministic operand values for parity checks (xorshift64*, the same
/// generator the compiler tests use; seed-stable across platforms).
pub fn seeded_operands(n: usize, prec: Precision, seed: u64) -> Vec<i32> {
    let (lo, hi) = prec.range();
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            lo + ((s >> 8) % (hi - lo + 1) as u64) as i32
        })
        .collect()
}

/// Execute `op` functionally under `choice` on a fresh engine with seeded
/// operands; returns the i32 output accumulators.
pub fn functional_output(
    cfg: &SpeedConfig,
    op: &OpDesc,
    choice: MappingChoice,
    seed: u64,
) -> Result<Vec<i32>> {
    let mut engine = Engine::new(*cfg)?;
    let prog = engine.program_with(op, choice)?;
    let layout = *prog.layout();
    drop(prog);
    let x = seeded_operands(op.input_elems() as usize, op.prec, seed);
    let w = seeded_operands(op.weight_elems() as usize, op.prec, seed ^ 0xD1B5_4A32_D192_ED03);
    engine.preload_packed(layout.in_addr, &x, op.prec);
    engine.preload_packed(layout.w_addr, &w, op.prec);
    engine.run_op_with(op, choice, true)?;
    Ok(engine.inspect_i32(layout.out_addr, op.output_elems() as usize))
}

/// Verify that `choice` is semantics-preserving for `op`: its functional
/// output must be bit-identical to the static mixed mapping's. A mismatch
/// is a tuner/compiler defect and returns a typed `Bench` error naming
/// the first diverging element.
pub fn verify_choice(cfg: &SpeedConfig, op: &OpDesc, choice: MappingChoice) -> Result<()> {
    let seed = 0x5EED_0F_7E57 ^ op.total_macs();
    let want = functional_output(cfg, op, MappingChoice::preferred(op), seed)?;
    let got = functional_output(cfg, op, choice, seed)?;
    if want.len() != got.len() {
        return Err(SpeedError::Bench(format!(
            "tuned parity failure for {op:?} under {choice}: {} vs {} output elems",
            got.len(),
            want.len()
        )));
    }
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        if g != w {
            return Err(SpeedError::Bench(format!(
                "tuned parity failure for {op:?} under {choice}: out[{i}] = {g}, static = {w}"
            )));
        }
    }
    Ok(())
}

/// Verify every entry of a plan whose mapping deviates from the static
/// one, skipping operators above `mac_limit` (functional simulation is
/// O(MACs); full-size zoo layers belong in `--quick`-downscaled runs).
/// Returns `(verified, skipped)` counts.
pub fn verify_plan(cfg: &SpeedConfig, plan: &TunedPlan, mac_limit: u64) -> Result<(usize, usize)> {
    let mut verified = 0;
    let mut skipped = 0;
    for t in &plan.ops {
        if !t.improved() {
            continue;
        }
        if t.op.total_macs() > mac_limit {
            skipped += 1;
            continue;
        }
        verify_choice(cfg, &t.op, t.choice)?;
        verified += 1;
    }
    Ok((verified, skipped))
}

/// A pool-wide tuned-plan registry, shared the way `SharedPrograms`
/// shares compiled programs: cloning is one `Arc`, and a plan any member
/// inserts is visible to every engine serving [`Policy::Tuned`] requests.
/// Keyed on `(model name, precision)`; lookups validate the configuration
/// signature so a plan tuned for another instance is never applied.
///
/// [`Policy::Tuned`]: crate::coordinator::Policy::Tuned
#[derive(Clone, Default)]
pub struct TunedPlans {
    /// model name → precision bits → plan. Nested so the serving hot
    /// path looks up with a borrowed `&str` (no per-request key
    /// allocation on `Policy::Tuned` requests).
    map: Arc<Mutex<HashMap<String, HashMap<u32, Arc<TunedPlan>>>>>,
}

impl TunedPlans {
    /// An empty plan registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of registered plans.
    pub fn len(&self) -> usize {
        self.map
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .map(HashMap::len)
            .sum()
    }

    /// Whether no plans are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Register a plan. An existing plan for the same `(model, precision)`
    /// is merged: new distinct operators are appended, existing ones keep
    /// their current choice (so plans for downscaled and full-size
    /// variants of one zoo model compose instead of clobbering).
    pub fn insert(&self, plan: TunedPlan) -> Arc<TunedPlan> {
        let bits = plan.prec.bits();
        let mut map = self.map.lock().unwrap_or_else(|e| e.into_inner());
        let inner = map.entry(plan.model.clone()).or_default();
        let merged = match inner.get(&bits) {
            Some(existing) if existing.cfg == plan.cfg => {
                let mut ops = existing.ops.clone();
                for t in plan.ops {
                    if !ops.iter().any(|have| have.op == t.op) {
                        ops.push(t);
                    }
                }
                TunedPlan { ops, ..(**existing).clone() }
            }
            _ => plan,
        };
        let arc = Arc::new(merged);
        inner.insert(bits, arc.clone());
        arc
    }

    /// The plan for `(model, prec)`, if present and tuned for `cfg`.
    pub fn get(&self, model: &str, prec: Precision, cfg: &SpeedConfig) -> Option<Arc<TunedPlan>> {
        let map = self.map.lock().unwrap_or_else(|e| e.into_inner());
        map.get(model)
            .and_then(|inner| inner.get(&prec.bits()))
            .filter(|p| p.matches(cfg))
            .cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;

    fn cfg() -> SpeedConfig {
        SpeedConfig::reference()
    }

    fn tiny_model() -> Model {
        Model {
            name: "tiny",
            ops: vec![
                OpDesc::conv(8, 8, 12, 12, 3, 1, 1, Precision::Int8),
                OpDesc::pwcv(8, 8, 12, 12, Precision::Int8),
                OpDesc::dwcv(8, 12, 12, 3, 1, 1, Precision::Int8),
                OpDesc::mm(8, 16, 8, Precision::Int8),
                // Repeat of the first layer: dedup + count.
                OpDesc::conv(8, 8, 12, 12, 3, 1, 1, Precision::Int8),
            ],
            scalar_fraction: 0.1,
        }
    }

    #[test]
    fn candidates_start_with_static_and_respect_applicability() {
        let opts = TuneOptions::default();
        let conv = OpDesc::conv(16, 16, 12, 12, 3, 1, 1, Precision::Int8);
        let cands = candidates_for(&conv, &cfg(), &opts);
        assert_eq!(cands[0], MappingChoice::preferred(&conv));
        assert!(cands.iter().all(|c| dataflow::applicable(c.strat, &conv)));
        assert!(cands.iter().any(|c| c.strat == StrategyKind::Ff));
        // No duplicates.
        for (i, a) in cands.iter().enumerate() {
            assert!(!cands[i + 1..].contains(a), "{a} duplicated");
        }
        let mm = OpDesc::mm(8, 32, 8, Precision::Int8);
        let mc = candidates_for(&mm, &cfg(), &opts);
        assert!(mc.iter().all(|c| c.strat == StrategyKind::Mm));
        let dw = OpDesc::dwcv(8, 12, 12, 3, 1, 1, Precision::Int8);
        let dc = candidates_for(&dw, &cfg(), &opts);
        assert_eq!(dc, vec![MappingChoice::of(StrategyKind::Ff)]);
    }

    #[test]
    fn tune_op_never_worse_than_static_and_deterministic() {
        let mut engine = Engine::new(cfg()).unwrap();
        let opts = TuneOptions::default();
        for op in [
            OpDesc::conv(8, 8, 12, 12, 3, 1, 1, Precision::Int8),
            OpDesc::pwcv(16, 16, 10, 10, Precision::Int16),
            OpDesc::mm(8, 32, 8, Precision::Int4),
        ] {
            let a = tune_op(&mut engine, &op, &opts).unwrap();
            assert!(a.cycles <= a.static_cycles, "{op:?}");
            assert!(a.candidates >= 1);
            // Re-tuning on the (now warm) engine reproduces the outcome.
            let b = tune_op(&mut engine, &op, &opts).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn decode_step_tuning_never_loses_to_static() {
        // Autoregressive decode shapes: skinny MMs whose K grows with the
        // KV cache. The tuner's skinny-chunk arm must never lose to the
        // static mapping at any cache length or precision, and every
        // growing-K variant must resolve through its plan.
        let spec = crate::models::zoo::llm_spec("llm_tiny").unwrap();
        for prec in [Precision::Int8, Precision::Int4] {
            for kv in [65u32, 96] {
                let step = spec.decode_step(prec, kv);
                let plan =
                    tune_model(&cfg(), &step, prec, &TuneOptions::default()).unwrap();
                assert!(
                    plan.tuned_cycles() <= plan.static_cycles(),
                    "{prec} kv={kv}: tuned {} > static {}",
                    plan.tuned_cycles(),
                    plan.static_cycles()
                );
                for op in &step.at_precision(prec).ops {
                    assert!(plan.choice_for(op).is_some(), "{op:?}");
                }
            }
        }
    }

    #[test]
    fn pruned_search_is_byte_identical_and_skips_candidates() {
        // The pruning acceptance bar: the static-cost-pruned search must
        // produce a byte-identical plan document while actually skipping
        // simulations (tune_candidates_pruned > 0), and the candidates it
        // does simulate must be strictly fewer than the full search's.
        let model = tiny_model();
        let prec = Precision::Int8;
        let full = tune_model(&cfg(), &model, prec, &TuneOptions::default()).unwrap();

        let mut engine = Engine::new(cfg()).unwrap();
        let opts = TuneOptions { prune: true, ..TuneOptions::default() };
        let pruned = tune_model_on(&mut engine, &model, prec, &opts).unwrap();

        assert_eq!(pruned.to_json(), full.to_json(), "pruning changed the plan");
        let skipped = engine.counters().get(Counter::TuneCandidatesPruned);
        let simulated = engine.counters().get(Counter::TuneCandidates);
        assert!(skipped > 0, "pruning never skipped a simulation");
        let total_candidates: u64 = full.ops.iter().map(|t| t.candidates as u64).sum();
        assert!(
            simulated < total_candidates,
            "pruned search simulated {simulated} of {total_candidates}"
        );
    }

    #[test]
    fn tune_op_rejects_invalid_geometry() {
        let mut engine = Engine::new(cfg()).unwrap();
        let bad = OpDesc::conv(3, 4, 2, 2, 5, 1, 0, Precision::Int8);
        assert!(matches!(
            tune_op(&mut engine, &bad, &TuneOptions::default()),
            Err(SpeedError::Config(_))
        ));
    }

    #[test]
    fn tune_model_dedups_and_counts() {
        let plan =
            tune_model(&cfg(), &tiny_model(), Precision::Int8, &TuneOptions::default())
                .unwrap();
        assert_eq!(plan.model, "tiny");
        assert_eq!(plan.ops.len(), 4, "5 layers, 4 distinct");
        assert_eq!(plan.ops[0].count, 2, "repeated conv counted");
        assert!(plan.tuned_cycles() <= plan.static_cycles());
        assert!(plan.speedup() >= 1.0);
        // Every model operator resolves through the plan.
        for op in &tiny_model().at_precision(Precision::Int8).ops {
            assert!(plan.choice_for(op).is_some(), "{op:?}");
        }
    }

    #[test]
    fn plan_json_round_trips() {
        let plan =
            tune_model(&cfg(), &tiny_model(), Precision::Int4, &TuneOptions::default())
                .unwrap();
        let back = TunedPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back);
        // Malformed documents fail typed.
        assert!(matches!(TunedPlan::from_json("[]"), Err(SpeedError::Parse(_))));
        assert!(matches!(
            TunedPlan::from_json(r#"{ "model": "x", "prec": 7 }"#),
            Err(SpeedError::Parse(_))
        ));
    }

    #[test]
    fn tuned_session_matches_plan_and_never_regresses() {
        let model = tiny_model();
        let prec = Precision::Int8;
        let plan = Arc::new(
            tune_model(&cfg(), &model, prec, &TuneOptions::default()).unwrap(),
        );
        let mut static_engine = Engine::new(cfg()).unwrap();
        let static_run = static_engine
            .session()
            .with_policy(Policy::Mixed)
            .run_model(&model, prec)
            .unwrap();
        let mut tuned_engine = Engine::new(cfg()).unwrap();
        let tuned_run = tuned_engine
            .session()
            .with_tuned_plan(plan.clone())
            .run_model(&model, prec)
            .unwrap();
        assert_eq!(tuned_run.layers.len(), static_run.layers.len());
        assert_eq!(tuned_run.total.macs, static_run.total.macs);
        assert!(
            tuned_run.total.cycles <= static_run.total.cycles,
            "tuned {} > static {}",
            tuned_run.total.cycles,
            static_run.total.cycles
        );
        // Each layer runs the strategy the plan recorded.
        for layer in &tuned_run.layers {
            let choice = plan.choice_for(&layer.op).unwrap();
            assert_eq!(layer.strat, choice.strat);
        }
        // Policy::Tuned without a plan degrades to the static mapping.
        let mut bare = Engine::new(cfg()).unwrap();
        let fallback = bare
            .session()
            .with_policy(Policy::Tuned)
            .run_model(&model, prec)
            .unwrap();
        assert_eq!(fallback.total, static_run.total);
    }

    #[test]
    fn verify_choice_accepts_all_candidates_of_small_ops() {
        let opts = TuneOptions::default();
        for op in [
            OpDesc::conv(6, 8, 10, 10, 3, 1, 1, Precision::Int8),
            OpDesc::pwcv(8, 8, 8, 8, Precision::Int16),
            OpDesc::mm(8, 24, 6, Precision::Int4),
        ] {
            for choice in candidates_for(&op, &cfg(), &opts) {
                verify_choice(&cfg(), &op, choice).unwrap();
            }
        }
    }

    #[test]
    fn registry_shares_merges_and_validates_config() {
        let reg = TunedPlans::new();
        assert!(reg.is_empty());
        let model = tiny_model();
        let plan =
            tune_model(&cfg(), &model, Precision::Int8, &TuneOptions::default()).unwrap();
        reg.insert(plan.clone());
        assert_eq!(reg.len(), 1);
        let got = reg.get("tiny", Precision::Int8, &cfg()).unwrap();
        assert_eq!(*got, plan);
        assert!(reg.get("tiny", Precision::Int4, &cfg()).is_none());
        // A different configuration signature refuses the plan.
        let other = SpeedConfig { lanes: 8, ..cfg() };
        assert!(reg.get("tiny", Precision::Int8, &other).is_none());
        // Merging keeps existing entries and appends new distinct ops.
        let extra = TunedPlan {
            ops: vec![OpTuning {
                op: OpDesc::mm(3, 9, 3, Precision::Int8),
                count: 1,
                choice: MappingChoice::of(StrategyKind::Mm),
                cycles: 10,
                static_choice: MappingChoice::of(StrategyKind::Mm),
                static_cycles: 10,
                candidates: 1,
            }],
            ..plan.clone()
        };
        let merged = reg.insert(extra);
        assert_eq!(merged.ops.len(), plan.ops.len() + 1);
        assert!(merged
            .choice_for(&OpDesc::mm(3, 9, 3, Precision::Int8))
            .is_some());
    }

    #[test]
    fn digest_matches_pre_consolidation_fold() {
        // The consolidation satellite's lock: ops_digest on the shared
        // Fnv64 must reproduce the private per-word fold it replaced, or
        // every existing bench/tuned/ cache file name would silently
        // orphan.
        fn legacy_fold_u32(mut h: u64, v: u32) -> u64 {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        let ops = tiny_model().ops;
        let mut legacy = 0xcbf2_9ce4_8422_2325u64;
        for op in &ops {
            for v in [
                op.kind as u32,
                op.prec.bits(),
                op.m,
                op.k,
                op.n,
                op.c,
                op.f,
                op.h,
                op.w,
                op.ksize,
                op.stride,
                op.pad,
            ] {
                legacy = legacy_fold_u32(legacy, v);
            }
        }
        assert_eq!(ops_digest(ops.iter()), legacy);
    }

    #[test]
    fn wide_mm_search_covers_the_j_dim() {
        // The J-dim arm of the chunk search: a wide MM offers B-tile
        // column-block candidates, every one of them is semantics-
        // preserving, and a plan that records one round-trips through the
        // JSON cache representation.
        let opts = TuneOptions::default();
        let op = OpDesc::mm(8, 32, 64, Precision::Int8);
        let cands = candidates_for(&op, &cfg(), &opts);
        assert!(
            cands.iter().any(|c| c.jchunk.is_some()),
            "wide MM search must include J-dim candidates: {cands:?}"
        );
        for choice in &cands {
            verify_choice(&cfg(), &op, *choice).unwrap();
        }
        // Force a jchunk entry into a plan and prove the JSON round-trip.
        let jcand = *cands.iter().find(|c| c.jchunk.is_some()).unwrap();
        let plan = TunedPlan {
            model: "jtest".into(),
            prec: Precision::Int8,
            cfg: TunedConfigSig::of(&cfg()),
            search_chunks: true,
            chain: vec![],
            ops: vec![OpTuning {
                op,
                count: 2,
                choice: jcand,
                cycles: 90,
                static_choice: MappingChoice::preferred(&op),
                static_cycles: 100,
                candidates: cands.len() as u32,
            }],
        };
        let back = TunedPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.ops[0].choice.jchunk, jcand.jchunk);
    }

    #[test]
    fn spilled_ff_is_enumerated_costed_and_parses() {
        // The honest-spill fix: FF stays in the candidate set for a
        // large-F CONV — its refetch runs are costed, not rejected — the
        // spilled candidates are tallied, and a plan document recording
        // the spilled mapping parses cleanly.
        let op = OpDesc::conv(64, 608, 6, 6, 3, 1, 1, Precision::Int8);
        assert!(
            dataflow::ff_weight_refetches(&op, &cfg(), None) > 0,
            "shape must spill under FF"
        );
        let cands = candidates_for(&op, &cfg(), &TuneOptions::default());
        assert!(
            cands.iter().any(|c| c.strat == StrategyKind::Ff),
            "{cands:?}"
        );
        let mut engine = Engine::new(cfg()).unwrap();
        tune_op(&mut engine, &op, &TuneOptions::default()).unwrap();
        assert!(
            engine.counters().get(Counter::TuneCandidatesSpilledFf) > 0,
            "spilled FF candidates must be tallied"
        );
        // A plan entry recording the spilled FF mapping round-trips.
        let plan = TunedPlan {
            model: "spilled".into(),
            prec: Precision::Int8,
            cfg: TunedConfigSig::of(&cfg()),
            search_chunks: true,
            chain: vec![],
            ops: vec![OpTuning {
                op,
                count: 1,
                choice: MappingChoice::of(StrategyKind::Ff),
                cycles: 1,
                static_choice: MappingChoice::preferred(&op),
                static_cycles: 1,
                candidates: 1,
            }],
        };
        let back = TunedPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn chain_pass_carries_decode_residency_and_round_trips() {
        // Model-level tuning: the llm_tiny decode step feeds skinny MM
        // outputs straight into the next layer's K axis, so the chain
        // pass must find at least one carried position, and the chain
        // must survive the JSON cache representation (including absent
        // "chain" in pre-model-level documents).
        let spec = crate::models::zoo::llm_spec("llm_tiny").unwrap();
        let step = spec.decode_step(Precision::Int8, 65);
        let prec = Precision::Int8;
        let plan = tune_model(&cfg(), &step, prec, &TuneOptions::default()).unwrap();
        let m = step.at_precision(prec);
        assert_eq!(plan.chain.len(), m.ops.len());
        assert!(!plan.chain[0], "layer 0 has no producer to carry from");
        assert!(
            plan.chain.iter().any(|&b| b),
            "decode step must chain at least one layer: {:?}",
            plan.chain
        );
        // Every carried position actually satisfies the residency chain.
        for i in 1..m.ops.len() {
            if plan.chain[i] {
                assert!(dataflow::carries_residency(&m.ops[i - 1], &m.ops[i], &cfg()));
            }
        }
        let back = TunedPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        // A document without "chain" (pre-model-level) parses as empty.
        let legacy: String = plan
            .to_json()
            .lines()
            .filter(|l| !l.trim_start().starts_with("\"chain\""))
            .collect::<Vec<_>>()
            .join("\n");
        let old = TunedPlan::from_json(&legacy).unwrap();
        assert!(old.chain.is_empty());
        assert_eq!(old.ops, plan.ops);
    }

    #[test]
    fn cache_round_trips_on_disk() {
        let dir = std::env::temp_dir()
            .join(format!("speed_tuned_cache_{}", std::process::id()));
        let model = tiny_model();
        let opts = TuneOptions::default();
        let (fresh, was_cached) =
            tune_model_cached(&cfg(), &model, Precision::Int8, &opts, &dir).unwrap();
        assert!(!was_cached);
        let (cached, was_cached) =
            tune_model_cached(&cfg(), &model, Precision::Int8, &opts, &dir).unwrap();
        assert!(was_cached, "second call must hit the JSON cache");
        assert_eq!(fresh, cached);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
