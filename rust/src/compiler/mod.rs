//! Operator compiler: lowers a DNN operator + dataflow strategy to the
//! SPEED instruction stream the paper's programs would contain (Figs. 2, 9).
//!
//! The compiler owns the loop orders that define each strategy's reuse:
//!
//! * **MM** — `for row_block { for k_chunk { load A; for col_tile { bcast
//!   B; VSAM } } store rows }`: inputs reused across processing stages,
//!   weights multi-broadcast, PE output-stationary across K chunks.
//! * **FFCS** — `for fm_block { for c_chunk { bcast inputs (sliding rows);
//!   for f_group { load W; VSAM } } store }`: inputs stream exactly once,
//!   partial sums for *all* output channels of the block stay in the VRF
//!   partial partition (spilled off-chip only when they cannot fit).
//! * **CF** — `for f_group { for fm_row { bcast inputs; for c_chunk { load
//!   W; VSAM } } store }`: accumulation lives in the PE across the whole
//!   input-channel traversal (no partial traffic at all), at the cost of
//!   re-streaming inputs once per output-channel group.
//! * **FF** — per-channel feature-map streaming (DWCV: no cross-channel
//!   accumulation whatsoever; CONV/PWCV ablation: partials round-trip the
//!   result path once per channel pass).
//!
//! Every emitted program is *executable*: the cycle simulator runs it and
//! the byte-accurate traffic of Fig. 10 and cycle counts of Figs. 11/12
//! fall out of the simulation rather than closed-form estimates.

pub mod codegen;

pub use codegen::{
    compile_op, compile_op_with, execute_op, stream_op, stream_op_with, summarize_op,
    summarize_op_with, CodegenSummary, CompiledOp, MemLayout, MEM_ALIGN, MEM_GUARD,
    MEM_MIN_BYTES,
};
