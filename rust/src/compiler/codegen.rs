//! Instruction-stream generation (see module docs in [`crate::compiler`]).

use crate::config::{Precision, SpeedConfig};
use crate::dataflow::{self, partition_budget, vreg_region, MappingChoice};
use crate::error::SpeedError;
use crate::isa::{Dim, Insn, LdMode, RunKind, Segment, StrategyKind, StreamRun, Vtype, WidthSel};
use crate::models::ops::{OpDesc, OpKind};
use crate::sim::OpPlan;

/// DRAM region alignment (and the base offset of the first region). The
/// coordinator's memory sizing shares these constants via
/// [`MemLayout::required_bytes`], so placement and sizing cannot drift.
pub const MEM_ALIGN: u64 = 64;
/// Guard bytes past the last region.
pub const MEM_GUARD: u64 = 64;
/// Floor on a processor's external-memory size: room for small operators,
/// epilogue scratch, and test programs without per-op sizing.
pub const MEM_MIN_BYTES: u64 = 1 << 20;

/// DRAM placement of one operator's tensors.
#[derive(Debug, Clone, Copy)]
pub struct MemLayout {
    /// Base address of the input tensor region.
    pub in_addr: u64,
    /// Base address of the weight tensor region.
    pub w_addr: u64,
    /// Base address of the output (i32 accumulator) region.
    pub out_addr: u64,
    /// Spill region for partial sums (used only when the schedule spills).
    pub partial_addr: u64,
}

impl MemLayout {
    /// The canonical placement for `op` and the total bytes it spans
    /// (including the trailing guard). Placement is a pure function of the
    /// operator — it does not depend on how much memory is present.
    pub fn place(op: &OpDesc) -> (Self, u64) {
        let align = |x: u64| (x + (MEM_ALIGN - 1)) & !(MEM_ALIGN - 1);
        let in_addr = MEM_ALIGN;
        let w_addr = align(in_addr + op.input_bytes());
        let out_addr = align(w_addr + op.weight_bytes());
        let partial_addr = align(out_addr + op.output_bytes());
        let end = partial_addr + op.output_bytes() + MEM_GUARD;
        (MemLayout { in_addr, w_addr, out_addr, partial_addr }, end)
    }

    /// External-memory bytes `op` needs under the canonical placement.
    pub fn required_bytes(op: &OpDesc) -> u64 {
        Self::place(op).1
    }

    /// A default layout with generous region spacing for `op` inside a
    /// memory of `mem_bytes`.
    pub fn for_op(op: &OpDesc, mem_bytes: usize) -> Result<Self, SpeedError> {
        let (layout, end) = Self::place(op);
        if end > mem_bytes as u64 {
            return Err(SpeedError::Layout(format!(
                "operator needs {end} B of external memory, have {mem_bytes}"
            )));
        }
        Ok(layout)
    }
}

/// Instruction-mix summary of a compiled operator.
#[derive(Debug, Default, Clone, Copy)]
pub struct CodegenSummary {
    /// Total instructions emitted (scalar + vector).
    pub total_insns: u64,
    /// `VSALD` transfers emitted.
    pub vsald: u64,
    /// Official `VLE` loads emitted (partial-sum reloads).
    pub vle: u64,
    /// `VSAM`/`VSAC` tensor bursts emitted.
    pub vsam: u64,
    /// `VSE` stores emitted (output rows + partial spills).
    pub vse: u64,
    /// Configuration instructions emitted (`VSACFG` forms).
    pub cfg_insns: u64,
    /// Total MPTU dataflow stages across all tensor bursts.
    pub total_stages: u64,
    /// Distinct vector registers the stream touches.
    pub vregs_used: u32,
}

/// A compiled operator: the plan to install plus the program segments to
/// run in order. Each [`Segment`] carries the emitter's [`StreamRun`]
/// metadata marking its homogeneous load/tensor/store runs, which the
/// simulator's batch fast path consumes (`Processor::run_segment`).
#[derive(Debug, Clone)]
pub struct CompiledOp {
    /// The plan the simulator installs before running the segments.
    pub plan: OpPlan,
    /// Program segments, run in order.
    pub segments: Vec<Segment>,
    /// Emission summary (instruction mix, stages, register footprint).
    pub summary: CodegenSummary,
}

// Scratch scalar registers used by generated code.
const X_VL: u8 = 30;
const X_IN: u8 = 29;
const X_OUT: u8 = 27;
const X_PART: u8 = 26;
const X_DIM: u8 = 25;

// Vector register allocation: 4-deep input buffering (the VLDU streams
// ahead of the MPTU), double-buffered weights, output tile, partial
// staging — mirrors Fig. 2's small register footprint.
const V_IN: [u8; 4] = [0, 1, 2, 3];
const V_W: [u8; 2] = [4, 5];
const V_OUT: u8 = 8;
const V_PART: u8 = 16;

const SEG_LIMIT: usize = 8192;

/// Where emitted segments go: collected for later runs (small operators,
/// tests, Fig. 2 traces) or streamed straight into a consumer (model-level
/// evaluation, where materializing millions of instructions would be
/// wasteful), or discarded after counting (the sizing pre-pass).
enum Sink<'a> {
    Collect(Vec<Segment>),
    Stream(&'a mut dyn FnMut(Segment) -> Result<(), SpeedError>),
    CountOnly,
}

/// A homogeneous stream run the emitter is currently extending.
struct OpenRun {
    kind: RunKind,
    start: usize,
    len: usize,
    /// Pattern key: the run's body instruction with its per-item fields
    /// (destination register / address) normalized — see [`run_key`].
    key: Insn,
}

/// Normalize a run body instruction to its pattern key: per-item fields
/// (destination/source vector register) are zeroed, uniform fields
/// (mode, width, eew, scalar address register) are kept.
fn run_key(i: &Insn) -> Insn {
    match *i {
        Insn::Vsald { rs1, mode, width, .. } => Insn::Vsald { vd: 0, rs1, mode, width },
        Insn::Vle { rs1, eew, .. } => Insn::Vle { vd: 0, rs1, eew },
        Insn::Vse { rs1, eew, .. } => Insn::Vse { vs3: 0, rs1, eew },
        other => other,
    }
}

struct Emitter<'a> {
    prec: Precision,
    sink: Sink<'a>,
    cur: Vec<Insn>,
    cur_vl: Option<(u32, u32)>,
    /// Carried-residency mapping: the input tensor is layer-(N-1)'s output,
    /// still resident in the VRF, so the generators skip every input fetch
    /// (the reload half of the drain/reload round-trip). `in_flip` stays 0
    /// and tensor bursts read `V_IN[0]` — the register the carried output
    /// occupies. Weight fetches and output drains are unaffected.
    carry_in: bool,
    in_flip: usize,
    w_flip: usize,
    summary: CodegenSummary,
    used: [bool; 32],
    err: Option<SpeedError>,
    /// Stream runs of the current segment (closed runs, ascending start).
    runs: Vec<StreamRun>,
    open_run: Option<OpenRun>,
}

impl<'a> Emitter<'a> {
    fn new(prec: Precision, sink: Sink<'a>) -> Self {
        Emitter {
            prec,
            sink,
            cur: Vec::new(),
            cur_vl: None,
            carry_in: false,
            in_flip: 0,
            w_flip: 0,
            summary: CodegenSummary::default(),
            used: [false; 32],
            err: None,
            runs: Vec::new(),
            open_run: None,
        }
    }

    fn count(&mut self, i: &Insn) {
        self.summary.total_insns += 1;
        for r in i.vregs_read().iter().chain(i.vregs_written().iter()) {
            self.used[*r as usize] = true;
        }
    }

    fn count_only(&self) -> bool {
        matches!(self.sink, Sink::CountOnly)
    }

    /// Append an instruction that is not part of a homogeneous run
    /// (prologue/config code). Breaks any open run.
    fn push(&mut self, i: Insn) {
        self.close_run();
        self.count(&i);
        if self.count_only() {
            return;
        }
        self.cur.push(i);
        if self.cur.len() >= SEG_LIMIT {
            self.cut();
        }
    }

    /// Append a `(scalar address setup, transfer)` pair, extending the
    /// open run when the transfer matches its pattern key.
    fn push_pair(&mut self, kind: RunKind, setup: Insn, body: Insn) {
        self.count(&setup);
        self.count(&body);
        if self.count_only() {
            return;
        }
        if self.cur.len() + 2 > SEG_LIMIT {
            self.cut();
        }
        let key = run_key(&body);
        let extend =
            matches!(&self.open_run, Some(r) if r.kind == kind && r.key == key);
        if !extend {
            self.close_run();
            self.open_run = Some(OpenRun { kind, start: self.cur.len(), len: 0, key });
        }
        self.cur.push(setup);
        self.cur.push(body);
        if let Some(r) = &mut self.open_run {
            r.len += 2;
        }
        if self.cur.len() >= SEG_LIMIT {
            self.cut();
        }
    }

    /// Append one tensor burst, extending a run of identical bursts.
    fn push_tensor(&mut self, i: Insn) {
        self.count(&i);
        if self.count_only() {
            return;
        }
        if self.cur.len() >= SEG_LIMIT {
            self.cut();
        }
        let extend =
            matches!(&self.open_run, Some(r) if r.kind == RunKind::Tensor && r.key == i);
        if !extend {
            self.close_run();
            self.open_run =
                Some(OpenRun { kind: RunKind::Tensor, start: self.cur.len(), len: 0, key: i });
        }
        self.cur.push(i);
        if let Some(r) = &mut self.open_run {
            r.len += 1;
        }
        if self.cur.len() >= SEG_LIMIT {
            self.cut();
        }
    }

    /// Close the open run, recording it when long enough to be worth a
    /// batched dispatch.
    fn close_run(&mut self) {
        if let Some(r) = self.open_run.take() {
            let keep = match r.kind {
                RunKind::Tensor => r.len >= 2,
                _ => r.len >= 4,
            };
            if keep {
                self.runs.push(StreamRun {
                    start: r.start as u32,
                    len: r.len as u32,
                    kind: r.kind,
                });
            }
        }
    }

    /// Close the current segment (hazards still carry across segments —
    /// the simulator's clock persists between runs).
    fn cut(&mut self) {
        self.close_run();
        if self.cur.is_empty() || self.err.is_some() {
            return;
        }
        let seg = Segment {
            insns: std::mem::take(&mut self.cur),
            runs: std::mem::take(&mut self.runs),
        };
        match &mut self.sink {
            Sink::Collect(v) => v.push(seg),
            Sink::Stream(f) => {
                if let Err(e) = f(seg) {
                    self.err = Some(e);
                }
            }
            Sink::CountOnly => {}
        }
    }

    fn li(&mut self, rd: u8, v: i64) {
        // Programmatic form: the i32 immediate may exceed the 12-bit text
        // encoding (a real toolchain emits LUI+ADDI; one insn is charged —
        // addresses are typically produced by ADDI increments anyway).
        self.push(Insn::Addi { rd, rs1: 0, imm: v as i32 });
    }

    fn set_vl(&mut self, vl: u32, sew: u32) {
        if self.cur_vl == Some((vl, sew)) {
            return;
        }
        self.cur_vl = Some((vl, sew));
        self.li(X_VL, vl as i64);
        self.push(Insn::Vsetvli { rd: 0, rs1: X_VL, vtype: Vtype::new(sew) });
        self.summary.cfg_insns += 2;
    }

    fn vsacfg(&mut self, ksize: u32, strat: StrategyKind) {
        let zimm = Insn::pack_cfg(self.prec, ksize.min(15), strat);
        self.push(Insn::Vsacfg { rd: X_DIM, zimm, uimm: 0 });
        self.summary.cfg_insns += 1;
    }

    fn dim(&mut self, d: Dim, v: u32) {
        self.li(X_DIM, v as i64);
        self.push(Insn::VsacfgDim { rd: 0, rs1: X_DIM, dim: d });
        self.summary.cfg_insns += 2;
    }

    /// Broadcast-load `elems` operands to every lane, splitting so each
    /// VSALD's per-lane image fits one vreg region. Returns nothing; the
    /// data lands in the double-buffered input registers.
    fn load_bcast(&mut self, cfg: &SpeedConfig, addr: u64, elems: u64) {
        let per = (vreg_region(cfg) as u64 * 8 / self.prec.bits() as u64).max(1);
        self.load_split(addr, elems, per, LdMode::Broadcast, &V_IN, true);
    }

    /// Sequential (lane-striped) load of `elems` operands into the weight
    /// registers; each VSALD moves up to lanes × region bytes.
    fn load_seq_w(&mut self, cfg: &SpeedConfig, addr: u64, elems: u64) {
        let per =
            (cfg.lanes as u64 * vreg_region(cfg) as u64 * 8 / self.prec.bits() as u64).max(1);
        self.load_split(addr, elems, per, LdMode::Sequential, &V_W, false);
    }

    /// Sequential load into the input registers (MM A-tiles).
    fn load_seq_in(&mut self, cfg: &SpeedConfig, addr: u64, elems: u64) {
        let per =
            (cfg.lanes as u64 * vreg_region(cfg) as u64 * 8 / self.prec.bits() as u64).max(1);
        self.load_split(addr, elems, per, LdMode::Sequential, &V_IN, true);
    }

    fn load_split(
        &mut self,
        addr: u64,
        elems: u64,
        per: u64,
        mode: LdMode,
        regs: &[u8],
        is_input: bool,
    ) {
        let mut off = 0u64;
        while off < elems {
            let n = per.min(elems - off) as u32;
            self.set_vl(n, self.prec.bits().max(8));
            let a = addr + self.prec.bytes_for(off);
            let flip = if is_input { &mut self.in_flip } else { &mut self.w_flip };
            let vd = regs[*flip % regs.len()];
            *flip += 1;
            self.push_pair(
                RunKind::Load,
                Insn::Addi { rd: X_IN, rs1: 0, imm: (a as i64) as i32 },
                Insn::Vsald { vd, rs1: X_IN, mode, width: WidthSel::FromCfg },
            );
            self.summary.vsald += 1;
            off += n as u64;
        }
    }

    /// Emit `stages` MPTU stages as VSAM bursts of ≤ 127.
    fn vsam(&mut self, stages: u64) {
        self.tensor_bursts(stages, false);
    }

    /// Emit `stages` MPTU stages as VSAC (matrix–vector) bursts — the
    /// GEMV form used when one output dimension degenerates (batch-1 FC
    /// layers / classifier heads).
    fn vsac(&mut self, stages: u64) {
        self.tensor_bursts(stages, true);
    }

    fn tensor_bursts(&mut self, mut stages: u64, vector_form: bool) {
        self.summary.total_stages += stages;
        while stages > 0 {
            let burst = stages.min(127) as u8;
            let vin = V_IN[(self.in_flip.max(1) - 1) % V_IN.len()];
            let vw = V_W[(self.w_flip.max(1) - 1) % V_W.len()];
            let insn = if vector_form {
                Insn::Vsac { vd: V_OUT, vs1: vin, vs2: vw, stages: burst }
            } else {
                Insn::Vsam { vd: V_OUT, vs1: vin, vs2: vw, stages: burst }
            };
            self.push_tensor(insn);
            self.summary.vsam += 1;
            stages -= burst as u64;
        }
    }

    /// Store one output row of `elems` i32 accumulators at `addr`.
    fn store_row(&mut self, addr: u64, elems: u64) {
        self.set_vl(elems as u32, 32);
        self.push_pair(
            RunKind::Store,
            Insn::Addi { rd: X_OUT, rs1: 0, imm: (addr as i64) as i32 },
            Insn::Vse { vs3: V_OUT, rs1: X_OUT, eew: 32 },
        );
        self.summary.vse += 1;
    }

    /// Spill `elems` i32 partials to the partial region at `addr`.
    fn spill_partial(&mut self, addr: u64, elems: u64) {
        self.set_vl(elems as u32, 32);
        self.push_pair(
            RunKind::Store,
            Insn::Addi { rd: X_PART, rs1: 0, imm: (addr as i64) as i32 },
            Insn::Vse { vs3: V_PART, rs1: X_PART, eew: 32 },
        );
        self.summary.vse += 1;
    }

    /// Reload `elems` i32 partials from the partial region.
    fn reload_partial(&mut self, addr: u64, elems: u64) {
        self.set_vl(elems as u32, 32);
        self.push_pair(
            RunKind::Load,
            Insn::Addi { rd: X_PART, rs1: 0, imm: (addr as i64) as i32 },
            Insn::Vle { vd: V_PART, rs1: X_PART, eew: 32 },
        );
        self.summary.vle += 1;
    }

    fn finish(mut self) -> Result<(Vec<Segment>, CodegenSummary), SpeedError> {
        self.cut();
        if let Some(e) = self.err {
            return Err(e);
        }
        self.summary.vregs_used = self.used.iter().filter(|&&b| b).count() as u32;
        let segs = match self.sink {
            Sink::Collect(v) => v,
            _ => Vec::new(),
        };
        Ok((segs, self.summary))
    }
}

fn generate<'a>(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
    layout: &MemLayout,
    sink: Sink<'a>,
) -> Result<(Vec<Segment>, CodegenSummary), SpeedError> {
    let strat = choice.strat;
    // The chunk is resolved once (clamped to a PP multiple the VRF fits —
    // see `dataflow::resolve_chunk`) and drives every chunked loop below.
    // Stage totals are chunk-invariant, so any resolved chunk produces the
    // same plan sizing and bit-identical outputs. The MM B-tile column
    // block resolves the same way (a TILE_C multiple one vreg region
    // fits); `None` keeps the static per-tile load structure.
    let chunk = dataflow::resolve_chunk(op, cfg, strat, choice.chunk);
    let jchunk = dataflow::resolve_jchunk(op, cfg, strat, choice.jchunk, chunk);
    let mut e = Emitter::new(op.prec, sink);
    e.carry_in = choice.carry_in;
    // Prologue: configuration-setting instructions (Fig. 9 step ①).
    e.vsacfg(op.ksize.max(1), strat);
    match op.kind {
        OpKind::Mm => {
            e.dim(Dim::M, op.m);
            e.dim(Dim::K, op.k);
            e.dim(Dim::N, op.n);
        }
        _ => {
            e.dim(Dim::C, op.c);
            e.dim(Dim::F, op.f);
            e.dim(Dim::H, op.h);
            e.dim(Dim::W, op.w);
            e.dim(Dim::Stride, op.stride);
        }
    }
    match strat {
        StrategyKind::Mm => gen_mm(&mut e, op, cfg, layout, chunk, jchunk),
        StrategyKind::Ffcs => gen_ffcs(&mut e, op, cfg, layout, chunk),
        StrategyKind::Cf => gen_cf(&mut e, op, cfg, layout, chunk),
        StrategyKind::Ff => gen_ff(&mut e, op, cfg, layout, chunk),
    }
    e.finish()
}

fn check(op: &OpDesc, cfg: &SpeedConfig, choice: MappingChoice) -> Result<(), SpeedError> {
    op.validate()?;
    cfg.validate()?;
    // The 4-bit VSACFG kernel field caps ksize at 15; anything larger must
    // be Kseg-decomposed upstream. Typed rejection here — the emitter's
    // `pack_cfg` would truncate the field in release builds.
    Insn::try_pack_cfg(op.prec, op.ksize.max(1), choice.strat)?;
    if !dataflow::applicable(choice.strat, op) {
        return Err(SpeedError::Compile(format!(
            "strategy {} not applicable to {}",
            choice.strat, op.kind
        )));
    }
    // Non-resident FF shapes are not rejected here: `gen_ff` emits the
    // real per-row refetch runs for the weight tail past
    // `dataflow::ff_resident_f`, so the stream the simulator, cost model,
    // and verifier see is honest — spill is a costed mapping property
    // (`Mapping::weight_refetches`), not a compile error.
    if choice.carry_in && !dataflow::carry_input_fits(op, cfg) {
        return Err(SpeedError::Layout(format!(
            "carry-in mapping declared but the input tensor ({} B) cannot \
             stay resident in the VRF output partition ({} B/lane over {} \
             lanes)",
            op.input_bytes(),
            dataflow::partition_budget(cfg),
            cfg.lanes
        )));
    }
    Ok(())
}

/// Compile `op` under `strat` into an executable instruction stream.
pub fn compile_op(
    op: &OpDesc,
    cfg: &SpeedConfig,
    strat: StrategyKind,
    layout: MemLayout,
    functional: bool,
) -> Result<CompiledOp, SpeedError> {
    compile_op_with(op, cfg, MappingChoice::of(strat), layout, functional)
}

/// [`compile_op`] with an explicit mapping choice (strategy + optional
/// chunk override): the auto-tuner's compilation entry point. Chunk
/// overrides never change plan sizing or outputs — only the load/store
/// structure of the stream.
pub fn compile_op_with(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
    layout: MemLayout,
    functional: bool,
) -> Result<CompiledOp, SpeedError> {
    check(op, cfg, choice)?;
    let (segments, summary) = generate(op, cfg, choice, &layout, Sink::Collect(Vec::new()))?;
    let plan = OpPlan {
        desc: *op,
        strat: choice.strat,
        in_addr: layout.in_addr,
        w_addr: layout.w_addr,
        out_addr: layout.out_addr,
        partial_addr: layout.partial_addr,
        total_stages: summary.total_stages.max(1),
        functional,
    };
    Ok(CompiledOp { plan, segments, summary })
}

/// Instruction-mix summary without materializing the stream (sizing pass).
pub fn summarize_op(
    op: &OpDesc,
    cfg: &SpeedConfig,
    strat: StrategyKind,
    layout: &MemLayout,
) -> Result<CodegenSummary, SpeedError> {
    summarize_op_with(op, cfg, MappingChoice::of(strat), layout)
}

/// [`summarize_op`] with an explicit mapping choice.
pub fn summarize_op_with(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
    layout: &MemLayout,
) -> Result<CodegenSummary, SpeedError> {
    check(op, cfg, choice)?;
    let (_, summary) = generate(op, cfg, choice, layout, Sink::CountOnly)?;
    Ok(summary)
}

/// Generate the instruction stream segment-by-segment into `feed` without
/// materializing it (the execute-many path of a cached program whose
/// stream is too large to keep resident). Each fed [`Segment`] carries its
/// stream-run metadata. Returns the emission summary.
pub fn stream_op(
    op: &OpDesc,
    cfg: &SpeedConfig,
    strat: StrategyKind,
    layout: &MemLayout,
    feed: &mut dyn FnMut(Segment) -> Result<(), SpeedError>,
) -> Result<CodegenSummary, SpeedError> {
    stream_op_with(op, cfg, MappingChoice::of(strat), layout, feed)
}

/// [`stream_op`] with an explicit mapping choice.
pub fn stream_op_with(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
    layout: &MemLayout,
    feed: &mut dyn FnMut(Segment) -> Result<(), SpeedError>,
) -> Result<CodegenSummary, SpeedError> {
    check(op, cfg, choice)?;
    let (_, summary) = generate(op, cfg, choice, layout, Sink::Stream(feed))?;
    Ok(summary)
}

/// Compile and execute `op` on `proc` without materializing the stream:
/// a counting pre-pass sizes the plan, then segments are generated and fed
/// to the simulator as they fill. Returns this operator's stats + summary.
pub fn execute_op(
    proc: &mut crate::sim::Processor,
    op: &OpDesc,
    strat: StrategyKind,
    layout: MemLayout,
    functional: bool,
) -> Result<(crate::sim::SimStats, CodegenSummary), SpeedError> {
    let cfg = proc.cfg;
    let choice = MappingChoice::of(strat);
    check(op, &cfg, choice)?;
    let sized = generate(op, &cfg, choice, &layout, Sink::CountOnly)?.1;
    proc.set_plan(OpPlan {
        desc: *op,
        strat,
        in_addr: layout.in_addr,
        w_addr: layout.w_addr,
        out_addr: layout.out_addr,
        partial_addr: layout.partial_addr,
        total_stages: sized.total_stages.max(1),
        functional,
    });
    let mut stats = crate::sim::SimStats::default();
    {
        let mut feed = |seg: Segment| -> Result<(), SpeedError> {
            let st = proc.run_segment(&seg)?;
            stats.merge(&st);
            Ok(())
        };
        generate(op, &cfg, choice, &layout, Sink::Stream(&mut feed))?;
    }
    Ok((stats, sized))
}

/// MM: weights multi-broadcast, inputs reused across stages, PE
/// output-stationary across K chunks (Fig. 6). `kc` is the resolved
/// reduction-dim chunk (default: [`dataflow::mm_k_chunk`]); `jc` the
/// resolved B-tile column block ([`dataflow::resolve_jchunk`], `None` =
/// the static per-`TILE_C`-tile load structure). The column block only
/// coalesces broadcast loads — stage totals, MAC accounting, and output
/// memory are identical for every resolved `jc`.
fn gen_mm(
    e: &mut Emitter,
    op: &OpDesc,
    cfg: &SpeedConfig,
    lay: &MemLayout,
    kc: u32,
    jc: Option<u32>,
) {
    let pp = op.prec.pp();
    let rows_per_block = cfg.lanes * cfg.tile_r;
    let row_blocks = op.m.div_ceil(rows_per_block);
    let col_tiles = op.n.div_ceil(cfg.tile_c);
    let kchunks = op.k.div_ceil(kc);
    for rb in 0..row_blocks {
        let r0 = rb * rows_per_block;
        let rows = rows_per_block.min(op.m - r0);
        for kci in 0..kchunks {
            let k0 = kci * kc;
            let kcur = kc.min(op.k - k0);
            if !e.carry_in {
                // A slice for this row block / K chunk (lane-striped).
                let a_off =
                    lay.in_addr + op.prec.bytes_for((r0 as u64) * op.k as u64 + k0 as u64);
                e.load_seq_in(cfg, a_off, rows as u64 * kcur as u64);
            }
            let stages_per_tile = kcur.div_ceil(pp) as u64;
            // Degenerate output dims (batch-1 FC / classifier heads)
            // use the matrix–vector form VSAC (Sec. II-B).
            let gemv = op.m == 1 || op.n == 1;
            if let Some(jc) = jc {
                // Tuned J-dim structure: one broadcast B load per jc-wide
                // column block, serving every tile inside the block.
                let jblocks = op.n.div_ceil(jc);
                for jb in 0..jblocks {
                    let j0 = jb * jc;
                    let jcur = jc.min(op.n - j0);
                    let b_off = lay.w_addr
                        + op.prec.bytes_for((k0 as u64) * op.n as u64 + j0 as u64);
                    e.load_bcast(cfg, b_off, kcur as u64 * jcur as u64);
                    for _ in 0..jcur.div_ceil(cfg.tile_c) {
                        if gemv {
                            e.vsac(stages_per_tile);
                        } else {
                            e.vsam(stages_per_tile);
                        }
                    }
                }
            } else {
                // When the whole K-chunk of B fits one vreg region, a
                // single multi-broadcast VSALD serves every column tile
                // (the Fig. 2 stream: one weight load, then the VSAM
                // sequence).
                let whole_b = op.prec.bytes_for(kcur as u64 * op.n as u64)
                    <= dataflow::vreg_region(cfg) as u64;
                if whole_b {
                    let b_off = lay.w_addr + op.prec.bytes_for((k0 as u64) * op.n as u64);
                    e.load_bcast(cfg, b_off, kcur as u64 * op.n as u64);
                }
                for ct in 0..col_tiles {
                    let n0 = ct * cfg.tile_c;
                    let ncur = cfg.tile_c.min(op.n - n0);
                    if !whole_b {
                        // B tile broadcast to every lane.
                        let b_off = lay.w_addr
                            + op.prec.bytes_for((k0 as u64) * op.n as u64 + n0 as u64);
                        e.load_bcast(cfg, b_off, kcur as u64 * ncur as u64);
                    }
                    if gemv {
                        e.vsac(stages_per_tile);
                    } else {
                        e.vsam(stages_per_tile);
                    }
                }
            }
        }
        // Drain the completed rows of this block.
        for r in 0..rows {
            let row = (r0 + r) as u64;
            e.store_row(lay.out_addr + row * op.n as u64 * 4, op.n as u64);
        }
        e.cut();
    }
}

/// Number of new input rows the sliding window needs at output row `oy`.
fn rows_new(op: &OpDesc, oy: u32) -> u32 {
    if oy == 0 {
        op.ksize.min(op.h)
    } else {
        op.stride.min(op.h)
    }
}

/// FFCS: feature-map-first, channel-second; inputs stream once, weights
/// re-fetched per feature-map block, partials for all F in the VRF.
fn gen_ffcs(e: &mut Emitter, op: &OpDesc, cfg: &SpeedConfig, lay: &MemLayout, cc: u32) {
    let pp = op.prec.pp();
    let cchunks = op.c.div_ceil(cc);
    let fgroup = cfg.lanes * cfg.tile_c;
    let fgroups = op.f.div_ceil(fgroup);
    let (oh, ow) = (op.oh(), op.ow());
    let kk = op.ksize * op.ksize;
    // Feature-map block: rows whose all-F partials fit the VRF partial
    // partition (per lane: F/lanes outputs per pixel, 4 B each).
    let per_pixel_lane = (op.f.div_ceil(cfg.lanes) as u64) * 4;
    let rows_blk =
        ((partition_budget(cfg) as u64 / (per_pixel_lane * ow as u64).max(1)) as u32).min(oh);
    let spill = rows_blk == 0;
    let rows_blk = rows_blk.max(1);
    let nblocks = oh.div_ceil(rows_blk);

    for blk in 0..nblocks {
        let oy0 = blk * rows_blk;
        let rcur = rows_blk.min(oh - oy0);
        for cci in 0..cchunks {
            let c0 = cci * cc;
            let ccur = cc.min(op.c - c0);
            // Inputs: sliding rows for this block at channels [c0, c0+ccur).
            let mut in_elems = 0u64;
            for oy in oy0..oy0 + rcur {
                in_elems += rows_new(op, oy) as u64 * op.w as u64 * ccur as u64;
            }
            let slab = ccur as u64 * op.h as u64 * op.w as u64;
            let in_off = lay.in_addr
                + op.prec.bytes_for((c0 as u64) * op.h as u64 * op.w as u64);
            if !e.carry_in {
                e.load_bcast(cfg, in_off, in_elems.min(slab));
            }
            if spill && cci > 0 {
                // Reload the block's partials (per output row of the block).
                for r in 0..rcur {
                    let addr = lay.partial_addr + ((oy0 + r) as u64 * ow as u64 * 4);
                    e.reload_partial(addr, ow as u64);
                }
            }
            for fg in 0..fgroups {
                let f0 = fg * fgroup;
                let fcur = fgroup.min(op.f - f0);
                // Weights for this (f-group, channel chunk) — refetched per
                // feature-map block (the FFCS traffic trade-off).
                let w_off = lay.w_addr
                    + op.prec.bytes_for(
                        (f0 as u64) * op.c as u64 * kk as u64 + (c0 as u64) * kk as u64,
                    );
                e.load_seq_w(cfg, w_off, fcur as u64 * ccur as u64 * kk as u64);
                let mut stages =
                    rcur as u64 * (ow.div_ceil(cfg.tile_r) as u64) * (ccur.div_ceil(pp) as u64)
                        * kk as u64;
                if op.ksize == 1 {
                    // Non-overlapped partial round trip per channel pass
                    // (Sec. III-B: PWCV under FFCS suffers frequent VRF
                    // accesses that dominate computation time).
                    stages +=
                        rcur as u64 * (ow.div_ceil(cfg.tile_r) as u64)
                            * (ccur.div_ceil(pp) as u64);
                }
                e.vsam(stages);
            }
            if spill && cci + 1 < cchunks {
                for r in 0..rcur {
                    let addr = lay.partial_addr + ((oy0 + r) as u64 * ow as u64 * 4);
                    e.spill_partial(addr, ow as u64);
                }
            }
        }
        // Store the block's output rows for every output channel.
        for f in 0..op.f {
            for r in 0..rcur {
                let row = f as u64 * oh as u64 + (oy0 + r) as u64;
                e.store_row(lay.out_addr + row * ow as u64 * 4, ow as u64);
            }
        }
        e.cut();
    }
}

/// CF: channel-first; PE-internal accumulation across all C, inputs
/// re-streamed once per output-channel group (Sec. III-B).
fn gen_cf(e: &mut Emitter, op: &OpDesc, cfg: &SpeedConfig, lay: &MemLayout, cc: u32) {
    let pp = op.prec.pp();
    let cchunks = op.c.div_ceil(cc);
    let fgroup = cfg.lanes * cfg.tile_c;
    let fgroups = op.f.div_ceil(fgroup);
    let (oh, ow) = (op.oh(), op.ow());
    let kk = op.ksize * op.ksize;
    for fg in 0..fgroups {
        let f0 = fg * fgroup;
        let fcur = fgroup.min(op.f - f0);
        for oy in 0..oh {
            // Inputs for this output row: *all* channels' window rows —
            // the full-input re-stream per f-group that makes CF's traffic
            // the highest of the three (Fig. 10).
            let rn = rows_new(op, oy) as u64;
            if !e.carry_in {
                e.load_bcast(cfg, lay.in_addr, rn * op.w as u64 * op.c as u64);
            }
            for cci in 0..cchunks {
                let c0 = cci * cc;
                let ccur = cc.min(op.c - c0);
                let w_off = lay.w_addr
                    + op.prec.bytes_for(
                        (f0 as u64) * op.c as u64 * kk as u64 + (c0 as u64) * kk as u64,
                    );
                e.load_seq_w(cfg, w_off, fcur as u64 * ccur as u64 * kk as u64);
                e.vsam(
                    (ow.div_ceil(cfg.tile_r) as u64) * (ccur.div_ceil(pp) as u64) * kk as u64,
                );
            }
        }
        for f in 0..fcur {
            for oy in 0..oh {
                let row = (f0 + f) as u64 * oh as u64 + oy as u64;
                e.store_row(lay.out_addr + row * ow as u64 * 4, ow as u64);
            }
        }
        e.cut();
    }
}

/// FF: feature-map-first per channel (DWCV native; CONV/PWCV ablation).
/// `cc` is the resolved channel chunk for the CONV/PWCV arm (DWCV has no
/// channel chunking; its chunk resolves to PP and is unused here).
fn gen_ff(e: &mut Emitter, op: &OpDesc, cfg: &SpeedConfig, lay: &MemLayout, cc: u32) {
    let pp = op.prec.pp();
    let (oh, ow) = (op.oh(), op.ow());
    let kk = op.ksize * op.ksize;
    if op.kind == OpKind::Dwcv {
        let cgroup = cfg.lanes * pp;
        let cgroups = op.c.div_ceil(cgroup);
        for cg in 0..cgroups {
            let c0 = cg * cgroup;
            let ccur = cgroup.min(op.c - c0);
            // Weights: tiny, resident for the whole group.
            let w_off = lay.w_addr + op.prec.bytes_for((c0 as u64) * kk as u64);
            e.load_seq_w(cfg, w_off, ccur as u64 * kk as u64);
            for oy in 0..oh {
                let rn = rows_new(op, oy) as u64;
                if !e.carry_in {
                    e.load_bcast(cfg, lay.in_addr
                        + op.prec.bytes_for((c0 as u64) * op.h as u64 * op.w as u64),
                        rn * op.w as u64 * ccur as u64);
                }
                e.vsam(
                    (ow.div_ceil(cfg.tile_r * cfg.tile_c) as u64) * kk as u64,
                );
            }
            for c in 0..ccur {
                for oy in 0..oh {
                    let row = (c0 + c) as u64 * oh as u64 + oy as u64;
                    e.store_row(lay.out_addr + row * ow as u64 * 4, ow as u64);
                }
            }
            e.cut();
        }
    } else {
        // FF on CONV/PWCV: inputs stream exactly once. The channel chunk's
        // weights split at `dataflow::ff_resident_f`: the resident prefix
        // (all of F when the shape fits — the lowest-traffic arm of
        // Fig. 10) is fetched once per chunk, and the tail past `rf`
        // output channels is re-streamed for every output row after the
        // first — the same honest refetch the cost model charges via
        // `Mapping::weight_refetches`. Partials round-trip the result path
        // per channel pass and spill off-chip only when the output image
        // exceeds the VRF.
        let cchunks = op.c.div_ceil(cc);
        let fgroup = cfg.lanes * cfg.tile_c;
        let fgroups = op.f.div_ceil(fgroup);
        let fits = (op.output_bytes() / cfg.lanes as u64) <= partition_budget(cfg) as u64;
        for cci in 0..cchunks {
            let c0 = cci * cc;
            let ccur = cc.min(op.c - c0);
            // Resident-prefix weights for this channel chunk, once.
            let rf = dataflow::ff_resident_f(op, cfg, ccur);
            let w_off = lay.w_addr + op.prec.bytes_for((c0 as u64) * kk as u64);
            if rf > 0 {
                e.load_seq_w(cfg, w_off, rf as u64 * ccur as u64 * kk as u64);
            }
            // Non-resident weight tail: streamed in full on the first row
            // (completing the initial fetch) and re-streamed per row after
            // it — `(oh - 1) · tail` refetched elements for this chunk.
            let tail = (op.f - rf) as u64 * ccur as u64 * kk as u64;
            let tail_off = w_off + op.prec.bytes_for(rf as u64 * ccur as u64 * kk as u64);
            for oy in 0..oh {
                if tail > 0 {
                    e.load_seq_w(cfg, tail_off, tail);
                }
                let rn = rows_new(op, oy) as u64;
                let in_off = lay.in_addr
                    + op.prec.bytes_for((c0 as u64) * op.h as u64 * op.w as u64);
                if !e.carry_in {
                    e.load_bcast(cfg, in_off, rn * op.w as u64 * ccur as u64);
                }
                if !fits && cchunks > 1 && cci > 0 {
                    e.reload_partial(lay.partial_addr + oy as u64 * ow as u64 * 4, ow as u64);
                }
                for _fg in 0..fgroups {
                    let mut stages = (ow.div_ceil(cfg.tile_r) as u64)
                        * (ccur.div_ceil(pp) as u64)
                        * kk as u64;
                    if op.ksize == 1 {
                        // Per-channel-pass partial round trip (as FFCS).
                        stages +=
                            (ow.div_ceil(cfg.tile_r) as u64) * (ccur.div_ceil(pp) as u64);
                    }
                    e.vsam(stages);
                }
                if !fits && cchunks > 1 && cci + 1 < cchunks {
                    e.spill_partial(lay.partial_addr + oy as u64 * ow as u64 * 4, ow as u64);
                }
            }
            e.cut();
        }
        for f in 0..op.f {
            for oy in 0..oh {
                let row = f as u64 * oh as u64 + oy as u64;
                e.store_row(lay.out_addr + row * ow as u64 * 4, ow as u64);
            }
        }
        e.cut();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Processor;

    fn run_op_choice(
        op: &OpDesc,
        cfg: &SpeedConfig,
        choice: MappingChoice,
        inputs: &[i32],
        weights: &[i32],
    ) -> (Vec<i32>, crate::sim::SimStats, CodegenSummary) {
        let mut p = Processor::new(*cfg, 1 << 22);
        let layout = MemLayout::for_op(op, 1 << 22).unwrap();
        p.mem.preload_packed(layout.in_addr, inputs, op.prec);
        p.mem.preload_packed(layout.w_addr, weights, op.prec);
        let compiled = compile_op_with(op, cfg, choice, layout, true).unwrap();
        p.set_plan(compiled.plan);
        let mut total = crate::sim::SimStats::default();
        for seg in &compiled.segments {
            let st = p.run_segment(seg).unwrap();
            total.merge(&st);
        }
        let out = p.mem.inspect_i32(layout.out_addr, op.output_elems() as usize);
        (out, total, compiled.summary)
    }

    fn run_op(
        op: &OpDesc,
        cfg: &SpeedConfig,
        strat: StrategyKind,
        inputs: &[i32],
        weights: &[i32],
    ) -> (Vec<i32>, crate::sim::SimStats) {
        let (out, st, _) = run_op_choice(op, cfg, MappingChoice::of(strat), inputs, weights);
        (out, st)
    }

    fn seeded(n: usize, prec: Precision, seed: u64) -> Vec<i32> {
        // One deterministic operand generator crate-wide: the parity
        // tests in `tune` must exercise the same value distribution.
        crate::tune::seeded_operands(n, prec, seed)
    }

    fn mm_ref(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    out[i * n + j] =
                        out[i * n + j].wrapping_add(a[i * k + kk].wrapping_mul(b[kk * n + j]));
                }
            }
        }
        out
    }

    #[test]
    fn mm_compiled_stream_computes_correctly() {
        let cfg = SpeedConfig::reference();
        for prec in Precision::ALL {
            let op = OpDesc::mm(12, 16, 10, prec);
            let a = seeded(12 * 16, prec, 7);
            let b = seeded(16 * 10, prec, 11);
            let (out, st) = run_op(&op, &cfg, StrategyKind::Mm, &a, &b);
            assert_eq!(out, mm_ref(&a, &b, 12, 16, 10), "{prec}");
            assert_eq!(st.macs, op.total_macs());
            assert!(st.cycles > 0);
        }
    }

    #[test]
    fn conv_compiled_stream_all_strategies_agree() {
        let cfg = SpeedConfig::reference();
        let op = OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8);
        let x = seeded(op.input_elems() as usize, op.prec, 3);
        let w = seeded(op.weight_elems() as usize, op.prec, 5);
        let (o1, s1) = run_op(&op, &cfg, StrategyKind::Ffcs, &x, &w);
        let (o2, s2) = run_op(&op, &cfg, StrategyKind::Cf, &x, &w);
        let (o3, s3) = run_op(&op, &cfg, StrategyKind::Ff, &x, &w);
        assert_eq!(o1, o2);
        assert_eq!(o2, o3);
        // Numerics agree; traffic must differ (the whole point of Fig. 10):
        // CF re-streams inputs per f-group, FFCS does not.
        assert!(s2.traffic.input_read > s1.traffic.input_read,
            "CF {} !> FFCS {}", s2.traffic.input_read, s1.traffic.input_read);
        let _ = s3;
    }

    #[test]
    fn dwcv_ff_stream_computes_correctly() {
        let cfg = SpeedConfig::reference();
        let op = OpDesc::dwcv(6, 9, 9, 3, 2, 1, Precision::Int8);
        let x = seeded(op.input_elems() as usize, op.prec, 13);
        let w = seeded(op.weight_elems() as usize, op.prec, 17);
        let (out, st) = run_op(&op, &cfg, StrategyKind::Ff, &x, &w);
        // Oracle via the sim's own functional engine (tested independently
        // against hand values in sim::mptu).
        let mut mem = crate::sim::ExtMem::new(1 << 20);
        mem.preload_packed(0, &x, op.prec);
        mem.preload_packed(0x8000, &w, op.prec);
        let plan = crate::sim::OpPlan {
            desc: op,
            strat: StrategyKind::Ff,
            in_addr: 0,
            w_addr: 0x8000,
            out_addr: 0x10000,
            partial_addr: u64::MAX,
            total_stages: 1,
            functional: true,
        };
        let rows = crate::sim::mptu::compute_output_rows(&mem, &plan);
        let want = rows.into_flat();
        assert_eq!(out, want);
        assert_eq!(st.macs, op.total_macs());
    }

    #[test]
    fn pwcv_cf_faster_but_more_traffic_than_ffcs() {
        let cfg = SpeedConfig::reference();
        let op = OpDesc::pwcv(64, 64, 12, 12, Precision::Int16);
        let x = seeded(op.input_elems() as usize, op.prec, 23);
        let w = seeded(op.weight_elems() as usize, op.prec, 29);
        let (o1, ffcs) = run_op(&op, &cfg, StrategyKind::Ffcs, &x, &w);
        let (o2, cf) = run_op(&op, &cfg, StrategyKind::Cf, &x, &w);
        assert_eq!(o1, o2);
        // The paper's trade-off: CF prioritizes performance, FFCS memory.
        assert!(cf.ops_per_cycle() > ffcs.ops_per_cycle(),
                "CF {} !> FFCS {}", cf.ops_per_cycle(), ffcs.ops_per_cycle());
        assert!(cf.traffic.total() > ffcs.traffic.total());
    }

    #[test]
    fn summary_counts_are_consistent() {
        let cfg = SpeedConfig::reference();
        let op = OpDesc::conv(8, 8, 8, 8, 3, 1, 1, Precision::Int8);
        let layout = MemLayout::for_op(&op, 1 << 22).unwrap();
        let c = compile_op(&op, &cfg, StrategyKind::Ffcs, layout, true).unwrap();
        let n: usize = c.segments.iter().map(|s| s.len()).sum();
        assert_eq!(n as u64, c.summary.total_insns);
        assert_eq!(c.plan.total_stages, c.summary.total_stages);
        assert!(c.summary.vsam > 0 && c.summary.vsald > 0 && c.summary.vse > 0);
        // SPEED's register economy (Fig. 2): small vreg footprint.
        assert!(c.summary.vregs_used <= 8, "{}", c.summary.vregs_used);
    }

    #[test]
    fn stream_runs_are_well_formed_and_cover_hot_insns() {
        use crate::isa::RunKind;
        let cfg = SpeedConfig::reference();
        for (op, strat) in [
            (OpDesc::mm(16, 48, 16, Precision::Int8), StrategyKind::Mm),
            (OpDesc::conv(8, 8, 12, 12, 3, 1, 1, Precision::Int16), StrategyKind::Ffcs),
            (OpDesc::pwcv(16, 16, 10, 10, Precision::Int4), StrategyKind::Cf),
        ] {
            let layout = MemLayout::for_op(&op, 1 << 24).unwrap();
            let c = compile_op(&op, &cfg, strat, layout, false).unwrap();
            let mut covered = 0u64;
            for seg in &c.segments {
                let mut last_end = 0u32;
                for r in &seg.runs {
                    assert!(r.start >= last_end, "overlapping runs");
                    assert!((r.start + r.len) as usize <= seg.len(), "run past segment");
                    last_end = r.start + r.len;
                    covered += r.len as u64;
                    match r.kind {
                        RunKind::Tensor => {
                            let first = seg.insns[r.start as usize];
                            assert!(seg.insns
                                [r.start as usize..(r.start + r.len) as usize]
                                .iter()
                                .all(|i| *i == first));
                        }
                        RunKind::Load | RunKind::Store => {
                            assert_eq!(r.len % 2, 0, "pair runs have even length");
                        }
                    }
                }
            }
            // Stage-heavy conv streams are dominated by VSAM burst chains
            // and row-drain sequences — the bulk must be marked as runs.
            // (MM interleaves single B-tile loads with single VSAMs, so
            // only its store sequences form runs; no coverage bound there.)
            if strat == StrategyKind::Ffcs {
                assert!(
                    covered * 2 >= c.summary.total_insns,
                    "{op:?} {strat}: only {covered} of {} insns in runs",
                    c.summary.total_insns
                );
            } else {
                assert!(covered > 0, "{op:?} {strat}: no runs marked");
            }
        }
    }

    #[test]
    fn chunk_override_preserves_outputs_and_stages() {
        // A chunk override reshapes the load/store structure only: the
        // stage total, MAC count, and output memory must be bit-identical
        // to the default chunk for every candidate the tuner may try.
        let cfg = SpeedConfig::reference();
        for (op, strat) in [
            (OpDesc::mm(12, 48, 10, Precision::Int8), StrategyKind::Mm),
            (OpDesc::conv(16, 8, 10, 10, 3, 1, 1, Precision::Int8), StrategyKind::Ffcs),
            (OpDesc::pwcv(32, 16, 8, 8, Precision::Int16), StrategyKind::Cf),
            (OpDesc::conv(16, 8, 10, 10, 3, 1, 1, Precision::Int8), StrategyKind::Ff),
        ] {
            let x = seeded(op.input_elems() as usize, op.prec, 31);
            let w = seeded(op.weight_elems() as usize, op.prec, 37);
            let (base_out, base_st, base_sum) =
                run_op_choice(&op, &cfg, MappingChoice::of(strat), &x, &w);
            let cands = dataflow::chunk_candidates(&op, &cfg, strat);
            assert!(!cands.is_empty(), "{op:?} {strat}: no chunk candidates");
            for c in cands {
                let choice = MappingChoice { chunk: Some(c), ..MappingChoice::of(strat) };
                let (out, st, sum) = run_op_choice(&op, &cfg, choice, &x, &w);
                assert_eq!(out, base_out, "{op:?} {choice}");
                assert_eq!(st.macs, base_st.macs, "{op:?} {choice}");
                assert_eq!(sum.total_stages, base_sum.total_stages, "{op:?} {choice}");
            }
        }
    }

    #[test]
    fn mm_jchunk_override_preserves_outputs_and_stages() {
        // Widening the B-tile column block coalesces broadcast loads only:
        // output memory, MACs, and stage totals stay bit-identical, while
        // the wide MM's load count strictly drops (the win the J-dim arm
        // of the tuner search exists to find).
        let cfg = SpeedConfig::reference();
        for op in [
            OpDesc::mm(12, 48, 24, Precision::Int8),
            OpDesc::mm(16, 64, 192, Precision::Int16),
            OpDesc::mm(1, 32, 40, Precision::Int4), // GEMV form
        ] {
            let x = seeded(op.input_elems() as usize, op.prec, 41);
            let w = seeded(op.weight_elems() as usize, op.prec, 43);
            let base = MappingChoice::of(StrategyKind::Mm);
            let (base_out, base_st, base_sum) = run_op_choice(&op, &cfg, base, &x, &w);
            let cands = dataflow::jchunk_candidates(&op, &cfg, StrategyKind::Mm);
            assert!(!cands.is_empty(), "{op:?}: no J-dim candidates");
            for j in cands {
                let choice = MappingChoice { jchunk: Some(j), ..base };
                let (out, st, sum) = run_op_choice(&op, &cfg, choice, &x, &w);
                assert_eq!(out, base_out, "{op:?} {choice}");
                assert_eq!(st.macs, base_st.macs, "{op:?} {choice}");
                assert_eq!(sum.total_stages, base_sum.total_stages, "{op:?} {choice}");
                assert!(
                    sum.vsald <= base_sum.vsald,
                    "{op:?} {choice}: {} loads vs {}",
                    sum.vsald,
                    base_sum.vsald
                );
            }
        }
    }

    #[test]
    fn ff_weight_spill_compiles_and_refetches_honestly() {
        // Boundary pair from dataflow::ff_residency_boundary_at_large_f:
        // F = 604 is the last resident shape on the reference config,
        // F = 608 spills the weight tail. Both compile under FF — the
        // spilled stream re-fetches the non-resident tail per output row
        // instead of being rejected — and both agree bit-exactly with
        // FFCS. The stream's measured weight traffic must equal the
        // mapping's declared accounting: one full fetch plus
        // `ff_weight_refetches` re-streamed elements.
        let cfg = SpeedConfig::reference();
        for (f, spilled) in [(604u32, false), (608u32, true)] {
            let op = OpDesc::conv(8, f, 6, 6, 3, 1, 1, Precision::Int8);
            assert_eq!(dataflow::ff_weights_resident(&op, &cfg), !spilled, "F={f}");
            let x = seeded(op.input_elems() as usize, op.prec, 47);
            let w = seeded(op.weight_elems() as usize, op.prec, 53);
            let (ff, ff_st, _) =
                run_op_choice(&op, &cfg, MappingChoice::of(StrategyKind::Ff), &x, &w);
            let (ffcs, _, _) =
                run_op_choice(&op, &cfg, MappingChoice::of(StrategyKind::Ffcs), &x, &w);
            assert_eq!(ff, ffcs, "F={f}");
            assert_eq!(ff_st.macs, op.total_macs(), "F={f}");
            let refetch = dataflow::ff_weight_refetches(&op, &cfg, None);
            assert_eq!(spilled, refetch > 0, "F={f}");
            assert_eq!(
                ff_st.traffic.weight_read,
                op.prec.bytes_for(op.weight_elems() + refetch),
                "F={f}"
            );
        }
    }

    #[test]
    fn carry_in_elides_input_loads_only() {
        // A carried mapping (layer N-1's output still resident in the VRF)
        // skips the input-reload half of the drain/reload round-trip:
        // zero input bytes read, identical outputs and weight traffic,
        // strictly fewer instructions.
        let cfg = SpeedConfig::reference();
        let op = OpDesc::mm(1, 128, 256, Precision::Int8);
        let x = seeded(op.input_elems() as usize, op.prec, 59);
        let w = seeded(op.weight_elems() as usize, op.prec, 61);
        let base = MappingChoice::of(StrategyKind::Mm);
        let carry = MappingChoice { carry_in: true, ..base };
        assert!(dataflow::carry_input_fits(&op, &cfg));
        let (o1, s1, sum1) = run_op_choice(&op, &cfg, base, &x, &w);
        let (o2, s2, sum2) = run_op_choice(&op, &cfg, carry, &x, &w);
        assert_eq!(o1, o2);
        assert_eq!(s2.traffic.input_read, 0);
        assert!(s1.traffic.input_read > 0);
        assert_eq!(s1.traffic.weight_read, s2.traffic.weight_read);
        assert!(sum2.total_insns < sum1.total_insns);
        assert!(s2.cycles <= s1.cycles, "carry {} !<= base {}", s2.cycles, s1.cycles);

        // Declaring carry-in on a shape whose input cannot stay resident
        // is a typed Layout error, not a silently-wrong stream.
        let big = OpDesc::conv(256, 64, 64, 64, 3, 1, 1, Precision::Int16);
        assert!(!dataflow::carry_input_fits(&big, &cfg));
        let layout = MemLayout::place(&big).0;
        let choice = MappingChoice { carry_in: true, ..MappingChoice::of(StrategyKind::Ffcs) };
        match compile_op_with(&big, &cfg, choice, layout, false) {
            Err(SpeedError::Layout(m)) => assert!(m.contains("carry"), "{m}"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn incompatible_strategy_rejected() {
        let cfg = SpeedConfig::reference();
        let op = OpDesc::dwcv(8, 8, 8, 3, 1, 1, Precision::Int8);
        let layout = MemLayout::for_op(&op, 1 << 22).unwrap();
        assert!(compile_op(&op, &cfg, StrategyKind::Cf, layout, true).is_err());
        let mm = OpDesc::mm(4, 4, 4, Precision::Int8);
        let layout = MemLayout::for_op(&mm, 1 << 22).unwrap();
        assert!(compile_op(&mm, &cfg, StrategyKind::Ffcs, layout, true).is_err());
    }

    #[test]
    fn layout_rejects_oversized_op() {
        let op = OpDesc::conv(512, 512, 112, 112, 3, 1, 1, Precision::Int16);
        assert!(MemLayout::for_op(&op, 1 << 20).is_err());
    }
}
