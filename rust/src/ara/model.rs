//! Mechanistic cost model of Ara executing DNN operators with official RVV.

use crate::config::Precision;
use crate::models::ops::{OpDesc, OpKind};

/// Ara microarchitectural parameters (defaults follow the 4-lane, 16 KiB
/// VRF instance the paper compares against — Sec. IV-A / Table II).
#[derive(Debug, Clone, Copy)]
pub struct AraParams {
    /// Number of 64-bit lanes.
    pub lanes: u32,
    /// Dispatch + sequencer occupancy per vector instruction (cycles).
    pub issue: u64,
    /// Lane pipeline depth until a result is writeback-visible — the RAW
    /// latency a dependent VMACC chain exposes.
    pub lat_alu: u64,
    /// Memory round-trip latency of a vector load (cycles).
    pub lat_mem: u64,
    /// External-memory bandwidth, bytes/cycle (same port as SPEED's).
    pub mem_bw: u64,
    /// Independent accumulation chains the compiler interleaves to hide
    /// `lat_alu` (software pipelining across output rows/channels).
    pub interleave: u64,
    /// Architectural vector registers usable to cache input rows across
    /// the output-channel sweep (32 minus accumulators/operands/temps).
    pub cache_regs: u32,
}

impl Default for AraParams {
    fn default() -> Self {
        AraParams {
            lanes: 4,
            issue: 3,
            lat_alu: 13,
            lat_mem: 25,
            mem_bw: 16,
            interleave: 2,
            cache_regs: 16,
        }
    }
}

impl AraParams {
    /// Ara executes at SEW ≥ 8: 4-bit operands are processed as 8-bit
    /// (the paper's "lacks native handling for low precision").
    pub fn effective_sew(&self, p: Precision) -> u64 {
        (p.bits() as u64).max(8)
    }

    /// Elements per cycle at a SEW (single-dimension parallelism).
    pub fn throughput(&self, sew: u64) -> u64 {
        (self.lanes as u64 * 64 / sew).max(1)
    }

    /// Cost of one step of a dependent accumulation chain when
    /// `interleave` independent chains hide the lane latency and each
    /// step moves `vl` elements.
    pub fn chain_step(&self, vl: u64, sew: u64) -> u64 {
        let work = vl.div_ceil(self.throughput(sew));
        work.max(self.issue).max(self.lat_alu / self.interleave)
    }
}

/// Cost of one operator on Ara.
#[derive(Debug, Clone, Copy, Default)]
pub struct AraCost {
    /// Total cycles.
    pub cycles: u64,
    /// External-memory bytes read (inputs + weights).
    pub dram_read: u64,
    /// External-memory bytes written (outputs, 32-bit accumulators — same
    /// convention as SPEED for a fair Fig. 10 comparison).
    pub dram_write: u64,
    /// Vector instructions issued.
    pub insns: u64,
    /// Architectural vector registers the schedule occupies.
    pub vregs: u32,
}

impl AraCost {
    /// MAC-ops of `op` per modeled cycle.
    pub fn ops_per_cycle(&self, op: &OpDesc) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        op.total_ops() as f64 / self.cycles as f64
    }

    /// Total DRAM traffic, bytes.
    pub fn dram_total(&self) -> u64 {
        self.dram_read + self.dram_write
    }
}

/// Cost of `op` on Ara (cycle count, DRAM traffic, instruction count).
pub fn ara_cost(op: &OpDesc, p: &AraParams) -> AraCost {
    match op.kind {
        OpKind::Mm => mm_cost(op, p),
        OpKind::Conv | OpKind::Pwcv => conv_cost(op, p),
        OpKind::Dwcv => dwcv_cost(op, p),
    }
}

/// MM on Ara (the Fig. 2 schedule): one accumulator row per output row,
/// `vl = N`; `VMACC.VX` per (row, k) with B rows vector-resident when they
/// fit, A elements fed by the scalar core.
fn mm_cost(op: &OpDesc, p: &AraParams) -> AraCost {
    let sew = p.effective_sew(op.prec);
    let sew_b = sew / 8;
    let (m, k, n) = (op.m as u64, op.k as u64, op.n as u64);

    // Register schedule: B rows + accumulator rows + 2 staging.
    let b_resident = k.min(p.cache_regs as u64);
    let b_reloads = if k > b_resident {
        // B rows beyond the cache are re-fetched once per row block.
        (k - b_resident) * m.div_ceil(p.interleave).max(1)
    } else {
        0
    };
    let loads = k + b_reloads;
    let vmaccs = m * k;
    let stores = m;
    let insns = 1 + loads + vmaccs + stores; // + vsetvli

    // Compute: the compiler interleaves up to 8 output-row accumulators
    // (registers permitting), hiding the lane-pipeline RAW latency.
    let chains = m.min(8).max(1);
    let work = n.div_ceil(p.throughput(sew));
    let step = work.max(p.issue).max(p.lat_alu / chains);
    let compute = vmaccs * step;
    // Loads/stores overlap compute on the separate memory units.
    let load_bytes = (k + b_reloads) * n * sew_b + m * k * sew_b; // B rows + A scalars
    let load_cycles = loads * p.issue + load_bytes.div_ceil(p.mem_bw) + p.lat_mem;
    let store_bytes = m * n * 4;
    let store_cycles = stores * p.issue + store_bytes.div_ceil(p.mem_bw);
    let cycles = compute.max(load_cycles).max(store_cycles) + p.lat_alu;

    AraCost {
        cycles,
        dram_read: load_bytes,
        dram_write: store_bytes,
        insns,
        vregs: (b_resident + chains + 2).min(32) as u32,
    }
}

/// CONV / PWCV on Ara: the measured Ara convolution kernels execute a
/// *dependent* `VLE`/`VMACC.VX` chain per output row — each tap's input
/// row is loaded (one row per (c, ky), reused across the kx taps) and the
/// accumulating `VMACC` depends on it, exposing the full lane-pipeline and
/// memory latencies (the paper's Table I implies ~0.3 ops/cycle on
/// MobileNetV2: essentially un-pipelined chains). Input rows survive
/// across the output-channel sweep only while they fit the register file
/// (no broadcast — the Fig. 10 traffic gap).
fn conv_cost(op: &OpDesc, p: &AraParams) -> AraCost {
    let sew = p.effective_sew(op.prec);
    let sew_b = sew / 8;
    let (c, f) = (op.c as u64, op.f as u64);
    let (oh, ow) = (op.oh() as u64, op.ow() as u64);
    let k = op.ksize as u64;
    let kk = k * k;

    let links = f * oh * c * kk; // VMACC count
    // Row loads: one per (c, ky) tap row, reused across kx; cached across
    // the f-sweep only while C·K rows fit the architectural registers.
    let rows_live = c * k;
    let cached = (p.cache_regs as u64).min(rows_live);
    let loads_per_oy = rows_live + (f - 1) * (rows_live - cached);
    let loads = oh * loads_per_oy;
    let stores = f * oh;
    let insns = 1 + loads + links + stores;

    // Dependent-chain schedule: a link costs its element work, floored by
    // the issue rate and (for short vectors) the exposed lane-pipeline
    // latency. For K >= 3 a loaded row feeds K kx-taps and row loads
    // pipeline behind compute; for PWCV (K = 1) there is nothing to reuse
    // and every link's VLE latency serializes with its consuming VMACC —
    // Sec. IV-C's MobileNetV2 numbers imply exactly this collapse.
    let link_cost = ow.div_ceil(p.throughput(sew)).max(p.issue).max(p.lat_alu / p.interleave);
    let row_bytes = (ow + k - 1) * sew_b;
    let serial_loads = if k == 1 { loads * p.lat_mem } else { 0 };
    let compute = links * link_cost + serial_loads;
    let in_bytes = loads * row_bytes;
    let w_bytes = f * c * kk * sew_b; // scalar-core weight stream, once
    let store_bytes = f * oh * ow * 4;
    let store_cycles = stores * p.issue + store_bytes.div_ceil(p.mem_bw);
    let cycles = compute.max(in_bytes.div_ceil(p.mem_bw)) + store_cycles + p.lat_mem + p.lat_alu;

    AraCost {
        cycles,
        dram_read: in_bytes + w_bytes,
        dram_write: store_bytes,
        insns,
        vregs: 32.min((cached + p.interleave + 2) as u32),
    }
}

/// DWCV on Ara: per (c, oy) a dependent chain of K² VMACCs; strided loads
/// when stride > 1 (vector stride loads run at one element per lane per
/// cycle and drag the skipped elements across the interface).
fn dwcv_cost(op: &OpDesc, p: &AraParams) -> AraCost {
    let sew = p.effective_sew(op.prec);
    let sew_b = sew / 8;
    let c = op.c as u64;
    let (oh, ow) = (op.oh() as u64, op.ow() as u64);
    let k = op.ksize as u64;
    let kk = k * k;
    let stride = op.stride as u64;

    let links = c * oh * kk;
    let loads = c * oh * k; // one (possibly strided) row load per tap row
    let stores = c * oh;
    let insns = 1 + loads + links + stores;

    // Strided loads throttle to `lanes` elements/cycle.
    let link_cost = (ow.div_ceil(p.throughput(sew)) + p.issue).max(p.lat_alu);
    let row_elems = ow * stride.min(2);
    let load_transfer = if stride > 1 {
        ow.div_ceil(p.lanes as u64)
    } else {
        (row_elems * sew_b).div_ceil(p.mem_bw)
    };
    let compute = links * link_cost + loads * (p.lat_mem + load_transfer);
    let in_bytes = loads * row_elems * sew_b;
    let store_bytes = c * oh * ow * 4;
    let store_cycles = stores * p.issue + store_bytes.div_ceil(p.mem_bw);
    let w_bytes = c * kk * sew_b;
    let cycles = compute + store_cycles + p.lat_mem + p.lat_alu;

    AraCost {
        cycles,
        dram_read: in_bytes + w_bytes,
        dram_write: store_bytes,
        insns,
        vregs: 32.min((kk + p.interleave + 2) as u32),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    #[test]
    fn fig2_mm_trace_matches_published_throughput() {
        // Fig. 2: INT16 MM producing a 4x8 output (M=4, K=4, N=8):
        // Ara achieves 4.74 OPs/cycle with 16 VMACCs. The model must land
        // in the same regime (±25%).
        let op = OpDesc::mm(4, 4, 8, Precision::Int16);
        let cost = ara_cost(&op, &AraParams::default());
        let opc = cost.ops_per_cycle(&op);
        assert!((3.5..6.0).contains(&opc), "Ara Fig.2 OPs/cycle = {opc}");
        // 16 VMACC + 4 VSE + loads + vsetvli.
        assert!(cost.insns >= 25 && cost.insns <= 35, "insns = {}", cost.insns);
        assert!(cost.vregs >= 6, "vregs = {}", cost.vregs);
    }

    #[test]
    fn peak_throughput_matches_published_ara() {
        // Large MM at 16-bit approaches Ara's 32 ops/cycle peak
        // (4 lanes x 4 elems x 2 ops) — within pipeline overheads.
        let op = OpDesc::mm(256, 256, 256, Precision::Int16);
        let cost = ara_cost(&op, &AraParams::default());
        let opc = cost.ops_per_cycle(&op);
        assert!((16.0..=32.0).contains(&opc), "Ara large-MM OPs/cycle = {opc}");
    }

    #[test]
    fn small_tensors_collapse() {
        // Fig. 11's driver: Ara's per-instruction overheads dominate tiny
        // operators.
        let big = OpDesc::pwcv(64, 64, 32, 32, Precision::Int16);
        let small = OpDesc::pwcv(8, 8, 4, 4, Precision::Int16);
        let p = AraParams::default();
        let big_opc = ara_cost(&big, &p).ops_per_cycle(&big);
        let small_opc = ara_cost(&small, &p).ops_per_cycle(&small);
        assert!(big_opc > 3.0 * small_opc,
                "expected collapse: big {big_opc} vs small {small_opc}");
    }

    #[test]
    fn no_subbyte_support() {
        // 4-bit ops run at 8-bit cost on Ara: same cycles as Int8.
        let op4 = OpDesc::mm(32, 32, 32, Precision::Int4);
        let op8 = OpDesc::mm(32, 32, 32, Precision::Int8);
        let p = AraParams::default();
        assert_eq!(ara_cost(&op4, &p).cycles, ara_cost(&op8, &p).cycles);
    }

    #[test]
    fn conv_traffic_exceeds_tensor_sizes() {
        // No broadcast + limited register cache => Ara re-fetches inputs
        // across the output-channel sweep.
        let op = OpDesc::pwcv(64, 64, 12, 12, Precision::Int16);
        let cost = ara_cost(&op, &AraParams::default());
        assert!(
            cost.dram_read > 4 * op.input_bytes(),
            "read {} vs input {}",
            cost.dram_read,
            op.input_bytes()
        );
    }

    #[test]
    fn dwcv_strided_loads_slow_it_down() {
        let s1 = OpDesc::dwcv(32, 33, 33, 3, 1, 1, Precision::Int16);
        let s2 = OpDesc::dwcv(32, 33, 33, 3, 2, 1, Precision::Int16);
        let p = AraParams::default();
        let c1 = ara_cost(&s1, &p);
        let c2 = ara_cost(&s2, &p);
        // Stride-2 produces 1/4 the outputs; if loads dominated equally the
        // cycles would drop 4x — the strided-load throttle keeps the ratio
        // well under that.
        assert!(c1.cycles < 4 * c2.cycles, "{} vs {}", c1.cycles, c2.cycles);
    }

    #[test]
    fn costs_are_monotone_in_size() {
        let p = AraParams::default();
        let small = OpDesc::conv(8, 8, 8, 8, 3, 1, 1, Precision::Int16);
        let big = OpDesc::conv(16, 16, 16, 16, 3, 1, 1, Precision::Int16);
        assert!(ara_cost(&big, &p).cycles > ara_cost(&small, &p).cycles);
        assert!(ara_cost(&big, &p).dram_total() > ara_cost(&small, &p).dram_total());
    }
}
