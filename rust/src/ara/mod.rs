//! Ara baseline model (Perotti et al., ASAP'22 — the paper's comparison
//! target for Figs. 2, 10, 11, 12 and Table I).
//!
//! Ara is the pioneering open-source RVV v1.0 processor: four 64-bit
//! lanes, 16 KiB of VRF, official instructions only. Its relevant
//! microarchitectural properties — as the SPEED paper exploits them — are:
//!
//! * **official RVV only**: no configuration/tensor instructions, so DNN
//!   operators decompose into long `VLE`/`VMACC`/`VSE` sequences (Fig. 2);
//! * **single-dimension parallelism**: `lanes × 64/SEW` MACs per cycle,
//!   and no sub-byte support (4-bit workloads execute at 8-bit);
//! * **no multi-broadcast loads**: every lane group re-fetches shared
//!   data, and input rows survive across the output-channel sweep only
//!   while they fit the architectural register file;
//! * **deep lane pipeline**: dependent accumulation chains (`VMACC` into
//!   the same destination) expose the writeback latency on short vectors
//!   — the mechanism behind Ara's collapse on small tensors (Fig. 11).
//!
//! The model is *mechanistic* (instruction schedules with documented
//! constants), not fitted: the constants below come from the Ara paper's
//! published pipeline structure, and the single cross-check point is
//! Fig. 2's 4.74 OPs/cycle INT16 MM trace (see `fig2` tests).

pub mod model;

pub use model::{ara_cost, AraCost, AraParams};
