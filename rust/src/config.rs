//! Hardware configuration of a SPEED instance.
//!
//! SPEED is parameterized exactly as in the paper: a number of scalable
//! modules (lanes), a per-lane MPTU tensor-core geometry (`#TILE_R` ×
//! `#TILE_C`), a per-lane VRF capacity, and an operating frequency. The
//! reference evaluation instance (Sec. IV-A) is 4 lanes, 2×2 tiles, 16 KiB
//! VRF at 1.05 GHz; the Table III instance is 4 lanes with 8×4 tiles.
//!
//! Custom instances are assembled with [`SpeedConfig::builder`], which
//! validates the structural constraints before the configuration can reach
//! an [`Engine`](crate::engine::Engine).

use crate::error::SpeedError;


/// Operand precision of the datapath. SPEED supports runtime switching
/// between these via a single-cycle `VSACFG` update (Sec. II-E).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// 16-bit operands — PP = 1 MAC per PE per cycle.
    Int16,
    /// 8-bit operands — PP = 4 MACs per PE per cycle.
    Int8,
    /// 4-bit operands — PP = 16 MACs per PE per cycle.
    Int4,
}

impl Precision {
    /// Operand width in bits.
    pub fn bits(self) -> u32 {
        match self {
            Precision::Int16 => 16,
            Precision::Int8 => 8,
            Precision::Int4 => 4,
        }
    }

    /// Operand width in bytes as stored in VRF / external memory.
    /// 4-bit operands are nibble-packed, two per byte.
    pub fn bytes_num(self) -> u32 {
        self.bits()
    }

    /// Bytes occupied by `n` operands (nibble packing for 4-bit).
    pub fn bytes_for(self, n: u64) -> u64 {
        (n * self.bits() as u64).div_ceil(8)
    }

    /// Parallelism-within-PE: how many MACs one PE performs per cycle.
    /// Each PE holds sixteen 4-bit multipliers (Fig. 4): one 16-bit MAC,
    /// four 8-bit MACs, or sixteen 4-bit MACs.
    pub fn pp(self) -> u32 {
        match self {
            Precision::Int16 => 1,
            Precision::Int8 => 4,
            Precision::Int4 => 16,
        }
    }

    /// Signed value range (inclusive).
    pub fn range(self) -> (i32, i32) {
        let b = self.bits();
        (-(1 << (b - 1)), (1 << (b - 1)) - 1)
    }

    /// Clamp a value into this precision's range.
    pub fn clamp(self, v: i32) -> i32 {
        let (lo, hi) = self.range();
        v.clamp(lo, hi)
    }

    /// The precision with the given bit width (16, 8, or 4).
    pub fn from_bits(bits: u32) -> Option<Precision> {
        match bits {
            16 => Some(Precision::Int16),
            8 => Some(Precision::Int8),
            4 => Some(Precision::Int4),
            _ => None,
        }
    }

    /// All supported precisions, widest first.
    pub const ALL: [Precision; 3] = [Precision::Int16, Precision::Int8, Precision::Int4];
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "INT{}", self.bits())
    }
}

/// Full hardware configuration of one SPEED instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeedConfig {
    /// Number of scalable modules (lanes). The paper evaluates 2 / 4 / 8.
    pub lanes: u32,
    /// MPTU tensor-core rows per lane (`#TILE_R`) — POI parallelism.
    pub tile_r: u32,
    /// MPTU tensor-core columns per lane (`#TILE_C`) — POW parallelism.
    pub tile_c: u32,
    /// Vector register file capacity per lane, KiB.
    pub vrf_kib: u32,
    /// Typical-corner operating frequency, GHz.
    pub freq_ghz: f64,
    /// External-memory bandwidth, bytes per processor cycle (AXI-style port).
    pub mem_bw_bytes_per_cycle: u32,
    /// External-memory access latency in cycles (first-word).
    pub mem_latency: u32,
}

impl SpeedConfig {
    /// The paper's operator/model evaluation instance (Sec. IV-A):
    /// 4 lanes, 2×2 MPTU, 16 KiB VRF, 1.05 GHz — matched to Ara's
    /// computational resources for the comparisons of Figs. 10–12.
    pub fn reference() -> Self {
        SpeedConfig {
            lanes: 4,
            tile_r: 2,
            tile_c: 2,
            vrf_kib: 16,
            freq_ghz: 1.05,
            // One 4-byte/cycle AXI-style port per lane (aggregate 16 B/cyc
            // at 4 lanes) to the external SRAM-class memory of the paper's
            // testbed; the VLDU pipelines bursts, so the exposed first-word
            // latency is short.
            mem_bw_bytes_per_cycle: 16,
            mem_latency: 4,
        }
    }

    /// The Table III instance: 4 lanes, TILE_R = 8, TILE_C = 4 — the
    /// highest-area-efficiency configuration.
    pub fn table3() -> Self {
        SpeedConfig { tile_r: 8, tile_c: 4, ..Self::reference() }
    }

    /// A DSE point (Fig. 14): lanes ∈ {2,4,8}, tile_{r,c} ∈ {2,4,8}.
    /// External-memory bandwidth scales with the lane count (one VLDU port
    /// per scalable module), as in the reference instance.
    pub fn dse(lanes: u32, tile_r: u32, tile_c: u32) -> Self {
        SpeedConfig {
            lanes,
            tile_r,
            tile_c,
            mem_bw_bytes_per_cycle: 4 * lanes,
            ..Self::reference()
        }
    }

    /// Processing elements per lane.
    pub fn pes_per_lane(&self) -> u32 {
        self.tile_r * self.tile_c
    }

    /// Total PEs across all lanes.
    pub fn total_pes(&self) -> u32 {
        self.lanes * self.pes_per_lane()
    }

    /// Peak MACs per cycle at a precision (all PEs busy).
    pub fn peak_macs_per_cycle(&self, p: Precision) -> u64 {
        self.total_pes() as u64 * p.pp() as u64
    }

    /// Peak throughput in GOPS (1 MAC = 2 ops) at a precision.
    pub fn peak_gops(&self, p: Precision) -> f64 {
        self.peak_macs_per_cycle(p) as f64 * 2.0 * self.freq_ghz
    }

    /// VRF bytes per lane.
    pub fn vrf_bytes(&self) -> u32 {
        self.vrf_kib * 1024
    }

    /// Validate structural constraints (powers of two, supported ranges).
    pub fn validate(&self) -> Result<(), SpeedError> {
        let bad = |m: String| Err(SpeedError::Config(m));
        if !self.lanes.is_power_of_two() || self.lanes == 0 || self.lanes > 16 {
            return bad(format!("lanes must be a power of two in 1..=16, got {}", self.lanes));
        }
        for (name, v) in [("tile_r", self.tile_r), ("tile_c", self.tile_c)] {
            if !v.is_power_of_two() || v == 0 || v > 16 {
                return bad(format!("{name} must be a power of two in 1..=16, got {v}"));
            }
        }
        if self.vrf_kib == 0 {
            return bad("vrf_kib must be nonzero".into());
        }
        if self.freq_ghz <= 0.0 {
            return bad("freq_ghz must be positive".into());
        }
        if self.mem_bw_bytes_per_cycle == 0 {
            return bad("mem_bw_bytes_per_cycle must be nonzero".into());
        }
        Ok(())
    }

    /// Start a builder seeded from the reference instance.
    pub fn builder() -> SpeedConfigBuilder {
        SpeedConfigBuilder { cfg: Self::reference() }
    }
}

/// Builder for a validated [`SpeedConfig`] — every field defaults to the
/// paper's reference instance, so a builder chain only states what differs.
///
/// ```
/// use speed_rvv::SpeedConfig;
/// let cfg = SpeedConfig::builder().lanes(8).tile(4, 4).build().unwrap();
/// assert_eq!(cfg.total_pes(), 128);
/// ```
#[derive(Debug, Clone)]
pub struct SpeedConfigBuilder {
    cfg: SpeedConfig,
}

impl SpeedConfigBuilder {
    /// Number of vector lanes (scalable modules).
    pub fn lanes(mut self, lanes: u32) -> Self {
        self.cfg.lanes = lanes;
        self
    }

    /// MPTU tensor-core geometry (`#TILE_R` × `#TILE_C`).
    pub fn tile(mut self, tile_r: u32, tile_c: u32) -> Self {
        self.cfg.tile_r = tile_r;
        self.cfg.tile_c = tile_c;
        self
    }

    /// VRF capacity per lane, KiB.
    pub fn vrf_kib(mut self, kib: u32) -> Self {
        self.cfg.vrf_kib = kib;
        self
    }

    /// Clock frequency, GHz.
    pub fn freq_ghz(mut self, ghz: f64) -> Self {
        self.cfg.freq_ghz = ghz;
        self
    }

    /// External-memory bandwidth, bytes per cycle.
    pub fn mem_bw_bytes_per_cycle(mut self, bytes: u32) -> Self {
        self.cfg.mem_bw_bytes_per_cycle = bytes;
        self
    }

    /// External-memory access latency, cycles.
    pub fn mem_latency(mut self, cycles: u32) -> Self {
        self.cfg.mem_latency = cycles;
        self
    }

    /// Scale the external-memory bandwidth with the lane count, as the
    /// DSE instances do (one VLDU port per scalable module).
    pub fn bw_per_lane(mut self) -> Self {
        self.cfg.mem_bw_bytes_per_cycle = 4 * self.cfg.lanes;
        self
    }

    /// Validate and produce the configuration.
    pub fn build(self) -> Result<SpeedConfig, SpeedError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

impl Default for SpeedConfig {
    fn default() -> Self {
        Self::reference()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pp_matches_paper() {
        assert_eq!(Precision::Int16.pp(), 1);
        assert_eq!(Precision::Int8.pp(), 4);
        assert_eq!(Precision::Int4.pp(), 16);
    }

    #[test]
    fn precision_ranges() {
        assert_eq!(Precision::Int4.range(), (-8, 7));
        assert_eq!(Precision::Int8.range(), (-128, 127));
        assert_eq!(Precision::Int16.range(), (-32768, 32767));
    }

    #[test]
    fn nibble_packing() {
        assert_eq!(Precision::Int4.bytes_for(3), 2);
        assert_eq!(Precision::Int4.bytes_for(4), 2);
        assert_eq!(Precision::Int8.bytes_for(3), 3);
        assert_eq!(Precision::Int16.bytes_for(3), 6);
    }

    #[test]
    fn reference_matches_paper_setup() {
        let c = SpeedConfig::reference();
        assert_eq!(c.lanes, 4);
        assert_eq!((c.tile_r, c.tile_c), (2, 2));
        assert_eq!(c.vrf_kib, 16);
        // Matched to Ara's 16-bit peak: 4 lanes × 2×2 PEs × 1 PP × 2 ops
        // = 32 ops/cycle, the same as Ara's 4×(64/16)×2.
        assert_eq!(c.peak_macs_per_cycle(Precision::Int16), 16);
    }

    #[test]
    fn table3_peak_gops_order_of_magnitude() {
        // 4 lanes × 8×4 PEs × 16 PP × 2 × 1.05 GHz = 4300.8 GOPS theoretical
        // peak; the paper's 737.9 GOPS is the *achieved* benchmark peak.
        let c = SpeedConfig::table3();
        assert_eq!(c.total_pes(), 128);
        assert!((c.peak_gops(Precision::Int4) - 4300.8).abs() < 1e-6);
    }

    #[test]
    fn validate_rejects_bad() {
        assert!(SpeedConfig { lanes: 3, ..SpeedConfig::reference() }.validate().is_err());
        assert!(SpeedConfig { tile_r: 0, ..SpeedConfig::reference() }.validate().is_err());
        assert!(SpeedConfig { freq_ghz: 0.0, ..SpeedConfig::reference() }.validate().is_err());
        assert!(SpeedConfig::reference().validate().is_ok());
        assert!(SpeedConfig::table3().validate().is_ok());
    }

    #[test]
    fn builder_defaults_to_reference_and_validates() {
        let cfg = SpeedConfig::builder().build().unwrap();
        assert_eq!(cfg, SpeedConfig::reference());
        let cfg = SpeedConfig::builder().lanes(8).tile(8, 4).bw_per_lane().build().unwrap();
        assert_eq!(cfg.lanes, 8);
        assert_eq!((cfg.tile_r, cfg.tile_c), (8, 4));
        assert_eq!(cfg.mem_bw_bytes_per_cycle, 32);
        let err = SpeedConfig::builder().lanes(3).build().unwrap_err();
        assert!(matches!(err, crate::error::SpeedError::Config(_)), "{err}");
    }

    #[test]
    fn clamp_saturates() {
        assert_eq!(Precision::Int8.clamp(1000), 127);
        assert_eq!(Precision::Int8.clamp(-1000), -128);
        assert_eq!(Precision::Int4.clamp(5), 5);
    }
}
