//! Multi-head attention as MM compositions with FlashAttention-style
//! KV tiling.
//!
//! SPEED has no attention primitive — the MPTU executes CONV/PWCV/DWCV/MM
//! (Sec. III). An attention layer therefore *lowers* to the MM vocabulary:
//! per KV tile, a `QK^T` score MM and an `AV` weighted-value MM, with the
//! softmax-scale epilogue on the scalar core (it is part of every model's
//! `scalar_fraction`, Table I). The tile size is chosen so the resident
//! working set — one K tile plus one V tile — fits the VRF input
//! partitions across lanes, the FlashAttention discipline of streaming
//! the KV cache through on-chip memory exactly once per query block.
//!
//! Two layers of fidelity live here:
//!
//! * **Cost model** ([`AttnDesc::lower`]) — the MM decomposition the
//!   simulator prices. Head loops are fused along the M dimension
//!   (`heads·q_len` rows), the same MAC-identical fusion
//!   [`crate::models::zoo::vit`] uses; [`AttnDesc::total_macs`] is
//!   conserved exactly by the tiling.
//! * **Functional model** ([`attn_reference`] / [`attn_tiled`]) — integer
//!   attention used by the golden tests. The softmax surrogate is a
//!   deterministic fixed-point weighting (Q16 `1/√d` score scale, row-max
//!   normalization, power-of-two weight decay) chosen so that the tiled
//!   two-pass evaluation is **bit-exact** against the naive reference at
//!   every precision: pass one reduces the row maximum over tiles (max is
//!   associative), pass two accumulates the integer numerator/denominator
//!   (addition is associative), so no floating-point rescaling error
//!   exists by construction.

use crate::config::{Precision, SpeedConfig};
use crate::dataflow::partition_budget;
use crate::error::SpeedError;
use crate::models::ops::OpDesc;
use crate::models::zoo::Model;

/// One multi-head attention layer, fully specified.
///
/// `q_len` is the number of query tokens this invocation scores
/// (`kv_len` for prefill, 1 for an autoregressive decode step); `kv_len`
/// is the number of key/value entries attended over — the KV-cache length.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AttnDesc {
    /// Attention heads.
    pub heads: u32,
    /// Per-head feature width (`dim = heads × head_dim`).
    pub head_dim: u32,
    /// Query tokens scored by this invocation.
    pub q_len: u32,
    /// Key/value entries attended over (KV-cache length).
    pub kv_len: u32,
    /// Operand precision of Q/K/V.
    pub prec: Precision,
}

impl AttnDesc {
    /// Prefill-shaped attention: every token attends over the whole
    /// prompt (`q_len == kv_len == tokens`).
    pub fn prefill(heads: u32, head_dim: u32, tokens: u32, prec: Precision) -> Self {
        AttnDesc { heads, head_dim, q_len: tokens, kv_len: tokens, prec }
    }

    /// Decode-shaped attention: one new query token attends over a
    /// `kv_len`-entry cache (`q_len == 1`).
    pub fn decode(heads: u32, head_dim: u32, kv_len: u32, prec: Precision) -> Self {
        AttnDesc { heads, head_dim, q_len: 1, kv_len, prec }
    }

    /// Model width `heads × head_dim`.
    pub fn dim(&self) -> u32 {
        self.heads * self.head_dim
    }

    /// Validate dimension consistency.
    pub fn validate(&self) -> Result<(), SpeedError> {
        if self.heads == 0 || self.head_dim == 0 || self.q_len == 0 || self.kv_len == 0 {
            return Err(SpeedError::Compile(format!(
                "attention dims must be nonzero: {self:?}"
            )));
        }
        Ok(())
    }

    /// Total multiply-accumulates: `QK^T` plus `AV`, summed over heads.
    pub fn total_macs(&self) -> u64 {
        2 * self.heads as u64 * self.q_len as u64 * self.kv_len as u64 * self.head_dim as u64
    }

    /// Bytes the K and V caches occupy at the operand precision
    /// (nibble-packed for INT4) — the per-layer residency the serving
    /// scheduler tracks.
    pub fn kv_bytes(&self) -> u64 {
        2 * self.prec.bytes_for(self.kv_len as u64 * self.dim() as u64)
    }

    /// FlashAttention-style KV tile: the largest PP multiple of KV rows
    /// whose K tile plus V tile (`2 × tile × dim` operands at the
    /// precision) fits the VRF input partitions aggregated over lanes
    /// ([`partition_budget`] per lane), so the cache streams through the
    /// VRF once without spilling partials. At least PP rows; capped at
    /// the cache length (a short cache is a single tile).
    pub fn kv_tile(&self, cfg: &SpeedConfig) -> u32 {
        let budget = cfg.lanes as u64 * partition_budget(cfg) as u64;
        let row_bytes = self.prec.bytes_for(2 * self.dim() as u64).max(1);
        let fit = (budget / row_bytes).min(u32::MAX as u64) as u32;
        let pp = self.prec.pp();
        ((fit / pp).max(1) * pp).min(self.kv_len.max(1))
    }

    /// Lower to the MM vocabulary: per KV tile of [`AttnDesc::kv_tile`]
    /// rows, a `QK^T` score MM (`heads·q_len × head_dim × tile`) and an
    /// `AV` weighted-value MM (`heads·q_len × tile × head_dim`). Head
    /// loops are fused along M — identical MAC count, one compiled
    /// program per tile shape. The softmax-scale epilogue between the two
    /// MMs is scalar-core work, modeled by the owning model's
    /// `scalar_fraction`.
    pub fn lower(&self, cfg: &SpeedConfig) -> Vec<OpDesc> {
        let tile = self.kv_tile(cfg);
        let rows = self.heads * self.q_len;
        let mut ops = Vec::new();
        let mut off = 0u32;
        while off < self.kv_len {
            let t = tile.min(self.kv_len - off);
            ops.push(OpDesc::mm(rows, self.head_dim, t, self.prec));
            ops.push(OpDesc::mm(rows, t, self.head_dim, self.prec));
            off += t;
        }
        ops
    }

    /// The lowered layer as a standalone [`Model`] (for
    /// [`Session::run_attn`](crate::engine::Session::run_attn)).
    pub fn to_model(&self, cfg: &SpeedConfig) -> Model {
        Model { name: "attn", ops: self.lower(cfg), scalar_fraction: 0.0 }
    }
}

/// Q16 fixed-point score scale `⌊65536 / ⌊√head_dim⌋⌋` — the integer
/// stand-in for attention's `1/√d` temperature.
fn scale_q16(head_dim: u32) -> i64 {
    let mut r = 0u32;
    while (r + 1) * (r + 1) <= head_dim {
        r += 1;
    }
    (1i64 << 16) / r.max(1) as i64
}

/// Weight-decay granularity: the scaled-score deficit to the row maximum
/// is quantized in steps of `2^WEIGHT_SHIFT`, each step halving the
/// fixed-point weight (`WEIGHT_ONE >> step`).
const WEIGHT_SHIFT: u32 = 8;
/// Fixed-point unity weight (Q16); the row-maximum score always weighs
/// this much, so the denominator is never zero.
const WEIGHT_ONE: i64 = 1 << 16;

/// Integer softmax-surrogate weight of a scaled score `s` under row
/// maximum `m` (`m ≥ s`): `2^16` halved once per `2^WEIGHT_SHIFT` of
/// deficit, reaching exactly zero past 16 halvings.
fn weight(m: i64, s: i64) -> i64 {
    let steps = ((m - s) >> WEIGHT_SHIFT).min(63) as u32;
    WEIGHT_ONE >> steps
}

/// Naive scalar reference for integer multi-head attention.
///
/// Layout (row-major, head-major): `q` is `heads × q_len × head_dim`,
/// `k` and `v` are `heads × kv_len × head_dim`; the result is
/// `heads × q_len × head_dim`, requantized to `desc.prec`'s range.
///
/// Per head and query row: i64 `QK^T` scores, Q16 `1/√d` scaling
/// ([`scale_q16`]), row-max normalization, power-of-two weights
/// ([`weight`]), then `⌊Σ wv / Σ w⌋` (truncating i64 division) clamped
/// into the precision's signed range.
pub fn attn_reference(desc: &AttnDesc, q: &[i32], k: &[i32], v: &[i32]) -> Vec<i32> {
    attn_tiled(desc, q, k, v, desc.kv_len.max(1))
}

/// Two-pass streaming evaluation of the same integer attention over KV
/// tiles of `tile` rows: pass one reduces the row maximum across tiles,
/// pass two accumulates the weight denominator and the weighted-value
/// numerator. Both reductions are associative in integer arithmetic, so
/// the result is bit-exact against [`attn_reference`] for **any** tile
/// size — the property the FlashAttention-style lowering relies on and
/// `tests/attn_golden.rs` enforces.
pub fn attn_tiled(desc: &AttnDesc, q: &[i32], k: &[i32], v: &[i32], tile: u32) -> Vec<i32> {
    let (h, hd) = (desc.heads as usize, desc.head_dim as usize);
    let (ql, kl) = (desc.q_len as usize, desc.kv_len as usize);
    assert_eq!(q.len(), h * ql * hd, "Q operand shape");
    assert_eq!(k.len(), h * kl * hd, "K operand shape");
    assert_eq!(v.len(), h * kl * hd, "V operand shape");
    let tile = (tile as usize).max(1);
    let scale = scale_q16(desc.head_dim);
    let score = |qrow: &[i32], krow: &[i32]| -> i64 {
        let dot: i64 = qrow
            .iter()
            .zip(krow)
            .map(|(&a, &b)| a as i64 * b as i64)
            .sum();
        (dot * scale) >> 16
    };
    let mut out = vec![0i32; h * ql * hd];
    for head in 0..h {
        let kbase = head * kl * hd;
        for row in 0..ql {
            let qrow = &q[(head * ql + row) * hd..(head * ql + row + 1) * hd];
            // Pass 1: row maximum of the scaled scores, tile by tile.
            let mut m = i64::MIN;
            for t0 in (0..kl).step_by(tile) {
                for j in t0..(t0 + tile).min(kl) {
                    m = m.max(score(qrow, &k[kbase + j * hd..kbase + (j + 1) * hd]));
                }
            }
            // Pass 2: integer numerator/denominator, tile by tile.
            let mut den = 0i64;
            let mut num = vec![0i64; hd];
            for t0 in (0..kl).step_by(tile) {
                for j in t0..(t0 + tile).min(kl) {
                    let krow = &k[kbase + j * hd..kbase + (j + 1) * hd];
                    let w = weight(m, score(qrow, krow));
                    if w == 0 {
                        continue;
                    }
                    den += w;
                    let vrow = &v[kbase + j * hd..kbase + (j + 1) * hd];
                    for (acc, &val) in num.iter_mut().zip(vrow) {
                        *acc += w * val as i64;
                    }
                }
            }
            let orow = &mut out[(head * ql + row) * hd..(head * ql + row + 1) * hd];
            for (o, n) in orow.iter_mut().zip(&num) {
                *o = desc.prec.clamp((n / den) as i32);
            }
        }
    }
    out
}

/// Deterministic Q/K/V operands for `desc` from `seed` (values uniform in
/// the precision's signed range) — the shared generator of the attention
/// golden tests.
pub fn seeded_operands(desc: &AttnDesc, seed: u64) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let (lo, hi) = desc.prec.range();
    let span = (hi - lo + 1) as u64;
    let mut state = seed ^ 0x9E37_79B9_7F4A_7C15;
    let mut next = move || {
        // xorshift64* — matches the scenario RNG family.
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        let r = state.wrapping_mul(0x2545_F491_4F6C_DD1D);
        lo + (r % span) as i32
    };
    let qn = (desc.heads * desc.q_len * desc.head_dim) as usize;
    let kn = (desc.heads * desc.kv_len * desc.head_dim) as usize;
    let q: Vec<i32> = (0..qn).map(|_| next()).collect();
    let k: Vec<i32> = (0..kn).map(|_| next()).collect();
    let v: Vec<i32> = (0..kn).map(|_| next()).collect();
    (q, k, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_validation() {
        let a = AttnDesc::prefill(4, 32, 64, Precision::Int8);
        assert_eq!((a.q_len, a.kv_len, a.dim()), (64, 64, 128));
        let d = AttnDesc::decode(4, 32, 48, Precision::Int4);
        assert_eq!(d.q_len, 1);
        assert!(a.validate().is_ok());
        assert!(AttnDesc::decode(0, 32, 48, Precision::Int8).validate().is_err());
        assert!(AttnDesc::prefill(4, 32, 0, Precision::Int8).validate().is_err());
    }

    #[test]
    fn kv_bytes_nibble_packs() {
        let d = AttnDesc::decode(4, 32, 3, Precision::Int4);
        // 2 caches x 3 rows x 128 nibbles = 384 B at INT8; halved at INT4.
        assert_eq!(d.kv_bytes(), 2 * (3 * 128) / 2);
        assert_eq!(
            AttnDesc { prec: Precision::Int16, ..d }.kv_bytes(),
            2 * 3 * 128 * 2
        );
    }

    #[test]
    fn lowering_conserves_macs_and_validates() {
        let cfg = SpeedConfig::reference();
        for prec in Precision::ALL {
            for (heads, hd, q, kv) in
                [(4, 32, 64, 64), (4, 32, 1, 48), (12, 64, 197, 197), (8, 64, 1, 2000)]
            {
                let a = AttnDesc { heads, head_dim: hd, q_len: q, kv_len: kv, prec };
                let ops = a.lower(&cfg);
                assert!(ops.len() >= 2 && ops.len() % 2 == 0);
                let macs: u64 = ops.iter().map(|o| o.total_macs()).sum();
                assert_eq!(macs, a.total_macs(), "{a:?}");
                for op in &ops {
                    op.validate().unwrap();
                }
            }
        }
    }

    #[test]
    fn kv_tile_fits_vrf_input_partitions() {
        // A long cache must be split: tile x dim K and V slices together
        // stay within the aggregated per-lane input-partition budget.
        let cfg = SpeedConfig::reference();
        for prec in Precision::ALL {
            let a = AttnDesc { heads: 8, head_dim: 64, q_len: 1, kv_len: 100_000, prec };
            let t = a.kv_tile(&cfg);
            assert_eq!(t % prec.pp(), 0);
            assert!(t < a.kv_len, "long cache must tile at {prec}");
            assert!(
                prec.bytes_for(2 * t as u64 * a.dim() as u64)
                    <= cfg.lanes as u64 * partition_budget(&cfg) as u64,
                "tile overflows the VRF budget at {prec}"
            );
        }
        // A short cache is a single tile.
        let a = AttnDesc::prefill(4, 32, 16, Precision::Int8);
        assert_eq!(a.kv_tile(&cfg), 16);
    }

    #[test]
    fn tiled_matches_reference_at_every_precision_and_tile() {
        for prec in Precision::ALL {
            let a = AttnDesc { heads: 2, head_dim: 8, q_len: 5, kv_len: 23, prec };
            let (q, k, v) = seeded_operands(&a, 0xC0FF_EE00 + prec.bits() as u64);
            let golden = attn_reference(&a, &q, &k, &v);
            assert_eq!(golden.len(), 2 * 5 * 8);
            let (lo, hi) = prec.range();
            assert!(golden.iter().all(|&o| (lo..=hi).contains(&o)));
            for tile in [1, 2, 3, 7, 8, 16, 23, 64] {
                assert_eq!(
                    attn_tiled(&a, &q, &k, &v, tile),
                    golden,
                    "tile {tile} diverges at {prec}"
                );
            }
        }
    }

    #[test]
    fn attention_attends_to_the_matching_key() {
        // One query identical to key row 1 and far from the rest: the
        // output must reproduce value row 1 (weights collapse onto it).
        let a = AttnDesc { heads: 1, head_dim: 4, q_len: 1, kv_len: 3, prec: Precision::Int8 };
        let q = vec![100, -100, 100, -100];
        let k = vec![
            -100, 100, -100, 100, // opposite -> huge deficit -> weight 0
            100, -100, 100, -100, // match -> row max
            0, 0, 0, 0, // zero score -> large deficit
        ];
        let v = vec![1, 2, 3, 4, 50, -60, 70, -80, 9, 9, 9, 9];
        assert_eq!(attn_reference(&a, &q, &k, &v), vec![50, -60, 70, -80]);
    }
}
