//! DNN workload descriptions: operator descriptors and the benchmark
//! network zoo of the paper's evaluation (Sec. IV-A).

pub mod attn;
pub mod ops;
pub mod zoo;

pub use attn::{attn_reference, attn_tiled, AttnDesc};
pub use ops::{OpDesc, OpKind};
pub use zoo::{llm_spec, model_by_name, LlmSpec, Model, MODELS};
