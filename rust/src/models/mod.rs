//! DNN workload descriptions: operator descriptors and the benchmark
//! network zoo of the paper's evaluation (Sec. IV-A).

pub mod ops;
pub mod zoo;

pub use ops::{OpDesc, OpKind};
pub use zoo::{model_by_name, Model, MODELS};
