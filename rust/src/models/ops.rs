//! DNN operator descriptors — the workload vocabulary of the paper.
//!
//! Every benchmark in Sec. IV is a sequence of these four operator kinds:
//! standard convolution (CONV), point-wise convolution (PWCV), depth-wise
//! convolution (DWCV) and matrix multiplication (MM). An [`OpDesc`] fully
//! determines the arithmetic (MAC count), the tensor footprints, and — via
//! the dataflow strategies — the cycle cost and memory traffic.

use crate::config::Precision;
use crate::error::SpeedError;
use crate::isa::StrategyKind;

/// Operator class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Matrix multiplication `A(M×K) @ B(K×N)`.
    Mm,
    /// Standard convolution `F×C×K×K` over `C×H×W`.
    Conv,
    /// Point-wise (1×1) convolution `F×C` over `C×H×W`.
    Pwcv,
    /// Depth-wise convolution `C×K×K` over `C×H×W`.
    Dwcv,
}

impl std::fmt::Display for OpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            OpKind::Mm => "MM",
            OpKind::Conv => "CONV",
            OpKind::Pwcv => "PWCV",
            OpKind::Dwcv => "DWCV",
        };
        write!(f, "{s}")
    }
}

/// A fully-specified DNN operator instance.
///
/// MM uses `m/k/n`; convolutions use `c/f/h/w/ksize/stride/pad` (PWCV has
/// `ksize == 1`; DWCV has `f == c`). Unused fields are zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OpDesc {
    /// Operator class.
    pub kind: OpKind,
    /// Operand precision.
    pub prec: Precision,
    // --- MM dims ---
    /// MM rows of `A` (0 for convolutions).
    pub m: u32,
    /// MM inner dimension (0 for convolutions).
    pub k: u32,
    /// MM columns of `B` (0 for convolutions).
    pub n: u32,
    // --- convolution dims ---
    /// Input channels (0 for MM).
    pub c: u32,
    /// Output channels / filters (0 for MM; `== c` for DWCV).
    pub f: u32,
    /// Input height (0 for MM).
    pub h: u32,
    /// Input width (0 for MM).
    pub w: u32,
    /// Square kernel size (1 for PWCV, 0 for MM).
    pub ksize: u32,
    /// Convolution stride (0 for MM).
    pub stride: u32,
    /// Zero padding on each spatial edge (0 for MM).
    pub pad: u32,
}

impl OpDesc {
    /// Matrix multiplication `A(M×K) @ B(K×N)`.
    pub fn mm(m: u32, k: u32, n: u32, prec: Precision) -> Self {
        OpDesc {
            kind: OpKind::Mm,
            prec,
            m,
            k,
            n,
            c: 0,
            f: 0,
            h: 0,
            w: 0,
            ksize: 0,
            stride: 0,
            pad: 0,
        }
    }

    /// Standard convolution: `f` filters of `c×ksize×ksize` over `c×h×w`.
    pub fn conv(c: u32, f: u32, h: u32, w: u32, ksize: u32, stride: u32, pad: u32,
                prec: Precision) -> Self {
        OpDesc { kind: OpKind::Conv, prec, m: 0, k: 0, n: 0, c, f, h, w, ksize, stride, pad }
    }

    /// Point-wise (1×1, stride-1, unpadded) convolution.
    pub fn pwcv(c: u32, f: u32, h: u32, w: u32, prec: Precision) -> Self {
        OpDesc { kind: OpKind::Pwcv, prec, m: 0, k: 0, n: 0, c, f, h, w, ksize: 1, stride: 1, pad: 0 }
    }

    /// Depth-wise convolution: one `ksize×ksize` filter per channel.
    pub fn dwcv(c: u32, h: u32, w: u32, ksize: u32, stride: u32, pad: u32,
                prec: Precision) -> Self {
        OpDesc { kind: OpKind::Dwcv, prec, m: 0, k: 0, n: 0, c, f: c, h, w, ksize, stride, pad }
    }

    /// Output spatial size along one axis. Computed in u64 (huge pads
    /// cannot overflow `d + 2·pad`) and total: a kernel larger than the
    /// padded input yields 0 output pixels instead of a u32 underflow
    /// (debug panic / release wraparound feeding [`OpDesc::total_macs`]).
    /// [`OpDesc::validate`] rejects such geometry before compilation.
    fn out_dim(d: u32, pad: u32, ksize: u32, stride: u32) -> u32 {
        let padded = d as u64 + 2 * pad as u64;
        match padded.checked_sub(ksize as u64) {
            Some(span) => (span / stride.max(1) as u64 + 1).min(u32::MAX as u64) as u32,
            None => 0,
        }
    }

    /// Output spatial height (convolutions; 0 when the kernel does not fit).
    pub fn oh(&self) -> u32 {
        Self::out_dim(self.h, self.pad, self.ksize, self.stride)
    }

    /// Output spatial width (convolutions; 0 when the kernel does not fit).
    pub fn ow(&self) -> u32 {
        Self::out_dim(self.w, self.pad, self.ksize, self.stride)
    }

    /// The dataflow strategy the paper's mixed mapping assigns (Sec. III):
    /// MM for MM, FFCS for CONV, CF for PWCV, FF for DWCV.
    pub fn preferred_strategy(&self) -> StrategyKind {
        match self.kind {
            OpKind::Mm => StrategyKind::Mm,
            OpKind::Conv => StrategyKind::Ffcs,
            OpKind::Pwcv => StrategyKind::Cf,
            OpKind::Dwcv => StrategyKind::Ff,
        }
    }

    /// Total multiply-accumulates of the operator.
    pub fn total_macs(&self) -> u64 {
        match self.kind {
            OpKind::Mm => self.m as u64 * self.k as u64 * self.n as u64,
            OpKind::Conv => {
                self.f as u64
                    * self.oh() as u64
                    * self.ow() as u64
                    * self.c as u64
                    * (self.ksize as u64).pow(2)
            }
            OpKind::Pwcv => {
                self.f as u64 * self.oh() as u64 * self.ow() as u64 * self.c as u64
            }
            OpKind::Dwcv => {
                self.c as u64 * self.oh() as u64 * self.ow() as u64
                    * (self.ksize as u64).pow(2)
            }
        }
    }

    /// Total arithmetic operations (1 MAC = 2 ops), the paper's "ops".
    pub fn total_ops(&self) -> u64 {
        2 * self.total_macs()
    }

    /// Input tensor element count.
    pub fn input_elems(&self) -> u64 {
        match self.kind {
            OpKind::Mm => self.m as u64 * self.k as u64,
            _ => self.c as u64 * self.h as u64 * self.w as u64,
        }
    }

    /// Weight tensor element count.
    pub fn weight_elems(&self) -> u64 {
        match self.kind {
            OpKind::Mm => self.k as u64 * self.n as u64,
            OpKind::Conv => self.f as u64 * self.c as u64 * (self.ksize as u64).pow(2),
            OpKind::Pwcv => self.f as u64 * self.c as u64,
            OpKind::Dwcv => self.c as u64 * (self.ksize as u64).pow(2),
        }
    }

    /// Output element count (32-bit accumulators before requantization).
    pub fn output_elems(&self) -> u64 {
        match self.kind {
            OpKind::Mm => self.m as u64 * self.n as u64,
            OpKind::Dwcv => self.c as u64 * self.oh() as u64 * self.ow() as u64,
            _ => self.f as u64 * self.oh() as u64 * self.ow() as u64,
        }
    }

    /// Input tensor bytes at the operand precision (nibble-packed for 4-bit).
    pub fn input_bytes(&self) -> u64 {
        self.prec.bytes_for(self.input_elems())
    }

    /// Weight tensor bytes at the operand precision.
    pub fn weight_bytes(&self) -> u64 {
        self.prec.bytes_for(self.weight_elems())
    }

    /// Output bytes (int32 accumulators).
    pub fn output_bytes(&self) -> u64 {
        self.output_elems() * 4
    }

    /// Output rows as stored by `VSE` (MM: M rows of N; conv: F·OH rows of
    /// OW; DWCV: C·OH rows of OW).
    pub fn output_rows(&self) -> u64 {
        match self.kind {
            OpKind::Mm => self.m as u64,
            OpKind::Dwcv => self.c as u64 * self.oh() as u64,
            _ => self.f as u64 * self.oh() as u64,
        }
    }

    /// Elements per output row.
    pub fn output_row_elems(&self) -> u64 {
        match self.kind {
            OpKind::Mm => self.n as u64,
            _ => self.ow() as u64,
        }
    }

    /// Validate dimension consistency.
    pub fn validate(&self) -> Result<(), SpeedError> {
        let bad = |m: String| Err(SpeedError::Compile(m));
        match self.kind {
            OpKind::Mm => {
                if self.m == 0 || self.k == 0 || self.n == 0 {
                    return bad(format!("MM dims must be nonzero: {self:?}"));
                }
            }
            _ => {
                if self.c == 0 || self.h == 0 || self.w == 0 || self.ksize == 0 {
                    return bad(format!("conv dims must be nonzero: {self:?}"));
                }
                if self.kind != OpKind::Dwcv && self.f == 0 {
                    return bad("output channels must be nonzero".into());
                }
                if self.kind == OpKind::Dwcv && self.f != self.c {
                    return bad("DWCV requires f == c".into());
                }
                if self.kind == OpKind::Pwcv && self.ksize != 1 {
                    return bad("PWCV requires ksize == 1".into());
                }
                if self.stride == 0 {
                    return bad("stride must be nonzero".into());
                }
                // Degenerate geometry is a request-parameter problem
                // (`Config`), not a compiler defect: the tuner and the
                // serving layer reject it at admission, before any sweep
                // touches `oh()`/`ow()`-derived sizing.
                if (self.h as u64 + 2 * self.pad as u64) < self.ksize as u64
                    || (self.w as u64 + 2 * self.pad as u64) < self.ksize as u64
                {
                    return Err(SpeedError::Config(format!(
                        "kernel {k} larger than padded input {h}x{w} (pad {p}): {self:?}",
                        k = self.ksize,
                        h = self.h,
                        w = self.w,
                        p = self.pad
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_counts() {
        let op = OpDesc::mm(4, 8, 8, Precision::Int16);
        assert_eq!(op.total_macs(), 256);
        assert_eq!(op.total_ops(), 512);
        assert_eq!(op.input_bytes(), 64);
        assert_eq!(op.weight_bytes(), 128);
        assert_eq!(op.output_bytes(), 128);
        assert_eq!(op.output_rows(), 4);
        assert!(op.validate().is_ok());
    }

    #[test]
    fn conv_counts() {
        let op = OpDesc::conv(8, 16, 12, 12, 3, 1, 1, Precision::Int8);
        assert_eq!((op.oh(), op.ow()), (12, 12));
        assert_eq!(op.total_macs(), 16 * 144 * 8 * 9);
        assert_eq!(op.weight_elems(), 16 * 8 * 9);
        assert!(op.validate().is_ok());
    }

    #[test]
    fn dwcv_stride2() {
        let op = OpDesc::dwcv(8, 13, 13, 3, 2, 1, Precision::Int8);
        assert_eq!((op.oh(), op.ow()), (7, 7));
        assert_eq!(op.output_elems(), 8 * 49);
        assert_eq!(op.preferred_strategy(), StrategyKind::Ff);
        assert!(op.validate().is_ok());
    }

    #[test]
    fn pwcv_prefers_cf() {
        let op = OpDesc::pwcv(16, 32, 8, 8, Precision::Int8);
        assert_eq!(op.preferred_strategy(), StrategyKind::Cf);
        assert_eq!(op.total_macs(), 32 * 64 * 16);
        assert!(op.validate().is_ok());
    }

    #[test]
    fn int4_nibble_footprints() {
        let op = OpDesc::mm(3, 5, 7, Precision::Int4);
        assert_eq!(op.input_bytes(), 8); // 15 nibbles -> 8 bytes
        assert_eq!(op.weight_bytes(), 18); // 35 nibbles -> 18 bytes
    }

    #[test]
    fn validation_rejects_bad() {
        assert!(OpDesc::mm(0, 1, 1, Precision::Int8).validate().is_err());
        assert!(OpDesc::conv(3, 4, 2, 2, 5, 1, 0, Precision::Int8).validate().is_err());
        let mut dw = OpDesc::dwcv(8, 8, 8, 3, 1, 1, Precision::Int8);
        dw.f = 4;
        assert!(dw.validate().is_err());
    }

    #[test]
    fn oversized_kernel_no_underflow_and_typed_config_error() {
        // ksize > h + 2*pad used to underflow u32 in oh()/ow() (debug
        // panic; release wraparound feeding total_macs). Now the geometry
        // is well-defined (0 output pixels, 0 MACs) and validate() rejects
        // it with a typed Config error.
        let op = OpDesc::conv(3, 4, 2, 2, 5, 1, 0, Precision::Int8);
        assert_eq!((op.oh(), op.ow()), (0, 0));
        assert_eq!(op.total_macs(), 0);
        assert_eq!(op.output_elems(), 0);
        assert!(matches!(op.validate(), Err(SpeedError::Config(_))));
        // One pad short of fitting: still rejected, still no underflow.
        let dw = OpDesc::dwcv(4, 3, 3, 7, 2, 1, Precision::Int16);
        assert_eq!(dw.oh(), 0);
        assert!(matches!(dw.validate(), Err(SpeedError::Config(_))));
        // Exactly fitting geometry stays accepted with 1 output pixel.
        let fit = OpDesc::conv(3, 4, 3, 3, 5, 1, 1, Precision::Int8);
        assert_eq!((fit.oh(), fit.ow()), (1, 1));
        assert!(fit.validate().is_ok());
        // Huge pads must not overflow h + 2*pad either.
        let padded = OpDesc::conv(1, 1, 8, 8, 3, 1, u32::MAX / 2, Precision::Int8);
        let _ = (padded.oh(), padded.ow()); // must not panic
    }
}
