//! The benchmark network zoo of Sec. IV: VGG16, ResNet18, GoogLeNet,
//! MobileNetV2, ViT-Tiny and ViT-B/16, expressed as operator sequences —
//! plus `llm_tiny`, a small decoder-only transformer whose prefill and
//! autoregressive-decode forms drive the stateful serving scenarios.
//!
//! Layer tables follow the published architectures at 224×224 (CNNs) /
//! 197 tokens (ViTs), batch 1. Weight values are synthetic (shapes are what
//! determine cycles and traffic — see DESIGN.md "Substitutions"), and the
//! scalar-core share of the complete application (pooling, normalization,
//! non-vectorizable glue) is modeled per Table I's complete-application
//! evaluation.

use crate::config::{Precision, SpeedConfig};
use crate::models::attn::AttnDesc;
use crate::models::ops::OpDesc;

/// A benchmark network: a name plus its vectorizable operator sequence.
#[derive(Debug, Clone)]
pub struct Model {
    /// Network name as used by the CLI and reports.
    pub name: &'static str,
    /// Vector-processor operators (CONV/PWCV/DWCV/MM) in execution order.
    pub ops: Vec<OpDesc>,
    /// Fraction of complete-application time spent in scalar-core work
    /// (max-pool, normalization, softmax, ...) relative to the *vector*
    /// time on SPEED — used for Table I's complete-application rows.
    /// Lightweight networks (MobileNetV2) have a much larger share.
    pub scalar_fraction: f64,
}

impl Model {
    /// Total MACs over all vector operators.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.total_macs()).sum()
    }

    /// Re-type every operator to a new precision.
    pub fn at_precision(&self, prec: Precision) -> Model {
        Model {
            name: self.name,
            ops: self.ops.iter().map(|o| OpDesc { prec, ..*o }).collect(),
            scalar_fraction: self.scalar_fraction,
        }
    }
}

/// All seven benchmark models (constructed at INT8; use
/// [`Model::at_precision`] to re-type). `llm_tiny` resolves to its
/// prefill form at [`LLM_DEFAULT_TOKENS`] tokens; the per-step decode
/// workloads come from [`LlmSpec::decode_step`].
pub const MODELS: [&str; 7] =
    ["vgg16", "resnet18", "googlenet", "mobilenetv2", "vit_tiny", "vit_b16", "llm_tiny"];

/// Look up a benchmark model by name.
pub fn model_by_name(name: &str) -> Option<Model> {
    let p = Precision::Int8;
    match name {
        "vgg16" => Some(vgg16(p)),
        "resnet18" => Some(resnet18(p)),
        "googlenet" => Some(googlenet(p)),
        "mobilenetv2" => Some(mobilenetv2(p)),
        "vit_tiny" => Some(vit(p, "vit_tiny", 192, 768, 197, 12)),
        "vit_b16" => Some(vit(p, "vit_b16", 768, 3072, 197, 12)),
        "llm_tiny" => Some(LLM_TINY.prefill(p, LLM_DEFAULT_TOKENS)),
        _ => None,
    }
}

/// Prompt length `llm_tiny` prefills at when resolved through
/// [`model_by_name`] (the fig. 12 / verify sweeps); serving scenarios
/// choose their own prompt and decode lengths per session.
pub const LLM_DEFAULT_TOKENS: u32 = 64;

/// The decoder-only transformer of the zoo: deliberately tiny (2 layers,
/// width 128) so the whole-zoo sweeps stay fast while still exercising
/// multi-head attention, KV growth, and decode-shaped GEMMs.
pub const LLM_TINY: LlmSpec =
    LlmSpec { name: "llm_tiny", dim: 128, heads: 4, mlp: 256, depth: 2 };

/// Geometry of a decoder-only transformer family entry, from which both
/// serving phases derive: [`LlmSpec::prefill`] (whole-prompt attention,
/// throughput-bound) and [`LlmSpec::decode_step`] (one token against a
/// growing KV cache, memory-bound at every precision). The KV residency
/// the serving scheduler tracks is [`LlmSpec::kv_bytes`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LlmSpec {
    /// Zoo name of the family (both phases report under it).
    pub name: &'static str,
    /// Model width (`heads × head_dim`).
    pub dim: u32,
    /// Attention heads per layer.
    pub heads: u32,
    /// MLP hidden width.
    pub mlp: u32,
    /// Transformer layers.
    pub depth: u32,
}

impl LlmSpec {
    /// Per-head feature width.
    pub fn head_dim(&self) -> u32 {
        self.dim / self.heads
    }

    /// The prefill workload: every prompt token through every layer —
    /// QKV projection, tiled attention ([`AttnDesc::lower`] on the
    /// reference instance), output projection, and the MLP pair — plus
    /// the last-token LM head. Embedding lookup is scalar-core work
    /// (no MACs), inside `scalar_fraction` with softmax and layernorm.
    pub fn prefill(&self, prec: Precision, tokens: u32) -> Model {
        let cfg = SpeedConfig::reference();
        let t = tokens.max(1);
        let mut ops = Vec::new();
        for _ in 0..self.depth {
            ops.push(OpDesc::mm(t, self.dim, 3 * self.dim, prec));
            ops.extend(AttnDesc::prefill(self.heads, self.head_dim(), t, prec).lower(&cfg));
            ops.push(OpDesc::mm(t, self.dim, self.dim, prec));
            ops.push(OpDesc::mm(t, self.dim, self.mlp, prec));
            ops.push(OpDesc::mm(t, self.mlp, self.dim, prec));
        }
        ops.push(OpDesc::mm(1, self.dim, 1000, prec));
        Model { name: self.name, ops, scalar_fraction: 0.10 }
    }

    /// One autoregressive decode step: a single new token attends over a
    /// `kv_len`-entry cache (`kv_len` counts the new token itself, i.e.
    /// prompt length + tokens generated so far). Every projection MM has
    /// `m == 1` and the head-fused attention MMs have `m == heads` — the
    /// memory-bound skinny-MM regime the tuner's decode candidates
    /// target.
    pub fn decode_step(&self, prec: Precision, kv_len: u32) -> Model {
        let cfg = SpeedConfig::reference();
        let mut ops = Vec::new();
        for _ in 0..self.depth {
            ops.push(OpDesc::mm(1, self.dim, 3 * self.dim, prec));
            ops.extend(
                AttnDesc::decode(self.heads, self.head_dim(), kv_len.max(1), prec).lower(&cfg),
            );
            ops.push(OpDesc::mm(1, self.dim, self.dim, prec));
            ops.push(OpDesc::mm(1, self.dim, self.mlp, prec));
            ops.push(OpDesc::mm(1, self.mlp, self.dim, prec));
        }
        ops.push(OpDesc::mm(1, self.dim, 1000, prec));
        Model { name: self.name, ops, scalar_fraction: 0.10 }
    }

    /// Bytes the session's K and V caches occupy across all layers at
    /// `kv_len` cached tokens — the residency the serving scheduler
    /// charges against its per-worker KV budget.
    pub fn kv_bytes(&self, prec: Precision, kv_len: u32) -> u64 {
        self.depth as u64
            * AttnDesc::decode(self.heads, self.head_dim(), kv_len.max(1), prec).kv_bytes()
    }
}

/// Look up a transformer family entry by zoo name.
pub fn llm_spec(name: &str) -> Option<LlmSpec> {
    (name == LLM_TINY.name).then_some(LLM_TINY)
}

/// VGG16: thirteen 3×3 CONV layers + three FC layers.
pub fn vgg16(p: Precision) -> Model {
    let mut ops = Vec::new();
    // (in_ch, out_ch, spatial)
    let convs: [(u32, u32, u32); 13] = [
        (3, 64, 224),
        (64, 64, 224),
        (64, 128, 112),
        (128, 128, 112),
        (128, 256, 56),
        (256, 256, 56),
        (256, 256, 56),
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 28),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
    ];
    for (c, f, s) in convs {
        ops.push(OpDesc::conv(c, f, s, s, 3, 1, 1, p));
    }
    // FC layers as MM (batch-1 GEMV-style MMs).
    ops.push(OpDesc::mm(1, 512 * 7 * 7, 4096, p));
    ops.push(OpDesc::mm(1, 4096, 4096, p));
    ops.push(OpDesc::mm(1, 4096, 1000, p));
    Model { name: "vgg16", ops, scalar_fraction: 0.015 }
}

/// ResNet18: 7×7 stem + 8 basic blocks (+1×1 downsamples) + FC.
pub fn resnet18(p: Precision) -> Model {
    let mut ops = Vec::new();
    ops.push(OpDesc::conv(3, 64, 224, 224, 7, 2, 3, p));
    // (channels, spatial, first_stride)
    let stages: [(u32, u32, u32, u32); 4] =
        [(64, 64, 56, 1), (64, 128, 56, 2), (128, 256, 28, 2), (256, 512, 14, 2)];
    for (cin, cout, s_in, stride1) in stages {
        // block 1 (possibly strided, with PWCV downsample shortcut)
        ops.push(OpDesc::conv(cin, cout, s_in, s_in, 3, stride1, 1, p));
        let s_out = s_in / stride1;
        ops.push(OpDesc::conv(cout, cout, s_out, s_out, 3, 1, 1, p));
        if stride1 != 1 || cin != cout {
            ops.push(OpDesc::pwcv(cin, cout, s_out, s_out, p));
        }
        // block 2
        ops.push(OpDesc::conv(cout, cout, s_out, s_out, 3, 1, 1, p));
        ops.push(OpDesc::conv(cout, cout, s_out, s_out, 3, 1, 1, p));
    }
    ops.push(OpDesc::mm(1, 512, 1000, p));
    Model { name: "resnet18", ops, scalar_fraction: 0.03 }
}

/// GoogLeNet (Inception v1): stem + 9 inception modules + FC.
pub fn googlenet(p: Precision) -> Model {
    let mut ops = Vec::new();
    ops.push(OpDesc::conv(3, 64, 224, 224, 7, 2, 3, p));
    ops.push(OpDesc::pwcv(64, 64, 56, 56, p));
    ops.push(OpDesc::conv(64, 192, 56, 56, 3, 1, 1, p));
    // (cin, #1x1, #3x3red, #3x3, #5x5red, #5x5, pool_proj, spatial)
    let inception: [(u32, u32, u32, u32, u32, u32, u32, u32); 9] = [
        (192, 64, 96, 128, 16, 32, 32, 28),   // 3a
        (256, 128, 128, 192, 32, 96, 64, 28), // 3b
        (480, 192, 96, 208, 16, 48, 64, 14),  // 4a
        (512, 160, 112, 224, 24, 64, 64, 14), // 4b
        (512, 128, 128, 256, 24, 64, 64, 14), // 4c
        (512, 112, 144, 288, 32, 64, 64, 14), // 4d
        (528, 256, 160, 320, 32, 128, 128, 14), // 4e
        (832, 256, 160, 320, 32, 128, 128, 7), // 5a
        (832, 384, 192, 384, 48, 128, 128, 7), // 5b
    ];
    for (cin, n1, n3r, n3, n5r, n5, pp, s) in inception {
        ops.push(OpDesc::pwcv(cin, n1, s, s, p));
        ops.push(OpDesc::pwcv(cin, n3r, s, s, p));
        ops.push(OpDesc::conv(n3r, n3, s, s, 3, 1, 1, p));
        ops.push(OpDesc::pwcv(cin, n5r, s, s, p));
        ops.push(OpDesc::conv(n5r, n5, s, s, 5, 1, 2, p));
        ops.push(OpDesc::pwcv(cin, pp, s, s, p));
    }
    ops.push(OpDesc::mm(1, 1024, 1000, p));
    Model { name: "googlenet", ops, scalar_fraction: 0.05 }
}

/// MobileNetV2: stem + 17 inverted-residual blocks + head.
pub fn mobilenetv2(p: Precision) -> Model {
    let mut ops = Vec::new();
    ops.push(OpDesc::conv(3, 32, 224, 224, 3, 2, 1, p));
    // Inverted residual: expand (PWCV) -> DWCV 3x3 -> project (PWCV).
    // (expansion t, cout, repeats n, stride s), input starts 32ch @112.
    let cfg: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32u32;
    let mut s = 112u32;
    for (t, cout, n, stride) in cfg {
        for i in 0..n {
            let st = if i == 0 { stride } else { 1 };
            let e = cin * t;
            if t != 1 {
                ops.push(OpDesc::pwcv(cin, e, s, s, p));
            }
            ops.push(OpDesc::dwcv(e, s, s, 3, st, 1, p));
            let s_out = s / st;
            ops.push(OpDesc::pwcv(e, cout, s_out, s_out, p));
            cin = cout;
            s = s_out;
        }
    }
    ops.push(OpDesc::pwcv(320, 1280, 7, 7, p));
    ops.push(OpDesc::mm(1, 1280, 1000, p));
    // Lightweight network: non-linear / scalar ops are a visibly larger
    // share of end-to-end time (Table I's MobileNetV2 discussion).
    Model { name: "mobilenetv2", ops, scalar_fraction: 0.30 }
}

/// ViT family: `depth` transformer blocks over `tokens` tokens of width
/// `dim` with MLP hidden size `mlp`.
pub fn vit(p: Precision, name: &'static str, dim: u32, mlp: u32, tokens: u32,
           depth: u32) -> Model {
    let mut ops = Vec::new();
    // Patch embedding: the 16x16/s16 convolution is exactly a matrix
    // multiply of the 196 flattened patches by the (3*16*16, dim) weight —
    // the standard deployment form (and a kernel this size would need
    // Kseg decomposition as a convolution).
    ops.push(OpDesc::mm(196, 3 * 16 * 16, dim, p));
    for _ in 0..depth {
        // QKV projection.
        ops.push(OpDesc::mm(tokens, dim, 3 * dim, p));
        // Attention scores + weighted values (per-head MMs fused as full-dim
        // MMs — identical MAC count).
        ops.push(OpDesc::mm(tokens, dim, tokens, p));
        ops.push(OpDesc::mm(tokens, tokens, dim, p));
        // Output projection.
        ops.push(OpDesc::mm(tokens, dim, dim, p));
        // MLP.
        ops.push(OpDesc::mm(tokens, dim, mlp, p));
        ops.push(OpDesc::mm(tokens, mlp, dim, p));
    }
    ops.push(OpDesc::mm(1, dim, 1000, p));
    let scalar_fraction = 0.08; // softmax + layernorm share
    Model { name, ops, scalar_fraction }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_resolve_and_validate() {
        for name in MODELS {
            let m = model_by_name(name).unwrap_or_else(|| panic!("{name} missing"));
            assert!(!m.ops.is_empty());
            for op in &m.ops {
                op.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            }
        }
    }

    #[test]
    fn every_model_validates_at_every_precision() {
        // The serving scenarios draw (model, precision) pairs freely; every
        // combination must be structurally valid.
        for name in MODELS {
            let m = model_by_name(name).unwrap();
            for prec in Precision::ALL {
                let mp = m.at_precision(prec);
                assert_eq!(mp.ops.len(), m.ops.len());
                for op in &mp.ops {
                    assert_eq!(op.prec, prec);
                    op.validate().unwrap_or_else(|e| panic!("{name}@{prec}: {e}"));
                }
            }
        }
    }

    #[test]
    fn mem_requirement_fits_or_yields_typed_layout_error() {
        use crate::compiler::{MemLayout, MEM_MIN_BYTES};
        use crate::coordinator::mem_requirement;
        use crate::error::SpeedError;
        for name in MODELS {
            let m = model_by_name(name).unwrap();
            for prec in Precision::ALL {
                let mp = m.at_precision(prec);
                let need = mem_requirement(&mp);
                assert!(need >= MEM_MIN_BYTES as usize, "{name}@{prec}");
                for op in &mp.ops {
                    // The model's own requirement covers every layer...
                    MemLayout::for_op(op, need)
                        .unwrap_or_else(|e| panic!("{name}@{prec}: {e}"));
                    // ...the engine's default memory floor either fits the
                    // layer or yields a typed Layout error — never a panic
                    // (the engine grows memory lazily off this signal)...
                    match MemLayout::for_op(op, MEM_MIN_BYTES as usize) {
                        Ok(_) | Err(SpeedError::Layout(_)) => {}
                        Err(other) => panic!("{name}@{prec}: wrong class {other}"),
                    }
                    // ...and a hopeless memory is always the typed error.
                    match MemLayout::for_op(op, 64) {
                        Err(SpeedError::Layout(_)) => {}
                        Ok(_) => panic!("{name}@{prec}: {op:?} fit 64 B"),
                        Err(other) => panic!("{name}@{prec}: wrong class {other}"),
                    }
                }
            }
        }
    }

    #[test]
    fn vgg16_macs_match_published_scale() {
        // VGG16 is ~15.5 GMACs at 224x224.
        let m = vgg16(Precision::Int8);
        let g = m.total_macs() as f64 / 1e9;
        assert!((15.0..16.0).contains(&g), "VGG16 GMACs = {g}");
    }

    #[test]
    fn resnet18_macs_match_published_scale() {
        // ResNet18 is ~1.8 GMACs.
        let m = resnet18(Precision::Int8);
        let g = m.total_macs() as f64 / 1e9;
        assert!((1.6..2.0).contains(&g), "ResNet18 GMACs = {g}");
    }

    #[test]
    fn mobilenetv2_macs_match_published_scale() {
        // MobileNetV2 is ~0.3 GMACs.
        let m = mobilenetv2(Precision::Int8);
        let g = m.total_macs() as f64 / 1e9;
        assert!((0.25..0.40).contains(&g), "MobileNetV2 GMACs = {g}");
    }

    #[test]
    fn vit_b16_macs_match_published_scale() {
        // ViT-B/16 is ~16-17 GMACs at 224x224 with 197 tokens.
        let m = model_by_name("vit_b16").unwrap();
        let g = m.total_macs() as f64 / 1e9;
        assert!((14.0..19.0).contains(&g), "ViT-B/16 GMACs = {g}");
    }

    #[test]
    fn mobilenet_is_dw_pw_dominated() {
        use crate::models::ops::OpKind;
        let m = mobilenetv2(Precision::Int8);
        let pw_dw: u64 = m
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Pwcv | OpKind::Dwcv))
            .map(|o| o.total_macs())
            .sum();
        assert!(pw_dw as f64 / m.total_macs() as f64 > 0.8);
    }

    #[test]
    fn llm_tiny_phases_validate_and_scale() {
        let spec = llm_spec("llm_tiny").unwrap();
        assert_eq!(spec, LLM_TINY);
        assert!(llm_spec("vgg16").is_none());
        for prec in Precision::ALL {
            let pre = spec.prefill(prec, 32);
            let step = spec.decode_step(prec, 33);
            for op in pre.ops.iter().chain(&step.ops) {
                op.validate().unwrap_or_else(|e| panic!("{prec}: {e}"));
            }
            // Decode is skinny: one output row per MM, or one per head
            // for the head-fused attention MMs.
            assert!(step.ops.iter().all(|o| o.m == 1 || o.m == spec.heads));
            assert!(step.ops.iter().any(|o| o.m == 1));
            // One step is far cheaper than the whole prompt prefill.
            assert!(step.total_macs() < pre.total_macs());
        }
        // KV residency grows monotonically with the cache and halves
        // with the operand width (nibble-packed INT4).
        let b8 = spec.kv_bytes(Precision::Int8, 64);
        assert_eq!(b8, spec.depth as u64 * 2 * 64 * spec.dim as u64);
        assert!(spec.kv_bytes(Precision::Int8, 65) > b8);
        assert_eq!(spec.kv_bytes(Precision::Int4, 64), b8 / 2);
        assert_eq!(spec.kv_bytes(Precision::Int16, 64), b8 * 2);
    }

    #[test]
    fn llm_tiny_resolves_to_prefill_form() {
        let m = model_by_name("llm_tiny").unwrap();
        assert_eq!(m.name, "llm_tiny");
        assert_eq!(
            m.total_macs(),
            LLM_TINY.prefill(Precision::Int8, LLM_DEFAULT_TOKENS).total_macs()
        );
    }

    #[test]
    fn precision_retype_preserves_shape() {
        let m = vgg16(Precision::Int8).at_precision(Precision::Int4);
        assert!(m.ops.iter().all(|o| o.prec == Precision::Int4));
        assert_eq!(m.total_macs(), vgg16(Precision::Int8).total_macs());
    }
}
