//! Static verification of compiled SPEED instruction streams.
//!
//! The custom VSA instructions fold dataflow strategy, precision, and
//! dimension state into latched `VSACFG` control registers (Sec. II-B), so
//! a bad emitter produces a stream that is *silently wrong* rather than
//! loudly illegal: the simulator would execute it, charge plausible cycles,
//! and store garbage. This module is the compile-time line of defense — an
//! abstract interpreter that walks a [`CompiledOp`]'s segments without
//! simulating them and proves (or refutes) the invariants every layer above
//! relies on. It tracks the latched control state (the same machine as
//! [`crate::sim::ctrl::CtrlState`]), scalar address registers, vector
//! register definedness, and the memory extent of every transfer against
//! the operator's [`MemLayout`].
//!
//! [`CompiledOp`]: crate::compiler::CompiledOp
//!
//! # Rule families
//!
//! | ID | Checks |
//! |----------|--------------------------------------------------------|
//! | V-CFG-01 | custom load/compute before any `VSACFG` latch          |
//! | V-CFG-02 | latched precision/strategy/ksize/dim contradicts the op |
//! | V-CFG-03 | tensor op uses a dimension register never latched       |
//! | V-CFG-04 | memory/compute before `VSETVLI` latches a vector length |
//! | V-CFG-05 | `VSACFG` encoding invalid (zimm, ksize 0, ksize > 15)   |
//! | V-REG-01 | vector register read before it was written              |
//! | V-REG-02 | load destination never consumed (dead write)            |
//! | V-REG-03 | tensor operand is not the latest load of its class      |
//! | V-MEM-01 | load not contained in its input/weight region           |
//! | V-MEM-02 | output store misaligned, out of range, or not a row     |
//! | V-MEM-03 | partial spill/reload outside the spill region           |
//! | V-MEM-04 | access outside every region or not statically provable  |
//! | V-MEM-05 | load image overflows a vector-register region           |
//! | V-RUN-01 | stream-run metadata malformed (bounds/overlap/order)    |
//! | V-RUN-02 | tensor run is not a chain of identical bursts           |
//! | V-RUN-03 | load run is not uniform `(li; vsald/vle)` pairs         |
//! | V-RUN-04 | store run is not `(li; vse)` pairs                      |
//! | V-RUN-05 | tensor burst encodes zero stages                        |
//! | V-RES-01 | FF weight traffic contradicts the declared mapping: the |
//! |          | stream loads more (or fewer) weight elements than the   |
//! |          | one-full-fetch-plus-`weight_refetches` contract allows  |
//! | V-RES-02 | stream loads fewer weight elements than the op needs    |
//!
//! # Invocation layers
//!
//! 1. [`Engine`](crate::engine::Engine) verifies on program-cache insert —
//!    always in debug builds, behind
//!    [`set_verify_on_compile`](crate::engine::Engine::set_verify_on_compile)
//!    in release builds.
//! 2. The auto-tuner rejects candidates that fail verification before
//!    paying for a simulation ([`crate::tune::tune_op`]).
//! 3. The `repro verify` CLI sweeps zoo × precisions × feasible mappings
//!    and prints a per-rule table.
//! 4. `tests/verifier.rs` corrupts known-good streams and asserts each
//!    mutation is caught by the intended rule ID.
//!
//! The verifier is deliberately *sound for codegen* rather than complete
//! for arbitrary hand-written streams: every program
//! [`crate::compiler::compile_op_with`] can emit must verify clean (a
//! property test enforces this), and any diagnostic on a compiled stream
//! is a compiler bug. Two modeling choices keep that property:
//!
//! * Tensor operands are *partition handles*, not strict dataflow: the MPTU
//!   consumes whole VRF partitions, and the `vs1`/`vs2` fields name the
//!   rotation slot of the most recent load. Under the MM strategy there is
//!   no weight bank (both A and B tiles rotate through the input slots),
//!   so only `vs1` is constrained there.
//! * A load overwritten before a tensor op is *not* dead: multi-chunk
//!   loads rotate a small register window while the data accumulates in
//!   the partition. Dead-write detection therefore runs at end of stream:
//!   a load nothing ever consumed is V-REG-02.

use std::fmt;

use crate::compiler::MemLayout;
use crate::config::{Precision, SpeedConfig};
use crate::dataflow::{vreg_region, MappingChoice};
use crate::error::SpeedError;
use crate::isa::{Dim, Insn, LdMode, RunKind, Segment, StrategyKind, WidthSel};
use crate::models::ops::{OpDesc, OpKind};

/// Maximum diagnostics materialized in a [`VerifyReport`]. Rule *counts*
/// keep accumulating past the cap (the per-rule table stays truthful);
/// only the stored diagnostic list is truncated.
pub const MAX_DIAGNOSTICS: usize = 256;

/// A named verifier rule with a stable ID (see the module-level table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    /// V-CFG-01: custom load/compute before any `VSACFG` latch.
    CfgNotLatched,
    /// V-CFG-02: latched precision/strategy/ksize/dim value contradicts
    /// the operator or mapping choice the program was compiled for.
    CfgMismatch,
    /// V-CFG-03: a tensor op consumes a dimension register never latched.
    DimUnset,
    /// V-CFG-04: memory/compute before `VSETVLI` latches a vector length.
    VlUnset,
    /// V-CFG-05: invalid `VSACFG` encoding — undecodable zimm, a kernel
    /// field of 0 (keeps stale state), or a kernel size beyond the 4-bit
    /// field (must be Kseg-decomposed below 16).
    CfgEncoding,
    /// V-REG-01: a vector register is read before anything wrote it.
    UseBeforeDef,
    /// V-REG-02: a load destination is never consumed by any tensor,
    /// compute, or store instruction (dead write).
    DeadLoad,
    /// V-REG-03: a tensor operand register is not the destination of the
    /// most recent load of its class.
    StaleOperand,
    /// V-MEM-01: a load access is not contained in its input or weight
    /// region (or reads the output region).
    LoadOutOfRegion,
    /// V-MEM-02: an output store is misaligned, past the last row, not a
    /// full row, or not 32-bit.
    StoreNotRow,
    /// V-MEM-03: a partial spill/reload falls outside the spill region.
    PartialOutOfRegion,
    /// V-MEM-04: an access lands outside every region of the layout, or
    /// its address/length cannot be proven statically.
    UnprovenAccess,
    /// V-MEM-05: a load image exceeds the per-lane vector-register region.
    VrfOverflow,
    /// V-RUN-01: stream-run metadata is malformed (out of bounds,
    /// overlapping, or out of order).
    RunBounds,
    /// V-RUN-02: a tensor run is not a chain of identical bursts — the
    /// closed-form fast path would be unsound.
    TensorRunNotHomogeneous,
    /// V-RUN-03: a load run is not uniform `(li; vsald/vle)` pairs.
    LoadRunPairs,
    /// V-RUN-04: a store run is not `(li; vse)` pairs.
    StoreRunPairs,
    /// V-RUN-05: a tensor burst encodes zero stages.
    ZeroStageTensor,
    /// V-RES-01: an FF-strategy stream's weight traffic contradicts the
    /// declared mapping. The mapping promises exactly one full weight
    /// fetch plus [`crate::dataflow::ff_weight_refetches`] re-streamed
    /// tail elements; loading more (phantom refetches the cost model
    /// never charged) or fewer (declared refetches the stream never
    /// performs) is an error in either direction.
    WeightRefetch,
    /// V-RES-02: the stream loads fewer weight elements than the operator
    /// needs — part of the weight tensor never reaches the datapath.
    WeightCoverage,
}

impl Rule {
    /// Every rule, in table order.
    pub const ALL: [Rule; 20] = [
        Rule::CfgNotLatched,
        Rule::CfgMismatch,
        Rule::DimUnset,
        Rule::VlUnset,
        Rule::CfgEncoding,
        Rule::UseBeforeDef,
        Rule::DeadLoad,
        Rule::StaleOperand,
        Rule::LoadOutOfRegion,
        Rule::StoreNotRow,
        Rule::PartialOutOfRegion,
        Rule::UnprovenAccess,
        Rule::VrfOverflow,
        Rule::RunBounds,
        Rule::TensorRunNotHomogeneous,
        Rule::LoadRunPairs,
        Rule::StoreRunPairs,
        Rule::ZeroStageTensor,
        Rule::WeightRefetch,
        Rule::WeightCoverage,
    ];

    /// The stable rule identifier (`V-CFG-01` … `V-RES-02`).
    pub fn id(self) -> &'static str {
        match self {
            Rule::CfgNotLatched => "V-CFG-01",
            Rule::CfgMismatch => "V-CFG-02",
            Rule::DimUnset => "V-CFG-03",
            Rule::VlUnset => "V-CFG-04",
            Rule::CfgEncoding => "V-CFG-05",
            Rule::UseBeforeDef => "V-REG-01",
            Rule::DeadLoad => "V-REG-02",
            Rule::StaleOperand => "V-REG-03",
            Rule::LoadOutOfRegion => "V-MEM-01",
            Rule::StoreNotRow => "V-MEM-02",
            Rule::PartialOutOfRegion => "V-MEM-03",
            Rule::UnprovenAccess => "V-MEM-04",
            Rule::VrfOverflow => "V-MEM-05",
            Rule::RunBounds => "V-RUN-01",
            Rule::TensorRunNotHomogeneous => "V-RUN-02",
            Rule::LoadRunPairs => "V-RUN-03",
            Rule::StoreRunPairs => "V-RUN-04",
            Rule::ZeroStageTensor => "V-RUN-05",
            Rule::WeightRefetch => "V-RES-01",
            Rule::WeightCoverage => "V-RES-02",
        }
    }

    /// One-line human description of what the rule proves.
    pub fn summary(self) -> &'static str {
        match self {
            Rule::CfgNotLatched => "custom load/compute before any VSACFG latch",
            Rule::CfgMismatch => "latched config contradicts the compiled op/choice",
            Rule::DimUnset => "tensor op uses a dimension register never latched",
            Rule::VlUnset => "memory/compute before VSETVLI latches a vector length",
            Rule::CfgEncoding => "invalid VSACFG encoding (zimm / ksize 0 / ksize > 15)",
            Rule::UseBeforeDef => "vector register read before it was written",
            Rule::DeadLoad => "load destination never consumed (dead write)",
            Rule::StaleOperand => "tensor operand is not the latest load of its class",
            Rule::LoadOutOfRegion => "load not contained in its input/weight region",
            Rule::StoreNotRow => "output store misaligned, out of range, or not a row",
            Rule::PartialOutOfRegion => "partial spill/reload outside the spill region",
            Rule::UnprovenAccess => "access outside every region or not statically provable",
            Rule::VrfOverflow => "load image overflows a vector-register region",
            Rule::RunBounds => "stream-run metadata malformed (bounds/overlap/order)",
            Rule::TensorRunNotHomogeneous => "tensor run is not a chain of identical bursts",
            Rule::LoadRunPairs => "load run is not uniform (li; vsald/vle) pairs",
            Rule::StoreRunPairs => "store run is not (li; vse) pairs",
            Rule::ZeroStageTensor => "tensor burst encodes zero stages",
            Rule::WeightRefetch => "FF weight traffic contradicts the declared mapping",
            Rule::WeightCoverage => "stream loads fewer weight elements than the op needs",
        }
    }

    fn index(self) -> usize {
        Rule::ALL.iter().position(|r| *r == self).expect("rule in ALL")
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.id())
    }
}

/// One verifier finding: a rule violation at a stream position.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// The violated rule.
    pub rule: Rule,
    /// Segment index within the compiled program.
    pub segment: usize,
    /// Instruction index within the segment (0 for program-level findings).
    pub index: usize,
    /// Human-readable detail.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] seg {} insn {}: {}",
            self.rule.id(),
            self.segment,
            self.index,
            self.message
        )
    }
}

/// Outcome of verifying one compiled program.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    /// Stored diagnostics (at most [`MAX_DIAGNOSTICS`]; counts keep going).
    pub diagnostics: Vec<Diagnostic>,
    /// Total violations per rule, indexed like [`Rule::ALL`].
    pub rule_counts: [u64; Rule::ALL.len()],
    /// Instructions walked.
    pub insns: u64,
    /// Segments walked.
    pub segments: usize,
    /// True when diagnostics past [`MAX_DIAGNOSTICS`] were dropped.
    pub truncated: bool,
}

impl VerifyReport {
    /// No rule fired.
    pub fn is_clean(&self) -> bool {
        self.total_violations() == 0
    }

    /// Total violations across all rules (counted, not just stored).
    pub fn total_violations(&self) -> u64 {
        self.rule_counts.iter().sum()
    }

    /// Violation count for one rule.
    pub fn count(&self, rule: Rule) -> u64 {
        self.rule_counts[rule.index()]
    }

    /// Did this specific rule fire?
    pub fn fired(&self, rule: Rule) -> bool {
        self.count(rule) > 0
    }

    /// Fold the report into a typed error: `Ok(())` when clean, otherwise
    /// a [`SpeedError::Verify`] summarizing the first finding.
    pub fn into_result(self) -> Result<(), SpeedError> {
        if self.is_clean() {
            return Ok(());
        }
        let total = self.total_violations();
        let rules: Vec<&str> = Rule::ALL
            .iter()
            .filter(|r| self.fired(**r))
            .map(|r| r.id())
            .collect();
        let first = self
            .diagnostics
            .first()
            .map(|d| d.to_string())
            .unwrap_or_else(|| "no stored diagnostic".into());
        Err(SpeedError::Verify(format!(
            "{total} violation(s) of {rules}; first: {first}",
            rules = rules.join(", ")
        )))
    }
}

/// Tri-state abstract value for latched scalars (vl, dimension registers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AbsVal {
    /// Never latched.
    Unset,
    /// Latched from a value the verifier could not track.
    Unknown,
    /// Latched to a statically-known value.
    Known(u32),
}

/// Memory region of the operator layout an address falls in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Region {
    Input,
    Weight,
    Output,
    Partial,
    Outside,
}

/// The abstract interpreter. State persists across segments — the emitter
/// dedups `VSETVLI` on a `cur_vl` that survives segment cuts, and the
/// simulator's control state likewise persists between `run_segment`
/// calls, so per-segment-fresh analysis would be wrong on both counts.
#[derive(Debug)]
pub struct Verifier {
    op: OpDesc,
    cfg: SpeedConfig,
    choice: MappingChoice,
    layout: MemLayout,
    // ---- abstract machine state ----
    xregs: [Option<i64>; 32],
    vreg_defined: [bool; 32],
    latched: Option<(Precision, u32, StrategyKind)>,
    dims: [AbsVal; 9],
    vl: AbsVal,
    sew: u32,
    /// Loads not yet consumed: vd -> (segment, index) of the load.
    pending_loads: [Option<(usize, usize)>; 32],
    /// vd of the most recent VSALD (any region) — the MM operand slot.
    last_load_any: Option<u8>,
    /// vd of the most recent input-region VSALD.
    last_input_load: Option<u8>,
    /// vd of the most recent weight-region VSALD.
    last_weight_load: Option<u8>,
    /// Total weight elements loaded so far (None once unprovable).
    weight_elems_loaded: Option<u64>,
    // ---- reporting ----
    seg: usize,
    report: VerifyReport,
}

impl Verifier {
    /// Start verifying a program compiled from `op` under `choice` for
    /// `cfg`, placed at `layout`.
    pub fn new(op: &OpDesc, cfg: &SpeedConfig, choice: MappingChoice, layout: MemLayout) -> Self {
        let mut xregs = [None; 32];
        xregs[0] = Some(0); // x0 is architecturally zero
        let mut v = Verifier {
            op: *op,
            cfg: *cfg,
            choice,
            layout,
            xregs,
            vreg_defined: [false; 32],
            latched: None,
            dims: [AbsVal::Unset; 9],
            vl: AbsVal::Unset,
            sew: 8,
            pending_loads: [None; 32],
            last_load_any: None,
            last_input_load: None,
            last_weight_load: None,
            weight_elems_loaded: Some(0),
            seg: 0,
            report: VerifyReport::default(),
        };
        // A carried-residency mapping starts with layer N-1's output
        // already resident in the input partition: the stream legitimately
        // issues tensor ops without any input-region VSALD, reading the
        // carried rotation slot v0 (the emitter's `V_IN[0]`). Pre-seed the
        // abstract state so the register rules hold the same contract
        // against carried streams.
        if choice.carry_in {
            for r in 0..4 {
                v.vreg_defined[r] = true;
            }
            v.last_input_load = Some(0);
            v.last_load_any = Some(0);
        }
        // Program-level precondition: the 4-bit VSACFG kernel field cannot
        // carry a kernel this large; upstream must Kseg-decompose first.
        if op.ksize > 15 {
            v.emit(Rule::CfgEncoding, 0, || {
                format!(
                    "operator kernel size {} exceeds the 4-bit VSACFG field; \
                     Kseg-decompose below 16 before compiling",
                    op.ksize
                )
            });
        }
        v
    }

    fn emit(&mut self, rule: Rule, index: usize, msg: impl FnOnce() -> String) {
        self.report.rule_counts[rule.index()] += 1;
        if self.report.diagnostics.len() < MAX_DIAGNOSTICS {
            self.report.diagnostics.push(Diagnostic {
                rule,
                segment: self.seg,
                index,
                message: msg(),
            });
        } else {
            self.report.truncated = true;
        }
    }

    fn region_of(&self, addr: u64) -> Region {
        let l = &self.layout;
        if addr >= l.partial_addr {
            Region::Partial
        } else if addr >= l.out_addr {
            Region::Output
        } else if addr >= l.w_addr {
            Region::Weight
        } else if addr >= l.in_addr {
            Region::Input
        } else {
            Region::Outside
        }
    }

    /// The statically-known address in `rs1`, or a V-MEM-04 diagnostic.
    fn known_addr(&mut self, idx: usize, rs1: u8) -> Option<u64> {
        match self.xregs[rs1 as usize] {
            Some(a) if a >= 0 => Some(a as u64),
            Some(a) => {
                self.emit(Rule::UnprovenAccess, idx, || {
                    format!("address in x{rs1} is negative ({a})")
                });
                None
            }
            None => {
                self.emit(Rule::UnprovenAccess, idx, || {
                    format!("address in x{rs1} is not statically known")
                });
                None
            }
        }
    }

    /// The statically-known vector length, or a diagnostic (V-CFG-04 when
    /// never set, V-MEM-04 when set from an untracked scalar).
    fn known_vl(&mut self, idx: usize, what: &str) -> Option<u32> {
        match self.vl {
            AbsVal::Known(n) => Some(n),
            AbsVal::Unset => {
                self.emit(Rule::VlUnset, idx, || {
                    format!("{what} before any VSETVLI latched a vector length")
                });
                None
            }
            AbsVal::Unknown => {
                self.emit(Rule::UnprovenAccess, idx, || {
                    format!("{what} under a vector length that is not statically known")
                });
                None
            }
        }
    }

    fn require_cfg(&mut self, idx: usize, what: &str) {
        if self.latched.is_none() {
            self.emit(Rule::CfgNotLatched, idx, || {
                format!("{what} before any VSACFG latched precision/strategy")
            });
        }
    }

    /// The effective operand precision of a VSALD.
    fn width_prec(&self, width: WidthSel) -> Precision {
        match width {
            WidthSel::Explicit(p) => p,
            WidthSel::FromCfg => self.latched.map(|(p, _, _)| p).unwrap_or(self.op.prec),
        }
    }

    fn expected_dim(&self, d: Dim) -> u32 {
        let op = &self.op;
        match d {
            Dim::M => op.m,
            Dim::K => op.k,
            Dim::N => op.n,
            Dim::C => op.c,
            Dim::F => op.f,
            Dim::H => op.h,
            Dim::W => op.w,
            Dim::Stride => op.stride,
            Dim::NStages => 0,
        }
    }

    fn required_dims(&self) -> &'static [Dim] {
        match self.op.kind {
            OpKind::Mm => &[Dim::M, Dim::K, Dim::N],
            _ => &[Dim::C, Dim::F, Dim::H, Dim::W, Dim::Stride],
        }
    }

    /// Bounds-check a load of `bytes` at `addr`; returns the region. The
    /// one-byte slack for sub-byte precisions absorbs the nibble-packing
    /// ceiling: `bytes_for(off) + bytes_for(n)` can exceed
    /// `bytes_for(off + n)` by one when both round up.
    fn check_load_bounds(&mut self, idx: usize, addr: u64, bytes: u64, prec: Precision) -> Region {
        let l = self.layout;
        let op = self.op;
        let slack = u64::from(prec.bits() < 8);
        let end = addr + bytes;
        let region = self.region_of(addr);
        match region {
            Region::Input => {
                let limit = l.in_addr + op.input_bytes() + slack;
                if end > limit {
                    self.emit(Rule::LoadOutOfRegion, idx, || {
                        format!(
                            "load [{addr:#x}, {end:#x}) overruns the input region \
                             (ends at {limit:#x})"
                        )
                    });
                }
            }
            Region::Weight => {
                let limit = l.w_addr + op.weight_bytes() + slack;
                if end > limit {
                    self.emit(Rule::LoadOutOfRegion, idx, || {
                        format!(
                            "load [{addr:#x}, {end:#x}) overruns the weight region \
                             (ends at {limit:#x})"
                        )
                    });
                }
            }
            Region::Output => {
                self.emit(Rule::LoadOutOfRegion, idx, || {
                    format!("load at {addr:#x} reads the output region")
                });
            }
            Region::Partial => {
                let limit = l.partial_addr + op.output_bytes();
                if end > limit {
                    self.emit(Rule::PartialOutOfRegion, idx, || {
                        format!(
                            "partial reload [{addr:#x}, {end:#x}) overruns the spill \
                             region (ends at {limit:#x})"
                        )
                    });
                }
            }
            Region::Outside => {
                self.emit(Rule::UnprovenAccess, idx, || {
                    format!("load at {addr:#x} lies below every region of the layout")
                });
            }
        }
        region
    }

    /// Mirror of the simulator's per-lane VRF capacity check
    /// (`Processor::load_to_vrf`): broadcast images must fit one vector
    /// register region; sequential images are striped across lanes.
    fn check_vrf_capacity(&mut self, idx: usize, vd: u8, bytes: u64, broadcast: bool) {
        let region = vreg_region(&self.cfg) as u64;
        if broadcast {
            if bytes > region {
                self.emit(Rule::VrfOverflow, idx, || {
                    format!(
                        "broadcast load of {bytes} B into v{vd} exceeds the \
                         {region} B vector-register region"
                    )
                });
            }
        } else {
            let per_lane = bytes.div_ceil(self.cfg.lanes as u64);
            if per_lane > region {
                self.emit(Rule::VrfOverflow, idx, || {
                    format!(
                        "sequential load of {bytes} B into v{vd} needs {per_lane} B \
                         per lane, exceeding the {region} B vector-register region"
                    )
                });
            }
        }
    }

    /// A vector register was read by a content-bearing instruction.
    fn consume_vreg(&mut self, idx: usize, r: u8, what: &str) {
        if !self.vreg_defined[r as usize] {
            self.emit(Rule::UseBeforeDef, idx, || {
                format!("{what} reads v{r} before anything wrote it")
            });
        }
        self.pending_loads[r as usize] = None;
    }

    /// Verify one segment, advancing the persistent abstract state.
    pub fn check_segment(&mut self, seg: &Segment) {
        self.check_runs(seg);
        for (idx, insn) in seg.insns.iter().enumerate() {
            self.step(idx, insn);
        }
        self.report.insns += seg.insns.len() as u64;
        self.report.segments += 1;
        self.seg += 1;
    }

    fn step(&mut self, idx: usize, insn: &Insn) {
        match *insn {
            Insn::Addi { rd, rs1, imm } => {
                if rd != 0 {
                    self.xregs[rd as usize] = if rs1 == 0 {
                        Some(imm as i64)
                    } else {
                        self.xregs[rs1 as usize].map(|v| v + imm as i64)
                    };
                }
            }
            Insn::Vsacfg { zimm, .. } => self.latch_cfg(idx, zimm),
            Insn::VsacfgDim { rs1, dim, .. } => {
                let val = match self.xregs[rs1 as usize] {
                    Some(v) if v >= 0 && v <= u32::MAX as i64 => AbsVal::Known(v as u32),
                    _ => AbsVal::Unknown,
                };
                self.dims[dim.code() as usize] = val;
                if let AbsVal::Known(v) = val {
                    let want = self.expected_dim(dim);
                    if self.required_dims().contains(&dim) && v != want {
                        self.emit(Rule::CfgMismatch, idx, || {
                            format!("dimension {dim} latched as {v} but the operator has {want}")
                        });
                    }
                }
            }
            Insn::Vsetvli { rs1, vtype, .. } => {
                self.sew = vtype.sew;
                if rs1 != 0 {
                    self.vl = match self.xregs[rs1 as usize] {
                        Some(v) if v >= 0 && v <= u32::MAX as i64 => AbsVal::Known(v as u32),
                        _ => AbsVal::Unknown,
                    };
                }
            }
            Insn::Vsald { vd, rs1, mode, width } => self.step_vsald(idx, vd, rs1, mode, width),
            Insn::Vle { vd, rs1, eew } => self.step_vle(idx, vd, rs1, eew),
            Insn::Vse { vs3, rs1, eew } => self.step_vse(idx, vs3, rs1, eew),
            Insn::Vsam { vd, vs1, vs2, stages } | Insn::Vsac { vd, vs1, vs2, stages } => {
                self.step_tensor(idx, vd, vs1, vs2, stages)
            }
            Insn::Vmacc { .. }
            | Insn::Vmul { .. }
            | Insn::Vadd { .. }
            | Insn::Vsub { .. }
            | Insn::Vmax { .. }
            | Insn::Vmin { .. }
            | Insn::Vsra { .. } => {
                let _ = self.known_vl(idx, "elementwise vector op");
                for r in insn.vregs_read() {
                    self.consume_vreg(idx, r, "elementwise vector op");
                }
                for r in insn.vregs_written() {
                    self.vreg_defined[r as usize] = true;
                }
            }
            Insn::Vmv { vd, .. } => {
                self.vreg_defined[vd as usize] = true;
            }
        }
    }

    fn latch_cfg(&mut self, idx: usize, zimm: u16) {
        let Some((prec, ksize, strat)) = Insn::unpack_cfg(zimm) else {
            self.emit(Rule::CfgEncoding, idx, || {
                format!("VSACFG zimm {zimm:#06x} does not decode to a precision/strategy")
            });
            return;
        };
        if ksize == 0 {
            self.emit(Rule::CfgEncoding, idx, || {
                "VSACFG kernel field is 0: the kernel size would keep stale state".into()
            });
        }
        // Latching mirrors CtrlState::apply: precision and strategy always
        // latch; a zero kernel field keeps the previous kernel size.
        let eff_ksize = if ksize > 0 {
            ksize
        } else {
            self.latched.map(|(_, k, _)| k).unwrap_or(1)
        };
        self.latched = Some((prec, eff_ksize, strat));
        if prec != self.op.prec {
            let want = self.op.prec;
            self.emit(Rule::CfgMismatch, idx, || {
                format!("VSACFG latches {prec} but the program was compiled for {want}")
            });
        }
        if strat != self.choice.strat {
            let want = self.choice.strat;
            self.emit(Rule::CfgMismatch, idx, || {
                format!("VSACFG latches strategy {strat} but the mapping choice is {want}")
            });
        }
        let want_k = self.op.ksize.max(1).min(15);
        if eff_ksize != want_k {
            self.emit(Rule::CfgMismatch, idx, || {
                format!("VSACFG latches kernel size {eff_ksize} but the operator has {want_k}")
            });
        }
    }

    fn step_vsald(&mut self, idx: usize, vd: u8, rs1: u8, mode: LdMode, width: WidthSel) {
        self.require_cfg(idx, "VSALD");
        let prec = self.width_prec(width);
        let vl = self.known_vl(idx, "VSALD");
        let addr = self.known_addr(idx, rs1);
        let mut region = None;
        if let (Some(addr), Some(vl)) = (addr, vl) {
            let bytes = prec.bytes_for(vl as u64);
            region = Some(self.check_load_bounds(idx, addr, bytes, prec));
            self.check_vrf_capacity(idx, vd, bytes, mode == LdMode::Broadcast);
        }
        self.vreg_defined[vd as usize] = true;
        self.pending_loads[vd as usize] = Some((self.seg, idx));
        self.last_load_any = Some(vd);
        match region {
            Some(Region::Input) => self.last_input_load = Some(vd),
            Some(Region::Weight) => {
                self.last_weight_load = Some(vd);
                self.weight_elems_loaded = match (self.weight_elems_loaded, vl) {
                    (Some(t), Some(n)) => Some(t + n as u64),
                    _ => None,
                };
            }
            _ => {
                // Unknown address/length: weight accounting is unprovable.
                if region.is_none() {
                    self.weight_elems_loaded = None;
                }
            }
        }
    }

    fn step_vle(&mut self, idx: usize, vd: u8, rs1: u8, eew: u32) {
        let vl = self.known_vl(idx, "VLE");
        let addr = self.known_addr(idx, rs1);
        if let (Some(addr), Some(vl)) = (addr, vl) {
            let bytes = vl as u64 * (eew as u64 / 8);
            self.check_load_bounds(idx, addr, bytes, Precision::Int8);
            self.check_vrf_capacity(idx, vd, bytes, false);
        }
        self.vreg_defined[vd as usize] = true;
        self.pending_loads[vd as usize] = Some((self.seg, idx));
    }

    fn step_vse(&mut self, idx: usize, vs3: u8, rs1: u8, eew: u32) {
        let vl = self.known_vl(idx, "VSE");
        let addr = self.known_addr(idx, rs1);
        let Some(addr) = addr else {
            self.pending_loads[vs3 as usize] = None;
            return;
        };
        let l = self.layout;
        let op = self.op;
        match self.region_of(addr) {
            Region::Partial => {
                // Spill path: the store drains the accumulator partition —
                // vs3 is architecturally allowed to be a register nothing
                // wrote (the first spill of a block), so no def check.
                if self.sew != 32 {
                    let sew = self.sew;
                    self.emit(Rule::PartialOutOfRegion, idx, || {
                        format!("partial spill at SEW {sew}; partials are 32-bit accumulators")
                    });
                }
                if let Some(vl) = vl {
                    let end = addr + vl as u64 * 4;
                    let limit = l.partial_addr + op.output_bytes();
                    if end > limit {
                        self.emit(Rule::PartialOutOfRegion, idx, || {
                            format!(
                                "partial spill [{addr:#x}, {end:#x}) overruns the spill \
                                 region (ends at {limit:#x})"
                            )
                        });
                    }
                }
            }
            Region::Output => {
                self.consume_vreg(idx, vs3, "VSE");
                let row_bytes = op.output_row_elems() * 4;
                if eew != 32 {
                    self.emit(Rule::StoreNotRow, idx, || {
                        format!("output store at EEW {eew}; rows are 32-bit accumulators")
                    });
                }
                if row_bytes == 0 || (addr - l.out_addr) % row_bytes != 0 {
                    self.emit(Rule::StoreNotRow, idx, || {
                        format!(
                            "store at {addr:#x} is not aligned to a {row_bytes}-byte \
                             output row"
                        )
                    });
                } else {
                    let row = (addr - l.out_addr) / row_bytes;
                    if row >= op.output_rows() {
                        let rows = op.output_rows();
                        self.emit(Rule::StoreNotRow, idx, || {
                            format!("store drains row {row} of a {rows}-row output")
                        });
                    }
                }
                if let Some(vl) = vl {
                    if vl as u64 != op.output_row_elems() {
                        let want = op.output_row_elems();
                        self.emit(Rule::StoreNotRow, idx, || {
                            format!("store of {vl} elements; an output row has {want}")
                        });
                    }
                }
            }
            Region::Input | Region::Weight | Region::Outside => {
                self.emit(Rule::StoreNotRow, idx, || {
                    format!("store at {addr:#x} targets neither the output nor spill region")
                });
                self.pending_loads[vs3 as usize] = None;
            }
        }
    }

    fn step_tensor(&mut self, idx: usize, vd: u8, vs1: u8, vs2: u8, stages: u8) {
        self.require_cfg(idx, "tensor op");
        if stages == 0 {
            self.emit(Rule::ZeroStageTensor, idx, || {
                "tensor burst encodes zero stages".into()
            });
        }
        for d in self.required_dims() {
            if self.dims[d.code() as usize] == AbsVal::Unset {
                self.emit(Rule::DimUnset, idx, || {
                    format!("tensor op before dimension {d} was latched")
                });
            }
        }
        let strat = self.latched.map(|(_, _, s)| s).unwrap_or(self.choice.strat);
        if strat == StrategyKind::Mm {
            // MM has no weight bank: A and B tiles both rotate through the
            // input slots, and vs2 is a don't-care slot the MPTU ignores.
            match self.last_load_any {
                None => self.emit(Rule::UseBeforeDef, idx, || {
                    format!("tensor op reads v{vs1} before any VSALD ran")
                }),
                Some(last) if last != vs1 => self.emit(Rule::StaleOperand, idx, || {
                    format!("tensor operand v{vs1} is stale; the latest load wrote v{last}")
                }),
                _ => {}
            }
        } else {
            match self.last_input_load {
                None => self.emit(Rule::UseBeforeDef, idx, || {
                    format!("tensor op reads v{vs1} before any input-region VSALD ran")
                }),
                Some(last) if last != vs1 => self.emit(Rule::StaleOperand, idx, || {
                    format!(
                        "tensor input operand v{vs1} is stale; the latest input load \
                         wrote v{last}"
                    )
                }),
                _ => {}
            }
            match self.last_weight_load {
                None => self.emit(Rule::UseBeforeDef, idx, || {
                    format!("tensor op reads v{vs2} before any weight-region VSALD ran")
                }),
                Some(last) if last != vs2 => self.emit(Rule::StaleOperand, idx, || {
                    format!(
                        "tensor weight operand v{vs2} is stale; the latest weight load \
                         wrote v{last}"
                    )
                }),
                _ => {}
            }
        }
        // The MPTU consumes whole partitions: every staged load is live.
        self.pending_loads = [None; 32];
        self.vreg_defined[vd as usize] = true;
    }

    /// Validate the segment's stream-run metadata (the batch fast path
    /// trusts it: `Processor::run_segment` dispatches whole runs through
    /// closed-form scheduling).
    fn check_runs(&mut self, seg: &Segment) {
        let mut last_end = 0u32;
        for r in &seg.runs {
            let end = r.start.saturating_add(r.len);
            if r.len == 0 || r.start < last_end || end as usize > seg.insns.len() {
                self.emit(Rule::RunBounds, r.start as usize, || {
                    format!(
                        "run [{}, {}) is empty, overlapping, or past the segment \
                         ({} insns)",
                        r.start,
                        end,
                        seg.insns.len()
                    )
                });
                continue;
            }
            last_end = end;
            let body = &seg.insns[r.start as usize..end as usize];
            match r.kind {
                RunKind::Tensor => {
                    let first = body[0];
                    if !matches!(first, Insn::Vsam { .. } | Insn::Vsac { .. })
                        || body.iter().any(|i| *i != first)
                    {
                        self.emit(Rule::TensorRunNotHomogeneous, r.start as usize, || {
                            format!(
                                "tensor run [{}, {}) is not a chain of identical \
                                 VSAM/VSAC bursts",
                                r.start, end
                            )
                        });
                    }
                }
                RunKind::Load => {
                    if body.len() % 2 != 0 || !valid_load_pairs(body) {
                        self.emit(Rule::LoadRunPairs, r.start as usize, || {
                            format!(
                                "load run [{}, {}) is not uniform (li; vsald/vle) pairs",
                                r.start, end
                            )
                        });
                    }
                }
                RunKind::Store => {
                    if body.len() % 2 != 0 || !valid_store_pairs(body) {
                        self.emit(Rule::StoreRunPairs, r.start as usize, || {
                            format!("store run [{}, {}) is not (li; vse) pairs", r.start, end)
                        });
                    }
                }
            }
        }
    }

    /// Finish the walk: end-of-stream rules (dead loads, residency) and
    /// the final report.
    pub fn finish(mut self) -> VerifyReport {
        for vd in 0..32u8 {
            if let Some((seg, idx)) = self.pending_loads[vd as usize] {
                self.seg = seg;
                self.emit(Rule::DeadLoad, idx, || {
                    format!("load into v{vd} is never consumed by any tensor/compute/store")
                });
            }
        }
        self.seg = self.report.segments;
        if let Some(total) = self.weight_elems_loaded {
            let want = self.op.weight_elems();
            if total < want {
                self.emit(Rule::WeightCoverage, 0, || {
                    format!(
                        "stream loads only {total} of {want} weight elements: part of \
                         the weight tensor never reaches the datapath"
                    )
                });
            } else if self.choice.strat == StrategyKind::Ff {
                // Mapping-aware residency: the declared mapping promises
                // one full fetch plus exactly `ff_weight_refetches`
                // re-streamed tail elements. A contradiction in either
                // direction is an error — more means the cost model never
                // charged the extra traffic, fewer means the stream skips
                // refetches the mapping declared.
                let refetch = crate::dataflow::ff_weight_refetches(
                    &self.op,
                    &self.cfg,
                    self.choice.chunk,
                );
                let expected = want + refetch;
                if total != expected {
                    self.emit(Rule::WeightRefetch, 0, || {
                        format!(
                            "FF stream loads {total} weight elements but the mapping \
                             declares {expected} ({want} resident + {refetch} \
                             refetched): the stream contradicts the costed mapping"
                        )
                    });
                }
            }
        }
        self.report
    }
}

/// Mirror of `Processor::valid_load_pairs`: uniform `(li xN, addr ;
/// vsald/vle vX, (xN))` pairs keyed on the first transfer. Shared with
/// the lint pass (`L-RUN-01` proves a merged run would still batch).
pub(crate) fn valid_load_pairs(body: &[Insn]) -> bool {
    if body.len() < 2 {
        return false;
    }
    let key = body[1];
    body.chunks_exact(2).all(|p| match (p[0], p[1]) {
        (Insn::Addi { rd, rs1: 0, .. }, Insn::Vsald { rs1, mode, width, .. }) => {
            rd != 0
                && rs1 == rd
                && matches!(key, Insn::Vsald { mode: km, width: kw, .. }
                    if km == mode && kw == width)
        }
        (Insn::Addi { rd, rs1: 0, .. }, Insn::Vle { rs1, eew, .. }) => {
            rd != 0 && rs1 == rd && matches!(key, Insn::Vle { eew: ke, .. } if ke == eew)
        }
        _ => false,
    })
}

/// Mirror of `Processor::valid_store_pairs`: `(li xN, addr ; vse vS, (xN))`.
pub(crate) fn valid_store_pairs(body: &[Insn]) -> bool {
    body.chunks_exact(2).all(|p| match (p[0], p[1]) {
        (Insn::Addi { rd, rs1: 0, .. }, Insn::Vse { rs1, .. }) => rd != 0 && rs1 == rd,
        _ => false,
    })
}

/// Verify already-materialized segments of a program compiled from `op`
/// under `choice` for `cfg` at `layout`.
pub fn verify_segments(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
    layout: MemLayout,
    segments: &[Segment],
) -> VerifyReport {
    let mut v = Verifier::new(op, cfg, choice, layout);
    for seg in segments {
        v.check_segment(seg);
    }
    v.finish()
}

/// Compile `op` under `choice` (streaming — the instruction stream is
/// never materialized) and verify it against the canonical layout.
/// Compilation failures surface as their own typed errors.
pub fn verify_op(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
) -> Result<VerifyReport, SpeedError> {
    let (layout, _) = MemLayout::place(op);
    let mut v = Verifier::new(op, cfg, choice, layout);
    {
        let mut feed = |seg: Segment| -> Result<(), SpeedError> {
            v.check_segment(&seg);
            Ok(())
        };
        crate::compiler::stream_op_with(op, cfg, choice, &layout, &mut feed)?;
    }
    Ok(v.finish())
}

/// [`verify_op`] folded to a typed error: `Ok(())` when the stream is
/// clean, [`SpeedError::Verify`] otherwise.
pub fn ensure_verified(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
) -> Result<(), SpeedError> {
    verify_op(op, cfg, choice)?.into_result()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile_op_with;

    fn cfg() -> SpeedConfig {
        SpeedConfig::reference()
    }

    fn compile(op: &OpDesc, choice: MappingChoice) -> (MemLayout, Vec<Segment>) {
        let (layout, _) = MemLayout::place(op);
        let c = compile_op_with(op, &cfg(), choice, layout, false).unwrap();
        (layout, c.segments)
    }

    #[test]
    fn compiled_streams_verify_clean_across_kinds_and_strategies() {
        let cases = [
            (OpDesc::mm(12, 48, 10, Precision::Int8), StrategyKind::Mm),
            (OpDesc::mm(1, 32, 40, Precision::Int4), StrategyKind::Mm),
            (OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int16), StrategyKind::Ffcs),
            (OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8), StrategyKind::Cf),
            (OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8), StrategyKind::Ff),
            (OpDesc::pwcv(16, 16, 8, 8, Precision::Int4), StrategyKind::Cf),
            (OpDesc::dwcv(8, 9, 9, 3, 2, 1, Precision::Int8), StrategyKind::Ff),
        ];
        for (op, strat) in cases {
            let choice = MappingChoice::of(strat);
            let (layout, segs) = compile(&op, choice);
            let report = verify_segments(&op, &cfg(), choice, layout, &segs);
            assert!(
                report.is_clean(),
                "{op:?} {strat}: {:?}",
                report.diagnostics.first()
            );
            assert!(report.insns > 0 && report.segments > 0);
        }
    }

    #[test]
    fn spilled_ffcs_stream_verifies_clean() {
        // Large feature map forces the partial spill/reload path.
        let op = OpDesc::conv(8, 64, 40, 40, 3, 1, 1, Precision::Int8);
        let choice = MappingChoice::of(StrategyKind::Ffcs);
        let report = verify_op(&op, &cfg(), choice).unwrap();
        assert!(report.is_clean(), "{:?}", report.diagnostics.first());
    }

    #[test]
    fn spilled_ff_stream_verifies_clean() {
        // F = 608 INT8 spills the FF weight tail on the reference config:
        // the compiled stream performs exactly the refetches the mapping
        // declares, so the mapping-aware V-RES-01 stays silent.
        let op = OpDesc::conv(8, 608, 6, 6, 3, 1, 1, Precision::Int8);
        assert!(!crate::dataflow::ff_weights_resident(&op, &cfg()));
        let choice = MappingChoice::of(StrategyKind::Ff);
        let report = verify_op(&op, &cfg(), choice).unwrap();
        assert!(report.is_clean(), "{:?}", report.diagnostics.first());
    }

    #[test]
    fn ff_refetch_contradiction_fires_in_both_directions() {
        let c = cfg();
        // More weight traffic than declared: a resident stream (zero
        // declared refetches) with one extra weight load appended.
        let op = OpDesc::conv(8, 604, 6, 6, 3, 1, 1, Precision::Int8);
        assert!(crate::dataflow::ff_weights_resident(&op, &c));
        let choice = MappingChoice::of(StrategyKind::Ff);
        let (layout, mut segs) = compile(&op, choice);
        segs.push(Segment {
            insns: vec![
                Insn::Addi { rd: 29, rs1: 0, imm: layout.w_addr as i32 },
                Insn::Vsald { vd: 4, rs1: 29, mode: LdMode::Sequential, width: WidthSel::FromCfg },
            ],
            runs: vec![],
        });
        let report = verify_segments(&op, &c, choice, layout, &segs);
        assert!(report.fired(Rule::WeightRefetch), "{:?}", report.diagnostics);

        // Fewer than declared: a spilled stream with its last tail-refetch
        // load blanked out skips traffic the mapping costed.
        let op = OpDesc::conv(8, 608, 6, 6, 3, 1, 1, Precision::Int8);
        let (layout, mut segs) = compile(&op, choice);
        let mut victim = None;
        for (si, seg) in segs.iter().enumerate() {
            for i in 0..seg.insns.len().saturating_sub(1) {
                if let (Insn::Addi { imm, .. }, Insn::Vsald { mode: LdMode::Sequential, .. }) =
                    (seg.insns[i], seg.insns[i + 1])
                {
                    if (imm as u64) >= layout.w_addr && (imm as u64) < layout.out_addr {
                        victim = Some((si, i));
                    }
                }
            }
        }
        let (si, i) = victim.expect("spilled FF stream has weight loads");
        segs[si].insns[i] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
        segs[si].insns[i + 1] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
        let report = verify_segments(&op, &c, choice, layout, &segs);
        assert!(report.fired(Rule::WeightRefetch), "{:?}", report.diagnostics);
    }

    #[test]
    fn carried_streams_verify_clean() {
        // Carried-residency mappings elide every input load; the pre-seeded
        // abstract state must keep the register rules satisfied for both
        // the MM and conv-family generators.
        let cases = [
            (OpDesc::mm(1, 128, 256, Precision::Int8), StrategyKind::Mm),
            (OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8), StrategyKind::Ffcs),
        ];
        for (op, strat) in cases {
            let choice = MappingChoice { carry_in: true, ..MappingChoice::of(strat) };
            let report = verify_op(&op, &cfg(), choice).unwrap();
            assert!(report.is_clean(), "{op:?} {strat}: {:?}", report.diagnostics.first());
        }
    }

    #[test]
    fn dropped_vsacfg_fires_cfg_rule() {
        let op = OpDesc::mm(8, 16, 8, Precision::Int8);
        let choice = MappingChoice::of(StrategyKind::Mm);
        let (layout, mut segs) = compile(&op, choice);
        let pos = segs[0]
            .insns
            .iter()
            .position(|i| matches!(i, Insn::Vsacfg { .. }))
            .unwrap();
        // Replace in place so run indices stay valid.
        segs[0].insns[pos] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
        let report = verify_segments(&op, &cfg(), choice, layout, &segs);
        assert!(report.fired(Rule::CfgNotLatched), "{:?}", report.diagnostics);
    }

    #[test]
    fn wrong_precision_fires_mismatch() {
        let op = OpDesc::mm(8, 16, 8, Precision::Int8);
        let choice = MappingChoice::of(StrategyKind::Mm);
        let (layout, mut segs) = compile(&op, choice);
        let pos = segs[0]
            .insns
            .iter()
            .position(|i| matches!(i, Insn::Vsacfg { .. }))
            .unwrap();
        segs[0].insns[pos] = Insn::Vsacfg {
            rd: 25,
            zimm: Insn::pack_cfg(Precision::Int16, 1, StrategyKind::Mm),
            uimm: 0,
        };
        let report = verify_segments(&op, &cfg(), choice, layout, &segs);
        assert!(report.fired(Rule::CfgMismatch), "{:?}", report.diagnostics);
        assert!(!report.fired(Rule::CfgNotLatched));
    }

    #[test]
    fn oversized_kernel_is_a_program_level_encoding_violation() {
        let op = OpDesc::conv(4, 4, 40, 40, 17, 1, 1, Precision::Int8);
        let (layout, _) = MemLayout::place(&op);
        let report =
            verify_segments(&op, &cfg(), MappingChoice::of(StrategyKind::Ffcs), layout, &[]);
        assert!(report.fired(Rule::CfgEncoding));
    }

    #[test]
    fn report_folds_into_typed_verify_error() {
        let op = OpDesc::mm(8, 16, 8, Precision::Int8);
        let choice = MappingChoice::of(StrategyKind::Mm);
        let (layout, mut segs) = compile(&op, choice);
        segs[0].insns[0] = Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 0 };
        let report = verify_segments(&op, &cfg(), choice, layout, &segs);
        let err = report.into_result().unwrap_err();
        assert!(matches!(err, SpeedError::Verify(_)), "{err}");
        assert!(err.to_string().contains("V-RUN-05"), "{err}");
    }

    #[test]
    fn rule_ids_are_unique_and_stable() {
        for (i, a) in Rule::ALL.iter().enumerate() {
            assert_eq!(a.index(), i);
            for b in &Rule::ALL[i + 1..] {
                assert_ne!(a.id(), b.id());
            }
        }
        assert_eq!(Rule::CfgNotLatched.id(), "V-CFG-01");
        assert_eq!(Rule::WeightCoverage.id(), "V-RES-02");
    }
}
