//! Liveness and performance lints over compiled instruction streams.
//!
//! Where the verifier ([`crate::analysis::verify_segments`]) proves a
//! stream *legal* (violations are errors that stop execution), the linter
//! flags streams that are legal but *wasteful*: work the processor will
//! happily pay for that a better schedule would not emit. Findings are
//! warnings, reported through a [`LintReport`] that never folds into an
//! error — the severity contract with [`crate::analysis::VerifyReport`].
//!
//! ## Rules
//!
//! | ID          | Finding                                                  |
//! |-------------|----------------------------------------------------------|
//! | `L-DEAD-01` | vector-ALU/VMV result overwritten before any read        |
//! | `L-LOAD-01` | reload of data a live vector register already holds      |
//! | `L-CFG-01`  | config re-latch that changes nothing / precision thrash  |
//! | `L-RUN-01`  | adjacent same-pattern runs a single batch run could cover|
//! | `L-VRF-01`  | register footprint near the 32-entry VRF budget          |
//! | `L-RES-01`  | mapping spills partial sums off-chip (geometry, see      |
//! |             | [`lint_mapping`] — never fired by the stream walkers)    |
//!
//! ## Soundness against the operator compiler
//!
//! Every rule is designed to be *provably silent* on the compiler's own
//! output (the `clean` tier-2 test sweeps the whole zoo), which is what
//! makes a finding actionable rather than noise:
//!
//! * `L-DEAD-01` deliberately excludes loads. A `VSALD`/`VLE` destination
//!   is a partition *handle*, not a value container: multi-chunk loads
//!   rotate a small register window while the data accumulates in the
//!   MPTU partition, so "overwritten before read" is normal for loads
//!   (the same reason the verifier's dead-load rule only fires at stream
//!   end). Vector-ALU and `VMV` results, by contrast, live in the named
//!   register — and the compiler emits none, so clean streams cannot fire.
//! * `L-LOAD-01` requires a statically known address identical to what
//!   the same register already holds, and its tracking table is cleared
//!   by tensor ops (which consume the partition) and stores (which may
//!   alias the loaded region). Compiled split loads strictly advance
//!   their addresses, so clean streams cannot fire.
//! * `L-CFG-01` needs a *previously latched* state to call a re-latch
//!   redundant; the compiler emits exactly one `VSACFG` per stream and
//!   dedups `VSETVLI` on the emitter's `cur_vl`, which survives segment
//!   cuts.
//! * `L-RUN-01` fires only when the concatenated bodies of two adjacent
//!   runs would still validate as one batch run; the emitter only closes
//!   a run when the pattern key changes or the segment cuts, so compiled
//!   metadata is already maximal.
//! * `L-VRF-01` fires at ≥ [`VRF_PRESSURE_REGS`] distinct registers; the
//!   compiler's fixed allocation touches eight.
//! * `L-RES-01` is a *mapping* lint, not a stream lint: full-size zoo
//!   shapes legitimately spill partials (the compiler emits the real
//!   spill/reload round-trips, and the cost model charges them), so
//!   wiring it into the stream walkers would make every big-fmap layer
//!   "dirty". It only fires from [`lint_mapping`], the advisory entry the
//!   tuner and reports call when they want the residency geometry of a
//!   specific `(op, choice)` surfaced.

use std::fmt;

use crate::compiler::{self, MemLayout};
use crate::config::{Precision, SpeedConfig};
use crate::dataflow::MappingChoice;
use crate::error::SpeedError;
use crate::isa::{Insn, LdMode, RunKind, Segment, StrategyKind, WidthSel};
use crate::models::ops::OpDesc;

use super::verify::{valid_load_pairs, valid_store_pairs};

/// Findings kept per report; further findings only bump the counts.
pub const MAX_FINDINGS: usize = 256;

/// Distinct-register threshold for `L-VRF-01` (of the 32 architectural
/// vector registers).
pub const VRF_PRESSURE_REGS: u32 = 28;

/// Stable lint-rule identifiers. Warning-severity counterparts to the
/// verifier's [`crate::analysis::Rule`]s: `L-*` findings never stop a
/// program from running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LintRule {
    /// `L-DEAD-01`: a vector-ALU/`VMV` result is overwritten before any
    /// instruction reads it — the defining instruction was wasted work.
    DeadDef,
    /// `L-LOAD-01`: a load transfers data the destination register
    /// provably still holds (same address, same shape, no intervening
    /// write/consume) — the reload pays memory latency for nothing.
    RedundantLoad,
    /// `L-CFG-01`: a configuration instruction re-latches the exact
    /// current state, or switches precision straight back without any
    /// tensor work in between.
    RedundantCfg,
    /// `L-RUN-01`: two adjacent stream runs of the same pattern would
    /// validate as a single batch run — the split costs the simulator's
    /// per-run dispatch and the ≥ 1-cycle run clamp.
    CoalescableRuns,
    /// `L-VRF-01`: the stream's register footprint is within a few
    /// registers of the 32-entry budget; one more live value forces a
    /// spill (estimated cost attached to the finding).
    VrfPressure,
    /// `L-RES-01`: the mapping's partial sums do not fit the VRF partial
    /// partition ([`crate::dataflow::Mapping::partials_in_vrf`] is
    /// false) — every channel pass round-trips partials off-chip. A
    /// geometry finding from [`lint_mapping`] only; the stream walkers
    /// never fire it (the spill traffic is legal and honestly costed).
    PartialSpill,
}

impl LintRule {
    /// All rules, in stable report order.
    pub const ALL: [LintRule; 6] = [
        LintRule::DeadDef,
        LintRule::RedundantLoad,
        LintRule::RedundantCfg,
        LintRule::CoalescableRuns,
        LintRule::VrfPressure,
        LintRule::PartialSpill,
    ];

    /// Stable rule identifier (reports, JSON, CI greps).
    pub fn id(self) -> &'static str {
        match self {
            LintRule::DeadDef => "L-DEAD-01",
            LintRule::RedundantLoad => "L-LOAD-01",
            LintRule::RedundantCfg => "L-CFG-01",
            LintRule::CoalescableRuns => "L-RUN-01",
            LintRule::VrfPressure => "L-VRF-01",
            LintRule::PartialSpill => "L-RES-01",
        }
    }

    /// One-line description of what the rule flags.
    pub fn summary(self) -> &'static str {
        match self {
            LintRule::DeadDef => "vector result overwritten before any read",
            LintRule::RedundantLoad => "reload of data the register already holds",
            LintRule::RedundantCfg => "configuration re-latch that changes nothing",
            LintRule::CoalescableRuns => "adjacent runs coalescable into one batch run",
            LintRule::VrfPressure => "register footprint near the VRF budget",
            LintRule::PartialSpill => "mapping spills partial sums off-chip",
        }
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|r| *r == self).expect("rule in ALL")
    }
}

impl fmt::Display for LintRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.id())
    }
}

/// One lint finding, located at `(segment, index)` like the verifier's
/// diagnostics.
#[derive(Debug, Clone)]
pub struct Finding {
    /// The rule that fired.
    pub rule: LintRule,
    /// Segment index within the compiled stream.
    pub segment: usize,
    /// Instruction index within the segment (for `L-VRF-01`, the last
    /// instruction of the stream).
    pub index: usize,
    /// Human-readable explanation with the concrete values involved.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] seg {} insn {}: {}",
            self.rule.id(),
            self.segment,
            self.index,
            self.message
        )
    }
}

/// The linter's result: warning-level findings plus per-rule counts.
/// Unlike [`crate::analysis::VerifyReport`] there is no conversion to an
/// error — a dirty report is advice, not a gate.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Findings in stream order (capped at [`MAX_FINDINGS`]).
    pub findings: Vec<Finding>,
    /// Per-rule firing counts, indexed like [`LintRule::ALL`] (counted
    /// even past the finding cap).
    pub rule_counts: [u64; LintRule::ALL.len()],
    /// Instructions inspected.
    pub insns: u64,
    /// Segments inspected.
    pub segments: usize,
    /// Whether findings were dropped at the cap.
    pub truncated: bool,
}

impl LintReport {
    /// True when no rule fired.
    pub fn is_clean(&self) -> bool {
        self.rule_counts.iter().all(|&c| c == 0)
    }

    /// Total findings across all rules (including any past the cap).
    pub fn total_warnings(&self) -> u64 {
        self.rule_counts.iter().sum()
    }

    /// Firing count of one rule.
    pub fn count(&self, rule: LintRule) -> u64 {
        self.rule_counts[rule.index()]
    }

    /// Whether one rule fired at all.
    pub fn fired(&self, rule: LintRule) -> bool {
        self.count(rule) > 0
    }
}

/// What a vector register currently holds, as far as the linter can
/// prove.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RegVal {
    /// Nothing tracked (initial, or invalidated).
    Unknown,
    /// A vector-ALU/`VMV` result defined at `(segment, index)`, not yet
    /// read. True register semantics: safe to call dead on overwrite.
    UnreadDef { segment: usize, index: usize },
    /// A read (consumed) ALU/`VMV` result — overwriting it is fine.
    ReadDef,
    /// Data established by a load at a known address/shape (for
    /// `L-LOAD-01`); partition-handle semantics, never declared dead.
    Loaded(LoadKey),
}

/// Identity of a load's transfer: same key ⇒ byte-identical transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LoadKey {
    /// `VSALD` with resolved precision and mode.
    Sald { addr: u64, vl: u32, prec: Precision, mode: LdMode },
    /// Official `VLE` at an element width.
    Vle { addr: u64, vl: u32, eew: u32 },
}

/// Abstract interpreter for the lint pass. State persists across
/// segments (like the verifier's): the emitter's dedup state does too.
struct Linter {
    cfg: SpeedConfig,
    report: LintReport,
    seg: usize,
    /// Known scalar registers (`x0` fixed at 0); `None` = unknown.
    xregs: [Option<i64>; 32],
    regs: [RegVal; 32],
    touched: [bool; 32],
    latched: Option<(Precision, u32, StrategyKind)>,
    /// Precision latched before the current one (for thrash detection).
    prev_prec: Option<Precision>,
    /// Tensor op seen since the last precision switch?
    tensor_since_switch: bool,
    vl: Option<u32>,
    sew: u32,
    /// Location of the last instruction seen (anchor for `L-VRF-01`).
    last_loc: (usize, usize),
}

impl Linter {
    fn new(cfg: &SpeedConfig) -> Self {
        let mut xregs = [None; 32];
        xregs[0] = Some(0);
        Linter {
            cfg: *cfg,
            report: LintReport::default(),
            seg: 0,
            xregs,
            regs: [RegVal::Unknown; 32],
            touched: [false; 32],
            latched: None,
            prev_prec: None,
            tensor_since_switch: true,
            vl: None,
            sew: 8,
            last_loc: (0, 0),
        }
    }

    fn emit(&mut self, rule: LintRule, segment: usize, index: usize, message: String) {
        self.report.rule_counts[rule.index()] += 1;
        if self.report.findings.len() >= MAX_FINDINGS {
            self.report.truncated = true;
            return;
        }
        self.report.findings.push(Finding { rule, segment, index, message });
    }

    fn xreg(&self, r: u8) -> Option<i64> {
        if r == 0 {
            Some(0)
        } else {
            self.xregs[r as usize]
        }
    }

    /// Invalidate the `L-LOAD-01` tracking table: tensor ops consume the
    /// partition, stores may alias the loaded region.
    fn clear_loads(&mut self) {
        for r in self.regs.iter_mut() {
            if matches!(r, RegVal::Loaded(_)) {
                *r = RegVal::Unknown;
            }
        }
    }

    /// Record a write to `vd`, firing `L-DEAD-01` when it kills an
    /// unread ALU/`VMV` result, then installing `val`.
    fn write_reg(&mut self, vd: u8, val: RegVal, at: (usize, usize)) {
        if let RegVal::UnreadDef { segment, index } = self.regs[vd as usize] {
            self.emit(
                LintRule::DeadDef,
                at.0,
                at.1,
                format!(
                    "overwrites v{vd} whose result (defined at seg {segment} insn {index}) \
                     was never read — the defining instruction is dead work"
                ),
            );
        }
        self.regs[vd as usize] = val;
    }

    fn step(&mut self, insn: &Insn, idx: usize) {
        self.report.insns += 1;
        let at = (self.seg, idx);
        self.last_loc = at;
        for r in insn.vregs_read().iter().chain(insn.vregs_written().iter()) {
            self.touched[*r as usize] = true;
        }
        // Reads first (an instruction may read the register it writes).
        for r in insn.vregs_read().iter() {
            if matches!(self.regs[*r as usize], RegVal::UnreadDef { .. }) {
                self.regs[*r as usize] = RegVal::ReadDef;
            }
        }
        match *insn {
            Insn::Addi { rd, rs1, imm } => {
                if rd != 0 {
                    self.xregs[rd as usize] = self.xreg(rs1).map(|v| v + imm as i64);
                }
            }
            Insn::Vsetvli { rs1, vtype, .. } => {
                let new_vl = if rs1 == 0 { self.vl } else { self.xreg(rs1).map(|v| v as u32) };
                let same_vl = rs1 == 0 || (new_vl.is_some() && new_vl == self.vl);
                if vtype.sew == self.sew && same_vl && self.vl.is_some() {
                    self.emit(
                        LintRule::RedundantCfg,
                        at.0,
                        at.1,
                        format!(
                            "VSETVLI re-latches the active vl={}/sew={} unchanged",
                            self.vl.unwrap_or(0),
                            self.sew
                        ),
                    );
                }
                self.sew = vtype.sew;
                if rs1 != 0 {
                    self.vl = new_vl;
                }
            }
            Insn::Vsacfg { zimm, .. } => {
                if let Some((prec, ksize, strat)) = Insn::unpack_cfg(zimm) {
                    if let Some((lp, lk, ls)) = self.latched {
                        let eff_ksize = if ksize > 0 { ksize } else { lk };
                        if prec == lp && eff_ksize == lk && strat == ls {
                            self.emit(
                                LintRule::RedundantCfg,
                                at.0,
                                at.1,
                                format!(
                                    "VSACFG re-latches the active \
                                     ({lp:?}, ksize={lk}, {ls:?}) unchanged"
                                ),
                            );
                        } else if prec != lp {
                            if self.prev_prec == Some(prec) && !self.tensor_since_switch {
                                self.emit(
                                    LintRule::RedundantCfg,
                                    at.0,
                                    at.1,
                                    format!(
                                        "precision thrash: switches back to {prec:?} with \
                                         no tensor work at {lp:?} in between"
                                    ),
                                );
                            }
                            self.prev_prec = Some(lp);
                            self.tensor_since_switch = false;
                        }
                        self.latched = Some((prec, eff_ksize, strat));
                    } else {
                        self.latched = Some((prec, ksize.max(1), strat));
                    }
                }
            }
            Insn::VsacfgDim { .. } => {}
            Insn::Vle { vd, rs1, eew } => {
                let key = match (self.xreg(rs1), self.vl) {
                    (Some(addr), Some(vl)) => {
                        Some(LoadKey::Vle { addr: addr as u64, vl, eew })
                    }
                    _ => None,
                };
                self.check_reload(vd, key, at);
            }
            Insn::Vsald { vd, rs1, mode, width } => {
                let prec = match width {
                    WidthSel::FromCfg => self.latched.map(|(p, _, _)| p),
                    WidthSel::Explicit(p) => Some(p),
                };
                let key = match (self.xreg(rs1), self.vl, prec) {
                    (Some(addr), Some(vl), Some(prec)) => {
                        Some(LoadKey::Sald { addr: addr as u64, vl, prec, mode })
                    }
                    _ => None,
                };
                self.check_reload(vd, key, at);
            }
            Insn::Vse { .. } => {
                // A store may overwrite the bytes a tracked load fetched.
                self.clear_loads();
            }
            Insn::Vsam { vd, .. } | Insn::Vsac { vd, .. } => {
                // The MPTU consumes the whole partition and redefines the
                // output handle; drop the reload table.
                self.clear_loads();
                self.write_reg(vd, RegVal::Unknown, at);
            }
            Insn::Vmv { vd, .. }
            | Insn::Vadd { vd, .. }
            | Insn::Vsub { vd, .. }
            | Insn::Vmul { vd, .. }
            | Insn::Vmax { vd, .. }
            | Insn::Vmin { vd, .. }
            | Insn::Vsra { vd, .. }
            | Insn::Vmacc { vd, .. } => {
                self.write_reg(vd, RegVal::UnreadDef { segment: at.0, index: at.1 }, at);
            }
        }
    }

    fn check_reload(&mut self, vd: u8, key: Option<LoadKey>, at: (usize, usize)) {
        if let (Some(k), RegVal::Loaded(prev)) = (key, self.regs[vd as usize]) {
            if k == prev {
                let (addr, bytes) = match k {
                    LoadKey::Sald { addr, vl, prec, .. } => (addr, prec.bytes_for(vl as u64)),
                    LoadKey::Vle { addr, vl, eew } => (addr, vl as u64 * (eew as u64 / 8)),
                };
                let bw = self.cfg.mem_bw_bytes_per_cycle as u64;
                let cost = self.cfg.mem_latency as u64 + bytes.div_ceil(bw).max(1);
                self.emit(
                    LintRule::RedundantLoad,
                    at.0,
                    at.1,
                    format!(
                        "v{vd} already holds the {bytes} B at {addr:#x}; the reload \
                         costs ~{cost} cycles for nothing"
                    ),
                );
            }
        }
        self.write_reg(vd, key.map_or(RegVal::Unknown, RegVal::Loaded), at);
    }

    /// `L-RUN-01`: adjacent same-kind runs whose concatenated body still
    /// validates as a single batch run.
    fn check_adjacent_runs(&mut self, seg: &Segment) {
        for w in seg.runs.windows(2) {
            let (a, b) = (w[0], w[1]);
            if a.start + a.len != b.start || a.kind != b.kind {
                continue;
            }
            let lo = a.start as usize;
            let hi = (b.start + b.len) as usize;
            if hi > seg.insns.len() {
                continue;
            }
            let body = &seg.insns[lo..hi];
            let merged_valid = match a.kind {
                RunKind::Tensor => body.iter().all(|i| *i == body[0]),
                RunKind::Load => body.len() % 2 == 0 && valid_load_pairs(body),
                RunKind::Store => body.len() % 2 == 0 && valid_store_pairs(body),
            };
            if merged_valid {
                self.emit(
                    LintRule::CoalescableRuns,
                    self.seg,
                    lo,
                    format!(
                        "{:?} runs [{lo}, {}) and [{}, {hi}) are adjacent and \
                         pattern-compatible: one run would dispatch them in a single \
                         batch advance",
                        a.kind,
                        (a.start + a.len) as usize,
                        b.start as usize,
                    ),
                );
            }
        }
    }

    fn check_segment(&mut self, seg: &Segment) {
        self.check_adjacent_runs(seg);
        for (idx, insn) in seg.insns.iter().enumerate() {
            self.step(insn, idx);
        }
        self.report.segments += 1;
        self.seg += 1;
    }

    fn finish(mut self) -> LintReport {
        let used = self.touched.iter().filter(|&&b| b).count() as u32;
        if used >= VRF_PRESSURE_REGS {
            let bytes = self.cfg.lanes as u64 * (self.cfg.vrf_bytes() as u64 / 32);
            let bw = self.cfg.mem_bw_bytes_per_cycle as u64;
            let spill = bytes.div_ceil(bw).max(1)
                + self.cfg.mem_latency as u64
                + bytes.div_ceil(bw).max(1);
            let at = self.last_loc;
            self.emit(
                LintRule::VrfPressure,
                at.0,
                at.1,
                format!(
                    "stream touches {used} of 32 vector registers; one more live \
                     value spills ~{bytes} B (≈{spill} cycles per spill/reload \
                     round-trip)"
                ),
            );
        }
        self.report
    }
}

/// Lint a compiled stream. Purely structural — works on any segment
/// sequence (no operator context needed), which is what the engine's
/// [`crate::engine::Engine::set_lint_on_compile`] hook and the mutation
/// tests use.
pub fn lint_segments(cfg: &SpeedConfig, segments: &[Segment]) -> LintReport {
    let mut l = Linter::new(cfg);
    for seg in segments {
        l.check_segment(seg);
    }
    l.finish()
}

/// Advisory residency lint of a mapping's *geometry* — no compilation,
/// no stream walk. Fires `L-RES-01` when the chosen strategy's partial
/// sums cannot stay in the VRF partial partition
/// ([`crate::dataflow::Mapping::partials_in_vrf`]), so every channel pass
/// round-trips partials through external memory. Deliberately separate
/// from [`lint_segments`]/[`lint_op`]: the spill traffic is legal and
/// honestly costed, so stream-level passes (and the zoo-wide CI `lint`
/// sweep) must stay silent on it. Inapplicable `(op, strategy)` pairs
/// yield an empty report.
pub fn lint_mapping(op: &OpDesc, cfg: &SpeedConfig, choice: MappingChoice) -> LintReport {
    let mut report = LintReport::default();
    if !crate::dataflow::applicable(choice.strat, op) {
        return report;
    }
    let m = crate::dataflow::map_op(op, cfg, choice.strat);
    if !m.partials_in_vrf {
        report.rule_counts[LintRule::PartialSpill.index()] += 1;
        report.findings.push(Finding {
            rule: LintRule::PartialSpill,
            segment: 0,
            index: 0,
            message: format!(
                "{} under {} spills partial sums off-chip: the per-lane partial \
                 footprint exceeds the VRF partial partition, so every channel \
                 pass pays a spill/reload round-trip (traffic is charged in the \
                 static cost; see StaticCost::partials_spilled)",
                op.kind, choice.strat
            ),
        });
    }
    report
}

/// Compile `op` under `choice` (streaming — nothing is materialized) and
/// lint the resulting stream.
pub fn lint_op(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
) -> Result<LintReport, SpeedError> {
    op.validate()?;
    let (layout, _) = MemLayout::place(op);
    let mut l = Linter::new(cfg);
    compiler::stream_op_with(op, cfg, choice, &layout, &mut |seg| {
        l.check_segment(&seg);
        Ok(())
    })?;
    Ok(l.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{StreamRun, Vtype};

    fn cfg() -> SpeedConfig {
        SpeedConfig::builder().lanes(4).tile(2, 2).build().unwrap()
    }

    fn seg(insns: Vec<Insn>) -> Segment {
        Segment::new(insns)
    }

    #[test]
    fn dead_alu_def_fires_l_dead_01() {
        // Two VMV splats into v1 with no read in between: the first is dead.
        let s = seg(vec![
            Insn::Vmv { vd: 1, rs1: 0 },
            Insn::Vmv { vd: 1, rs1: 0 },
        ]);
        let r = lint_segments(&cfg(), &[s]);
        assert_eq!(r.count(LintRule::DeadDef), 1);
        assert!(r.findings[0].message.contains("seg 0 insn 0"));
    }

    #[test]
    fn read_def_does_not_fire_l_dead_01() {
        let s = seg(vec![
            Insn::Vmv { vd: 1, rs1: 0 },
            Insn::Vadd { vd: 2, vs1: 1, vs2: 1 }, // reads v1
            Insn::Vmv { vd: 1, rs1: 0 },          // overwrite after read: fine
        ]);
        let r = lint_segments(&cfg(), &[s]);
        assert_eq!(r.count(LintRule::DeadDef), 0);
    }

    #[test]
    fn identical_reload_fires_l_load_01() {
        let cfg = cfg();
        let ld = |vd| Insn::Vsald { vd, rs1: 29, mode: LdMode::Broadcast, width: WidthSel::FromCfg };
        let s = seg(vec![
            Insn::Vsacfg { rd: 0, zimm: Insn::pack_cfg(Precision::Int8, 1, StrategyKind::Mm), uimm: 0 },
            Insn::Addi { rd: 30, rs1: 0, imm: 16 },
            Insn::Vsetvli { rd: 0, rs1: 30, vtype: Vtype::new(8) },
            Insn::Addi { rd: 29, rs1: 0, imm: 256 },
            ld(2),
            Insn::Addi { rd: 29, rs1: 0, imm: 256 },
            ld(2), // same register, same address, same shape: redundant
        ]);
        let r = lint_segments(&cfg, &[s]);
        assert_eq!(r.count(LintRule::RedundantLoad), 1);
        assert!(r.findings[0].message.contains("0x100"));
    }

    #[test]
    fn reload_after_tensor_op_is_not_redundant() {
        let cfg = cfg();
        let ld = |vd| Insn::Vsald { vd, rs1: 29, mode: LdMode::Broadcast, width: WidthSel::FromCfg };
        let s = seg(vec![
            Insn::Vsacfg { rd: 0, zimm: Insn::pack_cfg(Precision::Int8, 1, StrategyKind::Mm), uimm: 0 },
            Insn::Addi { rd: 30, rs1: 0, imm: 16 },
            Insn::Vsetvli { rd: 0, rs1: 30, vtype: Vtype::new(8) },
            Insn::Addi { rd: 29, rs1: 0, imm: 256 },
            ld(2),
            Insn::Vsam { vd: 8, vs1: 2, vs2: 4, stages: 4 }, // consumes the partition
            Insn::Addi { rd: 29, rs1: 0, imm: 256 },
            ld(2),
        ]);
        let r = lint_segments(&cfg, &[s]);
        assert_eq!(r.count(LintRule::RedundantLoad), 0);
    }

    #[test]
    fn identical_vsacfg_relatch_fires_l_cfg_01() {
        let z = Insn::pack_cfg(Precision::Int4, 3, StrategyKind::Ffcs);
        let s = seg(vec![
            Insn::Vsacfg { rd: 0, zimm: z, uimm: 0 },
            Insn::Vsacfg { rd: 0, zimm: z, uimm: 0 },
        ]);
        let r = lint_segments(&cfg(), &[s]);
        assert_eq!(r.count(LintRule::RedundantCfg), 1);
        // The first latch of a stream never fires.
        assert!(r.findings[0].index == 1);
    }

    #[test]
    fn precision_thrash_fires_l_cfg_01() {
        let s = seg(vec![
            Insn::Vsacfg { rd: 0, zimm: Insn::pack_cfg(Precision::Int8, 1, StrategyKind::Mm), uimm: 0 },
            Insn::Vsacfg { rd: 0, zimm: Insn::pack_cfg(Precision::Int4, 1, StrategyKind::Mm), uimm: 0 },
            Insn::Vsacfg { rd: 0, zimm: Insn::pack_cfg(Precision::Int8, 1, StrategyKind::Mm), uimm: 0 },
        ]);
        let r = lint_segments(&cfg(), &[s]);
        assert_eq!(r.count(LintRule::RedundantCfg), 1);
        assert!(r.findings[0].message.contains("thrash"));
    }

    #[test]
    fn adjacent_tensor_runs_fire_l_run_01() {
        let burst = Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 7 };
        let mut s = seg(vec![burst; 6]);
        // Artificially split what the emitter would keep as one run.
        s.runs = vec![
            StreamRun { start: 0, len: 3, kind: RunKind::Tensor },
            StreamRun { start: 3, len: 3, kind: RunKind::Tensor },
        ];
        let r = lint_segments(&cfg(), &[s]);
        assert_eq!(r.count(LintRule::CoalescableRuns), 1);
    }

    #[test]
    fn incompatible_adjacent_runs_do_not_fire() {
        let a = Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 7 };
        let b = Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 3 }; // different burst
        let mut s = seg(vec![a, a, a, b, b, b]);
        s.runs = vec![
            StreamRun { start: 0, len: 3, kind: RunKind::Tensor },
            StreamRun { start: 3, len: 3, kind: RunKind::Tensor },
        ];
        let r = lint_segments(&cfg(), &[s]);
        assert_eq!(r.count(LintRule::CoalescableRuns), 0);
    }

    #[test]
    fn wide_register_footprint_fires_l_vrf_01() {
        let insns: Vec<Insn> = (0..VRF_PRESSURE_REGS as u8).map(|v| Insn::Vmv { vd: v, rs1: 0 }).collect();
        let r = lint_segments(&cfg(), &[seg(insns)]);
        assert_eq!(r.count(LintRule::VrfPressure), 1);
        assert!(r.findings.iter().any(|f| f.rule == LintRule::VrfPressure));
        // Narrow footprints stay quiet.
        let few: Vec<Insn> = (0..8u8).map(|v| Insn::Vmv { vd: v, rs1: 0 }).collect();
        assert!(!lint_segments(&cfg(), &[seg(few)]).fired(LintRule::VrfPressure));
    }

    #[test]
    fn rule_ids_are_unique_and_stable() {
        let ids: Vec<&str> = LintRule::ALL.iter().map(|r| r.id()).collect();
        assert_eq!(
            ids,
            ["L-DEAD-01", "L-LOAD-01", "L-CFG-01", "L-RUN-01", "L-VRF-01", "L-RES-01"]
        );
        for r in LintRule::ALL {
            assert!(r.id().starts_with("L-"));
            assert!(!r.summary().is_empty());
        }
    }

    #[test]
    fn partial_spill_fires_from_mapping_lint_only() {
        use crate::models::ops::OpDesc;
        let cfg = SpeedConfig::reference();
        // Big feature map: FFCS partials round-trip off-chip.
        let big = OpDesc::conv(8, 64, 40, 40, 3, 1, 1, Precision::Int8);
        let choice = MappingChoice::of(StrategyKind::Ffcs);
        let geo = lint_mapping(&big, &cfg, choice);
        assert_eq!(geo.count(LintRule::PartialSpill), 1);
        assert!(geo.findings[0].message.contains("partial"), "{}", geo.findings[0].message);
        // The stream-level pass must stay silent on the same shape: the
        // spill traffic is legal and costed, not a stream defect.
        let stream = lint_op(&big, &cfg, choice).unwrap();
        assert!(!stream.fired(LintRule::PartialSpill));
        // Resident shapes are clean in both passes.
        let small = OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8);
        assert!(lint_mapping(&small, &cfg, choice).is_clean());
        // Inapplicable pairs yield an empty report, not a panic.
        let dw = OpDesc::dwcv(8, 9, 9, 3, 1, 1, Precision::Int8);
        assert!(lint_mapping(&dw, &cfg, choice).is_clean());
    }

    #[test]
    fn report_counts_past_the_finding_cap() {
        let mut insns = Vec::new();
        for _ in 0..(MAX_FINDINGS + 10) {
            insns.push(Insn::Vmv { vd: 1, rs1: 0 });
        }
        let r = lint_segments(&cfg(), &[seg(insns)]);
        assert!(r.truncated);
        assert_eq!(r.findings.len(), MAX_FINDINGS);
        assert_eq!(r.count(LintRule::DeadDef), (MAX_FINDINGS + 9) as u64);
    }
}
